//! End-to-end: train real MDGNNs on the tiny synthetic stream through the
//! full stack (datagen -> batching -> assembly -> EXEC step -> write-back)
//! and require learning to happen.
//!
//! Since the host EXEC backend these tests run EVERYWHERE: `cfg.exec`
//! defaults to "auto", which picks the compiled PJRT artifacts when
//! `artifacts/` exists and the pure-Rust host step otherwise — same ABI,
//! same assertions either way.

use pres::config::ExperimentConfig;
use pres::training::Trainer;

fn cfg(model: &str, pres: bool) -> ExperimentConfig {
    let mut c = ExperimentConfig::default_with("tiny", model, 50, pres);
    c.epochs = 3;
    c.artifacts_dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    c.eval_every = 0;
    c
}

#[test]
fn tgn_learns_link_prediction_above_chance() {
    let mut trainer = Trainer::from_config(&cfg("tgn", false)).unwrap();
    let report = trainer.run().unwrap();
    // 1:1 pos:neg -> random AP = 0.5; the stream is strongly learnable
    assert!(
        report.best_val_ap > 0.7,
        "val AP {} should beat chance by a margin",
        report.best_val_ap
    );
    assert!(report.test_ap > 0.65, "test AP {}", report.test_ap);
    // loss went down across epochs
    let first = report.epochs.first().unwrap().train_bce;
    let last = report.epochs.last().unwrap().train_bce;
    assert!(last < first, "bce {first} -> {last}");
}

#[test]
fn pres_mode_trains_and_tracks_gamma() {
    let mut trainer = Trainer::from_config(&cfg("tgn", true)).unwrap();
    let report = trainer.run().unwrap();
    assert!(report.best_val_ap > 0.65, "val AP {}", report.best_val_ap);
    // gamma stays a valid mixing weight
    let g = report.epochs.last().unwrap().gamma;
    assert!((0.0..=1.0).contains(&g), "gamma {g}");
    // coherence is reported and in range
    let coh = report.epochs.last().unwrap().coherence;
    assert!((-1.0..=1.0).contains(&coh), "coherence {coh}");
}

#[test]
fn jodie_and_apan_run_end_to_end() {
    for model in ["jodie", "apan"] {
        let mut trainer = Trainer::from_config(&cfg(model, true)).unwrap();
        let report = trainer.run().unwrap();
        assert!(
            report.best_val_ap > 0.55,
            "{model}: val AP {}",
            report.best_val_ap
        );
        assert!(report.epochs.iter().all(|e| e.train_loss.is_finite()));
    }
}

#[test]
fn determinism_same_seed_same_curve() {
    let c = cfg("jodie", true);
    let mut a = Trainer::from_config(&c).unwrap();
    let mut b = Trainer::from_config(&c).unwrap();
    let ra = a.train_epoch(0).unwrap();
    let rb = b.train_epoch(0).unwrap();
    assert_eq!(ra.train_loss, rb.train_loss);
    assert_eq!(ra.train_ap, rb.train_ap);
}

#[test]
fn pending_stats_grow_with_batch_size() {
    let mut c_small = cfg("tgn", false);
    c_small.batch_size = 25;
    let mut c_large = cfg("tgn", false);
    c_large.batch_size = 200;
    let t_small = Trainer::from_config(&c_small).unwrap();
    let t_large = Trainer::from_config(&c_large).unwrap();
    let (frac_s, pairs_s) = t_small.pending_summary();
    let (frac_l, pairs_l) = t_large.pending_summary();
    // Def. 2: larger temporal batches accumulate more pending events
    assert!(frac_l > frac_s, "pending fraction {frac_s} -> {frac_l}");
    assert!(pairs_l > pairs_s, "pending pairs {pairs_s} -> {pairs_l}");
}
