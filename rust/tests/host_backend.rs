//! Host EXEC backend gates: the full training loop must run — and learn —
//! with NO artifacts directory at all, and the pipelined loop must stay
//! bit-identical to the sequential one on the host step (`depth = 1,
//! staleness = 0`), mirroring the PJRT-era equivalence contract.
//!
//! Everything here runs in plain `cargo test -q` on a fresh checkout.

use std::path::Path;

use pres::config::{ExperimentConfig, PipelineConfig};
use pres::model::ModelState;
use pres::runtime::{Engine, ExecBackendKind};
use pres::training::Trainer;

/// A config whose artifacts_dir can never exist, so "auto" resolves host.
fn host_cfg(dataset: &str, model: &str, batch: usize, pres: bool) -> ExperimentConfig {
    let mut c = ExperimentConfig::default_with(dataset, model, batch, pres);
    c.artifacts_dir = format!("{}/no-such-artifacts", env!("CARGO_MANIFEST_DIR"));
    c.eval_every = 0;
    c
}

#[test]
fn auto_resolves_to_host_without_artifacts_and_pjrt_needs_them() {
    let missing = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/no-such-artifacts"));
    let engine = Engine::auto(missing, "auto").unwrap();
    assert_eq!(engine.backend(), ExecBackendKind::Host);
    // explicit host never touches the directory
    assert_eq!(Engine::auto(missing, "host").unwrap().backend(), ExecBackendKind::Host);
    // explicit pjrt must fail loudly without a manifest
    assert!(Engine::auto(missing, "pjrt").is_err());
    assert!(Engine::auto(missing, "cuda").is_err());
}

#[test]
fn host_engine_serves_any_batch_size_and_caches_steps() {
    let engine = Engine::host();
    // no compiled batch matrix: odd sizes work too
    let step = engine.step("tgn", 7, "train").unwrap();
    assert_eq!(step.spec.batch, 7);
    let again = engine.step("tgn", 7, "train").unwrap();
    assert!(std::rc::Rc::ptr_eq(&step, &again));
    assert_eq!(engine.compiled_count(), 1);
    // model state initializes from the builtin manifest
    let state = ModelState::init(&engine, "tgn", 0).unwrap();
    assert!(state.len() > 10);
    let g = state.gamma().unwrap();
    assert!((g - 0.98).abs() < 0.01, "initial gamma {g}");
}

#[test]
fn host_loss_descends_on_tiny_wiki_stream() {
    // the satellite smoke test: a scaled-down wiki profile (Zipf-ish
    // bipartite stream with edge features), a few epochs, loss must drop
    let mut c = host_cfg("wiki", "tgn", 100, true);
    c.data_scale = 0.05; // ~1250 events
    c.epochs = 3;
    let mut trainer = Trainer::from_config(&c).unwrap();
    assert_eq!(trainer.engine.backend(), ExecBackendKind::Host);
    let mut bces = Vec::new();
    for e in 0..c.epochs {
        let r = trainer.train_epoch(e).unwrap();
        assert!(r.train_loss.is_finite(), "epoch {e} loss {}", r.train_loss);
        assert!((0.0..=1.0).contains(&r.gamma), "gamma {}", r.gamma);
        bces.push(r.train_bce);
    }
    assert!(
        bces.last().unwrap() < bces.first().unwrap(),
        "bce should descend: {bces:?}"
    );
}

#[test]
fn host_pipelined_is_bit_identical_to_sequential() {
    // the host-backend equivalence gate at depth = 1, staleness = 0 —
    // the host step is a pure function of its literal inputs, so the
    // pipelined loop must reproduce the sequential loop bit for bit
    let mut seq_cfg = host_cfg("tiny", "tgn", 50, true);
    seq_cfg.pipeline = PipelineConfig { depth: 0, bounded_staleness: 0, pool_workers: 0, exec_streams: 1, param_staleness: 0 };
    let mut pipe_cfg = host_cfg("tiny", "tgn", 50, true);
    pipe_cfg.pipeline = PipelineConfig { depth: 1, bounded_staleness: 0, pool_workers: 0, exec_streams: 1, param_staleness: 0 };
    let mut seq = Trainer::from_config(&seq_cfg).unwrap();
    let mut pipe = Trainer::from_config(&pipe_cfg).unwrap();
    for e in 0..2 {
        let rs = seq.train_epoch(e).unwrap();
        let rp = pipe.train_epoch(e).unwrap();
        assert_eq!(rs.train_loss, rp.train_loss, "epoch {e}: loss diverged");
        assert_eq!(rs.train_bce, rp.train_bce, "epoch {e}: bce diverged");
        assert_eq!(rs.train_ap, rp.train_ap, "epoch {e}: AP diverged");
        assert_eq!(rs.coherence, rp.coherence, "epoch {e}: coherence diverged");
        assert_eq!(rs.gamma, rp.gamma, "epoch {e}: gamma diverged");
    }
    assert_eq!(seq.eval_val().unwrap(), pipe.eval_val().unwrap());
}

#[test]
fn host_training_is_deterministic_across_trainer_instances() {
    let c = host_cfg("tiny", "jodie", 50, true);
    let mut a = Trainer::from_config(&c).unwrap();
    let mut b = Trainer::from_config(&c).unwrap();
    let ra = a.train_epoch(0).unwrap();
    let rb = b.train_epoch(0).unwrap();
    assert_eq!(ra.train_loss, rb.train_loss);
    assert_eq!(ra.train_ap, rb.train_ap);
}

#[test]
fn explicit_host_exec_overrides_even_with_artifacts_present() {
    // `--exec host` must win regardless of what's on disk: point at the
    // real artifacts dir (which may or may not exist) and require host
    let mut c = ExperimentConfig::default_with("tiny", "tgn", 25, false);
    c.artifacts_dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    c.exec = "host".into();
    c.epochs = 1;
    let trainer = Trainer::from_config(&c).unwrap();
    assert_eq!(trainer.engine.backend(), ExecBackendKind::Host);
}
