//! Training-semantics integration tests: properties of the orchestrated
//! loop that unit tests can't see (lag-one splice through the EXEC step,
//! PRES vs STANDARD behavioural differences, memory continuity, anchor-set
//! fallbacks).
//!
//! Run everywhere since the host EXEC backend: "auto" resolves to the
//! compiled artifacts when present and the pure-Rust host step otherwise.

use pres::config::ExperimentConfig;
use pres::training::Trainer;

fn cfg(model: &str, pres: bool, batch: usize) -> ExperimentConfig {
    let mut c = ExperimentConfig::default_with("tiny", model, batch, pres);
    c.epochs = 2;
    c.artifacts_dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    c
}

#[test]
fn standard_and_pres_diverge_only_through_pres_machinery() {
    // identical seeds: losses start close (GMM has no observations at the
    // first iteration -> prediction = identity -> correction is a no-op
    // even with pres on) but diverge as trackers accumulate.
    let mut t_std = Trainer::from_config(&cfg("tgn", false, 50)).unwrap();
    let mut t_pres = Trainer::from_config(&{
        let mut c = cfg("tgn", true, 50);
        c.beta = 0.0; // isolate the correction path from the loss term
        c
    })
    .unwrap();
    let r_std = t_std.train_epoch(0).unwrap();
    let r_pres = t_pres.train_epoch(0).unwrap();
    assert!((r_std.train_loss - r_pres.train_loss).abs() < 0.1);
    assert_ne!(r_std.train_loss, r_pres.train_loss);
}

#[test]
fn beta_zero_and_beta_positive_give_different_training() {
    let mut a = Trainer::from_config(&{
        let mut c = cfg("tgn", true, 50);
        c.beta = 0.0;
        c
    })
    .unwrap();
    let mut b = Trainer::from_config(&{
        let mut c = cfg("tgn", true, 50);
        c.beta = 0.5;
        c
    })
    .unwrap();
    let ra = a.train_epoch(0).unwrap();
    let rb = b.train_epoch(0).unwrap();
    // loss includes the penalty term...
    assert!(rb.train_loss > rb.train_bce);
    assert!((ra.train_loss - ra.train_bce).abs() < 1e-9);
    // ...and the parameter trajectories differ
    assert_ne!(ra.train_bce, rb.train_bce);
}

#[test]
fn anchor_fraction_zero_disables_prediction_learning() {
    // with no tracked vertices, predictions are identity; training still
    // works and gamma becomes irrelevant
    let mut c = cfg("jodie", true, 50);
    c.anchor_fraction = 0.0;
    c.epochs = 3;
    let mut tr = Trainer::from_config(&c).unwrap();
    for e in 0..3 {
        let r = tr.train_epoch(e).unwrap();
        assert!(r.train_loss.is_finite());
    }
    let ap = tr.eval_val().unwrap();
    assert!(ap > 0.5, "ap {ap}");
}

#[test]
fn eval_does_not_perturb_training_state() {
    let mut a = Trainer::from_config(&cfg("tgn", true, 50)).unwrap();
    let mut b = Trainer::from_config(&cfg("tgn", true, 50)).unwrap();
    // a: eval_val between epochs; b: straight through. Epoch 1 must match.
    a.train_epoch(0).unwrap();
    let _ = a.eval_val().unwrap();
    let ra = a.train_epoch(1).unwrap();
    b.train_epoch(0).unwrap();
    let rb = b.train_epoch(1).unwrap();
    assert_eq!(ra.train_loss, rb.train_loss, "eval leaked state into training");
}

#[test]
fn larger_batch_fewer_iterations_same_events() {
    let mut a = Trainer::from_config(&cfg("tgn", false, 50)).unwrap();
    let mut b = Trainer::from_config(&cfg("tgn", false, 200)).unwrap();
    a.train_epoch(0).unwrap();
    b.train_epoch(0).unwrap();
    // iteration counters reflect the ~4x difference (one step per batch)
    assert!(a.iteration_ap.len() >= 3 * b.iteration_ap.len());
}

#[test]
fn coherence_penalty_raises_measured_coherence() {
    // the smoothing objective should push memory coherence up vs beta=0
    let mut lo = Trainer::from_config(&{
        let mut c = cfg("tgn", false, 100);
        c.beta = 0.0;
        c.epochs = 3;
        c
    })
    .unwrap();
    let mut hi = Trainer::from_config(&{
        let mut c = cfg("tgn", false, 100);
        c.beta = 1.0;
        c.epochs = 3;
        c
    })
    .unwrap();
    let mut coh_lo = 0.0;
    let mut coh_hi = 0.0;
    for e in 0..3 {
        coh_lo = lo.train_epoch(e).unwrap().coherence;
        coh_hi = hi.train_epoch(e).unwrap().coherence;
    }
    assert!(
        coh_hi > coh_lo,
        "beta=1.0 coherence {coh_hi} should exceed beta=0 coherence {coh_lo}"
    );
}
