//! Sharded-vs-flat memory equivalence: the acceptance gate for the sharded
//! memory store. Routing only changes the physical layout, and SPLICE /
//! WRITEBACK are pure `f32` copies, so at `depth = 1, staleness = 0` every
//! shard count must reproduce the flat store bit-for-bit — same epoch
//! losses, same APs, same memory trajectory.
//!
//! Mirrors `tests/pipeline_equivalence.rs`: the trainer-level tests run
//! everywhere since the host EXEC backend ("auto" resolves to compiled
//! artifacts when present, the pure-Rust host step otherwise); the
//! host-level epoch harness below additionally drives the full PREP →
//! SPLICE → (simulated) EXEC → WRITEBACK loop against both memory
//! backends directly, with no model in the loop.

use std::sync::Arc;

use pres::batching::{partition, BatchPlan};
use pres::config::{ExperimentConfig, PipelineConfig};
use pres::datagen;
use pres::memory::{
    make_backend, make_backend_pooled, GmmTrackers, MemoryBackend, ShardRouter,
    ShardedMemoryStore,
};
use pres::pipeline::{fill_prep_from, negative_stream, PrepBatch};
use pres::runtime::Dims;
use pres::sampler::{NegativeSampler, NeighborIndex};
use pres::training::{Assembler, HostBatch, Trainer};
use pres::util::pool::WorkerPool;
use pres::util::rng::Pcg32;

fn cfg(model: &str, pres: bool, batch: usize) -> ExperimentConfig {
    let mut c = ExperimentConfig::default_with("tiny", model, batch, pres);
    c.epochs = 2;
    c.artifacts_dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    c
}

// ---------------------------------------------------------------- host level

fn dims() -> Dims {
    Dims {
        d_mem: 4,
        d_msg: 4,
        d_edge: 2,
        d_time: 2,
        k_nbr: 3,
        heads: 1,
        d_emb: 4,
        clf_batch: 8,
    }
}

/// Drive a full epoch of PREP → SPLICE → simulated EXEC → WRITEBACK against
/// one memory backend and return the final logical snapshot. The simulated
/// step output is a pure function of the iteration, so two backends fed the
/// same stream diverge only if gather/scatter/routing diverge.
fn run_host_epoch<S: MemoryBackend>(
    store: &mut S,
    d: Dims,
    b: usize,
    pool: &WorkerPool,
) -> pres::memory::MemorySnapshot {
    let ds = datagen::generate(&datagen::tiny_profile(), 5);
    let plans: Vec<BatchPlan> = partition(0..ds.log.len(), b)
        .into_iter()
        .map(|r| BatchPlan::build(&ds.log, r))
        .collect();
    let sampler = NegativeSampler::new(&ds.log);
    let asm = Assembler::new(d);
    let mut host = HostBatch::new("tgn", b, d);
    let mut nbr = NeighborIndex::new(ds.log.num_nodes, d.k_nbr);
    let mut gmm = GmmTrackers::new(ds.log.num_nodes, d.d_mem, 1.0, 0);
    for i in 1..plans.len() {
        let (prev, cur) = (&plans[i - 1], &plans[i]);
        let base = negative_stream(7, 0, i);
        sampler.sample_batch_rowwise(
            &ds.log,
            cur.range.clone(),
            &base,
            &mut host.prep.negatives,
            pool,
        );
        pres::pipeline::fill_prep_from_with(
            &mut host.prep, &ds.log, prev, cur, store.router(), pool,
        );
        asm.splice(&mut host, &ds.log, prev, &*store, &nbr, None, &gmm, true, 0.1);
        // "EXEC": a deterministic stand-in for the step's corrected states
        let mut step_rng = Pcg32::new(0xE0EC ^ i as u64);
        let u_sbar: Vec<f32> =
            (0..prev.rows() * d.d_mem).map(|_| step_rng.range_f32(-1.0, 1.0)).collect();
        asm.commit(&host, &ds.log, prev, &u_sbar, None, &mut *store, &mut nbr, None, &mut gmm, true);
    }
    store.snapshot()
}

#[test]
fn host_epoch_is_bit_identical_across_shard_counts() {
    let d = dims();
    let num_nodes = datagen::generate(&datagen::tiny_profile(), 5).log.num_nodes;
    let pool = WorkerPool::global();
    let mut flat = make_backend(num_nodes, d.d_mem, 1);
    let baseline = run_host_epoch(&mut flat, d, 25, pool);
    for shards in [2usize, 4, 7] {
        let mut sharded = make_backend(num_nodes, d.d_mem, shards);
        assert_eq!(sharded.router().n_shards, shards as u32);
        let snap = run_host_epoch(&mut sharded, d, 25, pool);
        assert_eq!(
            snap, baseline,
            "{shards}-shard epoch diverged from the flat store"
        );
    }
}

#[test]
fn host_epoch_survives_forced_parallel_paths() {
    // same harness, but with the serial/parallel crossover forced to 0 so
    // every gather/scatter takes the pooled path even at toy sizes
    let d = dims();
    let num_nodes = datagen::generate(&datagen::tiny_profile(), 5).log.num_nodes;
    let pool = Arc::new(WorkerPool::new(4));
    let mut flat = make_backend(num_nodes, d.d_mem, 1);
    let baseline = run_host_epoch(&mut flat, d, 25, &pool);
    let mut forced = ShardedMemoryStore::new(num_nodes, d.d_mem, 4)
        .with_par_threshold(0)
        .with_pool(pool.clone());
    let snap = run_host_epoch(&mut forced, d, 25, &pool);
    assert_eq!(snap, baseline, "parallel-path epoch diverged from the flat store");
}

#[test]
fn host_epoch_is_bit_identical_for_every_shard_and_worker_combination() {
    // the PR-3 acceptance sweep: (shards, pool lanes) ∈ {2,4} × {1,2,4,8}
    // all reproduce the flat baseline bit-for-bit, with the parallel path
    // forced so every gather/scatter actually runs through the pool
    let d = dims();
    let num_nodes = datagen::generate(&datagen::tiny_profile(), 5).log.num_nodes;
    let serial = Arc::new(WorkerPool::new(1));
    let mut flat = make_backend(num_nodes, d.d_mem, 1);
    let baseline = run_host_epoch(&mut flat, d, 25, &serial);
    for shards in [2usize, 4] {
        for lanes in [1usize, 2, 4, 8] {
            let pool = Arc::new(WorkerPool::new(lanes));
            let mut store = ShardedMemoryStore::new(num_nodes, d.d_mem, shards)
                .with_par_threshold(0)
                .with_pool(pool.clone());
            let snap = run_host_epoch(&mut store, d, 25, &pool);
            assert_eq!(
                snap, baseline,
                "epoch diverged at shards={shards}, lanes={lanes}"
            );
        }
    }
}

#[test]
fn pooled_backend_constructor_matches_default_pool_backend() {
    // make_backend_pooled with an explicit pool is the same machine as
    // make_backend on the process pool — layout and values
    let d = dims();
    let num_nodes = datagen::generate(&datagen::tiny_profile(), 5).log.num_nodes;
    let pool = Arc::new(WorkerPool::new(2));
    let mut a = make_backend_pooled(num_nodes, d.d_mem, 3, pool.clone());
    let mut b = make_backend(num_nodes, d.d_mem, 3);
    let snap_a = run_host_epoch(&mut a, d, 25, &pool);
    let snap_b = run_host_epoch(&mut b, d, 25, WorkerPool::global());
    assert_eq!(snap_a, snap_b);
}

#[test]
fn prep_routes_match_backend_router_through_the_public_surface() {
    // the routes a PREP fill computes for a backend's router must agree
    // with the backend's own routing — the contract that lets SPLICE trust
    // prefetched routes blindly
    let ds = datagen::generate(&datagen::tiny_profile(), 5);
    let plans: Vec<BatchPlan> = partition(0..ds.log.len(), 25)
        .into_iter()
        .map(|r| BatchPlan::build(&ds.log, r))
        .collect();
    let store = ShardedMemoryStore::new(ds.log.num_nodes, 4, 3);
    let router: ShardRouter = store.router();
    let mut prep = PrepBatch::new(25, ds.log.d_edge);
    fill_prep_from(&mut prep, &ds.log, &plans[0], &plans[1], router);
    assert_eq!(prep.routes.n_shards, 3);
    for (r, &v) in prep.routes.u_other.iter().zip(&prep.u_other) {
        assert_eq!(*r, router.route(v));
    }
}

// ------------------------------------------------------------- trainer level

#[test]
fn sharded_training_is_bit_identical_to_flat() {
    let flat_cfg = cfg("tgn", true, 50);
    assert_eq!(flat_cfg.memory_shards, 1);
    let mut flat = Trainer::from_config(&flat_cfg).unwrap();
    let mut flat_epochs = Vec::new();
    for e in 0..2 {
        flat_epochs.push(flat.train_epoch(e).unwrap());
    }
    let flat_val = flat.eval_val().unwrap();

    for shards in [2usize, 4] {
        let mut c = cfg("tgn", true, 50);
        c.memory_shards = shards;
        let mut tr = Trainer::from_config(&c).unwrap();
        for (e, flat_r) in flat_epochs.iter().enumerate() {
            let r = tr.train_epoch(e).unwrap();
            assert_eq!(
                r.train_loss, flat_r.train_loss,
                "epoch {e}: {shards}-shard loss diverged from flat"
            );
            assert_eq!(r.train_bce, flat_r.train_bce, "epoch {e} ({shards} shards): bce");
            assert_eq!(r.train_ap, flat_r.train_ap, "epoch {e} ({shards} shards): train AP");
            assert_eq!(r.coherence, flat_r.coherence, "epoch {e} ({shards} shards): coherence");
            assert_eq!(r.gamma, flat_r.gamma, "epoch {e} ({shards} shards): gamma");
        }
        assert_eq!(tr.eval_val().unwrap(), flat_val, "{shards}-shard val AP diverged");
    }
}

#[test]
fn training_is_bit_identical_for_every_pool_worker_count() {
    // depth=1/staleness=0 with shards ∈ {1, 4} and --pool-workers ∈
    // {1, 2, 4}: every combination must match the serial flat baseline
    let flat_cfg = {
        let mut c = cfg("tgn", true, 50);
        c.pipeline.pool_workers = 1; // fully serial baseline
        c
    };
    let mut flat = Trainer::from_config(&flat_cfg).unwrap();
    let mut baseline = Vec::new();
    for e in 0..2 {
        baseline.push(flat.train_epoch(e).unwrap());
    }
    for shards in [1usize, 4] {
        for workers in [2usize, 4] {
            let mut c = cfg("tgn", true, 50);
            c.memory_shards = shards;
            c.pipeline.pool_workers = workers;
            let mut tr = Trainer::from_config(&c).unwrap();
            for (e, want) in baseline.iter().enumerate() {
                let r = tr.train_epoch(e).unwrap();
                assert_eq!(
                    r.train_loss, want.train_loss,
                    "epoch {e}: loss diverged at shards={shards}, workers={workers}"
                );
                assert_eq!(
                    r.train_ap, want.train_ap,
                    "epoch {e}: AP diverged at shards={shards}, workers={workers}"
                );
            }
        }
    }
}

#[test]
fn sharded_training_matches_flat_in_sequential_mode_too() {
    // depth = 0 exercises the inline-PREP path's router plumbing
    let mut a_cfg = cfg("jodie", false, 50);
    a_cfg.pipeline = PipelineConfig { depth: 0, bounded_staleness: 0, pool_workers: 0, exec_streams: 1, param_staleness: 0 };
    let mut b_cfg = cfg("jodie", false, 50);
    b_cfg.pipeline = PipelineConfig { depth: 0, bounded_staleness: 0, pool_workers: 0, exec_streams: 1, param_staleness: 0 };
    b_cfg.memory_shards = 4;
    let mut a = Trainer::from_config(&a_cfg).unwrap();
    let mut b = Trainer::from_config(&b_cfg).unwrap();
    for e in 0..2 {
        let ra = a.train_epoch(e).unwrap();
        let rb = b.train_epoch(e).unwrap();
        assert_eq!(ra.train_loss, rb.train_loss, "epoch {e}");
        assert_eq!(ra.train_ap, rb.train_ap, "epoch {e}");
    }
}

#[test]
fn apan_mailbox_path_is_shard_agnostic() {
    // APAN adds the mailbox substrate to SPLICE/WRITEBACK; sharding only
    // touches the memory store, so results must stay bit-identical
    let mut a = Trainer::from_config(&cfg("apan", true, 50)).unwrap();
    let mut c = cfg("apan", true, 50);
    c.memory_shards = 2;
    let mut b = Trainer::from_config(&c).unwrap();
    for e in 0..2 {
        let ra = a.train_epoch(e).unwrap();
        let rb = b.train_epoch(e).unwrap();
        assert_eq!(ra.train_loss, rb.train_loss, "epoch {e}");
    }
}
