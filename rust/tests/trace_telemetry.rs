//! Observability acceptance gates: the traced 2-stream epoch exports a
//! Chrome trace with per-thread rows and overlapping spans, tracing never
//! perturbs training results, `EpochReport` carries per-stage latency
//! quantiles, and the CLI end-to-end path (`--trace-out`/`--metrics-out`)
//! writes files that parse.
//!
//! The span recorder and telemetry counters are process-global, so every
//! test that toggles them serializes on one mutex — the OTHER integration
//! binaries run as separate processes and are unaffected.

use std::sync::Mutex;

use pres::config::{ExperimentConfig, PipelineConfig};
use pres::trace;
use pres::training::Trainer;
use pres::util::json::Json;

static GATE: Mutex<()> = Mutex::new(());

fn cfg(streams: usize) -> ExperimentConfig {
    let mut c = ExperimentConfig::default_with("tiny", "tgn", 50, true);
    c.epochs = 2;
    c.exec = "host".into();
    c.artifacts_dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    c.pipeline = PipelineConfig {
        depth: 2,
        bounded_staleness: 1,
        pool_workers: 0,
        exec_streams: streams,
        param_staleness: 0,
    };
    c
}

#[test]
fn traced_two_stream_epoch_exports_thread_rows_with_overlapping_spans() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    trace::stop();
    trace::clear();
    trace::start();
    let mut tr = Trainer::from_config(&cfg(2)).unwrap();
    for e in 0..2 {
        tr.train_epoch(e).unwrap();
    }
    drop(tr); // lanes + PREP joined: rings are quiescent
    trace::stop();
    let doc = trace::chrome_trace_json();
    trace::clear();

    let parsed = Json::parse(&doc.to_string()).unwrap();
    let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty(), "traced run must produce events");

    // one named row per instrumented thread: PREP and the EXEC lanes at
    // minimum (the coordinator row is named after the test thread)
    let names: Vec<String> = events
        .iter()
        .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "M")
        .map(|e| {
            e.get("args")
                .unwrap()
                .get("name")
                .unwrap()
                .as_str()
                .unwrap()
                .to_string()
        })
        .collect();
    assert!(
        names.iter().any(|n| n.starts_with("pres-prep")),
        "missing PREP thread row in {names:?}"
    );
    assert!(
        names.iter().any(|n| n.starts_with("pres-exec")),
        "missing EXEC lane row in {names:?}"
    );

    // complete events carry stage names and land on >= 2 distinct threads
    let spans: Vec<(u64, f64, f64)> = events
        .iter()
        .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "X")
        .map(|e| {
            (
                e.get("tid").unwrap().as_u64().unwrap(),
                e.get("ts").unwrap().as_f64().unwrap(),
                e.get("dur").unwrap().as_f64().unwrap(),
            )
        })
        .collect();
    let stage_names: Vec<String> = events
        .iter()
        .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "X")
        .map(|e| e.get("name").unwrap().as_str().unwrap().to_string())
        .collect();
    assert!(stage_names.iter().any(|n| n == "prep"), "no PREP spans");
    assert!(stage_names.iter().any(|n| n == "exec"), "no EXEC spans");
    let mut tids: Vec<u64> = spans.iter().map(|s| s.0).collect();
    tids.sort_unstable();
    tids.dedup();
    assert!(tids.len() >= 2, "spans landed on {} thread(s)", tids.len());

    // pipelining means some pair of spans on DIFFERENT threads overlaps in
    // the shared clock domain (PREP runs ahead while the coordinator works)
    let overlap = spans.iter().enumerate().any(|(i, a)| {
        spans[i + 1..]
            .iter()
            .any(|b| a.0 != b.0 && a.1 < b.1 + b.2 && b.1 < a.1 + a.2)
    });
    assert!(overlap, "expected cross-thread overlapping spans");
}

#[test]
fn tracing_enabled_is_bit_identical_to_disabled() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    trace::stop();
    trace::clear();

    // instrumented-but-disabled run (the default fast path)
    let mut plain = Trainer::from_config(&cfg(2)).unwrap();
    let mut plain_epochs = Vec::new();
    for e in 0..2 {
        plain_epochs.push(plain.train_epoch(e).unwrap());
    }
    let plain_val = plain.eval_val().unwrap();

    // everything on: span recording + telemetry counters
    trace::start();
    trace::telemetry::enable_metrics();
    let mut traced = Trainer::from_config(&cfg(2)).unwrap();
    for (e, want) in plain_epochs.iter().enumerate() {
        let r = traced.train_epoch(e).unwrap();
        assert_eq!(r.train_loss, want.train_loss, "epoch {e}: tracing changed loss");
        assert_eq!(r.train_bce, want.train_bce, "epoch {e}");
        assert_eq!(r.train_ap, want.train_ap, "epoch {e}");
        assert_eq!(r.coherence, want.coherence, "epoch {e}");
        assert_eq!(r.gamma, want.gamma, "epoch {e}");
        assert_eq!(r.splice_lag_max, want.splice_lag_max, "epoch {e}");
    }
    let traced_val = traced.eval_val().unwrap();
    trace::stop();
    trace::telemetry::disable_metrics();
    drop(traced);
    trace::clear();
    trace::telemetry::reset();
    assert_eq!(traced_val, plain_val, "tracing changed the memory trajectory");
}

#[test]
fn epoch_report_carries_per_stage_latency_quantiles() {
    // gated too: a concurrent test enabling tracing must not race this
    // trainer's span pushes against the other tests' clear() calls
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    // histograms are part of EpochTimer, recorded regardless of tracing
    let mut tr = Trainer::from_config(&cfg(2)).unwrap();
    let r = tr.train_epoch(0).unwrap();
    assert!(!r.stage_quantiles.is_empty(), "no stage quantiles reported");
    let exec = r
        .stage_quantiles
        .iter()
        .find(|q| q.stage == "exec")
        .expect("exec stage missing from quantiles");
    assert!(exec.count > 0, "exec histogram recorded no samples");
    assert!(exec.p50 > 0.0, "exec p50 must be positive");
    assert!(
        exec.p50 <= exec.p95 && exec.p95 <= exec.p99,
        "quantiles must be monotone: p50 {} p95 {} p99 {}",
        exec.p50,
        exec.p95,
        exec.p99
    );
    let splice = r
        .stage_quantiles
        .iter()
        .find(|q| q.stage == "splice_lag")
        .expect("splice_lag missing from quantiles");
    assert!(splice.count > 0, "every spliced batch records a lag sample");
    // the report serializes without NaN/Infinity leaking into the JSON
    let text = r.to_json().to_string();
    assert!(Json::parse(&text).is_ok(), "EpochReport JSON must parse");
    assert!(!text.contains("NaN") && !text.contains("inf"), "{text}");
}

#[test]
fn cli_trace_and_metrics_outputs_parse_end_to_end() {
    let dir = std::env::temp_dir().join("pres_trace_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let trace_path = dir.join("trace.json");
    let metrics_path = dir.join("metrics.jsonl");

    let out = std::process::Command::new(env!("CARGO_BIN_EXE_pres-train"))
        .args([
            "train",
            "--dataset",
            "tiny",
            "--model",
            "tgn",
            "--batch",
            "50",
            "--epochs",
            "2",
            "--exec",
            "host",
            "--pipeline-depth",
            "2",
            "--staleness",
            "1",
            "--exec-streams",
            "2",
            "--log-level",
            "info",
        ])
        .arg(format!("--trace-out={}", trace_path.display()))
        .arg(format!("--metrics-out={}", metrics_path.display()))
        .output()
        .expect("launching pres-train");
    assert!(
        out.status.success(),
        "pres-train failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );

    // the trace is a valid Chrome trace_event document with named rows
    let trace_text = std::fs::read_to_string(&trace_path).unwrap();
    let trace_doc = Json::parse(&trace_text).unwrap();
    let events = trace_doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty(), "CLI trace must contain events");
    assert!(trace_text.contains("pres-prep"), "missing PREP row");
    assert!(trace_text.contains("pres-exec"), "missing EXEC lane row");

    // one metrics record per epoch, each a parseable object with the
    // epoch report + telemetry delta
    let metrics_text = std::fs::read_to_string(&metrics_path).unwrap();
    let lines: Vec<&str> = metrics_text.lines().collect();
    assert_eq!(lines.len(), 2, "one JSONL record per epoch");
    for (i, line) in lines.iter().enumerate() {
        let rec = Json::parse(line).unwrap();
        assert_eq!(rec.get("epoch").unwrap().as_usize().unwrap(), i);
        assert!(rec.get("stage_quantiles").unwrap().as_arr().is_ok());
        let tele = rec.get("telemetry").unwrap();
        assert!(tele.get("pool_occupancy").unwrap().as_f64().is_ok());
        assert!(tele.get("prep_depth_hwm").unwrap().as_f64().unwrap() >= 0.0);
    }

    let _ = std::fs::remove_dir_all(&dir);
}
