//! Integration: resolve an EXEC engine (compiled PJRT artifacts when
//! `artifacts/` exists, the pure-Rust host backend otherwise), execute
//! steps, and verify the ABI end-to-end (output arity, finite numerics,
//! STANDARD-mode semantics reproduced through the executed path).

use pres::model::ModelState;
use pres::runtime::engine::{fetch_f32, fetch_scalar, lit_f32, lit_i32, lit_scalar};
use pres::runtime::{DType, Engine};
use pres::util::rng::Pcg32;
use xla::Literal;

fn engine() -> Engine {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Engine::auto(&dir, "auto").expect("resolving EXEC engine")
}

/// Build zero-ish but well-formed data inputs for a step (everything after
/// the first `skip` ABI slots).
fn data_literals(
    spec: &pres::runtime::ArtifactSpec,
    skip: usize,
    pres_on: f32,
    seed: u64,
) -> Vec<Literal> {
    let mut rng = Pcg32::new(seed);
    spec.inputs[skip..]
        .iter()
        .map(|t| match t.dtype {
            DType::I32 => lit_i32(&vec![-1i32; t.elems()], &t.shape).unwrap(),
            DType::F32 => {
                let host: Vec<f32> = if t.name == "pres_on" {
                    vec![pres_on]
                } else if t.name == "beta" || t.name == "lr" {
                    vec![0.01]
                } else if t.name == "step_t" {
                    vec![1.0]
                } else if t.name.ends_with("_mask") || t.name == "u_wmask" {
                    (0..t.elems()).map(|_| (rng.below(2)) as f32).collect()
                } else if t.name.ends_with("_dt") {
                    (0..t.elems()).map(|_| rng.f32() * 3.0).collect()
                } else {
                    (0..t.elems()).map(|_| rng.normal() * 0.3).collect()
                };
                lit_f32(&host, &t.shape).unwrap()
            }
        })
        .collect()
}

fn clone_lits(lits: &[Literal]) -> Vec<Literal> {
    // Literal has no Clone; round-trip through raw parts
    lits.iter()
        .map(|l| {
            let n = l.element_count();
            let mut host = vec![0.0f32; n];
            l.copy_raw_to(&mut host).unwrap();
            let shape = l.array_shape().unwrap();
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            lit_f32(&host, &dims).unwrap()
        })
        .collect()
}

#[test]
fn eval_step_runs_with_correct_arity_and_standard_semantics() {
    let engine = engine();
    let step = engine.step("tgn", 25, "eval").unwrap();
    let state = ModelState::init(&engine, "tgn", 0).unwrap();

    // STANDARD mode (pres_on = 0): delta must be zero, outputs finite
    let mut args = clone_lits(&state.params);
    args.extend(data_literals(&step.spec, state.len(), 0.0, 1));
    let outputs = step.run(&args).expect("execute");
    assert_eq!(outputs.len(), step.spec.outputs.len());

    for (lit, spec) in outputs.iter().zip(&step.spec.outputs) {
        if spec.dtype == DType::F32 {
            let mut host = vec![0.0f32; spec.elems()];
            fetch_f32(lit, &mut host).unwrap();
            assert!(
                host.iter().all(|x| x.is_finite()),
                "output {} has non-finite values",
                spec.name
            );
        }
    }

    let delta_idx = step.spec.output_index("u_delta").unwrap();
    let mut delta = vec![1.0f32; step.spec.outputs[delta_idx].elems()];
    fetch_f32(&outputs[delta_idx], &mut delta).unwrap();
    assert!(delta.iter().all(|&x| x == 0.0), "STANDARD mode delta != 0");
}

#[test]
fn train_step_updates_params_and_reports_loss() {
    let engine = engine();
    let step = engine.step("tgn", 25, "train").unwrap();
    let mut state = ModelState::init(&engine, "tgn", 0).unwrap();
    let n = state.len();
    let before = state.fetch("msg_w1").unwrap();

    let mut args = clone_lits(&state.params);
    args.extend(clone_lits(&state.adam_m));
    args.extend(clone_lits(&state.adam_v));
    args.extend(data_literals(&step.spec, 3 * n, 1.0, 2));
    assert_eq!(args.len(), step.spec.inputs.len());
    let mut outputs = step.run(&args).expect("train execute");
    assert_eq!(outputs.len(), step.spec.outputs.len());

    let loss_idx = step.spec.output_index("loss").unwrap();
    let loss = fetch_scalar(&outputs[loss_idx]).unwrap();
    assert!(loss.is_finite() && loss > 0.0, "loss {loss}");

    state.absorb_outputs(&mut outputs);
    assert_eq!(outputs.len(), step.spec.outputs.len() - 3 * n);
    let after = state.fetch("msg_w1").unwrap();
    assert_ne!(before, after, "Adam step must move parameters");
    assert_eq!(state.step, 1);
}

#[test]
fn pres_mode_produces_innovation() {
    let engine = engine();
    let step = engine.step("tgn", 25, "eval").unwrap();
    let state = ModelState::init(&engine, "tgn", 0).unwrap();
    let mut args = clone_lits(&state.params);
    args.extend(data_literals(&step.spec, state.len(), 1.0, 3));
    let outputs = step.run(&args).unwrap();
    let delta_idx = step.spec.output_index("u_delta").unwrap();
    let mut delta = vec![0.0f32; step.spec.outputs[delta_idx].elems()];
    fetch_f32(&outputs[delta_idx], &mut delta).unwrap();
    assert!(
        delta.iter().any(|&x| x.abs() > 1e-6),
        "PRES mode should produce non-zero innovation"
    );
}

#[test]
fn all_models_compile_and_run_eval() {
    let engine = engine();
    for model in ["tgn", "jodie", "apan"] {
        let step = engine.step(model, 25, "eval").unwrap();
        let state = ModelState::init(&engine, model, 0).unwrap();
        let mut args = clone_lits(&state.params);
        args.extend(data_literals(&step.spec, state.len(), 0.0, 4));
        let outputs = step.run(&args).unwrap_or_else(|e| panic!("{model}: {e}"));
        let loss_idx = step.spec.output_index("loss").unwrap();
        let loss = fetch_scalar(&outputs[loss_idx]).unwrap();
        assert!(loss.is_finite(), "{model} loss {loss}");
    }
}

#[test]
fn compile_cache_reuses_executables() {
    let engine = engine();
    let a = engine.step("jodie", 25, "eval").unwrap();
    let b = engine.step("jodie", 25, "eval").unwrap();
    assert!(std::rc::Rc::ptr_eq(&a, &b));
    assert_eq!(engine.compiled_count(), 1);
}

#[test]
fn scalar_literal_roundtrip() {
    let lit = lit_scalar(3.25).unwrap();
    assert_eq!(fetch_scalar(&lit).unwrap(), 3.25);
}
