//! Naive-vs-blocked GEMM equivalence: the epoch-level acceptance gate for
//! the blocked kernel backend.
//!
//! `--gemm naive` lifts the pre-gemm scalar loops verbatim, so it is the
//! bit-exact reference. `--gemm blocked` keeps NN-shape products in the
//! same per-element accumulation order (bitwise equal) but reorders the
//! TN-accumulate shape and the dot-product reduction — per-element
//! `|Δ| ≤ 1e-5 · k · max|a| · max|b| + 1e-6` (see `runtime/gemm.rs`).
//! Those deltas feed back through training, so the epoch-level contract is
//! a loose one: trajectories must track within the tolerances below, and
//! both backends must train to a working model. The per-kernel tolerance
//! itself is pinned by the property tests in `runtime/gemm.rs`; the
//! single-step contract by `runtime/host_step.rs`.

use pres::config::ExperimentConfig;
use pres::runtime::GemmBackendKind;
use pres::training::Trainer;

fn cfg(model: &str, gemm: &str) -> ExperimentConfig {
    let mut c = ExperimentConfig::default_with("tiny", model, 50, true);
    c.epochs = 2;
    c.artifacts_dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    // the gemm choice only reaches kernels on the host backend — pin it so
    // the gate stays meaningful if compiled artifacts ever appear in-tree
    c.exec = "host".to_string();
    c.gemm = gemm.to_string();
    c
}

#[test]
fn naive_and_blocked_agree_within_tolerance() {
    // the tolerance contract at epoch granularity: float-summation-order
    // deltas compound over ~60 steps but must stay in lockstep on every
    // aggregate the trainer reports, and neither trajectory may collapse
    let mut naive = Trainer::from_config(&cfg("tgn", "naive")).unwrap();
    let mut blocked = Trainer::from_config(&cfg("tgn", "blocked")).unwrap();
    for e in 0..2 {
        let rn = naive.train_epoch(e).unwrap();
        let rb = blocked.train_epoch(e).unwrap();
        assert!(rn.train_loss.is_finite() && rb.train_loss.is_finite(), "epoch {e}");
        let tol = 5e-3 * (1.0 + rn.train_loss.abs());
        assert!(
            (rn.train_loss - rb.train_loss).abs() <= tol,
            "epoch {e}: naive loss {} vs blocked {} exceeds tolerance {tol}",
            rn.train_loss,
            rb.train_loss
        );
        assert!(
            (rn.train_bce - rb.train_bce).abs() <= 5e-3 * (1.0 + rn.train_bce.abs()),
            "epoch {e}: bce diverged ({} vs {})",
            rn.train_bce,
            rb.train_bce
        );
        // AP is a ranking metric — near-tied pairs may flip on 1e-6 logit
        // deltas, so it gets the loosest budget
        assert!(
            (rn.train_ap - rb.train_ap).abs() <= 0.05,
            "epoch {e}: train AP diverged ({} vs {})",
            rn.train_ap,
            rb.train_ap
        );
        assert!(
            (rn.gamma - rb.gamma).abs() <= 1e-2 * (1.0 + rn.gamma.abs()),
            "epoch {e}: gamma diverged ({} vs {})",
            rn.gamma,
            rb.gamma
        );
    }
    let ap_n = naive.eval_val().unwrap();
    let ap_b = blocked.eval_val().unwrap();
    assert!(ap_n > 0.5, "naive val AP collapsed: {ap_n}");
    assert!(ap_b > 0.5, "blocked val AP collapsed: {ap_b}");
    assert!(
        (ap_n - ap_b).abs() <= 0.05,
        "val AP diverged: naive {ap_n} vs blocked {ap_b}"
    );
}

#[test]
fn same_backend_runs_are_bit_identical() {
    // each backend is individually deterministic: whatever order a kernel
    // sums in, it sums in that order every run — reordering is allowed
    // between backends, never between runs
    for gemm in ["naive", "blocked"] {
        let mut a = Trainer::from_config(&cfg("tgn", gemm)).unwrap();
        let mut b = Trainer::from_config(&cfg("tgn", gemm)).unwrap();
        for e in 0..2 {
            let ra = a.train_epoch(e).unwrap();
            let rb = b.train_epoch(e).unwrap();
            assert_eq!(ra.train_loss, rb.train_loss, "{gemm}, epoch {e}: loss drifted");
            assert_eq!(ra.train_ap, rb.train_ap, "{gemm}, epoch {e}: AP drifted");
            assert_eq!(ra.gamma, rb.gamma, "{gemm}, epoch {e}: gamma drifted");
        }
        assert_eq!(
            a.eval_val().unwrap(),
            b.eval_val().unwrap(),
            "{gemm}: post-training memory state drifted between identical runs"
        );
    }
}

#[test]
fn gemm_backend_selection_flows_to_engine_and_report() {
    // --gemm / config "gemm" -> Engine::set_host_gemm -> EpochReport
    for (choice, want) in [
        ("auto", GemmBackendKind::Blocked),
        ("blocked", GemmBackendKind::Blocked),
        ("naive", GemmBackendKind::Naive),
    ] {
        let mut c = cfg("tgn", choice);
        c.epochs = 1;
        let mut tr = Trainer::from_config(&c).unwrap();
        assert_eq!(
            tr.engine.host_gemm(),
            Some(want),
            "'{choice}' resolved to the wrong kernel backend"
        );
        let r = tr.train_epoch(0).unwrap();
        assert_eq!(r.gemm_backend, want.name(), "'{choice}': report names the wrong backend");
        // the always-on counters attribute EXEC time to the kernels. The
        // counters are process-global, so concurrently-running tests in
        // this binary can inflate the epoch delta — assert presence and
        // sanity, not an upper bound
        assert!(r.gemm_secs > 0.0, "'{choice}': an epoch of matmuls took zero gemm time");
        assert!(
            r.gemm_share > 0.0 && r.gemm_share.is_finite(),
            "'{choice}': gemm share {} not positive/finite",
            r.gemm_share
        );
    }
    // unknown values die at config validation, before a trainer exists
    let bad = cfg("tgn", "cublas");
    let err = Trainer::from_config(&bad).unwrap_err().to_string();
    assert!(err.contains("auto | naive | blocked"), "unexpected error: {err}");
}
