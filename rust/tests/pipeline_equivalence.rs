//! Pipeline-vs-sequential equivalence: the acceptance gate for the staged
//! training runtime. With `depth = 1, bounded_staleness = 0` the pipelined
//! loop must reproduce the sequential loop bit-for-bit — same losses, same
//! APs, same GMM trajectory — because PREP is pure and negative streams
//! are derived per `(seed, epoch, batch)`.
//!
//! Run everywhere since the host EXEC backend: the trainer resolves
//! `exec = "auto"` to the compiled artifacts when present and the
//! pure-Rust host step otherwise — the equivalence contract is identical
//! (the host step is a deterministic pure function of its literal inputs).

use pres::config::{ExperimentConfig, PipelineConfig};
use pres::training::Trainer;

fn cfg(model: &str, pres: bool, batch: usize) -> ExperimentConfig {
    let mut c = ExperimentConfig::default_with("tiny", model, batch, pres);
    c.epochs = 2;
    c.artifacts_dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    c
}

#[test]
fn depth1_staleness0_is_bit_identical_to_sequential() {
    let mut seq_cfg = cfg("tgn", true, 50);
    seq_cfg.pipeline = PipelineConfig { depth: 0, bounded_staleness: 0, pool_workers: 0, exec_streams: 1, param_staleness: 0 };
    let mut pipe_cfg = cfg("tgn", true, 50);
    pipe_cfg.pipeline = PipelineConfig { depth: 1, bounded_staleness: 0, pool_workers: 0, exec_streams: 1, param_staleness: 0 };

    let mut seq = Trainer::from_config(&seq_cfg).unwrap();
    let mut pipe = Trainer::from_config(&pipe_cfg).unwrap();
    for e in 0..2 {
        let rs = seq.train_epoch(e).unwrap();
        let rp = pipe.train_epoch(e).unwrap();
        assert_eq!(
            rs.train_loss, rp.train_loss,
            "epoch {e}: pipelined loss diverged from sequential"
        );
        assert_eq!(rs.train_bce, rp.train_bce, "epoch {e}: bce diverged");
        assert_eq!(rs.train_ap, rp.train_ap, "epoch {e}: train AP diverged");
        assert_eq!(rs.coherence, rp.coherence, "epoch {e}: coherence diverged");
        assert_eq!(rs.gamma, rp.gamma, "epoch {e}: gamma diverged");
    }
    // and the evaluation state machines stayed in lockstep too
    assert_eq!(seq.eval_val().unwrap(), pipe.eval_val().unwrap());
}

#[test]
fn deeper_lookahead_stays_bit_identical_without_staleness() {
    // PREP never reads memory, so ANY depth with staleness 0 is exact —
    // lookahead only changes when prep work happens, not what it computes.
    let mut a_cfg = cfg("jodie", false, 50);
    a_cfg.pipeline = PipelineConfig { depth: 1, bounded_staleness: 0, pool_workers: 0, exec_streams: 1, param_staleness: 0 };
    let mut b_cfg = cfg("jodie", false, 50);
    b_cfg.pipeline = PipelineConfig { depth: 3, bounded_staleness: 0, pool_workers: 0, exec_streams: 1, param_staleness: 0 };
    let mut a = Trainer::from_config(&a_cfg).unwrap();
    let mut b = Trainer::from_config(&b_cfg).unwrap();
    for e in 0..2 {
        let ra = a.train_epoch(e).unwrap();
        let rb = b.train_epoch(e).unwrap();
        assert_eq!(ra.train_loss, rb.train_loss, "epoch {e}");
    }
}

#[test]
fn bounded_staleness_trains_to_finite_loss() {
    // staleness > 0 is allowed to change results (it reads lagged memory)
    // but must stay numerically sane and produce a working model
    let mut c = cfg("tgn", true, 50);
    c.epochs = 3;
    c.pipeline = PipelineConfig { depth: 2, bounded_staleness: 1, pool_workers: 0, exec_streams: 1, param_staleness: 0 };
    let mut tr = Trainer::from_config(&c).unwrap();
    for e in 0..3 {
        let r = tr.train_epoch(e).unwrap();
        assert!(r.train_loss.is_finite(), "epoch {e} loss {}", r.train_loss);
    }
    let ap = tr.eval_val().unwrap();
    assert!(ap > 0.5, "staleness-1 val AP collapsed: {ap}");
}

#[test]
fn staleness_zero_stays_bit_identical_and_reports_zero_lag() {
    // the k = 0 contract, asserted directly on the staleness path's own
    // metric: every splice is exact (lag 0) and the results are the
    // sequential loop's, bit for bit
    let mut seq_cfg = cfg("tgn", true, 50);
    seq_cfg.pipeline = PipelineConfig { depth: 0, bounded_staleness: 0, pool_workers: 0, exec_streams: 1, param_staleness: 0 };
    let mut pipe_cfg = cfg("tgn", true, 50);
    pipe_cfg.pipeline = PipelineConfig { depth: 3, bounded_staleness: 0, pool_workers: 0, exec_streams: 1, param_staleness: 0 };
    let mut seq = Trainer::from_config(&seq_cfg).unwrap();
    let mut pipe = Trainer::from_config(&pipe_cfg).unwrap();
    for e in 0..2 {
        let rs = seq.train_epoch(e).unwrap();
        let rp = pipe.train_epoch(e).unwrap();
        assert_eq!(rs.splice_lag_max, 0, "sequential epochs never lag");
        assert_eq!(rp.splice_lag_max, 0, "k = 0 must keep every splice exact");
        assert_eq!(rs.train_loss, rp.train_loss, "epoch {e}: k = 0 loss diverged");
        assert_eq!(rs.train_ap, rp.train_ap, "epoch {e}: k = 0 train AP diverged");
    }
}

#[test]
fn staleness_k_views_lag_exactly_k_commits() {
    // the MSPipe-style bound itself: with bounded_staleness = k, the
    // farthest any splice's memory view may trail the commit stream is k —
    // and since the window fill became deterministic (the coordinator
    // BLOCKS on PREP for window entries instead of opportunistically
    // try_recv-ing), the witness is exact: every epoch with enough batches
    // realizes the full bound, regardless of thread timing. That
    // determinism is what makes the multi-stream equivalence gate below
    // meaningful at all.
    for k in [1usize, 2] {
        let mut c = cfg("tgn", true, 50);
        c.epochs = 2;
        c.pipeline = PipelineConfig { depth: k + 1, bounded_staleness: k, pool_workers: 0, exec_streams: 1, param_staleness: 0 };
        let mut tr = Trainer::from_config(&c).unwrap();
        for e in 0..2 {
            let r = tr.train_epoch(e).unwrap();
            assert_eq!(
                r.splice_lag_max, k,
                "k = {k}, epoch {e}: deterministic window fill must realize the bound exactly"
            );
            assert!(r.train_loss.is_finite(), "k = {k}, epoch {e}: loss diverged");
        }
    }
}

#[test]
fn staleness_schedule_is_timing_independent() {
    // two fresh trainers at the same k must produce bit-identical results:
    // under the old try_recv window fill the splice schedule depended on
    // PREP thread timing, so this could flake apart
    let mut c = cfg("tgn", true, 50);
    c.epochs = 2;
    c.pipeline = PipelineConfig { depth: 2, bounded_staleness: 1, pool_workers: 0, exec_streams: 1, param_staleness: 0 };
    let mut a = Trainer::from_config(&c).unwrap();
    let mut b = Trainer::from_config(&c).unwrap();
    for e in 0..2 {
        let ra = a.train_epoch(e).unwrap();
        let rb = b.train_epoch(e).unwrap();
        assert_eq!(ra.train_loss, rb.train_loss, "epoch {e}: staleness schedule drifted");
        assert_eq!(ra.splice_lag_max, rb.splice_lag_max, "epoch {e}");
    }
}

#[test]
fn stream_counts_are_bit_identical_under_staleness() {
    // THE multi-stream equivalence gate: at bounded_staleness = k >= 1,
    // running the staleness window's steps through N executor lanes with
    // ordered commits must be byte-for-byte the serial staleness-k loop —
    // same losses, same memory trajectory (witnessed by val AP, which
    // evaluates on the evolved memory), same splice-lag witness — for
    // every stream count. The lanes may only hide coordinator work, never
    // change values.
    for k in [1usize, 2] {
        let mut ref_cfg = cfg("tgn", true, 50);
        ref_cfg.epochs = 2;
        ref_cfg.pipeline =
            PipelineConfig { depth: k + 1, bounded_staleness: k, pool_workers: 0, exec_streams: 1, param_staleness: 0 };
        let mut reference = Trainer::from_config(&ref_cfg).unwrap();
        let mut ref_epochs = Vec::new();
        for e in 0..2 {
            ref_epochs.push(reference.train_epoch(e).unwrap());
        }
        let ref_val = reference.eval_val().unwrap();

        for streams in [2usize, 4] {
            let mut c = cfg("tgn", true, 50);
            c.epochs = 2;
            c.pipeline = PipelineConfig {
                depth: k + 1,
                bounded_staleness: k,
                pool_workers: 0,
                exec_streams: streams,
                param_staleness: 0,
            };
            let mut tr = Trainer::from_config(&c).unwrap();
            for (e, want) in ref_epochs.iter().enumerate() {
                let r = tr.train_epoch(e).unwrap();
                assert_eq!(
                    r.train_loss, want.train_loss,
                    "k = {k}, streams = {streams}, epoch {e}: loss diverged from serial"
                );
                assert_eq!(r.train_bce, want.train_bce, "k = {k}, streams = {streams}, epoch {e}");
                assert_eq!(r.train_ap, want.train_ap, "k = {k}, streams = {streams}, epoch {e}");
                assert_eq!(
                    r.coherence, want.coherence,
                    "k = {k}, streams = {streams}, epoch {e}"
                );
                assert_eq!(r.gamma, want.gamma, "k = {k}, streams = {streams}, epoch {e}");
                assert_eq!(
                    r.splice_lag_max, want.splice_lag_max,
                    "k = {k}, streams = {streams}, epoch {e}: staleness schedule diverged"
                );
                assert_eq!(
                    r.param_lag_max, 0,
                    "k = {k}, streams = {streams}, epoch {e}: the exact chain must never \
                     execute a step against stale parameters"
                );
            }
            // the memory/neighbor/mailbox state machines stayed in lockstep
            assert_eq!(
                tr.eval_val().unwrap(),
                ref_val,
                "k = {k}, streams = {streams}: post-training memory state diverged"
            );
        }
    }
}

#[test]
fn multistream_reports_per_stream_execute() {
    let mut c = cfg("tgn", false, 50);
    c.pipeline = PipelineConfig { depth: 2, bounded_staleness: 1, pool_workers: 0, exec_streams: 2, param_staleness: 0 };
    let mut tr = Trainer::from_config(&c).unwrap();
    let r = tr.train_epoch(0).unwrap();
    assert!(r.execute_secs > 0.0, "lane busy time must be recorded");
    assert!(
        r.exec_union_secs <= r.epoch_secs + 1e-9,
        "busy-union ({}) can never exceed wall clock ({})",
        r.exec_union_secs,
        r.epoch_secs
    );
    let busy_sum: f64 = r.exec_stream_busy_secs.iter().sum();
    assert!(
        (busy_sum - r.execute_secs).abs() < 1e-9,
        "per-stream busy ({busy_sum}) must sum to execute ({})",
        r.execute_secs
    );
    assert!((0.0..=1.0).contains(&r.device_idle_frac));
}

#[test]
fn param_lag_realizes_min_p_streams_minus_one_exactly() {
    // the relaxed chain's bound is tight AND deterministic: with
    // param_staleness = p and exec_streams = s the in-flight window holds
    // min(p, s - 1) + 1 steps, so the largest parameter lag any step
    // executes against is exactly min(p, s - 1) once the window fills —
    // not "at most", exactly, because submissions happen at fixed loop
    // positions, never in response to lane timing
    for (p, s) in [(1usize, 2usize), (2, 2), (1, 4), (2, 4), (3, 4)] {
        let want = p.min(s - 1);
        let k = want.max(1);
        let mut c = cfg("tgn", true, 50);
        c.pipeline = PipelineConfig {
            depth: k + 1,
            bounded_staleness: k,
            pool_workers: 0,
            exec_streams: s,
            param_staleness: p,
        };
        let mut tr = Trainer::from_config(&c).unwrap();
        for e in 0..2 {
            let r = tr.train_epoch(e).unwrap();
            assert_eq!(
                r.param_lag_max, want,
                "p = {p}, s = {s}, epoch {e}: param lag must realize min(p, s - 1) exactly"
            );
            assert!(r.train_loss.is_finite(), "p = {p}, s = {s}, epoch {e}");
        }
    }
    // streams = 1 runs the inline exact chain: p is a documented no-op
    let mut c = cfg("tgn", true, 50);
    c.pipeline = PipelineConfig {
        depth: 1,
        bounded_staleness: 0,
        pool_workers: 0,
        exec_streams: 1,
        param_staleness: 3,
    };
    let mut tr = Trainer::from_config(&c).unwrap();
    let r = tr.train_epoch(0).unwrap();
    assert_eq!(r.param_lag_max, 0, "inline chain is exact regardless of p");
}

#[test]
fn relaxed_chain_is_deterministic_across_identical_runs() {
    // the relaxed schedule must be a pure function of (n_train, k, p,
    // streams): two fresh trainers produce bit-identical losses, APs and
    // lag witnesses even though lanes genuinely race for work
    let mut c = cfg("tgn", true, 50);
    c.pipeline = PipelineConfig {
        depth: 3,
        bounded_staleness: 2,
        pool_workers: 0,
        exec_streams: 4,
        param_staleness: 2,
    };
    let mut a = Trainer::from_config(&c).unwrap();
    let mut b = Trainer::from_config(&c).unwrap();
    for e in 0..2 {
        let ra = a.train_epoch(e).unwrap();
        let rb = b.train_epoch(e).unwrap();
        assert_eq!(ra.train_loss, rb.train_loss, "epoch {e}: relaxed schedule drifted");
        assert_eq!(ra.train_bce, rb.train_bce, "epoch {e}");
        assert_eq!(ra.train_ap, rb.train_ap, "epoch {e}");
        assert_eq!(ra.coherence, rb.coherence, "epoch {e}");
        assert_eq!(ra.gamma, rb.gamma, "epoch {e}");
        assert_eq!(ra.splice_lag_max, rb.splice_lag_max, "epoch {e}");
        assert_eq!(ra.param_lag_max, rb.param_lag_max, "epoch {e}");
    }
    assert_eq!(
        a.eval_val().unwrap(),
        b.eval_val().unwrap(),
        "post-training memory state diverged between identical relaxed runs"
    );
}

#[test]
fn relaxed_chain_clamps_p_to_lanes_so_excess_p_is_schedule_invariant() {
    // p is clamped by the lane count: at s = 2 both p = 1 and p = 3 keep
    // the same W = 2 window, so the schedules — and therefore the results
    // — must be bit-identical
    let mk = |p: usize| {
        let mut c = cfg("tgn", true, 50);
        c.pipeline = PipelineConfig {
            depth: 2,
            bounded_staleness: 1,
            pool_workers: 0,
            exec_streams: 2,
            param_staleness: p,
        };
        Trainer::from_config(&c).unwrap()
    };
    let mut a = mk(1);
    let mut b = mk(3);
    for e in 0..2 {
        let ra = a.train_epoch(e).unwrap();
        let rb = b.train_epoch(e).unwrap();
        assert_eq!(ra.train_loss, rb.train_loss, "epoch {e}: clamped p changed the schedule");
        assert_eq!(ra.param_lag_max, rb.param_lag_max, "epoch {e}");
        assert_eq!(ra.param_lag_max, 1, "epoch {e}: both clamp to lag 1");
    }
    assert_eq!(a.eval_val().unwrap(), b.eval_val().unwrap());
}

#[test]
fn relaxed_chain_trains_to_working_model() {
    // bounded gradient delay is allowed to change numerics but must not
    // wreck convergence: the quality gate behind the staleness study
    let mut c = cfg("tgn", true, 50);
    c.epochs = 3;
    c.pipeline = PipelineConfig {
        depth: 3,
        bounded_staleness: 2,
        pool_workers: 0,
        exec_streams: 4,
        param_staleness: 2,
    };
    let mut tr = Trainer::from_config(&c).unwrap();
    for e in 0..3 {
        let r = tr.train_epoch(e).unwrap();
        assert!(r.train_loss.is_finite(), "epoch {e} loss {}", r.train_loss);
    }
    let ap = tr.eval_val().unwrap();
    assert!(ap > 0.5, "relaxed-chain val AP collapsed: {ap}");
}

#[test]
fn mid_epoch_fault_leaves_model_state_at_epoch_start() {
    // the error-path contract for BOTH multi-stream loops: a lane
    // rejecting a step mid-epoch must error the epoch without touching
    // ModelState — params, Adam moments and the step counter stay at
    // their consistent epoch-start values, and training can resume as if
    // the failed epoch never happened
    for p in [0usize, 2] {
        let mut c = cfg("tgn", true, 50);
        c.pipeline = PipelineConfig {
            depth: 3,
            bounded_staleness: 2,
            pool_workers: 0,
            exec_streams: if p == 0 { 2 } else { 4 },
            param_staleness: p,
        };
        let mut tr = Trainer::from_config(&c).unwrap();
        let before = tr.param_state_digest().unwrap();
        tr.exec_fault_at = Some(5);
        let err = tr.train_epoch(0).unwrap_err().to_string();
        assert!(err.contains("step 5"), "p = {p}: unexpected error: {err}");
        assert_eq!(
            tr.param_state_digest().unwrap(),
            before,
            "p = {p}: a failed epoch must not move ModelState"
        );

        // recovery: the next epoch must match a fresh trainer bit-for-bit
        tr.exec_fault_at = None;
        let r = tr.train_epoch(0).unwrap();
        let mut fresh = Trainer::from_config(&c).unwrap();
        let want = fresh.train_epoch(0).unwrap();
        assert_eq!(
            r.train_loss, want.train_loss,
            "p = {p}: post-fault epoch diverged from a fresh trainer"
        );
        assert_eq!(r.train_ap, want.train_ap, "p = {p}");
    }
}

#[test]
fn model_checker_predictions_match_trainer_witnesses() {
    // the pallas-verify cross-validation gate: the schedule model's
    // closed-form witnesses — proved exhaustively over the small-scope
    // grid by `pres::verify::schedule::check_grid` — must equal the real
    // trainer's EpochReport witnesses on a sampled sub-grid of runnable
    // configurations covering all three coordinator loops. This is what
    // pins the abstract state machines to the real loop bodies.
    use pres::batching::partition;
    use pres::verify::schedule::{predicted, Knobs};

    // n_train exactly as the trainer computes it: plans whose predicted
    // range lies inside the train split
    let base = cfg("tgn", true, 50);
    let ds = Trainer::make_dataset(&base).unwrap();
    let n_train = partition(0..ds.log.len(), 50)
        .into_iter()
        .filter(|r| r.end <= ds.split.train_end)
        .count();
    assert!(n_train > 4, "tiny dataset too small to exercise the schedules");

    for (k, p, s) in [
        (0usize, 0usize, 1usize), // pipelined, staleness off
        (1, 0, 1),                // pipelined, k = 1
        (2, 0, 1),                // pipelined, k = 2
        (1, 0, 2),                // exact multistream
        (2, 0, 4),                // exact multistream, wide
        (1, 1, 2),                // relaxed, W = 2
        (2, 2, 3),                // relaxed, W = 3
        (2, 2, 4),                // relaxed, p below lane count
        (3, 3, 4),                // relaxed, W = 4 (grid corner)
    ] {
        let kn = Knobs { n_train, k, p, streams: s };
        assert!(kn.valid(), "k = {k}, p = {p}, s = {s}: sub-grid point must be runnable");
        let pred = predicted(&kn);

        let mut c = cfg("tgn", true, 50);
        c.pipeline = PipelineConfig {
            depth: k + 1,
            bounded_staleness: k,
            pool_workers: 0,
            exec_streams: s,
            param_staleness: p,
        };
        let mut tr = Trainer::from_config(&c).unwrap();
        let r = tr.train_epoch(0).unwrap();
        assert_eq!(
            r.splice_lag_max, pred.splice_lag_max,
            "k = {k}, p = {p}, s = {s}: trainer splice-lag witness disagrees with the model"
        );
        assert_eq!(
            r.param_lag_max, pred.param_lag_max,
            "k = {k}, p = {p}, s = {s}: trainer param-lag witness disagrees with the model"
        );
        assert!(r.train_loss.is_finite(), "k = {k}, p = {p}, s = {s}");
    }
}

#[test]
fn stream_misconfigurations_are_rejected_with_clear_errors() {
    // streams without a staleness window: nothing is pre-spliced, so lanes
    // could never overlap anything — rejected at validation
    let mut c = cfg("tgn", true, 50);
    c.pipeline = PipelineConfig { depth: 2, bounded_staleness: 0, pool_workers: 0, exec_streams: 2, param_staleness: 0 };
    let err = match Trainer::from_config(&c) {
        Ok(_) => panic!("streams without a staleness window must be rejected"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("bounded_staleness"), "unexpected error: {err}");

    // the PJRT backend cannot serve stream lanes (its handles are not
    // Send) — the config layer rejects the explicit request up front
    let mut c = cfg("tgn", true, 50);
    c.exec = "pjrt".into();
    c.pipeline = PipelineConfig { depth: 2, bounded_staleness: 1, pool_workers: 0, exec_streams: 2, param_staleness: 0 };
    let err = c.validate().unwrap_err().to_string();
    assert!(err.contains("host EXEC backend"), "unexpected error: {err}");
}

#[test]
fn overlap_metrics_are_reported_when_pipelined() {
    let mut c = cfg("tgn", false, 50);
    c.pipeline = PipelineConfig { depth: 2, bounded_staleness: 0, pool_workers: 0, exec_streams: 1, param_staleness: 0 };
    let mut tr = Trainer::from_config(&c).unwrap();
    tr.train_epoch(0).unwrap(); // warm the executable cache
    let r = tr.train_epoch(1).unwrap();
    assert!(r.prep_secs > 0.0, "background PREP time must be recorded");
    assert!(
        r.assemble_hidden_secs >= 0.0 && r.assemble_hidden_secs <= r.prep_secs,
        "hidden ({}) must be within [0, prep busy ({})]",
        r.assemble_hidden_secs,
        r.prep_secs
    );
    assert!((0.0..=1.0).contains(&r.device_idle_frac));
    // sequential epochs report no overlap
    tr.cfg.pipeline = PipelineConfig { depth: 0, bounded_staleness: 0, pool_workers: 0, exec_streams: 1, param_staleness: 0 };
    let r = tr.train_epoch(2).unwrap();
    assert_eq!(r.prep_secs, 0.0);
    assert_eq!(r.assemble_hidden_secs, 0.0);
}
