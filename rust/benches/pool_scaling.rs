//! Persistent-pool acceptance bench: pooled SPLICE/WRITEBACK vs the old
//! scoped-spawn design, the small-batch sweep around the recalibrated
//! serial/parallel crossover, and PREP throughput vs `--pool-workers`.
//!
//!     cargo bench --bench pool_scaling [-- --quick]
//!
//! Three sections, all landing in `BENCH_pool.json`:
//!
//! * **store**: one trainer iteration's five routed gathers + masked
//!   scatter on the pooled [`ShardedMemoryStore`] vs a faithful bench-local
//!   reimplementation of the PR-2 scoped-spawn fan-out, at wiki/gdelt-like
//!   scales for shards ∈ {2, 4, 8}. Acceptance: pooled ≤ scoped.
//! * **crossover**: the same op pair at small batches (64 … 4000 rows),
//!   pooled vs forced-serial, bracketing `PAR_MIN_ELEMS` — the effective
//!   crossover is where pooled dips under serial, and with spawn overhead
//!   gone it sits far below the old `1 << 15`.
//! * **prep**: full `fill_prep_with` rows/s at `--pool-workers`
//!   ∈ {1, 2, 4, 8} on a wiki-like event stream (sampling + features +
//!   matches + routes).

// The scoped-spawn baseline this bench compares against is deliberately the
// banned pattern — that is the point of the comparison.
#![allow(clippy::disallowed_methods)]

use std::sync::Arc;

use pres::batching::BatchPlan;
use pres::datagen;
use pres::memory::{MemoryBackend, MemoryStore, RowRoute, ShardRouter, ShardedMemoryStore};
use pres::pipeline::{fill_prep_with, negative_stream, PrepBatch};
use pres::sampler::NegativeSampler;
use pres::util::bench::{black_box, Bench};
use pres::util::json::Json;
use pres::util::pool::WorkerPool;
use pres::util::prop::{f32_vec, vertex_vec};
use pres::util::rng::Pcg32;

// ---------------------------------------------------------------- baseline
//
// The PR-2 design, preserved verbatim as the comparison target: per-shard
// work lists handed to `std::thread::scope` workers spawned per op.

fn scoped_gather(
    shards: &[MemoryStore],
    router: ShardRouter,
    d: usize,
    vs: &[u32],
    routes: &[RowRoute],
    out: &mut [f32],
) {
    let mut work: Vec<Vec<(u32, &mut [f32])>> =
        (0..shards.len()).map(|_| Vec::with_capacity(vs.len() / shards.len() + 1)).collect();
    for (i, slot) in out.chunks_exact_mut(d).enumerate() {
        let r = if routes.is_empty() { router.route(vs[i]) } else { routes[i] };
        work[r.shard as usize].push((r.local, slot));
    }
    // lint: allow(thread-discipline) — the scoped-spawn baseline IS the comparison subject
    std::thread::scope(|scope| {
        for (shard, items) in shards.iter().zip(work) {
            if items.is_empty() {
                continue;
            }
            scope.spawn(move || {
                for (local, slot) in items {
                    slot.copy_from_slice(shard.row(local));
                }
            });
        }
    });
}

fn scoped_scatter(
    shards: &mut [MemoryStore],
    router: ShardRouter,
    d: usize,
    vs: &[u32],
    routes: &[RowRoute],
    rows: &[f32],
    ts: &[f32],
    mask: &[f32],
) {
    let mut work: Vec<Vec<(u32, &[f32], f32)>> =
        (0..shards.len()).map(|_| Vec::with_capacity(vs.len() / shards.len() + 1)).collect();
    for (r, (&v, row)) in vs.iter().zip(rows.chunks_exact(d)).enumerate() {
        if mask[r] != 1.0 {
            continue;
        }
        let rt = if routes.is_empty() { router.route(v) } else { routes[r] };
        work[rt.shard as usize].push((rt.local, row, ts[r]));
    }
    // lint: allow(thread-discipline) — the scoped-spawn baseline IS the comparison subject
    std::thread::scope(|scope| {
        for (shard, items) in shards.iter_mut().zip(work) {
            if items.is_empty() {
                continue;
            }
            scope.spawn(move || {
                for (local, row, t) in items {
                    shard.scatter(local, row, t);
                }
            });
        }
    });
}

/// One iteration's gather/scatter lists, shared by both implementations.
struct Workload {
    u_self: Vec<u32>,
    u_other: Vec<u32>,
    c_lists: Vec<Vec<u32>>,
    wb_rows: Vec<f32>,
    wb_ts: Vec<f32>,
    wb_mask: Vec<f32>,
}

fn workload(num_nodes: u32, d: usize, batch: usize, seed: u64) -> Workload {
    let rows = 2 * batch;
    let mut rng = Pcg32::new(seed ^ num_nodes as u64);
    Workload {
        u_self: vertex_vec(&mut rng, num_nodes, rows),
        u_other: vertex_vec(&mut rng, num_nodes, rows),
        c_lists: (0..3).map(|_| vertex_vec(&mut rng, num_nodes, batch)).collect(),
        wb_rows: f32_vec(&mut rng, rows * d),
        wb_ts: (0..rows).map(|_| rng.f32() * 100.0).collect(),
        wb_mask: (0..rows).map(|_| if rng.below(8) == 0 { 0.0 } else { 1.0 }).collect(),
    }
}

fn routes_for(router: ShardRouter, vs: &[u32]) -> Vec<RowRoute> {
    let mut r = Vec::new();
    router.fill_routes(vs, &mut r);
    r
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut bench = Bench::new("pool_scaling").with_iters(3, if quick { 8 } else { 40 });
    bench.header();
    let mut cases: Vec<Json> = Vec::new();

    // ---- section 1: pooled vs scoped-spawn at acceptance scales --------
    let scales: &[(&str, u32, usize, usize)] = &[
        ("wiki_like", 10_000, 100, 600),
        ("gdelt_like", if quick { 16_384 } else { 65_536 }, 128, 4_000),
    ];
    for &(label, num_nodes, d, batch) in scales {
        let w = workload(num_nodes, d, batch, 0x900C);
        let rows = 2 * batch;
        let mut u_self_out = vec![0.0f32; rows * d];
        let mut u_other_out = vec![0.0f32; rows * d];
        let mut c_out = vec![0.0f32; batch * d];
        for shards in [2usize, 4, 8] {
            let pool = Arc::new(WorkerPool::auto());
            let mut pooled =
                ShardedMemoryStore::new(num_nodes, d, shards).with_pool(pool.clone());
            pooled.scatter_rows(&w.u_self, &w.wb_rows, &w.wb_ts, None);
            let router = pooled.router();
            let n = router.n_shards;
            let (r_self, r_other) = (routes_for(router, &w.u_self), routes_for(router, &w.u_other));
            let r_c: Vec<Vec<RowRoute>> = w.c_lists.iter().map(|vs| routes_for(router, vs)).collect();

            // the scoped baseline operates on a bare shard vector with the
            // identical routing and warm state
            let mut scoped: Vec<MemoryStore> = (0..n)
                .map(|s| MemoryStore::new(router.shard_len(s, num_nodes), d))
                .collect();
            for (r, &v) in w.u_self.iter().enumerate() {
                let rt = router.route(v);
                scoped[rt.shard as usize].scatter(
                    rt.local,
                    &w.wb_rows[r * d..(r + 1) * d],
                    w.wb_ts[r],
                );
            }

            let tag = format!("{label}_s{shards}");
            let pooled_splice = bench
                .run(&format!("{tag}_splice_pooled"), || {
                    pooled.gather_rows_routed(&w.u_self, &r_self, n, &mut u_self_out);
                    pooled.gather_rows_routed(&w.u_other, &r_other, n, &mut u_other_out);
                    for (vs, r) in w.c_lists.iter().zip(&r_c) {
                        pooled.gather_rows_routed(vs, r, n, &mut c_out);
                    }
                    black_box(c_out.first().copied());
                })
                .mean_ns;
            let scoped_splice = bench
                .run(&format!("{tag}_splice_scoped"), || {
                    scoped_gather(&scoped, router, d, &w.u_self, &r_self, &mut u_self_out);
                    scoped_gather(&scoped, router, d, &w.u_other, &r_other, &mut u_other_out);
                    for (vs, r) in w.c_lists.iter().zip(&r_c) {
                        scoped_gather(&scoped, router, d, vs, r, &mut c_out);
                    }
                    black_box(c_out.first().copied());
                })
                .mean_ns;
            let pooled_wb = bench
                .run(&format!("{tag}_writeback_pooled"), || {
                    pooled.scatter_rows_routed(
                        &w.u_self, &w.wb_rows, &w.wb_ts, Some(&w.wb_mask), &r_self, n,
                    );
                })
                .mean_ns;
            let scoped_wb = bench
                .run(&format!("{tag}_writeback_scoped"), || {
                    scoped_scatter(
                        &mut scoped, router, d, &w.u_self, &r_self, &w.wb_rows, &w.wb_ts,
                        &w.wb_mask,
                    );
                })
                .mean_ns;
            pres::log_info!(
                "    {tag}: splice pooled {:.2} ms vs scoped {:.2} ms | \
                 writeback pooled {:.2} ms vs scoped {:.2} ms",
                pooled_splice / 1e6,
                scoped_splice / 1e6,
                pooled_wb / 1e6,
                scoped_wb / 1e6
            );
            cases.push(Json::obj(vec![
                ("section", Json::str("store")),
                ("label", Json::str(&tag)),
                ("shards", Json::num(shards as f64)),
                ("pool_lanes", Json::num(pool.lanes() as f64)),
                ("splice_pooled_ns", Json::num(pooled_splice)),
                ("splice_scoped_ns", Json::num(scoped_splice)),
                ("writeback_pooled_ns", Json::num(pooled_wb)),
                ("writeback_scoped_ns", Json::num(scoped_wb)),
            ]));
        }
    }

    // ---- section 2: small-batch sweep around the crossover -------------
    {
        let (num_nodes, d, shards) = (10_000u32, 100usize, 4usize);
        for batch in [64usize, 128, 256, 512, 1024, 4000] {
            let w = workload(num_nodes, d, batch, 0xC705);
            let rows = 2 * batch;
            let mut out = vec![0.0f32; rows * d];
            let pool = Arc::new(WorkerPool::auto());
            let mut pooled =
                ShardedMemoryStore::new(num_nodes, d, shards).with_pool(pool.clone());
            // forced-serial twin: same layout, crossover pinned to infinity
            let mut serial = ShardedMemoryStore::new(num_nodes, d, shards)
                .with_par_threshold(usize::MAX);
            pooled.scatter_rows(&w.u_self, &w.wb_rows, &w.wb_ts, None);
            serial.scatter_rows(&w.u_self, &w.wb_rows, &w.wb_ts, None);
            let router = pooled.router();
            let n = router.n_shards;
            let r_self = routes_for(router, &w.u_self);
            let elems_per_shard = rows * d / shards;

            let tag = format!("b{batch}");
            let pooled_ns = bench
                .run(&format!("crossover_{tag}_pooled"), || {
                    pooled.gather_rows_routed(&w.u_self, &r_self, n, &mut out);
                    pooled.scatter_rows_routed(
                        &w.u_self, &w.wb_rows, &w.wb_ts, Some(&w.wb_mask), &r_self, n,
                    );
                })
                .mean_ns;
            let serial_ns = bench
                .run(&format!("crossover_{tag}_serial"), || {
                    serial.gather_rows_routed(&w.u_self, &r_self, n, &mut out);
                    serial.scatter_rows_routed(
                        &w.u_self, &w.wb_rows, &w.wb_ts, Some(&w.wb_mask), &r_self, n,
                    );
                })
                .mean_ns;
            cases.push(Json::obj(vec![
                ("section", Json::str("crossover")),
                ("label", Json::str(&tag)),
                ("batch", Json::num(batch as f64)),
                ("elems_per_shard", Json::num(elems_per_shard as f64)),
                ("pooled_ns", Json::num(pooled_ns)),
                ("serial_ns", Json::num(serial_ns)),
            ]));
        }
    }

    // ---- section 3: PREP rows/s vs --pool-workers ----------------------
    {
        let mut profile = datagen::profile("wiki").expect("wiki profile");
        profile.n_events = if quick { 4_096 } else { 16_384 };
        let ds = datagen::generate(&profile, 7);
        let b = 2_000.min(ds.log.len() / 2);
        let prev = BatchPlan::build(&ds.log, 0..b);
        let cur = BatchPlan::build(&ds.log, b..2 * b);
        let sampler = NegativeSampler::new(&ds.log);
        let router = ShardRouter { n_shards: 4 };
        let mut prep = PrepBatch::new(b, ds.log.d_edge);
        for workers in [1usize, 2, 4, 8] {
            let pool = WorkerPool::new(workers);
            let base = negative_stream(7, 0, 1);
            let ns = bench
                .run(&format!("prep_w{workers}"), || {
                    fill_prep_with(&mut prep, &ds.log, &prev, &cur, &sampler, &base, router, &pool);
                    black_box(prep.negatives.first().copied());
                })
                .mean_ns;
            let rows_per_sec = (prev.rows() + b) as f64 / (ns / 1e9);
            pres::log_info!("    prep workers={workers}: {rows_per_sec:.0} rows/s");
            cases.push(Json::obj(vec![
                ("section", Json::str("prep")),
                ("label", Json::str(&format!("prep_w{workers}"))),
                ("pool_workers", Json::num(workers as f64)),
                ("batch", Json::num(b as f64)),
                ("fill_ns", Json::num(ns)),
                ("rows_per_sec", Json::num(rows_per_sec)),
            ]));
        }
    }

    bench.write_csv().unwrap();
    let mut report = bench.report_json(cases);
    report.set(
        "par_min_elems",
        Json::num(pres::memory::shard::PAR_MIN_ELEMS as f64),
    );
    std::fs::write("BENCH_pool.json", report.to_string_pretty()).unwrap();
    pres::log_info!("-> wrote BENCH_pool.json");
}
