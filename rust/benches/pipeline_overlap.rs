//! Sequential vs pipelined training throughput: how much host assembly the
//! PREP thread hides behind device execution, per (model, batch).
//!
//!     cargo bench --bench pipeline_overlap [-- --quick]
//!
//! Reports events/sec, device-idle fraction, assemble-hidden seconds and
//! prep-stall seconds per configuration, and writes the whole sweep to
//! `BENCH_pipeline.json` for EXPERIMENTS.md / CI tracking.

use pres::config::{ExperimentConfig, PipelineConfig};
use pres::training::Trainer;
use pres::util::bench::Bench;
use pres::util::json::Json;

struct Case {
    label: String,
    depth: usize,
    staleness: usize,
    events_per_sec: f64,
    epoch_secs: f64,
    device_idle_frac: f64,
    assemble_hidden_secs: f64,
    prep_stall_secs: f64,
}

fn case_json(c: &Case) -> Json {
    Json::obj(vec![
        ("label", Json::str(&c.label)),
        ("pipeline_depth", Json::num(c.depth as f64)),
        ("bounded_staleness", Json::num(c.staleness as f64)),
        ("events_per_sec", Json::num(c.events_per_sec)),
        ("epoch_secs", Json::num(c.epoch_secs)),
        ("device_idle_frac", Json::num(c.device_idle_frac)),
        ("assemble_hidden_secs", Json::num(c.assemble_hidden_secs)),
        ("prep_stall_secs", Json::num(c.prep_stall_secs)),
    ])
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut bench = Bench::new("pipeline_overlap").with_iters(2, if quick { 3 } else { 8 });
    bench.header();

    // (depth, staleness) sweep: sequential baseline, the bit-identical
    // default, deeper lookahead, and lookahead + one batch of staleness
    let modes = [
        ("seq", 0usize, 0usize),
        ("depth1", 1, 0),
        ("depth2", 2, 0),
        ("depth2_stale1", 2, 1),
    ];
    let mut cases: Vec<Case> = Vec::new();

    for model in ["tgn", "jodie"] {
        for batch in [200usize, 800] {
            let mut cfg = ExperimentConfig::default_with("wiki", model, batch, true);
            cfg.epochs = 1;
            cfg.data_scale = if quick { 0.25 } else { 1.0 };
            let mut tr = match Trainer::from_config(&cfg) {
                Ok(t) => t,
                Err(e) => {
                    pres::log_warn!("skip {model} b={batch}: {e}");
                    continue;
                }
            };
            // one warm epoch primes the XLA executable + caches
            tr.train_epoch(0).unwrap();
            for (name, depth, staleness) in modes {
                tr.cfg.pipeline = PipelineConfig {
                    depth,
                    bounded_staleness: staleness,
                    pool_workers: 0,
                    exec_streams: 1,
                    param_staleness: 0,
                };
                let label = format!("{model}_b{batch}_{name}");
                bench.run(&label, || {
                    tr.train_epoch(1).unwrap();
                });
                let r = tr.train_epoch(2).unwrap();
                pres::log_info!(
                    "    {label}: {:.0} ev/s | idle {:.1}% | hidden {:.3}s | stall {:.3}s",
                    r.events_per_sec,
                    r.device_idle_frac * 100.0,
                    r.assemble_hidden_secs,
                    r.prep_stall_secs,
                );
                cases.push(Case {
                    label,
                    depth,
                    staleness,
                    events_per_sec: r.events_per_sec,
                    epoch_secs: r.epoch_secs,
                    device_idle_frac: r.device_idle_frac,
                    assemble_hidden_secs: r.assemble_hidden_secs,
                    prep_stall_secs: r.prep_stall_secs,
                });
            }
        }
    }

    bench.write_csv().unwrap();
    bench
        .write_json("BENCH_pipeline.json", cases.iter().map(case_json).collect())
        .unwrap();
    pres::log_info!("-> wrote BENCH_pipeline.json ({} cases)", cases.len());
}
