//! Microbenches for the L3 substrates on the hot path: pending-set
//! analysis, neighbor index, memory store, GMM trackers, negative sampling,
//! metrics. Run with `cargo bench --bench substrates`.

use pres::batching::BatchPlan;
use pres::datagen;
use pres::memory::gmm::Role;
use pres::memory::{GmmTrackers, MemoryStore};
use pres::metrics::ranking::{average_precision, roc_auc};
use pres::sampler::{NegativeSampler, NeighborEntry, NeighborIndex};
use pres::util::bench::{black_box, Bench};
use pres::util::rng::Pcg32;

fn main() {
    let profile = datagen::profile("wiki").unwrap();
    let ds = datagen::generate(&profile, 0);
    let log = &ds.log;

    let mut b = Bench::new("substrates");
    b.header();

    for batch in [100usize, 400, 1600] {
        b.run(&format!("pending_plan_b{batch}"), || {
            black_box(BatchPlan::build(log, 1000..1000 + batch));
        });
    }

    // neighbor index insert+gather at dataset scale
    b.run("neighbor_index_epoch_insert", || {
        let mut idx = NeighborIndex::new(log.num_nodes, 10);
        for (i, e) in log.events.iter().enumerate().take(10_000) {
            idx.insert_event(e.src, e.dst, e.t, i as u32);
        }
        black_box(idx.degree(0));
    });
    let mut idx = NeighborIndex::new(log.num_nodes, 10);
    for (i, e) in log.events.iter().enumerate() {
        idx.insert_event(e.src, e.dst, e.t, i as u32);
    }
    let mut out = [NeighborEntry::default(); 10];
    b.run("neighbor_gather_batch400x3", || {
        for e in &log.events[5000..5400] {
            black_box(idx.gather(e.src, &mut out));
            black_box(idx.gather(e.dst, &mut out));
            black_box(idx.gather(e.dst, &mut out));
        }
    });

    // memory store gather/scatter of a 2b update-row block
    let mut store = MemoryStore::new(log.num_nodes, 64);
    let mut row = vec![0.5f32; 64];
    b.run("memory_scatter_gather_800rows", || {
        for e in &log.events[2000..2400] {
            store.gather_into(e.src, &mut row);
            store.scatter(e.dst, &row, e.t);
        }
    });

    // GMM predict + observe over an update block
    let mut gmm = GmmTrackers::new(log.num_nodes, 64, 1.0, 0);
    let s1 = vec![0.1f32; 64];
    let s2 = vec![0.3f32; 64];
    let mut pred = vec![0.0f32; 64];
    b.run("gmm_predict_observe_800rows", || {
        for e in &log.events[3000..3400] {
            gmm.predict_into(e.src, Role::Src, &s1, 1.0, &mut pred);
            gmm.observe(e.src, Role::Src, &s1, &s2, 1.0);
            gmm.predict_into(e.dst, Role::Dst, &s1, 1.0, &mut pred);
            gmm.observe(e.dst, Role::Dst, &s1, &s2, 1.0);
        }
    });

    // negative sampling
    let sampler = NegativeSampler::new(log);
    let mut rng = Pcg32::new(7);
    let mut negs = vec![0u32; 400];
    b.run("negative_sample_b400", || {
        sampler.sample_batch(log, 4000..4400, &mut rng, &mut negs);
        black_box(negs[0]);
    });

    // ranking metrics at eval scale
    let mut mrng = Pcg32::new(9);
    let scores: Vec<f32> = (0..8000).map(|_| mrng.f32()).collect();
    let labels: Vec<bool> = (0..8000).map(|_| mrng.below(2) == 0).collect();
    b.run("average_precision_8k", || {
        black_box(average_precision(&scores, &labels));
    });
    b.run("roc_auc_8k", || {
        black_box(roc_auc(&scores, &labels));
    });

    // dataset generation itself
    b.run("datagen_wiki_25k", || {
        black_box(datagen::generate(&profile, 1));
    });

    b.write_csv().unwrap();
    // comparable-artifact convention (bench-manifest lint): the timing
    // rows land in the JSON doc; this bench has no extra case records
    b.write_json("BENCH_substrates.json", vec![]).unwrap();
}
