//! Host EXEC throughput: full train-step latency (forward + backward +
//! Adam) on the pure-Rust backend, swept over batch size × pool workers.
//!
//!     cargo bench --bench host_exec [-- --quick]
//!
//! Lands in `BENCH_exec.json`: per-case step wall time and steps/s, plus
//! an events/s figure (batch events per step). The worker sweep is the
//! acceptance signal that host EXEC actually exercises the PR 3 worker
//! pool — steps/s should improve from 1 lane to multiple lanes at the
//! larger batch sizes. The gemm sweep (naive vs blocked kernels) is the
//! end-to-end acceptance signal for the blocked GEMM backend: steps/s
//! should improve under `blocked` at every worker count.

use std::sync::Arc;

use pres::model::ModelState;
use pres::runtime::engine::{lit_f32, lit_i32};
use pres::runtime::{DType, Engine, GemmBackendKind};
use pres::util::bench::{black_box, Bench};
use pres::util::json::Json;
use pres::util::pool::WorkerPool;
use pres::util::rng::Pcg32;
use xla::Literal;

/// Plausible data literals for every non-param input of a train spec.
fn data_literals(spec: &pres::runtime::ArtifactSpec, skip: usize, seed: u64) -> Vec<Literal> {
    let mut rng = Pcg32::new(seed);
    spec.inputs[skip..]
        .iter()
        .map(|t| match t.dtype {
            DType::I32 => {
                let vals: Vec<i32> = (0..t.elems())
                    .map(|_| if rng.below(3) == 0 { rng.below(2 * spec.batch as u32) as i32 } else { -1 })
                    .collect();
                lit_i32(&vals, &t.shape).unwrap()
            }
            DType::F32 => {
                let host: Vec<f32> = if t.name == "pres_on" {
                    vec![1.0]
                } else if t.name == "beta" || t.name == "lr" {
                    vec![0.01]
                } else if t.name == "step_t" {
                    vec![1.0]
                } else if t.name.ends_with("_mask") || t.name == "u_wmask" || t.name == "u_cmask" {
                    (0..t.elems()).map(|_| rng.below(2) as f32).collect()
                } else if t.name.ends_with("_dt") {
                    (0..t.elems()).map(|_| rng.f32() * 3.0).collect()
                } else {
                    (0..t.elems()).map(|_| rng.normal() * 0.3).collect()
                };
                lit_f32(&host, &t.shape).unwrap()
            }
        })
        .collect()
}

fn clone_f32(lits: &[Literal]) -> Vec<Literal> {
    lits.iter()
        .map(|l| {
            let mut host = vec![0.0f32; l.element_count()];
            l.copy_raw_to(&mut host).unwrap();
            let dims: Vec<usize> =
                l.array_shape().unwrap().dims().iter().map(|&d| d as usize).collect();
            lit_f32(&host, &dims).unwrap()
        })
        .collect()
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut bench = Bench::new("host_exec");
    bench.header();
    let mut cases = Vec::new();

    let batches: &[usize] = if quick { &[50] } else { &[50, 200] };
    let workers: &[usize] = if quick { &[1, 4] } else { &[1, 2, 4, 8] };

    for model in ["tgn", "jodie", "apan"] {
        for &b in batches {
            for &w in workers {
                for g in [GemmBackendKind::Naive, GemmBackendKind::Blocked] {
                    let engine = Engine::host();
                    engine.set_host_pool(Arc::new(WorkerPool::new(w)));
                    engine.set_host_gemm(g);
                    let step = engine.step(model, b, "train").unwrap();
                    let state = ModelState::init(&engine, model, 0).unwrap();
                    let n = state.len();
                    let data = data_literals(&step.spec, 3 * n, 7);
                    let params = clone_f32(&state.params);
                    let m = clone_f32(&state.adam_m);
                    let v = clone_f32(&state.adam_v);
                    let args: Vec<&Literal> = params
                        .iter()
                        .chain(m.iter())
                        .chain(v.iter())
                        .chain(data.iter())
                        .collect();
                    let label = format!("{model}_b{b}_w{w}_{}", g.name());
                    let ns = bench
                        .run(&label, || {
                            black_box(step.run(&args).unwrap().len());
                        })
                        .mean_ns;
                    let steps_per_sec = 1e9 / ns;
                    cases.push(Json::obj(vec![
                        ("label", Json::str(&label)),
                        ("model", Json::str(model)),
                        ("batch", Json::num(b as f64)),
                        ("pool_workers", Json::num(w as f64)),
                        ("gemm", Json::str(g.name())),
                        ("step_ns", Json::num(ns)),
                        ("steps_per_sec", Json::num(steps_per_sec)),
                        ("events_per_sec", Json::num(steps_per_sec * b as f64)),
                    ]));
                }
            }
        }
    }

    bench.write_csv().unwrap();
    let mut report = bench.report_json(cases);
    report.set("backend", Json::str("host"));
    std::fs::write("BENCH_exec.json", report.to_string_pretty()).unwrap();
    pres::log_info!("-> wrote BENCH_exec.json");
}
