//! The Table 1 speedup source: epoch wall-time at the STANDARD base batch
//! vs PRES at 4x, per model. `cargo bench --bench table1_epoch_time`.

use pres::config::ExperimentConfig;
use pres::training::Trainer;
use pres::util::bench::Bench;

fn main() {
    let base = 50usize;
    let mut b = Bench::new("table1_epoch_time").with_iters(3, 10);
    b.header();
    for model in ["tgn", "jodie", "apan"] {
        let mut times = [0.0f64; 2];
        for (i, (batch, pres)) in [(base, false), (4 * base, true)].into_iter().enumerate() {
            let mut cfg = ExperimentConfig::default_with("wiki", model, batch, pres);
            cfg.epochs = 1;
            let mut tr = Trainer::from_config(&cfg).unwrap();
            tr.train_epoch(0).unwrap(); // warm the executable
            let label = format!(
                "{model}_{}_b{batch}",
                if pres { "pres" } else { "std" }
            );
            let row = b.run(&label, || {
                tr.train_epoch(1).unwrap();
            });
            times[i] = row.mean_ns;
        }
        pres::log_info!(
            "    {model}: speedup = {:.2}x (STANDARD b{base} -> PRES b{})",
            times[0] / times[1],
            4 * base
        );
    }
    b.write_csv().unwrap();
    // comparable-artifact convention (bench-manifest lint): the timing
    // rows land in the JSON doc; this bench has no extra case records
    b.write_json("BENCH_table1.json", vec![]).unwrap();
}
