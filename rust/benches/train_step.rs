//! One-training-iteration latency per (model, batch): the end-to-end hot
//! path (assemble -> PJRT execute -> write-back), measured per phase.
//! This is the number the Table 1 speedup decomposes into.

use pres::config::ExperimentConfig;
use pres::training::Trainer;
use pres::util::bench::Bench;

fn main() {
    let mut b = Bench::new("train_step").with_iters(5, 40);
    b.header();
    for model in ["tgn", "jodie", "apan"] {
        for batch in [25usize, 100, 400, 1600] {
            let mut cfg = ExperimentConfig::default_with("wiki", model, batch, true);
            cfg.epochs = 1;
            cfg.data_scale = 1.0;
            let mut tr = match Trainer::from_config(&cfg) {
                Ok(t) => t,
                Err(e) => {
                    pres::log_warn!("skip {model} b={batch}: {e}");
                    continue;
                }
            };
            // one warm epoch primes the XLA executable + caches
            tr.train_epoch(0).unwrap();
            b.run(&format!("{model}_b{batch}_epoch"), || {
                tr.train_epoch(1).unwrap();
            });
            let r = tr.train_epoch(2).unwrap();
            pres::log_info!(
                "    breakdown: assemble {:.1}% execute {:.1}% writeback {:.1}% ({:.0} events/s)",
                r.assemble_secs / r.epoch_secs * 100.0,
                r.execute_secs / r.epoch_secs * 100.0,
                r.writeback_secs / r.epoch_secs * 100.0,
                r.events_per_sec,
            );
        }
    }
    b.write_csv().unwrap();
    // comparable-artifact convention (bench-manifest lint): the timing
    // rows land in the JSON doc; this bench has no extra case records
    b.write_json("BENCH_train_step.json", vec![]).unwrap();
}
