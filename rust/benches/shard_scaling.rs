//! SPLICE/WRITEBACK wall-time vs memory-shard count, at wiki/gdelt-like
//! `|V| * d` scales — the sharded store's acceptance benchmark.
//!
//!     cargo bench --bench shard_scaling [-- --quick]
//!
//! Per (scale, shards) case this times the two store-side stage bodies the
//! trainer actually runs:
//!
//! * **splice**: the five routed batched gathers of one iteration
//!   (u_self, u_other, src/dst/neg), with routes precomputed PREP-style;
//! * **writeback**: the masked routed scatter of the update rows.
//!
//! Results go to `BENCH_shard.json` (plus the usual results/bench CSV) for
//! EXPERIMENTS.md / CI tracking. Shard counts sweep {1, 2, 4, 8}; 1 is the
//! flat legacy store via `make_backend`, so the speedup column is honest
//! end-to-end (enum dispatch included). Since the worker-pool PR the
//! sharded cases fan out on the persistent process pool (spawn-free
//! handoff, crossover at the recalibrated `PAR_MIN_ELEMS`);
//! `benches/pool_scaling.rs` isolates pool-vs-scoped-spawn and the
//! small-batch crossover → `BENCH_pool.json`.

use pres::memory::{make_backend, MemoryBackend, RowRoute};
use pres::util::bench::{black_box, Bench};
use pres::util::json::Json;
use pres::util::prop::{f32_vec, vertex_vec};
use pres::util::rng::Pcg32;

struct Scale {
    label: &'static str,
    num_nodes: u32,
    d: usize,
    batch: usize,
}

struct Case {
    label: String,
    shards: usize,
    num_nodes: u32,
    d: usize,
    rows: usize,
    splice_ns: f64,
    writeback_ns: f64,
}

fn case_json(c: &Case) -> Json {
    Json::obj(vec![
        ("label", Json::str(&c.label)),
        ("shards", Json::num(c.shards as f64)),
        ("num_nodes", Json::num(c.num_nodes as f64)),
        ("d_mem", Json::num(c.d as f64)),
        ("update_rows", Json::num(c.rows as f64)),
        ("splice_ns", Json::num(c.splice_ns)),
        ("writeback_ns", Json::num(c.writeback_ns)),
    ])
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut bench = Bench::new("shard_scaling").with_iters(3, if quick { 8 } else { 40 });
    bench.header();

    // wiki-scale exercises the small-batch regime; the gdelt-like scale is
    // the one PRES targets (large |V| * d, large temporal batches)
    let scales = [
        Scale { label: "wiki_like", num_nodes: 10_000, d: 100, batch: 600 },
        Scale {
            label: "gdelt_like",
            num_nodes: if quick { 16_384 } else { 65_536 },
            d: 128,
            batch: 4_000,
        },
    ];
    let mut cases: Vec<Case> = Vec::new();

    for s in &scales {
        let rows = 2 * s.batch; // update rows per iteration (src + dst)
        let mut rng = Pcg32::new(0x5A4D ^ s.num_nodes as u64);
        // five gather lists (u_self, u_other, src, dst, neg) + the masked
        // write-back of the update rows, like one trainer iteration
        let u_self = vertex_vec(&mut rng, s.num_nodes, rows);
        let u_other = vertex_vec(&mut rng, s.num_nodes, rows);
        let c_lists: Vec<Vec<u32>> =
            (0..3).map(|_| vertex_vec(&mut rng, s.num_nodes, s.batch)).collect();
        let wb_rows = f32_vec(&mut rng, rows * s.d);
        let wb_ts: Vec<f32> = (0..rows).map(|_| rng.f32() * 100.0).collect();
        let wb_mask: Vec<f32> =
            (0..rows).map(|_| if rng.below(8) == 0 { 0.0 } else { 1.0 }).collect();
        let mut u_self_out = vec![0.0f32; rows * s.d];
        let mut u_other_out = vec![0.0f32; rows * s.d];
        let mut c_out = vec![0.0f32; s.batch * s.d];

        for shards in [1usize, 2, 4, 8] {
            let mut store = make_backend(s.num_nodes, s.d, shards);
            // warm state so gathers copy non-trivial rows
            store.scatter_rows(&u_self, &wb_rows, &wb_ts, None);
            let router = store.router();
            let route = |vs: &[u32]| -> Vec<RowRoute> {
                let mut r = Vec::new();
                router.fill_routes(vs, &mut r);
                r
            };
            let (r_self, r_other) = (route(&u_self), route(&u_other));
            let r_c: Vec<Vec<RowRoute>> = c_lists.iter().map(|vs| route(vs)).collect();
            let n = router.n_shards;

            let label = format!("{}_s{shards}", s.label);
            let splice_ns = bench
                .run(&format!("{label}_splice"), || {
                    store.gather_rows_routed(&u_self, &r_self, n, &mut u_self_out);
                    store.gather_rows_routed(&u_other, &r_other, n, &mut u_other_out);
                    for (vs, r) in c_lists.iter().zip(&r_c) {
                        store.gather_rows_routed(vs, r, n, &mut c_out);
                    }
                    black_box(c_out.first().copied());
                })
                .mean_ns;
            let writeback_ns = bench
                .run(&format!("{label}_writeback"), || {
                    store.scatter_rows_routed(&u_self, &wb_rows, &wb_ts, Some(&wb_mask), &r_self, n);
                })
                .mean_ns;
            pres::log_info!(
                "    {label}: splice {:.2} ms | writeback {:.2} ms",
                splice_ns / 1e6,
                writeback_ns / 1e6
            );
            cases.push(Case {
                label,
                shards,
                num_nodes: s.num_nodes,
                d: s.d,
                rows,
                splice_ns,
                writeback_ns,
            });
        }
    }

    bench.write_csv().unwrap();
    let mut report = bench.report_json(cases.iter().map(case_json).collect());
    report.set(
        "shard_counts",
        Json::arr([1.0, 2.0, 4.0, 8.0].iter().map(|&s| Json::num(s))),
    );
    std::fs::write("BENCH_shard.json", report.to_string_pretty()).unwrap();
    pres::log_info!("-> wrote BENCH_shard.json ({} cases)", cases.len());
}
