//! PJRT dispatch overhead: executable call latency vs payload size, and
//! literal creation/fetch costs. Quantifies the fixed per-step cost that
//! makes small temporal batches slow (the CPU analogue of the paper's
//! GPU-underutilization argument).

use std::path::Path;

use pres::model::ModelState;
use pres::runtime::engine::{fetch_f32, lit_f32};
use pres::runtime::{DType, Engine};
use pres::util::bench::{black_box, Bench};
use xla::Literal;

fn main() {
    let engine = Engine::new(Path::new("artifacts")).expect("run `make artifacts`");
    let mut b = Bench::new("runtime_dispatch").with_iters(5, 60);
    b.header();

    // literal staging costs
    let host_small = vec![0.5f32; 64];
    let host_big = vec![0.5f32; 1600 * 10 * 64];
    b.run("lit_create_256B", || {
        black_box(lit_f32(&host_small, &[64]).unwrap());
    });
    b.run("lit_create_4MB", || {
        black_box(lit_f32(&host_big, &[1600, 10, 64]).unwrap());
    });
    let big = lit_f32(&host_big, &[1600, 10, 64]).unwrap();
    let mut out = vec![0.0f32; host_big.len()];
    b.run("lit_fetch_4MB", || {
        fetch_f32(&big, &mut out).unwrap();
    });

    // full eval-step dispatch at several batch sizes (params + data)
    for batch in [25usize, 100, 400, 1600] {
        let step = engine.step("tgn", batch, "eval").unwrap();
        let state = ModelState::init(&engine, "tgn", 0).unwrap();
        let data: Vec<Literal> = step.spec.inputs[state.len()..]
            .iter()
            .map(|t| match t.dtype {
                DType::I32 => pres::runtime::engine::lit_i32(
                    &vec![-1i32; t.elems()],
                    &t.shape,
                )
                .unwrap(),
                DType::F32 => lit_f32(&vec![0.1f32; t.elems()], &t.shape).unwrap(),
            })
            .collect();
        let args: Vec<&Literal> = state.params.iter().chain(data.iter()).collect();
        b.run(&format!("eval_dispatch_tgn_b{batch}"), || {
            black_box(step.run(&args).unwrap());
        });
    }
    b.write_csv().unwrap();
    // comparable-artifact convention (bench-manifest lint): the timing
    // rows land in the JSON doc; this bench has no extra case records
    b.write_json("BENCH_runtime_dispatch.json", vec![]).unwrap();
}
