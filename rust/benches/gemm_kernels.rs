//! GEMM kernel throughput: naive vs blocked across the host step's
//! dominant matmul shapes, swept over pool worker counts.
//!
//!     cargo bench --bench gemm_kernels [-- --quick]
//!
//! Lands in `BENCH_gemm.json`: per-case kernel wall time and GFLOP/s. Two
//! acceptance signals live here: blocked must beat naive at `w1` (a
//! single-lane pool — the speedup is the microkernel's, not the pool's)
//! AND at `w4` (the kernels scale across lanes). Shapes are the step-ABI
//! sizes at wiki batch 200 (`u = 2b = 400` update rows, `u * k_nbr = 2000`
//! attention rows) so the numbers transfer to `benches/host_exec.rs`.

use std::sync::Arc;

use pres::runtime::gemm::{self, Act, GemmBackendKind};
use pres::util::bench::{black_box, Bench};
use pres::util::json::Json;
use pres::util::pool::WorkerPool;
use pres::util::rng::Pcg32;

fn randv(rng: &mut Pcg32, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() * 0.3).collect()
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut bench = Bench::new("gemm_kernels");
    bench.header();
    let mut cases = Vec::new();

    // (site, op, m-or-r, k, n): the step-ABI shapes. NN rows fuse
    // bias + relu (the message-MLP epilogue); NT/TN are the backward
    // shapes of the first MLP layer (dX = dH @ W^T, dW = X^T @ dH).
    let shapes: &[(&str, &str, usize, usize, usize)] = &[
        ("msg_h1", "nn", 400, 160, 128),
        ("msg_out", "nn", 400, 128, 64),
        ("gru_gates", "nn", 400, 64, 192),
        ("att_qkv", "nn", 2000, 96, 64),
        ("clf_h1", "nn", 200, 128, 128),
        ("msg_h1_dx", "nt", 400, 128, 160),
        ("msg_h1_dw", "tn", 400, 160, 128),
    ];
    let workers: &[usize] = if quick { &[1, 4] } else { &[1, 2, 4] };

    for &(site, op, m, k, n) in shapes {
        let mut rng = Pcg32::new(42);
        // NT reads b as [n, k]; TN reads a as [r=m, k-as-rows] — sized for
        // the widest layout so every op can share the same buffers
        let a = randv(&mut rng, m * k.max(n));
        let b = randv(&mut rng, k.max(m) * n);
        let bias = randv(&mut rng, n);
        let mut out = vec![0.0f32; m.max(k) * n];
        for &w in workers {
            let pool = Arc::new(WorkerPool::new(w));
            for g in [GemmBackendKind::Naive, GemmBackendKind::Blocked] {
                let label = format!("{site}_w{w}_{}", g.name());
                let flops: f64;
                let ns = match op {
                    "nn" => {
                        flops = 2.0 * m as f64 * k as f64 * n as f64;
                        let (a, b, o) = (&a[..m * k], &b[..k * n], &mut out[..m * n]);
                        bench
                            .run(&label, || {
                                gemm::mm_nn(g, &pool, a, b, m, k, n, Some(&bias), Act::Relu, o);
                                black_box(o[0]);
                            })
                            .mean_ns
                    }
                    "nt" => {
                        flops = 2.0 * m as f64 * k as f64 * n as f64;
                        let (a, b, o) = (&a[..m * k], &b[..n * k], &mut out[..m * n]);
                        bench
                            .run(&label, || {
                                gemm::mm_nt(g, &pool, a, b, m, k, n, o);
                                black_box(o[0]);
                            })
                            .mean_ns
                    }
                    "tn" => {
                        // out[k, n] += a[m, k]^T @ b[m, n]
                        flops = 2.0 * m as f64 * k as f64 * n as f64;
                        let (a, b, o) = (&a[..m * k], &b[..m * n], &mut out[..k * n]);
                        bench
                            .run(&label, || {
                                gemm::mm_tn_acc(g, &pool, a, b, m, k, n, o);
                                black_box(o[0]);
                            })
                            .mean_ns
                    }
                    other => unreachable!("unknown op {other}"),
                };
                cases.push(Json::obj(vec![
                    ("label", Json::str(&label)),
                    ("site", Json::str(site)),
                    ("op", Json::str(op)),
                    ("m", Json::num(m as f64)),
                    ("k", Json::num(k as f64)),
                    ("n", Json::num(n as f64)),
                    ("pool_workers", Json::num(w as f64)),
                    ("gemm", Json::str(g.name())),
                    ("kernel_ns", Json::num(ns)),
                    ("gflops", Json::num(flops / ns)),
                ]));
            }
        }
    }

    bench.write_csv().unwrap();
    let report = bench.report_json(cases);
    std::fs::write("BENCH_gemm.json", report.to_string_pretty()).unwrap();
    pres::log_info!("-> wrote BENCH_gemm.json");
}
