//! Multi-stream EXEC overlap and the parameter-staleness quality study:
//! training throughput AND model quality across the
//! `--staleness × --param-staleness × --exec-streams` grid, wiki- and
//! gdelt-like profiles.
//!
//!     cargo bench --bench stream_overlap [-- --quick]
//!
//! Two regimes share the lanes (see `pipeline/stream.rs`):
//!
//! * `p = 0` (exact chain): results are bit-identical to the serial
//!   staleness-k loop (tests/pipeline_equivalence.rs), at most one step
//!   mid-flight — streams = 4 is a *control* expected to match
//!   streams = 2, and any steps/s delta is pure coordinator overlap.
//! * `p >= 1` (relaxed chain): `min(p, streams - 1) + 1` grad steps run
//!   genuinely concurrently against cloned parameter snapshots, with Adam
//!   applied in plan order on the coordinator. Numerics change (bounded
//!   gradient delay), so each case also records its final train loss and
//!   val AP — the quality axis of the throughput/staleness trade.
//!
//! Every case builds a FRESH trainer and runs the identical epoch count,
//! so final-loss / val-AP columns are comparable across the grid.
//! `pool_workers = 1` pins the intra-step GEMM fan-out to the executing
//! thread: lane concurrency is then the only parallelism axis, so the
//! steps/s ratios measure the parameter chain, not pool contention.
//! `host_cores` is recorded because lane scaling is bounded by physical
//! cores — on a 1-core box every ratio honestly reports ~1.0x. Writes the
//! sweep to `BENCH_stream.json` for EXPERIMENTS.md / CI tracking.

use pres::config::{ExperimentConfig, PipelineConfig};
use pres::training::Trainer;
use pres::util::bench::Bench;
use pres::util::json::Json;

struct Case {
    label: String,
    profile: String,
    batch: usize,
    streams: usize,
    staleness: usize,
    param_staleness: usize,
    param_lag_max: usize,
    steps_per_sec: f64,
    events_per_sec: f64,
    epoch_secs: f64,
    exec_wait_secs: f64,
    exec_union_secs: f64,
    device_idle_frac: f64,
    final_train_loss: f64,
    val_ap: f64,
    host_cores: usize,
}

fn case_json(c: &Case) -> Json {
    Json::obj(vec![
        ("label", Json::str(&c.label)),
        ("profile", Json::str(&c.profile)),
        ("batch", Json::num(c.batch as f64)),
        ("exec_streams", Json::num(c.streams as f64)),
        ("bounded_staleness", Json::num(c.staleness as f64)),
        ("param_staleness", Json::num(c.param_staleness as f64)),
        ("param_lag_max", Json::num(c.param_lag_max as f64)),
        ("steps_per_sec", Json::num(c.steps_per_sec)),
        ("events_per_sec", Json::num(c.events_per_sec)),
        ("epoch_secs", Json::num(c.epoch_secs)),
        ("exec_wait_secs", Json::num(c.exec_wait_secs)),
        ("exec_union_secs", Json::num(c.exec_union_secs)),
        ("device_idle_frac", Json::num(c.device_idle_frac)),
        ("final_train_loss", Json::num(c.final_train_loss)),
        ("val_ap", Json::num(c.val_ap)),
        ("host_cores", Json::num(c.host_cores as f64)),
    ])
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut bench = Bench::new("stream_overlap").with_iters(2, if quick { 3 } else { 6 });
    bench.header();
    let host_cores = std::thread::available_parallelism().map_or(1, |n| n.get());

    // (staleness k, param_staleness p, exec_streams s): serial baseline,
    // exact-chain overlap (4 lanes = flat control), relaxed chain at
    // growing windows. (2, 2, 4) is the acceptance point: window W = 3.
    let grid: [(usize, usize, usize); 7] = [
        (1, 0, 1), // serial staleness-1 baseline
        (2, 0, 1), // staleness effect alone (memory lag, exact params)
        (1, 0, 2), // exact chain, coordinator overlap only
        (1, 0, 4), // exact chain control: must stay ~flat vs s = 2
        (1, 1, 2), // relaxed, W = 2
        (1, 1, 4), // relaxed, W = 2 (p clamps; lanes beyond W park)
        (2, 2, 4), // relaxed, W = 3 — the scaling point
    ];

    let mut cases: Vec<Case> = Vec::new();
    // (profile, batch, data_scale): wiki-scale is the acceptance profile;
    // the gdelt-like case stresses bigger batches at reduced scale
    let profiles = [
        ("wiki", 200usize, if quick { 0.2f32 } else { 0.5 }),
        ("gdelt", 400, if quick { 0.02 } else { 0.1 }),
    ];
    for (profile, batch, scale) in profiles {
        for (k, p, s) in grid {
            let mut cfg = ExperimentConfig::default_with(profile, "tgn", batch, true);
            cfg.epochs = 3;
            cfg.data_scale = scale;
            cfg.exec = "host".into(); // lanes require the host backend
            cfg.pipeline = PipelineConfig {
                depth: k + 1,
                bounded_staleness: k,
                pool_workers: 1, // GEMMs stay on the executing thread
                exec_streams: s,
                param_staleness: p,
            };
            let label = format!("{profile}_b{batch}_k{k}_p{p}_s{s}");
            let mut tr = match Trainer::from_config(&cfg) {
                Ok(t) => t,
                Err(e) => {
                    pres::log_warn!("skip {label}: {e}");
                    continue;
                }
            };
            // one warm epoch primes the step cache and the worker pool
            tr.train_epoch(0).unwrap();
            bench.run(&label, || {
                tr.train_epoch(1).unwrap();
            });
            let r = tr.train_epoch(2).unwrap();
            let val_ap = tr.eval_val().unwrap();
            let steps_per_sec = r.events_per_sec / batch as f64;
            pres::log_info!(
                "    {label}: {:.2} steps/s ({:.0} ev/s) | lag {} | wait {:.3}s | idle {:.1}% | loss {:.4} | val AP {:.4}",
                steps_per_sec,
                r.events_per_sec,
                r.param_lag_max,
                r.exec_wait_secs,
                r.device_idle_frac * 100.0,
                r.train_loss,
                val_ap,
            );
            cases.push(Case {
                label,
                profile: profile.to_string(),
                batch,
                streams: s,
                staleness: k,
                param_staleness: p,
                param_lag_max: r.param_lag_max,
                steps_per_sec,
                events_per_sec: r.events_per_sec,
                epoch_secs: r.epoch_secs,
                exec_wait_secs: r.exec_wait_secs,
                exec_union_secs: r.exec_union_secs,
                device_idle_frac: r.device_idle_frac,
                final_train_loss: r.train_loss,
                val_ap,
                host_cores,
            });
        }
    }

    bench.write_csv().unwrap();
    bench
        .write_json("BENCH_stream.json", cases.iter().map(case_json).collect())
        .unwrap();
    pres::log_info!("-> wrote BENCH_stream.json ({} cases)", cases.len());

    // the acceptance line: relaxed 4-stream W = 3 vs the serial baseline
    // on the wiki-scale profile (bounded above by host_cores — a 1-core
    // box cannot show lane scaling, and this line says so honestly)
    let wiki = |k: usize, p: usize, s: usize| {
        cases
            .iter()
            .find(|c| c.profile == "wiki" && c.staleness == k && c.param_staleness == p && c.streams == s)
            .map(|c| c.steps_per_sec)
    };
    if let (Some(base), Some(relaxed)) = (wiki(1, 0, 1), wiki(2, 2, 4)) {
        pres::log_info!(
            "-> wiki 4-stream p=2 / 1-stream: {:.3}x ({relaxed:.2} vs {base:.2} steps/s) on {host_cores} core(s)",
            relaxed / base
        );
    }
}
