//! Multi-stream EXEC overlap: training throughput at exec streams 1 vs 2
//! vs 4 under bounded staleness, wiki- and gdelt-like profiles.
//!
//!     cargo bench --bench stream_overlap [-- --quick]
//!
//! At streams = 1 the staleness-k loop executes every step inline on the
//! coordinator; at streams >= 2 steps run on executor lanes while the
//! coordinator commits write-backs, computes metrics and pre-splices the
//! window — results are bit-identical (tests/pipeline_equivalence.rs), so
//! any steps/s delta here is pure overlap. The exact parameter chain keeps
//! at most one step mid-flight, so streams = 4 is a *control* expected to
//! match streams = 2 (flat beyond 2 lanes until relaxed parameter
//! staleness lands), not a scaling point. Writes the sweep to
//! `BENCH_stream.json` for EXPERIMENTS.md / CI tracking.

use pres::config::{ExperimentConfig, PipelineConfig};
use pres::training::Trainer;
use pres::util::bench::Bench;
use pres::util::json::Json;

struct Case {
    label: String,
    profile: String,
    batch: usize,
    streams: usize,
    staleness: usize,
    steps_per_sec: f64,
    events_per_sec: f64,
    epoch_secs: f64,
    exec_wait_secs: f64,
    exec_union_secs: f64,
    device_idle_frac: f64,
}

fn case_json(c: &Case) -> Json {
    Json::obj(vec![
        ("label", Json::str(&c.label)),
        ("profile", Json::str(&c.profile)),
        ("batch", Json::num(c.batch as f64)),
        ("exec_streams", Json::num(c.streams as f64)),
        ("bounded_staleness", Json::num(c.staleness as f64)),
        ("steps_per_sec", Json::num(c.steps_per_sec)),
        ("events_per_sec", Json::num(c.events_per_sec)),
        ("epoch_secs", Json::num(c.epoch_secs)),
        ("exec_wait_secs", Json::num(c.exec_wait_secs)),
        ("exec_union_secs", Json::num(c.exec_union_secs)),
        ("device_idle_frac", Json::num(c.device_idle_frac)),
    ])
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut bench = Bench::new("stream_overlap").with_iters(2, if quick { 3 } else { 6 });
    bench.header();
    const STALENESS: usize = 1;

    let mut cases: Vec<Case> = Vec::new();
    // (profile, batch, data_scale): wiki-scale is the acceptance profile;
    // the gdelt-like case stresses bigger batches at reduced scale
    let profiles = [
        ("wiki", 200usize, if quick { 0.2f32 } else { 0.5 }),
        ("gdelt", 400, if quick { 0.02 } else { 0.1 }),
    ];
    for (profile, batch, scale) in profiles {
        let mut cfg = ExperimentConfig::default_with(profile, "tgn", batch, true);
        cfg.epochs = 1;
        cfg.data_scale = scale;
        cfg.exec = "host".into(); // lanes require the host backend
        let mut tr = match Trainer::from_config(&cfg) {
            Ok(t) => t,
            Err(e) => {
                pres::log_warn!("skip {profile} b={batch}: {e}");
                continue;
            }
        };
        // one warm epoch primes the step cache and the worker pool
        tr.train_epoch(0).unwrap();
        for streams in [1usize, 2, 4] {
            tr.cfg.pipeline = PipelineConfig {
                depth: 2,
                bounded_staleness: STALENESS,
                pool_workers: 0,
                exec_streams: streams,
            };
            let label = format!("{profile}_b{batch}_s{streams}");
            bench.run(&label, || {
                tr.train_epoch(1).unwrap();
            });
            let r = tr.train_epoch(2).unwrap();
            let steps_per_sec = r.events_per_sec / batch as f64;
            pres::log_info!(
                "    {label}: {:.2} steps/s ({:.0} ev/s) | wait {:.3}s | union {:.3}s | idle {:.1}%",
                steps_per_sec,
                r.events_per_sec,
                r.exec_wait_secs,
                r.exec_union_secs,
                r.device_idle_frac * 100.0,
            );
            cases.push(Case {
                label,
                profile: profile.to_string(),
                batch,
                streams,
                staleness: STALENESS,
                steps_per_sec,
                events_per_sec: r.events_per_sec,
                epoch_secs: r.epoch_secs,
                exec_wait_secs: r.exec_wait_secs,
                exec_union_secs: r.exec_union_secs,
                device_idle_frac: r.device_idle_frac,
            });
        }
    }

    bench.write_csv().unwrap();
    bench
        .write_json("BENCH_stream.json", cases.iter().map(case_json).collect())
        .unwrap();
    pres::log_info!("-> wrote BENCH_stream.json ({} cases)", cases.len());

    // the acceptance line: 2-stream >= 1-stream on the wiki-scale profile
    let wiki = |s: usize| {
        cases
            .iter()
            .find(|c| c.profile == "wiki" && c.streams == s)
            .map(|c| c.steps_per_sec)
    };
    if let (Some(s1), Some(s2)) = (wiki(1), wiki(2)) {
        pres::log_info!(
            "-> wiki 2-stream / 1-stream: {:.3}x ({s2:.2} vs {s1:.2} steps/s)",
            s2 / s1
        );
    }
}
