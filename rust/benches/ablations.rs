//! Ablation benches for DESIGN.md's coordinator design choices:
//!   * plan caching across epochs (cfg.prefetch) vs rebuild-per-epoch
//!   * anchor-set fraction: GMM cost/memory at 1.0 / 0.25 / 0.0
//!   * PRES on/off overhead at a fixed batch size

use pres::config::ExperimentConfig;
use pres::training::Trainer;
use pres::util::bench::Bench;

fn main() {
    let mut b = Bench::new("ablations").with_iters(3, 12);
    b.header();

    // plan caching
    for (name, prefetch) in [("plans_cached", true), ("plans_rebuilt", false)] {
        let mut cfg = ExperimentConfig::default_with("wiki", "tgn", 200, false);
        cfg.prefetch = prefetch;
        cfg.epochs = 1;
        let mut tr = Trainer::from_config(&cfg).unwrap();
        tr.train_epoch(0).unwrap();
        b.run(name, || {
            tr.train_epoch(1).unwrap();
        });
    }

    // anchor-set fraction (PRES tracker coverage)
    for frac in [1.0f32, 0.25, 0.0] {
        let mut cfg = ExperimentConfig::default_with("wiki", "tgn", 200, true);
        cfg.anchor_fraction = frac;
        cfg.epochs = 1;
        let mut tr = Trainer::from_config(&cfg).unwrap();
        tr.train_epoch(0).unwrap();
        pres::log_info!("    anchor={frac}: gmm bytes = {:.2} MB", tr.memory_bytes() as f64 / 1e6);
        b.run(&format!("anchor_{frac}"), || {
            tr.train_epoch(1).unwrap();
        });
    }

    // PRES coordinator overhead vs STANDARD at the same batch
    for (name, pres) in [("std_b400", false), ("pres_b400", true)] {
        let mut cfg = ExperimentConfig::default_with("wiki", "tgn", 400, pres);
        cfg.epochs = 1;
        let mut tr = Trainer::from_config(&cfg).unwrap();
        tr.train_epoch(0).unwrap();
        b.run(name, || {
            tr.train_epoch(1).unwrap();
        });
    }
    b.write_csv().unwrap();
    // comparable-artifact convention (bench-manifest lint): the timing
    // rows land in the JSON doc; this bench has no extra case records
    b.write_json("BENCH_ablations.json", vec![]).unwrap();
}
