//! Tracing/telemetry overhead: training throughput with spans + counters
//! enabled vs the default-off fast path, at exec streams 1 / 2 / 4.
//!
//!     cargo bench --bench trace_overhead [-- --quick]
//!
//! The overhead contract (`trace/mod.rs`): disabled, every instrumentation
//! point costs one relaxed atomic load and a branch — the untraced rows
//! here ARE that fast path, so regressions against the historical
//! `BENCH_stream.json` throughput show up directly. The traced rows bound
//! what `--trace-out`/`--metrics-out` cost when switched on (span pushes
//! into per-thread rings + relaxed counter bumps; still allocation-free).
//! Writes the sweep to `BENCH_trace.json` for EXPERIMENTS.md / CI tracking.

use pres::config::{ExperimentConfig, PipelineConfig};
use pres::trace;
use pres::training::Trainer;
use pres::util::bench::Bench;
use pres::util::json::Json;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut bench = Bench::new("trace_overhead").with_iters(2, if quick { 3 } else { 6 });
    bench.header();

    let batch = 200usize;
    let mut cfg = ExperimentConfig::default_with("wiki", "tgn", batch, true);
    cfg.epochs = 1;
    cfg.data_scale = if quick { 0.2 } else { 0.5 };
    cfg.exec = "host".into(); // lanes require the host backend
    let mut tr = match Trainer::from_config(&cfg) {
        Ok(t) => t,
        Err(e) => {
            pres::log_warn!("skip wiki b={batch}: {e}");
            return;
        }
    };
    // one warm epoch primes the step cache and the worker pool
    tr.train_epoch(0).unwrap();

    let mut cases: Vec<Json> = Vec::new();
    for streams in [1usize, 2, 4] {
        tr.cfg.pipeline = PipelineConfig {
            depth: 2,
            bounded_staleness: 1,
            pool_workers: 0,
            exec_streams: streams,
            param_staleness: 0,
        };

        // default-off fast path: instrumentation gates on one relaxed load
        bench.run(&format!("untraced_s{streams}"), || {
            tr.train_epoch(1).unwrap();
        });
        let r_off = tr.train_epoch(2).unwrap();
        let sps_off = r_off.events_per_sec / batch as f64;

        // everything on: span rings + telemetry counters
        trace::start();
        trace::telemetry::enable_metrics();
        bench.run(&format!("traced_s{streams}"), || {
            tr.train_epoch(1).unwrap();
        });
        let r_on = tr.train_epoch(2).unwrap();
        trace::stop();
        trace::telemetry::disable_metrics();
        trace::clear();
        trace::telemetry::reset();
        let sps_on = r_on.events_per_sec / batch as f64;

        let overhead = 1.0 - sps_on / sps_off;
        pres::log_info!(
            "    s{streams}: untraced {sps_off:.2} steps/s, traced {sps_on:.2} steps/s, \
             enabled overhead {:.1}%",
            overhead * 100.0
        );
        cases.push(Json::obj(vec![
            ("exec_streams", Json::num(streams as f64)),
            ("batch", Json::num(batch as f64)),
            ("untraced_steps_per_sec", Json::num(sps_off)),
            ("traced_steps_per_sec", Json::num(sps_on)),
            ("enabled_overhead_frac", Json::num(overhead)),
        ]));
    }

    bench.write_csv().unwrap();
    bench
        .write_json("BENCH_trace.json", cases)
        .unwrap();
    pres::log_info!("-> wrote BENCH_trace.json");
}
