//! Deterministic, splittable pseudo-random number generation.
//!
//! PCG-XSH-RR 64/32 seeded through SplitMix64. Every stochastic component
//! of the system (dataset generation, negative sampling, parameter init,
//! trial seeds) takes an explicit [`Pcg32`] so experiments are exactly
//! reproducible from the config seed — a requirement for the paper's
//! five-trial mean ± std protocol.

/// SplitMix64: seed expander / fast one-shot hash. Reference: Steele et al.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG-XSH-RR 64/32 (O'Neill 2014): small, fast, statistically solid.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Seed with SplitMix64 expansion so nearby seeds decorrelate.
    pub fn new(seed: u64) -> Self {
        let mut s = seed;
        let state = splitmix64(&mut s);
        let inc = splitmix64(&mut s) | 1;
        let mut rng = Pcg32 { state, inc };
        rng.next_u32(); // warm-up step decouples state from seed layout
        rng
    }

    /// Derive an independent stream (e.g. per trial, per component).
    pub fn split(&mut self, tag: u64) -> Pcg32 {
        let mut s = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let state = splitmix64(&mut s);
        let inc = splitmix64(&mut s) | 1;
        Pcg32 { state, inc }
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform in [0, 1) with 53-bit mantissa.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller (cached second value dropped: the
    /// simplicity is worth the 2x cos/sin cost at our call volumes).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-12);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (inter-event times).
    pub fn exponential(&mut self, lambda: f32) -> f32 {
        -self.f32().max(1e-12).ln() / lambda
    }

    /// Sample an index from unnormalized weights (linear scan — fine for
    /// the generator's per-event Zipf draws over cached prefix sums).
    pub fn weighted(&mut self, cumulative: &[f64]) -> usize {
        let total = *cumulative.last().expect("non-empty weights");
        let x = self.f64() * total;
        match cumulative.binary_search_by(|c| c.total_cmp(&x)) {
            Ok(i) => (i + 1).min(cumulative.len() - 1),
            Err(i) => i.min(cumulative.len() - 1),
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// Cumulative weights for a Zipf-like popularity distribution over n items:
/// w_i ∝ 1 / (i + 1)^alpha. Used by the dataset generators to mirror the
/// heavy-tailed actor/item activity of the JODIE datasets.
pub fn zipf_cumulative(n: usize, alpha: f64) -> Vec<f64> {
    let mut cum = Vec::with_capacity(n);
    let mut acc = 0.0;
    for i in 0..n {
        acc += 1.0 / ((i + 1) as f64).powf(alpha);
        cum.push(acc);
    }
    cum
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3);
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Pcg32::new(7);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3);
    }

    #[test]
    fn f32_in_unit_interval_and_uniform() {
        let mut rng = Pcg32::new(3);
        let n = 100_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let x = rng.f32();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_unbiased_over_small_n() {
        let mut rng = Pcg32::new(4);
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            counts[rng.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(5);
        let n = 100_000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = rng.normal() as f64;
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn weighted_respects_zipf_head() {
        let mut rng = Pcg32::new(6);
        let cum = zipf_cumulative(100, 1.2);
        let mut head = 0;
        let n = 20_000;
        for _ in 0..n {
            if rng.weighted(&cum) < 10 {
                head += 1;
            }
        }
        // top-10 of a 100-item zipf(1.2) carries well over half the mass
        assert!(head as f64 / n as f64 > 0.55, "{head}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(8);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
