//! Minimal JSON parser + writer (RFC 8259 subset sufficient for the
//! artifact manifest, config files and results emission).
//!
//! Written from scratch because the offline registry has no serde facade.
//! Numbers are stored as f64 (the manifest only carries shapes, floats and
//! small ints, all exactly representable).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A JSON value. Object keys are sorted (BTreeMap) so emission is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing data at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Json::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    // ------------------------------------------------------------ accessors

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("expected object for key '{key}'"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_f32(&self) -> Result<f32> {
        Ok(self.as_f64()? as f32)
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_usize()? as u64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// Shape helper: `[2, 3]` -> `vec![2, 3]`.
    pub fn as_shape(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|d| d.as_usize()).collect()
    }

    // ------------------------------------------------------------- builders

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<T: Into<f64>>(n: T) -> Json {
        Json::Num(n.into())
    }

    /// Number that degrades to `null` when non-finite (`NaN`/`inf` are not
    /// representable in JSON; emitting them would corrupt the document).
    pub fn finite(n: f64) -> Json {
        if n.is_finite() {
            Json::Num(n)
        } else {
            Json::Null
        }
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Insert/replace a key on an object (no-op on non-objects). Lets
    /// emitters merge extra fields into a `to_json` result without
    /// rebuilding the pair list.
    pub fn set(&mut self, key: &str, v: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), v);
        }
    }

    // ------------------------------------------------------------- emission

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !items.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !map.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!(
                "expected '{}' at byte {}, found '{}'",
                b as char,
                self.pos,
                self.peek()? as char
            );
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, lit: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let esc = self.peek()?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.pos += 4;
                            // Surrogate pairs are not needed for our data;
                            // map lone surrogates to the replacement char.
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => bail!("bad escape at byte {}", self.pos),
                    }
                }
                b if b < 0x80 => out.push(b as char),
                _ => {
                    // multi-byte UTF-8: re-decode from the original slice
                    let start = self.pos - 1;
                    let s = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|e| anyhow!("utf8: {e}"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos = start + c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n: f64 = text
            .parse()
            .map_err(|e| anyhow!("bad number '{text}' at byte {start}: {e}"))?;
        Ok(Json::Num(n))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']' at byte {}, got '{}'", self.pos, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let value = self.value()?;
            map.insert(key, value);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}' at byte {}, got '{}'", self.pos, c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse("\"a\\nb\\u0041\"").unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{'a': 1}").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,"s\"x"],"n":null,"o":{"k":true}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn property_roundtrip_random_values(){
        // mini property test: random JSON trees survive emit->parse
        use crate::util::rng::Pcg32;
        fn gen(rng: &mut Pcg32, depth: usize) -> Json {
            match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.below(2) == 0),
                2 => Json::Num((rng.next_u32() as f64 / 7.0 * 100.0).round() / 100.0),
                3 => Json::Str(format!("s{}-\"\\\n", rng.next_u32())),
                4 => Json::Arr((0..rng.below(4)).map(|_| gen(rng, depth - 1)).collect()),
                _ => Json::Obj(
                    (0..rng.below(4))
                        .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                        .collect(),
                ),
            }
        }
        let mut rng = Pcg32::new(99);
        for _ in 0..200 {
            let v = gen(&mut rng, 3);
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn set_inserts_and_replaces_on_objects() {
        let mut v = Json::obj(vec![("a", Json::num(1.0))]);
        v.set("b", Json::str("x"));
        v.set("a", Json::num(2.0));
        assert_eq!(v.get("a").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "x");
        let mut n = Json::Num(1.0);
        n.set("a", Json::Null); // no-op, not a panic
        assert_eq!(n, Json::Num(1.0));
    }

    #[test]
    fn shape_accessor() {
        let v = Json::parse("[2, 3, 4]").unwrap();
        assert_eq!(v.as_shape().unwrap(), vec![2, 3, 4]);
        assert!(Json::parse("[2, -1]").unwrap().as_shape().is_err());
    }
}
