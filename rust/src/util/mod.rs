//! From-scratch infrastructure: the offline registry snapshot only ships
//! the `xla` crate closure + `anyhow`, so RNG, JSON, CLI parsing, statistics,
//! a microbench harness, a mini property-testing helper and the persistent
//! worker pool live here.

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;

/// Monotonic wall-clock helper: the sanctioned clock entry point for all
/// stage/bench code (the `clock-discipline` lint in `crate::lint` rejects
/// raw `Instant::now()` outside `trace/`/`metrics/`). One greppable choke
/// point means clock-origin refactors — span-origin anchoring, a virtual
/// clock for deterministic replay — touch exactly one function.
#[allow(clippy::disallowed_methods)] // the choke point itself
pub fn now() -> std::time::Instant {
    std::time::Instant::now()
}

/// Create the parent directory of `path` if it does not exist yet, so
/// `--trace-out runs/a/trace.json` works without a manual `mkdir -p`.
/// A bare filename (no parent, or an empty parent after stripping the
/// final component) is already writable and is left alone.
pub fn ensure_parent_dir(path: &str) -> anyhow::Result<()> {
    use anyhow::Context;
    if let Some(parent) = std::path::Path::new(path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating parent directory for {path}"))?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn ensure_parent_dir_creates_nested_dirs_and_tolerates_bare_names() {
        let root = std::env::temp_dir().join(format!("pres-parent-{}", std::process::id()));
        let file = root.join("a/b/out.json");
        let file = file.to_str().unwrap();
        super::ensure_parent_dir(file).unwrap();
        assert!(root.join("a/b").is_dir());
        // idempotent on an existing parent; a bare filename is a no-op
        super::ensure_parent_dir(file).unwrap();
        super::ensure_parent_dir("just-a-file.json").unwrap();
        std::fs::remove_dir_all(&root).unwrap();
    }
}
