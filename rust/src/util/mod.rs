//! From-scratch infrastructure: the offline registry snapshot only ships
//! the `xla` crate closure + `anyhow`, so RNG, JSON, CLI parsing, statistics,
//! a microbench harness, a mini property-testing helper and the persistent
//! worker pool live here.

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;

/// Monotonic wall-clock helper used across benches/metrics.
pub fn now() -> std::time::Instant {
    std::time::Instant::now()
}
