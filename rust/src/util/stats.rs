//! Small descriptive-statistics helpers used by metrics, figures and the
//! microbench harness (mean ± std over trials is the paper's protocol).

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1); 0 for n < 2.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Linear-interpolated quantile, q in [0, 1].
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// "mean ± std" with given precision (Table 1/2 cell format).
pub fn fmt_mean_std(xs: &[f64], digits: usize) -> String {
    format!("{:.d$} ± {:.d$}", mean(xs), std_dev(xs), d = digits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - 1.2909944).abs() < 1e-6);
        assert_eq!(variance(&xs), 1.25);
    }

    #[test]
    fn quantiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(quantile(&xs, 0.5), 2.5);
    }

    #[test]
    fn empty_is_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(quantile(&[], 0.5), 0.0);
    }
}
