//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw args. `flag_names` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, flag_names: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&name) {
                    out.flags.push(name.to_string());
                } else {
                    match iter.next() {
                        Some(v) => {
                            out.options.insert(name.to_string(), v);
                        }
                        None => bail!("option --{name} expects a value"),
                    }
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    /// Present-or-absent variant for options whose default lives elsewhere
    /// (e.g. `--pipeline-depth` overriding `PipelineConfig::default()`).
    pub fn usize_opt(&self, name: &str) -> Result<Option<usize>> {
        match self.get(name) {
            Some(v) => Ok(Some(v.parse()?)),
            None => Ok(None),
        }
    }

    pub fn f32_or(&self, name: &str, default: f32) -> Result<f32> {
        match self.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str], flags: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()), flags).unwrap()
    }

    #[test]
    fn positional_options_flags() {
        let a = parse(
            &["train", "--dataset", "wiki", "--pres", "--batch=200"],
            &["pres"],
        );
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get("dataset"), Some("wiki"));
        assert!(a.flag("pres"));
        assert_eq!(a.usize_or("batch", 0).unwrap(), 200);
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(["--x".to_string()], &[]).is_err());
    }

    #[test]
    fn defaults() {
        let a = parse(&[], &[]);
        assert_eq!(a.get_or("dataset", "wiki"), "wiki");
        assert_eq!(a.f32_or("beta", 0.1).unwrap(), 0.1);
    }

    #[test]
    fn memory_shards_option_parses_both_spellings() {
        // the trainer's `--memory-shards N` knob: space and `=` forms both
        // reach the same option, absent falls back to the flat default
        let a = parse(&["train", "--memory-shards", "4"], &[]);
        assert_eq!(a.usize_or("memory-shards", 1).unwrap(), 4);
        let b = parse(&["train", "--memory-shards=8"], &[]);
        assert_eq!(b.usize_or("memory-shards", 1).unwrap(), 8);
        let c = parse(&["train"], &[]);
        assert_eq!(c.usize_or("memory-shards", 1).unwrap(), 1);
    }

    #[test]
    fn usize_opt_distinguishes_absent_from_set() {
        let a = parse(&["--pipeline-depth", "2"], &[]);
        assert_eq!(a.usize_opt("pipeline-depth").unwrap(), Some(2));
        assert_eq!(a.usize_opt("staleness").unwrap(), None);
        let bad = parse(&["--pipeline-depth", "two"], &[]);
        assert!(bad.usize_opt("pipeline-depth").is_err());
    }

    #[test]
    fn param_staleness_option_parses_both_spellings() {
        // `--param-staleness p` relaxes the multi-stream parameter chain;
        // absent means "0 = exact" decided by the config layer, not here
        let a = parse(&["train", "--param-staleness", "2"], &[]);
        assert_eq!(a.usize_opt("param-staleness").unwrap(), Some(2));
        let b = parse(&["train", "--param-staleness=1"], &[]);
        assert_eq!(b.usize_opt("param-staleness").unwrap(), Some(1));
        let c = parse(&["train"], &[]);
        assert_eq!(c.usize_opt("param-staleness").unwrap(), None);
    }

    #[test]
    fn pool_workers_option_parses_both_spellings() {
        // `--pool-workers N` sizes the trainer's persistent worker pool;
        // absent means "0 = auto" decided by the config layer, not here
        let a = parse(&["train", "--pool-workers", "4"], &[]);
        assert_eq!(a.usize_opt("pool-workers").unwrap(), Some(4));
        let b = parse(&["train", "--pool-workers=8"], &[]);
        assert_eq!(b.usize_opt("pool-workers").unwrap(), Some(8));
        let c = parse(&["train"], &[]);
        assert_eq!(c.usize_opt("pool-workers").unwrap(), None);
    }
}
