//! Microbench harness (criterion is unavailable offline).
//!
//! Each `cargo bench` target is a plain binary (`harness = false`) that
//! builds a [`Bench`] runner: warmup, timed iterations, and a report with
//! mean / std / p50 / p95 per case, printed in a stable aligned format and
//! optionally appended to `results/bench/*.csv`.

// Sanctioned clock module: the harness times iterations directly.
#![allow(clippy::disallowed_methods)]

use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats;

pub struct Bench {
    name: String,
    warmup_iters: usize,
    min_iters: usize,
    max_iters: usize,
    target_time: Duration,
    rows: Vec<Row>,
}

#[derive(Clone, Debug)]
pub struct Row {
    pub case: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        // honor `cargo bench -- --quick` style knobs through env to keep the
        // CLI surface minimal
        let quick = std::env::args().any(|a| a == "--quick")
            || std::env::var("PRES_BENCH_QUICK").is_ok();
        Bench {
            name: name.to_string(),
            warmup_iters: if quick { 1 } else { 3 },
            min_iters: if quick { 3 } else { 10 },
            max_iters: if quick { 10 } else { 200 },
            target_time: Duration::from_millis(if quick { 200 } else { 1000 }),
            rows: Vec::new(),
        }
    }

    pub fn with_iters(mut self, min: usize, max: usize) -> Self {
        self.min_iters = min;
        self.max_iters = max;
        self
    }

    /// Time `f` and record a row under `case`.
    pub fn run<F: FnMut()>(&mut self, case: &str, mut f: F) -> &Row {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples: Vec<f64> = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (start.elapsed() < self.target_time && samples.len() < self.max_iters)
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let row = Row {
            case: case.to_string(),
            iters: samples.len(),
            mean_ns: stats::mean(&samples),
            std_ns: stats::std_dev(&samples),
            p50_ns: stats::quantile(&samples, 0.5),
            p95_ns: stats::quantile(&samples, 0.95),
        };
        crate::log_info!(
            "{:<44} {:>10} {:>12} {:>12} {:>6}",
            format!("{}/{}", self.name, case),
            fmt_ns(row.mean_ns),
            fmt_ns(row.p50_ns),
            fmt_ns(row.p95_ns),
            row.iters,
        );
        self.rows.push(row);
        self.rows.last().unwrap()
    }

    pub fn header(&self) {
        crate::log_info!(
            "\n=== bench: {} ===\n{:<44} {:>10} {:>12} {:>12} {:>6}",
            self.name, "case", "mean", "p50", "p95", "iters"
        );
    }

    pub fn rows(&self) -> &[Row] {
        &self.rows
    }

    /// Canonical `BENCH_*.json` document: bench name, the harness timing
    /// rows, plus bench-specific `cases` (the one JSON shape every bench
    /// target emits, mirroring `EpochReport::to_json` on the training side).
    pub fn report_json(&self, cases: Vec<Json>) -> Json {
        Json::obj(vec![
            ("bench", Json::str(&self.name)),
            ("rows", Json::arr(self.rows.iter().map(Row::to_json))),
            ("cases", Json::arr(cases)),
        ])
    }

    /// Write [`Bench::report_json`] pretty-printed to `path`.
    pub fn write_json(&self, path: &str, cases: Vec<Json>) -> std::io::Result<()> {
        std::fs::write(path, self.report_json(cases).to_string_pretty())
    }

    /// Append rows to `results/bench/<name>.csv` for EXPERIMENTS.md.
    pub fn write_csv(&self) -> std::io::Result<()> {
        std::fs::create_dir_all("results/bench")?;
        let path = format!("results/bench/{}.csv", self.name);
        let mut out = String::from("case,iters,mean_ns,std_ns,p50_ns,p95_ns\n");
        for r in &self.rows {
            out.push_str(&format!(
                "{},{},{:.0},{:.0},{:.0},{:.0}\n",
                r.case, r.iters, r.mean_ns, r.std_ns, r.p50_ns, r.p95_ns
            ));
        }
        std::fs::write(path, out)
    }
}

impl Row {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("case", Json::str(&self.case)),
            ("iters", Json::num(self.iters as f64)),
            ("mean_ns", Json::num(self.mean_ns)),
            ("std_ns", Json::num(self.std_ns)),
            ("p50_ns", Json::num(self.p50_ns)),
            ("p95_ns", Json::num(self.p95_ns)),
        ])
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Prevent the optimizer from discarding a computed value (std::hint's
/// black_box is stable since 1.66; thin wrapper for readability).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_records() {
        std::env::set_var("PRES_BENCH_QUICK", "1");
        let mut b = Bench::new("selftest");
        let mut acc = 0u64;
        b.run("noop", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert_eq!(b.rows().len(), 1);
        assert!(b.rows()[0].iters >= 3);
        assert!(b.rows()[0].mean_ns >= 0.0);
    }

    #[test]
    fn report_json_carries_rows_and_cases() {
        std::env::set_var("PRES_BENCH_QUICK", "1");
        let mut b = Bench::new("selftest_json");
        b.run("noop", || {});
        let j = b.report_json(vec![Json::obj(vec![("k", Json::num(1.0))])]);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str().unwrap(), "selftest_json");
        let rows = parsed.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("case").unwrap().as_str().unwrap(), "noop");
        assert!(rows[0].get("mean_ns").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(parsed.get("cases").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.0e9), "3.00 s");
    }
}
