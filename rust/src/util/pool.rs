//! Persistent worker pool: the threads behind every parallel host-side
//! stage (sharded SPLICE gathers, WRITEBACK scatters, parallel PREP).
//!
//! ## Why not `std::thread::scope` per op
//!
//! The sharded memory store used to respawn scoped threads on every batched
//! gather/scatter — tens of microseconds of spawn/join per op, which forced
//! a conservative serial/parallel crossover (`PAR_MIN_ELEMS = 1 << 15`) and
//! left wiki-scale batches on the serial path. A [`WorkerPool`] spawns its
//! workers **once**; per-op handoff is a generation bump + condvar wake
//! (~1–2 µs), so the crossover drops by an order of magnitude and the PREP
//! hot loops can afford to fan out too.
//!
//! ## Handoff protocol (generation barrier)
//!
//! One job slot guarded by a mutex, two condvars:
//!
//! ```text
//!   submitter: job = f; generation += 1; remaining = workers; notify_all
//!              f(0)                               (lane 0 = caller)
//!              wait until remaining == 0          (done_cv)
//!   worker i:  wait until generation != seen      (work_cv)
//!              f(i); remaining -= 1; notify done_cv
//! ```
//!
//! The submitter **blocks until every worker has finished**, which is what
//! makes it sound to hand workers a borrowed closure: the borrow outlives
//! every use by construction (the `'static` transmute in `broadcast` is
//! justified exactly by that barrier). Tasks are claimed through an atomic
//! counter, so each `&mut` task is handed out exactly once — ownership
//! replaces locking, as in the scoped design this pool supersedes.
//!
//! A `submit` mutex serializes concurrent submitters (the coordinator's
//! SPLICE/WRITEBACK and the PREP thread may share one pool): the per-op
//! critical sections are microseconds, so contention is noise next to the
//! copies. **Do not** call [`WorkerPool::run`] from inside a task closure
//! of the same pool — the nested submit would self-deadlock.
//!
//! `lanes() == 1` pools spawn no threads at all and run everything inline
//! on the caller, so `--pool-workers 1` is the zero-overhead serial path —
//! and the trivial witness that results cannot depend on the worker count
//! (every parallel consumer is bit-identical across lane counts; pinned by
//! the property suites in `memory/shard.rs` and `tests/shard_equivalence.rs`).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, PoisonError};
use std::thread::JoinHandle;

use crate::trace::{self, telemetry, Stage};

/// Lock with poison recovery: a panic inside a job closure unwinds through
/// `broadcast` while guards are live, poisoning the mutexes — but every
/// critical section here leaves `PoolState` consistent (plain field writes,
/// nothing partial), and the `poisoned` flag already carries the error
/// state, so recovering the guard is correct. Without this, one caught
/// task panic would permanently brick the pool (including the process-wide
/// global one) via `PoisonError` on the next op.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Condvar wait with the same poison recovery as [`lock`].
fn wait<'m, T>(cv: &Condvar, guard: MutexGuard<'m, T>) -> MutexGuard<'m, T> {
    cv.wait(guard).unwrap_or_else(PoisonError::into_inner)
}

/// Type-erased pointer to the submitter's borrowed job closure. Only ever
/// dereferenced between the generation bump and the matching
/// `remaining == 0` barrier, while the submitter is still blocked in
/// [`WorkerPool::broadcast`] keeping the referent alive.
#[derive(Clone, Copy)]
struct RawJob {
    ptr: *const (dyn Fn(usize) + Sync + 'static),
}

// SAFETY: the pointer is only shared under the generation-barrier protocol
// above; the pointee is Sync, so calling it from worker threads is sound.
unsafe impl Send for RawJob {}

struct PoolState {
    generation: u64,
    job: Option<RawJob>,
    /// Workers still running the current generation.
    remaining: usize,
    /// A worker's job closure panicked this generation.
    poisoned: bool,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    work_cv: Condvar,
    done_cv: Condvar,
}

/// `lanes` persistent execution lanes: `lanes - 1` pinned worker threads
/// plus the submitting thread itself (lane 0). Spawned once, reused for
/// every op until drop (which joins all workers).
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<JoinHandle<()>>,
    /// Serializes concurrent submitters onto the single job slot.
    submit: Mutex<()>,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool").field("lanes", &self.lanes()).finish()
    }
}

impl WorkerPool {
    /// Pool with `lanes` total lanes (including the caller's). `lanes = 1`
    /// spawns nothing and runs everything inline; `lanes = 0` means "auto"
    /// (one lane per available core).
    #[allow(clippy::disallowed_methods)] // sanctioned thread-builder site
    pub fn new(lanes: usize) -> WorkerPool {
        let lanes = if lanes == 0 { default_lanes() } else { lanes };
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                generation: 0,
                job: None,
                remaining: 0,
                poisoned: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (1..lanes)
            .map(|lane| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("pres-pool-{lane}"))
                    .spawn(move || worker_loop(&shared, lane))
                    .expect("spawning pool worker thread")
            })
            .collect();
        WorkerPool { shared, handles, submit: Mutex::new(()) }
    }

    /// Auto-sized pool: one lane per available core.
    pub fn auto() -> WorkerPool {
        WorkerPool::new(0)
    }

    /// The process-wide shared pool (auto-sized, spawned on first use,
    /// lives for the process). Default home of every component that is not
    /// handed an explicit pool — so casual construction of a sharded store
    /// or a PREP fill never respawns threads.
    pub fn global() -> &'static Arc<WorkerPool> {
        static GLOBAL: OnceLock<Arc<WorkerPool>> = OnceLock::new();
        GLOBAL.get_or_init(|| Arc::new(WorkerPool::auto()))
    }

    /// Total execution lanes (worker threads + the submitting caller).
    pub fn lanes(&self) -> usize {
        self.handles.len() + 1
    }

    /// Run `f` over every task, fanned out across the pool's lanes. Tasks
    /// are claimed via an atomic counter, so each `&mut T` is exclusive to
    /// exactly one lane; **within** a task `f` runs sequentially, so a task
    /// that is an ordered work list keeps its order (the property WRITEBACK
    /// "last masked row wins" leans on). Blocks until all tasks finished.
    ///
    /// Inline (no handoff at all) when the pool has one lane or there is at
    /// most one task.
    pub fn run<T: Send, F: Fn(&mut T) + Sync>(&self, tasks: &mut [T], f: F) {
        let n = tasks.len();
        if n == 0 {
            return;
        }
        // checked-claims: compiled out in release unless the feature is on
        let run_id = claims::begin_run();
        if self.handles.is_empty() || n == 1 {
            for (i, t) in tasks.iter_mut().enumerate() {
                let _task = claims::task_scope(run_id, i);
                f(t);
            }
            claims::verify(run_id);
            return;
        }
        let base = TaskPtr(tasks.as_mut_ptr());
        let next = AtomicUsize::new(0);
        let body = move |_lane: usize| loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            let _task = claims::task_scope(run_id, i);
            // SAFETY: `i` is claimed exactly once across all lanes, so this
            // is the unique `&mut` to task `i`; the slice outlives
            // `broadcast`, which does not return before every lane is done.
            f(unsafe { &mut *base.0.add(i) });
        };
        // one span per generation barrier (arg = task count) plus the
        // occupancy counters — both a single relaxed load when disabled
        let span = trace::span(Stage::PoolBarrier, n as u64);
        telemetry::count_pool_generation(n as u64, self.lanes() as u64);
        self.broadcast(&body);
        // every task claim is in once the barrier fires; disjointness is
        // asserted before the results are handed back to the caller
        claims::verify(run_id);
        drop(span);
    }

    /// Publish one job to every worker lane, run lane 0 on the caller, and
    /// block until all lanes completed (the generation barrier).
    fn broadcast<'a>(&self, f: &'a (dyn Fn(usize) + Sync + 'a)) {
        /// Erase the job borrow's lifetime. Sound only because `broadcast`
        /// does not return before `remaining` hits zero, so the referent
        /// outlives every worker dereference.
        fn erase<'a>(
            f: &'a (dyn Fn(usize) + Sync + 'a),
        ) -> *const (dyn Fn(usize) + Sync + 'static) {
            let ptr: *const (dyn Fn(usize) + Sync + 'a) = f;
            // SAFETY: same pointer, lifetime bound erased (see above).
            unsafe {
                std::mem::transmute::<
                    *const (dyn Fn(usize) + Sync + 'a),
                    *const (dyn Fn(usize) + Sync + 'static),
                >(ptr)
            }
        }
        let _serialized = lock(&self.submit);
        {
            let mut s = lock(&self.shared.state);
            s.job = Some(RawJob { ptr: erase(f) });
            s.generation += 1;
            s.remaining = self.handles.len();
            self.shared.work_cv.notify_all();
        }
        // Lane 0: the submitter works too — a 1-worker delta never loses to
        // the serial path. Catch a panic so we still drain the barrier (the
        // workers may be touching borrows of this very frame).
        let lane0 = catch_unwind(AssertUnwindSafe(|| f(0)));
        let poisoned = {
            let mut s = lock(&self.shared.state);
            while s.remaining > 0 {
                s = wait(&self.shared.done_cv, s);
            }
            s.job = None;
            std::mem::take(&mut s.poisoned)
        };
        match lane0 {
            Err(payload) => std::panic::resume_unwind(payload),
            Ok(()) if poisoned => panic!("WorkerPool: a worker lane panicked"),
            Ok(()) => {}
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut s = lock(&self.shared.state);
            s.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &PoolShared, lane: usize) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut s = lock(&shared.state);
            loop {
                if s.shutdown {
                    return;
                }
                if s.generation != seen {
                    seen = s.generation;
                    break s.job.expect("job published with generation bump");
                }
                s = wait(&shared.work_cv, s);
            }
        };
        // run outside the lock so lanes actually overlap
        // SAFETY: `job.ptr` was published under the state lock this
        // generation and the submitter blocks in `broadcast` until
        // `remaining` hits zero, so the erased-lifetime referent is alive
        // for the whole call (the soundness argument behind `erase`).
        let ok = catch_unwind(AssertUnwindSafe(|| unsafe { (&*job.ptr)(lane) })).is_ok();
        let mut s = lock(&shared.state);
        if !ok {
            s.poisoned = true;
        }
        s.remaining -= 1;
        if s.remaining == 0 {
            shared.done_cv.notify_all();
        }
    }
}

fn default_lanes() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Send a raw task pointer into the job closure.
struct TaskPtr<T>(*mut T);

// SAFETY: lanes only ever materialize disjoint `&mut` elements from it
// (atomic index claim), and T: Send bounds the data that crosses threads.
unsafe impl<T: Send> Sync for TaskPtr<T> {}

// ---- chunking helpers (shared by the PREP / sampler / route loops) ------

/// Chunk size for splitting `total` rows across `lanes`, with `min_rows`
/// the serial crossover: below it (or on a 1-lane pool) everything lands in
/// one chunk, which [`WorkerPool::run`] executes inline. Chunks are pure
/// layout — per-row outputs are written to fixed disjoint slots — so the
/// chunking can never change results, only where they are computed.
pub fn chunk_for(total: usize, lanes: usize, min_rows: usize) -> usize {
    if lanes <= 1 || total < min_rows {
        return total.max(1);
    }
    total.div_ceil(lanes).max(min_rows / 2).max(1)
}

/// Carve the leading `n` elements off a mutable-slice cursor (the standard
/// `mem::take` + `split_at_mut` reborrow dance, named once instead of
/// inlined at every parallel-loop construction site).
pub fn take_chunk<'a, T>(cursor: &mut &'a mut [T], n: usize) -> &'a mut [T] {
    let (head, tail) = std::mem::take(cursor).split_at_mut(n);
    *cursor = tail;
    head
}

// ---- checked-claims mode (dynamic disjoint-write checking) --------------

/// The pool's Exactness invariant — every pooled loop writes disjoint
/// fixed slots — is what makes the `&mut`-per-task handoff sound and the
/// results lane-count-invariant. This module checks it *dynamically*:
/// pooled tasks register the output ranges they are about to write
/// ([`claims::claim`] / [`claims::claim_raw`]), and the generation barrier
/// asserts pairwise disjointness across tasks before [`WorkerPool::run`]
/// returns results to the caller. Same-task overlap is allowed (a task may
/// claim a whole buffer and then its rows).
///
/// Gated on `debug_assertions` OR the `checked-claims` cargo feature:
/// `cargo test` exercises it everywhere the pool runs, while release
/// builds compile the no-op twin below and pay nothing (soak runs can opt
/// back in with `--features checked-claims`).
#[cfg(any(debug_assertions, feature = "checked-claims"))]
pub mod claims {
    use std::cell::Cell;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::{Mutex, OnceLock, PoisonError};

    #[derive(Clone, Copy, Debug)]
    struct Claim {
        run: u64,
        task: usize,
        base: usize,
        len: usize,
        tag: &'static str,
    }

    /// Run ids are global (not per-pool): two pools — or two concurrent
    /// inline runs on 1-lane pools — interleave in one table without
    /// cross-talk because every claim carries its run id.
    static NEXT_RUN: AtomicU64 = AtomicU64::new(1);

    fn table() -> &'static Mutex<Vec<Claim>> {
        static TABLE: OnceLock<Mutex<Vec<Claim>>> = OnceLock::new();
        TABLE.get_or_init(|| Mutex::new(Vec::new()))
    }

    thread_local! {
        /// (run, task) the current thread is executing for, if any.
        static CURRENT: Cell<Option<(u64, usize)>> = const { Cell::new(None) };
        /// Per-lane claim buffer, flushed into [`table`] once per task so
        /// row-granular claims don't take the global lock per row.
        static LOCAL: std::cell::RefCell<Vec<Claim>> =
            const { std::cell::RefCell::new(Vec::new()) };
    }

    pub(super) fn begin_run() -> u64 {
        NEXT_RUN.fetch_add(1, Ordering::Relaxed)
    }

    /// RAII task context: claims registered while the guard lives are
    /// attributed to `(run, task)`. Drop clears the context and flushes
    /// the lane-local buffer — including on unwind, so a panicking task
    /// neither leaks its identity onto later claims nor loses the claims
    /// it already made.
    pub(super) struct TaskScope;

    impl Drop for TaskScope {
        fn drop(&mut self) {
            CURRENT.with(|c| c.set(None));
            LOCAL.with(|l| {
                let mut buf = l.borrow_mut();
                if !buf.is_empty() {
                    let mut t = table().lock().unwrap_or_else(PoisonError::into_inner);
                    t.append(&mut buf);
                }
            });
        }
    }

    pub(super) fn task_scope(run: u64, task: usize) -> TaskScope {
        CURRENT.with(|c| c.set(Some((run, task))));
        TaskScope
    }

    /// Register the slice this pooled task is about to write. No-op when
    /// called outside a pool task, so serial code paths may call it
    /// unconditionally.
    pub fn claim<T>(xs: &[T], tag: &'static str) {
        claim_raw(xs.as_ptr() as usize, std::mem::size_of_val(xs), tag);
    }

    /// Raw-range flavor of [`claim`]: base address + extent in bytes.
    pub fn claim_raw(base: usize, len: usize, tag: &'static str) {
        let Some((run, task)) = CURRENT.with(|c| c.get()) else {
            return;
        };
        if len == 0 {
            return;
        }
        LOCAL.with(|l| l.borrow_mut().push(Claim { run, task, base, len, tag }));
    }

    /// Drain this run's claims and assert cross-task disjointness (sweep
    /// over base-sorted ranges tracking the furthest extent; the panic
    /// fires at the earliest overlap). Runs at the generation barrier,
    /// before results are published to the submitter.
    pub(super) fn verify(run: u64) {
        let mut mine: Vec<Claim> = Vec::new();
        {
            let mut t = table().lock().unwrap_or_else(PoisonError::into_inner);
            t.retain(|c| {
                if c.run == run {
                    mine.push(*c);
                    false
                } else {
                    true
                }
            });
        }
        mine.sort_by_key(|c| c.base);
        let mut furthest: Option<usize> = None; // index of max-end claim so far
        for i in 0..mine.len() {
            if let Some(m) = furthest {
                let prev = mine[m];
                let cur = mine[i];
                if cur.base < prev.base + prev.len && cur.task != prev.task {
                    panic!(
                        "checked-claims: overlapping pooled writes — task {} ({}: {:#x}..{:#x}) \
                         vs task {} ({}: {:#x}..{:#x})",
                        prev.task,
                        prev.tag,
                        prev.base,
                        prev.base + prev.len,
                        cur.task,
                        cur.tag,
                        cur.base,
                        cur.base + cur.len
                    );
                }
                if cur.base + cur.len > prev.base + prev.len {
                    furthest = Some(i);
                }
            } else {
                furthest = Some(i);
            }
        }
    }
}

/// No-op twin of the checked-claims module: with the gate off every entry
/// point is an empty `#[inline(always)]` fn, so claim registrations at
/// call sites (shard gathers/scatters) compile to nothing in release.
#[cfg(not(any(debug_assertions, feature = "checked-claims")))]
pub mod claims {
    #[inline(always)]
    pub fn claim<T>(_xs: &[T], _tag: &'static str) {}

    #[inline(always)]
    pub fn claim_raw(_base: usize, _len: usize, _tag: &'static str) {}

    pub(super) struct TaskScope;

    #[inline(always)]
    pub(super) fn begin_run() -> u64 {
        0
    }

    #[inline(always)]
    pub(super) fn task_scope(_run: u64, _task: usize) -> TaskScope {
        TaskScope
    }

    #[inline(always)]
    pub(super) fn verify(_run: u64) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_executes_every_task_exactly_once() {
        let pool = WorkerPool::new(4);
        let mut tasks: Vec<(usize, usize)> = (0..257).map(|i| (i, 0)).collect();
        pool.run(&mut tasks, |t| t.1 = t.0 * 2);
        for (i, got) in &tasks {
            assert_eq!(*got, i * 2);
        }
    }

    #[test]
    fn results_are_identical_across_lane_counts() {
        let serial = {
            let mut xs: Vec<u64> = (0..1000).collect();
            WorkerPool::new(1).run(&mut xs, |x| *x = x.wrapping_mul(0x9E37_79B9).rotate_left(7));
            xs
        };
        for lanes in [2usize, 3, 8] {
            let pool = WorkerPool::new(lanes);
            let mut xs: Vec<u64> = (0..1000).collect();
            pool.run(&mut xs, |x| *x = x.wrapping_mul(0x9E37_79B9).rotate_left(7));
            assert_eq!(xs, serial, "lanes={lanes}");
        }
    }

    #[test]
    fn single_lane_pool_spawns_no_threads_and_runs_inline() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.lanes(), 1);
        // inline => runs on this very thread, in task order
        let me = std::thread::current().id();
        let log = Mutex::new(Vec::new());
        let mut tasks: Vec<usize> = (0..8).collect();
        pool.run(&mut tasks, |t| {
            assert_eq!(std::thread::current().id(), me);
            log.lock().unwrap().push(*t);
        });
        assert_eq!(log.into_inner().unwrap(), (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn pool_is_reusable_across_many_generations() {
        // epochs × ops on one pool: the generation counter and the free
        // barrier must survive arbitrary reuse (the trainer runs thousands
        // of ops per epoch on the same pool)
        let pool = WorkerPool::new(3);
        for epoch in 0..50 {
            let mut xs = vec![0usize; 64];
            pool.run(&mut xs, |x| *x += epoch);
            assert!(xs.iter().all(|&x| x == epoch));
        }
    }

    #[test]
    fn construct_drop_cycles_do_not_leak_workers() {
        // every Drop joins its workers; 50 cycles would accumulate 150
        // threads if join were broken (and deadlock if shutdown were)
        for _ in 0..50 {
            let pool = WorkerPool::new(4);
            let mut xs = vec![1u32; 16];
            pool.run(&mut xs, |x| *x += 1);
            assert!(xs.iter().all(|&x| x == 2));
            drop(pool);
        }
    }

    #[test]
    #[allow(clippy::disallowed_methods)] // raw spawn: the test IS the second thread
    fn concurrent_submitters_serialize_without_deadlock() {
        // the PREP thread and the coordinator share one pool in the trainer
        let pool = Arc::new(WorkerPool::new(2));
        let other = pool.clone();
        let handle = std::thread::spawn(move || {
            let mut xs = vec![0u64; 512];
            for _ in 0..20 {
                other.run(&mut xs, |x| *x += 1);
            }
            xs[0]
        });
        let mut ys = vec![0u64; 512];
        for _ in 0..20 {
            pool.run(&mut ys, |y| *y += 2);
        }
        assert_eq!(handle.join().unwrap(), 20);
        assert!(ys.iter().all(|&y| y == 40));
    }

    #[test]
    fn empty_and_singleton_task_lists_are_noops_or_inline() {
        let pool = WorkerPool::new(4);
        let mut none: Vec<u32> = Vec::new();
        pool.run(&mut none, |_| unreachable!("no tasks to run"));
        let mut one = vec![7u32];
        pool.run(&mut one, |x| *x += 1);
        assert_eq!(one[0], 8);
    }

    #[test]
    fn worker_panic_propagates_to_the_submitter_and_pool_survives() {
        let pool = WorkerPool::new(4);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            let mut xs: Vec<usize> = (0..64).collect();
            pool.run(&mut xs, |x| {
                if *x == 13 {
                    panic!("unlucky task");
                }
            });
        }));
        assert!(caught.is_err(), "panic in a task must surface");
        // the barrier drained, the pool keeps working
        let mut xs = vec![0u8; 32];
        pool.run(&mut xs, |x| *x = 1);
        assert!(xs.iter().all(|&x| x == 1));
    }

    #[test]
    fn chunk_for_respects_serial_crossover_and_covers_total() {
        assert_eq!(chunk_for(100, 1, 8), 100); // 1 lane => one chunk
        assert_eq!(chunk_for(100, 4, 256), 100); // below crossover => serial
        let c = chunk_for(10_000, 4, 256);
        assert!(c >= 128 && c * 4 >= 10_000);
        assert_eq!(chunk_for(0, 4, 8), 1); // degenerate: still nonzero
    }

    #[test]
    fn take_chunk_walks_a_cursor_without_overlap() {
        let mut data: Vec<u32> = (0..10).collect();
        let mut cur = data.as_mut_slice();
        let a = take_chunk(&mut cur, 4);
        let b = take_chunk(&mut cur, 6);
        assert_eq!(a, &[0, 1, 2, 3]);
        assert_eq!(b, &[4, 5, 6, 7, 8, 9]);
        assert!(cur.is_empty());
    }

    #[test]
    #[cfg(any(debug_assertions, feature = "checked-claims"))]
    fn checked_claims_accept_disjoint_pooled_writes() {
        let pool = WorkerPool::new(4);
        let mut buf = vec![0u8; 1024];
        let base = buf.as_mut_ptr() as usize;
        // 8 tasks each claim (and write) their own 128-byte stripe; a task
        // may also re-claim rows inside its own stripe (self-overlap is
        // legal — only cross-task overlap is a violation)
        let mut tasks: Vec<(usize, usize)> = (0..8).map(|i| (i * 128, 128)).collect();
        pool.run(&mut tasks, |t| {
            claims::claim_raw(base + t.0, t.1, "stripe");
            claims::claim_raw(base + t.0, 16, "stripe-head");
        });
        // inline (1-lane) runs verify too
        WorkerPool::new(1).run(&mut tasks, |t| claims::claim_raw(base + t.0, t.1, "stripe"));
        drop(buf);
    }

    #[test]
    #[cfg(any(debug_assertions, feature = "checked-claims"))]
    fn checked_claims_catch_an_overlapping_scatter_claim() {
        let pool = WorkerPool::new(2);
        // deliberately overlapping "scatter" claims: task 0 takes bytes
        // 0x1000..0x1060, task 1 takes 0x1040..0x10a0 (32-byte collision)
        let mut tasks: Vec<(usize, usize)> = vec![(0x1000, 0x60), (0x1040, 0x60)];
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.run(&mut tasks, |t| claims::claim_raw(t.0, t.1, "scatter"));
        }));
        let payload = caught.expect_err("overlapping claims must panic at the barrier");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "non-string panic payload".to_string());
        assert!(msg.contains("checked-claims"), "unexpected panic: {msg}");
        // the claim table drained despite the panic; the pool still works
        let mut ok: Vec<(usize, usize)> = vec![(0, 16), (16, 16)];
        pool.run(&mut ok, |t| claims::claim_raw(t.0, t.1, "disjoint"));
    }

    #[test]
    #[cfg(any(debug_assertions, feature = "checked-claims"))]
    fn claims_outside_a_pool_task_are_ignored() {
        // serial code paths may call claim unconditionally: without a task
        // scope on this thread the registration is a no-op
        claims::claim_raw(0x2000, 64, "no-task-context");
        let xs = [0f32; 8];
        claims::claim(&xs, "no-task-context");
    }
}
