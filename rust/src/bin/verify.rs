//! `pallas-verify`: exhaustive small-scope model check of the pipeline
//! schedules. Compiles each coordinator loop to its action script and
//! verifies, for every knob combination on the grid `n_train <= 12`,
//! `k <= 3`, `p <= 3`, `streams <= 4`: splice lag <= k (equality
//! witnessed), param lag <= min(p, streams-1) (equality witnessed),
//! commits strictly in plan order, in-flight window <= W, and
//! deadlock-freedom over every lane-completion interleaving. See
//! [`pres::verify`] for the abstraction. Exits nonzero on any violation
//! so CI can gate on it. This file is sanctioned for direct printing —
//! the verdict is its stdout product.
//!
//! Usage: `pallas-verify [--json]`.

use std::process::ExitCode;

use pres::util::json::Json;
use pres::verify::schedule;

fn main() -> ExitCode {
    let mut json = false;
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--json" => json = true,
            "--help" | "-h" => {
                println!("usage: pallas-verify [--json]");
                println!(
                    "exhaustively checks every pipeline schedule with n_train <= {}, \
                     k <= {}, p <= {}, streams <= {}",
                    schedule::GRID_N_TRAIN,
                    schedule::GRID_K,
                    schedule::GRID_P,
                    schedule::GRID_STREAMS
                );
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("pallas-verify: unknown argument `{other}`");
                return ExitCode::from(2);
            }
        }
    }
    match schedule::check_grid() {
        Ok(sum) => {
            if json {
                let doc = Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("configs_checked", Json::num(sum.checked as u32)),
                    ("configs_skipped_invalid", Json::num(sum.skipped as u32)),
                    ("coordinator_actions", Json::num(sum.actions as u32)),
                    ("interleaving_states", Json::num(sum.states as u32)),
                ]);
                println!("{}", doc.to_string_pretty());
            } else {
                println!(
                    "pallas-verify: clean — {} configs exhaustively checked \
                     ({} invalid combos mirrored+skipped, {} coordinator actions, \
                     {} interleaving states)",
                    sum.checked, sum.skipped, sum.actions, sum.states
                );
            }
            ExitCode::SUCCESS
        }
        Err(v) => {
            if json {
                let doc = Json::obj(vec![
                    ("ok", Json::Bool(false)),
                    ("violation", Json::str(v.to_string())),
                ]);
                println!("{}", doc.to_string_pretty());
            } else {
                println!("pallas-verify: VIOLATION {v}");
            }
            ExitCode::FAILURE
        }
    }
}
