//! `pallas-lint`: the repo-invariant lint CLI. Walks `src/`, `benches/`
//! and `tests/` enforcing the rules documented in [`pres::lint`]; exits
//! nonzero on any finding so CI (and pre-push hooks) can gate on it.
//!
//! Usage: `pallas-lint [--json] [crate-root]`. With no root argument it
//! accepts being launched from either the crate directory (`rust/`) or
//! the repo root. This file is sanctioned for direct printing — the
//! findings are its stdout product.

use std::path::PathBuf;
use std::process::ExitCode;

use pres::lint;

fn main() -> ExitCode {
    let mut json = false;
    let mut root: Option<PathBuf> = None;
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--json" => json = true,
            "--help" | "-h" => {
                println!("usage: pallas-lint [--json] [crate-root]");
                println!("rules:");
                for (name, what) in lint::RULES {
                    println!("  {name:<18} {what}");
                }
                return ExitCode::SUCCESS;
            }
            other => root = Some(PathBuf::from(other)),
        }
    }
    let root = root.unwrap_or_else(|| {
        if PathBuf::from("src").is_dir() {
            PathBuf::from(".")
        } else {
            PathBuf::from("rust")
        }
    });
    match lint::lint_tree(&root) {
        Err(e) => {
            eprintln!("pallas-lint: {e:#}");
            ExitCode::from(2)
        }
        Ok(findings) if findings.is_empty() => {
            println!("pallas-lint: clean ({} rules over {})", lint::RULES.len(), root.display());
            ExitCode::SUCCESS
        }
        Ok(findings) => {
            if json {
                println!("{}", lint::to_json(&findings).to_string_pretty());
            } else {
                print!("{}", lint::render(&findings));
                println!("pallas-lint: {} finding(s)", findings.len());
            }
            ExitCode::FAILURE
        }
    }
}
