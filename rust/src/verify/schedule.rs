//! The schedule state machines and their exhaustive checker.
//!
//! See the module docs on [`crate::verify`] for the abstraction. The
//! scripts here must mirror the coordinator loop bodies in
//! `training/trainer.rs` statement-for-statement; the cross-validation
//! test in `tests/pipeline_equivalence.rs` keeps the two from drifting by
//! replaying [`predicted`] against the real trainer's epoch witnesses.

use std::collections::BTreeSet;
use std::fmt;

/// The pipeline knobs a schedule is a pure function of.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Knobs {
    /// Train-split plan count; iterations run `1..n_train` (plan 0 only
    /// seeds the first splice), so `n_train - 1` steps commit per epoch.
    pub n_train: usize,
    /// `bounded_staleness`: memory-splice lag bound (MSPipe-style).
    pub k: usize,
    /// `param_staleness`: parameter lag bound (DistTGL-style).
    pub p: usize,
    /// `exec_streams`: EXEC lane count.
    pub streams: usize,
}

/// Which coordinator loop `train_epoch` dispatches to (with prefetch
/// depth > 0, which every staleness configuration requires anyway).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoopKind {
    /// `n_train <= 1`: nothing to overlap, no loop body runs.
    Trivial,
    /// `streams == 1`: inline EXEC, pre-splicing up to `k` ahead.
    Pipelined,
    /// `streams > 1`, `p == 0`: lanes hide coordinator work, exact
    /// parameter chain (at most one step mid-flight).
    ExactMultistream,
    /// `streams > 1`, `p > 0`: `W = min(p, streams-1) + 1` steps
    /// genuinely in flight against bounded-lag parameter snapshots.
    RelaxedMultistream,
}

impl Knobs {
    /// Mirror of the `PipelineConfig` rules in `config::validate` (the
    /// combinations a user can actually run): at least one lane;
    /// multi-stream requires `k >= 1` (nothing can overlap at `k = 0`);
    /// a realized parameter lag must fit inside the memory window
    /// (`min(p, streams-1) <= k`, which is what makes batch `i + W`
    /// already spliced when it is submitted).
    pub fn valid(&self) -> bool {
        if self.streams == 0 {
            return false;
        }
        if self.streams > 1 && self.k == 0 {
            return false;
        }
        if self.p > 0 && self.p.min(self.streams - 1) > self.k {
            return false;
        }
        true
    }

    /// The loop `train_epoch` dispatches this configuration to.
    pub fn loop_kind(&self) -> LoopKind {
        if self.n_train <= 1 {
            LoopKind::Trivial
        } else if self.streams > 1 {
            if self.p > 0 {
                LoopKind::RelaxedMultistream
            } else {
                LoopKind::ExactMultistream
            }
        } else {
            LoopKind::Pipelined
        }
    }

    /// The in-flight window `W = min(p, streams - 1) + 1` (1 for every
    /// exact loop: submissions happen only after the previous wait).
    pub fn window(&self) -> usize {
        self.p.min(self.streams - 1) + 1
    }
}

impl fmt::Display for Knobs {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "n_train={} k={} p={} streams={}",
            self.n_train, self.k, self.p, self.streams
        )
    }
}

/// One coordinator operation. `j` is always a plan index in
/// `1..n_train`; `lag`s are the values the real loops record into the
/// epoch timer (the static pass re-derives them from first principles
/// and rejects any mismatch).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Action {
    /// Install batch `j`'s memory splice; its view misses `lag` commits.
    Splice { j: usize, lag: usize },
    /// Put step `j` in flight on a lane; its parameter snapshot misses
    /// `param_lag` plan-order optimizer commits.
    Submit { j: usize, param_lag: usize },
    /// Block until step `j` (the commit-queue front) returns, then apply
    /// its optimizer commit.
    Wait { j: usize },
    /// Apply step `j`'s memory write-back, strictly in plan order.
    Writeback { j: usize },
}

/// Compile the coordinator loop for `kn` to its action script. Each arm
/// mirrors the corresponding `run_*_epoch` body in
/// `training/trainer.rs`, including the prologue fills and the in-loop
/// window top-ups, so the script *is* the schedule.
pub fn script(kn: &Knobs) -> Vec<Action> {
    let n_train = kn.n_train;
    let stale = kn.k;
    let mut s = Vec::new();
    if n_train <= 1 {
        return s;
    }
    let last = n_train - 1;
    match kn.loop_kind() {
        LoopKind::Trivial => {}
        LoopKind::Pipelined => {
            // mirrors run_pipelined_epoch: splice-exec inline, then the
            // pre-splice window fill, then the write-back
            let mut presliced: std::collections::VecDeque<usize> = Default::default();
            for i in 1..n_train {
                if presliced.front() == Some(&i) {
                    presliced.pop_front();
                } else {
                    s.push(Action::Splice { j: i, lag: 0 });
                }
                s.push(Action::Submit { j: i, param_lag: 0 });
                s.push(Action::Wait { j: i });
                while stale > 0 && presliced.len() < stale {
                    let next = i + presliced.len() + 1;
                    if next >= n_train {
                        break;
                    }
                    s.push(Action::Splice { j: next, lag: next - i });
                    presliced.push_back(next);
                }
                s.push(Action::Writeback { j: i });
            }
        }
        LoopKind::ExactMultistream => {
            // mirrors run_multistream_epoch: prologue splice + submit 1,
            // window fill, then wait i -> submit i+1 -> WB i -> top-up
            s.push(Action::Splice { j: 1, lag: 0 });
            s.push(Action::Submit { j: 1, param_lag: 0 });
            let mut hi = 1usize;
            while hi < (1 + stale).min(last) {
                let next = hi + 1;
                s.push(Action::Splice { j: next, lag: next - 1 });
                hi = next;
            }
            for i in 1..n_train {
                s.push(Action::Wait { j: i });
                if i < last {
                    s.push(Action::Submit { j: i + 1, param_lag: 0 });
                }
                s.push(Action::Writeback { j: i });
                while hi < (i + 1 + stale).min(last) {
                    let next = hi + 1;
                    s.push(Action::Splice { j: next, lag: next - (i + 1) });
                    hi = next;
                }
            }
        }
        LoopKind::RelaxedMultistream => {
            // mirrors run_relaxed_multistream_epoch: prologue splices,
            // then the first W submissions against params v0, then
            // wait i -> (Adam) -> WB i -> splice top-up -> submit i+W
            let w = kn.window();
            s.push(Action::Splice { j: 1, lag: 0 });
            let mut hi = 1usize;
            while hi < (1 + stale).min(last) {
                let next = hi + 1;
                s.push(Action::Splice { j: next, lag: next - 1 });
                hi = next;
            }
            for j in 1..=w.min(last) {
                s.push(Action::Submit { j, param_lag: j - 1 });
            }
            for i in 1..n_train {
                s.push(Action::Wait { j: i });
                s.push(Action::Writeback { j: i });
                while hi < (i + 1 + stale).min(last) {
                    let next = hi + 1;
                    s.push(Action::Splice { j: next, lag: next - (i + 1) });
                    hi = next;
                }
                if i + w <= last {
                    s.push(Action::Submit { j: i + w, param_lag: w - 1 });
                }
            }
        }
    }
    s
}

/// The closed-form schedule witnesses: what the real trainer's
/// `EpochReport` must report for this configuration. The checker proves
/// these are exact (bounds hold AND are attained) for every grid point.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Prediction {
    /// `min(k, n_train - 2)` for any pipelined loop (0 when nothing runs):
    /// the window wants lag `k` but is capped by the last batch.
    pub splice_lag_max: usize,
    /// `min(p, streams - 1, n_train - 2)` for the relaxed loop, else 0.
    pub param_lag_max: usize,
    /// Peak submitted-but-uncommitted steps: `min(W, n_train - 1)`.
    pub window_peak: usize,
}

/// Closed-form witnesses for `kn` (see [`Prediction`]).
pub fn predicted(kn: &Knobs) -> Prediction {
    if kn.n_train <= 1 {
        return Prediction { splice_lag_max: 0, param_lag_max: 0, window_peak: 0 };
    }
    let last = kn.n_train - 1;
    let splice_lag_max = kn.k.min(kn.n_train - 2);
    match kn.loop_kind() {
        LoopKind::RelaxedMultistream => Prediction {
            splice_lag_max,
            param_lag_max: kn.window().min(last) - 1,
            window_peak: kn.window().min(last),
        },
        _ => Prediction { splice_lag_max, param_lag_max: 0, window_peak: 1 },
    }
}

/// One invariant violation at one grid point.
#[derive(Clone, Debug)]
pub struct Violation {
    pub knobs: Knobs,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}", self.knobs, self.msg)
    }
}

/// Per-configuration check report.
#[derive(Clone, Copy, Debug)]
pub struct Report {
    /// Script length (coordinator operations this epoch).
    pub actions: usize,
    /// Distinct `(pc, in-flight set)` states the exhaustive DFS visited.
    pub states: usize,
    /// The witnessed schedule quantities (equal to [`predicted`]).
    pub observed: Prediction,
}

/// Check one configuration: static plan-order/lag/window pass plus the
/// exhaustive completion-interleaving DFS. See the module docs.
pub fn check(kn: &Knobs) -> Result<Report, Violation> {
    let s = script(kn);
    let observed = check_script(kn, &s)?;
    let states = check_interleavings(kn, &s)?;
    Ok(Report { actions: s.len(), states, observed })
}

/// The static pass: replay the script against counters that define the
/// ground truth (`spliced`/`submitted`/`waited`/`committed` front
/// indices) and reject any recorded lag, ordering, or window excursion
/// that contradicts them. Returns the witnessed quantities.
fn check_script(kn: &Knobs, s: &[Action]) -> Result<Prediction, Violation> {
    let fail = |msg: String| Violation { knobs: *kn, msg };
    if !kn.valid() {
        return Err(fail("invalid knob combination reached the checker".to_string()));
    }
    let pred = predicted(kn);
    let param_bound = kn.p.min(kn.streams.saturating_sub(1));
    let w = kn.window();
    let last = kn.n_train.saturating_sub(1);

    let mut spliced = 0usize; // splices land in plan order: highest so far
    let mut submitted = 0usize;
    let mut waited = 0usize; // optimizer commits land at the wait
    let mut committed = 0usize; // memory write-backs
    let mut splice_lag_max = 0usize;
    let mut param_lag_max = 0usize;
    let mut window_peak = 0usize;

    for (pos, a) in s.iter().enumerate() {
        match *a {
            Action::Splice { j, lag } => {
                if j != spliced + 1 {
                    return Err(fail(format!(
                        "action {pos}: splice {j} out of plan order (previous {spliced})"
                    )));
                }
                spliced = j;
                // batch j's exact view needs memory commits ..= j-1; only
                // `committed` have landed when it is spliced
                let true_lag = (j - 1) - committed.min(j - 1);
                if lag != true_lag {
                    return Err(fail(format!(
                        "splice {j}: recorded lag {lag} != true lag {true_lag}"
                    )));
                }
                if lag > kn.k {
                    return Err(fail(format!(
                        "splice {j}: lag {lag} exceeds bounded_staleness {}",
                        kn.k
                    )));
                }
                splice_lag_max = splice_lag_max.max(lag);
            }
            Action::Submit { j, param_lag } => {
                if j != submitted + 1 {
                    return Err(fail(format!(
                        "action {pos}: submit {j} out of plan order (previous {submitted})"
                    )));
                }
                if j > spliced {
                    return Err(fail(format!(
                        "submit {j}: batch not yet spliced (spliced through {spliced})"
                    )));
                }
                submitted = j;
                // step j's snapshot needs optimizer commits ..= j-1; only
                // `waited` have been applied when it is submitted
                let true_lag = (j - 1) - waited.min(j - 1);
                if param_lag != true_lag {
                    return Err(fail(format!(
                        "submit {j}: recorded param lag {param_lag} != true lag {true_lag}"
                    )));
                }
                if param_lag > param_bound {
                    return Err(fail(format!(
                        "submit {j}: param lag {param_lag} exceeds min(p, streams-1) = {param_bound}"
                    )));
                }
                param_lag_max = param_lag_max.max(param_lag);
                let in_window = submitted - waited;
                if in_window > w {
                    return Err(fail(format!(
                        "submit {j}: {in_window} steps in flight exceeds window W = {w}"
                    )));
                }
                if in_window > kn.streams {
                    return Err(fail(format!(
                        "submit {j}: {in_window} steps in flight exceeds {} lane(s)",
                        kn.streams
                    )));
                }
                window_peak = window_peak.max(in_window);
            }
            Action::Wait { j } => {
                if j > submitted {
                    return Err(fail(format!(
                        "wait {j}: step never submitted (submitted through {submitted}) — deadlock"
                    )));
                }
                if j != waited + 1 {
                    return Err(fail(format!(
                        "action {pos}: wait {j} out of plan order (previous {waited})"
                    )));
                }
                waited = j;
            }
            Action::Writeback { j } => {
                if j != committed + 1 {
                    return Err(fail(format!(
                        "action {pos}: write-back {j} out of plan order (previous {committed})"
                    )));
                }
                if j > waited {
                    return Err(fail(format!(
                        "write-back {j} before its commit wait (waited through {waited})"
                    )));
                }
                committed = j;
            }
        }
    }

    for (what, got) in [
        ("spliced", spliced),
        ("submitted", submitted),
        ("waited", waited),
        ("committed", committed),
    ] {
        if got != last {
            return Err(fail(format!(
                "epoch ends with {got}/{last} steps {what} — steps lost"
            )));
        }
    }
    let got = Prediction { splice_lag_max, param_lag_max, window_peak };
    if got != pred {
        return Err(fail(format!(
            "witness mismatch: observed {got:?} but closed form predicts {pred:?}"
        )));
    }
    Ok(got)
}

/// The dynamic pass: memoized DFS over every interleaving of lane
/// completions with the coordinator script. State is `(pc, in-flight
/// bitmask)`; from each state the coordinator may advance (unless it is
/// at a `Wait` whose job has not completed) and any in-flight job may
/// complete. Proves deadlock-freedom for all completion orders and
/// returns the number of distinct states visited.
fn check_interleavings(kn: &Knobs, s: &[Action]) -> Result<usize, Violation> {
    let fail = |msg: String| Violation { knobs: *kn, msg };
    let bit = |j: usize| 1u32 << j; // plan indices <= 12 on the grid
    let mut seen: BTreeSet<(usize, u32)> = BTreeSet::new();
    let mut stack: Vec<(usize, u32)> = vec![(0, 0)];
    while let Some((pc, pending)) = stack.pop() {
        if !seen.insert((pc, pending)) {
            continue;
        }
        if pc == s.len() {
            if pending != 0 {
                return Err(fail(format!(
                    "script ended with {} job(s) still in flight",
                    pending.count_ones()
                )));
            }
            continue; // terminal: epoch drained
        }
        let mut progressed = false;
        let advance_ok = match s[pc] {
            Action::Wait { j } => pending & bit(j) == 0,
            _ => true,
        };
        if advance_ok {
            let npending = match s[pc] {
                Action::Submit { j, .. } => pending | bit(j),
                _ => pending,
            };
            stack.push((pc + 1, npending));
            progressed = true;
        }
        // nondeterminism: any in-flight job may complete now
        let mut m = pending;
        while m != 0 {
            let b = m & m.wrapping_neg();
            stack.push((pc, pending & !b));
            m &= !b;
            progressed = true;
        }
        if !progressed {
            return Err(fail(format!(
                "deadlock: stuck at action {pc} ({:?}) with nothing in flight",
                s[pc]
            )));
        }
    }
    Ok(seen.len())
}

/// The exhaustive grid `pallas-verify` gates CI on.
pub const GRID_N_TRAIN: usize = 12;
pub const GRID_K: usize = 3;
pub const GRID_P: usize = 3;
pub const GRID_STREAMS: usize = 4;

/// Totals from one full-grid run.
#[derive(Clone, Copy, Debug, Default)]
pub struct GridSummary {
    /// Valid configurations exhaustively checked.
    pub checked: usize,
    /// Knob combinations config validation rejects (mirrored, skipped).
    pub skipped: usize,
    /// Total coordinator actions across all scripts.
    pub actions: usize,
    /// Total distinct interleaving states explored.
    pub states: usize,
}

/// Check every configuration with `n_train <= 12`, `k <= 3`, `p <= 3`,
/// `1 <= streams <= 4`, stopping at the first violation.
pub fn check_grid() -> Result<GridSummary, Violation> {
    let mut sum = GridSummary::default();
    for n_train in 0..=GRID_N_TRAIN {
        for k in 0..=GRID_K {
            for p in 0..=GRID_P {
                for streams in 1..=GRID_STREAMS {
                    let kn = Knobs { n_train, k, p, streams };
                    if !kn.valid() {
                        sum.skipped += 1;
                        continue;
                    }
                    let rep = check(&kn)?;
                    sum.checked += 1;
                    sum.actions += rep.actions;
                    sum.states += rep.states;
                }
            }
        }
    }
    Ok(sum)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_grid_is_clean() {
        // the tier-1 mirror of the pallas-verify CI gate
        let sum = check_grid().unwrap_or_else(|v| panic!("schedule violation: {v}"));
        assert!(sum.checked > 500, "grid unexpectedly small: {sum:?}");
        assert!(sum.skipped > 0, "the invalid-knob mirror never fired");
    }

    #[test]
    fn loop_dispatch_mirrors_trainer() {
        let kn = |n_train, k, p, streams| Knobs { n_train, k, p, streams };
        assert_eq!(kn(1, 2, 0, 1).loop_kind(), LoopKind::Trivial);
        assert_eq!(kn(6, 2, 0, 1).loop_kind(), LoopKind::Pipelined);
        assert_eq!(kn(6, 2, 0, 3).loop_kind(), LoopKind::ExactMultistream);
        assert_eq!(kn(6, 2, 2, 3).loop_kind(), LoopKind::RelaxedMultistream);
        // p with a single stream is a validated no-op: still pipelined
        assert_eq!(kn(6, 2, 2, 1).loop_kind(), LoopKind::Pipelined);
    }

    #[test]
    fn validity_mirrors_config_rules() {
        let kn = |n_train, k, p, streams| Knobs { n_train, k, p, streams };
        assert!(!kn(6, 0, 0, 0).valid(), "zero lanes");
        assert!(!kn(6, 0, 0, 2).valid(), "multi-stream at k = 0");
        assert!(!kn(6, 1, 3, 4).valid(), "realized param lag 3 > k = 1");
        assert!(kn(6, 3, 3, 4).valid());
        assert!(kn(6, 0, 3, 1).valid(), "p with one stream is a no-op");
        assert!(kn(6, 0, 0, 1).valid(), "the sequential default");
    }

    #[test]
    fn witnesses_match_hand_computed_schedules() {
        // pipelined, k = 2, 5 steps: window wants lag 2 and gets it
        let got = check(&Knobs { n_train: 6, k: 2, p: 0, streams: 1 }).unwrap();
        assert_eq!(got.observed.splice_lag_max, 2);
        assert_eq!(got.observed.param_lag_max, 0);
        assert_eq!(got.observed.window_peak, 1);
        // exact multistream keeps the parameter chain exact
        let got = check(&Knobs { n_train: 6, k: 2, p: 0, streams: 3 }).unwrap();
        assert_eq!(got.observed.param_lag_max, 0);
        assert_eq!(got.observed.window_peak, 1);
        // relaxed: W = min(2, 2) + 1 = 3 in flight, param lag 2
        let got = check(&Knobs { n_train: 6, k: 2, p: 2, streams: 3 }).unwrap();
        assert_eq!(got.observed.param_lag_max, 2);
        assert_eq!(got.observed.window_peak, 3);
        // streams cap p: W - 1 = min(3, 1) = 1
        let got = check(&Knobs { n_train: 6, k: 3, p: 3, streams: 2 }).unwrap();
        assert_eq!(got.observed.param_lag_max, 1);
        assert_eq!(got.observed.window_peak, 2);
        // n_train caps everything: one step, nothing can lag
        let got = check(&Knobs { n_train: 2, k: 3, p: 3, streams: 4 }).unwrap();
        assert_eq!(got.observed, Prediction { splice_lag_max: 0, param_lag_max: 0, window_peak: 1 });
        // trivial epoch: empty script
        let got = check(&Knobs { n_train: 1, k: 2, p: 1, streams: 2 }).unwrap();
        assert_eq!(got.actions, 0);
        assert_eq!(got.observed, Prediction { splice_lag_max: 0, param_lag_max: 0, window_peak: 0 });
    }

    #[test]
    fn static_pass_rejects_corrupted_schedules() {
        let kn = Knobs { n_train: 6, k: 2, p: 2, streams: 3 };
        let good = script(&kn);
        assert!(check_script(&kn, &good).is_ok());

        // swap two write-backs: commits leave plan order
        let mut bad = good.clone();
        let wbs: Vec<usize> = bad
            .iter()
            .enumerate()
            .filter(|(_, a)| matches!(a, Action::Writeback { .. }))
            .map(|(i, _)| i)
            .collect();
        bad.swap(wbs[1], wbs[2]);
        let err = check_script(&kn, &bad).unwrap_err();
        assert!(err.msg.contains("out of plan order"), "{err}");

        // claim a submission saw fresher params than it could have
        let mut bad = good.clone();
        let pos = bad
            .iter()
            .position(|a| matches!(a, Action::Submit { param_lag: 2, .. }))
            .unwrap();
        if let Action::Submit { j, .. } = bad[pos] {
            bad[pos] = Action::Submit { j, param_lag: 0 };
        }
        let err = check_script(&kn, &bad).unwrap_err();
        assert!(err.msg.contains("recorded param lag"), "{err}");

        // submit a step whose batch was never spliced
        let mut bad = good.clone();
        let pos = bad.iter().position(|a| matches!(a, Action::Splice { j: 3, .. })).unwrap();
        bad.remove(pos);
        let err = check_script(&kn, &bad).unwrap_err();
        assert!(
            err.msg.contains("not yet spliced") || err.msg.contains("out of plan order"),
            "{err}"
        );

        // drop the last write-back: a step never commits
        let mut bad = good.clone();
        let pos = bad.iter().rposition(|a| matches!(a, Action::Writeback { .. })).unwrap();
        bad.remove(pos);
        let err = check_script(&kn, &bad).unwrap_err();
        assert!(err.msg.contains("steps lost"), "{err}");

        // wait for a step that was never submitted: deadlock shape
        let kn1 = Knobs { n_train: 2, k: 1, p: 0, streams: 2 };
        let bad = vec![Action::Splice { j: 1, lag: 0 }, Action::Wait { j: 1 }];
        let err = check_script(&kn1, &bad).unwrap_err();
        assert!(err.msg.contains("deadlock"), "{err}");
    }

    #[test]
    fn static_pass_rejects_window_overflow() {
        // more submissions in flight than W (and than lanes) must be caught
        let kn = Knobs { n_train: 4, k: 3, p: 1, streams: 2 }; // W = 2
        let bad = vec![
            Action::Splice { j: 1, lag: 0 },
            Action::Splice { j: 2, lag: 1 },
            Action::Splice { j: 3, lag: 2 },
            Action::Submit { j: 1, param_lag: 0 },
            Action::Submit { j: 2, param_lag: 1 },
            Action::Submit { j: 3, param_lag: 2 },
        ];
        let err = check_script(&kn, &bad).unwrap_err();
        assert!(
            err.msg.contains("exceeds window") || err.msg.contains("exceeds min"),
            "{err}"
        );
    }

    #[test]
    fn dfs_explores_more_states_as_the_window_widens() {
        // exact loop: one job in flight, the interleaving space is a line
        let kn1 = Knobs { n_train: 8, k: 1, p: 0, streams: 2 };
        let r1 = check(&kn1).unwrap();
        // relaxed W = 3: genuinely concurrent jobs multiply the states
        let kn3 = Knobs { n_train: 8, k: 3, p: 3, streams: 4 };
        let r3 = check(&kn3).unwrap();
        assert!(
            r3.states > r1.states,
            "wider window should widen the state space: {} vs {}",
            r3.states,
            r1.states
        );
    }

    #[test]
    fn scripts_are_pure_functions_of_the_knobs() {
        let kn = Knobs { n_train: 9, k: 2, p: 1, streams: 3 };
        assert_eq!(script(&kn), script(&kn));
        assert_eq!(predicted(&kn), predicted(&kn));
    }
}
