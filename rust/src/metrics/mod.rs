//! Evaluation metrics: average precision (the paper's headline metric),
//! ROC-AUC (Table 2), and run timing/throughput accounting.

pub mod ranking;
pub mod timing;

pub use ranking::{average_precision, roc_auc};
pub use timing::{EpochTimer, StageHists, StageQuantiles};
