//! Wall-clock accounting for epochs and phases (assembly vs PJRT dispatch
//! vs write-back) — the numbers behind Table 1's speedup column and the
//! §Perf iteration log.
//!
//! Pipeline-era buckets: coordinator-side phases (`assemble` = splice +
//! pack, `execute`, `writeback`) plus two overlap counters — `prep_busy`
//! (time the background PREP worker spent filling batches) and
//! `prep_stall` (time the coordinator spent blocked waiting for one).
//! Their difference is the assembly work actually hidden behind device
//! execution; in the sequential loop PREP runs inline inside `assemble`
//! and both counters stay zero.

use std::time::{Duration, Instant};

#[derive(Clone, Debug, Default)]
pub struct EpochTimer {
    pub assemble: Duration,
    pub execute: Duration,
    pub writeback: Duration,
    /// Background PREP worker busy time (off-thread; overlaps the rest).
    pub prep_busy: Duration,
    /// Coordinator blocked on the PREP channel (pipeline bubble).
    pub prep_stall: Duration,
    pub other: Duration,
    epoch_start: Option<Instant>,
    pub total: Duration,
    pub steps: usize,
}

impl EpochTimer {
    pub fn start_epoch(&mut self) {
        *self = EpochTimer::default();
        self.epoch_start = Some(Instant::now());
    }

    pub fn finish_epoch(&mut self) {
        if let Some(t0) = self.epoch_start.take() {
            self.total = t0.elapsed();
            // prep_busy is NOT part of the coordinator wall clock (it ran on
            // the worker thread); prep_stall is.
            let tracked = self.assemble + self.execute + self.writeback + self.prep_stall;
            self.other = self.total.saturating_sub(tracked);
        }
    }

    pub fn time<T>(bucket: &mut Duration, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        *bucket += t0.elapsed();
        out
    }

    /// PREP work hidden behind device execution: worker busy time minus the
    /// part the coordinator ended up waiting for anyway. Zero in the
    /// sequential loop (both counters stay zero there).
    pub fn assemble_hidden(&self) -> Duration {
        self.prep_busy.saturating_sub(self.prep_stall)
    }

    /// Fraction of the epoch wall clock the device spent idle (no step
    /// executing). The pipeline exists to push this toward the true
    /// host-bound floor.
    pub fn device_idle_fraction(&self) -> f64 {
        if self.total.is_zero() {
            return 0.0;
        }
        (1.0 - self.execute.as_secs_f64() / self.total.as_secs_f64()).clamp(0.0, 1.0)
    }

    pub fn events_per_sec(&self, events: usize) -> f64 {
        if self.total.is_zero() {
            return 0.0;
        }
        events as f64 / self.total.as_secs_f64()
    }

    pub fn summary(&self) -> String {
        format!(
            "total {:.3}s (assemble {:.3}s | execute {:.3}s | writeback {:.3}s | stall {:.3}s | other {:.3}s; prep hidden {:.3}s, device idle {:.1}%) over {} steps",
            self.total.as_secs_f64(),
            self.assemble.as_secs_f64(),
            self.execute.as_secs_f64(),
            self.writeback.as_secs_f64(),
            self.prep_stall.as_secs_f64(),
            self.other.as_secs_f64(),
            self.assemble_hidden().as_secs_f64(),
            self.device_idle_fraction() * 100.0,
            self.steps,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_accumulate() {
        let mut t = EpochTimer::default();
        t.start_epoch();
        EpochTimer::time(&mut t.execute, || std::thread::sleep(Duration::from_millis(5)));
        t.steps = 1;
        t.finish_epoch();
        assert!(t.execute >= Duration::from_millis(5));
        assert!(t.total >= t.execute);
        assert!(t.events_per_sec(100) > 0.0);
    }

    #[test]
    fn overlap_accounting() {
        let mut t = EpochTimer::default();
        t.start_epoch();
        // real wall time must dominate the synthetic phase durations below,
        // otherwise `other` saturates to zero and proves nothing
        std::thread::sleep(Duration::from_millis(20));
        t.prep_busy = Duration::from_millis(12);
        t.prep_stall = Duration::from_millis(2);
        t.execute = Duration::from_millis(5);
        t.finish_epoch();
        assert_eq!(t.assemble_hidden(), Duration::from_millis(10));
        assert!(t.total >= Duration::from_millis(20));
        // stall counts toward coordinator wall time, busy does not: the
        // untracked remainder is total minus (execute + stall) exactly
        assert_eq!(t.other, t.total - Duration::from_millis(7));
        let idle = t.device_idle_fraction();
        assert!(idle > 0.0 && idle < 1.0, "idle {idle}");
    }

    #[test]
    fn hidden_clamps_at_zero_when_stalled_throughout() {
        let t = EpochTimer {
            prep_busy: Duration::from_millis(5),
            prep_stall: Duration::from_millis(9),
            ..EpochTimer::default()
        };
        assert_eq!(t.assemble_hidden(), Duration::ZERO);
    }
}
