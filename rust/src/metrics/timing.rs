//! Wall-clock accounting for epochs and phases (assembly vs PJRT dispatch
//! vs write-back) — the numbers behind Table 1's speedup column and the
//! §Perf iteration log.

use std::time::{Duration, Instant};

#[derive(Clone, Debug, Default)]
pub struct EpochTimer {
    pub assemble: Duration,
    pub execute: Duration,
    pub writeback: Duration,
    pub other: Duration,
    epoch_start: Option<Instant>,
    pub total: Duration,
    pub steps: usize,
}

impl EpochTimer {
    pub fn start_epoch(&mut self) {
        *self = EpochTimer::default();
        self.epoch_start = Some(Instant::now());
    }

    pub fn finish_epoch(&mut self) {
        if let Some(t0) = self.epoch_start.take() {
            self.total = t0.elapsed();
            let tracked = self.assemble + self.execute + self.writeback;
            self.other = self.total.saturating_sub(tracked);
        }
    }

    pub fn time<T>(bucket: &mut Duration, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        *bucket += t0.elapsed();
        out
    }

    pub fn events_per_sec(&self, events: usize) -> f64 {
        if self.total.is_zero() {
            return 0.0;
        }
        events as f64 / self.total.as_secs_f64()
    }

    pub fn summary(&self) -> String {
        format!(
            "total {:.3}s (assemble {:.3}s | execute {:.3}s | writeback {:.3}s | other {:.3}s) over {} steps",
            self.total.as_secs_f64(),
            self.assemble.as_secs_f64(),
            self.execute.as_secs_f64(),
            self.writeback.as_secs_f64(),
            self.other.as_secs_f64(),
            self.steps,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_accumulate() {
        let mut t = EpochTimer::default();
        t.start_epoch();
        EpochTimer::time(&mut t.execute, || std::thread::sleep(Duration::from_millis(5)));
        t.steps = 1;
        t.finish_epoch();
        assert!(t.execute >= Duration::from_millis(5));
        assert!(t.total >= t.execute);
        assert!(t.events_per_sec(100) > 0.0);
    }
}
