//! Wall-clock accounting for epochs and phases (assembly vs EXEC dispatch
//! vs write-back) — the numbers behind Table 1's speedup column and the
//! §Perf iteration log.
//!
//! Pipeline-era buckets: coordinator-side phases (`assemble` = splice +
//! pack, `writeback`) plus two overlap counters — `prep_busy` (time the
//! background PREP worker spent filling batches) and `prep_stall` (time
//! the coordinator spent blocked waiting for one). Their difference is the
//! assembly work actually hidden behind device execution; in the
//! sequential loop PREP runs inline inside `assemble` and both counters
//! stay zero.
//!
//! ## EXEC accounting under stream lanes
//!
//! With multi-stream EXEC (`exec_streams > 1`) step executions run on lane
//! threads and overlap coordinator work, so a single `execute` bucket can
//! no longer double as both "device busy time" and "coordinator time spent
//! on EXEC" — summed busy time may exceed the epoch wall clock, which used
//! to clamp `device_idle_fraction` to 0 and corrupt `other = total -
//! tracked`. Execution is therefore accounted three ways:
//!
//! * `execute` / `stream_busy[s]` — step-run busy time, summed / per lane
//!   (recorded via [`EpochTimer::record_exec`]);
//! * `exec_union` — the busy-union: overlapping busy intervals merged
//!   before summing, so it never exceeds `total`. This is what
//!   [`EpochTimer::device_idle_fraction`] is measured against;
//! * `exec_wait` — coordinator wall time attributable to EXEC: the inline
//!   run itself at `exec_streams = 1` (where it equals `execute`), or the
//!   time blocked waiting on the commit queue's front under stream lanes.
//!   This is the bucket that participates in `other = total - tracked`.
//!
//! ## Per-step latency distributions
//!
//! Aggregate buckets answer "where did the epoch go"; they cannot show tail
//! behaviour. Each accrual method therefore also records the sample into a
//! per-stage [`LogHistogram`] ([`StageHists`]) — fixed-allocation,
//! log-bucketed, ~3% relative error — and [`EpochTimer::stage_quantiles`]
//! surfaces p50/p95/p99 per stage for `EpochReport` / the `--metrics-out`
//! JSONL stream. Histogram samples use the same clock reads the buckets
//! already take, so the extra per-step cost is one bucket index + add.
//! Timeline-level visibility (who overlapped whom, on which thread) is the
//! `trace` module's job; this module stays aggregate-only.

// Sanctioned clock module: the epoch/phase accounting here IS the clock
// consumer, and the tests drive timers with raw Instants.
#![allow(clippy::disallowed_methods)]

use std::time::{Duration, Instant};

use crate::trace::LogHistogram;
use crate::util::json::Json;

/// Per-stage per-step latency histograms for one epoch (ns samples), plus
/// the per-step splice-lag distribution (commit counts, not time).
#[derive(Clone, Debug, Default)]
pub struct StageHists {
    /// Background PREP fill time per batch.
    pub prep: LogHistogram,
    /// Coordinator assemble/splice time per step.
    pub assemble: LogHistogram,
    /// Step-run busy time per execution (all lanes).
    pub exec: LogHistogram,
    /// Writeback time per committed step.
    pub writeback: LogHistogram,
    /// Coordinator blocked-on-commit-queue time per wait.
    pub exec_wait: LogHistogram,
    /// Coordinator blocked-on-PREP-channel time per stall.
    pub prep_stall: LogHistogram,
    /// Memory-version lag (commits) each step's splice observed.
    pub splice_lag: LogHistogram,
    /// Parameter-version lag (commits) each step executed against: how many
    /// plan-order Adam commits were still outstanding when the step's
    /// parameter snapshot was taken. Always 0 in the exact chain
    /// (`param_staleness = 0`); bounded by `min(p, exec_streams - 1)` in
    /// the relaxed chain.
    pub param_lag: LogHistogram,
    /// Per-call GEMM kernel latency (all host-step matmuls; drained from
    /// the global recorder in `runtime::gemm` once per epoch via
    /// [`EpochTimer::absorb_gemm`]). Empty unless metrics are enabled —
    /// the per-call histogram is only recorded under `--metrics-out`.
    pub gemm: LogHistogram,
}

/// p50/p95/p99 for one stage, as surfaced in `EpochReport`.
#[derive(Clone, Debug, PartialEq)]
pub struct StageQuantiles {
    pub stage: &'static str,
    /// "s" for latency stages, "commits" for splice lag.
    pub unit: &'static str,
    pub count: u64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl StageQuantiles {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("stage", Json::str(self.stage)),
            ("unit", Json::str(self.unit)),
            ("count", Json::num(self.count as f64)),
            ("p50", Json::num(self.p50)),
            ("p95", Json::num(self.p95)),
            ("p99", Json::num(self.p99)),
        ])
    }
}

#[derive(Clone, Debug, Default)]
pub struct EpochTimer {
    pub assemble: Duration,
    /// Step-run busy time summed over all EXEC streams (equals the old
    /// single-stream meaning at `exec_streams = 1`). May exceed `total`
    /// when lanes overlap — use `exec_union` against wall clock.
    pub execute: Duration,
    pub writeback: Duration,
    /// Background PREP worker busy time (off-thread; overlaps the rest).
    pub prep_busy: Duration,
    /// Coordinator blocked on the PREP channel (pipeline bubble).
    pub prep_stall: Duration,
    /// Coordinator wall time attributable to EXEC: inline run time at one
    /// stream, blocked-wait time on the commit queue under stream lanes.
    pub exec_wait: Duration,
    /// Per-stream step-run busy time (index = stream id; sums to `execute`).
    pub stream_busy: Vec<Duration>,
    /// Union of EXEC busy intervals across streams (never exceeds `total`);
    /// computed from the recorded spans at `finish_epoch`.
    pub exec_union: Duration,
    /// EXEC busy intervals as offsets from epoch start, for the union.
    exec_spans: Vec<(Duration, Duration)>,
    pub other: Duration,
    /// GEMM kernel busy time accrued inside step executions this epoch
    /// (a subset of `execute`; always-on nanosecond counters in
    /// `runtime::gemm`, drained once per epoch via [`absorb_gemm`]).
    ///
    /// [`absorb_gemm`]: EpochTimer::absorb_gemm
    pub gemm_busy: Duration,
    epoch_start: Option<Instant>,
    pub total: Duration,
    pub steps: usize,
    /// Largest parameter-version lag any step executed against this epoch
    /// (commits; the witness surfaced as `EpochReport::param_lag_max`).
    pub param_lag_max: usize,
    /// Per-step latency distributions per stage (see module docs).
    pub hist: StageHists,
}

impl EpochTimer {
    pub fn start_epoch(&mut self) {
        *self = EpochTimer::default();
        self.epoch_start = Some(Instant::now());
    }

    pub fn finish_epoch(&mut self) {
        if let Some(t0) = self.epoch_start.take() {
            self.total = t0.elapsed();
            self.exec_union = merge_spans(&mut self.exec_spans);
            // prep_busy and lane busy time are NOT part of the coordinator
            // wall clock (they ran on other threads); prep_stall and
            // exec_wait are.
            let tracked = self.assemble + self.writeback + self.prep_stall + self.exec_wait;
            self.other = self.total.saturating_sub(tracked);
        }
    }

    /// Record one step execution on stream `stream` spanning
    /// `[started, finished]` (lane-side wall clock; `Instant`s are
    /// comparable across threads). Executions reported after
    /// `finish_epoch` (e.g. a straggler lane) are ignored entirely, so the
    /// summed buckets can never drift from the already-computed union.
    pub fn record_exec(&mut self, stream: usize, started: Instant, finished: Instant) {
        let t0 = match self.epoch_start {
            Some(t0) => t0,
            None => return,
        };
        let busy = finished.saturating_duration_since(started);
        self.execute += busy;
        if self.stream_busy.len() <= stream {
            self.stream_busy.resize(stream + 1, Duration::ZERO);
        }
        self.stream_busy[stream] += busy;
        self.hist.exec.record_duration(busy);
        let s = started.saturating_duration_since(t0);
        self.exec_spans.push((s, s + busy));
    }

    /// Record an inline (coordinator-thread) step execution: busy time and
    /// coordinator EXEC time coincide, so both buckets accrue. Ignored
    /// after `finish_epoch`, like `record_exec`.
    pub fn record_exec_inline(&mut self, started: Instant, finished: Instant) {
        if self.epoch_start.is_none() {
            return;
        }
        self.exec_wait += finished.saturating_duration_since(started);
        self.record_exec(0, started, finished);
    }

    // ------------------------------------------------- per-step accrual
    // Each method adds to the aggregate bucket AND records the sample into
    // the stage histogram, so quantiles come for free at the call sites.

    pub fn add_assemble(&mut self, d: Duration) {
        self.assemble += d;
        self.hist.assemble.record_duration(d);
    }

    pub fn add_writeback(&mut self, d: Duration) {
        self.writeback += d;
        self.hist.writeback.record_duration(d);
    }

    pub fn add_exec_wait(&mut self, d: Duration) {
        self.exec_wait += d;
        self.hist.exec_wait.record_duration(d);
    }

    pub fn add_prep_stall(&mut self, d: Duration) {
        self.prep_stall += d;
        self.hist.prep_stall.record_duration(d);
    }

    pub fn add_prep_busy(&mut self, d: Duration) {
        self.prep_busy += d;
        self.hist.prep.record_duration(d);
    }

    /// Absorb the per-epoch GEMM snapshot drained from the global
    /// recorders in `runtime::gemm`: `busy` is the epoch's delta of the
    /// always-on nanosecond counter; `hist` is the per-call latency
    /// histogram taken via `gemm::take_call_hist` (empty unless metrics
    /// were enabled for the epoch).
    pub fn absorb_gemm(&mut self, busy: Duration, hist: &LogHistogram) {
        self.gemm_busy += busy;
        self.hist.gemm.merge(hist);
    }

    /// Record the memory-version lag (in commits) one step's splice saw.
    pub fn record_splice_lag(&mut self, lag: usize) {
        self.hist.splice_lag.record(lag as u64);
    }

    /// Record the parameter-version lag (in commits) one step executed
    /// against, updating both the histogram and the epoch max witness.
    pub fn record_param_lag(&mut self, lag: usize) {
        self.hist.param_lag.record(lag as u64);
        self.param_lag_max = self.param_lag_max.max(lag);
    }

    /// Per-stage p50/p95/p99 from the per-step histograms. Latency stages
    /// report seconds; `splice_lag` reports commits.
    pub fn stage_quantiles(&self) -> Vec<StageQuantiles> {
        const NS: f64 = 1e9;
        let time_q = |stage: &'static str, h: &LogHistogram| StageQuantiles {
            stage,
            unit: "s",
            count: h.count(),
            p50: h.quantile(0.50) / NS,
            p95: h.quantile(0.95) / NS,
            p99: h.quantile(0.99) / NS,
        };
        let lag = &self.hist.splice_lag;
        let plag = &self.hist.param_lag;
        vec![
            time_q("prep", &self.hist.prep),
            time_q("assemble", &self.hist.assemble),
            time_q("exec", &self.hist.exec),
            time_q("gemm", &self.hist.gemm),
            time_q("writeback", &self.hist.writeback),
            time_q("exec_wait", &self.hist.exec_wait),
            time_q("prep_stall", &self.hist.prep_stall),
            StageQuantiles {
                stage: "splice_lag",
                unit: "commits",
                count: lag.count(),
                p50: lag.quantile(0.50),
                p95: lag.quantile(0.95),
                p99: lag.quantile(0.99),
            },
            StageQuantiles {
                stage: "param_lag",
                unit: "commits",
                count: plag.count(),
                p50: plag.quantile(0.50),
                p95: plag.quantile(0.95),
                p99: plag.quantile(0.99),
            },
        ]
    }

    pub fn time<T>(bucket: &mut Duration, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        *bucket += t0.elapsed();
        out
    }

    /// PREP work hidden behind device execution: worker busy time minus the
    /// part the coordinator ended up waiting for anyway. Zero in the
    /// sequential loop (both counters stay zero there).
    pub fn assemble_hidden(&self) -> Duration {
        self.prep_busy.saturating_sub(self.prep_stall)
    }

    /// Fraction of the epoch wall clock no step was executing on ANY
    /// stream (the busy-union against total). The pipeline exists to push
    /// this toward the true host-bound floor.
    pub fn device_idle_fraction(&self) -> f64 {
        if self.total.is_zero() {
            return 0.0;
        }
        (1.0 - self.exec_union.as_secs_f64() / self.total.as_secs_f64()).clamp(0.0, 1.0)
    }

    pub fn events_per_sec(&self, events: usize) -> f64 {
        if self.total.is_zero() {
            return 0.0;
        }
        events as f64 / self.total.as_secs_f64()
    }

    pub fn summary(&self) -> String {
        format!(
            "total {:.3}s (assemble {:.3}s | execute {:.3}s over {} stream(s), union {:.3}s, wait {:.3}s | writeback {:.3}s | stall {:.3}s | other {:.3}s; prep hidden {:.3}s, device idle {:.1}%) over {} steps",
            self.total.as_secs_f64(),
            self.assemble.as_secs_f64(),
            self.execute.as_secs_f64(),
            self.stream_busy.len().max(1),
            self.exec_union.as_secs_f64(),
            self.exec_wait.as_secs_f64(),
            self.writeback.as_secs_f64(),
            self.prep_stall.as_secs_f64(),
            self.other.as_secs_f64(),
            self.assemble_hidden().as_secs_f64(),
            self.device_idle_fraction() * 100.0,
            self.steps,
        )
    }
}

/// Union length of a set of `[start, end)` spans: sort by start, merge
/// overlapping/adjacent spans, sum the merged lengths. Input may be
/// unsorted and may contain duplicate or even inverted (`end < start`)
/// intervals — inverted intervals are treated as empty rather than
/// panicking on `Duration` underflow.
fn merge_spans(spans: &mut [(Duration, Duration)]) -> Duration {
    spans.sort_by_key(|s| s.0);
    let mut total = Duration::ZERO;
    let mut current: Option<(Duration, Duration)> = None;
    for &(start, end) in spans.iter() {
        let end = end.max(start);
        match current {
            Some((_, ref mut cur_end)) if start <= *cur_end => {
                if end > *cur_end {
                    *cur_end = end;
                }
            }
            _ => {
                if let Some((s, e)) = current.take() {
                    total += e.saturating_sub(s);
                }
                current = Some((start, end));
            }
        }
    }
    if let Some((s, e)) = current {
        total += e.saturating_sub(s);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(n: u64) -> Duration {
        Duration::from_millis(n)
    }

    #[test]
    fn buckets_accumulate() {
        let mut t = EpochTimer::default();
        t.start_epoch();
        let t0 = Instant::now();
        std::thread::sleep(ms(5));
        t.record_exec_inline(t0, Instant::now());
        t.steps = 1;
        t.finish_epoch();
        assert!(t.execute >= ms(5));
        assert_eq!(t.execute, t.exec_wait, "inline EXEC: busy == coordinator time");
        assert_eq!(t.execute, t.exec_union, "one stream never overlaps itself");
        assert!(t.total >= t.execute);
        assert!(t.events_per_sec(100) > 0.0);
    }

    #[test]
    fn overlap_accounting() {
        let mut t = EpochTimer::default();
        t.start_epoch();
        let base = Instant::now();
        // real wall time must dominate the synthetic phase durations below,
        // otherwise `other` saturates to zero and proves nothing
        std::thread::sleep(ms(20));
        t.prep_busy = ms(12);
        t.prep_stall = ms(2);
        t.record_exec_inline(base, base + ms(5));
        t.finish_epoch();
        assert_eq!(t.assemble_hidden(), ms(10));
        assert!(t.total >= ms(20));
        // stall and exec_wait count toward coordinator wall time, busy does
        // not: the untracked remainder is total minus (exec_wait + stall)
        assert_eq!(t.other, t.total - ms(7));
        let idle = t.device_idle_fraction();
        assert!(idle > 0.0 && idle < 1.0, "idle {idle}");
    }

    #[test]
    fn hidden_clamps_at_zero_when_stalled_throughout() {
        let t = EpochTimer {
            prep_busy: ms(5),
            prep_stall: ms(9),
            ..EpochTimer::default()
        };
        assert_eq!(t.assemble_hidden(), Duration::ZERO);
    }

    #[test]
    fn two_stream_overlap_unions_not_sums() {
        // two lanes whose busy windows overlap by 5 ms: summed execute (20)
        // exceeds the union (15). Idle fraction must be measured against
        // the union, and `other` must not be corrupted by lane busy time.
        let mut t = EpochTimer::default();
        t.start_epoch();
        let base = Instant::now();
        std::thread::sleep(ms(25));
        t.record_exec(0, base, base + ms(10));
        t.record_exec(1, base + ms(5), base + ms(15));
        t.exec_wait = ms(2); // coordinator only briefly blocked
        t.finish_epoch();
        assert_eq!(t.execute, ms(20), "execute sums lane busy time");
        assert_eq!(t.stream_busy, vec![ms(10), ms(10)]);
        assert_eq!(t.exec_union, ms(15), "overlap must merge, not double-count");
        let idle = t.device_idle_fraction();
        assert!(
            idle > 0.0 && idle < 1.0,
            "union-based idle must be meaningful under overlap: {idle}"
        );
        // tracked coordinator time is exec_wait, not lane busy time
        assert_eq!(t.other, t.total - ms(2));
    }

    #[test]
    fn disjoint_spans_union_to_their_sum() {
        let mut t = EpochTimer::default();
        t.start_epoch();
        let base = Instant::now();
        t.record_exec(0, base, base + ms(4));
        t.record_exec(1, base + ms(10), base + ms(14));
        t.finish_epoch();
        assert_eq!(t.exec_union, ms(8));
        assert_eq!(t.execute, ms(8));
    }

    #[test]
    fn idle_fraction_and_throughput_on_zero_total_are_zero_not_nan() {
        // a timer that never ran an epoch must not divide by zero
        let t = EpochTimer::default();
        assert_eq!(t.device_idle_fraction(), 0.0);
        assert_eq!(t.events_per_sec(100), 0.0);
    }

    #[test]
    fn records_after_finish_epoch_are_ignored() {
        // a straggler lane reporting after finish_epoch used to accrue
        // execute/stream_busy without a matching union span; now the whole
        // record is dropped so the buckets stay consistent
        let mut t = EpochTimer::default();
        t.start_epoch();
        t.finish_epoch();
        let base = Instant::now();
        t.record_exec(1, base, base + ms(5));
        t.record_exec_inline(base, base + ms(5));
        assert_eq!(t.execute, Duration::ZERO);
        assert_eq!(t.exec_wait, Duration::ZERO);
        assert!(t.stream_busy.is_empty());
        assert_eq!(t.hist.exec.count(), 0);
    }

    #[test]
    fn merge_spans_handles_unsorted_and_identical_intervals() {
        let mut spans = vec![
            (ms(10), ms(14)),
            (ms(0), ms(4)),
            (ms(10), ms(14)), // exact duplicate must not double-count
            (ms(2), ms(6)),
        ];
        assert_eq!(merge_spans(&mut spans), ms(10)); // [0,6) ∪ [10,14)
    }

    #[test]
    fn merge_spans_inverted_interval_is_empty_not_panic() {
        let mut spans = vec![(ms(5), ms(3)), (ms(0), ms(2))];
        assert_eq!(merge_spans(&mut spans), ms(2));
    }

    #[test]
    fn stage_quantiles_surface_recorded_samples() {
        let mut t = EpochTimer::default();
        t.start_epoch();
        for i in 1..=20u64 {
            t.add_assemble(Duration::from_micros(i * 100));
        }
        t.record_splice_lag(3);
        t.record_param_lag(1);
        t.record_param_lag(2);
        t.finish_epoch();
        let qs = t.stage_quantiles();
        let asm = qs.iter().find(|q| q.stage == "assemble").unwrap();
        assert_eq!(asm.count, 20);
        assert_eq!(asm.unit, "s");
        assert!(asm.p50 > 0.0 && asm.p99 >= asm.p50);
        // aggregate bucket accrues alongside the histogram
        assert_eq!(t.assemble, Duration::from_micros((1..=20).sum::<u64>() * 100));
        let lag = qs.iter().find(|q| q.stage == "splice_lag").unwrap();
        assert_eq!(lag.unit, "commits");
        assert_eq!(lag.count, 1);
        assert!((lag.p50 - 3.0).abs() < 1e-9);
        let plag = qs.iter().find(|q| q.stage == "param_lag").unwrap();
        assert_eq!(plag.unit, "commits");
        assert_eq!(plag.count, 2);
        assert_eq!(t.param_lag_max, 2, "max witness tracks the largest recorded lag");
    }

    #[test]
    fn absorb_gemm_accrues_busy_and_merges_hist() {
        let mut t = EpochTimer::default();
        t.start_epoch();
        let mut h = LogHistogram::new();
        h.record(1_000);
        h.record(50_000);
        t.absorb_gemm(ms(3), &h);
        t.absorb_gemm(ms(2), &LogHistogram::new());
        t.finish_epoch();
        assert_eq!(t.gemm_busy, ms(5));
        assert_eq!(t.hist.gemm.count(), 2);
        let qs = t.stage_quantiles();
        let g = qs.iter().find(|q| q.stage == "gemm").unwrap();
        assert_eq!(g.unit, "s");
        assert_eq!(g.count, 2);
        assert!(g.p50 > 0.0);
    }

    #[test]
    fn param_lag_max_defaults_to_zero_for_exact_chains() {
        // an epoch that never records a param lag (exact chain, inline or
        // pipelined loops) must report a 0 witness, not garbage
        let mut t = EpochTimer::default();
        t.start_epoch();
        t.finish_epoch();
        assert_eq!(t.param_lag_max, 0);
        let qs = t.stage_quantiles();
        let plag = qs.iter().find(|q| q.stage == "param_lag").unwrap();
        assert_eq!(plag.count, 0);
    }
}
