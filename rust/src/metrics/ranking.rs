//! Ranking metrics over (score, label) pairs.
//!
//! `average_precision` matches sklearn's `average_precision_score`
//! (step-wise precision-recall integral, ties broken by stable descending
//! sort); `roc_auc` is the Mann-Whitney U statistic with tie correction.
//!
//! ## NaN scores
//!
//! A diverged model can emit NaN logits; eval must *report* that run, not
//! crash it, so both metrics order scores with [`f32::total_cmp`] instead
//! of `partial_cmp().unwrap()`. Under the IEEE total order, +NaN ranks
//! above +inf and -NaN below -inf — i.e. a (positive-bit-pattern) NaN
//! score is treated as the most confident score in the ranking, and the
//! metric stays finite and deterministic. Callers who want to reject NaN
//! runs outright should check `scores.iter().all(|s| s.is_finite())`.

/// Average precision: sum over positive hits of precision-at-that-rank
/// weighted by recall increments. Scores descending; `labels[i]` in {0,1}.
/// NaN-safe: scores sort under the IEEE total order (see module docs).
pub fn average_precision(scores: &[f32], labels: &[bool]) -> f64 {
    debug_assert_eq!(scores.len(), labels.len());
    let n_pos = labels.iter().filter(|&&l| l).count();
    if n_pos == 0 || n_pos == labels.len() {
        return if n_pos == 0 { 0.0 } else { 1.0 };
    }
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]).then(a.cmp(&b)));
    let mut tp = 0usize;
    let mut ap = 0.0f64;
    for (rank, &i) in order.iter().enumerate() {
        if labels[i] {
            tp += 1;
            ap += tp as f64 / (rank + 1) as f64;
        }
    }
    ap / n_pos as f64
}

/// ROC-AUC via rank statistics (tie-corrected midranks). NaN-safe: scores
/// sort under the IEEE total order (see module docs); NaNs never compare
/// `==`, so each forms its own midrank group.
pub fn roc_auc(scores: &[f32], labels: &[bool]) -> f64 {
    debug_assert_eq!(scores.len(), labels.len());
    let n_pos = labels.iter().filter(|&&l| l).count();
    let n_neg = labels.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]));
    // midranks for ties
    let mut rank_sum_pos = 0.0f64;
    let mut i = 0;
    while i < order.len() {
        let mut j = i;
        while j + 1 < order.len() && scores[order[j + 1]] == scores[order[i]] {
            j += 1;
        }
        let midrank = (i + j) as f64 / 2.0 + 1.0;
        for &k in &order[i..=j] {
            if labels[k] {
                rank_sum_pos += midrank;
            }
        }
        i = j + 1;
    }
    let u = rank_sum_pos - (n_pos as f64 * (n_pos as f64 + 1.0)) / 2.0;
    u / (n_pos as f64 * n_neg as f64)
}

/// AP for the link-prediction protocol: positive logits vs negative logits.
pub fn link_ap(pos_logits: &[f32], neg_logits: &[f32]) -> f64 {
    let scores: Vec<f32> = pos_logits.iter().chain(neg_logits).copied().collect();
    let labels: Vec<bool> = std::iter::repeat(true)
        .take(pos_logits.len())
        .chain(std::iter::repeat(false).take(neg_logits.len()))
        .collect();
    average_precision(&scores, &labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg32;

    #[test]
    fn perfect_ranking() {
        let scores = [0.9, 0.8, 0.2, 0.1];
        let labels = [true, true, false, false];
        assert_eq!(average_precision(&scores, &labels), 1.0);
        assert_eq!(roc_auc(&scores, &labels), 1.0);
    }

    #[test]
    fn inverted_ranking() {
        let scores = [0.1, 0.2, 0.8, 0.9];
        let labels = [true, true, false, false];
        assert!(average_precision(&scores, &labels) < 0.6);
        assert_eq!(roc_auc(&scores, &labels), 0.0);
    }

    #[test]
    fn known_ap_value() {
        // ranks of positives: 1 and 3 -> AP = (1/1 + 2/3) / 2 = 5/6
        let scores = [0.9, 0.8, 0.7, 0.1];
        let labels = [true, false, true, false];
        assert!((average_precision(&scores, &labels) - 5.0 / 6.0).abs() < 1e-12);
        // AUC: pairs (pos > neg): (0.9>0.8, 0.9>0.1, 0.7>0.1) of 4 pairs
        assert!((roc_auc(&scores, &labels) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn ties_get_midrank_auc() {
        let scores = [0.5, 0.5];
        let labels = [true, false];
        assert_eq!(roc_auc(&scores, &labels), 0.5);
    }

    #[test]
    fn degenerate_labels() {
        assert_eq!(average_precision(&[0.5], &[true]), 1.0);
        assert_eq!(average_precision(&[0.5], &[false]), 0.0);
        assert_eq!(roc_auc(&[0.5, 0.4], &[true, true]), 0.5);
    }

    #[test]
    fn random_scores_near_half() {
        let mut rng = Pcg32::new(1);
        let n = 20_000;
        let scores: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let labels: Vec<bool> = (0..n).map(|_| rng.below(2) == 0).collect();
        let auc = roc_auc(&scores, &labels);
        assert!((auc - 0.5).abs() < 0.02, "{auc}");
        let ap = average_precision(&scores, &labels);
        let base = labels.iter().filter(|&&l| l).count() as f64 / n as f64;
        assert!((ap - base).abs() < 0.03, "ap {ap} base {base}");
    }

    #[test]
    fn property_auc_matches_naive_pair_count() {
        prop::check_msg(
            "auc == pair statistic",
            13,
            100,
            |rng: &mut Pcg32| {
                let n = 2 + rng.below(30) as usize;
                let scores: Vec<f32> = (0..n).map(|_| (rng.below(8) as f32) / 8.0).collect();
                let labels: Vec<bool> = (0..n).map(|_| rng.below(2) == 0).collect();
                (scores, labels)
            },
            |(scores, labels)| {
                let n_pos = labels.iter().filter(|&&l| l).count();
                let n_neg = labels.len() - n_pos;
                if n_pos == 0 || n_neg == 0 {
                    return Ok(());
                }
                let mut wins = 0.0f64;
                for i in 0..scores.len() {
                    for j in 0..scores.len() {
                        if labels[i] && !labels[j] {
                            if scores[i] > scores[j] {
                                wins += 1.0;
                            } else if scores[i] == scores[j] {
                                wins += 0.5;
                            }
                        }
                    }
                }
                let naive = wins / (n_pos as f64 * n_neg as f64);
                let fast = roc_auc(scores, labels);
                if (fast - naive).abs() > 1e-9 {
                    return Err(format!("fast {fast} != naive {naive}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn nan_scores_report_instead_of_panicking_ap() {
        // one diverged logit used to panic the whole eval via
        // partial_cmp().unwrap(); now it ranks as the most confident score
        // (IEEE total order: +NaN above +inf) and AP stays finite.
        let scores = [f32::NAN, 0.9, 0.1];
        let labels = [false, true, false];
        let ap = average_precision(&scores, &labels);
        assert!(ap.is_finite());
        // NaN (negative) outranks the positive at 0.9 -> precision 1/2
        assert!((ap - 0.5).abs() < 1e-12, "ap {ap}");

        // a NaN-scoring positive counts as an immediate hit
        let ap = average_precision(&[f32::NAN, 0.5], &[true, false]);
        assert_eq!(ap, 1.0);
        // all-NaN input: deterministic, finite, index-tiebroken
        let ap = average_precision(&[f32::NAN, f32::NAN], &[true, false]);
        assert!(ap.is_finite());
    }

    #[test]
    fn nan_scores_report_instead_of_panicking_auc() {
        // NaN sorts above every finite score: a NaN-scoring positive wins
        // every (pos, neg) pair
        let auc = roc_auc(&[f32::NAN, 0.5, 0.2], &[true, false, false]);
        assert_eq!(auc, 1.0);
        // and a NaN-scoring negative loses the metric the same way
        let auc = roc_auc(&[f32::NAN, 0.5, 0.2], &[false, true, true]);
        assert_eq!(auc, 0.0);
        // mixed NaNs stay in [0, 1] and deterministic
        let scores = [f32::NAN, 0.3, f32::NAN, 0.7];
        let labels = [true, false, false, true];
        let a = roc_auc(&scores, &labels);
        let b = roc_auc(&scores, &labels);
        assert_eq!(a, b);
        assert!((0.0..=1.0).contains(&a), "auc {a}");
    }

    #[test]
    fn link_ap_concat_order() {
        let ap = link_ap(&[2.0, 1.5], &[0.5, 0.1]);
        assert_eq!(ap, 1.0);
    }
}
