//! Samplers feeding the embedding module and the self-supervised loss.

pub mod negative;
pub mod neighbor;

pub use negative::NegativeSampler;
pub use neighbor::{NeighborEntry, NeighborIndex};
