//! Most-recent-K temporal neighbor index.
//!
//! The EMB module attends over each vertex's K most recent interactions
//! (TGN's "recent" sampling strategy, the TGL default). The index is a
//! per-vertex ring buffer updated incrementally as batches are committed,
//! so insertion is O(1) and a batch gather is O(b * K) — this sits on the
//! hot path and is benched in rust/benches/substrates.rs.

/// One stored neighbor interaction.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct NeighborEntry {
    pub nbr: u32,
    pub t: f32,
    /// Event index into the log (for edge feature lookup).
    pub event: u32,
}

/// Fixed-capacity ring buffer per vertex, newest-first gather order.
#[derive(Clone, Debug)]
pub struct NeighborIndex {
    k: usize,
    /// [num_nodes * k] flat ring storage.
    entries: Vec<NeighborEntry>,
    /// Per-vertex (head, len): head = next write slot.
    heads: Vec<(u16, u16)>,
}

impl NeighborIndex {
    pub fn new(num_nodes: u32, k: usize) -> Self {
        assert!(k > 0 && k < u16::MAX as usize);
        NeighborIndex {
            k,
            entries: vec![NeighborEntry::default(); num_nodes as usize * k],
            heads: vec![(0, 0); num_nodes as usize],
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Record an interaction on vertex `v`.
    #[inline]
    pub fn insert(&mut self, v: u32, entry: NeighborEntry) {
        let (head, len) = &mut self.heads[v as usize];
        let base = v as usize * self.k;
        self.entries[base + *head as usize] = entry;
        *head = ((*head as usize + 1) % self.k) as u16;
        *len = (*len + 1).min(self.k as u16);
    }

    /// Record both endpoints of an event.
    #[inline]
    pub fn insert_event(&mut self, src: u32, dst: u32, t: f32, event: u32) {
        self.insert(src, NeighborEntry { nbr: dst, t, event });
        self.insert(dst, NeighborEntry { nbr: src, t, event });
    }

    /// Gather the up-to-K most recent neighbors of `v`, newest first.
    /// Returns the number of valid entries written into `out`.
    #[inline]
    pub fn gather(&self, v: u32, out: &mut [NeighborEntry]) -> usize {
        let (head, len) = self.heads[v as usize];
        let len = len as usize;
        let base = v as usize * self.k;
        for (i, slot) in out.iter_mut().enumerate().take(len) {
            // newest = head-1, going backwards
            let pos = (head as usize + self.k - 1 - i) % self.k;
            *slot = self.entries[base + pos];
        }
        len
    }

    pub fn degree(&self, v: u32) -> usize {
        self.heads[v as usize].1 as usize
    }

    /// Reset all state (epoch boundary).
    pub fn clear(&mut self) {
        self.heads.iter_mut().for_each(|h| *h = (0, 0));
    }

    /// Bytes of live storage (Fig. 19 memory accounting).
    pub fn bytes(&self) -> usize {
        self.entries.len() * std::mem::size_of::<NeighborEntry>()
            + self.heads.len() * std::mem::size_of::<(u16, u16)>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn e(nbr: u32, t: f32) -> NeighborEntry {
        NeighborEntry { nbr, t, event: t as u32 }
    }

    #[test]
    fn newest_first_order() {
        let mut idx = NeighborIndex::new(4, 3);
        idx.insert(0, e(10, 1.0));
        idx.insert(0, e(11, 2.0));
        let mut out = [NeighborEntry::default(); 3];
        let n = idx.gather(0, &mut out);
        assert_eq!(n, 2);
        assert_eq!(out[0], e(11, 2.0));
        assert_eq!(out[1], e(10, 1.0));
    }

    #[test]
    fn ring_evicts_oldest() {
        let mut idx = NeighborIndex::new(2, 3);
        for t in 0..5 {
            idx.insert(1, e(100 + t, t as f32));
        }
        let mut out = [NeighborEntry::default(); 3];
        let n = idx.gather(1, &mut out);
        assert_eq!(n, 3);
        assert_eq!(out.iter().map(|x| x.nbr).collect::<Vec<_>>(), vec![104, 103, 102]);
    }

    #[test]
    fn insert_event_updates_both_sides() {
        let mut idx = NeighborIndex::new(4, 2);
        idx.insert_event(0, 3, 5.0, 7);
        assert_eq!(idx.degree(0), 1);
        assert_eq!(idx.degree(3), 1);
        let mut out = [NeighborEntry::default(); 2];
        idx.gather(3, &mut out);
        assert_eq!(out[0].nbr, 0);
        assert_eq!(out[0].event, 7);
    }

    #[test]
    fn clear_resets() {
        let mut idx = NeighborIndex::new(2, 2);
        idx.insert(0, e(1, 1.0));
        idx.clear();
        assert_eq!(idx.degree(0), 0);
    }

    #[test]
    fn property_matches_naive_reference() {
        // ring buffer == "keep last K of an append-only list"
        prop::check_msg(
            "neighbor-ring vs naive",
            42,
            200,
            |rng| {
                let k = 1 + rng.below(6) as usize;
                let n_ops = rng.below(40) as usize;
                let ops: Vec<(u32, u32, u32)> = (0..n_ops)
                    .map(|i| (rng.below(5), rng.below(100), i as u32))
                    .collect();
                (k, ops)
            },
            |(k, ops)| {
                let mut idx = NeighborIndex::new(5, *k);
                let mut naive: Vec<Vec<NeighborEntry>> = vec![Vec::new(); 5];
                for &(v, nbr, i) in ops {
                    let entry = NeighborEntry { nbr, t: i as f32, event: i };
                    idx.insert(v, entry);
                    naive[v as usize].push(entry);
                }
                for v in 0..5u32 {
                    let mut out = vec![NeighborEntry::default(); *k];
                    let n = idx.gather(v, &mut out);
                    let expect: Vec<NeighborEntry> = naive[v as usize]
                        .iter()
                        .rev()
                        .take(*k)
                        .copied()
                        .collect();
                    if n != expect.len() || out[..n] != expect[..] {
                        return Err(format!("v={v}: got {:?} want {:?}", &out[..n], expect));
                    }
                }
                Ok(())
            },
        );
    }
}
