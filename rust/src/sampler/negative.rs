//! Negative event sampling (paper §3, Assumption 1).
//!
//! For each positive event in a temporal batch we draw one destination
//! uniformly from the item range that has no event with the source inside
//! the batch window — the standard TGN/TGL protocol. The sampler is seeded
//! per (trial, batch) so Assumption 1's variance is reproducible.

use std::collections::HashSet;

use crate::graph::EventLog;
use crate::util::rng::Pcg32;

#[derive(Clone, Debug)]
pub struct NegativeSampler {
    dst_lo: u32,
    dst_hi: u32,
}

impl NegativeSampler {
    pub fn new(log: &EventLog) -> Self {
        NegativeSampler {
            dst_lo: log.dst_lo,
            dst_hi: log.num_nodes,
        }
    }

    /// Sample `out.len()` negative destinations for the batch `events`
    /// (srcs aligned with `out`). Rejects destinations that interact with
    /// the corresponding source *within this batch* (capped retries keep
    /// the sampler O(b) even for dense batches).
    pub fn sample_batch(
        &self,
        log: &EventLog,
        events: std::ops::Range<usize>,
        rng: &mut Pcg32,
        out: &mut [u32],
    ) {
        debug_assert_eq!(out.len(), events.len());
        let pairs: HashSet<(u32, u32)> = log.events[events.clone()]
            .iter()
            .map(|e| (e.src, e.dst))
            .collect();
        let n_dst = self.dst_hi - self.dst_lo;
        for (slot, ev) in out.iter_mut().zip(&log.events[events]) {
            let mut dst = self.dst_lo + rng.below(n_dst);
            for _ in 0..8 {
                if !pairs.contains(&(ev.src, dst)) {
                    break;
                }
                dst = self.dst_lo + rng.below(n_dst);
            }
            *slot = dst;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Event, NO_LABEL};
    use crate::util::prop;

    fn log_with(pairs: &[(u32, u32)]) -> EventLog {
        let mut log = EventLog::new(10, 5, 0);
        for (i, &(s, d)) in pairs.iter().enumerate() {
            log.push(Event { src: s, dst: d, t: i as f32, label: NO_LABEL }, &[])
                .unwrap();
        }
        log
    }

    #[test]
    fn negatives_in_dst_range() {
        let log = log_with(&[(0, 5), (1, 6), (2, 7)]);
        let sampler = NegativeSampler::new(&log);
        let mut rng = Pcg32::new(0);
        let mut out = vec![0u32; 3];
        sampler.sample_batch(&log, 0..3, &mut rng, &mut out);
        for &d in &out {
            assert!((5..10).contains(&d));
        }
    }

    #[test]
    fn avoids_in_batch_pairs_when_possible() {
        // src 0 interacts with 5; with 5 candidate dsts the sampler should
        // essentially never return 5 for src 0
        let log = log_with(&[(0, 5); 20]);
        let sampler = NegativeSampler::new(&log);
        let mut rng = Pcg32::new(1);
        let mut out = vec![0u32; 20];
        for trial in 0..50 {
            let mut r = rng.split(trial);
            sampler.sample_batch(&log, 0..20, &mut r, &mut out);
            assert!(out.iter().filter(|&&d| d == 5).count() <= 1);
        }
    }

    #[test]
    fn deterministic_given_rng() {
        let log = log_with(&[(0, 5), (1, 6), (2, 7), (3, 8)]);
        let sampler = NegativeSampler::new(&log);
        let mut a_out = vec![0u32; 4];
        let mut b_out = vec![0u32; 4];
        sampler.sample_batch(&log, 0..4, &mut Pcg32::new(9), &mut a_out);
        sampler.sample_batch(&log, 0..4, &mut Pcg32::new(9), &mut b_out);
        assert_eq!(a_out, b_out);
    }

    #[test]
    fn property_range_invariant() {
        prop::check(
            "negatives always in item range",
            3,
            100,
            |rng| {
                let n = 1 + rng.below(30) as usize;
                let pairs: Vec<(u32, u32)> = (0..n)
                    .map(|_| (rng.below(5), 5 + rng.below(5)))
                    .collect();
                (pairs, rng.next_u64())
            },
            |(pairs, seed)| {
                let log = log_with(pairs);
                let sampler = NegativeSampler::new(&log);
                let mut out = vec![0u32; pairs.len()];
                sampler.sample_batch(&log, 0..pairs.len(), &mut Pcg32::new(*seed), &mut out);
                out.iter().all(|&d| (5..10).contains(&d))
            },
        );
    }
}
