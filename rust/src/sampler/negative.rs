//! Negative event sampling (paper §3, Assumption 1).
//!
//! For each positive event in a temporal batch we draw one destination
//! uniformly from the item range that has no event with the source inside
//! the batch window — the standard TGN/TGL protocol. The sampler is seeded
//! per (trial, batch) so Assumption 1's variance is reproducible.

use crate::graph::{Event, EventLog};
use crate::util::pool::{chunk_for, take_chunk, WorkerPool};
use crate::util::rng::Pcg32;

/// Rows below which row-wise sampling stays on one lane (binary-search
/// probes + a handful of RNG draws per row — parallelism only pays on
/// real batches).
const SAMPLE_PAR_MIN_ROWS: usize = 256;

#[derive(Clone, Debug)]
pub struct NegativeSampler {
    dst_lo: u32,
    dst_hi: u32,
}

impl NegativeSampler {
    pub fn new(log: &EventLog) -> Self {
        NegativeSampler {
            dst_lo: log.dst_lo,
            dst_hi: log.num_nodes,
        }
    }

    /// Sample `out.len()` negative destinations for the batch `events`
    /// (srcs aligned with `out`). Rejects destinations that interact with
    /// the corresponding source *within this batch* (capped retries keep
    /// the sampler O(b) even for dense batches).
    pub fn sample_batch(
        &self,
        log: &EventLog,
        events: std::ops::Range<usize>,
        rng: &mut Pcg32,
        out: &mut [u32],
    ) {
        debug_assert_eq!(out.len(), events.len());
        // sorted probe table (deterministic by construction; probed with
        // binary_search, never iterated)
        let mut pairs: Vec<(u32, u32)> = log.events[events.clone()]
            .iter()
            .map(|e| (e.src, e.dst))
            .collect();
        pairs.sort_unstable();
        pairs.dedup();
        let n_dst = self.dst_hi - self.dst_lo;
        for (slot, ev) in out.iter_mut().zip(&log.events[events]) {
            let mut dst = self.dst_lo + rng.below(n_dst);
            for _ in 0..8 {
                if pairs.binary_search(&(ev.src, dst)).is_err() {
                    break;
                }
                dst = self.dst_lo + rng.below(n_dst);
            }
            *slot = dst;
        }
    }

    /// Row-wise variant for the parallel PREP stage: row `j` draws from its
    /// own stream `base.split(j)` instead of consuming one shared serial
    /// stream, which makes every row independent — so the batch fans out
    /// across `pool` lanes and the result is a pure function of
    /// `(base, events)` whatever the lane count (or the chunking). Same
    /// rejection protocol per row as [`NegativeSampler::sample_batch`].
    pub fn sample_batch_rowwise(
        &self,
        log: &EventLog,
        events: std::ops::Range<usize>,
        base: &Pcg32,
        out: &mut [u32],
        pool: &WorkerPool,
    ) {
        debug_assert_eq!(out.len(), events.len());
        // sorted probe table (deterministic by construction; probed with
        // binary_search, never iterated)
        let mut pairs: Vec<(u32, u32)> = log.events[events.clone()]
            .iter()
            .map(|e| (e.src, e.dst))
            .collect();
        pairs.sort_unstable();
        pairs.dedup();
        let n_dst = self.dst_hi - self.dst_lo;
        let evs = &log.events[events];

        struct RowChunk<'a> {
            j0: usize,
            out: &'a mut [u32],
            evs: &'a [Event],
        }
        let total = out.len();
        let chunk = chunk_for(total, pool.lanes(), SAMPLE_PAR_MIN_ROWS);
        let mut tasks: Vec<RowChunk> = Vec::with_capacity(total.div_ceil(chunk));
        let mut rest = out;
        let mut j0 = 0;
        while j0 < total {
            let n = chunk.min(total - j0);
            tasks.push(RowChunk { j0, out: take_chunk(&mut rest, n), evs: &evs[j0..j0 + n] });
            j0 += n;
        }
        pool.run(&mut tasks, |c| {
            for (k, (slot, ev)) in c.out.iter_mut().zip(c.evs).enumerate() {
                let mut rng = base.clone().split((c.j0 + k) as u64);
                let mut dst = self.dst_lo + rng.below(n_dst);
                for _ in 0..8 {
                    if pairs.binary_search(&(ev.src, dst)).is_err() {
                        break;
                    }
                    dst = self.dst_lo + rng.below(n_dst);
                }
                *slot = dst;
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Event, NO_LABEL};
    use crate::util::prop;

    fn log_with(pairs: &[(u32, u32)]) -> EventLog {
        let mut log = EventLog::new(10, 5, 0);
        for (i, &(s, d)) in pairs.iter().enumerate() {
            log.push(Event { src: s, dst: d, t: i as f32, label: NO_LABEL }, &[])
                .unwrap();
        }
        log
    }

    #[test]
    fn negatives_in_dst_range() {
        let log = log_with(&[(0, 5), (1, 6), (2, 7)]);
        let sampler = NegativeSampler::new(&log);
        let mut rng = Pcg32::new(0);
        let mut out = vec![0u32; 3];
        sampler.sample_batch(&log, 0..3, &mut rng, &mut out);
        for &d in &out {
            assert!((5..10).contains(&d));
        }
    }

    #[test]
    fn avoids_in_batch_pairs_when_possible() {
        // src 0 interacts with 5; with 5 candidate dsts the sampler should
        // essentially never return 5 for src 0
        let log = log_with(&[(0, 5); 20]);
        let sampler = NegativeSampler::new(&log);
        let mut rng = Pcg32::new(1);
        let mut out = vec![0u32; 20];
        for trial in 0..50 {
            let mut r = rng.split(trial);
            sampler.sample_batch(&log, 0..20, &mut r, &mut out);
            assert!(out.iter().filter(|&&d| d == 5).count() <= 1);
        }
    }

    #[test]
    fn deterministic_given_rng() {
        let log = log_with(&[(0, 5), (1, 6), (2, 7), (3, 8)]);
        let sampler = NegativeSampler::new(&log);
        let mut a_out = vec![0u32; 4];
        let mut b_out = vec![0u32; 4];
        sampler.sample_batch(&log, 0..4, &mut Pcg32::new(9), &mut a_out);
        sampler.sample_batch(&log, 0..4, &mut Pcg32::new(9), &mut b_out);
        assert_eq!(a_out, b_out);
    }

    #[test]
    fn rowwise_sampling_is_identical_for_every_worker_count() {
        // the parallel-PREP guarantee: row-wise negatives are a pure
        // function of (base stream, batch) — lane count and chunking can
        // never change them
        let pairs: Vec<(u32, u32)> = (0..600).map(|i| (i % 5, 5 + (i * 7) % 5)).collect();
        let log = log_with(&pairs);
        let sampler = NegativeSampler::new(&log);
        let base = Pcg32::new(17);
        let mut want = vec![0u32; pairs.len()];
        sampler.sample_batch_rowwise(
            &log, 0..pairs.len(), &base, &mut want, &WorkerPool::new(1),
        );
        for lanes in [2usize, 3, 8] {
            let pool = WorkerPool::new(lanes);
            let mut got = vec![0u32; pairs.len()];
            sampler.sample_batch_rowwise(&log, 0..pairs.len(), &base, &mut got, &pool);
            assert_eq!(got, want, "lanes={lanes}");
        }
    }

    #[test]
    fn rowwise_sampling_respects_range_and_in_batch_avoidance() {
        // src 0 always pairs with dst 5: of 5 candidates the rejection loop
        // should essentially never return 5
        let log = log_with(&[(0, 5); 300]);
        let sampler = NegativeSampler::new(&log);
        let pool = WorkerPool::new(4);
        let mut out = vec![0u32; 300];
        sampler.sample_batch_rowwise(&log, 0..300, &Pcg32::new(3), &mut out, &pool);
        assert!(out.iter().all(|&d| (5..10).contains(&d)));
        assert!(out.iter().filter(|&&d| d == 5).count() <= 2);
    }

    #[test]
    fn property_range_invariant() {
        prop::check(
            "negatives always in item range",
            3,
            100,
            |rng| {
                let n = 1 + rng.below(30) as usize;
                let pairs: Vec<(u32, u32)> = (0..n)
                    .map(|_| (rng.below(5), 5 + rng.below(5)))
                    .collect();
                (pairs, rng.next_u64())
            },
            |(pairs, seed)| {
                let log = log_with(pairs);
                let sampler = NegativeSampler::new(&log);
                let mut out = vec![0u32; pairs.len()];
                sampler.sample_batch(&log, 0..pairs.len(), &mut Pcg32::new(*seed), &mut out);
                out.iter().all(|&d| (5..10).contains(&d))
            },
        );
    }
}
