//! Pending-set analysis and the update-row plan for one temporal batch.
//!
//! Definitions (paper §3.1): event e' is *pending* on e if they share a
//! vertex and t' < t; the *pending set* P(e, B) collects e's pending events
//! inside batch B. Batch processing applies only one memory update per
//! vertex (the temporal discontinuity), so the plan:
//!
//! * lays out 2b *update rows* — row r in [0, b) is the src side of event
//!   (start + r), row b + r its dst side;
//! * marks per vertex the *last* occurrence (write-back mask): that row's
//!   corrected state is what enters the memory store, mirroring the
//!   "single transition" in Fig. 2(b)'s bottom panel;
//! * exposes `last_row_of`, which the next batch uses to splice freshly
//!   updated states into its own rows (the in-graph lag-one gather);
//! * measures pending statistics, the quantity Theorems 1-2 reason about.

use std::collections::BTreeMap;

use crate::graph::EventLog;

/// Aggregate pending-event statistics of one batch (paper Def. 2).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PendingStats {
    /// Events whose pending set is non-empty.
    pub pending_events: usize,
    /// Sum over events of |P(e, B)| (pairs sharing a vertex, earlier-first).
    pub pending_pairs: usize,
    /// Vertices updated more than once (their intermediate states are lost).
    pub collided_vertices: usize,
    /// Total distinct vertices in the batch.
    pub distinct_vertices: usize,
}

/// The per-batch plan consumed by the step assembler.
#[derive(Clone, Debug)]
pub struct BatchPlan {
    /// Event index range into the log.
    pub range: std::ops::Range<usize>,
    /// Vertex per update row; length 2b (src sides then dst sides).
    pub upd_vertex: Vec<u32>,
    /// Event log index per update row.
    pub upd_event: Vec<u32>,
    /// 1.0 where the row is the vertex's last occurrence in the batch.
    pub wmask: Vec<f32>,
    /// 1.0 where the row's vertex occurs more than once in the batch —
    /// i.e. its batch update suffers temporal discontinuity (Def. 1) and
    /// is a "noisy measurement" for the PRES filter.
    pub collided: Vec<f32>,
    /// vertex -> its last update row (the row whose corrected state the
    /// next batch should splice in).
    last_row: BTreeMap<u32, u32>,
    pub stats: PendingStats,
}

impl BatchPlan {
    /// Analyze `range` of `log`. O(b log b) time, O(distinct vertices)
    /// space; the per-vertex tables are `BTreeMap`s so every traversal
    /// below is in sorted vertex order (determinism by construction, not
    /// by each consumer happening to be order-independent).
    pub fn build(log: &EventLog, range: std::ops::Range<usize>) -> BatchPlan {
        let b = range.len();
        let u = 2 * b;
        let mut upd_vertex = vec![0u32; u];
        let mut upd_event = vec![0u32; u];
        let mut wmask = vec![0.0f32; u];
        let mut collided = vec![0.0f32; u];
        let mut last_row: BTreeMap<u32, u32> = BTreeMap::new();
        // per-vertex update-ROW count (a self-loop contributes two rows):
        // drives collided marking, i.e. "this vertex's intermediate state is
        // lost under batch processing"
        let mut occurrences: BTreeMap<u32, u32> = BTreeMap::new();
        // per-vertex prior-EVENT count (a self-loop counts once): drives the
        // pending math, which reasons about event pairs sharing a vertex
        let mut event_occ: BTreeMap<u32, u32> = BTreeMap::new();
        // prior events per normalized endpoint pair: corrects the double
        // count when a prior event shares BOTH endpoints with this one
        let mut pair_counts: BTreeMap<(u32, u32), u32> = BTreeMap::new();
        let mut pending_events = 0usize;
        let mut pending_pairs = 0usize;

        for (r, i) in range.clone().enumerate() {
            let ev = log.events[i];
            // |P(e, B)| = prior events sharing src + sharing dst - sharing
            // both (inclusion-exclusion; a self-loop event has one distinct
            // endpoint, so only the src term applies)
            let prior_src = event_occ.get(&ev.src).copied().unwrap_or(0);
            let key = (ev.src.min(ev.dst), ev.src.max(ev.dst));
            let pending = if ev.src == ev.dst {
                prior_src as usize
            } else {
                let prior_dst = event_occ.get(&ev.dst).copied().unwrap_or(0);
                let prior_both = pair_counts.get(&key).copied().unwrap_or(0);
                (prior_src + prior_dst - prior_both) as usize
            };
            if pending > 0 {
                pending_events += 1;
                pending_pairs += pending;
            }
            *occurrences.entry(ev.src).or_insert(0) += 1;
            *occurrences.entry(ev.dst).or_insert(0) += 1;
            *event_occ.entry(ev.src).or_insert(0) += 1;
            if ev.src != ev.dst {
                *event_occ.entry(ev.dst).or_insert(0) += 1;
            }
            *pair_counts.entry(key).or_insert(0) += 1;

            upd_vertex[r] = ev.src;
            upd_event[r] = i as u32;
            upd_vertex[b + r] = ev.dst;
            upd_event[b + r] = i as u32;
            // later insert wins: row order within the batch is chronological
            // (src and dst sides of one event are simultaneous; dst row
            // index b + r > r keeps the map deterministic)
            last_row.insert(ev.src, r as u32);
            last_row.insert(ev.dst, (b + r) as u32);
        }

        for (&v, &r) in &last_row {
            debug_assert_eq!(upd_vertex[r as usize], v);
            wmask[r as usize] = 1.0;
        }
        for (r, &v) in upd_vertex.iter().enumerate() {
            if occurrences.get(&v).copied().unwrap_or(0) > 1 {
                collided[r] = 1.0;
            }
        }
        let collided_vertices = occurrences.values().filter(|&&c| c > 1).count();
        let stats = PendingStats {
            pending_events,
            pending_pairs,
            collided_vertices,
            distinct_vertices: occurrences.len(),
        };
        BatchPlan {
            range,
            upd_vertex,
            upd_event,
            wmask,
            collided,
            last_row,
            stats,
        }
    }

    pub fn batch_size(&self) -> usize {
        self.range.len()
    }

    /// Row count (2b).
    pub fn rows(&self) -> usize {
        self.upd_vertex.len()
    }

    /// Last update row of `v` in this batch, if any.
    #[inline]
    pub fn last_row_of(&self, v: u32) -> Option<u32> {
        self.last_row.get(&v).copied()
    }

    /// Fill `out[i] = last_row_of(vertices[i])` or -1 (the lag-one match
    /// indices the executable uses to splice fresh states).
    pub fn match_rows(&self, vertices: &[u32], out: &mut [i32]) {
        debug_assert_eq!(vertices.len(), out.len());
        for (slot, &v) in out.iter_mut().zip(vertices) {
            *slot = self.last_row.get(&v).map_or(-1, |&r| r as i32);
        }
    }
}

/// Naive O(b^2) pending-pair count, kept as the property-test oracle.
pub fn pending_pairs_naive(log: &EventLog, range: std::ops::Range<usize>) -> usize {
    let evs = &log.events[range];
    let mut total = 0;
    for (j, e) in evs.iter().enumerate() {
        for e2 in &evs[..j] {
            let shares = e.src == e2.src
                || e.src == e2.dst
                || e.dst == e2.src
                || e.dst == e2.dst;
            if shares {
                total += 1;
            }
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Event, NO_LABEL};
    use crate::util::prop;
    use crate::util::rng::Pcg32;

    fn log_with(pairs: &[(u32, u32)]) -> EventLog {
        let mut log = EventLog::new(16, 8, 0);
        for (i, &(s, d)) in pairs.iter().enumerate() {
            log.push(Event { src: s, dst: d, t: i as f32, label: NO_LABEL }, &[])
                .unwrap();
        }
        log
    }

    #[test]
    fn layout_src_rows_then_dst_rows() {
        let log = log_with(&[(0, 8), (1, 9)]);
        let plan = BatchPlan::build(&log, 0..2);
        assert_eq!(plan.upd_vertex, vec![0, 1, 8, 9]);
        assert_eq!(plan.upd_event, vec![0, 1, 0, 1]);
    }

    #[test]
    fn wmask_marks_last_occurrence_only() {
        // vertex 0 is src of events 0 and 1 -> only row 1 wins
        let log = log_with(&[(0, 8), (0, 9)]);
        let plan = BatchPlan::build(&log, 0..2);
        assert_eq!(plan.wmask, vec![0.0, 1.0, 1.0, 1.0]);
        assert_eq!(plan.last_row_of(0), Some(1));
        assert_eq!(plan.last_row_of(8), Some(2));
        assert_eq!(plan.last_row_of(9), Some(3));
    }

    #[test]
    fn pending_stats_simple() {
        // e1 pends on e0 (share vertex 0); e2 pends on both? shares 0 with
        // e0,e1 -> pending_pairs = 1 (e1) + 2 (e2) = 3
        let log = log_with(&[(0, 8), (0, 9), (0, 10)]);
        let plan = BatchPlan::build(&log, 0..3);
        assert_eq!(plan.stats.pending_events, 2);
        assert_eq!(plan.stats.pending_pairs, 3);
        assert_eq!(plan.stats.collided_vertices, 1);
        assert_eq!(plan.stats.distinct_vertices, 4);
    }

    #[test]
    fn no_pending_in_disjoint_batch() {
        let log = log_with(&[(0, 8), (1, 9), (2, 10)]);
        let plan = BatchPlan::build(&log, 0..3);
        assert_eq!(plan.stats.pending_events, 0);
        assert_eq!(plan.stats.collided_vertices, 0);
        assert!(plan.wmask.iter().all(|&w| w == 1.0));
    }

    #[test]
    fn self_loop_counts_each_prior_event_once() {
        // (5,5) then (5,9): the self-loop shares exactly one vertex with
        // the later event -> one pending pair, not the two a row-level
        // count would claim
        let log = log_with(&[(5, 5), (5, 9)]);
        let plan = BatchPlan::build(&log, 0..2);
        assert_eq!(plan.stats.pending_events, 1);
        assert_eq!(plan.stats.pending_pairs, 1);
        assert_eq!(pending_pairs_naive(&log, 0..2), 1);
        // vertex 5 occupies three update rows (both self-loop sides + the
        // src side of event 1) -> collided; vertex 9 appears once
        assert_eq!(plan.stats.collided_vertices, 1);
        assert_eq!(plan.collided, vec![1.0, 1.0, 1.0, 0.0]);
        // the chronologically-last update of vertex 5 is event 1's src side
        assert_eq!(plan.last_row_of(5), Some(1));
        assert_eq!(plan.wmask, vec![0.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    fn single_self_loop_is_collided_but_not_pending() {
        let log = log_with(&[(4, 4)]);
        let plan = BatchPlan::build(&log, 0..1);
        // no earlier event -> nothing pending (src/dst sides are simultaneous)
        assert_eq!(plan.stats.pending_events, 0);
        assert_eq!(plan.stats.pending_pairs, 0);
        assert_eq!(pending_pairs_naive(&log, 0..1), 0);
        // but batch processing applies only one of its two updates: the
        // vertex is operationally collided and the dst-side row (index 1)
        // is the write-back winner
        assert_eq!(plan.stats.collided_vertices, 1);
        assert_eq!(plan.stats.distinct_vertices, 1);
        assert_eq!(plan.collided, vec![1.0, 1.0]);
        assert_eq!(plan.wmask, vec![0.0, 1.0]);
        assert_eq!(plan.last_row_of(4), Some(1));
    }

    #[test]
    fn repeated_endpoint_pair_not_double_counted() {
        // (0,8) three times: event k pends on the k prior events exactly
        // once each despite sharing BOTH endpoints
        let log = log_with(&[(0, 8), (0, 8), (0, 8)]);
        let plan = BatchPlan::build(&log, 0..3);
        assert_eq!(plan.stats.pending_events, 2);
        assert_eq!(plan.stats.pending_pairs, 1 + 2);
        assert_eq!(pending_pairs_naive(&log, 0..3), 3);
        assert_eq!(plan.stats.collided_vertices, 2);
        assert_eq!(plan.stats.distinct_vertices, 2);
        // winners: the last event's rows
        assert_eq!(plan.last_row_of(0), Some(2));
        assert_eq!(plan.last_row_of(8), Some(5));
        assert_eq!(plan.wmask, vec![0.0, 0.0, 1.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn batch_of_size_one_has_trivial_plan() {
        let log = log_with(&[(2, 9)]);
        let plan = BatchPlan::build(&log, 0..1);
        assert_eq!(plan.batch_size(), 1);
        assert_eq!(plan.rows(), 2);
        assert_eq!(
            plan.stats,
            PendingStats {
                pending_events: 0,
                pending_pairs: 0,
                collided_vertices: 0,
                distinct_vertices: 2,
            }
        );
        assert_eq!(plan.wmask, vec![1.0, 1.0]);
        assert_eq!(plan.collided, vec![0.0, 0.0]);
        assert_eq!(plan.last_row_of(2), Some(0));
        assert_eq!(plan.last_row_of(9), Some(1));
    }

    #[test]
    fn match_rows_hits_and_misses() {
        let log = log_with(&[(0, 8), (1, 8)]);
        let plan = BatchPlan::build(&log, 0..2);
        let mut out = [0i32; 4];
        plan.match_rows(&[0, 1, 8, 5], &mut out);
        assert_eq!(out, [0, 1, 3, -1]);
    }

    #[test]
    fn subrange_plans_use_log_indices() {
        let log = log_with(&[(0, 8), (1, 9), (2, 10), (3, 11)]);
        let plan = BatchPlan::build(&log, 2..4);
        assert_eq!(plan.upd_event, vec![2, 3, 2, 3]);
        assert_eq!(plan.upd_vertex, vec![2, 3, 10, 11]);
    }

    #[test]
    fn property_pending_pairs_match_naive_oracle() {
        prop::check_msg(
            "pending pairs == O(b^2) oracle",
            7,
            150,
            |rng: &mut Pcg32| {
                let b = 1 + rng.below(40) as usize;
                (0..b)
                    .map(|_| (rng.below(8), 8 + rng.below(8)))
                    .collect::<Vec<_>>()
            },
            |pairs| {
                let log = log_with(pairs);
                let plan = BatchPlan::build(&log, 0..pairs.len());
                let naive = pending_pairs_naive(&log, 0..pairs.len());
                if plan.stats.pending_pairs != naive {
                    return Err(format!(
                        "fast {} != naive {naive}",
                        plan.stats.pending_pairs
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_wmask_invariants() {
        prop::check_msg(
            "wmask: one winner per vertex, winner is max row",
            11,
            150,
            |rng: &mut Pcg32| {
                let b = 1 + rng.below(40) as usize;
                (0..b)
                    .map(|_| (rng.below(6), 6 + rng.below(6)))
                    .collect::<Vec<_>>()
            },
            |pairs| {
                let log = log_with(pairs);
                let plan = BatchPlan::build(&log, 0..pairs.len());
                let mut winners: BTreeMap<u32, Vec<u32>> = BTreeMap::new();
                for (r, &v) in plan.upd_vertex.iter().enumerate() {
                    if plan.wmask[r] == 1.0 {
                        winners.entry(v).or_default().push(r as u32);
                    }
                }
                for (v, rows) in &winners {
                    if rows.len() != 1 {
                        return Err(format!("vertex {v} has {} winners", rows.len()));
                    }
                    let max_row = plan
                        .upd_vertex
                        .iter()
                        .enumerate()
                        .filter(|(_, &u)| u == *v)
                        .map(|(r, _)| r as u32)
                        .max()
                        .unwrap();
                    if rows[0] != max_row {
                        return Err(format!("vertex {v}: winner {} != max {max_row}", rows[0]));
                    }
                }
                // every distinct vertex has exactly one winner
                let distinct: std::collections::BTreeSet<u32> =
                    plan.upd_vertex.iter().copied().collect();
                if winners.len() != distinct.len() {
                    return Err("some vertex lost its winner".into());
                }
                Ok(())
            },
        );
    }
}
