//! Temporal batch partitioning + pending-set analysis (paper §3.1).
//!
//! This module owns the paper's core bookkeeping: which events inside a
//! temporal batch are *pending* on one another (Def. 1-2), which update
//! row carries the final state of each vertex under batch processing (the
//! temporal-discontinuity dedup), and how the next batch's vertices match
//! into the previous batch's freshly updated rows (the lag-one splice).

pub mod pending;

pub use pending::{BatchPlan, PendingStats};

/// Partition an event range into consecutive temporal batches of size `b`.
/// The last partial batch is dropped (a fixed shape is required by the AOT
/// executables; at most b-1 of |E| events are unused, matching TGL).
pub fn partition(range: std::ops::Range<usize>, b: usize) -> Vec<std::ops::Range<usize>> {
    assert!(b > 0);
    let mut out = Vec::new();
    let mut lo = range.start;
    while lo + b <= range.end {
        out.push(lo..lo + b);
        lo += b;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn partition_basic() {
        let parts = partition(0..10, 3);
        assert_eq!(parts, vec![0..3, 3..6, 6..9]);
    }

    #[test]
    fn partition_exact() {
        assert_eq!(partition(5..11, 3), vec![5..8, 8..11]);
    }

    #[test]
    fn property_partition_covers_prefix_in_order() {
        prop::check_msg(
            "partition covers consecutive prefix exactly once",
            1,
            200,
            |rng| {
                let start = rng.below(50) as usize;
                let len = rng.below(500) as usize;
                let b = 1 + rng.below(64) as usize;
                (start, len, b)
            },
            |&(start, len, b)| {
                let parts = partition(start..start + len, b);
                let mut expect = start;
                for p in &parts {
                    if p.start != expect || p.len() != b {
                        return Err(format!("bad part {p:?}, expect start {expect}"));
                    }
                    expect = p.end;
                }
                if start + len - expect >= b {
                    return Err("dropped a full batch".into());
                }
                Ok(())
            },
        );
    }
}
