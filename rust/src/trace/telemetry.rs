//! Pipeline-health gauges/counters and the machine-readable metrics sink.
//!
//! Counters are process-global relaxed atomics, gated behind one
//! `metrics_enabled()` branch per call site so a run without `--metrics-out`
//! or `--trace-out` pays a single relaxed load. `snapshot()` reads them all;
//! `take_delta()` returns the change since the previous call, which is what
//! the per-epoch JSONL emitter wants.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};

use anyhow::{Context, Result};

use crate::util::json::Json;

static METRICS_ENABLED: AtomicBool = AtomicBool::new(false);

/// GMM variance estimates clamped at zero (raw estimate was negative).
static GMM_VAR_CLAMPS: AtomicU64 = AtomicU64::new(0);
/// Current PREP channel depth (batches prepared but not yet consumed).
static PREP_DEPTH: AtomicI64 = AtomicI64::new(0);
/// High-water mark of `PREP_DEPTH`.
static PREP_DEPTH_HWM: AtomicI64 = AtomicI64::new(0);
/// Worker-pool generations dispatched (parallel `run` calls).
static POOL_OPS: AtomicU64 = AtomicU64::new(0);
/// Tasks distributed across those generations.
static POOL_TASKS: AtomicU64 = AtomicU64::new(0);
/// Lane slots those generations could occupy (ops × lanes).
static POOL_LANE_SLOTS: AtomicU64 = AtomicU64::new(0);

#[inline]
pub fn metrics_enabled() -> bool {
    METRICS_ENABLED.load(Ordering::Relaxed)
}

pub fn enable_metrics() {
    METRICS_ENABLED.store(true, Ordering::Relaxed);
}

pub fn disable_metrics() {
    METRICS_ENABLED.store(false, Ordering::Relaxed);
}

#[inline]
pub fn count_gmm_var_clamps(n: u64) {
    if metrics_enabled() && n > 0 {
        GMM_VAR_CLAMPS.fetch_add(n, Ordering::Relaxed);
    }
}

#[inline]
pub fn prep_depth_inc() {
    if metrics_enabled() {
        let d = PREP_DEPTH.fetch_add(1, Ordering::Relaxed) + 1;
        PREP_DEPTH_HWM.fetch_max(d, Ordering::Relaxed);
    }
}

#[inline]
pub fn prep_depth_dec() {
    if metrics_enabled() {
        PREP_DEPTH.fetch_sub(1, Ordering::Relaxed);
    }
}

#[inline]
pub fn count_pool_generation(tasks: u64, lanes: u64) {
    if metrics_enabled() {
        POOL_OPS.fetch_add(1, Ordering::Relaxed);
        POOL_TASKS.fetch_add(tasks, Ordering::Relaxed);
        POOL_LANE_SLOTS.fetch_add(lanes, Ordering::Relaxed);
    }
}

/// Point-in-time read of every counter/gauge.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TelemetrySnapshot {
    pub gmm_var_clamps: u64,
    pub prep_depth: i64,
    pub prep_depth_hwm: i64,
    pub pool_ops: u64,
    pub pool_tasks: u64,
    pub pool_lane_slots: u64,
}

impl TelemetrySnapshot {
    /// Counter change relative to an earlier snapshot (gauges pass through).
    pub fn delta_since(&self, prev: &TelemetrySnapshot) -> TelemetrySnapshot {
        TelemetrySnapshot {
            gmm_var_clamps: self.gmm_var_clamps.saturating_sub(prev.gmm_var_clamps),
            prep_depth: self.prep_depth,
            prep_depth_hwm: self.prep_depth_hwm,
            pool_ops: self.pool_ops.saturating_sub(prev.pool_ops),
            pool_tasks: self.pool_tasks.saturating_sub(prev.pool_tasks),
            pool_lane_slots: self.pool_lane_slots.saturating_sub(prev.pool_lane_slots),
        }
    }

    /// Mean fraction of pool lane slots actually carrying tasks, in [0, 1].
    pub fn pool_occupancy(&self) -> f64 {
        if self.pool_lane_slots == 0 {
            return 0.0;
        }
        (self.pool_tasks.min(self.pool_lane_slots) as f64) / self.pool_lane_slots as f64
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("gmm_var_clamps", Json::num(self.gmm_var_clamps as f64)),
            ("prep_depth", Json::num(self.prep_depth as f64)),
            ("prep_depth_hwm", Json::num(self.prep_depth_hwm as f64)),
            ("pool_ops", Json::num(self.pool_ops as f64)),
            ("pool_tasks", Json::num(self.pool_tasks as f64)),
            ("pool_lane_slots", Json::num(self.pool_lane_slots as f64)),
            ("pool_occupancy", Json::num(self.pool_occupancy())),
        ])
    }
}

pub fn snapshot() -> TelemetrySnapshot {
    TelemetrySnapshot {
        gmm_var_clamps: GMM_VAR_CLAMPS.load(Ordering::Relaxed),
        prep_depth: PREP_DEPTH.load(Ordering::Relaxed),
        prep_depth_hwm: PREP_DEPTH_HWM.load(Ordering::Relaxed),
        pool_ops: POOL_OPS.load(Ordering::Relaxed),
        pool_tasks: POOL_TASKS.load(Ordering::Relaxed),
        pool_lane_slots: POOL_LANE_SLOTS.load(Ordering::Relaxed),
    }
}

/// Reset all counters and gauges (for test isolation / run boundaries).
pub fn reset() {
    GMM_VAR_CLAMPS.store(0, Ordering::Relaxed);
    PREP_DEPTH.store(0, Ordering::Relaxed);
    PREP_DEPTH_HWM.store(0, Ordering::Relaxed);
    POOL_OPS.store(0, Ordering::Relaxed);
    POOL_TASKS.store(0, Ordering::Relaxed);
    POOL_LANE_SLOTS.store(0, Ordering::Relaxed);
}

/// Append-style JSONL writer for `--metrics-out`: one compact JSON object
/// per line, flushed per emit so partial runs still leave a parseable file.
pub struct MetricsSink {
    w: BufWriter<File>,
    path: String,
}

impl MetricsSink {
    pub fn create(path: &str) -> Result<MetricsSink> {
        crate::util::ensure_parent_dir(path)?;
        let f = File::create(path).with_context(|| format!("creating metrics file {path}"))?;
        Ok(MetricsSink {
            w: BufWriter::new(f),
            path: path.to_string(),
        })
    }

    pub fn emit(&mut self, record: &Json) -> Result<()> {
        let line = record.to_string();
        writeln!(self.w, "{line}").with_context(|| format!("writing {}", self.path))?;
        self.w
            .flush()
            .with_context(|| format!("flushing {}", self.path))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gate_on_enable() {
        // process-global; other tests do not touch gmm clamps concurrently
        disable_metrics();
        let before = snapshot().gmm_var_clamps;
        count_gmm_var_clamps(3);
        assert_eq!(snapshot().gmm_var_clamps, before);
        enable_metrics();
        count_gmm_var_clamps(3);
        assert_eq!(snapshot().gmm_var_clamps, before + 3);
        disable_metrics();
    }

    #[test]
    fn delta_subtracts_counters() {
        let a = TelemetrySnapshot {
            gmm_var_clamps: 5,
            pool_ops: 10,
            pool_tasks: 40,
            pool_lane_slots: 80,
            prep_depth: 1,
            prep_depth_hwm: 2,
        };
        let b = TelemetrySnapshot {
            gmm_var_clamps: 8,
            pool_ops: 14,
            pool_tasks: 60,
            pool_lane_slots: 112,
            prep_depth: 0,
            prep_depth_hwm: 2,
        };
        let d = b.delta_since(&a);
        assert_eq!(d.gmm_var_clamps, 3);
        assert_eq!(d.pool_ops, 4);
        assert_eq!(d.pool_tasks, 20);
        assert_eq!(d.pool_lane_slots, 32);
        assert_eq!(d.prep_depth, 0);
    }

    #[test]
    fn occupancy_handles_zero_slots() {
        let z = TelemetrySnapshot::default();
        assert_eq!(z.pool_occupancy(), 0.0);
        let s = TelemetrySnapshot {
            pool_tasks: 30,
            pool_lane_slots: 40,
            ..TelemetrySnapshot::default()
        };
        assert!((s.pool_occupancy() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn sink_writes_one_object_per_line() {
        let dir = std::env::temp_dir();
        let path = dir.join("pres_metrics_sink_test.jsonl");
        let path = path.to_str().unwrap().to_string();
        {
            let mut sink = MetricsSink::create(&path).unwrap();
            sink.emit(&Json::obj(vec![("epoch", Json::num(1.0))])).unwrap();
            sink.emit(&Json::obj(vec![("epoch", Json::num(2.0))])).unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for (i, line) in lines.iter().enumerate() {
            let j = Json::parse(line).unwrap();
            assert_eq!(j.get("epoch").unwrap().as_usize().unwrap(), i + 1);
        }
        let _ = std::fs::remove_file(&path);
    }
}
