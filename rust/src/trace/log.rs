//! Leveled logger replacing scattered `println!`/`eprintln!` call sites.
//!
//! The level is a single process-global `AtomicU8`, lazily initialised from
//! the `PALLAS_LOG` environment variable (error|warn|info|debug|trace,
//! default `info`) and overridable via `--log-level` on the CLI. Checking
//! whether a level is enabled is one relaxed atomic load.
//!
//! Routing preserves the historical output contract: `info` prints bare lines
//! to stdout (so epoch tables and reports look exactly as before), while
//! `error`/`warn` go to stderr with a level prefix. `debug`/`trace` are
//! prefixed on stdout so they are trivially filterable from piped output.

use std::fmt;
use std::sync::atomic::{AtomicU8, Ordering};

// derive(PartialOrd) expands to partial_cmp calls on the discriminant,
// which the clippy.toml ban would otherwise flag.
#[allow(clippy::disallowed_methods)]
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

const UNSET: u8 = 255;
static LEVEL: AtomicU8 = AtomicU8::new(UNSET);

pub fn parse_level(s: &str) -> Option<Level> {
    match s.to_ascii_lowercase().as_str() {
        "error" => Some(Level::Error),
        "warn" | "warning" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        "trace" => Some(Level::Trace),
        _ => None,
    }
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

fn from_u8(v: u8) -> Level {
    match v {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Current level; first call resolves `PALLAS_LOG` (default info).
pub fn level() -> Level {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != UNSET {
        return from_u8(v);
    }
    let init = std::env::var("PALLAS_LOG")
        .ok()
        .and_then(|s| parse_level(&s))
        .unwrap_or(Level::Info);
    LEVEL.store(init as u8, Ordering::Relaxed);
    init
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

pub fn log(l: Level, args: fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    match l {
        Level::Error => eprintln!("error: {args}"),
        Level::Warn => eprintln!("warn: {args}"),
        Level::Info => println!("{args}"),
        Level::Debug => println!("debug: {args}"),
        Level::Trace => println!("trace: {args}"),
    }
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::trace::log::log($crate::trace::log::Level::Error, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::trace::log::log($crate::trace::log::Level::Warn, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::trace::log::log($crate::trace::log::Level::Info, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::trace::log::log($crate::trace::log::Level::Debug, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => {
        $crate::trace::log::log($crate::trace::log::Level::Trace, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_known_levels() {
        assert_eq!(parse_level("error"), Some(Level::Error));
        assert_eq!(parse_level("WARN"), Some(Level::Warn));
        assert_eq!(parse_level("Info"), Some(Level::Info));
        assert_eq!(parse_level("debug"), Some(Level::Debug));
        assert_eq!(parse_level("trace"), Some(Level::Trace));
        assert_eq!(parse_level("loud"), None);
    }

    #[test]
    fn levels_order_error_to_trace() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Debug < Level::Trace);
    }

    #[test]
    fn enabled_respects_set_level() {
        // note: process-global; restore info (the default) afterwards so
        // parallel tests that log keep their output
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
