//! Log-bucketed latency histogram with fixed allocation (HDR-style).
//!
//! Values below 2^SUB_BITS are recorded exactly; above that each power-of-two
//! octave is split into `2^SUB_BITS` sub-buckets, bounding relative error at
//! `2^-SUB_BITS` (~3% with `SUB_BITS = 5`). The bucket array is allocated once
//! (`BUCKETS` u64 slots) and never grows, so recording is a single index + add
//! with no allocation on the hot path.

use std::fmt;
use std::time::Duration;

const SUB_BITS: u32 = 5;
const SUB: usize = 1 << SUB_BITS; // 32 sub-buckets per octave
const OCTAVES: usize = 64 - SUB_BITS as usize; // octaves above the exact range
const BUCKETS: usize = SUB * (OCTAVES + 1);

/// Fixed-allocation log-bucketed histogram over `u64` samples (nanoseconds by
/// convention for stage latencies; raw counts for e.g. splice lag).
#[derive(Clone)]
pub struct LogHistogram {
    buckets: Box<[u64]>,
    count: u64,
    /// Running sum of recorded samples. u128 on purpose: a u64 accumulator
    /// saturates after ~2^64 total (e.g. a few billion near-max samples, or
    /// one `u64::MAX` sample followed by anything), after which `mean()`
    /// silently reports `saturated / count` — a pinned, shrinking lie.
    sum: u128,
    max: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram {
            buckets: vec![0u64; BUCKETS].into_boxed_slice(),
            count: 0,
            sum: 0,
            max: 0,
        }
    }
}

impl fmt::Debug for LogHistogram {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("LogHistogram")
            .field("count", &self.count)
            .field("p50", &self.quantile(0.50))
            .field("p95", &self.quantile(0.95))
            .field("p99", &self.quantile(0.99))
            .field("max", &self.max)
            .finish()
    }
}

impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram::default()
    }

    fn bucket_index(v: u64) -> usize {
        if v < SUB as u64 {
            return v as usize;
        }
        let msb = 63 - v.leading_zeros();
        let octave = msb - SUB_BITS;
        let sub = (v >> octave) as usize & (SUB - 1);
        (((octave as usize) + 1) * SUB + sub).min(BUCKETS - 1)
    }

    /// Representative (midpoint) value for a bucket index.
    fn bucket_mid(i: usize) -> f64 {
        if i < SUB {
            return i as f64;
        }
        let octave = (i / SUB - 1) as u32;
        let sub = (i % SUB) as u64;
        let lo = (SUB as u64 + sub) << octave;
        let width = 1u64 << octave;
        lo as f64 + width as f64 / 2.0
    }

    pub fn record(&mut self, v: u64) {
        self.buckets[Self::bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v as u128;
        self.max = self.max.max(v);
    }

    pub fn record_duration(&mut self, d: Duration) {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        self.record(ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum as f64 / self.count as f64
    }

    /// Quantile estimate (bucket midpoint). `q` in [0, 1]; returns 0 when
    /// empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if c > 0 && cum >= target {
                return Self::bucket_mid(i);
            }
        }
        Self::bucket_mid(BUCKETS - 1)
    }

    pub fn clear(&mut self) {
        self.buckets.iter_mut().for_each(|b| *b = 0);
        self.count = 0;
        self.sum = 0;
        self.max = 0;
    }

    /// Fold another histogram into this one (bucket-wise add). Used to
    /// absorb per-epoch snapshots drained from global recorders (e.g. the
    /// GEMM call histogram) into a stage histogram.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (b, &o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += o;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_below_sub_range() {
        // values < 32 land in their own bucket and quantiles are exact
        let mut h = LogHistogram::new();
        for v in 0..32u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 32);
        for v in 0..32u64 {
            assert_ne!(
                LogHistogram::bucket_index(v),
                LogHistogram::bucket_index(v + 1)
            );
        }
        let mut h2 = LogHistogram::new();
        h2.record(7);
        assert_eq!(h2.quantile(0.5), 7.0);
    }

    #[test]
    fn octave_bucket_boundaries() {
        // 64 and 65 share a bucket (second octave, width 2); 66 does not
        assert_eq!(
            LogHistogram::bucket_index(64),
            LogHistogram::bucket_index(65)
        );
        assert_ne!(
            LogHistogram::bucket_index(65),
            LogHistogram::bucket_index(66)
        );
        // octave transitions are contiguous: 31 -> 32 and 63 -> 64 move up
        assert_eq!(LogHistogram::bucket_index(31) + 1, LogHistogram::bucket_index(32));
        assert_eq!(LogHistogram::bucket_index(63) + 1, LogHistogram::bucket_index(64));
    }

    #[test]
    fn relative_error_bounded() {
        let mut h = LogHistogram::new();
        for &v in &[1_000u64, 10_000, 1_000_000, 123_456_789] {
            h.clear();
            h.record(v);
            let est = h.quantile(0.5);
            let err = (est - v as f64).abs() / v as f64;
            assert!(err < 0.04, "v={v} est={est} err={err}");
        }
    }

    #[test]
    fn quantiles_walk_cumulative_counts() {
        let mut h = LogHistogram::new();
        for v in 1..=100u64 {
            h.record(v * 1000);
        }
        let p50 = h.quantile(0.50);
        let p99 = h.quantile(0.99);
        assert!(p50 > 40_000.0 && p50 < 60_000.0, "p50={p50}");
        assert!(p99 > 90_000.0, "p99={p99}");
        assert!(p99 >= p50);
    }

    #[test]
    fn empty_histogram_reports_zero() {
        let h = LogHistogram::new();
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn huge_values_clamp_to_last_bucket() {
        let mut h = LogHistogram::new();
        h.record(u64::MAX);
        assert_eq!(h.count(), 1);
        assert!(h.quantile(0.5) > 0.0);
    }

    #[test]
    fn merge_adds_counts_sums_and_buckets() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        for v in [100u64, 2_000, 50_000] {
            a.record(v);
        }
        for v in [7u64, 900_000] {
            b.record(v);
        }
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged.count(), 5);
        assert_eq!(merged.max(), 900_000);
        // mean equals the pooled mean of all samples (within bucket-free
        // exact arithmetic: sum is tracked exactly, not bucketed)
        let want = (100.0 + 2_000.0 + 50_000.0 + 7.0 + 900_000.0) / 5.0;
        assert!((merged.mean() - want).abs() < 1e-9);
        // merging an empty histogram changes nothing
        let before = merged.quantile(0.5);
        merged.merge(&LogHistogram::new());
        assert_eq!(merged.count(), 5);
        assert_eq!(merged.quantile(0.5), before);
    }

    #[test]
    fn mean_survives_sum_past_u64_max() {
        // regression: the old u64 accumulator saturated at u64::MAX, so a
        // second sample pinned the sum and mean() decayed toward
        // u64::MAX / count instead of the true average
        let mut h = LogHistogram::new();
        h.record(u64::MAX);
        h.record(u64::MAX);
        let want = u64::MAX as f64; // true mean of two identical samples
        let got = h.mean();
        assert!(
            (got - want).abs() / want < 1e-9,
            "mean must not saturate: got {got}, want {want}"
        );
        // and a saturating boundary mix: MAX then a small sample must
        // average to roughly MAX/2, not (MAX + ~0)/2 == pinned MAX/2 — the
        // distinguishing case is MAX twice above; here just sanity-check
        // monotonicity of the accumulator
        h.record(0);
        assert!(h.mean() < got);
        assert_eq!(h.count(), 3);
    }
}
