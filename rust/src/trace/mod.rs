//! Zero-dependency tracing & telemetry for the PRES pipeline.
//!
//! # Span model
//!
//! A *span* is one closed interval of work on one thread, tagged with a
//! [`Stage`] (PREP, SPLICE, per-lane EXEC, WRITEBACK, commit-queue wait,
//! PREP stall, pool generation barrier) and a stage-specific `arg` (step
//! index, lane id, task count). Spans are recorded into per-thread
//! fixed-capacity seqlock rings ([`span`] module) — the recording thread is
//! the only writer, so pushes are lock-free and allocation-free. Ring
//! wraparound overwrites the oldest spans and is **counted** per thread,
//! never silent. [`export_chrome`] serialises every ring as Chrome
//! `trace_event` JSON (one named row per thread) for `chrome://tracing` /
//! Perfetto; it is driven by `--trace-out <path>` on the CLI.
//!
//! # Clock domain
//!
//! All timestamps are nanosecond offsets from a single process-wide origin
//! `Instant`, pinned the first time tracing starts. `Instant` is monotonic,
//! so spans from different threads order consistently in the exported
//! timeline; there is no wall-clock component and no cross-process meaning.
//!
//! # Overhead contract
//!
//! Disabled (the default), every instrumentation point costs exactly one
//! relaxed atomic load and one branch — no time reads, no stores. The same
//! holds for the telemetry counters behind [`telemetry::metrics_enabled`].
//! `benches/trace_overhead.rs` pins this (`BENCH_trace.json`: traced vs.
//! untraced steps/s at 1/2/4 streams), and the pipeline/stream equivalence
//! suites run with tracing enabled to prove instrumentation never perturbs
//! bit-identical results — tracing only ever *observes* the step stream.
//!
//! Complementing spans, [`hist::LogHistogram`] provides fixed-allocation
//! log-bucketed per-step latency histograms (HDR-style) that
//! `metrics::EpochTimer` aggregates into per-stage p50/p95/p99 for
//! `EpochReport`, and [`telemetry`] holds pipeline-health gauges/counters
//! (PREP channel depth, pool occupancy, GMM clamp events) plus the
//! `--metrics-out` JSONL sink. [`log`] is the leveled logger
//! (`--log-level` / `PALLAS_LOG`) that replaced the scattered `println!`
//! call sites.

pub mod chrome;
pub mod hist;
pub mod log;
pub mod span;
pub mod telemetry;

pub use chrome::{chrome_trace_json, export_chrome};
pub use hist::LogHistogram;
pub use log::Level;
pub use span::{
    clear, enabled, record_span, snapshot, span, start, stop, SpanGuard, SpanRec, Stage,
    ThreadSpans,
};
pub use telemetry::{metrics_enabled, MetricsSink, TelemetrySnapshot};
