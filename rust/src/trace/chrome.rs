//! Chrome `trace_event` JSON export (opens in `chrome://tracing` / Perfetto).
//!
//! Emits the JSON-object form: `{"traceEvents": [...], "displayTimeUnit":
//! "ms"}` with one complete event (`"ph": "X"`) per recorded span and one
//! `thread_name` metadata event (`"ph": "M"`) per registered ring, so PREP,
//! EXEC lanes, pool workers and the coordinator each render as their own
//! named row. Timestamps/durations are microseconds (fractional, from the
//! shared nanosecond clock domain).

use anyhow::{Context, Result};

use super::span::{snapshot, ThreadSpans};
use crate::util::json::Json;

/// Build the full Chrome trace document from the current span rings.
pub fn chrome_trace_json() -> Json {
    build(&snapshot())
}

/// Pure document builder over an explicit snapshot. Split from
/// [`chrome_trace_json`] so escaping/structure tests can feed synthetic
/// rings instead of racing other tests for the global span state.
fn build(snaps: &[ThreadSpans]) -> Json {
    let mut events = Vec::new();
    for t in snaps {
        events.push(Json::obj(vec![
            ("name", Json::str("thread_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(1.0)),
            ("tid", Json::num(t.tid as f64)),
            (
                "args",
                Json::obj(vec![("name", Json::str(t.thread.clone()))]),
            ),
        ]));
        for s in &t.spans {
            events.push(Json::obj(vec![
                ("name", Json::str(s.stage.name())),
                ("cat", Json::str("pres")),
                ("ph", Json::str("X")),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(t.tid as f64)),
                ("ts", Json::num(s.start_ns as f64 / 1_000.0)),
                ("dur", Json::num(s.dur_ns as f64 / 1_000.0)),
                ("args", Json::obj(vec![("arg", Json::num(s.arg as f64))])),
            ]));
        }
    }
    let dropped: u64 = snaps.iter().map(|t| t.dropped).sum();
    Json::obj(vec![
        ("traceEvents", Json::arr(events)),
        ("displayTimeUnit", Json::str("ms")),
        (
            "otherData",
            Json::obj(vec![("dropped_spans", Json::num(dropped as f64))]),
        ),
    ])
}

/// Write the trace document to `path`, creating missing parent
/// directories. Warns (does not fail the run) when ring wraparound
/// dropped spans.
pub fn export_chrome(path: &str) -> Result<()> {
    let snaps = snapshot();
    let dropped: u64 = snaps.iter().map(|t| t.dropped).sum();
    if dropped > 0 {
        crate::log_warn!("trace ring wrapped: {dropped} spans dropped from {path}");
    }
    let doc = build(&snaps);
    crate::util::ensure_parent_dir(path)?;
    std::fs::write(path, doc.to_string())
        .with_context(|| format!("writing trace file {path}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::span::{SpanRec, Stage};

    fn ring(name: &str, tid: u64, spans: Vec<SpanRec>) -> ThreadSpans {
        ThreadSpans {
            thread: name.to_string(),
            tid,
            dropped: 0,
            spans,
        }
    }

    #[test]
    fn empty_trace_is_valid_chrome_json() {
        let doc = chrome_trace_json();
        let text = doc.to_string();
        let parsed = Json::parse(&text).unwrap();
        assert!(parsed.get("traceEvents").unwrap().as_arr().is_ok());
        assert_eq!(
            parsed.get("displayTimeUnit").unwrap().as_str().unwrap(),
            "ms"
        );
    }

    #[test]
    fn hostile_thread_names_survive_a_serialize_parse_roundtrip() {
        // every character class the escaper must handle: quotes,
        // backslashes, newline/tab/CR, and a bare control byte
        let nasty = [
            "quote\"in\"name",
            "back\\slash\\path",
            "multi\nline\tname\r",
            "ctrl\u{1}byte",
            "unicode π λ — name",
        ];
        let snaps: Vec<ThreadSpans> = nasty
            .iter()
            .enumerate()
            .map(|(i, n)| ring(n, i as u64 + 1, vec![]))
            .collect();
        let text = build(&snaps).to_string();
        let parsed = Json::parse(&text).unwrap_or_else(|e| {
            panic!("escaper emitted unparseable JSON: {e}\n{text}")
        });
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), nasty.len());
        for (ev, want) in events.iter().zip(nasty) {
            assert_eq!(ev.get("ph").unwrap().as_str().unwrap(), "M");
            let got = ev
                .get("args")
                .unwrap()
                .get("name")
                .unwrap()
                .as_str()
                .unwrap();
            assert_eq!(got, want, "thread name mangled by escape/parse");
        }
    }

    #[test]
    fn span_events_carry_scaled_timestamps_and_args() {
        let snaps = vec![ring(
            "exec-0",
            9,
            vec![SpanRec {
                stage: Stage::Exec,
                start_ns: 1_500,
                dur_ns: 2_500,
                arg: 42,
            }],
        )];
        let parsed = Json::parse(&build(&snaps).to_string()).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // one metadata event + one complete event
        assert_eq!(events.len(), 2);
        let ev = &events[1];
        assert_eq!(ev.get("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(ev.get("name").unwrap().as_str().unwrap(), Stage::Exec.name());
        assert_eq!(ev.get("tid").unwrap().as_f64().unwrap(), 9.0);
        // nanoseconds scale to fractional microseconds
        assert_eq!(ev.get("ts").unwrap().as_f64().unwrap(), 1.5);
        assert_eq!(ev.get("dur").unwrap().as_f64().unwrap(), 2.5);
        let arg = ev.get("args").unwrap().get("arg").unwrap();
        assert_eq!(arg.as_f64().unwrap(), 42.0);
    }

    #[test]
    fn dropped_span_counts_aggregate_across_rings() {
        let mut a = ring("a", 1, vec![]);
        let mut b = ring("b", 2, vec![]);
        a.dropped = 3;
        b.dropped = 4;
        let parsed = Json::parse(&build(&[a, b]).to_string()).unwrap();
        let dropped = parsed
            .get("otherData")
            .unwrap()
            .get("dropped_spans")
            .unwrap()
            .as_f64()
            .unwrap();
        assert_eq!(dropped, 7.0);
    }

    #[test]
    fn export_creates_missing_parent_directories() {
        let root =
            std::env::temp_dir().join(format!("pres-chrome-{}", std::process::id()));
        let path = root.join("nested/deeper/trace.json");
        let path = path.to_str().unwrap();
        export_chrome(path).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        assert!(Json::parse(&text).is_ok());
        std::fs::remove_dir_all(&root).unwrap();
    }
}
