//! Chrome `trace_event` JSON export (opens in `chrome://tracing` / Perfetto).
//!
//! Emits the JSON-object form: `{"traceEvents": [...], "displayTimeUnit":
//! "ms"}` with one complete event (`"ph": "X"`) per recorded span and one
//! `thread_name` metadata event (`"ph": "M"`) per registered ring, so PREP,
//! EXEC lanes, pool workers and the coordinator each render as their own
//! named row. Timestamps/durations are microseconds (fractional, from the
//! shared nanosecond clock domain).

use anyhow::{Context, Result};

use super::span::snapshot;
use crate::util::json::Json;

/// Build the full Chrome trace document from the current span rings.
pub fn chrome_trace_json() -> Json {
    let snaps = snapshot();
    let mut events = Vec::new();
    for t in &snaps {
        events.push(Json::obj(vec![
            ("name", Json::str("thread_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(1.0)),
            ("tid", Json::num(t.tid as f64)),
            (
                "args",
                Json::obj(vec![("name", Json::str(t.thread.clone()))]),
            ),
        ]));
        for s in &t.spans {
            events.push(Json::obj(vec![
                ("name", Json::str(s.stage.name())),
                ("cat", Json::str("pres")),
                ("ph", Json::str("X")),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(t.tid as f64)),
                ("ts", Json::num(s.start_ns as f64 / 1_000.0)),
                ("dur", Json::num(s.dur_ns as f64 / 1_000.0)),
                ("args", Json::obj(vec![("arg", Json::num(s.arg as f64))])),
            ]));
        }
    }
    let dropped: u64 = snaps.iter().map(|t| t.dropped).sum();
    Json::obj(vec![
        ("traceEvents", Json::arr(events)),
        ("displayTimeUnit", Json::str("ms")),
        (
            "otherData",
            Json::obj(vec![("dropped_spans", Json::num(dropped as f64))]),
        ),
    ])
}

/// Write the trace document to `path`. Warns (does not fail the run) when
/// ring wraparound dropped spans.
pub fn export_chrome(path: &str) -> Result<()> {
    let dropped: u64 = snapshot().iter().map(|t| t.dropped).sum();
    if dropped > 0 {
        crate::log_warn!("trace ring wrapped: {dropped} spans dropped from {path}");
    }
    let doc = chrome_trace_json();
    std::fs::write(path, doc.to_string())
        .with_context(|| format!("writing trace file {path}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_trace_is_valid_chrome_json() {
        let doc = chrome_trace_json();
        let text = doc.to_string();
        let parsed = Json::parse(&text).unwrap();
        assert!(parsed.get("traceEvents").unwrap().as_arr().is_ok());
        assert_eq!(
            parsed.get("displayTimeUnit").unwrap().as_str().unwrap(),
            "ms"
        );
    }
}
