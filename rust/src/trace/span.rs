//! Thread-local, lock-free span recorders.
//!
//! Each instrumented thread lazily registers a fixed-capacity `SpanBuf` (a
//! seqlock-style SPSC ring: the owning thread is the only writer, exporters
//! read concurrently and skip torn slots). Recording a span when tracing is
//! disabled costs exactly one relaxed atomic load; when enabled it is two
//! `Instant` reads plus five relaxed/release stores into a pre-allocated
//! slot — no locks, no allocation, no syscalls on the hot path.
//!
//! Timestamps are nanosecond offsets from a single process-wide origin
//! `Instant` (captured the first time tracing starts), so spans from
//! different threads share one monotonic clock domain and interleave
//! correctly in the exported timeline.
//!
//! When a ring wraps, the oldest spans are overwritten and the loss is
//! *counted* (`ThreadSpans::dropped`), never silent. `clear()` and
//! `snapshot()` are only guaranteed exact while instrumented threads are
//! quiescent (between epochs / after join); a concurrent snapshot is still
//! memory-safe and simply skips slots that are mid-write.

// Sanctioned clock module: raw `Instant::now()` IS the product here (span
// timestamps), and the stress tests spawn their own reader threads.
#![allow(clippy::disallowed_methods)]

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Pipeline stage a span belongs to. One Chrome-trace track name per stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u32)]
pub enum Stage {
    /// Background batch preparation (sampling + packing) on `pres-prep`.
    Prep = 0,
    /// Memory splice of a prepared batch into the live slot (coordinator).
    Splice = 1,
    /// One training step execution (inline or on a `pres-exec-{s}` lane).
    Exec = 2,
    /// Memory/GMM writeback after a committed step (coordinator).
    Writeback = 3,
    /// Coordinator blocked on the ordered commit queue.
    CommitWait = 4,
    /// Coordinator blocked waiting for the PREP channel.
    PrepStall = 5,
    /// One worker-pool generation (scatter/gather barrier to barrier).
    PoolBarrier = 6,
}

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::Prep => "prep",
            Stage::Splice => "splice",
            Stage::Exec => "exec",
            Stage::Writeback => "writeback",
            Stage::CommitWait => "commit_wait",
            Stage::PrepStall => "prep_stall",
            Stage::PoolBarrier => "pool_barrier",
        }
    }

    fn from_u32(v: u32) -> Option<Stage> {
        Some(match v {
            0 => Stage::Prep,
            1 => Stage::Splice,
            2 => Stage::Exec,
            3 => Stage::Writeback,
            4 => Stage::CommitWait,
            5 => Stage::PrepStall,
            6 => Stage::PoolBarrier,
            _ => return None,
        })
    }
}

/// Ring capacity per thread (power of two). 16k spans ≈ several epochs of
/// per-step spans on the profiles we trace; overflow is counted, not fatal.
const CAP: usize = 16 * 1024;

struct Slot {
    /// Seqlock word: `2*h + 1` while the entry for head value `h` is being
    /// written, `2*(h+1)` once it is complete. Readers require the latter.
    seq: AtomicU64,
    start_ns: AtomicU64,
    dur_ns: AtomicU64,
    stage: AtomicU32,
    arg: AtomicU64,
}

/// Per-thread span ring. The owning thread writes; exporters read.
pub struct SpanBuf {
    tid: u64,
    name: String,
    head: AtomicU64,
    slots: Box<[Slot]>,
}

impl SpanBuf {
    fn new(tid: u64, name: String) -> SpanBuf {
        let slots: Vec<Slot> = (0..CAP)
            .map(|_| Slot {
                seq: AtomicU64::new(0),
                start_ns: AtomicU64::new(0),
                dur_ns: AtomicU64::new(0),
                stage: AtomicU32::new(0),
                arg: AtomicU64::new(0),
            })
            .collect();
        SpanBuf {
            tid,
            name,
            head: AtomicU64::new(0),
            slots: slots.into_boxed_slice(),
        }
    }

    fn push(&self, stage: Stage, start_ns: u64, dur_ns: u64, arg: u64) {
        let head = self.head.load(Ordering::Relaxed);
        let slot = &self.slots[(head as usize) & (CAP - 1)];
        slot.seq.store(2 * head + 1, Ordering::Release);
        slot.start_ns.store(start_ns, Ordering::Relaxed);
        slot.dur_ns.store(dur_ns, Ordering::Relaxed);
        slot.stage.store(stage as u32, Ordering::Relaxed);
        slot.arg.store(arg, Ordering::Relaxed);
        slot.seq.store(2 * (head + 1), Ordering::Release);
        self.head.store(head + 1, Ordering::Release);
    }

    fn snapshot(&self) -> ThreadSpans {
        let head = self.head.load(Ordering::Acquire);
        let lo = head.saturating_sub(CAP as u64);
        let mut spans = Vec::with_capacity((head - lo) as usize);
        for h in lo..head {
            let slot = &self.slots[(h as usize) & (CAP - 1)];
            if slot.seq.load(Ordering::Acquire) != 2 * (h + 1) {
                continue; // torn or already overwritten
            }
            let start_ns = slot.start_ns.load(Ordering::Relaxed);
            let dur_ns = slot.dur_ns.load(Ordering::Relaxed);
            let stage = slot.stage.load(Ordering::Relaxed);
            let arg = slot.arg.load(Ordering::Relaxed);
            if slot.seq.load(Ordering::Acquire) != 2 * (h + 1) {
                continue; // overwritten while we read the fields
            }
            if let Some(stage) = Stage::from_u32(stage) {
                spans.push(SpanRec {
                    stage,
                    start_ns,
                    dur_ns,
                    arg,
                });
            }
        }
        spans.sort_by_key(|s| s.start_ns);
        ThreadSpans {
            thread: self.name.clone(),
            tid: self.tid,
            dropped: head.saturating_sub(CAP as u64),
            spans,
        }
    }
}

/// One completed span as read back from a ring.
#[derive(Clone, Copy, Debug)]
pub struct SpanRec {
    pub stage: Stage,
    pub start_ns: u64,
    pub dur_ns: u64,
    /// Stage-specific payload (step index, lane id, task count, ...).
    pub arg: u64,
}

/// All spans recovered from one thread's ring, oldest first.
#[derive(Clone, Debug)]
pub struct ThreadSpans {
    pub thread: String,
    pub tid: u64,
    /// Spans overwritten by ring wraparound (counted, never silent).
    pub dropped: u64,
    pub spans: Vec<SpanRec>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static ORIGIN: OnceLock<Instant> = OnceLock::new();
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

fn registry() -> &'static Mutex<Vec<Arc<SpanBuf>>> {
    static REGISTRY: OnceLock<Mutex<Vec<Arc<SpanBuf>>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

fn origin() -> Instant {
    *ORIGIN.get_or_init(Instant::now)
}

thread_local! {
    static LOCAL: RefCell<Option<Arc<SpanBuf>>> = const { RefCell::new(None) };
}

fn with_buf(f: impl FnOnce(&SpanBuf)) {
    LOCAL.with(|cell| {
        let mut opt = cell.borrow_mut();
        if opt.is_none() {
            let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            let name = std::thread::current()
                .name()
                .unwrap_or("thread")
                .to_string();
            let buf = Arc::new(SpanBuf::new(tid, name));
            registry().lock().unwrap().push(buf.clone());
            *opt = Some(buf);
        }
        f(opt.as_ref().unwrap());
    });
}

/// Is span recording on? One relaxed atomic load — this is the entire cost
/// of instrumentation when tracing is disabled.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn span recording on (pins the clock origin on first call).
pub fn start() {
    origin();
    ENABLED.store(true, Ordering::Relaxed);
}

pub fn stop() {
    ENABLED.store(false, Ordering::Relaxed);
}

/// Drop all recorded spans. Only exact while instrumented threads are
/// quiescent (the drop counter restarts from zero as well).
pub fn clear() {
    for buf in registry().lock().unwrap().iter() {
        buf.head.store(0, Ordering::Release);
    }
}

/// Record an already-measured interval on the calling thread's ring.
#[inline]
pub fn record_span(stage: Stage, start: Instant, end: Instant, arg: u64) {
    if !enabled() {
        return;
    }
    let o = origin();
    let start_ns = start.saturating_duration_since(o).as_nanos() as u64;
    let dur_ns = end.saturating_duration_since(start).as_nanos() as u64;
    with_buf(|b| b.push(stage, start_ns, dur_ns, arg));
}

/// RAII span: measures from construction to drop. When tracing is disabled
/// this holds `None` and drop is a no-op.
pub struct SpanGuard {
    live: Option<(Instant, Stage, u64)>,
}

#[inline]
pub fn span(stage: Stage, arg: u64) -> SpanGuard {
    if enabled() {
        SpanGuard {
            live: Some((Instant::now(), stage, arg)),
        }
    } else {
        SpanGuard { live: None }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some((t0, stage, arg)) = self.live.take() {
            record_span(stage, t0, Instant::now(), arg);
        }
    }
}

/// Read back every registered thread's spans (rings are left untouched).
pub fn snapshot() -> Vec<ThreadSpans> {
    registry()
        .lock()
        .unwrap()
        .iter()
        .map(|b| b.snapshot())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    // span recording is process-global; serialize the tests that toggle it
    fn lock() -> std::sync::MutexGuard<'static, ()> {
        static GATE: Mutex<()> = Mutex::new(());
        GATE.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn my_spans() -> ThreadSpans {
        let mut out = None;
        LOCAL.with(|cell| {
            let opt = cell.borrow();
            out = opt.as_ref().map(|b| b.snapshot());
        });
        out.expect("thread has no span buffer yet")
    }

    #[test]
    fn disabled_records_nothing() {
        let _g = lock();
        stop();
        clear();
        let t = Instant::now();
        record_span(Stage::Exec, t, t + Duration::from_micros(5), 1);
        drop(span(Stage::Prep, 0));
        // no buffer may even exist for this thread; if one does it is empty
        LOCAL.with(|cell| {
            if let Some(b) = cell.borrow().as_ref() {
                assert_eq!(b.snapshot().spans.len(), 0);
            }
        });
    }

    #[test]
    fn records_and_reads_back_in_order() {
        let _g = lock();
        start();
        clear();
        let t0 = Instant::now();
        record_span(Stage::Splice, t0, t0 + Duration::from_micros(3), 7);
        record_span(
            Stage::Exec,
            t0 + Duration::from_micros(3),
            t0 + Duration::from_micros(9),
            8,
        );
        let got = my_spans();
        stop();
        assert_eq!(got.dropped, 0);
        assert_eq!(got.spans.len(), 2);
        assert_eq!(got.spans[0].stage, Stage::Splice);
        assert_eq!(got.spans[0].arg, 7);
        assert_eq!(got.spans[1].stage, Stage::Exec);
        assert!(got.spans[0].start_ns <= got.spans[1].start_ns);
        clear();
    }

    #[test]
    fn wraparound_drops_are_counted_not_silent() {
        let _g = lock();
        start();
        clear();
        let t0 = Instant::now();
        let extra = 37u64;
        for i in 0..(CAP as u64 + extra) {
            record_span(Stage::Exec, t0, t0 + Duration::from_nanos(1), i);
        }
        let got = my_spans();
        stop();
        assert_eq!(got.dropped, extra);
        assert_eq!(got.spans.len(), CAP);
        // oldest surviving span is the one right after the dropped window
        assert!(got.spans.iter().any(|s| s.arg == extra));
        assert!(!got.spans.iter().any(|s| s.arg < extra));
        clear();
    }

    #[test]
    fn spans_from_multiple_threads_land_in_separate_rings() {
        let _g = lock();
        start();
        clear();
        let t0 = Instant::now();
        record_span(Stage::Splice, t0, t0 + Duration::from_micros(1), 0);
        // lint: allow(thread-discipline) — per-thread ring registration is the subject under test
        let handle = std::thread::Builder::new()
            .name("trace-test-worker".into())
            .spawn(move || {
                record_span(Stage::Exec, t0, t0 + Duration::from_micros(2), 1);
            })
            .unwrap();
        handle.join().unwrap();
        let snaps = snapshot();
        stop();
        let worker = snaps
            .iter()
            .find(|t| t.thread == "trace-test-worker")
            .expect("worker ring registered");
        assert!(worker.spans.iter().any(|s| s.stage == Stage::Exec));
        let tids: std::collections::BTreeSet<u64> = snaps.iter().map(|t| t.tid).collect();
        assert_eq!(tids.len(), snaps.len(), "tids are unique per thread");
        clear();
    }

    /// Seqlock stress: one writer hammers its ring (several wraps) while
    /// two readers snapshot concurrently. Every span carries
    /// `arg == dur_ns + 7`, and consecutive overwrites of any slot differ
    /// in `dur_ns` (the cycle length 997 is coprime to the ring size), so
    /// a torn read — fields mixed across two generations of a slot — would
    /// break the relation. The seq protocol must instead *skip* slots
    /// caught mid-write, so every span a reader sees satisfies it.
    #[test]
    fn concurrent_snapshots_never_observe_torn_spans() {
        let _g = lock();
        start();
        clear();
        let t0 = Instant::now();
        // Miri runs threads with a large interpretive slowdown; a couple
        // thousand pushes still races the readers without timing out.
        let rounds: usize = if cfg!(miri) { 2_000 } else { 120_000 };
        // seed one span so this thread's ring exists and we learn its tid
        record_span(Stage::Exec, t0, t0 + Duration::from_nanos(1), 8);
        let writer_tid = my_spans().tid;
        let done = Arc::new(AtomicBool::new(false));
        let mut readers = Vec::new();
        for r in 0..2 {
            let done = done.clone();
            // lint: allow(thread-discipline) — seqlock readers must race the writer for real
            let h = std::thread::Builder::new()
                .name(format!("seqlock-reader-{r}"))
                .spawn(move || {
                    let mut seen = 0u64;
                    while !done.load(Ordering::Relaxed) {
                        for t in snapshot() {
                            if t.tid != writer_tid {
                                continue;
                            }
                            for s in &t.spans {
                                assert_eq!(
                                    s.arg,
                                    s.dur_ns + 7,
                                    "torn span read: dur_ns={} arg={}",
                                    s.dur_ns,
                                    s.arg
                                );
                                seen += 1;
                            }
                        }
                    }
                    seen
                })
                .unwrap();
            readers.push(h);
        }
        for i in 0..rounds {
            let d = (i % 997) as u64 + 1;
            record_span(Stage::Exec, t0, t0 + Duration::from_nanos(d), d + 7);
        }
        done.store(true, Ordering::Relaxed);
        let observed: u64 = readers.into_iter().map(|h| h.join().unwrap()).sum();
        stop();
        clear();
        assert!(observed > 0, "readers never observed a span — vacuous stress");
    }
}
