//! Dynamic node classification (paper Table 2).
//!
//! Protocol (following TGN/JODIE): the MDGNN encoder is frozen; the dynamic
//! source embeddings h_src(t) collected during stream replay are paired
//! with the dynamic node labels (state flips), split chronologically, and a
//! small MLP head — the `clf_train`/`clf_eval` artifacts — is trained on
//! them. We report ROC-AUC on the held-out tail.

use anyhow::Result;
use xla::Literal;

use crate::metrics::ranking::roc_auc;
use crate::model::ModelState;
use crate::runtime::engine::{fetch_f32, lit_f32, lit_scalar};
use crate::runtime::Engine;

/// Train the classification head on `rows` = (embedding, label) in stream
/// order; returns test ROC-AUC over the chronological last 30%.
pub fn train_and_auc(engine: &Engine, rows: &[(Vec<f32>, f32)], seed: u64) -> Result<f64> {
    let dims = engine.manifest().dims;
    let b = dims.clf_batch;
    if rows.len() < 8 {
        return Ok(f64::NAN); // not enough labeled events to measure
    }
    let split = rows.len() * 70 / 100;
    let (train_rows, test_rows) = rows.split_at(split);

    let train_step = engine.step("clf", b, "train")?;
    let eval_step = engine.step("clf", b, "eval")?;
    let mut state = ModelState::init(engine, "clf", seed)?;

    // epochs over padded minibatches
    let mut emb = vec![0.0f32; b * dims.d_emb];
    let mut labels = vec![0.0f32; b];
    let mut weight = vec![0.0f32; b];
    const EPOCHS: usize = 30;
    for _ in 0..EPOCHS {
        for chunk in train_rows.chunks(b) {
            emb.iter_mut().for_each(|x| *x = 0.0);
            labels.iter_mut().for_each(|x| *x = 0.0);
            weight.iter_mut().for_each(|x| *x = 0.0);
            for (j, (e, l)) in chunk.iter().enumerate() {
                emb[j * dims.d_emb..(j + 1) * dims.d_emb].copy_from_slice(e);
                labels[j] = *l;
                weight[j] = 1.0;
            }
            let data = [
                lit_f32(&emb, &[b, dims.d_emb])?,
                lit_f32(&labels, &[b])?,
                lit_f32(&weight, &[b])?,
                lit_scalar(1e-2)?,
                lit_scalar((state.step + 1) as f32)?,
            ];
            let args: Vec<&Literal> = state
                .params
                .iter()
                .chain(state.adam_m.iter())
                .chain(state.adam_v.iter())
                .chain(data.iter())
                .collect();
            let mut outputs = train_step.run(&args)?;
            state.absorb_outputs(&mut outputs);
        }
    }

    // score the test tail
    let mut scores = Vec::with_capacity(test_rows.len());
    let mut bools = Vec::with_capacity(test_rows.len());
    let mut logits = vec![0.0f32; b];
    for chunk in test_rows.chunks(b) {
        emb.iter_mut().for_each(|x| *x = 0.0);
        for (j, (e, _)) in chunk.iter().enumerate() {
            emb[j * dims.d_emb..(j + 1) * dims.d_emb].copy_from_slice(e);
        }
        let data = [lit_f32(&emb, &[b, dims.d_emb])?];
        let args: Vec<&Literal> = state.params.iter().chain(data.iter()).collect();
        let outputs = eval_step.run(&args)?;
        fetch_f32(&outputs[0], &mut logits)?;
        for (j, (_, l)) in chunk.iter().enumerate() {
            scores.push(logits[j]);
            bools.push(*l > 0.5);
        }
    }
    if bools.iter().all(|&x| x) || bools.iter().all(|&x| !x) {
        return Ok(f64::NAN); // degenerate test labels
    }
    Ok(roc_auc(&scores, &bools))
}
