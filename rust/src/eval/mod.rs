//! Downstream-task evaluation pipelines (beyond the in-loop link AP).

pub mod nodeclf;
