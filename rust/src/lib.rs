//! # PRES — scalable memory-based dynamic graph neural network training
//!
//! Rust reproduction of *PRES: Toward Scalable Memory-Based Dynamic Graph
//! Neural Networks* (Su, Zou & Wu, ICLR 2024). This crate is the L3
//! coordinator of a three-layer stack (see `DESIGN.md`):
//!
//! * **L3 (this crate)** — temporal-batch scheduling, pending-set analysis,
//!   the vertex memory store, the PRES GMM prediction filter, samplers,
//!   metrics, and the training orchestrator driving AOT-compiled XLA
//!   executables through PJRT. Training runs as a staged pipeline
//!   (`pipeline/`): a background thread PREPs future batches (sampling +
//!   pure tensor assembly) while the coordinator thread SPLICEs memory
//!   rows, EXECs the XLA step, and WRITEs memory back — hiding host
//!   assembly behind device execution (MSPipe/DistTGL-style overlap, which
//!   compounds with PRES's larger temporal batches).
//! * **L2 (python/compile/model.py)** — MDGNN encoders (TGN/JODIE/APAN)
//!   with the PRES correction + memory-coherence objective, lowered once
//!   to `artifacts/*.hlo.txt`.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the step's hot
//!   spots, lowered inside the L2 graphs.
//!
//! Python never runs on the training path: `make artifacts` is the only
//! python invocation; everything else is this crate.
//!
//! ## Quickstart
//!
//! ```no_run
//! use pres::config::ExperimentConfig;
//! use pres::training::Trainer;
//!
//! let cfg = ExperimentConfig::default_with("wiki", "tgn", 200, true);
//! let mut trainer = Trainer::from_config(&cfg).unwrap();
//! let report = trainer.run().unwrap();
//! println!("val AP = {:.4}", report.best_val_ap);
//! ```

pub mod batching;
pub mod config;
pub mod datagen;
pub mod eval;
pub mod figures;
pub mod graph;
pub mod lint;
pub mod memory;
pub mod metrics;
pub mod model;
pub mod pipeline;
pub mod runtime;
pub mod sampler;
pub mod tables;
pub mod trace;
pub mod training;
pub mod util;
pub mod verify;

/// Crate-wide result alias (anyhow is the only error dependency available
/// in the offline registry snapshot).
pub type Result<T> = anyhow::Result<T>;
