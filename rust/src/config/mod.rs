//! Experiment configuration: JSON-loadable, CLI-overridable, with defaults
//! mirroring the paper's protocol (beta = 0.1, Adam 1e-3, 5 trials).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Everything needed to reproduce one training run.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Dataset profile name: wiki | reddit | mooc | lastfm | gdelt | tiny.
    pub dataset: String,
    /// Encoder: tgn | jodie | apan.
    pub model: String,
    /// Temporal batch size (must be one of the compiled artifact sizes).
    pub batch_size: usize,
    /// Enable PRES (prediction-correction + coherence smoothing).
    pub pres: bool,
    /// Coherence-smoothing strength (paper uses 0.1).
    pub beta: f32,
    pub epochs: usize,
    pub lr: f32,
    pub seed: u64,
    /// Fraction of vertices carrying full GMM trackers (1.0 = all; the
    /// paper's anchor-set heuristic for memory-constrained deployments).
    pub anchor_fraction: f32,
    /// Directory with HLO artifacts + manifest.json.
    pub artifacts_dir: String,
    /// Evaluate on val split every n epochs (0 = only at the end).
    pub eval_every: usize,
    /// Overlap next-batch assembly with the current PJRT call.
    pub prefetch: bool,
    /// Scale events generated (1.0 = profile default; figures use < 1 for
    /// quick sweeps).
    pub data_scale: f32,
}

impl ExperimentConfig {
    pub fn default_with(dataset: &str, model: &str, batch_size: usize, pres: bool) -> Self {
        ExperimentConfig {
            dataset: dataset.to_string(),
            model: model.to_string(),
            batch_size,
            pres,
            beta: if pres { 0.1 } else { 0.0 },
            epochs: 10,
            lr: 1e-3,
            seed: 0,
            anchor_fraction: 1.0,
            artifacts_dir: "artifacts".to_string(),
            eval_every: 0,
            prefetch: true,
            data_scale: 1.0,
        }
    }

    pub fn from_json_file(path: &Path) -> Result<Self> {
        let j = Json::parse_file(path)?;
        Self::from_json(&j).with_context(|| format!("config {}", path.display()))
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut cfg = Self::default_with(
            j.get("dataset")?.as_str()?,
            j.get("model")?.as_str()?,
            j.get("batch_size")?.as_usize()?,
            j.opt("pres").map(|v| v.as_bool()).transpose()?.unwrap_or(false),
        );
        if let Some(v) = j.opt("beta") {
            cfg.beta = v.as_f32()?;
        }
        if let Some(v) = j.opt("epochs") {
            cfg.epochs = v.as_usize()?;
        }
        if let Some(v) = j.opt("lr") {
            cfg.lr = v.as_f32()?;
        }
        if let Some(v) = j.opt("seed") {
            cfg.seed = v.as_u64()?;
        }
        if let Some(v) = j.opt("anchor_fraction") {
            cfg.anchor_fraction = v.as_f32()?;
        }
        if let Some(v) = j.opt("artifacts_dir") {
            cfg.artifacts_dir = v.as_str()?.to_string();
        }
        if let Some(v) = j.opt("eval_every") {
            cfg.eval_every = v.as_usize()?;
        }
        if let Some(v) = j.opt("prefetch") {
            cfg.prefetch = v.as_bool()?;
        }
        if let Some(v) = j.opt("data_scale") {
            cfg.data_scale = v.as_f32()?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if !["tgn", "jodie", "apan"].contains(&self.model.as_str()) {
            bail!("unknown model '{}'", self.model);
        }
        if self.batch_size == 0 {
            bail!("batch_size must be positive");
        }
        if !(0.0..=1.0).contains(&self.anchor_fraction) {
            bail!("anchor_fraction must be in [0, 1]");
        }
        if self.beta < 0.0 {
            bail!("beta must be non-negative");
        }
        if !(self.data_scale > 0.0) {
            bail!("data_scale must be positive");
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dataset", Json::str(&self.dataset)),
            ("model", Json::str(&self.model)),
            ("batch_size", Json::num(self.batch_size as f64)),
            ("pres", Json::Bool(self.pres)),
            ("beta", Json::num(self.beta as f64)),
            ("epochs", Json::num(self.epochs as f64)),
            ("lr", Json::num(self.lr as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("anchor_fraction", Json::num(self.anchor_fraction as f64)),
            ("artifacts_dir", Json::str(&self.artifacts_dir)),
            ("eval_every", Json::num(self.eval_every as f64)),
            ("prefetch", Json::Bool(self.prefetch)),
            ("data_scale", Json::num(self.data_scale as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let cfg = ExperimentConfig::default_with("wiki", "tgn", 200, true);
        let j = cfg.to_json();
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.dataset, "wiki");
        assert_eq!(back.batch_size, 200);
        assert!(back.pres);
        assert_eq!(back.beta, 0.1);
    }

    #[test]
    fn validation_rejects_bad_values() {
        let mut cfg = ExperimentConfig::default_with("wiki", "tgn", 200, false);
        cfg.model = "gpt".into();
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default_with("wiki", "tgn", 200, false);
        cfg.anchor_fraction = 1.5;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn pres_default_beta() {
        assert_eq!(ExperimentConfig::default_with("w", "tgn", 1, true).beta, 0.1);
        assert_eq!(ExperimentConfig::default_with("w", "tgn", 1, false).beta, 0.0);
    }
}
