//! Experiment configuration: JSON-loadable, CLI-overridable, with defaults
//! mirroring the paper's protocol (beta = 0.1, Adam 1e-3, 5 trials).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Staged-pipeline knobs (see `pipeline/` for the stage diagram).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PipelineConfig {
    /// How many batches ahead the background PREP thread may run.
    /// 0 = fully sequential legacy loop (PREP inline on the coordinator);
    /// 1 (default) overlaps PREP with execution and stays bit-identical to
    /// the sequential path.
    pub depth: usize,
    /// MSPipe-style bounded staleness for SPLICE: how many commits the
    /// memory view a splice reads may lag behind. 0 (default) keeps every
    /// splice exact — and results bit-identical to sequential training;
    /// k > 0 lets the coordinator pre-splice up to k future batches before
    /// the in-flight write-back lands. NOTE: with today's synchronous
    /// single-stream EXEC this is perf-neutral vs raising `depth` (it only
    /// reorders coordinator work); it becomes a real overlap lever with
    /// multi-stream execution (ROADMAP) — leave at 0 unless studying
    /// staleness effects on quality.
    pub bounded_staleness: usize,
    /// Lanes in the trainer's persistent worker pool (sharded
    /// gather/scatter fan-out + parallel PREP). 0 (default) shares the
    /// auto-sized process pool (one lane per core); 1 runs every stage
    /// fully serial with zero handoff; N >= 2 spawns a dedicated N-lane
    /// pool at trainer construction. Results are bit-identical for every
    /// value — the pool moves work across cores, never values.
    pub pool_workers: usize,
    /// EXEC stream lanes. 1 (default) runs every step inline on the
    /// coordinator (the legacy loop). N >= 2 spawns N executor lanes
    /// (`pipeline/stream.rs`) so a step executes off the coordinator while
    /// it commits the previous write-back, computes metrics and pre-splices
    /// the staleness window — requires `bounded_staleness >= 1` (the
    /// staleness window is what licenses splicing batch t+1 before step t
    /// commits) and the host EXEC backend (PJRT handles are not Send).
    /// Results are bit-identical for every stream count: the commit queue
    /// applies write-backs strictly in plan order and each step still
    /// consumes the previous step's parameters. At `param_staleness = 0`
    /// that exact parameter chain also means at most ONE step is ever
    /// mid-flight, so N > 2 adds only parked lane threads over N = 2;
    /// lanes become a real scaling dimension once `param_staleness >= 1`
    /// relaxes the chain (DistTGL-style).
    pub exec_streams: usize,
    /// DistTGL-style bounded PARAMETER staleness for multi-stream EXEC.
    /// 0 (default) keeps the exact parameter chain: step t consumes step
    /// t-1's updated parameters, at most one step mid-flight, results
    /// bit-identical to the serial staleness-k loop. p >= 1 lets the
    /// coordinator keep a window of `min(p, exec_streams - 1) + 1` steps
    /// genuinely in flight by cloning the parameter bank into each
    /// submitted job: lane j runs its step against parameters at most
    /// `min(p, exec_streams - 1)` commits stale, and gradients are applied
    /// (Adam) strictly in plan order on the coordinator, so the schedule
    /// is a pure function of `(n_train, k, p, streams)` and runs are
    /// reproducible. Requires `min(p, exec_streams - 1) <=
    /// bounded_staleness` (a step's batch must be spliceable before it is
    /// submitted). Changes numerics (bounded gradient delay) — the stream
    /// sweep in `benches/stream_overlap.rs` records the quality cost.
    pub param_staleness: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            depth: 1,
            bounded_staleness: 0,
            pool_workers: 0,
            exec_streams: 1,
            param_staleness: 0,
        }
    }
}

/// Everything needed to reproduce one training run.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Dataset profile name: wiki | reddit | mooc | lastfm | gdelt | tiny.
    pub dataset: String,
    /// Encoder: tgn | jodie | apan.
    pub model: String,
    /// Temporal batch size (must be one of the compiled artifact sizes).
    pub batch_size: usize,
    /// Enable PRES (prediction-correction + coherence smoothing).
    pub pres: bool,
    /// Coherence-smoothing strength (paper uses 0.1).
    pub beta: f32,
    pub epochs: usize,
    pub lr: f32,
    pub seed: u64,
    /// Fraction of vertices carrying full GMM trackers (1.0 = all; the
    /// paper's anchor-set heuristic for memory-constrained deployments).
    pub anchor_fraction: f32,
    /// Directory with HLO artifacts + manifest.json.
    pub artifacts_dir: String,
    /// EXEC backend: "auto" (default — PJRT when `artifacts_dir` holds a
    /// manifest, else the pure-Rust host step), "host", or "pjrt".
    pub exec: String,
    /// Host GEMM kernel backend: "auto" (default — resolves to blocked),
    /// "naive" (original scalar loops, bit-identical to pre-gemm builds),
    /// or "blocked" (cache-blocked SIMD-width panels; see
    /// `runtime/gemm.rs` for the tolerance contract). Ignored by PJRT.
    pub gemm: String,
    /// Evaluate on val split every n epochs (0 = only at the end).
    pub eval_every: usize,
    /// Reuse batch plans across epochs (false rebuilds per epoch — the
    /// plan-prefetch ablation; unrelated to the pipeline's PREP thread).
    pub prefetch: bool,
    /// Staged-pipeline knobs: PREP lookahead depth + bounded staleness.
    pub pipeline: PipelineConfig,
    /// Memory-store shard count. 1 (default) keeps the flat legacy
    /// `MemoryStore`; N > 1 partitions rows across N owned shards so
    /// SPLICE/WRITEBACK parallelize. Any value is bit-identical in results
    /// (at bounded_staleness = 0) — routing changes layout, not values.
    pub memory_shards: usize,
    /// Scale events generated (1.0 = profile default; figures use < 1 for
    /// quick sweeps).
    pub data_scale: f32,
    /// Chrome trace_event JSON output path (`--trace-out`); None disables
    /// span recording entirely (the instrumented sites cost one branch).
    pub trace_out: Option<String>,
    /// Per-epoch metrics JSONL output path (`--metrics-out`); None disables
    /// the telemetry counters.
    pub metrics_out: Option<String>,
}

impl ExperimentConfig {
    pub fn default_with(dataset: &str, model: &str, batch_size: usize, pres: bool) -> Self {
        ExperimentConfig {
            dataset: dataset.to_string(),
            model: model.to_string(),
            batch_size,
            pres,
            beta: if pres { 0.1 } else { 0.0 },
            epochs: 10,
            lr: 1e-3,
            seed: 0,
            anchor_fraction: 1.0,
            artifacts_dir: "artifacts".to_string(),
            exec: "auto".to_string(),
            gemm: "auto".to_string(),
            eval_every: 0,
            prefetch: true,
            pipeline: PipelineConfig::default(),
            memory_shards: 1,
            data_scale: 1.0,
            trace_out: None,
            metrics_out: None,
        }
    }

    pub fn from_json_file(path: &Path) -> Result<Self> {
        let j = Json::parse_file(path)?;
        Self::from_json(&j).with_context(|| format!("config {}", path.display()))
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut cfg = Self::default_with(
            j.get("dataset")?.as_str()?,
            j.get("model")?.as_str()?,
            j.get("batch_size")?.as_usize()?,
            j.opt("pres").map(|v| v.as_bool()).transpose()?.unwrap_or(false),
        );
        if let Some(v) = j.opt("beta") {
            cfg.beta = v.as_f32()?;
        }
        if let Some(v) = j.opt("epochs") {
            cfg.epochs = v.as_usize()?;
        }
        if let Some(v) = j.opt("lr") {
            cfg.lr = v.as_f32()?;
        }
        if let Some(v) = j.opt("seed") {
            cfg.seed = v.as_u64()?;
        }
        if let Some(v) = j.opt("anchor_fraction") {
            cfg.anchor_fraction = v.as_f32()?;
        }
        if let Some(v) = j.opt("artifacts_dir") {
            cfg.artifacts_dir = v.as_str()?.to_string();
        }
        if let Some(v) = j.opt("exec") {
            cfg.exec = v.as_str()?.to_string();
        }
        if let Some(v) = j.opt("gemm") {
            cfg.gemm = v.as_str()?.to_string();
        }
        if let Some(v) = j.opt("eval_every") {
            cfg.eval_every = v.as_usize()?;
        }
        if let Some(v) = j.opt("prefetch") {
            cfg.prefetch = v.as_bool()?;
        }
        if let Some(v) = j.opt("pipeline_depth") {
            cfg.pipeline.depth = v.as_usize()?;
        }
        if let Some(v) = j.opt("bounded_staleness") {
            cfg.pipeline.bounded_staleness = v.as_usize()?;
        }
        if let Some(v) = j.opt("pool_workers") {
            cfg.pipeline.pool_workers = v.as_usize()?;
        }
        if let Some(v) = j.opt("exec_streams") {
            cfg.pipeline.exec_streams = v.as_usize()?;
        }
        if let Some(v) = j.opt("param_staleness") {
            cfg.pipeline.param_staleness = v.as_usize()?;
        }
        if let Some(v) = j.opt("memory_shards") {
            cfg.memory_shards = v.as_usize()?;
        }
        if let Some(v) = j.opt("data_scale") {
            cfg.data_scale = v.as_f32()?;
        }
        if let Some(v) = j.opt("trace_out") {
            cfg.trace_out = Some(v.as_str()?.to_string());
        }
        if let Some(v) = j.opt("metrics_out") {
            cfg.metrics_out = Some(v.as_str()?.to_string());
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if !["tgn", "jodie", "apan"].contains(&self.model.as_str()) {
            bail!("unknown model '{}'", self.model);
        }
        if self.batch_size == 0 {
            bail!("batch_size must be positive");
        }
        if !(0.0..=1.0).contains(&self.anchor_fraction) {
            bail!("anchor_fraction must be in [0, 1]");
        }
        if self.beta < 0.0 {
            bail!("beta must be non-negative");
        }
        if !(self.data_scale > 0.0) {
            bail!("data_scale must be positive");
        }
        if !["auto", "host", "pjrt"].contains(&self.exec.as_str()) {
            bail!("exec must be one of auto | host | pjrt, got '{}'", self.exec);
        }
        if !["auto", "naive", "blocked"].contains(&self.gemm.as_str()) {
            bail!(
                "gemm must be one of auto | naive | blocked, got '{}'",
                self.gemm
            );
        }
        if self.pipeline.bounded_staleness > 0 && self.pipeline.depth == 0 {
            bail!("bounded_staleness > 0 requires pipeline depth >= 1");
        }
        if self.pipeline.exec_streams == 0 {
            bail!("exec_streams must be >= 1 (1 = inline EXEC on the coordinator)");
        }
        if self.pipeline.exec_streams > 1 {
            // Validate against the backend `Engine::auto` will actually
            // resolve, not just the literal string: "auto" with compiled
            // artifacts present picks PJRT and would die mid-run otherwise.
            let resolves_pjrt = self.exec == "pjrt"
                || (self.exec == "auto"
                    && Path::new(&self.artifacts_dir).join("manifest.json").exists());
            if resolves_pjrt {
                bail!(
                    "exec_streams > 1 requires the host EXEC backend — PJRT executes on a \
                     single stream (its handles are not Send){}; use --exec host or \
                     --exec-streams 1",
                    if self.exec == "auto" {
                        format!(
                            " and --exec auto resolves to pjrt because {}/manifest.json exists",
                            self.artifacts_dir
                        )
                    } else {
                        String::new()
                    }
                );
            }
            if self.pipeline.bounded_staleness == 0 {
                bail!(
                    "exec_streams > 1 requires bounded_staleness >= 1: overlapped EXEC is \
                     licensed by the staleness window (batch t+1 must be pre-spliced \
                     before step t commits)"
                );
            }
        }
        if self.pipeline.param_staleness > 0 {
            // The in-flight window submits step t while steps t-W..t-1 are
            // still executing, which needs batch t spliced W-1 commits
            // early — only licensed by an equal memory-staleness budget.
            let lag = self
                .pipeline
                .param_staleness
                .min(self.pipeline.exec_streams.saturating_sub(1));
            if lag > self.pipeline.bounded_staleness {
                bail!(
                    "param_staleness = {} with exec_streams = {} keeps steps up to {} \
                     commits in flight, which requires bounded_staleness >= {} (got {}): \
                     raise --staleness or lower --param-staleness",
                    self.pipeline.param_staleness,
                    self.pipeline.exec_streams,
                    lag + 1,
                    lag,
                    self.pipeline.bounded_staleness
                );
            }
        }
        if self.memory_shards == 0 {
            bail!("memory_shards must be >= 1 (1 = flat legacy store)");
        }
        // Catch unwritable telemetry destinations at config time: missing
        // parent directories are created at open (see
        // `util::ensure_parent_dir`), but an empty path or one naming an
        // existing directory would otherwise only fail after the run —
        // for --trace-out, after the *whole training run* completed.
        for (flag, path) in [
            ("--trace-out", &self.trace_out),
            ("--metrics-out", &self.metrics_out),
        ] {
            if let Some(p) = path {
                if p.trim().is_empty() {
                    bail!("{flag} requires a non-empty file path");
                }
                if Path::new(p).is_dir() {
                    bail!("{flag}: '{p}' is an existing directory, expected a file path");
                }
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![
            ("dataset", Json::str(&self.dataset)),
            ("model", Json::str(&self.model)),
            ("batch_size", Json::num(self.batch_size as f64)),
            ("pres", Json::Bool(self.pres)),
            ("beta", Json::num(self.beta as f64)),
            ("epochs", Json::num(self.epochs as f64)),
            ("lr", Json::num(self.lr as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("anchor_fraction", Json::num(self.anchor_fraction as f64)),
            ("artifacts_dir", Json::str(&self.artifacts_dir)),
            ("exec", Json::str(&self.exec)),
            ("eval_every", Json::num(self.eval_every as f64)),
            ("prefetch", Json::Bool(self.prefetch)),
            ("pipeline_depth", Json::num(self.pipeline.depth as f64)),
            (
                "bounded_staleness",
                Json::num(self.pipeline.bounded_staleness as f64),
            ),
            ("pool_workers", Json::num(self.pipeline.pool_workers as f64)),
            ("exec_streams", Json::num(self.pipeline.exec_streams as f64)),
            (
                "param_staleness",
                Json::num(self.pipeline.param_staleness as f64),
            ),
            ("memory_shards", Json::num(self.memory_shards as f64)),
            ("data_scale", Json::num(self.data_scale as f64)),
        ]);
        // Optional observability outputs only appear when set, so configs
        // written by older builds keep round-tripping byte-for-byte.
        if let Some(p) = &self.trace_out {
            j.set("trace_out", Json::str(p));
        }
        if let Some(p) = &self.metrics_out {
            j.set("metrics_out", Json::str(p));
        }
        // Same rationale: "auto" is the default, so omit it unless pinned.
        if self.gemm != "auto" {
            j.set("gemm", Json::str(&self.gemm));
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let cfg = ExperimentConfig::default_with("wiki", "tgn", 200, true);
        let j = cfg.to_json();
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.dataset, "wiki");
        assert_eq!(back.batch_size, 200);
        assert!(back.pres);
        assert_eq!(back.beta, 0.1);
    }

    #[test]
    fn validation_rejects_bad_values() {
        let mut cfg = ExperimentConfig::default_with("wiki", "tgn", 200, false);
        cfg.model = "gpt".into();
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default_with("wiki", "tgn", 200, false);
        cfg.anchor_fraction = 1.5;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn pipeline_knobs_roundtrip_and_validate() {
        let mut cfg = ExperimentConfig::default_with("wiki", "tgn", 200, false);
        assert_eq!(
            cfg.pipeline,
            PipelineConfig { depth: 1, bounded_staleness: 0, pool_workers: 0, exec_streams: 1, param_staleness: 0 }
        );
        cfg.pipeline =
            PipelineConfig { depth: 3, bounded_staleness: 2, pool_workers: 0, exec_streams: 1, param_staleness: 0 };
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.pipeline.depth, 3);
        assert_eq!(back.pipeline.bounded_staleness, 2);
        // staleness without a prefetch thread is meaningless
        cfg.pipeline =
            PipelineConfig { depth: 0, bounded_staleness: 1, pool_workers: 0, exec_streams: 1, param_staleness: 0 };
        assert!(cfg.validate().is_err());
        cfg.pipeline =
            PipelineConfig { depth: 0, bounded_staleness: 0, pool_workers: 0, exec_streams: 1, param_staleness: 0 };
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn exec_streams_roundtrip_and_validate() {
        let mut cfg = ExperimentConfig::default_with("wiki", "tgn", 200, false);
        assert_eq!(cfg.pipeline.exec_streams, 1); // default = inline EXEC
        cfg.pipeline =
            PipelineConfig { depth: 2, bounded_staleness: 1, pool_workers: 0, exec_streams: 4, param_staleness: 0 };
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.pipeline.exec_streams, 4);

        // 0 lanes is meaningless
        cfg.pipeline.exec_streams = 0;
        assert!(cfg.validate().is_err());

        // streams > 1 without a staleness window has nothing to overlap:
        // batch t+1 cannot splice before step t commits, so lanes would
        // only add overhead — rejected with a clear message
        cfg.pipeline =
            PipelineConfig { depth: 2, bounded_staleness: 0, pool_workers: 0, exec_streams: 2, param_staleness: 0 };
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("bounded_staleness"), "unexpected error: {err}");

        // the PJRT backend cannot serve stream lanes (handles are not Send)
        cfg.pipeline.bounded_staleness = 1;
        assert!(cfg.validate().is_ok());
        cfg.exec = "pjrt".into();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("host EXEC backend"), "unexpected error: {err}");
        cfg.exec = "host".into();
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn param_staleness_roundtrip_and_validate() {
        let mut cfg = ExperimentConfig::default_with("wiki", "tgn", 200, false);
        cfg.exec = "host".into();
        assert_eq!(cfg.pipeline.param_staleness, 0); // default = exact chain
        cfg.pipeline = PipelineConfig {
            depth: 2,
            bounded_staleness: 2,
            pool_workers: 0,
            exec_streams: 4,
            param_staleness: 2,
        };
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.pipeline.param_staleness, 2);

        // the in-flight window needs an equal memory-staleness budget:
        // min(p, streams - 1) must not exceed bounded_staleness
        cfg.pipeline.bounded_staleness = 1;
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("bounded_staleness >= 2"), "unexpected error: {err}");
        // ... but p is clamped by the lane count first: 2 lanes keep at
        // most 2 steps in flight, so staleness 1 suffices at any p
        cfg.pipeline.exec_streams = 2;
        assert!(cfg.validate().is_ok());
        // streams = 1 runs inline (exact chain) — p is a no-op, not an error
        cfg.pipeline =
            PipelineConfig { depth: 1, bounded_staleness: 0, pool_workers: 0, exec_streams: 1, param_staleness: 3 };
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn auto_exec_resolving_to_pjrt_rejects_streams_at_validate() {
        // regression: `--exec auto` with compiled artifacts present used to
        // pass validation for exec_streams > 1 and die mid-run when auto
        // resolved to PJRT — validate must check the *resolved* backend
        let dir = std::env::temp_dir().join(format!(
            "pres_cfg_auto_pjrt_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "{}").unwrap();

        let mut cfg = ExperimentConfig::default_with("wiki", "tgn", 200, false);
        cfg.artifacts_dir = dir.to_str().unwrap().to_string();
        cfg.pipeline =
            PipelineConfig { depth: 2, bounded_staleness: 1, pool_workers: 0, exec_streams: 2, param_staleness: 0 };
        assert_eq!(cfg.exec, "auto");
        let err = cfg.validate().unwrap_err().to_string();
        assert!(
            err.contains("resolves to pjrt") && err.contains("manifest.json"),
            "unexpected error: {err}"
        );
        // forcing the host backend over the same artifacts dir is fine
        cfg.exec = "host".into();
        assert!(cfg.validate().is_ok());
        // and auto over a dir with no manifest resolves to host — accepted
        cfg.exec = "auto".into();
        std::fs::remove_file(dir.join("manifest.json")).unwrap();
        assert!(cfg.validate().is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pool_workers_roundtrip_and_default() {
        let mut cfg = ExperimentConfig::default_with("wiki", "tgn", 200, false);
        assert_eq!(cfg.pipeline.pool_workers, 0); // 0 = auto (process pool)
        cfg.pipeline.pool_workers = 8;
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.pipeline.pool_workers, 8);
        // 1 = fully serial; any value is valid (bit-identical results)
        cfg.pipeline.pool_workers = 1;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn memory_shards_roundtrip_and_validate() {
        let mut cfg = ExperimentConfig::default_with("wiki", "tgn", 200, false);
        assert_eq!(cfg.memory_shards, 1); // default = flat legacy layout
        cfg.memory_shards = 8;
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.memory_shards, 8);
        cfg.memory_shards = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn exec_backend_roundtrip_and_validate() {
        let mut cfg = ExperimentConfig::default_with("wiki", "tgn", 200, false);
        assert_eq!(cfg.exec, "auto"); // default resolves by artifact presence
        cfg.exec = "host".into();
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.exec, "host");
        cfg.exec = "pjrt".into();
        assert!(cfg.validate().is_ok());
        cfg.exec = "tpu".into();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn gemm_backend_roundtrip_and_validate() {
        let mut cfg = ExperimentConfig::default_with("wiki", "tgn", 200, false);
        assert_eq!(cfg.gemm, "auto"); // default resolves to blocked
        // omitted from JSON when left at the default, so configs written
        // by pre-gemm builds keep round-tripping byte-for-byte
        assert!(!cfg.to_json().to_string().contains("gemm"));
        cfg.gemm = "naive".into();
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.gemm, "naive");
        cfg.gemm = "blocked".into();
        assert!(cfg.validate().is_ok());
        cfg.gemm = "cublas".into();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("auto | naive | blocked"), "unexpected error: {err}");
    }

    #[test]
    fn observability_paths_roundtrip_and_default_off() {
        let mut cfg = ExperimentConfig::default_with("wiki", "tgn", 200, false);
        assert!(cfg.trace_out.is_none());
        assert!(cfg.metrics_out.is_none());
        // absent from JSON when unset (older configs stay byte-identical)
        let plain = cfg.to_json().to_string();
        assert!(!plain.contains("trace_out"));
        assert!(!plain.contains("metrics_out"));
        cfg.trace_out = Some("trace.json".into());
        cfg.metrics_out = Some("metrics.jsonl".into());
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.trace_out.as_deref(), Some("trace.json"));
        assert_eq!(back.metrics_out.as_deref(), Some("metrics.jsonl"));
    }

    #[test]
    fn observability_paths_validate_at_config_time() {
        let mut cfg = ExperimentConfig::default_with("wiki", "tgn", 200, false);
        // nested not-yet-existing parents are fine (created at open)
        cfg.trace_out = Some("runs/not/yet/there/trace.json".into());
        cfg.metrics_out = Some("metrics.jsonl".into());
        assert!(cfg.validate().is_ok());
        // empty / whitespace paths fail up front, naming the flag
        cfg.trace_out = Some("  ".into());
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("--trace-out"), "unexpected error: {err}");
        cfg.trace_out = None;
        cfg.metrics_out = Some(String::new());
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("--metrics-out"), "unexpected error: {err}");
        // a path naming an existing directory fails up front, not after
        // the run when the file is finally opened
        let dir = std::env::temp_dir().join(format!("pres-cfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        cfg.metrics_out = Some(dir.to_str().unwrap().to_string());
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("existing directory"), "unexpected error: {err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn pres_default_beta() {
        assert_eq!(ExperimentConfig::default_with("w", "tgn", 1, true).beta, 0.1);
        assert_eq!(ExperimentConfig::default_with("w", "tgn", 1, false).beta, 0.0);
    }
}
