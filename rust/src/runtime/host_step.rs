//! Host EXEC backend: a pure-Rust forward **and backward** implementation
//! of the manifest step ABI — the same positional contract the compiled
//! XLA artifacts expose (see `runtime/manifest.rs` and
//! `python/compile/model.py`), executed natively so the full PRES training
//! loop runs on any machine with zero artifacts.
//!
//! One [`HostStep::run`] call is one fused training iteration of
//! Algorithm 2, mirroring model.py's `_forward` line for line:
//!
//! ```text
//!   messages -> memory update (GRU / RNN) -> PRES correction (Eq. 8)
//!   -> memory coherence (Eq. 10) -> lag-one splice -> embeddings
//!   (TGN attention / JODIE projection / APAN attention + pooled mail)
//!   -> MLP decoder -> BCE + beta * (1 - coherence) -> backprop -> Adam
//! ```
//!
//! The backward pass is hand-written reverse-mode over the exact forward
//! formulas (the same formulas the Pallas kernels' custom VJPs
//! differentiate), pinned by directional finite-difference checks in the
//! test module. The optimizer is the artifact's Adam with identical
//! hyper-parameters and bias correction, so `ModelState::absorb_outputs`
//! consumes host outputs unchanged.
//!
//! Batched matmuls route through the [`gemm`](crate::runtime::gemm)
//! kernel subsystem (`--gemm {auto|naive|blocked}`), which fans out on the
//! persistent [`WorkerPool`] in fixed row chunks with bias + activation
//! fused into the output sweep. On the naive backend each output row is
//! accumulated in exactly the pre-gemm loop order, so results are
//! bit-identical for every lane count AND to the pre-gemm code — the same
//! exactness invariant the PR 3 runtime pins for SPLICE/WRITEBACK/PREP.
//! The blocked backend keeps lane-count invariance but reorders two
//! reductions (see `runtime/gemm.rs` for the tolerance contract). The
//! remaining per-row sweeps here (`time_enc`, `col_sum_acc`,
//! `time_enc_bwd`) pool-parallelize the same way above a crossover,
//! partitioned so per-slot accumulation order never changes.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};
use xla::Literal;

use crate::runtime::engine::lit_f32;
use crate::runtime::gemm::{self, Act, GemmBackendKind};
use crate::runtime::manifest::{ArtifactSpec, DType, Dims, TensorSpec};
use crate::util::pool::{chunk_for, take_chunk, WorkerPool};

const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

/// Elements below which the column-partitioned reductions (`col_sum_acc`,
/// `time_enc_bwd`) stay serial — a chunk handoff costs ~1–2 µs, more than
/// the whole sweep at small sizes.
const COL_PAR_MIN_ELEMS: usize = 1 << 12;

/// Rows below which `time_enc` stays on one lane (rows are only
/// `d_time` floats wide, so the crossover sits far above the GEMM one).
const TE_PAR_MIN_ROWS: usize = 256;

// ------------------------------------------------------------ small math

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[inline]
fn softplus(x: f32) -> f32 {
    // stable log(1 + e^x)
    x.max(0.0) + (1.0 + (-x.abs()).exp()).ln()
}

/// out[j] += sum over rows of a[:, j] (bias gradients). Column-partitioned
/// across the pool above [`COL_PAR_MIN_ELEMS`]: each lane owns a disjoint
/// column range and walks all rows in ascending order, so every `out[j]`
/// accumulates in exactly the serial order — bit-identical for any lane
/// count.
fn col_sum_acc(pool: &WorkerPool, a: &[f32], n: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), n);
    if n == 0 {
        return;
    }
    let rows = a.len() / n;
    let min_cols = (COL_PAR_MIN_ELEMS / rows.max(1)).max(1);
    let chunk = chunk_for(n, pool.lanes(), min_cols);
    if chunk >= n {
        for row in a.chunks_exact(n) {
            for (o, &v) in out.iter_mut().zip(row) {
                *o += v;
            }
        }
        return;
    }
    let mut tasks: Vec<(usize, &mut [f32])> = Vec::with_capacity(n.div_ceil(chunk));
    let mut cursor = out;
    let mut j0 = 0;
    while j0 < n {
        let cols = chunk.min(n - j0);
        tasks.push((j0, take_chunk(&mut cursor, cols)));
        j0 += cols;
    }
    pool.run(&mut tasks, |t| {
        let (j0, ocols) = (t.0, &mut *t.1);
        let w = ocols.len();
        for row in a.chunks_exact(n) {
            for (o, &v) in ocols.iter_mut().zip(&row[j0..j0 + w]) {
                *o += v;
            }
        }
    });
}

/// phi(dt) = cos(dt * omega + phi) into `out` [n, D]. Row-partitioned on
/// the pool above [`TE_PAR_MIN_ROWS`]; rows are independent, so lane count
/// never changes results.
fn time_enc(pool: &WorkerPool, dt: &[f32], omega: &[f32], phi: &[f32], out: &mut [f32]) {
    let d = omega.len();
    debug_assert_eq!(out.len(), dt.len() * d);
    gemm::par_rows_min(pool, out, dt.len(), d, TE_PAR_MIN_ROWS, |r0, rows| {
        for (i, row) in rows.chunks_exact_mut(d).enumerate() {
            let t = dt[r0 + i];
            for (o, (&w, &ph)) in row.iter_mut().zip(omega.iter().zip(phi)) {
                *o = (t * w + ph).cos();
            }
        }
    });
}

/// Accumulate d_omega / d_phi for the encoding of `dt` given upstream
/// `d_out` [n, D] (dt itself is data — no gradient needed).
/// Column-partitioned like [`col_sum_acc`]: each lane owns a `j` range of
/// BOTH gradient banks and sweeps all rows ascending, preserving the
/// serial per-slot accumulation order exactly.
fn time_enc_bwd(
    pool: &WorkerPool,
    dt: &[f32],
    omega: &[f32],
    phi: &[f32],
    d_out: &[f32],
    g_omega: &mut [f32],
    g_phi: &mut [f32],
) {
    let d = omega.len();
    debug_assert_eq!(d_out.len(), dt.len() * d);
    if d == 0 {
        return;
    }
    let min_cols = (COL_PAR_MIN_ELEMS / (2 * dt.len().max(1))).max(1);
    let chunk = chunk_for(d, pool.lanes(), min_cols);
    if chunk >= d {
        for (i, drow) in d_out.chunks_exact(d).enumerate() {
            let t = dt[i];
            for j in 0..d {
                let s = (t * omega[j] + phi[j]).sin();
                g_omega[j] -= s * t * drow[j];
                g_phi[j] -= s * drow[j];
            }
        }
        return;
    }
    struct Task<'a> {
        j0: usize,
        go: &'a mut [f32],
        gp: &'a mut [f32],
    }
    let mut tasks: Vec<Task> = Vec::with_capacity(d.div_ceil(chunk));
    {
        let mut go_cur = g_omega;
        let mut gp_cur = g_phi;
        let mut j0 = 0;
        while j0 < d {
            let cols = chunk.min(d - j0);
            tasks.push(Task {
                j0,
                go: take_chunk(&mut go_cur, cols),
                gp: take_chunk(&mut gp_cur, cols),
            });
            j0 += cols;
        }
    }
    pool.run(&mut tasks, |t| {
        for (i, drow) in d_out.chunks_exact(d).enumerate() {
            let ti = dt[i];
            for (jj, (go, gp)) in t.go.iter_mut().zip(t.gp.iter_mut()).enumerate() {
                let j = t.j0 + jj;
                let s = (ti * omega[j] + phi[j]).sin();
                *go -= s * ti * drow[j];
                *gp -= s * drow[j];
            }
        }
    });
}

// --------------------------------------------------------------- arg views

fn read_f32(lit: &Literal, spec: &TensorSpec) -> Result<Vec<f32>> {
    let mut out = vec![0.0f32; spec.elems()];
    lit.copy_raw_to(&mut out)
        .map_err(|e| anyhow!("input '{}': {e}", spec.name))?;
    Ok(out)
}

fn read_i32(lit: &Literal, spec: &TensorSpec) -> Result<Vec<i32>> {
    let mut out = vec![0i32; spec.elems()];
    lit.copy_raw_to(&mut out)
        .map_err(|e| anyhow!("input '{}': {e}", spec.name))?;
    Ok(out)
}

/// Parameter bank: values in ABI order plus name lookup.
struct Params {
    index: BTreeMap<String, usize>,
    vals: Vec<Vec<f32>>,
}

impl Params {
    fn get(&self, name: &str) -> &[f32] {
        &self.vals[self.index[name]]
    }
}

/// Data tensors by name (f32 and the i32 match indices).
struct Data {
    f: BTreeMap<String, Vec<f32>>,
    i: BTreeMap<String, Vec<i32>>,
}

impl Data {
    fn f(&self, name: &str) -> &[f32] {
        &self.f[name]
    }

    fn i(&self, name: &str) -> &[i32] {
        &self.i[name]
    }

    fn scalar(&self, name: &str) -> f32 {
        self.f[name][0]
    }
}

// ----------------------------------------------------------- forward state

/// Per-role embedding intermediates kept for the backward pass.
#[derive(Default)]
struct RoleFwd {
    mem: Vec<f32>,   // spliced memory [b, d]
    q_in: Vec<f32>,  // tgn: [b, d + Dt] (mem | phi(0)); apan: empty
    q: Vec<f32>,     // [b, dqk]
    kv_in: Vec<f32>, // [b*K, k_in]
    k: Vec<f32>,     // [b*K, dqk]
    v: Vec<f32>,     // [b*K, dv]
    att_w: Vec<f32>, // softmax weights [b, H, K]
    cat: Vec<f32>,   // decoder-side concat [b, cat_w]
    h: Vec<f32>,     // embedding [b, d_emb]
}

/// Everything the backward pass reuses from the forward evaluation.
struct Fwd {
    x_msg: Vec<f32>,  // [U, msg_in]
    h1: Vec<f32>,     // [U, mh] post-relu
    msg: Vec<f32>,    // [U, dm]
    gh: Vec<f32>,     // gru hidden bank [U, 3d]
    r: Vec<f32>,      // [U, d]
    z: Vec<f32>,      // [U, d]
    cand: Vec<f32>,   // candidate tanh [U, d]
    s_new: Vec<f32>,  // [U, d]
    gamma: f32,
    gamma_rows: Vec<f32>, // [U]
    s_bar: Vec<f32>,      // [U, d]
    coh: f32,
    coh_da: f32,
    coh_db: f32,
    roles: [RoleFwd; 3],
    x_pos: Vec<f32>,   // [b, 2*demb]
    hid_pos: Vec<f32>, // [b, dh]
    pos: Vec<f32>,     // [b]
    x_neg: Vec<f32>,
    hid_neg: Vec<f32>,
    neg: Vec<f32>,
    bce: f32,
    loss: f32,
}

// ---------------------------------------------------------------- the step

/// One host-executed step for a `(model, batch, kind)` triple. Send + Sync
/// by construction (plain data + `Arc<WorkerPool>`), unlike its PJRT
/// counterpart — which is what lets the EXEC stream lanes
/// (`pipeline/stream.rs`) Arc-share one instance across threads. `run` is
/// stateless across calls: parameters arrive as inputs and every per-run
/// activation is a local, so concurrent `run`s from different lanes are
/// sound (they only contend on the shared `WorkerPool`'s handoff lock).
pub struct HostStep {
    pub spec: ArtifactSpec,
    dims: Dims,
    n_params: usize,
    pool: Arc<WorkerPool>,
    gemm: GemmBackendKind,
}

impl HostStep {
    pub fn new(
        spec: ArtifactSpec,
        dims: Dims,
        n_params: usize,
        pool: Arc<WorkerPool>,
        gemm: GemmBackendKind,
    ) -> HostStep {
        HostStep { spec, dims, n_params, pool, gemm }
    }

    /// Execute the step over positional literals; returns one literal per
    /// manifest output — the exact contract of the PJRT path.
    pub fn run(&self, args: &[&Literal]) -> Result<Vec<Literal>> {
        if args.len() != self.spec.inputs.len() {
            bail!(
                "host step {}: got {} args, ABI expects {}",
                self.spec.name,
                args.len(),
                self.spec.inputs.len()
            );
        }
        if self.spec.model == "clf" {
            return self.run_clf(args);
        }
        self.run_model(args)
    }

    fn parse_params(&self, args: &[&Literal]) -> Result<Params> {
        let mut index = BTreeMap::new();
        let mut vals = Vec::with_capacity(self.n_params);
        for (i, spec) in self.spec.inputs[..self.n_params].iter().enumerate() {
            index.insert(spec.name.clone(), i);
            vals.push(read_f32(args[i], spec)?);
        }
        Ok(Params { index, vals })
    }

    fn parse_data(&self, args: &[&Literal], offset: usize, count: usize) -> Result<Data> {
        let mut f = BTreeMap::new();
        let mut i32s = BTreeMap::new();
        for (spec, lit) in self.spec.inputs[offset..offset + count]
            .iter()
            .zip(&args[offset..offset + count])
        {
            match spec.dtype {
                DType::F32 => {
                    f.insert(spec.name.clone(), read_f32(lit, spec)?);
                }
                DType::I32 => {
                    i32s.insert(spec.name.clone(), read_i32(lit, spec)?);
                }
            }
        }
        Ok(Data { f, i: i32s })
    }

    fn run_model(&self, args: &[&Literal]) -> Result<Vec<Literal>> {
        let train = self.spec.kind == "train";
        let n = self.n_params;
        let data_off = if train { 3 * n } else { n };
        let n_data = self.spec.inputs.len() - data_off - if train { 2 } else { 0 };
        let p = self.parse_params(args)?;
        let d = self.parse_data(args, data_off, n_data)?;

        let fwd = self.forward(&p, &d);

        let mut outputs: Vec<Literal> = Vec::with_capacity(self.spec.outputs.len());
        if self.spec.kind == "grad" {
            // gradient-only step (relaxed-parameter-staleness EXEC): same
            // forward + backward as train, but the optimizer state never
            // crosses the lane boundary — raw per-param gradients come
            // back in spec order and the coordinator applies Adam in plan
            // order
            let grads = self.backward(&p, &d, &fwd);
            for (vals, spec) in grads.iter().zip(&self.spec.inputs[..n]) {
                outputs.push(lit_f32(vals, &spec.shape)?);
            }
        }
        if train {
            let grads = self.backward(&p, &d, &fwd);
            let lr = read_f32(args[args.len() - 2], &self.spec.inputs[args.len() - 2])?[0];
            let t = read_f32(args[args.len() - 1], &self.spec.inputs[args.len() - 1])?[0];
            let mut m: Vec<Vec<f32>> = Vec::with_capacity(n);
            let mut v: Vec<Vec<f32>> = Vec::with_capacity(n);
            for i in 0..n {
                m.push(read_f32(args[n + i], &self.spec.inputs[n + i])?);
                v.push(read_f32(args[2 * n + i], &self.spec.inputs[2 * n + i])?);
            }
            let mut new_p = p.vals.clone();
            adam_update(&mut new_p, &grads, &mut m, &mut v, lr, t);
            for (vals, spec) in new_p.iter().zip(&self.spec.inputs[..n]) {
                outputs.push(lit_f32(vals, &spec.shape)?);
            }
            for (vals, spec) in m.iter().zip(&self.spec.inputs[..n]) {
                outputs.push(lit_f32(vals, &spec.shape)?);
            }
            for (vals, spec) in v.iter().zip(&self.spec.inputs[..n]) {
                outputs.push(lit_f32(vals, &spec.shape)?);
            }
        }
        self.push_step_outputs(&fwd, &mut outputs)?;
        Ok(outputs)
    }

    fn push_step_outputs(&self, fwd: &Fwd, outputs: &mut Vec<Literal>) -> Result<()> {
        let off = outputs.len();
        let dims = self.dims;
        let b = self.spec.batch;
        let u = 2 * b;
        let delta: Vec<f32> = fwd
            .s_bar
            .iter()
            .zip(&fwd.s_new)
            .map(|(&sb, &sn)| sb - sn)
            .collect();
        outputs.push(lit_f32(&fwd.s_bar, &[u, dims.d_mem])?);
        outputs.push(lit_f32(&delta, &[u, dims.d_mem])?);
        outputs.push(lit_f32(&fwd.msg, &[u, dims.d_msg])?);
        outputs.push(lit_f32(&fwd.pos, &[b])?);
        outputs.push(lit_f32(&fwd.neg, &[b])?);
        outputs.push(lit_f32(&fwd.roles[0].h, &[b, dims.d_emb])?);
        outputs.push(lit_f32(&[fwd.loss], &[])?);
        outputs.push(lit_f32(&[fwd.bce], &[])?);
        outputs.push(lit_f32(&[fwd.coh], &[])?);
        debug_assert_eq!(outputs.len() - off, 9);
        Ok(())
    }

    // ------------------------------------------------------------ forward

    fn forward(&self, p: &Params, d: &Data) -> Fwd {
        let dims = self.dims;
        let model = self.spec.model.as_str();
        let pool = &*self.pool;
        let g = self.gemm;
        let b = self.spec.batch;
        let u = 2 * b;
        let (dm, de, dt_w) = (dims.d_msg, dims.d_edge, dims.d_time);
        let dmem = dims.d_mem;
        let msg_in = 2 * dmem + de + dt_w;
        let mh = p.get("msg_b1").len();

        // 1. MSG module: MLP over [s_self, s_other, e, phi(dt)] (Eq. 1)
        let u_self = d.f("u_self_mem");
        let u_dt = d.f("u_dt");
        let mut phi_u = vec![0.0f32; u * dt_w];
        time_enc(pool, u_dt, p.get("time_omega"), p.get("time_phi"), &mut phi_u);
        let mut x_msg = vec![0.0f32; u * msg_in];
        {
            let u_other = d.f("u_other_mem");
            let u_efeat = d.f("u_efeat");
            for r in 0..u {
                let row = &mut x_msg[r * msg_in..(r + 1) * msg_in];
                row[..dmem].copy_from_slice(&u_self[r * dmem..(r + 1) * dmem]);
                row[dmem..2 * dmem].copy_from_slice(&u_other[r * dmem..(r + 1) * dmem]);
                row[2 * dmem..2 * dmem + de].copy_from_slice(&u_efeat[r * de..(r + 1) * de]);
                row[2 * dmem + de..].copy_from_slice(&phi_u[r * dt_w..(r + 1) * dt_w]);
            }
        }
        let mut h1 = vec![0.0f32; u * mh];
        gemm::mm_nn(g, pool, &x_msg, p.get("msg_w1"), u, msg_in, mh, Some(p.get("msg_b1")), Act::Relu, &mut h1);
        let mut msg = vec![0.0f32; u * dm];
        gemm::mm_nn(g, pool, &h1, p.get("msg_w2"), u, mh, dm, Some(p.get("msg_b2")), Act::None, &mut msg);

        // 2. MEM module: GRU (tgn/apan) or vanilla RNN (jodie)
        let mut gh = Vec::new();
        let mut r_gate = Vec::new();
        let mut z_gate = Vec::new();
        let mut cand = Vec::new();
        let mut s_new = vec![0.0f32; u * dmem];
        if model == "jodie" {
            // pre = msg @ wx + h @ wh + b; s_new = tanh(pre), with the
            // h @ wh term, bias and tanh fused into one accumulate pass
            gemm::mm_nn(g, pool, &msg, p.get("rnn_wx"), u, dm, dmem, None, Act::None, &mut s_new);
            gemm::mm_nn_acc(g, pool, u_self, p.get("rnn_wh"), u, dmem, dmem, Some(p.get("rnn_b")), Act::Tanh, &mut s_new);
        } else {
            // fused gate banks, cuDNN layout: reset | update | candidate
            let d3 = 3 * dmem;
            let bias = p.get("gru_b"); // [2, 3d] row-major
            let mut gx = vec![0.0f32; u * d3];
            gemm::mm_nn(g, pool, &msg, p.get("gru_wx"), u, dm, d3, Some(&bias[..d3]), Act::None, &mut gx);
            gh = vec![0.0f32; u * d3];
            gemm::mm_nn(g, pool, u_self, p.get("gru_wh"), u, dmem, d3, Some(&bias[d3..]), Act::None, &mut gh);
            r_gate = vec![0.0f32; u * dmem];
            z_gate = vec![0.0f32; u * dmem];
            cand = vec![0.0f32; u * dmem];
            for rr in 0..u {
                let gxr = &gx[rr * d3..(rr + 1) * d3];
                let ghr = &gh[rr * d3..(rr + 1) * d3];
                let hr = &u_self[rr * dmem..(rr + 1) * dmem];
                for j in 0..dmem {
                    let r = sigmoid(gxr[j] + ghr[j]);
                    let z = sigmoid(gxr[dmem + j] + ghr[dmem + j]);
                    let n = (gxr[2 * dmem + j] + r * ghr[2 * dmem + j]).tanh();
                    r_gate[rr * dmem + j] = r;
                    z_gate[rr * dmem + j] = z;
                    cand[rr * dmem + j] = n;
                    s_new[rr * dmem + j] = (1.0 - z) * n + z * hr[j];
                }
            }
        }

        // 3. PRES prediction-correction (Eq. 8), gated to pending rows
        let gamma = sigmoid(p.get("gamma_raw")[0]);
        let pres_on = d.scalar("pres_on");
        let u_cmask = d.f("u_cmask");
        let u_pred = d.f("u_pred");
        let gamma_rows: Vec<f32> = (0..u)
            .map(|r| 1.0 - pres_on * u_cmask[r] * (1.0 - gamma))
            .collect();
        let mut s_bar = vec![0.0f32; u * dmem];
        for r in 0..u {
            let g = gamma_rows[r];
            for j in 0..dmem {
                let idx = r * dmem + j;
                s_bar[idx] = g * s_new[idx] + (1.0 - g) * u_pred[idx];
            }
        }

        // 4. memory coherence (Eq. 10): masked Frobenius cosine
        let wmask = d.f("u_wmask");
        let mut num = 0.0f32;
        let mut aa = 0.0f32;
        let mut bb = 0.0f32;
        for r in 0..u {
            let w = wmask[r];
            if w == 0.0 {
                continue;
            }
            for j in 0..dmem {
                let idx = r * dmem + j;
                let a = u_self[idx] * w;
                let bv = s_bar[idx] * w;
                num += a * bv;
                aa += a * a;
                bb += bv * bv;
            }
        }
        let coh_da = aa.sqrt();
        let coh_db = bb.sqrt();
        let coh = num / (coh_da * coh_db).max(1e-9);

        // 5 + 6. lag-one splice into the current rows, then embeddings
        let mut roles: [RoleFwd; 3] = Default::default();
        for (ri, role) in ["src", "dst", "neg"].iter().enumerate() {
            let matches = d.i(&format!("c_{role}_match"));
            let store_mem = d.f(&format!("c_{role}_mem"));
            let mut mem = vec![0.0f32; b * dmem];
            for j in 0..b {
                let src = if matches[j] >= 0 {
                    &s_bar[matches[j] as usize * dmem..(matches[j] as usize + 1) * dmem]
                } else {
                    &store_mem[j * dmem..(j + 1) * dmem]
                };
                mem[j * dmem..(j + 1) * dmem].copy_from_slice(src);
            }
            roles[ri] = self.embed(p, d, role, mem);
        }

        // 7. temporal link prediction (self-supervised BCE)
        let demb = dims.d_emb;
        let dh = p.get("dec_b1").len();
        let decode = |h_a: &[f32], h_b: &[f32]| -> (Vec<f32>, Vec<f32>, Vec<f32>) {
            let mut x = vec![0.0f32; b * 2 * demb];
            for j in 0..b {
                x[j * 2 * demb..j * 2 * demb + demb]
                    .copy_from_slice(&h_a[j * demb..(j + 1) * demb]);
                x[j * 2 * demb + demb..(j + 1) * 2 * demb]
                    .copy_from_slice(&h_b[j * demb..(j + 1) * demb]);
            }
            let mut hid = vec![0.0f32; b * dh];
            gemm::mm_nn(g, pool, &x, p.get("dec_w1"), b, 2 * demb, dh, Some(p.get("dec_b1")), Act::Relu, &mut hid);
            let w2 = p.get("dec_w2"); // [dh, 1]
            let b2 = p.get("dec_b2")[0];
            let logits: Vec<f32> =
                hid.chunks_exact(dh).map(|row| gemm::dot(g, row, w2) + b2).collect();
            (x, hid, logits)
        };
        let (x_pos, hid_pos, pos) = decode(&roles[0].h, &roles[1].h);
        let (x_neg, hid_neg, neg) = decode(&roles[0].h, &roles[2].h);

        let bce = pos
            .iter()
            .zip(&neg)
            .map(|(&p, &n)| softplus(-p) + softplus(n))
            .sum::<f32>()
            / b as f32;
        let loss = bce + d.scalar("beta") * (1.0 - coh);

        Fwd {
            x_msg,
            h1,
            msg,
            gh,
            r: r_gate,
            z: z_gate,
            cand,
            s_new,
            gamma,
            gamma_rows,
            s_bar,
            coh,
            coh_da,
            coh_db,
            roles,
            x_pos,
            hid_pos,
            pos,
            x_neg,
            hid_neg,
            neg,
            bce,
            loss,
        }
    }

    /// EMB module for one role over its spliced memory rows.
    fn embed(&self, p: &Params, d: &Data, role: &str, mem: Vec<f32>) -> RoleFwd {
        let dims = self.dims;
        let pool = &*self.pool;
        let g = self.gemm;
        let b = self.spec.batch;
        let (dmem, dt_w, k_n, heads) = (dims.d_mem, dims.d_time, dims.k_nbr, dims.heads);
        let mut out = RoleFwd { mem, ..Default::default() };
        match self.spec.model.as_str() {
            "jodie" => {
                // h = s * (1 + dt * w): a linear drift, no activation
                let dt = d.f(&format!("c_{role}_dt"));
                let w = p.get("proj_w");
                let mut h = vec![0.0f32; b * dmem];
                for j in 0..b {
                    for i in 0..dmem {
                        h[j * dmem + i] = out.mem[j * dmem + i] * (1.0 + dt[j] * w[i]);
                    }
                }
                out.h = h;
            }
            "apan" => {
                let mail = d.f(&format!("n_{role}_mail"));
                let n_dt = d.f(&format!("n_{role}_dt"));
                let mask = d.f(&format!("n_{role}_mask"));
                let dqk = p.get("att_wq").len() / dmem;
                let k_in = dims.d_msg + dt_w;
                let dv = p.get("att_wv").len() / k_in;
                let rows = b * k_n;
                let mut q = vec![0.0f32; b * dqk];
                gemm::mm_nn(g, pool, &out.mem, p.get("att_wq"), b, dmem, dqk, None, Act::None, &mut q);
                let mut phi_n = vec![0.0f32; rows * dt_w];
                time_enc(pool, n_dt, p.get("time_omega"), p.get("time_phi"), &mut phi_n);
                let mut kv_in = vec![0.0f32; rows * k_in];
                for r in 0..rows {
                    let row = &mut kv_in[r * k_in..(r + 1) * k_in];
                    row[..dims.d_msg]
                        .copy_from_slice(&mail[r * dims.d_msg..(r + 1) * dims.d_msg]);
                    row[dims.d_msg..].copy_from_slice(&phi_n[r * dt_w..(r + 1) * dt_w]);
                }
                let mut kk = vec![0.0f32; rows * dqk];
                gemm::mm_nn(g, pool, &kv_in, p.get("att_wk"), rows, k_in, dqk, None, Act::None, &mut kk);
                let mut vv = vec![0.0f32; rows * dv];
                gemm::mm_nn(g, pool, &kv_in, p.get("att_wv"), rows, k_in, dv, None, Act::None, &mut vv);
                let (att, att_w) = attention(g, pool, &q, &kk, &vv, mask, b, k_n, heads);
                // pooled masked mail mean over the value projections
                let mut pooled = vec![0.0f32; b * dv];
                masked_mean(&vv, mask, b, k_n, dv, &mut pooled);
                let cat_w = dmem + 2 * dv;
                let mut cat = vec![0.0f32; b * cat_w];
                for j in 0..b {
                    let row = &mut cat[j * cat_w..(j + 1) * cat_w];
                    row[..dmem].copy_from_slice(&out.mem[j * dmem..(j + 1) * dmem]);
                    row[dmem..dmem + dv].copy_from_slice(&att[j * dv..(j + 1) * dv]);
                    row[dmem + dv..].copy_from_slice(&pooled[j * dv..(j + 1) * dv]);
                }
                let mut h = vec![0.0f32; b * dims.d_emb];
                gemm::mm_nn(g, pool, &cat, p.get("att_wo"), b, cat_w, dims.d_emb, Some(p.get("att_bo")), Act::Tanh, &mut h);
                out.q = q;
                out.kv_in = kv_in;
                out.k = kk;
                out.v = vv;
                out.att_w = att_w;
                out.cat = cat;
                out.h = h;
            }
            _ => {
                // tgn: attention over the K most recent temporal neighbors
                let n_mem = d.f(&format!("n_{role}_mem"));
                let n_efeat = d.f(&format!("n_{role}_efeat"));
                let n_dt = d.f(&format!("n_{role}_dt"));
                let mask = d.f(&format!("n_{role}_mask"));
                let de = dims.d_edge;
                let q_in_w = dmem + dt_w;
                let dqk = p.get("att_wq").len() / q_in_w;
                let k_in = dmem + de + dt_w;
                let dv = p.get("att_wv").len() / k_in;
                let rows = b * k_n;
                // query = [mem | phi(0)]
                let zeros = vec![0.0f32; b];
                let mut phi0 = vec![0.0f32; b * dt_w];
                time_enc(pool, &zeros, p.get("time_omega"), p.get("time_phi"), &mut phi0);
                let mut q_in = vec![0.0f32; b * q_in_w];
                for j in 0..b {
                    let row = &mut q_in[j * q_in_w..(j + 1) * q_in_w];
                    row[..dmem].copy_from_slice(&out.mem[j * dmem..(j + 1) * dmem]);
                    row[dmem..].copy_from_slice(&phi0[j * dt_w..(j + 1) * dt_w]);
                }
                let mut q = vec![0.0f32; b * dqk];
                gemm::mm_nn(g, pool, &q_in, p.get("att_wq"), b, q_in_w, dqk, None, Act::None, &mut q);
                let mut phi_n = vec![0.0f32; rows * dt_w];
                time_enc(pool, n_dt, p.get("time_omega"), p.get("time_phi"), &mut phi_n);
                let mut kv_in = vec![0.0f32; rows * k_in];
                for r in 0..rows {
                    let row = &mut kv_in[r * k_in..(r + 1) * k_in];
                    row[..dmem].copy_from_slice(&n_mem[r * dmem..(r + 1) * dmem]);
                    row[dmem..dmem + de].copy_from_slice(&n_efeat[r * de..(r + 1) * de]);
                    row[dmem + de..].copy_from_slice(&phi_n[r * dt_w..(r + 1) * dt_w]);
                }
                let mut kk = vec![0.0f32; rows * dqk];
                gemm::mm_nn(g, pool, &kv_in, p.get("att_wk"), rows, k_in, dqk, None, Act::None, &mut kk);
                let mut vv = vec![0.0f32; rows * dv];
                gemm::mm_nn(g, pool, &kv_in, p.get("att_wv"), rows, k_in, dv, None, Act::None, &mut vv);
                let (att, att_w) = attention(g, pool, &q, &kk, &vv, mask, b, k_n, heads);
                let cat_w = dmem + dv;
                let mut cat = vec![0.0f32; b * cat_w];
                for j in 0..b {
                    let row = &mut cat[j * cat_w..(j + 1) * cat_w];
                    row[..dmem].copy_from_slice(&out.mem[j * dmem..(j + 1) * dmem]);
                    row[dmem..].copy_from_slice(&att[j * dv..(j + 1) * dv]);
                }
                let mut h = vec![0.0f32; b * dims.d_emb];
                gemm::mm_nn(g, pool, &cat, p.get("att_wo"), b, cat_w, dims.d_emb, Some(p.get("att_bo")), Act::Tanh, &mut h);
                out.q_in = q_in;
                out.q = q;
                out.kv_in = kv_in;
                out.k = kk;
                out.v = vv;
                out.att_w = att_w;
                out.cat = cat;
                out.h = h;
            }
        }
        out
    }

    // ----------------------------------------------------------- backward

    /// Hand-written reverse-mode pass: d loss / d params, in param order.
    fn backward(&self, p: &Params, d: &Data, fwd: &Fwd) -> Vec<Vec<f32>> {
        let dims = self.dims;
        let model = self.spec.model.as_str();
        let pool = &*self.pool;
        let g = self.gemm;
        let b = self.spec.batch;
        let u = 2 * b;
        let dmem = dims.d_mem;
        let demb = dims.d_emb;
        let beta = d.scalar("beta");

        let mut grads: Vec<Vec<f32>> =
            p.vals.iter().map(|v| vec![0.0f32; v.len()]).collect();
        // closures can't borrow `grads` twice; use an index helper
        let gi = |name: &str| p.index[name];

        // ---- loss = bce + beta * (1 - coh)
        // d_bce = 1, d_coh = -beta
        let inv_b = 1.0 / b as f32;
        let d_pos: Vec<f32> = fwd.pos.iter().map(|&x| -inv_b * sigmoid(-x)).collect();
        let d_neg: Vec<f32> = fwd.neg.iter().map(|&x| inv_b * sigmoid(x)).collect();

        // ---- decoder backward (pos and neg heads share parameters)
        let dh = p.get("dec_b1").len();
        let mut d_h = [
            vec![0.0f32; b * demb], // src
            vec![0.0f32; b * demb], // dst
            vec![0.0f32; b * demb], // neg
        ];
        let mut dec_bwd = |x: &[f32], hid: &[f32], d_logit: &[f32], other: usize| {
            let w2 = p.get("dec_w2");
            let mut d_hid = vec![0.0f32; b * dh];
            for j in 0..b {
                let dl = d_logit[j];
                grads[gi("dec_b2")][0] += dl;
                let hrow = &hid[j * dh..(j + 1) * dh];
                let drow = &mut d_hid[j * dh..(j + 1) * dh];
                let g2 = &mut grads[gi("dec_w2")];
                for i in 0..dh {
                    g2[i] += hrow[i] * dl;
                    drow[i] = if hrow[i] > 0.0 { dl * w2[i] } else { 0.0 };
                }
            }
            col_sum_acc(pool, &d_hid, dh, &mut grads[gi("dec_b1")]);
            gemm::mm_tn_acc(g, pool, x, &d_hid, b, 2 * demb, dh, &mut grads[gi("dec_w1")]);
            let mut d_x = vec![0.0f32; b * 2 * demb];
            gemm::mm_nt(g, pool, &d_hid, p.get("dec_w1"), b, dh, 2 * demb, &mut d_x);
            for j in 0..b {
                for i in 0..demb {
                    d_h[0][j * demb + i] += d_x[j * 2 * demb + i];
                    d_h[other][j * demb + i] += d_x[j * 2 * demb + demb + i];
                }
            }
        };
        dec_bwd(&fwd.x_pos, &fwd.hid_pos, &d_pos, 1);
        dec_bwd(&fwd.x_neg, &fwd.hid_neg, &d_neg, 2);

        // ---- embeddings backward -> d_mem per role, attention params
        let mut d_s_bar = vec![0.0f32; u * dmem];
        for (ri, role) in ["src", "dst", "neg"].iter().enumerate() {
            let d_mem = self.embed_bwd(p, d, fwd, role, ri, &d_h[ri], &mut grads);
            // splice backward: matched rows route into s_bar, store rows
            // are data (no parameter path)
            let matches = d.i(&format!("c_{role}_match"));
            for j in 0..b {
                if matches[j] >= 0 {
                    let m = matches[j] as usize;
                    for i in 0..dmem {
                        d_s_bar[m * dmem + i] += d_mem[j * dmem + i];
                    }
                }
            }
        }

        // ---- coherence backward into s_bar (a-side is input data)
        {
            let d_coh = -beta;
            let den = (fwd.coh_da * fwd.coh_db).max(1e-9);
            let active = fwd.coh_da * fwd.coh_db > 1e-9;
            let wmask = d.f("u_wmask");
            let u_self = d.f("u_self_mem");
            for r in 0..u {
                let w = wmask[r];
                if w == 0.0 {
                    continue;
                }
                for i in 0..dmem {
                    let idx = r * dmem + i;
                    let a = u_self[idx] * w;
                    let bv = fwd.s_bar[idx] * w;
                    let mut g = a / den;
                    if active {
                        g -= fwd.coh * bv / (fwd.coh_db * fwd.coh_db);
                    }
                    // d b / d s_bar = w
                    d_s_bar[idx] += d_coh * g * w;
                }
            }
        }

        // ---- PRES correction backward
        let pres_on = d.scalar("pres_on");
        let u_cmask = d.f("u_cmask");
        let u_pred = d.f("u_pred");
        let mut d_s_new = vec![0.0f32; u * dmem];
        let mut d_gamma = 0.0f32;
        for r in 0..u {
            let g = fwd.gamma_rows[r];
            let gate = pres_on * u_cmask[r];
            let mut d_grow = 0.0f32;
            for i in 0..dmem {
                let idx = r * dmem + i;
                d_s_new[idx] = d_s_bar[idx] * g;
                d_grow += d_s_bar[idx] * (fwd.s_new[idx] - u_pred[idx]);
            }
            d_gamma += d_grow * gate;
        }
        grads[gi("gamma_raw")][0] += d_gamma * fwd.gamma * (1.0 - fwd.gamma);

        // ---- memory cell backward -> d_msg
        let u_self = d.f("u_self_mem");
        let dm = dims.d_msg;
        let mut d_msg = vec![0.0f32; u * dm];
        if model == "jodie" {
            // s_new = tanh(msg wx + h wh + b)
            let mut d_pre = vec![0.0f32; u * dmem];
            for idx in 0..u * dmem {
                d_pre[idx] = d_s_new[idx] * (1.0 - fwd.s_new[idx] * fwd.s_new[idx]);
            }
            col_sum_acc(pool, &d_pre, dmem, &mut grads[gi("rnn_b")]);
            gemm::mm_tn_acc(g, pool, &fwd.msg, &d_pre, u, dm, dmem, &mut grads[gi("rnn_wx")]);
            gemm::mm_tn_acc(g, pool, u_self, &d_pre, u, dmem, dmem, &mut grads[gi("rnn_wh")]);
            gemm::mm_nt(g, pool, &d_pre, p.get("rnn_wx"), u, dmem, dm, &mut d_msg);
        } else {
            let d3 = 3 * dmem;
            let mut d_gx = vec![0.0f32; u * d3];
            let mut d_gh = vec![0.0f32; u * d3];
            for rr in 0..u {
                for j in 0..dmem {
                    let idx = rr * dmem + j;
                    let (r, z, n) = (fwd.r[idx], fwd.z[idx], fwd.cand[idx]);
                    let h = u_self[idx];
                    let ds = d_s_new[idx];
                    let d_n = ds * (1.0 - z);
                    let d_z = ds * (h - n);
                    let d_pre_n = d_n * (1.0 - n * n);
                    let gh_n = fwd.gh[rr * d3 + 2 * dmem + j];
                    let d_r = d_pre_n * gh_n;
                    let d_pre_z = d_z * z * (1.0 - z);
                    let d_pre_r = d_r * r * (1.0 - r);
                    d_gx[rr * d3 + j] = d_pre_r;
                    d_gh[rr * d3 + j] = d_pre_r;
                    d_gx[rr * d3 + dmem + j] = d_pre_z;
                    d_gh[rr * d3 + dmem + j] = d_pre_z;
                    d_gx[rr * d3 + 2 * dmem + j] = d_pre_n;
                    d_gh[rr * d3 + 2 * dmem + j] = d_pre_n * r;
                }
            }
            {
                let gb = &mut grads[gi("gru_b")];
                let (b0, b1) = gb.split_at_mut(d3);
                col_sum_acc(pool, &d_gx, d3, b0);
                col_sum_acc(pool, &d_gh, d3, b1);
            }
            gemm::mm_tn_acc(g, pool, &fwd.msg, &d_gx, u, dm, d3, &mut grads[gi("gru_wx")]);
            gemm::mm_tn_acc(g, pool, u_self, &d_gh, u, dmem, d3, &mut grads[gi("gru_wh")]);
            gemm::mm_nt(g, pool, &d_gx, p.get("gru_wx"), u, d3, dm, &mut d_msg);
        }

        // ---- MSG MLP backward (u_msg output carries no loss gradient)
        let mh = p.get("msg_b1").len();
        let de = dims.d_edge;
        let dt_w = dims.d_time;
        let msg_in = 2 * dmem + de + dt_w;
        col_sum_acc(pool, &d_msg, dm, &mut grads[gi("msg_b2")]);
        gemm::mm_tn_acc(g, pool, &fwd.h1, &d_msg, u, mh, dm, &mut grads[gi("msg_w2")]);
        let mut d_h1 = vec![0.0f32; u * mh];
        gemm::mm_nt(g, pool, &d_msg, p.get("msg_w2"), u, dm, mh, &mut d_h1);
        for (dv, &hv) in d_h1.iter_mut().zip(&fwd.h1) {
            if hv <= 0.0 {
                *dv = 0.0;
            }
        }
        col_sum_acc(pool, &d_h1, mh, &mut grads[gi("msg_b1")]);
        gemm::mm_tn_acc(g, pool, &fwd.x_msg, &d_h1, u, msg_in, mh, &mut grads[gi("msg_w1")]);
        let mut d_x = vec![0.0f32; u * msg_in];
        gemm::mm_nt(g, pool, &d_h1, p.get("msg_w1"), u, mh, msg_in, &mut d_x);
        // only the phi(dt) slice reaches parameters (the rest is data)
        let mut d_phi_u = vec![0.0f32; u * dt_w];
        for r in 0..u {
            d_phi_u[r * dt_w..(r + 1) * dt_w]
                .copy_from_slice(&d_x[r * msg_in + 2 * dmem + de..(r + 1) * msg_in]);
        }
        {
            let (go, gp) = split_two(&mut grads, gi("time_omega"), gi("time_phi"));
            time_enc_bwd(pool, d.f("u_dt"), p.get("time_omega"), p.get("time_phi"), &d_phi_u, go, gp);
        }
        grads
    }

    /// Backward through one role's embedding; returns d_mem [b, d_mem].
    #[allow(clippy::too_many_arguments)]
    fn embed_bwd(
        &self,
        p: &Params,
        d: &Data,
        fwd: &Fwd,
        role: &str,
        ri: usize,
        d_h: &[f32],
        grads: &mut [Vec<f32>],
    ) -> Vec<f32> {
        let dims = self.dims;
        let pool = &*self.pool;
        let g = self.gemm;
        let b = self.spec.batch;
        let (dmem, dt_w, k_n, heads) = (dims.d_mem, dims.d_time, dims.k_nbr, dims.heads);
        let rf = &fwd.roles[ri];
        let gi = |name: &str| p.index[name];
        match self.spec.model.as_str() {
            "jodie" => {
                let dt = d.f(&format!("c_{role}_dt"));
                let w = p.get("proj_w");
                let mut d_mem = vec![0.0f32; b * dmem];
                for j in 0..b {
                    for i in 0..dmem {
                        let idx = j * dmem + i;
                        d_mem[idx] = d_h[idx] * (1.0 + dt[j] * w[i]);
                        grads[gi("proj_w")][i] += d_h[idx] * rf.mem[idx] * dt[j];
                    }
                }
                d_mem
            }
            "apan" => {
                let mask = d.f(&format!("n_{role}_mask"));
                let k_in = dims.d_msg + dt_w;
                let dqk = p.get("att_wq").len() / dmem;
                let dv = p.get("att_wv").len() / k_in;
                let rows = b * k_n;
                let cat_w = dmem + 2 * dv;
                // h = tanh(cat @ wo + bo)
                let mut d_pre = vec![0.0f32; b * dims.d_emb];
                for (i, dp) in d_pre.iter_mut().enumerate() {
                    *dp = d_h[i] * (1.0 - rf.h[i] * rf.h[i]);
                }
                col_sum_acc(pool, &d_pre, dims.d_emb, &mut grads[gi("att_bo")]);
                gemm::mm_tn_acc(g, pool, &rf.cat, &d_pre, b, cat_w, dims.d_emb, &mut grads[gi("att_wo")]);
                let mut d_cat = vec![0.0f32; b * cat_w];
                gemm::mm_nt(g, pool, &d_pre, p.get("att_wo"), b, dims.d_emb, cat_w, &mut d_cat);
                let mut d_mem = vec![0.0f32; b * dmem];
                let mut d_att = vec![0.0f32; b * dv];
                let mut d_pooled = vec![0.0f32; b * dv];
                for j in 0..b {
                    let row = &d_cat[j * cat_w..(j + 1) * cat_w];
                    d_mem[j * dmem..(j + 1) * dmem].copy_from_slice(&row[..dmem]);
                    d_att[j * dv..(j + 1) * dv].copy_from_slice(&row[dmem..dmem + dv]);
                    d_pooled[j * dv..(j + 1) * dv].copy_from_slice(&row[dmem + dv..]);
                }
                let (d_q, d_k, mut d_v) =
                    attention_bwd(&rf.q, &rf.k, &rf.v, mask, &rf.att_w, &d_att, b, k_n, heads);
                masked_mean_bwd(mask, b, k_n, dv, &d_pooled, &mut d_v);
                // kv projections
                gemm::mm_tn_acc(g, pool, &rf.kv_in, &d_k, rows, k_in, dqk, &mut grads[gi("att_wk")]);
                gemm::mm_tn_acc(g, pool, &rf.kv_in, &d_v, rows, k_in, dv, &mut grads[gi("att_wv")]);
                let mut d_kv = vec![0.0f32; rows * k_in];
                gemm::mm_nt(g, pool, &d_k, p.get("att_wk"), rows, dqk, k_in, &mut d_kv);
                let mut d_kv2 = vec![0.0f32; rows * k_in];
                gemm::mm_nt(g, pool, &d_v, p.get("att_wv"), rows, dv, k_in, &mut d_kv2);
                for (a, &bv) in d_kv.iter_mut().zip(&d_kv2) {
                    *a += bv;
                }
                // phi(dt) slice -> time encoder params
                let mut d_phi = vec![0.0f32; rows * dt_w];
                for r in 0..rows {
                    d_phi[r * dt_w..(r + 1) * dt_w]
                        .copy_from_slice(&d_kv[r * k_in + dims.d_msg..(r + 1) * k_in]);
                }
                {
                    let (go, gp) = split_two(grads, gi("time_omega"), gi("time_phi"));
                    time_enc_bwd(
                        pool,
                        d.f(&format!("n_{role}_dt")),
                        p.get("time_omega"),
                        p.get("time_phi"),
                        &d_phi,
                        go,
                        gp,
                    );
                }
                // q = mem @ wq
                gemm::mm_tn_acc(g, pool, &rf.mem, &d_q, b, dmem, dqk, &mut grads[gi("att_wq")]);
                let mut d_mem_q = vec![0.0f32; b * dmem];
                gemm::mm_nt(g, pool, &d_q, p.get("att_wq"), b, dqk, dmem, &mut d_mem_q);
                for (a, &bv) in d_mem.iter_mut().zip(&d_mem_q) {
                    *a += bv;
                }
                d_mem
            }
            _ => {
                // tgn
                let mask = d.f(&format!("n_{role}_mask"));
                let de = dims.d_edge;
                let q_in_w = dmem + dt_w;
                let dqk = p.get("att_wq").len() / q_in_w;
                let k_in = dmem + de + dt_w;
                let dv = p.get("att_wv").len() / k_in;
                let rows = b * k_n;
                let cat_w = dmem + dv;
                let mut d_pre = vec![0.0f32; b * dims.d_emb];
                for (i, dp) in d_pre.iter_mut().enumerate() {
                    *dp = d_h[i] * (1.0 - rf.h[i] * rf.h[i]);
                }
                col_sum_acc(pool, &d_pre, dims.d_emb, &mut grads[gi("att_bo")]);
                gemm::mm_tn_acc(g, pool, &rf.cat, &d_pre, b, cat_w, dims.d_emb, &mut grads[gi("att_wo")]);
                let mut d_cat = vec![0.0f32; b * cat_w];
                gemm::mm_nt(g, pool, &d_pre, p.get("att_wo"), b, dims.d_emb, cat_w, &mut d_cat);
                let mut d_mem = vec![0.0f32; b * dmem];
                let mut d_att = vec![0.0f32; b * dv];
                for j in 0..b {
                    let row = &d_cat[j * cat_w..(j + 1) * cat_w];
                    d_mem[j * dmem..(j + 1) * dmem].copy_from_slice(&row[..dmem]);
                    d_att[j * dv..(j + 1) * dv].copy_from_slice(&row[dmem..]);
                }
                let (d_q, d_k, d_v) =
                    attention_bwd(&rf.q, &rf.k, &rf.v, mask, &rf.att_w, &d_att, b, k_n, heads);
                gemm::mm_tn_acc(g, pool, &rf.kv_in, &d_k, rows, k_in, dqk, &mut grads[gi("att_wk")]);
                gemm::mm_tn_acc(g, pool, &rf.kv_in, &d_v, rows, k_in, dv, &mut grads[gi("att_wv")]);
                let mut d_kv = vec![0.0f32; rows * k_in];
                gemm::mm_nt(g, pool, &d_k, p.get("att_wk"), rows, dqk, k_in, &mut d_kv);
                let mut d_kv2 = vec![0.0f32; rows * k_in];
                gemm::mm_nt(g, pool, &d_v, p.get("att_wv"), rows, dv, k_in, &mut d_kv2);
                for (a, &bv) in d_kv.iter_mut().zip(&d_kv2) {
                    *a += bv;
                }
                let mut d_phi = vec![0.0f32; rows * dt_w];
                for r in 0..rows {
                    d_phi[r * dt_w..(r + 1) * dt_w]
                        .copy_from_slice(&d_kv[r * k_in + dmem + de..(r + 1) * k_in]);
                }
                {
                    let (go, gp) = split_two(grads, gi("time_omega"), gi("time_phi"));
                    time_enc_bwd(
                        pool,
                        d.f(&format!("n_{role}_dt")),
                        p.get("time_omega"),
                        p.get("time_phi"),
                        &d_phi,
                        go,
                        gp,
                    );
                }
                // q = q_in @ wq with q_in = [mem | phi(0)]
                gemm::mm_tn_acc(g, pool, &rf.q_in, &d_q, b, q_in_w, dqk, &mut grads[gi("att_wq")]);
                let mut d_q_in = vec![0.0f32; b * q_in_w];
                gemm::mm_nt(g, pool, &d_q, p.get("att_wq"), b, dqk, q_in_w, &mut d_q_in);
                let zeros = vec![0.0f32; b];
                let mut d_phi0 = vec![0.0f32; b * dt_w];
                for j in 0..b {
                    for i in 0..dmem {
                        d_mem[j * dmem + i] += d_q_in[j * q_in_w + i];
                    }
                    d_phi0[j * dt_w..(j + 1) * dt_w]
                        .copy_from_slice(&d_q_in[j * q_in_w + dmem..(j + 1) * q_in_w]);
                }
                {
                    let (go, gp) = split_two(grads, gi("time_omega"), gi("time_phi"));
                    time_enc_bwd(pool, &zeros, p.get("time_omega"), p.get("time_phi"), &d_phi0, go, gp);
                }
                d_mem
            }
        }
    }

    // -------------------------------------------------- classifier head

    fn run_clf(&self, args: &[&Literal]) -> Result<Vec<Literal>> {
        let train = self.spec.kind == "train";
        let n = self.n_params;
        let b = self.spec.batch;
        let demb = self.dims.d_emb;
        let p = self.parse_params(args)?;
        let ch = p.get("clf_b1").len();
        let pool = &*self.pool;
        let g = self.gemm;
        let data_off = if train { 3 * n } else { n };
        let emb = read_f32(args[data_off], &self.spec.inputs[data_off])?;

        // forward: relu MLP over frozen embeddings (bias + relu fused)
        let mut hid = vec![0.0f32; b * ch];
        gemm::mm_nn(g, pool, &emb, p.get("clf_w1"), b, demb, ch, Some(p.get("clf_b1")), Act::Relu, &mut hid);
        let w2 = p.get("clf_w2");
        let b2 = p.get("clf_b2")[0];
        let logits: Vec<f32> =
            hid.chunks_exact(ch).map(|row| gemm::dot(g, row, w2) + b2).collect();

        if !train {
            return Ok(vec![lit_f32(&logits, &[b])?]);
        }

        let labels = read_f32(args[data_off + 1], &self.spec.inputs[data_off + 1])?;
        let weight = read_f32(args[data_off + 2], &self.spec.inputs[data_off + 2])?;
        let lr = read_f32(args[args.len() - 2], &self.spec.inputs[args.len() - 2])?[0];
        let t = read_f32(args[args.len() - 1], &self.spec.inputs[args.len() - 1])?[0];
        let wsum: f32 = weight.iter().sum::<f32>().max(1.0);
        let loss = logits
            .iter()
            .zip(&labels)
            .zip(&weight)
            .map(|((&lg, &y), &w)| w * (y * softplus(-lg) + (1.0 - y) * softplus(lg)))
            .sum::<f32>()
            / wsum;

        // backward
        let mut grads: Vec<Vec<f32>> =
            p.vals.iter().map(|v| vec![0.0f32; v.len()]).collect();
        let gi = |name: &str| p.index[name];
        let mut d_hid = vec![0.0f32; b * ch];
        for j in 0..b {
            // d loss / d logit = w * (sigmoid(logit) - y) / wsum
            let dl = weight[j] * (sigmoid(logits[j]) - labels[j]) / wsum;
            grads[gi("clf_b2")][0] += dl;
            let hrow = &hid[j * ch..(j + 1) * ch];
            let drow = &mut d_hid[j * ch..(j + 1) * ch];
            let g2 = &mut grads[gi("clf_w2")];
            for i in 0..ch {
                g2[i] += hrow[i] * dl;
                drow[i] = if hrow[i] > 0.0 { dl * w2[i] } else { 0.0 };
            }
        }
        col_sum_acc(pool, &d_hid, ch, &mut grads[gi("clf_b1")]);
        gemm::mm_tn_acc(g, pool, &emb, &d_hid, b, demb, ch, &mut grads[gi("clf_w1")]);

        let mut m: Vec<Vec<f32>> = Vec::with_capacity(n);
        let mut v: Vec<Vec<f32>> = Vec::with_capacity(n);
        for i in 0..n {
            m.push(read_f32(args[n + i], &self.spec.inputs[n + i])?);
            v.push(read_f32(args[2 * n + i], &self.spec.inputs[2 * n + i])?);
        }
        let mut new_p = p.vals.clone();
        adam_update(&mut new_p, &grads, &mut m, &mut v, lr, t);
        let mut outputs = Vec::with_capacity(self.spec.outputs.len());
        for (vals, spec) in new_p.iter().zip(&self.spec.inputs[..n]) {
            outputs.push(lit_f32(vals, &spec.shape)?);
        }
        for (vals, spec) in m.iter().zip(&self.spec.inputs[..n]) {
            outputs.push(lit_f32(vals, &spec.shape)?);
        }
        for (vals, spec) in v.iter().zip(&self.spec.inputs[..n]) {
            outputs.push(lit_f32(vals, &spec.shape)?);
        }
        outputs.push(lit_f32(&[loss], &[])?);
        outputs.push(lit_f32(&logits, &[b])?);
        Ok(outputs)
    }
}

/// Two distinct mutable gradient banks out of the flat gradient list
/// (omega/phi always travel together through the time encoder).
fn split_two(grads: &mut [Vec<f32>], a: usize, b: usize) -> (&mut [f32], &mut [f32]) {
    debug_assert_ne!(a, b);
    let (lo, hi) = (a.min(b), a.max(b));
    let (head, tail) = grads.split_at_mut(hi);
    if a < b {
        (head[lo].as_mut_slice(), tail[0].as_mut_slice())
    } else {
        (tail[0].as_mut_slice(), head[lo].as_mut_slice())
    }
}

/// Masked multi-head scaled-dot attention over K neighbors (kernels/ref.py
/// `temporal_attention`). Returns (out [b, H*dv], att weights [b, H, K]).
/// Score dot products dispatch on the GEMM backend: naive keeps the
/// sequential sum, blocked uses the 8-lane [`gemm::dot`] reduction.
#[allow(clippy::too_many_arguments)]
fn attention(
    kind: GemmBackendKind,
    pool: &WorkerPool,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    mask: &[f32],
    b: usize,
    kk: usize,
    heads: usize,
) -> (Vec<f32>, Vec<f32>) {
    let hdk = q.len() / b;
    let hdv = v.len() / (b * kk);
    let dk = hdk / heads;
    let dv = hdv / heads;
    let scale = 1.0 / (dk as f32).sqrt();
    assert!(kk <= 64, "attention scratch sized for K <= 64 neighbors");
    let mut out = vec![0.0f32; b * hdv];
    let mut att_w = vec![0.0f32; b * heads * kk];
    // fan out over batch rows; each row writes its own out + att_w slots
    {
        struct Task<'a> {
            i: usize,
            out: &'a mut [f32],
            att: &'a mut [f32],
        }
        let mut tasks: Vec<Task> = Vec::with_capacity(b);
        {
            let mut out_cur = out.as_mut_slice();
            let mut att_cur = att_w.as_mut_slice();
            for i in 0..b {
                tasks.push(Task {
                    i,
                    out: take_chunk(&mut out_cur, hdv),
                    att: take_chunk(&mut att_cur, heads * kk),
                });
            }
        }
        pool.run(&mut tasks, |t| {
            let i = t.i;
            for h in 0..heads {
                let qrow = &q[i * hdk + h * dk..i * hdk + (h + 1) * dk];
                let mut scores = [0.0f32; 64];
                let scores = &mut scores[..kk];
                let mut maxs = f32::NEG_INFINITY;
                for (s, sc) in scores.iter_mut().enumerate() {
                    let krow = &k[(i * kk + s) * hdk + h * dk..(i * kk + s) * hdk + (h + 1) * dk];
                    let dot = gemm::dot(kind, qrow, krow);
                    let mut val = dot * scale;
                    val += (1.0 - mask[i * kk + s]) * -1e9;
                    *sc = val;
                    maxs = maxs.max(val);
                }
                let mut denom = 0.0f32;
                for (s, sc) in scores.iter_mut().enumerate() {
                    *sc = (*sc - maxs).exp() * mask[i * kk + s];
                    denom += *sc;
                }
                let inv = 1.0 / denom.max(1e-9);
                for (s, sc) in scores.iter().enumerate() {
                    let a = sc * inv;
                    t.att[h * kk + s] = a;
                    if a != 0.0 {
                        let vrow =
                            &v[(i * kk + s) * hdv + h * dv..(i * kk + s) * hdv + (h + 1) * dv];
                        let orow = &mut t.out[h * dv..(h + 1) * dv];
                        for (o, &vv) in orow.iter_mut().zip(vrow) {
                            *o += a * vv;
                        }
                    }
                }
            }
        });
    }
    (out, att_w)
}

/// Reverse-mode of [`attention`]: given d_out [b, H*dv] and the saved
/// softmax weights, produce (d_q, d_k, d_v).
#[allow(clippy::too_many_arguments)]
fn attention_bwd(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    _mask: &[f32],
    att_w: &[f32],
    d_out: &[f32],
    b: usize,
    kk: usize,
    heads: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let hdk = q.len() / b;
    let hdv = d_out.len() / b;
    let dk = hdk / heads;
    let dv = hdv / heads;
    let scale = 1.0 / (dk as f32).sqrt();
    assert!(kk <= 64, "attention scratch sized for K <= 64 neighbors");
    let mut d_q = vec![0.0f32; b * hdk];
    let mut d_k = vec![0.0f32; b * kk * hdk];
    let mut d_v = vec![0.0f32; b * kk * hdv];
    for i in 0..b {
        for h in 0..heads {
            let dorow = &d_out[i * hdv + h * dv..i * hdv + (h + 1) * dv];
            // d_att and d_v
            let mut d_att = [0.0f32; 64];
            let d_att = &mut d_att[..kk];
            let mut inner = 0.0f32;
            for s in 0..kk {
                let a = att_w[(i * heads + h) * kk + s];
                let vrow = &v[(i * kk + s) * hdv + h * dv..(i * kk + s) * hdv + (h + 1) * dv];
                let dvrow =
                    &mut d_v[(i * kk + s) * hdv + h * dv..(i * kk + s) * hdv + (h + 1) * dv];
                let mut da = 0.0f32;
                for ((&g, &vv), dvv) in dorow.iter().zip(vrow).zip(dvrow.iter_mut()) {
                    da += g * vv;
                    *dvv += a * g;
                }
                d_att[s] = da;
                inner += a * da;
            }
            // masked softmax vjp (att rows are zero at masked slots, so
            // they contribute nothing — same as the reference formula)
            let qrow = &q[i * hdk + h * dk..i * hdk + (h + 1) * dk];
            let dqrow_base = i * hdk + h * dk;
            for s in 0..kk {
                let a = att_w[(i * heads + h) * kk + s];
                if a == 0.0 {
                    continue;
                }
                let d_score = a * (d_att[s] - inner) * scale;
                let krow = &k[(i * kk + s) * hdk + h * dk..(i * kk + s) * hdk + (h + 1) * dk];
                let dkrow =
                    &mut d_k[(i * kk + s) * hdk + h * dk..(i * kk + s) * hdk + (h + 1) * dk];
                for (j, (&kv, dkv)) in krow.iter().zip(dkrow.iter_mut()).enumerate() {
                    d_q[dqrow_base + j] += d_score * kv;
                    *dkv += d_score * qrow[j];
                }
            }
        }
    }
    (d_q, d_k, d_v)
}

/// Masked mean over the K axis (kernels/ref.py `masked_mean`).
fn masked_mean(v: &[f32], mask: &[f32], b: usize, kk: usize, dv: usize, out: &mut [f32]) {
    for i in 0..b {
        let den = mask[i * kk..(i + 1) * kk].iter().sum::<f32>().max(1.0);
        let orow = &mut out[i * dv..(i + 1) * dv];
        orow.fill(0.0);
        for s in 0..kk {
            let m = mask[i * kk + s];
            if m != 0.0 {
                let vrow = &v[(i * kk + s) * dv..(i * kk + s + 1) * dv];
                for (o, &x) in orow.iter_mut().zip(vrow) {
                    *o += m * x;
                }
            }
        }
        for o in orow.iter_mut() {
            *o /= den;
        }
    }
}

/// Reverse-mode of [`masked_mean`], accumulating into `d_v`.
fn masked_mean_bwd(mask: &[f32], b: usize, kk: usize, dv: usize, d_out: &[f32], d_v: &mut [f32]) {
    for i in 0..b {
        let den = mask[i * kk..(i + 1) * kk].iter().sum::<f32>().max(1.0);
        let dorow = &d_out[i * dv..(i + 1) * dv];
        for s in 0..kk {
            let m = mask[i * kk + s];
            if m != 0.0 {
                let dvrow = &mut d_v[(i * kk + s) * dv..(i * kk + s + 1) * dv];
                for (o, &g) in dvrow.iter_mut().zip(dorow) {
                    *o += m * g / den;
                }
            }
        }
    }
}

/// The artifact's Adam, bias-corrected with `t = step_t` (model.py
/// `_adam`). Updates params and moments in place.
pub(crate) fn adam_update(
    params: &mut [Vec<f32>],
    grads: &[Vec<f32>],
    m: &mut [Vec<f32>],
    v: &mut [Vec<f32>],
    lr: f32,
    t: f32,
) {
    let bc1 = 1.0 - ADAM_B1.powf(t);
    let bc2 = 1.0 - ADAM_B2.powf(t);
    for (((pv, gv), mv), vv) in params.iter_mut().zip(grads).zip(m.iter_mut()).zip(v.iter_mut()) {
        for i in 0..pv.len() {
            let g = gv[i];
            mv[i] = ADAM_B1 * mv[i] + (1.0 - ADAM_B1) * g;
            vv[i] = ADAM_B2 * vv[i] + (1.0 - ADAM_B2) * g * g;
            let step = lr * (mv[i] / bc1) / ((vv[i] / bc2).sqrt() + ADAM_EPS);
            pv[i] -= step;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::init_host;
    use crate::runtime::manifest::{builtin_param_specs, Manifest};
    use crate::util::rng::Pcg32;

    const B: usize = 3;

    fn pool() -> Arc<WorkerPool> {
        Arc::new(WorkerPool::new(1))
    }

    fn make_step(model: &str, kind: &str, pool: Arc<WorkerPool>) -> HostStep {
        make_step_gemm(model, kind, GemmBackendKind::Blocked, pool)
    }

    fn make_step_gemm(
        model: &str,
        kind: &str,
        g: GemmBackendKind,
        pool: Arc<WorkerPool>,
    ) -> HostStep {
        let m = Manifest::builtin();
        let spec = ArtifactSpec::host(m.dims, model, B, kind).unwrap();
        let n = m.param_specs(model).unwrap().len();
        HostStep::new(spec, m.dims, n, pool, g)
    }

    fn make_params(model: &str, seed: u64) -> Params {
        let m = Manifest::builtin();
        let specs = builtin_param_specs(m.dims, model);
        let mut rng = Pcg32::new(seed);
        let mut index = BTreeMap::new();
        let mut vals = Vec::new();
        for (i, s) in specs.iter().enumerate() {
            index.insert(s.name.clone(), i);
            vals.push(init_host(s, &mut rng));
        }
        Params { index, vals }
    }

    /// Well-formed random data exercising every path: mixed masks, real
    /// lag-one matches, pres gating on, nonzero beta.
    fn make_data(step: &HostStep, seed: u64, pres_on: f32) -> Data {
        let mut rng = Pcg32::new(seed ^ 0xDA7A);
        let mut f = BTreeMap::new();
        let mut i = BTreeMap::new();
        let n = step.n_params;
        let train = step.spec.kind == "train";
        let off = if train { 3 * n } else { n };
        let count = step.spec.inputs.len() - off - if train { 2 } else { 0 };
        let u = 2 * step.spec.batch;
        for spec in &step.spec.inputs[off..off + count] {
            match spec.dtype {
                DType::I32 => {
                    // alternate between "no match" and a valid update row
                    let vals: Vec<i32> = (0..spec.elems())
                        .map(|_| {
                            if rng.below(2) == 0 {
                                -1
                            } else {
                                rng.below(u as u32) as i32
                            }
                        })
                        .collect();
                    i.insert(spec.name.clone(), vals);
                }
                DType::F32 => {
                    let vals: Vec<f32> = if spec.name == "pres_on" {
                        vec![pres_on]
                    } else if spec.name == "beta" {
                        vec![0.3]
                    } else if spec.name.ends_with("_mask") || spec.name == "u_wmask"
                        || spec.name == "u_cmask"
                    {
                        (0..spec.elems()).map(|_| rng.below(2) as f32).collect()
                    } else if spec.name.ends_with("_dt") {
                        (0..spec.elems()).map(|_| rng.f32() * 3.0).collect()
                    } else {
                        (0..spec.elems()).map(|_| rng.normal() * 0.3).collect()
                    };
                    f.insert(spec.name.clone(), vals);
                }
            }
        }
        Data { f, i }
    }

    /// Directional finite-difference check, one direction per parameter
    /// tensor: (L(p + eps u) - L(p - eps u)) / 2eps vs grad . u.
    fn grad_check(model: &str) {
        let pool = pool();
        let step = make_step(model, "train", pool);
        let p = make_params(model, 11);
        let d = make_data(&step, 5, 1.0);
        let fwd = step.forward(&p, &d);
        assert!(fwd.loss.is_finite(), "{model} loss {}", fwd.loss);
        let grads = step.backward(&p, &d, &fwd);
        let eps = 5e-3f32;
        let mut rng = Pcg32::new(99);
        let mut checked = 0;
        // iterate in ABI order (not keyed-map order) so each tensor draws
        // the same direction every run — the check must be reproducible
        let specs = builtin_param_specs(Manifest::builtin().dims, model);
        for (name_idx, ps) in specs.iter().enumerate() {
            let ti = ps.name.as_str();
            let dir: Vec<f32> = (0..p.vals[name_idx].len()).map(|_| rng.normal()).collect();
            let ana: f32 = grads[name_idx].iter().zip(&dir).map(|(&g, &u)| g * u).sum();
            let mut plus = Params { index: p.index.clone(), vals: p.vals.clone() };
            let mut minus = Params { index: p.index.clone(), vals: p.vals.clone() };
            for (j, &uj) in dir.iter().enumerate() {
                plus.vals[name_idx][j] += eps * uj;
                minus.vals[name_idx][j] -= eps * uj;
            }
            let lp = step.forward(&plus, &d).loss;
            let lm = step.forward(&minus, &d).loss;
            let num = (lp - lm) / (2.0 * eps);
            let tol = 3e-2 * (num.abs() + ana.abs()) + 2e-3;
            assert!(
                (num - ana).abs() <= tol,
                "{model}/{ti}: numeric {num} vs analytic {ana} (tol {tol})"
            );
            checked += 1;
        }
        assert!(checked >= 10, "{model}: only {checked} tensors checked");
    }

    #[test]
    fn tgn_gradients_match_finite_differences() {
        grad_check("tgn");
    }

    #[test]
    fn jodie_gradients_match_finite_differences() {
        grad_check("jodie");
    }

    #[test]
    fn apan_gradients_match_finite_differences() {
        grad_check("apan");
    }

    #[test]
    fn standard_mode_delta_is_exactly_zero() {
        // pres_on = 0 -> gamma_rows = 1 -> s_bar == s_new bitwise
        let step = make_step("tgn", "eval", pool());
        let p = make_params("tgn", 3);
        let d = make_data(&step, 7, 0.0);
        let fwd = step.forward(&p, &d);
        assert_eq!(fwd.s_bar, fwd.s_new);
        assert!(fwd.gamma_rows.iter().all(|&g| g == 1.0));
    }

    #[test]
    fn pres_mode_produces_innovation_on_gated_rows() {
        let step = make_step("tgn", "eval", pool());
        let p = make_params("tgn", 3);
        let mut d = make_data(&step, 7, 1.0);
        d.f.get_mut("u_cmask").unwrap()[0] = 1.0; // at least one gated row
        let fwd = step.forward(&p, &d);
        assert!(
            fwd.s_bar.iter().zip(&fwd.s_new).any(|(&a, &b)| a != b),
            "PRES mode should correct gated rows"
        );
    }

    #[test]
    fn outputs_are_lane_count_invariant() {
        // the exactness invariant: matmul chunking moves work, never
        // values — on BOTH gemm backends
        for g in [GemmBackendKind::Naive, GemmBackendKind::Blocked] {
            let serial = make_step_gemm("tgn", "train", g, Arc::new(WorkerPool::new(1)));
            let pooled = make_step_gemm("tgn", "train", g, Arc::new(WorkerPool::new(4)));
            let p = make_params("tgn", 21);
            let d = make_data(&serial, 13, 1.0);
            let fa = serial.forward(&p, &d);
            let fb = pooled.forward(&p, &d);
            assert_eq!(fa.loss, fb.loss, "{g:?}");
            assert_eq!(fa.s_bar, fb.s_bar, "{g:?}");
            assert_eq!(fa.pos, fb.pos, "{g:?}");
            assert_eq!(fa.roles[0].h, fb.roles[0].h, "{g:?}");
            let ga = serial.backward(&p, &d, &fa);
            let gb = pooled.backward(&p, &d, &fb);
            assert_eq!(ga, gb, "{g:?}: gradients must be bit-identical across lane counts");
        }
    }

    #[test]
    fn naive_and_blocked_steps_agree_within_tolerance() {
        // the cross-backend contract: NN products match bitwise (same
        // per-element accumulation order), so everything upstream of the
        // decoder/attention dot reductions is exactly equal; losses and
        // gradients differ only by the documented reduction reordering
        for model in ["tgn", "jodie", "apan"] {
            let a = make_step_gemm(model, "train", GemmBackendKind::Naive, pool());
            let bl = make_step_gemm(model, "train", GemmBackendKind::Blocked, pool());
            let p = make_params(model, 11);
            let d = make_data(&a, 5, 1.0);
            let fa = a.forward(&p, &d);
            let fb = bl.forward(&p, &d);
            assert_eq!(fa.s_new, fb.s_new, "{model}: NN chain must match bitwise");
            assert_eq!(fa.s_bar, fb.s_bar, "{model}");
            assert!(
                (fa.loss - fb.loss).abs() <= 1e-4 * (1.0 + fa.loss.abs()),
                "{model}: loss {} vs {}",
                fa.loss,
                fb.loss
            );
            let ga = a.backward(&p, &d, &fa);
            let gb = bl.backward(&p, &d, &fb);
            for (ta, tb) in ga.iter().zip(&gb) {
                for (&x, &y) in ta.iter().zip(tb) {
                    let tol = 1e-3 * (x.abs() + y.abs()) + 1e-4;
                    assert!((x - y).abs() <= tol, "{model}: grad {x} vs {y}");
                }
            }
        }
    }

    #[test]
    fn splice_prefers_fresh_rows_over_store_rows() {
        let step = make_step("jodie", "eval", pool());
        let p = make_params("jodie", 1);
        let mut d = make_data(&step, 1, 0.0);
        // row 0 matched to update row 2, row 1 unmatched
        let matches = d.i.get_mut("c_src_match").unwrap();
        matches[0] = 2;
        matches[1] = -1;
        let fwd = step.forward(&p, &d);
        let dm = step.dims.d_mem;
        assert_eq!(&fwd.roles[0].mem[..dm], &fwd.s_bar[2 * dm..3 * dm]);
        assert_eq!(&fwd.roles[0].mem[dm..2 * dm], &d.f("c_src_mem")[dm..2 * dm]);
    }

    #[test]
    fn coherence_is_a_cosine() {
        let step = make_step("tgn", "eval", pool());
        let p = make_params("tgn", 2);
        let d = make_data(&step, 2, 0.0);
        let fwd = step.forward(&p, &d);
        assert!((-1.0..=1.0).contains(&fwd.coh), "coherence {}", fwd.coh);
        assert!(fwd.bce > 0.0);
        assert!((fwd.loss - (fwd.bce + 0.3 * (1.0 - fwd.coh))).abs() < 1e-5);
    }

    /// Data literals in spec order for a run() call (make_data keys by
    /// name; the ABI is positional).
    fn data_literals(step: &HostStep, d: &Data) -> Vec<Literal> {
        let n = step.n_params;
        let train = step.spec.kind == "train";
        let off = if train { 3 * n } else { n };
        let count = step.spec.inputs.len() - off - if train { 2 } else { 0 };
        step.spec.inputs[off..off + count]
            .iter()
            .map(|s| match s.dtype {
                DType::F32 => lit_f32(d.f(&s.name), &s.shape).unwrap(),
                DType::I32 => crate::runtime::engine::lit_i32(d.i(&s.name), &s.shape).unwrap(),
            })
            .collect()
    }

    #[test]
    fn grad_kind_plus_coordinator_adam_matches_fused_train() {
        // the contract behind relaxed-parameter-staleness EXEC: a lane
        // running the grad-kind step plus the coordinator applying
        // `adam_update` must be BIT-IDENTICAL to the fused train step —
        // otherwise p >= 1 at lag 0 would already diverge from p = 0
        for model in ["tgn", "jodie", "apan"] {
            let train = make_step(model, "train", pool());
            let grad = make_step(model, "grad", pool());
            let n = train.n_params;
            let p = make_params(model, 11);
            let d = make_data(&train, 5, 1.0);
            let mut rng = Pcg32::new(47);
            let m0: Vec<Vec<f32>> =
                p.vals.iter().map(|v| v.iter().map(|_| rng.normal() * 0.01).collect()).collect();
            let v0: Vec<Vec<f32>> =
                p.vals.iter().map(|v| v.iter().map(|_| rng.f32() * 0.01).collect()).collect();
            let (lr, t) = (1e-3f32, 3.0f32);

            // fused train run
            let mut args: Vec<Literal> = Vec::new();
            for (vals, s) in p.vals.iter().zip(&train.spec.inputs[..n]) {
                args.push(lit_f32(vals, &s.shape).unwrap());
            }
            for bank in [&m0, &v0] {
                for (vals, s) in bank.iter().zip(&train.spec.inputs[..n]) {
                    args.push(lit_f32(vals, &s.shape).unwrap());
                }
            }
            args.extend(data_literals(&train, &d));
            args.push(lit_f32(&[lr], &[]).unwrap());
            args.push(lit_f32(&[t], &[]).unwrap());
            let refs: Vec<&Literal> = args.iter().collect();
            let fused = train.run(&refs).unwrap();

            // grad run + coordinator-side Adam
            let mut gargs: Vec<Literal> = Vec::new();
            for (vals, s) in p.vals.iter().zip(&grad.spec.inputs[..n]) {
                gargs.push(lit_f32(vals, &s.shape).unwrap());
            }
            gargs.extend(data_literals(&grad, &d));
            let grefs: Vec<&Literal> = gargs.iter().collect();
            let gouts = grad.run(&grefs).unwrap();
            assert_eq!(gouts.len(), n + 9, "{model}: grads + 9 step outputs");
            let mut grads: Vec<Vec<f32>> = Vec::with_capacity(n);
            for (lit, s) in gouts[..n].iter().zip(&grad.spec.outputs[..n]) {
                let mut buf = vec![0.0f32; s.elems()];
                lit.copy_raw_to(&mut buf).unwrap();
                grads.push(buf);
            }
            let mut np = p.vals.clone();
            let mut nm = m0.clone();
            let mut nv = v0.clone();
            adam_update(&mut np, &grads, &mut nm, &mut nv, lr, t);

            for i in 0..n {
                let s = &train.spec.inputs[i];
                for (j, bank) in [&np, &nm, &nv].into_iter().enumerate() {
                    let mut got = vec![0.0f32; s.elems()];
                    fused[j * n + i].copy_raw_to(&mut got).unwrap();
                    assert_eq!(got, bank[i], "{model}: bank {j} tensor {} diverged", s.name);
                }
            }
            // the step outputs (metrics, write-back rows) match too
            for k in 0..9 {
                let s = &train.spec.outputs[3 * n + k];
                let mut a = vec![0.0f32; s.elems()];
                let mut b = vec![0.0f32; s.elems()];
                fused[3 * n + k].copy_raw_to(&mut a).unwrap();
                gouts[n + k].copy_raw_to(&mut b).unwrap();
                assert_eq!(a, b, "{model}: step output {} diverged", s.name);
            }
        }
    }

    #[test]
    fn adam_matches_reference_formula() {
        let mut p = vec![vec![1.0f32, -2.0]];
        let g = vec![vec![0.5f32, -0.25]];
        let mut m = vec![vec![0.0f32; 2]];
        let mut v = vec![vec![0.0f32; 2]];
        adam_update(&mut p, &g, &mut m, &mut v, 1e-2, 1.0);
        // t = 1: m_hat = g, v_hat = g^2 -> step ~ lr * sign(g)
        assert!((p[0][0] - (1.0 - 1e-2)).abs() < 1e-4, "{}", p[0][0]);
        assert!((p[0][1] - (-2.0 + 1e-2)).abs() < 1e-4, "{}", p[0][1]);
        assert!((m[0][0] - 0.05).abs() < 1e-6);
        assert!((v[0][0] - 0.00025).abs() < 1e-8);
    }

    #[test]
    fn attention_respects_masks_and_normalizes() {
        let pool = WorkerPool::new(1);
        let (b, kk, heads, dk) = (2usize, 4usize, 2usize, 3usize);
        let mut rng = Pcg32::new(17);
        let q: Vec<f32> = (0..b * heads * dk).map(|_| rng.normal()).collect();
        let k: Vec<f32> = (0..b * kk * heads * dk).map(|_| rng.normal()).collect();
        let v: Vec<f32> = (0..b * kk * heads * dk).map(|_| rng.normal()).collect();
        // row 0: slots 0 and 2 live; row 1: fully masked
        let mask = vec![1.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        let (out, att) =
            attention(GemmBackendKind::Blocked, &pool, &q, &k, &v, &mask, b, kk, heads);
        for h in 0..heads {
            let s: f32 = att[h * kk..(h + 1) * kk].iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "weights must normalize, got {s}");
            assert_eq!(att[h * kk + 1], 0.0);
            assert_eq!(att[h * kk + 3], 0.0);
        }
        // fully-masked row: zero weights, zero output
        assert!(att[heads * kk..].iter().all(|&a| a == 0.0));
        assert!(out[heads * dk..].iter().all(|&o| o == 0.0));
    }

    #[test]
    fn masked_mean_matches_reference() {
        let v = vec![
            1.0, 2.0, /* slot0 */ 3.0, 4.0, /* slot1 */ 5.0, 6.0, /* slot2 */
        ];
        let mask = vec![1.0, 0.0, 1.0];
        let mut out = vec![0.0; 2];
        masked_mean(&v, &mask, 1, 3, 2, &mut out);
        assert_eq!(out, vec![3.0, 4.0]); // mean of slots 0 and 2
        // empty mailbox -> zeros (den clamps to 1)
        let mut empty = vec![7.0; 2];
        masked_mean(&v, &[0.0, 0.0, 0.0], 1, 3, 2, &mut empty);
        assert_eq!(empty, vec![0.0, 0.0]);
    }

    #[test]
    fn clf_train_descends_on_separable_embeddings() {
        let m = Manifest::builtin();
        let b = m.dims.clf_batch;
        let spec = ArtifactSpec::host(m.dims, "clf", b, "train").unwrap();
        let step = HostStep::new(spec, m.dims, 4, pool(), GemmBackendKind::Blocked);
        let mut p = make_params_clf(7);
        let mut mm: Vec<Vec<f32>> = p.vals.iter().map(|v| vec![0.0; v.len()]).collect();
        let mut vv = mm.clone();
        // separable: label = 1 iff emb[0] > 0
        let mut rng = Pcg32::new(31);
        let mut emb = vec![0.0f32; b * m.dims.d_emb];
        let mut labels = vec![0.0f32; b];
        let weight = vec![1.0f32; b];
        for j in 0..b {
            let x = rng.normal();
            emb[j * m.dims.d_emb] = x;
            labels[j] = (x > 0.0) as u8 as f32;
        }
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        for t in 1..=40u64 {
            let mut args: Vec<Literal> = Vec::new();
            for (vals, s) in p.vals.iter().zip(&step.spec.inputs[..4]) {
                args.push(lit_f32(vals, &s.shape).unwrap());
            }
            for (vals, s) in mm.iter().zip(&step.spec.inputs[..4]) {
                args.push(lit_f32(vals, &s.shape).unwrap());
            }
            for (vals, s) in vv.iter().zip(&step.spec.inputs[..4]) {
                args.push(lit_f32(vals, &s.shape).unwrap());
            }
            args.push(lit_f32(&emb, &[b, m.dims.d_emb]).unwrap());
            args.push(lit_f32(&labels, &[b]).unwrap());
            args.push(lit_f32(&weight, &[b]).unwrap());
            args.push(lit_f32(&[0.05], &[]).unwrap());
            args.push(lit_f32(&[t as f32], &[]).unwrap());
            let refs: Vec<&Literal> = args.iter().collect();
            let outs = step.run(&refs).unwrap();
            // absorb params/m/v
            for i in 0..4 {
                outs[i].copy_raw_to(&mut p.vals[i]).unwrap();
                outs[4 + i].copy_raw_to(&mut mm[i]).unwrap();
                outs[8 + i].copy_raw_to(&mut vv[i]).unwrap();
            }
            let mut loss = [0.0f32];
            outs[12].copy_raw_to(&mut loss).unwrap();
            if t == 1 {
                first = loss[0];
            }
            last = loss[0];
        }
        assert!(
            last < first * 0.7,
            "clf loss should descend on separable data: {first} -> {last}"
        );
    }

    fn make_params_clf(seed: u64) -> Params {
        let m = Manifest::builtin();
        let specs = crate::runtime::manifest::builtin_clf_param_specs(m.dims);
        let mut rng = Pcg32::new(seed);
        let mut index = BTreeMap::new();
        let mut vals = Vec::new();
        for (i, s) in specs.iter().enumerate() {
            index.insert(s.name.clone(), i);
            vals.push(init_host(s, &mut rng));
        }
        Params { index, vals }
    }
}
