//! artifacts/manifest.json: the ABI contract between python/compile (which
//! lowered the steps) and this crate (which packs positional inputs).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.get("name")?.as_str()?.to_string(),
            shape: j.get("shape")?.as_shape()?,
            dtype: match j.get("dtype")?.as_str()? {
                "f32" => DType::F32,
                "i32" => DType::I32,
                other => bail!("unsupported dtype '{other}'"),
            },
        })
    }
}

/// Parameter initialization schemes (mirrors model.py's spec kinds).
#[derive(Clone, Debug, PartialEq)]
pub enum InitSpec {
    Zeros,
    Const(Vec<f32>),
    GlorotUniform { fan_in: usize, fan_out: usize },
}

#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: InitSpec,
}

impl ParamSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<ParamSpec> {
        let init = j.get("init")?;
        let kind = init.get("kind")?.as_str()?;
        let init = match kind {
            "zeros" => InitSpec::Zeros,
            "const" => InitSpec::Const(
                init.get("values")?
                    .as_arr()?
                    .iter()
                    .map(|v| v.as_f32())
                    .collect::<Result<_>>()?,
            ),
            "glorot_uniform" => InitSpec::GlorotUniform {
                fan_in: init.get("fan_in")?.as_usize()?,
                fan_out: init.get("fan_out")?.as_usize()?,
            },
            other => bail!("unsupported init kind '{other}'"),
        };
        Ok(ParamSpec {
            name: j.get("name")?.as_str()?.to_string(),
            shape: j.get("shape")?.as_shape()?,
            init,
        })
    }
}

/// One compiled step: (model, batch, kind) -> HLO file + positional ABI.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub model: String,
    pub kind: String,
    pub batch: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactSpec {
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|t| t.name == name)
            .ok_or_else(|| anyhow!("artifact {}: no input '{name}'", self.name))
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|t| t.name == name)
            .ok_or_else(|| anyhow!("artifact {}: no output '{name}'", self.name))
    }
}

/// Model dimension conventions (DESIGN.md §3), read from the manifest so
/// rust and python can never drift apart.
#[derive(Clone, Copy, Debug)]
pub struct Dims {
    pub d_mem: usize,
    pub d_msg: usize,
    pub d_edge: usize,
    pub d_time: usize,
    pub k_nbr: usize,
    pub heads: usize,
    pub d_emb: usize,
    pub clf_batch: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub dims: Dims,
    pub params: BTreeMap<String, Vec<ParamSpec>>,
    pub clf_params: Vec<ParamSpec>,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let j = Json::parse_file(&dir.join("manifest.json"))
            .context("loading artifact manifest (run `make artifacts`?)")?;
        let d = j.get("dims")?;
        let dims = Dims {
            d_mem: d.get("d_mem")?.as_usize()?,
            d_msg: d.get("d_msg")?.as_usize()?,
            d_edge: d.get("d_edge")?.as_usize()?,
            d_time: d.get("d_time")?.as_usize()?,
            k_nbr: d.get("k_nbr")?.as_usize()?,
            heads: d.get("heads")?.as_usize()?,
            d_emb: d.get("d_emb")?.as_usize()?,
            clf_batch: d.get("clf_batch")?.as_usize()?,
        };
        let mut params = BTreeMap::new();
        for (model, specs) in j.get("params")?.as_obj()? {
            let list: Vec<ParamSpec> = specs
                .as_arr()?
                .iter()
                .map(ParamSpec::from_json)
                .collect::<Result<_>>()?;
            params.insert(model.clone(), list);
        }
        let clf_params = j
            .get("clf_params")?
            .as_arr()?
            .iter()
            .map(ParamSpec::from_json)
            .collect::<Result<_>>()?;
        let mut artifacts = Vec::new();
        for a in j.get("artifacts")?.as_arr()? {
            artifacts.push(ArtifactSpec {
                name: a.get("name")?.as_str()?.to_string(),
                file: a.get("file")?.as_str()?.to_string(),
                model: a.get("model")?.as_str()?.to_string(),
                kind: a.get("kind")?.as_str()?.to_string(),
                batch: a.get("batch")?.as_usize()?,
                inputs: a
                    .get("inputs")?
                    .as_arr()?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<_>>()?,
                outputs: a
                    .get("outputs")?
                    .as_arr()?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<_>>()?,
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            dims,
            params,
            clf_params,
            artifacts,
        })
    }

    /// Find the artifact for (model, batch, kind).
    pub fn artifact(&self, model: &str, batch: usize, kind: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.model == model && a.batch == batch && a.kind == kind)
            .ok_or_else(|| {
                anyhow!(
                    "no artifact for model={model} batch={batch} kind={kind}; \
                     compiled batch sizes: {:?}",
                    self.batches_for(model)
                )
            })
    }

    /// Compiled batch sizes available for a model.
    pub fn batches_for(&self, model: &str) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.model == model && a.kind == "train")
            .map(|a| a.batch)
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    pub fn param_specs(&self, model: &str) -> Result<&[ParamSpec]> {
        if model == "clf" {
            return Ok(&self.clf_params);
        }
        self.params
            .get(model)
            .map(|v| v.as_slice())
            .ok_or_else(|| anyhow!("no param specs for model '{model}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir() -> PathBuf {
        // tests run from the crate root; `make artifacts` must have run
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Skip (with a notice) when `make artifacts` has not run — same
    /// convention as the integration suites, so the tier-1 command passes
    /// on a fresh checkout.
    fn artifacts_available() -> bool {
        let ok = manifest_dir().join("manifest.json").exists();
        if !ok {
            eprintln!("skipping manifest test: no compiled artifacts");
        }
        ok
    }

    #[test]
    fn loads_real_manifest() {
        if !artifacts_available() {
            return;
        }
        let m = Manifest::load(&manifest_dir()).expect("run `make artifacts` first");
        assert_eq!(m.dims.d_mem, 64);
        assert!(m.params.contains_key("tgn"));
        assert!(!m.clf_params.is_empty());
        let a = m.artifact("tgn", 100, "train").unwrap();
        assert_eq!(a.inputs[0].name, "time_omega");
        // train outputs start with updated params, in spec order
        assert_eq!(a.outputs[0].name, "time_omega");
        assert!(m.batches_for("tgn").contains(&200));
    }

    #[test]
    fn abi_positions_are_stable() {
        if !artifacts_available() {
            return;
        }
        let m = Manifest::load(&manifest_dir()).unwrap();
        let a = m.artifact("jodie", 100, "eval").unwrap();
        let n_params = m.param_specs("jodie").unwrap().len();
        // eval ABI: params then data; first data input is u_self_mem
        assert_eq!(a.inputs[n_params].name, "u_self_mem");
        assert_eq!(a.output_index("pos_logit").unwrap() + 1, a.output_index("neg_logit").unwrap());
    }

    #[test]
    fn missing_artifact_is_informative() {
        if !artifacts_available() {
            return;
        }
        let m = Manifest::load(&manifest_dir()).unwrap();
        let err = m.artifact("tgn", 12345, "train").unwrap_err().to_string();
        assert!(err.contains("compiled batch sizes"));
    }
}
