//! The step ABI contract, from either side of the backend split:
//!
//! * **PJRT**: `artifacts/manifest.json`, written by python/compile (which
//!   lowered the steps) and parsed here so this crate can pack positional
//!   inputs against the compiled executables;
//! * **Host**: [`Manifest::builtin`], the same dims / parameter specs /
//!   input-output orders generated natively (mirroring
//!   `python/compile/model.py` line for line), so the pure-Rust host step
//!   backend speaks the identical ABI without any artifact directory.
//!
//! [`ArtifactSpec::host`] synthesizes the positional spec for any
//! `(model, batch, kind)` — the host backend is not restricted to the
//! compiled batch matrix.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.get("name")?.as_str()?.to_string(),
            shape: j.get("shape")?.as_shape()?,
            dtype: match j.get("dtype")?.as_str()? {
                "f32" => DType::F32,
                "i32" => DType::I32,
                other => bail!("unsupported dtype '{other}'"),
            },
        })
    }
}

/// Parameter initialization schemes (mirrors model.py's spec kinds).
#[derive(Clone, Debug, PartialEq)]
pub enum InitSpec {
    Zeros,
    Const(Vec<f32>),
    GlorotUniform { fan_in: usize, fan_out: usize },
}

#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: InitSpec,
}

impl ParamSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<ParamSpec> {
        let init = j.get("init")?;
        let kind = init.get("kind")?.as_str()?;
        let init = match kind {
            "zeros" => InitSpec::Zeros,
            "const" => InitSpec::Const(
                init.get("values")?
                    .as_arr()?
                    .iter()
                    .map(|v| v.as_f32())
                    .collect::<Result<_>>()?,
            ),
            "glorot_uniform" => InitSpec::GlorotUniform {
                fan_in: init.get("fan_in")?.as_usize()?,
                fan_out: init.get("fan_out")?.as_usize()?,
            },
            other => bail!("unsupported init kind '{other}'"),
        };
        Ok(ParamSpec {
            name: j.get("name")?.as_str()?.to_string(),
            shape: j.get("shape")?.as_shape()?,
            init,
        })
    }
}

/// One compiled step: (model, batch, kind) -> HLO file + positional ABI.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub model: String,
    pub kind: String,
    pub batch: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactSpec {
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|t| t.name == name)
            .ok_or_else(|| anyhow!("artifact {}: no input '{name}'", self.name))
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|t| t.name == name)
            .ok_or_else(|| anyhow!("artifact {}: no output '{name}'", self.name))
    }
}

/// Model dimension conventions (DESIGN.md §3), read from the manifest so
/// rust and python can never drift apart.
#[derive(Clone, Copy, Debug)]
pub struct Dims {
    pub d_mem: usize,
    pub d_msg: usize,
    pub d_edge: usize,
    pub d_time: usize,
    pub k_nbr: usize,
    pub heads: usize,
    pub d_emb: usize,
    pub clf_batch: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub dims: Dims,
    pub params: BTreeMap<String, Vec<ParamSpec>>,
    pub clf_params: Vec<ParamSpec>,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let j = Json::parse_file(&dir.join("manifest.json"))
            .context("loading artifact manifest (run `make artifacts`?)")?;
        let d = j.get("dims")?;
        let dims = Dims {
            d_mem: d.get("d_mem")?.as_usize()?,
            d_msg: d.get("d_msg")?.as_usize()?,
            d_edge: d.get("d_edge")?.as_usize()?,
            d_time: d.get("d_time")?.as_usize()?,
            k_nbr: d.get("k_nbr")?.as_usize()?,
            heads: d.get("heads")?.as_usize()?,
            d_emb: d.get("d_emb")?.as_usize()?,
            clf_batch: d.get("clf_batch")?.as_usize()?,
        };
        let mut params = BTreeMap::new();
        for (model, specs) in j.get("params")?.as_obj()? {
            let list: Vec<ParamSpec> = specs
                .as_arr()?
                .iter()
                .map(ParamSpec::from_json)
                .collect::<Result<_>>()?;
            params.insert(model.clone(), list);
        }
        let clf_params = j
            .get("clf_params")?
            .as_arr()?
            .iter()
            .map(ParamSpec::from_json)
            .collect::<Result<_>>()?;
        let mut artifacts = Vec::new();
        for a in j.get("artifacts")?.as_arr()? {
            artifacts.push(ArtifactSpec {
                name: a.get("name")?.as_str()?.to_string(),
                file: a.get("file")?.as_str()?.to_string(),
                model: a.get("model")?.as_str()?.to_string(),
                kind: a.get("kind")?.as_str()?.to_string(),
                batch: a.get("batch")?.as_usize()?,
                inputs: a
                    .get("inputs")?
                    .as_arr()?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<_>>()?,
                outputs: a
                    .get("outputs")?
                    .as_arr()?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<_>>()?,
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            dims,
            params,
            clf_params,
            artifacts,
        })
    }

    /// Find the artifact for (model, batch, kind).
    pub fn artifact(&self, model: &str, batch: usize, kind: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.model == model && a.batch == batch && a.kind == kind)
            .ok_or_else(|| {
                anyhow!(
                    "no artifact for model={model} batch={batch} kind={kind}; \
                     compiled batch sizes: {:?}",
                    self.batches_for(model)
                )
            })
    }

    /// Compiled batch sizes available for a model.
    pub fn batches_for(&self, model: &str) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.model == model && a.kind == "train")
            .map(|a| a.batch)
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    pub fn param_specs(&self, model: &str) -> Result<&[ParamSpec]> {
        if model == "clf" {
            return Ok(&self.clf_params);
        }
        self.params
            .get(model)
            .map(|v| v.as_slice())
            .ok_or_else(|| anyhow!("no param specs for model '{model}'"))
    }
}

// --------------------------------------------------------- builtin manifest
//
// The native mirror of python/compile/model.py's DIMS / param_specs /
// data_input_specs / output_specs. Keep the two in lockstep: the host
// backend promises the EXACT positional ABI the compiled artifacts use, so
// the trainer's packing code cannot tell the backends apart.

/// Model hyper-dimensions that only appear inside parameter shapes
/// (model.py's DIMS entries that `Dims` doesn't carry).
pub const MSG_HIDDEN: usize = 128;
pub const DEC_HIDDEN: usize = 128;
pub const CLF_HIDDEN: usize = 64;
pub const D_QK: usize = 64;
pub const D_VAL: usize = 64;

fn glorot(shape: &[usize]) -> InitSpec {
    InitSpec::GlorotUniform { fan_in: shape[0], fan_out: *shape.last().unwrap() }
}

fn spec(name: &str, shape: &[usize], init: InitSpec) -> ParamSpec {
    ParamSpec { name: name.to_string(), shape: shape.to_vec(), init }
}

fn w(name: &str, shape: &[usize]) -> ParamSpec {
    spec(name, shape, glorot(shape))
}

fn zeros(name: &str, shape: &[usize]) -> ParamSpec {
    spec(name, shape, InitSpec::Zeros)
}

/// TGN-style timescale spread omega_i = 10^(-4i/(D-1)), phi = 0
/// (model.py `_time_encoder_specs`).
fn time_encoder_specs(d_time: usize) -> Vec<ParamSpec> {
    let denom = (d_time - 1).max(1) as f32;
    let omega: Vec<f32> = (0..d_time)
        .map(|i| 10.0f32.powf(-4.0 * i as f32 / denom))
        .collect();
    vec![
        spec("time_omega", &[d_time], InitSpec::Const(omega)),
        spec("time_phi", &[d_time], InitSpec::Const(vec![0.0; d_time])),
    ]
}

/// Ordered parameter specs for `model` (the ABI order; model.py
/// `param_specs`).
pub fn builtin_param_specs(dims: Dims, model: &str) -> Vec<ParamSpec> {
    let (d, dm, de, dt) = (dims.d_mem, dims.d_msg, dims.d_edge, dims.d_time);
    let (dqk, dv, demb) = (D_QK, D_VAL, dims.d_emb);
    let (mh, dh) = (MSG_HIDDEN, DEC_HIDDEN);
    let msg_in = 2 * d + de + dt;

    let mut specs = time_encoder_specs(dt);
    specs.extend([
        w("msg_w1", &[msg_in, mh]),
        zeros("msg_b1", &[mh]),
        w("msg_w2", &[mh, dm]),
        zeros("msg_b2", &[dm]),
    ]);
    if model == "jodie" {
        specs.extend([
            w("rnn_wx", &[dm, d]),
            w("rnn_wh", &[d, d]),
            zeros("rnn_b", &[d]),
            zeros("proj_w", &[d]), // drift starts at identity projection
        ]);
    } else {
        specs.extend([
            w("gru_wx", &[dm, 3 * d]),
            w("gru_wh", &[d, 3 * d]),
            zeros("gru_b", &[2, 3 * d]),
        ]);
    }
    if model == "tgn" {
        let k_in = d + de + dt;
        specs.extend([
            w("att_wq", &[d + dt, dqk]),
            w("att_wk", &[k_in, dqk]),
            w("att_wv", &[k_in, dv]),
            w("att_wo", &[d + dv, demb]),
            zeros("att_bo", &[demb]),
        ]);
    } else if model == "apan" {
        let k_in = dm + dt;
        specs.extend([
            w("att_wq", &[d, dqk]),
            w("att_wk", &[k_in, dqk]),
            w("att_wv", &[k_in, dv]),
            w("att_wo", &[d + 2 * dv, demb]),
            zeros("att_bo", &[demb]),
        ]);
    }
    specs.extend([
        w("dec_w1", &[2 * demb, dh]),
        zeros("dec_b1", &[dh]),
        w("dec_w2", &[dh, 1]),
        zeros("dec_b2", &[1]),
        // PRES learnable fusion gamma (Eq. 8), sigmoid-squashed:
        // raw = 3.9 -> gamma ~ 0.98
        spec("gamma_raw", &[1], InitSpec::Const(vec![3.9])),
    ]);
    specs
}

/// Node-classification head params (model.py `clf_param_specs`).
pub fn builtin_clf_param_specs(dims: Dims) -> Vec<ParamSpec> {
    vec![
        w("clf_w1", &[dims.d_emb, CLF_HIDDEN]),
        zeros("clf_b1", &[CLF_HIDDEN]),
        w("clf_w2", &[CLF_HIDDEN, 1]),
        zeros("clf_b2", &[1]),
    ]
}

fn t_f32(name: &str, shape: &[usize]) -> TensorSpec {
    TensorSpec { name: name.to_string(), shape: shape.to_vec(), dtype: DType::F32 }
}

fn t_i32(name: &str, shape: &[usize]) -> TensorSpec {
    TensorSpec { name: name.to_string(), shape: shape.to_vec(), dtype: DType::I32 }
}

/// Ordered non-parameter inputs (model.py `data_input_specs`).
pub fn builtin_data_input_specs(dims: Dims, model: &str, b: usize) -> Vec<TensorSpec> {
    let (d, dm, de, k) = (dims.d_mem, dims.d_msg, dims.d_edge, dims.k_nbr);
    let u = 2 * b;
    let mut specs = vec![
        t_f32("u_self_mem", &[u, d]),
        t_f32("u_other_mem", &[u, d]),
        t_f32("u_efeat", &[u, de]),
        t_f32("u_dt", &[u]),
        t_f32("u_pred", &[u, d]),
        t_f32("u_wmask", &[u]),
        t_f32("u_cmask", &[u]),
        t_f32("c_src_mem", &[b, d]),
        t_f32("c_dst_mem", &[b, d]),
        t_f32("c_neg_mem", &[b, d]),
        t_i32("c_src_match", &[b]),
        t_i32("c_dst_match", &[b]),
        t_i32("c_neg_match", &[b]),
        t_f32("c_src_dt", &[b]),
        t_f32("c_dst_dt", &[b]),
        t_f32("c_neg_dt", &[b]),
    ];
    if model == "tgn" {
        for role in ["src", "dst", "neg"] {
            specs.push(t_f32(&format!("n_{role}_mem"), &[b, k, d]));
            specs.push(t_f32(&format!("n_{role}_efeat"), &[b, k, de]));
            specs.push(t_f32(&format!("n_{role}_dt"), &[b, k]));
            specs.push(t_f32(&format!("n_{role}_mask"), &[b, k]));
        }
    } else if model == "apan" {
        for role in ["src", "dst", "neg"] {
            specs.push(t_f32(&format!("n_{role}_mail"), &[b, k, dm]));
            specs.push(t_f32(&format!("n_{role}_dt"), &[b, k]));
            specs.push(t_f32(&format!("n_{role}_mask"), &[b, k]));
        }
    }
    specs.push(t_f32("beta", &[]));
    specs.push(t_f32("pres_on", &[]));
    specs
}

/// Ordered step outputs after any params/opt state (model.py
/// `output_specs`).
pub fn builtin_output_specs(dims: Dims, b: usize) -> Vec<TensorSpec> {
    let u = 2 * b;
    vec![
        t_f32("u_sbar", &[u, dims.d_mem]),
        t_f32("u_delta", &[u, dims.d_mem]),
        t_f32("u_msg", &[u, dims.d_msg]),
        t_f32("pos_logit", &[b]),
        t_f32("neg_logit", &[b]),
        t_f32("h_src", &[b, dims.d_emb]),
        t_f32("loss", &[]),
        t_f32("bce", &[]),
        t_f32("coherence", &[]),
    ]
}

impl ArtifactSpec {
    /// Synthesize the positional ABI for a host-executed `(model, batch,
    /// kind)` step — identical to what aot.py would serialize for the same
    /// triple (train: params + m + v + data + lr/step_t in, updated state +
    /// step outputs out; eval: params + data in, step outputs out; grad:
    /// params + data in, per-parameter gradients + step outputs out — the
    /// host-only ABI behind relaxed-parameter-staleness EXEC, where the
    /// coordinator owns the Adam apply instead of the lane).
    pub fn host(dims: Dims, model: &str, batch: usize, kind: &str) -> Result<ArtifactSpec> {
        if !["train", "eval", "grad"].contains(&kind) {
            bail!("unknown step kind '{kind}'");
        }
        if model == "clf" && kind == "grad" {
            bail!("the clf head has no grad-kind step (it never runs on stream lanes)");
        }
        if model == "clf" {
            // the clf head is a fixed-batch artifact in the compiled
            // matrix too — reject mismatches upfront instead of failing
            // with a per-input length error at run()
            if batch != dims.clf_batch {
                bail!(
                    "clf steps exist at batch {} only (got {batch})",
                    dims.clf_batch
                );
            }
            return Ok(Self::host_clf(dims, kind));
        }
        if !["tgn", "jodie", "apan"].contains(&model) {
            bail!("unknown model '{model}'");
        }
        let pspecs = builtin_param_specs(dims, model);
        let params: Vec<TensorSpec> =
            pspecs.iter().map(|p| t_f32(&p.name, &p.shape)).collect();
        let mut inputs = params.clone();
        if kind == "train" {
            for prefix in ["adam_m_", "adam_v_"] {
                inputs.extend(
                    pspecs.iter().map(|p| t_f32(&format!("{prefix}{}", p.name), &p.shape)),
                );
            }
        }
        inputs.extend(builtin_data_input_specs(dims, model, batch));
        let mut outputs = Vec::new();
        if kind == "train" {
            inputs.push(t_f32("lr", &[]));
            inputs.push(t_f32("step_t", &[]));
            outputs.extend(params.clone());
            for prefix in ["adam_m_", "adam_v_"] {
                outputs.extend(
                    pspecs.iter().map(|p| t_f32(&format!("{prefix}{}", p.name), &p.shape)),
                );
            }
        }
        if kind == "grad" {
            // gradients come back in param-spec order, one per parameter,
            // so the coordinator can zip them against its bank directly
            outputs.extend(
                pspecs.iter().map(|p| t_f32(&format!("grad_{}", p.name), &p.shape)),
            );
        }
        outputs.extend(builtin_output_specs(dims, batch));
        Ok(ArtifactSpec {
            name: format!("{model}_b{batch}_{kind}"),
            file: String::new(), // host steps have no HLO file
            model: model.to_string(),
            kind: kind.to_string(),
            batch,
            inputs,
            outputs,
        })
    }

    /// The classifier head's ABI (model.py `make_clf_step`).
    fn host_clf(dims: Dims, kind: &str) -> ArtifactSpec {
        let b = dims.clf_batch;
        let pspecs = builtin_clf_param_specs(dims);
        let params: Vec<TensorSpec> =
            pspecs.iter().map(|p| t_f32(&p.name, &p.shape)).collect();
        let mut inputs = params.clone();
        let mut outputs = Vec::new();
        if kind == "train" {
            for prefix in ["adam_m_", "adam_v_"] {
                inputs.extend(
                    pspecs.iter().map(|p| t_f32(&format!("{prefix}{}", p.name), &p.shape)),
                );
            }
            inputs.push(t_f32("emb", &[b, dims.d_emb]));
            inputs.push(t_f32("labels", &[b]));
            inputs.push(t_f32("weight", &[b]));
            inputs.push(t_f32("lr", &[]));
            inputs.push(t_f32("step_t", &[]));
            outputs.extend(params.clone());
            for prefix in ["adam_m_", "adam_v_"] {
                outputs.extend(
                    pspecs.iter().map(|p| t_f32(&format!("{prefix}{}", p.name), &p.shape)),
                );
            }
            outputs.push(t_f32("loss", &[]));
            outputs.push(t_f32("logits", &[b]));
        } else {
            inputs.push(t_f32("emb", &[b, dims.d_emb]));
            outputs.push(t_f32("logits", &[b]));
        }
        ArtifactSpec {
            name: format!("clf_{kind}"),
            file: String::new(),
            model: "clf".to_string(),
            kind: kind.to_string(),
            batch: b,
            inputs,
            outputs,
        }
    }
}

impl Manifest {
    /// The native manifest backing the host EXEC backend: model.py's DIMS
    /// plus parameter specs for every model — no artifact directory, no
    /// compiled batch matrix ([`ArtifactSpec::host`] synthesizes the ABI
    /// for any batch size on demand).
    pub fn builtin() -> Manifest {
        let dims = Dims {
            d_mem: 64,
            d_msg: 64,
            d_edge: 16,
            d_time: 16,
            k_nbr: 10,
            heads: 2,
            d_emb: 64,
            clf_batch: 256,
        };
        let mut params = BTreeMap::new();
        for model in ["tgn", "jodie", "apan"] {
            params.insert(model.to_string(), builtin_param_specs(dims, model));
        }
        Manifest {
            dir: PathBuf::new(),
            dims,
            params,
            clf_params: builtin_clf_param_specs(dims),
            artifacts: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir() -> PathBuf {
        // tests run from the crate root; `make artifacts` must have run
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Skip (with a notice) when `make artifacts` has not run — same
    /// convention as the integration suites, so the tier-1 command passes
    /// on a fresh checkout.
    fn artifacts_available() -> bool {
        let ok = manifest_dir().join("manifest.json").exists();
        if !ok {
            crate::log_warn!("skipping manifest test: no compiled artifacts");
        }
        ok
    }

    #[test]
    fn loads_real_manifest() {
        if !artifacts_available() {
            return;
        }
        let m = Manifest::load(&manifest_dir()).expect("run `make artifacts` first");
        assert_eq!(m.dims.d_mem, 64);
        assert!(m.params.contains_key("tgn"));
        assert!(!m.clf_params.is_empty());
        let a = m.artifact("tgn", 100, "train").unwrap();
        assert_eq!(a.inputs[0].name, "time_omega");
        // train outputs start with updated params, in spec order
        assert_eq!(a.outputs[0].name, "time_omega");
        assert!(m.batches_for("tgn").contains(&200));
    }

    #[test]
    fn abi_positions_are_stable() {
        if !artifacts_available() {
            return;
        }
        let m = Manifest::load(&manifest_dir()).unwrap();
        let a = m.artifact("jodie", 100, "eval").unwrap();
        let n_params = m.param_specs("jodie").unwrap().len();
        // eval ABI: params then data; first data input is u_self_mem
        assert_eq!(a.inputs[n_params].name, "u_self_mem");
        assert_eq!(a.output_index("pos_logit").unwrap() + 1, a.output_index("neg_logit").unwrap());
    }

    #[test]
    fn builtin_dims_and_params_cover_all_models() {
        let m = Manifest::builtin();
        assert_eq!(m.dims.d_mem, 64);
        assert_eq!(m.dims.clf_batch, 256);
        for model in ["tgn", "jodie", "apan"] {
            let specs = m.param_specs(model).unwrap();
            assert_eq!(specs[0].name, "time_omega");
            assert_eq!(specs.last().unwrap().name, "gamma_raw");
            // omega_0 = 1, omega decays by 10^(-4/15) per index
            match &specs[0].init {
                InitSpec::Const(v) => {
                    assert_eq!(v.len(), 16);
                    assert!((v[0] - 1.0).abs() < 1e-6);
                    assert!((v[15] - 1e-4).abs() < 1e-8);
                }
                other => panic!("time_omega init {other:?}"),
            }
        }
        assert_eq!(m.param_specs("clf").unwrap().len(), 4);
    }

    #[test]
    fn builtin_abi_positions_mirror_compiled_layout() {
        // the invariants `abi_positions_are_stable` pins on the parsed
        // manifest, restated for the synthesized host ABI
        let m = Manifest::builtin();
        for model in ["tgn", "jodie", "apan"] {
            let n_params = m.param_specs(model).unwrap().len();
            let eval = ArtifactSpec::host(m.dims, model, 100, "eval").unwrap();
            assert_eq!(eval.inputs[0].name, "time_omega");
            assert_eq!(eval.inputs[n_params].name, "u_self_mem");
            assert_eq!(eval.outputs[0].name, "u_sbar");
            assert_eq!(
                eval.output_index("pos_logit").unwrap() + 1,
                eval.output_index("neg_logit").unwrap()
            );
            assert_eq!(eval.inputs.last().unwrap().name, "pres_on");

            let train = ArtifactSpec::host(m.dims, model, 100, "train").unwrap();
            assert_eq!(train.inputs.len(), eval.inputs.len() + 2 * n_params + 2);
            assert_eq!(train.inputs[n_params].name, "adam_m_time_omega");
            assert_eq!(train.inputs[3 * n_params].name, "u_self_mem");
            assert_eq!(train.inputs.last().unwrap().name, "step_t");
            assert_eq!(train.outputs[0].name, "time_omega");
            assert_eq!(train.outputs[3 * n_params].name, "u_sbar");
            assert_eq!(train.outputs.len(), 3 * n_params + eval.outputs.len());
            // match indices are the only i32 inputs
            let i32s: Vec<&str> = train
                .inputs
                .iter()
                .filter(|t| t.dtype == DType::I32)
                .map(|t| t.name.as_str())
                .collect();
            assert_eq!(i32s, ["c_src_match", "c_dst_match", "c_neg_match"]);

            // grad kind: eval-shaped inputs (params + data, no optimizer
            // state, no lr/step_t), per-param gradients ahead of the step
            // outputs in param-spec order
            let grad = ArtifactSpec::host(m.dims, model, 100, "grad").unwrap();
            assert_eq!(grad.inputs.len(), eval.inputs.len());
            assert_eq!(grad.inputs[0].name, "time_omega");
            assert_eq!(grad.inputs[n_params].name, "u_self_mem");
            assert_eq!(grad.inputs.last().unwrap().name, "pres_on");
            assert_eq!(grad.outputs.len(), n_params + eval.outputs.len());
            assert_eq!(grad.outputs[0].name, "grad_time_omega");
            assert_eq!(grad.outputs[n_params].name, "u_sbar");
            for (g, p) in grad.outputs[..n_params].iter().zip(m.param_specs(model).unwrap()) {
                assert_eq!(g.name, format!("grad_{}", p.name));
                assert_eq!(g.shape, p.shape, "grad shape mirrors its parameter");
            }
        }
        // the clf head never runs on stream lanes — no grad-kind ABI
        assert!(ArtifactSpec::host(m.dims, "clf", m.dims.clf_batch, "grad").is_err());
        // clf is fixed-batch: the right size resolves, others error early
        assert!(ArtifactSpec::host(m.dims, "clf", m.dims.clf_batch, "train").is_ok());
        let err = ArtifactSpec::host(m.dims, "clf", 64, "eval").unwrap_err().to_string();
        assert!(err.contains("batch"), "{err}");
        // tgn carries neighbor tensors, jodie none, apan mail
        let tgn = ArtifactSpec::host(m.dims, "tgn", 50, "eval").unwrap();
        assert!(tgn.input_index("n_src_efeat").is_ok());
        let jodie = ArtifactSpec::host(m.dims, "jodie", 50, "eval").unwrap();
        assert!(jodie.input_index("n_src_mem").is_err());
        let apan = ArtifactSpec::host(m.dims, "apan", 50, "eval").unwrap();
        assert!(apan.input_index("n_src_mail").is_ok());
        assert!(apan.input_index("n_src_efeat").is_err());
    }

    #[test]
    fn builtin_matches_compiled_manifest_when_artifacts_exist() {
        // the lockstep gate: whenever real artifacts are present, the
        // native mirror must agree tensor-for-tensor with what aot.py wrote
        if !artifacts_available() {
            return;
        }
        let compiled = Manifest::load(&manifest_dir()).unwrap();
        let builtin = Manifest::builtin();
        assert_eq!(builtin.dims.d_mem, compiled.dims.d_mem);
        assert_eq!(builtin.dims.k_nbr, compiled.dims.k_nbr);
        for model in ["tgn", "jodie", "apan"] {
            assert_eq!(
                builtin.param_specs(model).unwrap(),
                compiled.param_specs(model).unwrap(),
                "{model} param specs drifted from the compiled manifest"
            );
        }
        assert_eq!(&builtin.clf_params, &compiled.clf_params);
        for a in &compiled.artifacts {
            let host = ArtifactSpec::host(builtin.dims, &a.model, a.batch, &a.kind).unwrap();
            assert_eq!(host.inputs, a.inputs, "{} inputs drifted", a.name);
            assert_eq!(host.outputs, a.outputs, "{} outputs drifted", a.name);
        }
    }

    #[test]
    fn missing_artifact_is_informative() {
        if !artifacts_available() {
            return;
        }
        let m = Manifest::load(&manifest_dir()).unwrap();
        let err = m.artifact("tgn", 12345, "train").unwrap_err().to_string();
        assert!(err.contains("compiled batch sizes"));
    }
}
