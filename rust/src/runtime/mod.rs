//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) produced
//! by `make artifacts`, compiles them once per process on the CPU PJRT
//! client, and exposes a typed step interface to the trainer.
//!
//! Performance notes (EXPERIMENTS.md §Perf): parameters and optimizer state
//! stay resident as device buffers across steps — only batch data crosses
//! the host boundary per step, and outputs the trainer doesn't consume are
//! never copied back.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, Step};
pub use manifest::{ArtifactSpec, DType, Dims, InitSpec, Manifest, ParamSpec, TensorSpec};
