//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) produced
//! by `make artifacts`, compiles them once per process on the CPU PJRT
//! client, and exposes a typed step interface to the trainer.
//!
//! Performance notes (EXPERIMENTS.md §Perf): parameters and optimizer state
//! stay resident as device buffers across steps — only batch data crosses
//! the host boundary per step, and outputs the trainer doesn't consume are
//! never copied back.
//!
//! ## The Send boundary
//!
//! `Engine` and `Step` are deliberately **not** `Send`/`Sync`: they hold
//! `Rc`s, a `RefCell` compile cache, and raw PJRT client/executable
//! handles whose thread affinity the C API does not guarantee. The
//! pipelined training runtime (`pipeline/`) is designed around that fact
//! rather than against it:
//!
//! * every device handle stays on the **coordinator thread** — SPLICE,
//!   EXEC and WRITEBACK all run there;
//! * the background PREP worker receives only plain host data
//!   (`Arc<Dataset>`, `Arc<Vec<BatchPlan>>`, a cloned `NegativeSampler`)
//!   and sends back plain `PrepBatch` buffers over mpsc channels;
//! * nothing in this module is ever captured by a spawned closure, which
//!   the compiler enforces (`Rc` in `Engine`/`Step` makes them `!Send`).
//!
//! Keep it that way: if a future stage needs device access off-thread
//! (multi-stream exec), give it its own client, don't smuggle this one.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, Step};
pub use manifest::{ArtifactSpec, DType, Dims, InitSpec, Manifest, ParamSpec, TensorSpec};
