//! The EXEC runtime behind the trainer's step calls, split across two
//! backends sharing one ABI (see [`engine::ExecBackendKind`]):
//!
//! * **Pjrt** loads the AOT artifacts (`artifacts/*.hlo.txt`) produced by
//!   `make artifacts`, compiles them once per process on the CPU PJRT
//!   client, and executes through the device runtime;
//! * **Host** (`host_step.rs`) evaluates the identical step — forward,
//!   backward and Adam — in pure Rust over the builtin manifest
//!   (`manifest.rs`), so the full training loop runs with no artifacts at
//!   all. This is the default whenever `artifacts/` is absent.
//!
//! ## The Host/Pjrt ABI contract
//!
//! An [`engine::Engine`] hands out [`engine::Step`]s; a step is a pure
//! function over **positional host literals** in manifest order:
//!
//! ```text
//!   train:  params..., adam_m..., adam_v..., data..., lr, step_t
//!        -> params'..., adam_m'..., adam_v'..., step outputs...
//!   grad:   params..., data...
//!        -> grad_<param>..., step outputs...
//!   eval:   params..., data...  ->  step outputs...
//! ```
//!
//! with `data` and `step outputs` exactly `builtin_data_input_specs` /
//! `builtin_output_specs` (mirrored from python/compile/model.py, pinned
//! against the compiled manifest whenever artifacts exist). Everything the
//! trainer does — `HostBatch::pack`, `ModelState::absorb_outputs`, output
//! fetches by name — goes through the spec, so the backends are
//! interchangeable per step. Differences that remain are numeric only
//! (same formulas, different float-summation order), never structural.
//!
//! Performance notes (EXPERIMENTS.md §Perf): parameters and optimizer state
//! stay resident as literals that thread from one step's outputs into the
//! next step's inputs — only batch data is re-staged per step.
//!
//! ## The parameter-chain contract
//!
//! "train" fuses forward + backward + Adam, so whoever runs it owns the
//! whole optimizer step and the next step *must* consume its outputs —
//! the chain is exact by construction (one step in flight, the
//! `param_staleness = 0` regimes). "grad" (host backend only) splits that
//! fusion: it stops after gradient emission, takes no Adam state and no
//! trailing `lr`/`step_t`, and the **coordinator** owns the optimizer,
//! applying [`host_step::adam_update`] (β1 = 0.9, β2 = 0.999, ε = 1e-8,
//! bias-corrected by `step_t`) strictly in plan order as steps commit.
//! The two decompositions are bit-identical per step — "grad" + a
//! coordinator-side `adam_update` reproduces "train"'s updated bank
//! exactly (unit-tested in `host_step.rs`) — which is what lets the
//! relaxed multi-stream loop (`--param-staleness`, `pipeline/stream.rs`)
//! run several grad steps concurrently against cloned snapshots while the
//! committed parameter sequence stays the plan-order Adam chain, merely
//! evaluated on gradients up to `min(p, streams - 1)` commits stale.
//!
//! ## The Send boundary
//!
//! `Engine` and `Step` are deliberately **not** `Send`/`Sync`: they hold
//! `Rc`s, a `RefCell` compile cache, and (on the PJRT backend) raw client/
//! executable handles whose thread affinity the C API does not guarantee.
//! The pipelined training runtime (`pipeline/`) is designed around that
//! fact rather than against it:
//!
//! * every device handle stays on the **coordinator thread** — SPLICE,
//!   WRITEBACK, and inline EXEC (`exec_streams = 1`, or any stream count
//!   on PJRT, which rejects more) all run there;
//! * the background PREP worker receives only plain host data
//!   (`Arc<Dataset>`, `Arc<Vec<BatchPlan>>`, a cloned `NegativeSampler`)
//!   and sends back plain `PrepBatch` buffers over mpsc channels;
//! * nothing in this module is ever captured by a spawned closure, which
//!   the compiler enforces (`Rc` in `Engine`/`Step` makes them `!Send`).
//!
//! ## GEMM backends (`--gemm {auto | naive | blocked}`)
//!
//! Every host-step matmul routes through the [`gemm`] kernel subsystem,
//! a second closed-enum dispatch ([`gemm::GemmBackendKind`]) nested
//! inside the Host EXEC backend:
//!
//! * **naive** — the original scalar loops, lifted verbatim. Per output
//!   element the accumulation order is exactly the pre-gemm code, and the
//!   fused bias/activation epilogue replays the old separate sweeps
//!   element-for-element, so `--gemm naive` is **bit-identical** to the
//!   pre-gemm host backend (and stays the reference the equivalence gates
//!   pin against).
//! * **blocked** — cache-blocked, register-tiled panels with portable
//!   SIMD-width accumulators, pool-parallel over row panels. NN-shape
//!   products keep the naive per-element accumulation order (bitwise
//!   equal); only the TN-accumulate shape and the dot-product reduction
//!   reorder sums. Tolerance contract: per element
//!   `|Δ| ≤ 1e-5 · k · max|a| · max|b| + 1e-6` (see `gemm.rs`).
//! * **auto** (default) — resolves to blocked.
//!
//! Selection flows `--gemm` / config `"gemm"` → [`Engine::set_host_gemm`]
//! → every [`HostStep`] the engine builds; the PJRT backend ignores it.
//! Per-epoch GEMM time share is reported in `EpochReport` and as a
//! `gemm` stage histogram (`--metrics-out`).
//!
//! The one sanctioned crossing is the raw [`host_step::HostStep`], which
//! IS Send + Sync (plain data plus an `Arc<WorkerPool>`): multi-stream
//! EXEC (`pipeline/stream.rs`, `--exec-streams N`) Arc-shares exactly that
//! type with its executor lanes via [`engine::Step::host_step`], never the
//! `Step`/`Engine` wrappers — and job payloads cross as plain
//! `Vec<f32>`/`Vec<i32>` buffers, never as `xla::Literal`s, so linking the
//! real (non-Send-literal) bindings stays a one-line swap. If a future
//! stage needs *PJRT* access off-thread, give it its own client; don't
//! smuggle this one.

pub mod engine;
pub mod gemm;
pub mod host_step;
pub mod manifest;

pub use engine::{Engine, ExecBackendKind, Step};
pub use gemm::{Act, GemmBackendKind};
pub use host_step::HostStep;
pub use manifest::{ArtifactSpec, DType, Dims, InitSpec, Manifest, ParamSpec, TensorSpec};
