//! The EXEC engine: one typed step interface over two interchangeable
//! backends (see [`ExecBackendKind`]):
//!
//! * **Pjrt** — the original path: HLO-text loading, XLA compile caching,
//!   PJRT execution. Interchange is HLO *text*
//!   (`HloModuleProto::from_text_file`): jax >= 0.5 serializes protos with
//!   64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//!   parser reassigns ids (see /opt/xla-example/README.md).
//! * **Host** — the pure-Rust step (`runtime/host_step.rs`) over the
//!   builtin manifest; no artifacts, no device runtime, any batch size.
//!
//! Both produce [`Step`]s speaking the identical positional literal ABI,
//! so the trainer cannot tell them apart.
//!
//! ## Result handling (PJRT)
//!
//! The bundled PJRT CPU client executes with `untuple_result = false`, so a
//! multi-output step comes back as ONE tuple buffer. `Step::run` therefore
//! syncs it to a host literal and decomposes it — parameters round-trip
//! through the host every step by necessity. The engine keeps this cheap:
//! inputs are built with `Literal::create_from_shape_and_untyped_data`
//! straight from the assembler's reused host buffers (no intermediate
//! copies), and the decomposed output literals are *moved* into the next
//! step's input slots. Measured cost is ~0.2 ms per step at b = 200 vs
//! ~10 ms of step compute (EXPERIMENTS.md §Perf).

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};
use xla::{ElementType, Literal, PjRtClient, PjRtLoadedExecutable};

use crate::runtime::gemm::GemmBackendKind;
use crate::runtime::host_step::HostStep;
use crate::runtime::manifest::{ArtifactSpec, DType, Manifest, TensorSpec};
use crate::util::pool::WorkerPool;

/// Which EXEC backend an [`Engine`] runs steps on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecBackendKind {
    /// AOT-compiled XLA artifacts executed through PJRT.
    Pjrt,
    /// The pure-Rust host step (`runtime/host_step.rs`).
    Host,
}

enum BackendImpl {
    Pjrt {
        client: PjRtClient,
    },
    Host {
        pool: RefCell<Arc<WorkerPool>>,
        gemm: Cell<GemmBackendKind>,
    },
}

/// Process-wide runtime: the manifest + a per-(model, batch, kind) step
/// cache over one of the two EXEC backends.
pub struct Engine {
    backend: BackendImpl,
    manifest: Manifest,
    cache: RefCell<BTreeMap<String, Rc<Step>>>,
}

impl Engine {
    /// Create a PJRT CPU engine over an artifact directory (needs
    /// manifest.json).
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            backend: BackendImpl::Pjrt { client },
            manifest,
            cache: RefCell::new(BTreeMap::new()),
        })
    }

    /// Create a host-native engine over the builtin manifest — runs the
    /// full step ABI in pure Rust on the shared process pool (swap the
    /// pool with [`Engine::set_host_pool`]).
    pub fn host() -> Engine {
        Engine {
            backend: BackendImpl::Host {
                pool: RefCell::new(WorkerPool::global().clone()),
                gemm: Cell::new(GemmBackendKind::Blocked),
            },
            manifest: Manifest::builtin(),
            cache: RefCell::new(BTreeMap::new()),
        }
    }

    /// Resolve an engine for `artifacts_dir` under an exec choice string:
    /// `"pjrt"` requires the artifacts, `"host"` never touches them, and
    /// `"auto"` (the default) picks PJRT exactly when
    /// `artifacts_dir/manifest.json` exists — so a fresh checkout trains
    /// host-native with zero setup.
    pub fn auto(artifacts_dir: &Path, exec: &str) -> Result<Engine> {
        match exec {
            "pjrt" => Engine::new(artifacts_dir),
            "host" => Ok(Engine::host()),
            "auto" | "" => {
                if artifacts_dir.join("manifest.json").exists() {
                    Engine::new(artifacts_dir)
                } else {
                    Ok(Engine::host())
                }
            }
            other => bail!("unknown exec backend '{other}' (pjrt | host | auto)"),
        }
    }

    /// Which backend this engine executes on.
    pub fn backend(&self) -> ExecBackendKind {
        match self.backend {
            BackendImpl::Pjrt { .. } => ExecBackendKind::Pjrt,
            BackendImpl::Host { .. } => ExecBackendKind::Host,
        }
    }

    /// Point host-executed steps at a specific worker pool (the trainer
    /// passes its `--pool-workers` pool so host EXEC matmuls fan out on the
    /// same lanes as SPLICE/WRITEBACK/PREP). Steps created *after* this
    /// call use the new pool; results are lane-count-invariant either way.
    /// No-op on the PJRT backend.
    pub fn set_host_pool(&self, pool: Arc<WorkerPool>) {
        if let BackendImpl::Host { pool: slot, .. } = &self.backend {
            *slot.borrow_mut() = pool;
            self.cache.borrow_mut().clear(); // rebuild steps on the new pool
        }
    }

    /// Select the GEMM kernel backend (`--gemm`) for host-executed steps.
    /// Steps created *after* this call dispatch on the new kind; the step
    /// cache is cleared so stale steps can't mix backends mid-run. No-op
    /// on the PJRT backend.
    pub fn set_host_gemm(&self, kind: GemmBackendKind) {
        if let BackendImpl::Host { gemm, .. } = &self.backend {
            gemm.set(kind);
            self.cache.borrow_mut().clear(); // rebuild steps on the new kernels
        }
    }

    /// The GEMM backend host steps dispatch on (`None` on PJRT).
    pub fn host_gemm(&self) -> Option<GemmBackendKind> {
        match &self.backend {
            BackendImpl::Host { gemm, .. } => Some(gemm.get()),
            BackendImpl::Pjrt { .. } => None,
        }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Load + compile (cached) the step for (model, batch, kind).
    pub fn step(&self, model: &str, batch: usize, kind: &str) -> Result<Rc<Step>> {
        let spec = match &self.backend {
            // host ABI is synthesized for ANY batch size, no artifact matrix
            BackendImpl::Host { .. } => {
                ArtifactSpec::host(self.manifest.dims, model, batch, kind)?
            }
            BackendImpl::Pjrt { .. } => self.manifest.artifact(model, batch, kind)?.clone(),
        };
        if let Some(step) = self.cache.borrow().get(&spec.name) {
            return Ok(step.clone());
        }
        let imp = match &self.backend {
            BackendImpl::Host { pool, gemm } => {
                let n_params = self.manifest.param_specs(model)?.len();
                StepImpl::Host(Arc::new(HostStep::new(
                    spec.clone(),
                    self.manifest.dims,
                    n_params,
                    pool.borrow().clone(),
                    gemm.get(),
                )))
            }
            BackendImpl::Pjrt { client } => {
                let path = self.manifest.dir.join(&spec.file);
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .with_context(|| format!("parsing HLO text {}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .with_context(|| format!("XLA-compiling {}", spec.name))?;
                StepImpl::Pjrt { exe, client: client.clone() }
            }
        };
        let step = Rc::new(Step { spec, imp });
        self.cache
            .borrow_mut()
            .insert(step.spec.name.clone(), step.clone());
        Ok(step)
    }

    /// Number of executables compiled/instantiated so far (perf
    /// accounting; cache hits don't count).
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

// ----------------------------------------------------------- literal helpers

/// Build an f32 literal directly from host data (single copy).
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
    // SAFETY: reinterpreting an f32 slice as its raw bytes — same
    // allocation, `len * 4` bytes, u8 has alignment 1 and no invalid bit
    // patterns; the view ends before `data` does.
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::F32,
        dims,
        bytes,
    )?)
}

/// Build an i32 literal directly from host data.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<Literal> {
    // SAFETY: as in `lit_f32` — i32 slice viewed as `len * 4` raw bytes,
    // u8 alignment 1, no invalid bit patterns, same lifetime as `data`.
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::S32,
        dims,
        bytes,
    )?)
}

pub fn lit_scalar(value: f32) -> Result<Literal> {
    lit_f32(&[value], &[])
}

/// Copy a literal's f32 payload into `out`.
pub fn fetch_f32(lit: &Literal, out: &mut [f32]) -> Result<()> {
    lit.copy_raw_to(out)?;
    Ok(())
}

pub fn fetch_scalar(lit: &Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

/// Build a literal for `spec` from the matching host slice.
pub fn lit_for(spec: &TensorSpec, f32s: &[f32], i32s: &[i32]) -> Result<Literal> {
    match spec.dtype {
        DType::F32 => {
            check_len(spec, f32s.len())?;
            lit_f32(f32s, &spec.shape)
        }
        DType::I32 => {
            check_len(spec, i32s.len())?;
            lit_i32(i32s, &spec.shape)
        }
    }
}

/// Validate that a host slice matches a tensor spec.
pub fn check_len(spec: &TensorSpec, len: usize) -> Result<()> {
    if spec.elems() != len {
        bail!(
            "tensor '{}': host length {len} != spec {:?} ({} elems)",
            spec.name,
            spec.shape,
            spec.elems()
        );
    }
    Ok(())
}

/// One executable step + its ABI — compiled on PJRT or native on the host
/// backend, behind the same `run` contract.
pub struct Step {
    pub spec: ArtifactSpec,
    imp: StepImpl,
}

enum StepImpl {
    Pjrt {
        exe: PjRtLoadedExecutable,
        client: PjRtClient,
    },
    // Arc-shared: the host step is plain data + a pool handle (Send +
    // Sync), so the same instance serves both the coordinator's inline
    // `run` and the EXEC stream lanes (`pipeline/stream.rs`) — and the
    // enum stays lean next to the raw PJRT handles
    Host(Arc<HostStep>),
}

impl Step {
    /// Execute with host literals (owned or borrowed); returns one literal
    /// per manifest output (the PJRT tuple result is synced and decomposed —
    /// see module docs; the host backend produces per-output literals
    /// directly).
    ///
    /// PJRT inputs are staged to device buffers here and executed via
    /// `execute_b` so the rust `PjRtBuffer` wrappers free them on drop.
    /// The crate's literal-based `execute` leaks every input device buffer
    /// (the C shim `release()`s them and never frees) — at ~3 MB/step that
    /// OOM-killed long sweeps before this workaround.
    pub fn run<L: std::borrow::Borrow<Literal>>(&self, args: &[L]) -> Result<Vec<Literal>> {
        if args.len() != self.spec.inputs.len() {
            bail!(
                "step {}: got {} args, ABI expects {}",
                self.spec.name,
                args.len(),
                self.spec.inputs.len()
            );
        }
        let (exe, client) = match &self.imp {
            StepImpl::Host(host) => {
                let borrowed: Vec<&Literal> = args.iter().map(|l| l.borrow()).collect();
                return host.run(&borrowed);
            }
            StepImpl::Pjrt { exe, client } => (exe, client),
        };
        let buffers: Vec<xla::PjRtBuffer> = args
            .iter()
            .map(|lit| client.buffer_from_host_literal(None, lit.borrow()))
            .collect::<std::result::Result<_, _>>()?;
        let mut results = exe.execute_b(&buffers)?;
        let replica = results
            .drain(..)
            .next()
            .ok_or_else(|| anyhow!("no replica outputs"))?;
        let outputs = if replica.len() == 1 && self.spec.outputs.len() > 1 {
            let mut lit = replica[0].to_literal_sync()?;
            lit.decompose_tuple()?
        } else {
            replica
                .iter()
                .map(|b| Ok(b.to_literal_sync()?))
                .collect::<Result<Vec<_>>>()?
        };
        // single-output artifacts still arrive as a 1-tuple (return_tuple=True)
        let outputs = if outputs.len() == 1 && self.spec.outputs.len() == 1 {
            let mut lit = outputs;
            match lit[0].shape()? {
                xla::Shape::Tuple(_) => lit.remove(0).decompose_tuple()?,
                _ => lit,
            }
        } else {
            outputs
        };
        if outputs.len() != self.spec.outputs.len() {
            bail!(
                "step {}: output arity {} != manifest {}",
                self.spec.name,
                outputs.len(),
                self.spec.outputs.len()
            );
        }
        Ok(outputs)
    }

    /// The shared host-step instance when this step executes on the host
    /// backend — what an EXEC stream lane runs (`HostStep` is Send + Sync).
    /// `None` on PJRT: its handles are not Send, so steps cannot leave the
    /// coordinator thread there.
    pub fn host_step(&self) -> Option<Arc<HostStep>> {
        match &self.imp {
            StepImpl::Host(host) => Some(host.clone()),
            StepImpl::Pjrt { .. } => None,
        }
    }

    pub fn input_spec(&self, name: &str) -> Result<&TensorSpec> {
        Ok(&self.spec.inputs[self.spec.input_index(name)?])
    }

    pub fn output_spec(&self, name: &str) -> Result<&TensorSpec> {
        Ok(&self.spec.outputs[self.spec.output_index(name)?])
    }
}
