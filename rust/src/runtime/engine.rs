//! PJRT client wrapper: HLO-text loading, compile caching, typed execution.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax >= 0.5
//! serializes protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! ## Result handling
//!
//! The bundled PJRT CPU client executes with `untuple_result = false`, so a
//! multi-output step comes back as ONE tuple buffer. `Step::run` therefore
//! syncs it to a host literal and decomposes it — parameters round-trip
//! through the host every step by necessity. The engine keeps this cheap:
//! inputs are built with `Literal::create_from_shape_and_untyped_data`
//! straight from the assembler's reused host buffers (no intermediate
//! copies), and the decomposed output literals are *moved* into the next
//! step's input slots. Measured cost is ~0.2 ms per step at b = 200 vs
//! ~10 ms of step compute (EXPERIMENTS.md §Perf).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};
use xla::{ElementType, Literal, PjRtClient, PjRtLoadedExecutable};

use crate::runtime::manifest::{ArtifactSpec, DType, Manifest, TensorSpec};

/// Process-wide runtime: one PJRT CPU client + compiled-executable cache.
pub struct Engine {
    client: PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Step>>>,
}

impl Engine {
    /// Create a CPU engine over an artifact directory (needs manifest.json).
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    /// Load + compile (cached) the step for (model, batch, kind).
    pub fn step(&self, model: &str, batch: usize, kind: &str) -> Result<Rc<Step>> {
        let spec = self.manifest.artifact(model, batch, kind)?.clone();
        if let Some(step) = self.cache.borrow().get(&spec.name) {
            return Ok(step.clone());
        }
        let path = self.manifest.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA-compiling {}", spec.name))?;
        let step = Rc::new(Step {
            spec,
            exe,
            client: self.client.clone(),
        });
        self.cache
            .borrow_mut()
            .insert(step.spec.name.clone(), step.clone());
        Ok(step)
    }

    /// Number of executables compiled so far (perf accounting).
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

// ----------------------------------------------------------- literal helpers

/// Build an f32 literal directly from host data (single copy).
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::F32,
        dims,
        bytes,
    )?)
}

/// Build an i32 literal directly from host data.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<Literal> {
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::S32,
        dims,
        bytes,
    )?)
}

pub fn lit_scalar(value: f32) -> Result<Literal> {
    lit_f32(&[value], &[])
}

/// Copy a literal's f32 payload into `out`.
pub fn fetch_f32(lit: &Literal, out: &mut [f32]) -> Result<()> {
    lit.copy_raw_to(out)?;
    Ok(())
}

pub fn fetch_scalar(lit: &Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

/// Build a literal for `spec` from the matching host slice.
pub fn lit_for(spec: &TensorSpec, f32s: &[f32], i32s: &[i32]) -> Result<Literal> {
    match spec.dtype {
        DType::F32 => {
            check_len(spec, f32s.len())?;
            lit_f32(f32s, &spec.shape)
        }
        DType::I32 => {
            check_len(spec, i32s.len())?;
            lit_i32(i32s, &spec.shape)
        }
    }
}

/// Validate that a host slice matches a tensor spec.
pub fn check_len(spec: &TensorSpec, len: usize) -> Result<()> {
    if spec.elems() != len {
        bail!(
            "tensor '{}': host length {len} != spec {:?} ({} elems)",
            spec.name,
            spec.shape,
            spec.elems()
        );
    }
    Ok(())
}

/// One compiled executable + its ABI.
pub struct Step {
    pub spec: ArtifactSpec,
    exe: PjRtLoadedExecutable,
    client: PjRtClient,
}

impl Step {
    /// Execute with host literals (owned or borrowed); returns one literal
    /// per manifest output (the PJRT tuple result is synced and decomposed —
    /// see module docs).
    ///
    /// Inputs are staged to device buffers here and executed via
    /// `execute_b` so the rust `PjRtBuffer` wrappers free them on drop.
    /// The crate's literal-based `execute` leaks every input device buffer
    /// (the C shim `release()`s them and never frees) — at ~3 MB/step that
    /// OOM-killed long sweeps before this workaround.
    pub fn run<L: std::borrow::Borrow<Literal>>(&self, args: &[L]) -> Result<Vec<Literal>> {
        if args.len() != self.spec.inputs.len() {
            bail!(
                "step {}: got {} args, ABI expects {}",
                self.spec.name,
                args.len(),
                self.spec.inputs.len()
            );
        }
        let buffers: Vec<xla::PjRtBuffer> = args
            .iter()
            .map(|lit| self.client.buffer_from_host_literal(None, lit.borrow()))
            .collect::<std::result::Result<_, _>>()?;
        let mut results = self.exe.execute_b(&buffers)?;
        let replica = results
            .drain(..)
            .next()
            .ok_or_else(|| anyhow!("no replica outputs"))?;
        let outputs = if replica.len() == 1 && self.spec.outputs.len() > 1 {
            let mut lit = replica[0].to_literal_sync()?;
            lit.decompose_tuple()?
        } else {
            replica
                .iter()
                .map(|b| Ok(b.to_literal_sync()?))
                .collect::<Result<Vec<_>>>()?
        };
        // single-output artifacts still arrive as a 1-tuple (return_tuple=True)
        let outputs = if outputs.len() == 1 && self.spec.outputs.len() == 1 {
            let mut lit = outputs;
            match lit[0].shape()? {
                xla::Shape::Tuple(_) => lit.remove(0).decompose_tuple()?,
                _ => lit,
            }
        } else {
            outputs
        };
        if outputs.len() != self.spec.outputs.len() {
            bail!(
                "step {}: output arity {} != manifest {}",
                self.spec.name,
                outputs.len(),
                self.spec.outputs.len()
            );
        }
        Ok(outputs)
    }

    pub fn input_spec(&self, name: &str) -> Result<&TensorSpec> {
        Ok(&self.spec.inputs[self.spec.input_index(name)?])
    }

    pub fn output_spec(&self, name: &str) -> Result<&TensorSpec> {
        Ok(&self.spec.outputs[self.spec.output_index(name)?])
    }
}
