//! Blocked + SIMD GEMM backend for host EXEC, with fused bias/activation.
//!
//! Every matmul the host step runs (`runtime/host_step.rs`) routes through
//! the four entry points here — [`mm_nn`], [`mm_nn_acc`], [`mm_nt`],
//! [`mm_tn_acc`] — plus [`dot`] for the per-head attention scores and the
//! width-1 decoder/classifier heads. Dispatch is a closed enum
//! ([`GemmBackendKind`], the PR 3/4 devirtualization pattern: a `match`,
//! not a vtable), selected per [`HostStep`](crate::runtime::HostStep) via
//! `--gemm {auto|naive|blocked}`:
//!
//! * **Naive** — the original scalar loops, lifted verbatim: ikj
//!   accumulation for NN, a sequential dot per element for NT, and the
//!   zero-skipping r-loop for TN-accumulate (relu-sparse gradients make
//!   the skip worthwhile there). The fused bias/activation epilogue
//!   applies the exact per-element operation sequence the old separate
//!   `add_bias` + activation sweeps did, so `--gemm naive` is
//!   bit-identical to the pre-GEMM-subsystem code.
//! * **Blocked** — a cache-blocked, register-tiled microkernel: B is
//!   packed once per call into zero-padded `MR x NR` column panels
//!   (`[k][NR]` layout, contiguous per panel), and an `MR = 4` by
//!   `NR = 16` tile of accumulators (`[[f32; 16]; 4]` — fixed-size arrays
//!   LLVM keeps in SIMD registers and auto-vectorizes at opt-level 2+)
//!   sweeps the k dimension once per tile. Bias and activation fuse into
//!   the tile write-back, so no separate output sweep ever happens.
//!
//! Both backends fan row panels out on the shared [`WorkerPool`] above the
//! same `MM_PAR_MIN_ROWS` crossover, and both are **bit-identical across
//! lane counts**: every output element is accumulated by exactly one lane
//! in a fixed order, so chunking moves work, never values.
//!
//! ## Tolerance contract (naive vs blocked)
//!
//! Rust never contracts `a * b + c` into an fma and never reassociates
//! float sums, so accumulation order fully determines the result:
//!
//! * `mm_nn` / `mm_nn_acc` / `mm_nt`: the blocked microkernel gives each
//!   output element its own accumulator and walks k in ascending order —
//!   the same per-element order as the naive loops — so these match the
//!   naive backend *bitwise*.
//! * `mm_tn_acc`: naive accumulates directly into `out` (`out += a_i*b_i`
//!   interleaved with the existing value); blocked sums the update into a
//!   fresh accumulator first and applies one `out += acc`. Same terms,
//!   different association.
//! * `dot`: blocked uses eight parallel partial accumulators (chunks of
//!   8) with a fixed-order horizontal reduction; naive is one sequential
//!   sum.
//!
//! The reordered cases differ by at most a few ulps per element — bounded
//! by `k * eps * sum_i |a_i * b_i|` with `eps = f32::EPSILON` — and the
//! property tests below pin every shape against the naive backend with a
//! per-element tolerance of `1e-5 * (k * max|a| * max|b|) + 1e-6`. Epoch
//! level, `tests/gemm_equivalence.rs` gates that naive and blocked train
//! to matching losses/AP within loose tolerance.
//!
//! Shapes here are modest (k <= a few hundred for every step ABI shape),
//! so there is deliberately no k-blocking: an MR-row slab of A plus one
//! packed B panel fit L1, and skipping the k-split keeps per-element
//! accumulation order equal to naive's (the bitwise guarantee above).
//!
//! ## Timing
//!
//! Every `mm_*` call accrues wall time + call count into process-global
//! relaxed atomics ([`timing_totals`]) — cheap enough to stay always-on —
//! and, when telemetry metrics are enabled (`--metrics-out`), records the
//! per-call latency into a global histogram drained per epoch by the
//! trainer ([`take_call_hist`]) into the `gemm` stage histogram
//! (`metrics/timing.rs`). `dot` is *not* timed: it runs per attention
//! score, where a clock read would cost more than the kernel.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::trace::telemetry::metrics_enabled;
use crate::trace::LogHistogram;
use crate::util::pool::{chunk_for, take_chunk, WorkerPool};

/// Register tile height (rows of A per microkernel invocation).
pub const MR: usize = 4;
/// Register tile width (columns of B per packed panel).
pub const NR: usize = 16;

/// Rows below which a pooled matmul stays on one lane (a chunk handoff
/// costs ~1–2 µs; a 64-row by 64-wide GEMM slice is ~0.5 µs of FMA).
pub(crate) const MM_PAR_MIN_ROWS: usize = 64;

/// Which GEMM kernel family a host step runs its matmuls on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmBackendKind {
    /// The original scalar loops (bit-identical to the pre-GEMM code).
    Naive,
    /// Cache-blocked, register-tiled, packed-panel microkernel (default).
    Blocked,
}

impl GemmBackendKind {
    /// Resolve a `--gemm` / config choice string. `auto` (and empty)
    /// resolve to [`GemmBackendKind::Blocked`].
    pub fn resolve(choice: &str) -> Result<GemmBackendKind> {
        match choice {
            "auto" | "" | "blocked" => Ok(GemmBackendKind::Blocked),
            "naive" => Ok(GemmBackendKind::Naive),
            other => bail!("unknown gemm backend '{other}' (auto | naive | blocked)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            GemmBackendKind::Naive => "naive",
            GemmBackendKind::Blocked => "blocked",
        }
    }
}

/// Activation fused into the GEMM epilogue (applied after the optional
/// bias add, element-wise at tile write-back).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    None,
    Relu,
    Tanh,
    Sigmoid,
}

impl Act {
    #[inline(always)]
    fn apply(self, x: f32) -> f32 {
        match self {
            Act::None => x,
            Act::Relu => x.max(0.0),
            Act::Tanh => x.tanh(),
            Act::Sigmoid => 1.0 / (1.0 + (-x).exp()),
        }
    }
}

// --------------------------------------------------------------- timing

static GEMM_NANOS: AtomicU64 = AtomicU64::new(0);
static GEMM_CALLS: AtomicU64 = AtomicU64::new(0);
static GEMM_HIST: OnceLock<Mutex<LogHistogram>> = OnceLock::new();

#[inline]
fn record_call(t0: Instant) {
    let ns = t0.elapsed().as_nanos().min(u64::MAX as u128) as u64;
    GEMM_NANOS.fetch_add(ns, Ordering::Relaxed);
    GEMM_CALLS.fetch_add(1, Ordering::Relaxed);
    if metrics_enabled() {
        let h = GEMM_HIST.get_or_init(|| Mutex::new(LogHistogram::new()));
        if let Ok(mut h) = h.lock() {
            h.record(ns);
        }
    }
}

/// Monotonic process-wide `(nanoseconds, calls)` totals across every timed
/// GEMM entry point. The trainer snapshots this at epoch boundaries and
/// reports the delta as the epoch's GEMM time share.
pub fn timing_totals() -> (u64, u64) {
    (
        GEMM_NANOS.load(Ordering::Relaxed),
        GEMM_CALLS.load(Ordering::Relaxed),
    )
}

/// Drain the per-call latency histogram accumulated since the last drain.
/// Populated only while telemetry metrics are enabled; empty otherwise.
pub fn take_call_hist() -> LogHistogram {
    match GEMM_HIST.get() {
        Some(m) => m.lock().map(|mut h| std::mem::take(&mut *h)).unwrap_or_default(),
        None => LogHistogram::new(),
    }
}

// ------------------------------------------------------ row fan-out

/// Run `f(first_row, rows_chunk)` over `out` split into row chunks across
/// the pool. Per-row outputs land in fixed disjoint slots, so lane count
/// can never change results.
pub(crate) fn par_rows<F>(pool: &WorkerPool, out: &mut [f32], m: usize, row_w: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    par_rows_min(pool, out, m, row_w, MM_PAR_MIN_ROWS, f)
}

/// [`par_rows`] with an explicit parallelism crossover (minimum rows per
/// chunk) for sweeps whose per-row cost differs from a GEMM row.
pub(crate) fn par_rows_min<F>(
    pool: &WorkerPool,
    out: &mut [f32],
    m: usize,
    row_w: usize,
    min_rows: usize,
    f: F,
) where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len(), m * row_w);
    if m == 0 {
        return;
    }
    let chunk = chunk_for(m, pool.lanes(), min_rows);
    let mut tasks: Vec<(usize, &mut [f32])> = Vec::with_capacity(m.div_ceil(chunk));
    let mut cursor = out;
    let mut r0 = 0;
    while r0 < m {
        let rows = chunk.min(m - r0);
        tasks.push((r0, take_chunk(&mut cursor, rows * row_w)));
        r0 += rows;
    }
    pool.run(&mut tasks, |t| f(t.0, &mut *t.1));
}

// ------------------------------------------------------ public entry points

/// `out = act(a @ b + bias)` for `a: [m, k]`, `b: [k, n]` (overwrites
/// `out`; `bias` is per-column, length `n`).
#[allow(clippy::too_many_arguments)]
pub fn mm_nn(
    kind: GemmBackendKind,
    pool: &WorkerPool,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    bias: Option<&[f32]>,
    act: Act,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    debug_assert!(bias.is_none_or(|bv| bv.len() == n));
    let t0 = crate::util::now();
    match kind {
        GemmBackendKind::Naive => naive_nn(pool, a, b, m, k, n, bias, act, false, out),
        GemmBackendKind::Blocked => blocked_mm(pool, a, b, m, k, n, bias, act, false, false, out),
    }
    record_call(t0);
}

/// `out = act(out + a @ b + bias)`: the accumulate flavor of [`mm_nn`],
/// used where a step sums two matmuls before a pointwise epilogue (e.g.
/// the JODIE RNN cell `tanh(msg@wx + h@wh + b)`). Evaluation order per
/// element is `act((out + sum_k a*b) + bias)`.
#[allow(clippy::too_many_arguments)]
pub fn mm_nn_acc(
    kind: GemmBackendKind,
    pool: &WorkerPool,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    bias: Option<&[f32]>,
    act: Act,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    debug_assert!(bias.is_none_or(|bv| bv.len() == n));
    let t0 = crate::util::now();
    match kind {
        GemmBackendKind::Naive => naive_nn(pool, a, b, m, k, n, bias, act, true, out),
        GemmBackendKind::Blocked => blocked_mm(pool, a, b, m, k, n, bias, act, true, false, out),
    }
    record_call(t0);
}

/// `out = a @ b^T` for `a: [m, k]`, `b: [n, k]` (overwrites `out`). No
/// fused epilogue: every step-ABI use is a backward data-gradient.
#[allow(clippy::too_many_arguments)]
pub fn mm_nt(
    kind: GemmBackendKind,
    pool: &WorkerPool,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    let t0 = crate::util::now();
    match kind {
        GemmBackendKind::Naive => naive_nt(pool, a, b, m, k, n, out),
        GemmBackendKind::Blocked => {
            blocked_mm(pool, a, b, m, k, n, None, Act::None, false, true, out)
        }
    }
    record_call(t0);
}

/// `out += a^T @ b` for `a: [r, m]`, `b: [r, n]` (weight-gradient
/// accumulation into a possibly-nonzero `out`).
#[allow(clippy::too_many_arguments)]
pub fn mm_tn_acc(
    kind: GemmBackendKind,
    pool: &WorkerPool,
    a: &[f32],
    b: &[f32],
    r: usize,
    m: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), r * m);
    debug_assert_eq!(b.len(), r * n);
    debug_assert_eq!(out.len(), m * n);
    let t0 = crate::util::now();
    match kind {
        GemmBackendKind::Naive => naive_tn_acc(pool, a, b, r, m, n, out),
        GemmBackendKind::Blocked => blocked_tn_acc(pool, a, b, r, m, n, out),
    }
    record_call(t0);
}

/// Dot product of two equal-length slices. Naive: one sequential sum
/// (bit-identical to `iter().zip().map().sum()`); blocked: eight partial
/// accumulators over chunks of 8 with a fixed-order horizontal reduction,
/// then a sequential tail.
pub fn dot(kind: GemmBackendKind, a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match kind {
        GemmBackendKind::Naive => a.iter().zip(b).map(|(&x, &y)| x * y).sum(),
        GemmBackendKind::Blocked => {
            let mut acc = [0.0f32; 8];
            let mut ca = a.chunks_exact(8);
            let mut cb = b.chunks_exact(8);
            for (ar, br) in ca.by_ref().zip(cb.by_ref()) {
                for j in 0..8 {
                    acc[j] += ar[j] * br[j];
                }
            }
            // fixed-order pairwise horizontal reduction (deterministic)
            let mut s = ((acc[0] + acc[4]) + (acc[2] + acc[6]))
                + ((acc[1] + acc[5]) + (acc[3] + acc[7]));
            for (&x, &y) in ca.remainder().iter().zip(cb.remainder()) {
                s += x * y;
            }
            s
        }
    }
}

// ---------------------------------------------------------- naive backend

/// ikj-order accumulation of one A row against row-major B into `dst`
/// (the exact inner loop of the original `mm_nn`).
#[inline]
fn accum_row_nn(ar: &[f32], b: &[f32], n: usize, dst: &mut [f32]) {
    for (kk, &av) in ar.iter().enumerate() {
        let br = &b[kk * n..(kk + 1) * n];
        for (o, &bv) in dst.iter_mut().zip(br) {
            *o += av * bv;
        }
    }
}

#[inline]
fn epilogue_row(or: &mut [f32], bias: Option<&[f32]>, act: Act) {
    // separate passes on purpose: per-element op order matches the old
    // whole-matrix add_bias sweep followed by the activation sweep
    if let Some(bias) = bias {
        for (o, &bv) in or.iter_mut().zip(bias) {
            *o += bv;
        }
    }
    if act != Act::None {
        for o in or.iter_mut() {
            *o = act.apply(*o);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn naive_nn(
    pool: &WorkerPool,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    bias: Option<&[f32]>,
    act: Act,
    acc_out: bool,
    out: &mut [f32],
) {
    if n == 0 {
        return;
    }
    par_rows(pool, out, m, n, |r0, rows| {
        let mut scratch = vec![0.0f32; if acc_out { n } else { 0 }];
        for (i, or) in rows.chunks_exact_mut(n).enumerate() {
            let ar = &a[(r0 + i) * k..(r0 + i + 1) * k];
            if acc_out {
                scratch.fill(0.0);
                accum_row_nn(ar, b, n, &mut scratch);
                match bias {
                    Some(bias) => {
                        for ((o, &s), &bv) in or.iter_mut().zip(&scratch).zip(bias) {
                            *o = act.apply((*o + s) + bv);
                        }
                    }
                    None => {
                        for (o, &s) in or.iter_mut().zip(&scratch) {
                            *o = act.apply(*o + s);
                        }
                    }
                }
            } else {
                or.fill(0.0);
                accum_row_nn(ar, b, n, or);
                epilogue_row(or, bias, act);
            }
        }
    });
}

fn naive_nt(pool: &WorkerPool, a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    if n == 0 {
        return;
    }
    par_rows(pool, out, m, n, |r0, rows| {
        for (i, or) in rows.chunks_exact_mut(n).enumerate() {
            let ar = &a[(r0 + i) * k..(r0 + i + 1) * k];
            for (j, o) in or.iter_mut().enumerate() {
                let br = &b[j * k..(j + 1) * k];
                *o = ar.iter().zip(br).map(|(&x, &y)| x * y).sum();
            }
        }
    });
}

fn naive_tn_acc(
    pool: &WorkerPool,
    a: &[f32],
    b: &[f32],
    r: usize,
    m: usize,
    n: usize,
    out: &mut [f32],
) {
    if n == 0 {
        return;
    }
    par_rows(pool, out, m, n, |p0, rows| {
        for (pi, or) in rows.chunks_exact_mut(n).enumerate() {
            let p = p0 + pi;
            for i in 0..r {
                let av = a[i * m + p];
                // relu-sparse gradients make the zero-skip a real win on
                // the scalar path (the blocked kernel drops it: full
                // vectorized panels beat data-dependent branches)
                if av != 0.0 {
                    let br = &b[i * n..(i + 1) * n];
                    for (o, &bv) in or.iter_mut().zip(br) {
                        *o += av * bv;
                    }
                }
            }
        }
    });
}

// -------------------------------------------------------- blocked backend

/// Pack row-major `b: [k, n]` into `ceil(n/NR)` zero-padded column panels,
/// each `[k][NR]` contiguous — the layout the microkernel streams.
fn pack_panels_nn(b: &[f32], k: usize, n: usize) -> Vec<f32> {
    let panels = n.div_ceil(NR);
    let mut bp = vec![0.0f32; panels * k * NR];
    for pnl in 0..panels {
        let j0 = pnl * NR;
        let w = NR.min(n - j0);
        let base = pnl * k * NR;
        for kk in 0..k {
            bp[base + kk * NR..base + kk * NR + w]
                .copy_from_slice(&b[kk * n + j0..kk * n + j0 + w]);
        }
    }
    bp
}

/// Pack `b: [n, k]` (the NT operand: logical `B[kk][j] = b[j*k + kk]`)
/// into the same `[k][NR]` panel layout.
fn pack_panels_nt(b: &[f32], k: usize, n: usize) -> Vec<f32> {
    let panels = n.div_ceil(NR);
    let mut bp = vec![0.0f32; panels * k * NR];
    for pnl in 0..panels {
        let j0 = pnl * NR;
        let w = NR.min(n - j0);
        let base = pnl * k * NR;
        for jj in 0..w {
            let src = &b[(j0 + jj) * k..(j0 + jj + 1) * k];
            for (kk, &v) in src.iter().enumerate() {
                bp[base + kk * NR + jj] = v;
            }
        }
    }
    bp
}

/// The register-tile inner loop: accumulate `MRC` A rows against one
/// packed `[k][NR]` panel. `AT = false` reads `a[(row0+ii)*lda + kk]`
/// (row-major A, `lda = k`); `AT = true` reads `a[kk*lda + row0 + ii]`
/// (transposed access for TN, `lda = m`). Each `acc[ii][jj]` sweeps k in
/// ascending order — one accumulator per output element, so per-element
/// summation order equals the naive loops'.
#[inline(always)]
fn microkernel<const MRC: usize, const AT: bool>(
    a: &[f32],
    lda: usize,
    row0: usize,
    k: usize,
    panel: &[f32],
    acc: &mut [[f32; NR]; MR],
) {
    for kk in 0..k {
        let brow: &[f32; NR] = panel[kk * NR..(kk + 1) * NR].try_into().unwrap();
        for ii in 0..MRC {
            let av = if AT { a[kk * lda + row0 + ii] } else { a[(row0 + ii) * lda + kk] };
            for (o, &bv) in acc[ii].iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
}

#[inline(always)]
fn run_microkernel<const AT: bool>(
    mr: usize,
    a: &[f32],
    lda: usize,
    row0: usize,
    k: usize,
    panel: &[f32],
    acc: &mut [[f32; NR]; MR],
) {
    match mr {
        4 => microkernel::<4, AT>(a, lda, row0, k, panel, acc),
        3 => microkernel::<3, AT>(a, lda, row0, k, panel, acc),
        2 => microkernel::<2, AT>(a, lda, row0, k, panel, acc),
        _ => microkernel::<1, AT>(a, lda, row0, k, panel, acc),
    }
}

/// Tile write-back with the fused epilogue. `acc_out` chooses
/// `act((out + s) + bias)` over `act(s + bias)`.
#[inline(always)]
fn write_row(out: &mut [f32], acc: &[f32], bias: Option<&[f32]>, act: Act, acc_out: bool) {
    match (bias, acc_out) {
        (Some(bias), true) => {
            for ((o, &s), &bv) in out.iter_mut().zip(acc).zip(bias) {
                *o = act.apply((*o + s) + bv);
            }
        }
        (Some(bias), false) => {
            for ((o, &s), &bv) in out.iter_mut().zip(acc).zip(bias) {
                *o = act.apply(s + bv);
            }
        }
        (None, true) => {
            for (o, &s) in out.iter_mut().zip(acc) {
                *o = act.apply(*o + s);
            }
        }
        (None, false) => {
            for (o, &s) in out.iter_mut().zip(acc) {
                *o = act.apply(s);
            }
        }
    }
}

/// Blocked NN / NT driver (`bt` selects the NT pack). Packs B once on the
/// calling thread, then fans MR-row tiles out over the pool.
#[allow(clippy::too_many_arguments)]
fn blocked_mm(
    pool: &WorkerPool,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    bias: Option<&[f32]>,
    act: Act,
    acc_out: bool,
    bt: bool,
    out: &mut [f32],
) {
    if n == 0 {
        return;
    }
    let bp = if bt { pack_panels_nt(b, k, n) } else { pack_panels_nn(b, k, n) };
    let bp = &bp;
    par_rows(pool, out, m, n, move |r0, rows| {
        let m_chunk = rows.len() / n;
        let panels = n.div_ceil(NR);
        let mut i = 0;
        while i < m_chunk {
            let mr = MR.min(m_chunk - i);
            for pnl in 0..panels {
                let j0 = pnl * NR;
                let w = NR.min(n - j0);
                let panel = &bp[pnl * k * NR..(pnl + 1) * k * NR];
                let mut acc = [[0.0f32; NR]; MR];
                run_microkernel::<false>(mr, a, k, r0 + i, k, panel, &mut acc);
                let pbias = bias.map(|bv| &bv[j0..j0 + w]);
                for ii in 0..mr {
                    let orow = &mut rows[(i + ii) * n + j0..(i + ii) * n + j0 + w];
                    write_row(orow, &acc[ii][..w], pbias, act, acc_out);
                }
            }
            i += mr;
        }
    });
}

/// Blocked TN-accumulate: `out += a^T @ b` with `a: [r, m]` read
/// column-wise (`AT = true`, `lda = m`); B packs exactly like NN with the
/// k dimension = r.
fn blocked_tn_acc(
    pool: &WorkerPool,
    a: &[f32],
    b: &[f32],
    r: usize,
    m: usize,
    n: usize,
    out: &mut [f32],
) {
    if n == 0 || r == 0 {
        // r = 0: no update terms — leave `out` untouched (the naive loop
        // does the same; even `+= 0.0` would flip -0.0 to +0.0)
        return;
    }
    let bp = pack_panels_nn(b, r, n);
    let bp = &bp;
    par_rows(pool, out, m, n, move |p0, rows| {
        let m_chunk = rows.len() / n;
        let panels = n.div_ceil(NR);
        let mut i = 0;
        while i < m_chunk {
            let mr = MR.min(m_chunk - i);
            for pnl in 0..panels {
                let j0 = pnl * NR;
                let w = NR.min(n - j0);
                let panel = &bp[pnl * r * NR..(pnl + 1) * r * NR];
                let mut acc = [[0.0f32; NR]; MR];
                run_microkernel::<true>(mr, a, m, p0 + i, r, panel, &mut acc);
                for ii in 0..mr {
                    let orow = &mut rows[(i + ii) * n + j0..(i + ii) * n + j0 + w];
                    write_row(orow, &acc[ii][..w], None, Act::None, true);
                }
            }
            i += mr;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use std::sync::Arc;

    fn randv(rng: &mut Pcg32, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    /// Per-element tolerance for reordered accumulation over k terms (the
    /// documented contract: `1e-5 * k * max|a| * max|b| + 1e-6`).
    fn tol(k: usize, a: &[f32], b: &[f32]) -> f32 {
        let ma = a.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let mb = b.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        1e-5 * (k.max(1) as f32) * ma.max(1.0) * mb.max(1.0) + 1e-6
    }

    fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
        assert_eq!(got.len(), want.len(), "{what}: length");
        for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
            assert!(
                (g - w).abs() <= tol,
                "{what}[{i}]: got {g}, want {w} (tol {tol})"
            );
        }
    }

    /// The edge sweep the satellite asks for: every dim in
    /// {0, 1, tile-1, tile, tile+1} plus step-ABI-sized shapes, across
    /// pool worker counts {1, 2, 4}.
    fn shape_grid() -> Vec<(usize, usize, usize)> {
        let edge_m = [0usize, 1, MR - 1, MR, MR + 1, 2 * MR + 1, 67];
        let edge_n = [0usize, 1, NR - 1, NR, NR + 1, 33];
        let edge_k = [0usize, 1, 7, 8, 9, 64];
        let mut shapes = Vec::new();
        for &m in &edge_m {
            for &n in &edge_n {
                for &k in &edge_k {
                    shapes.push((m, k, n));
                }
            }
        }
        // step-ABI shapes (wiki profile, b = 200): msg MLP, GRU banks,
        // attention kv rows, decoder
        shapes.extend([
            (400, 160, 128),
            (400, 128, 64),
            (400, 64, 192),
            (2000, 96, 64),
            (200, 128, 128),
        ]);
        shapes
    }

    #[test]
    fn blocked_matches_naive_on_ragged_shapes_nn_nt() {
        for workers in [1usize, 2, 4] {
            let pool = Arc::new(WorkerPool::new(workers));
            let mut rng = Pcg32::new(42);
            for (m, k, n) in shape_grid() {
                let a = randv(&mut rng, m * k);
                let b_nn = randv(&mut rng, k * n);
                let b_nt = randv(&mut rng, n * k);
                let t = tol(k, &a, &b_nn);
                let mut want = vec![0.0f32; m * n];
                let mut got = vec![0.0f32; m * n];
                mm_nn(GemmBackendKind::Naive, &pool, &a, &b_nn, m, k, n, None, Act::None, &mut want);
                mm_nn(GemmBackendKind::Blocked, &pool, &a, &b_nn, m, k, n, None, Act::None, &mut got);
                assert_close(&got, &want, t, &format!("nn {m}x{k}x{n} w{workers}"));
                mm_nt(GemmBackendKind::Naive, &pool, &a, &b_nt, m, k, n, &mut want);
                mm_nt(GemmBackendKind::Blocked, &pool, &a, &b_nt, m, k, n, &mut got);
                assert_close(&got, &want, t, &format!("nt {m}x{k}x{n} w{workers}"));
            }
        }
    }

    #[test]
    fn tn_acc_accumulates_into_nonzero_out_on_both_backends() {
        for workers in [1usize, 2, 4] {
            let pool = Arc::new(WorkerPool::new(workers));
            let mut rng = Pcg32::new(7);
            for (m, k, n) in shape_grid() {
                let r = k; // reduction dim
                let a = randv(&mut rng, r * m);
                let b = randv(&mut rng, r * n);
                let seed = randv(&mut rng, m * n); // nonzero starting out
                let t = tol(r + 1, &a, &b);
                let mut want = seed.clone();
                let mut got = seed.clone();
                mm_tn_acc(GemmBackendKind::Naive, &pool, &a, &b, r, m, n, &mut want);
                mm_tn_acc(GemmBackendKind::Blocked, &pool, &a, &b, r, m, n, &mut got);
                assert_close(&got, &want, t, &format!("tn_acc {r}x{m}x{n} w{workers}"));
                // r = 0 leaves out untouched on both backends
                let mut w0 = seed.clone();
                let mut g0 = seed.clone();
                mm_tn_acc(GemmBackendKind::Naive, &pool, &a[..0], &b[..0], 0, m, n, &mut w0);
                mm_tn_acc(GemmBackendKind::Blocked, &pool, &a[..0], &b[..0], 0, m, n, &mut g0);
                assert_eq!(w0, seed);
                assert_eq!(g0, seed);
            }
        }
    }

    #[test]
    fn fused_bias_activation_matches_separate_sweeps() {
        let pool = Arc::new(WorkerPool::new(2));
        let mut rng = Pcg32::new(19);
        for act in [Act::None, Act::Relu, Act::Tanh, Act::Sigmoid] {
            for (m, k, n) in [(5usize, 9usize, 17usize), (64, 32, 16), (3, 1, 1)] {
                let a = randv(&mut rng, m * k);
                let b = randv(&mut rng, k * n);
                let bias = randv(&mut rng, n);
                // reference: plain GEMM then separate bias + act sweeps
                let mut want = vec![0.0f32; m * n];
                mm_nn(GemmBackendKind::Naive, &pool, &a, &b, m, k, n, None, Act::None, &mut want);
                for row in want.chunks_exact_mut(n) {
                    for (v, &bv) in row.iter_mut().zip(&bias) {
                        *v += bv;
                    }
                }
                want.iter_mut().for_each(|v| *v = act.apply(*v));
                let t = tol(k, &a, &b);
                for kind in [GemmBackendKind::Naive, GemmBackendKind::Blocked] {
                    let mut got = vec![0.0f32; m * n];
                    mm_nn(kind, &pool, &a, &b, m, k, n, Some(&bias), act, &mut got);
                    assert_close(&got, &want, t, &format!("fused {kind:?} {act:?} {m}x{k}x{n}"));
                }
            }
        }
    }

    #[test]
    fn nn_acc_sums_existing_out_before_bias_and_act() {
        let pool = Arc::new(WorkerPool::new(1));
        let mut rng = Pcg32::new(23);
        let (m, k, n) = (9usize, 13usize, 21usize);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let bias = randv(&mut rng, n);
        let seed = randv(&mut rng, m * n);
        // reference: act((seed + a@b) + bias), evaluated with a plain GEMM
        let mut prod = vec![0.0f32; m * n];
        mm_nn(GemmBackendKind::Naive, &pool, &a, &b, m, k, n, None, Act::None, &mut prod);
        let want: Vec<f32> = seed
            .iter()
            .zip(&prod)
            .enumerate()
            .map(|(i, (&s, &p))| Act::Tanh.apply((s + p) + bias[i % n]))
            .collect();
        let t = tol(k + 1, &a, &b);
        for kind in [GemmBackendKind::Naive, GemmBackendKind::Blocked] {
            let mut got = seed.clone();
            mm_nn_acc(kind, &pool, &a, &b, m, k, n, Some(&bias), Act::Tanh, &mut got);
            assert_close(&got, &want, t, &format!("nn_acc {kind:?}"));
        }
    }

    #[test]
    fn results_are_lane_count_invariant_per_backend() {
        // chunking moves work, never values — for BOTH backends
        let mut rng = Pcg32::new(31);
        let (m, k, n) = (131usize, 37usize, 45usize);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let b_tn = randv(&mut rng, m * n); // [r = m, n] operand for tn_acc
        for kind in [GemmBackendKind::Naive, GemmBackendKind::Blocked] {
            let p1 = Arc::new(WorkerPool::new(1));
            let p4 = Arc::new(WorkerPool::new(4));
            let mut o1 = vec![0.0f32; m * n];
            let mut o4 = vec![0.0f32; m * n];
            mm_nn(kind, &p1, &a, &b, m, k, n, None, Act::Relu, &mut o1);
            mm_nn(kind, &p4, &a, &b, m, k, n, None, Act::Relu, &mut o4);
            assert_eq!(o1, o4, "{kind:?} nn must be bit-identical across lanes");
            // a reinterpreted as [r = m, m = k]: out [k, n] += a^T @ b_tn
            let mut t1 = vec![0.1f32; k * n];
            let mut t4 = vec![0.1f32; k * n];
            mm_tn_acc(kind, &p1, &a, &b_tn, m, k, n, &mut t1);
            mm_tn_acc(kind, &p4, &a, &b_tn, m, k, n, &mut t4);
            assert_eq!(t1, t4, "{kind:?} tn_acc must be bit-identical across lanes");
        }
    }

    #[test]
    fn blocked_nn_preserves_per_element_k_order_bitwise() {
        // documented in the module docs: NN/NT keep one accumulator per
        // element in ascending k, so blocked == naive BITWISE there (the
        // tolerance contract only has to absorb tn_acc + dot reordering)
        let pool = Arc::new(WorkerPool::new(1));
        let mut rng = Pcg32::new(5);
        let (m, k, n) = (23usize, 50usize, 19usize);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let mut naive = vec![0.0f32; m * n];
        let mut blocked = vec![0.0f32; m * n];
        mm_nn(GemmBackendKind::Naive, &pool, &a, &b, m, k, n, None, Act::None, &mut naive);
        mm_nn(GemmBackendKind::Blocked, &pool, &a, &b, m, k, n, None, Act::None, &mut blocked);
        assert_eq!(naive, blocked);
    }

    #[test]
    fn dot_matches_sequential_within_tolerance() {
        let mut rng = Pcg32::new(61);
        // strided attention-head lengths: dk in {1, 3, 8, 24, 32, 37}
        for len in [0usize, 1, 3, 8, 24, 32, 37, 64, 100] {
            let a = randv(&mut rng, len);
            let b = randv(&mut rng, len);
            let want = dot(GemmBackendKind::Naive, &a, &b);
            let got = dot(GemmBackendKind::Blocked, &a, &b);
            let t = tol(len, &a, &b);
            assert!((want - got).abs() <= t, "dot len {len}: {got} vs {want}");
        }
    }

    #[test]
    fn dot_on_strided_attention_head_slices() {
        // heads interleave in the row: per-head slices are strided views;
        // both backends must agree on every head offset
        let mut rng = Pcg32::new(77);
        let (heads, dk) = (2usize, 32usize);
        let q = randv(&mut rng, heads * dk);
        let k = randv(&mut rng, heads * dk);
        for h in 0..heads {
            let qs = &q[h * dk..(h + 1) * dk];
            let ks = &k[h * dk..(h + 1) * dk];
            let want = dot(GemmBackendKind::Naive, qs, ks);
            let got = dot(GemmBackendKind::Blocked, qs, ks);
            assert!((want - got).abs() <= tol(dk, qs, ks), "head {h}");
        }
    }

    #[test]
    fn resolve_maps_auto_to_blocked_and_rejects_unknowns() {
        assert_eq!(GemmBackendKind::resolve("auto").unwrap(), GemmBackendKind::Blocked);
        assert_eq!(GemmBackendKind::resolve("").unwrap(), GemmBackendKind::Blocked);
        assert_eq!(GemmBackendKind::resolve("blocked").unwrap(), GemmBackendKind::Blocked);
        assert_eq!(GemmBackendKind::resolve("naive").unwrap(), GemmBackendKind::Naive);
        let err = GemmBackendKind::resolve("fast").unwrap_err().to_string();
        assert!(err.contains("fast") && err.contains("blocked"), "{err}");
        assert_eq!(GemmBackendKind::Blocked.name(), "blocked");
        assert_eq!(GemmBackendKind::Naive.name(), "naive");
    }

    #[test]
    fn timing_totals_accrue_across_calls() {
        let pool = Arc::new(WorkerPool::new(1));
        let (ns0, c0) = timing_totals();
        let a = vec![1.0f32; 32 * 32];
        let b = vec![1.0f32; 32 * 32];
        let mut out = vec![0.0f32; 32 * 32];
        mm_nn(GemmBackendKind::Blocked, &pool, &a, &b, 32, 32, 32, None, Act::None, &mut out);
        let (ns1, c1) = timing_totals();
        assert!(c1 >= c0 + 1, "call count must advance: {c0} -> {c1}");
        assert!(ns1 >= ns0, "nanos are monotonic");
    }
}
