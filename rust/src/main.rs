//! `pres-train` — the PRES training framework launcher.
//!
//! Subcommands:
//!   train    train one configuration and print the epoch log
//!   datagen  generate a synthetic dataset and print Table-3 stats
//!   pending  pending-set statistics vs batch size (paper Def. 2)
//!   figure   regenerate a paper figure (3, 4, 5, 15, 16, 17, 18, 19, all)
//!   table    regenerate a paper table (1, 2, 3, all)
//!   inspect  list compiled artifacts and their ABIs
//!
//! Examples:
//!   pres-train train --dataset wiki --model tgn --batch 200 --pres
//!   pres-train figure 4 --dataset wiki --trials 3
//!   pres-train table 1 --quick

use std::path::Path;
use std::rc::Rc;

use anyhow::{bail, Context, Result};
use pres::config::ExperimentConfig;
use pres::runtime::Engine;
use pres::training::Trainer;
use pres::util::cli::Args;
use pres::{datagen, figures, log_error, log_info, tables, trace};

const FLAGS: &[&str] = &["pres", "quick", "no-prefetch", "verbose"];

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() {
        print_usage();
        std::process::exit(2);
    }
    if let Err(e) = dispatch(raw) {
        log_error!("{e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    // lint: allow(no-direct-print) — usage must print whatever the log level
    eprintln!(
        "usage: pres-train <train|datagen|pending|figure|table|inspect> [options]\n\
         see README.md for the full option list"
    );
}

fn dispatch(raw: Vec<String>) -> Result<()> {
    let args = Args::parse(raw, FLAGS)?;
    if let Some(s) = args.get("log-level") {
        match trace::log::parse_level(s) {
            Some(l) => trace::log::set_level(l),
            None => bail!("unknown log level '{s}' (error|warn|info|debug|trace)"),
        }
    }
    let cmd = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or_default();
    match cmd {
        "train" => cmd_train(&args),
        "datagen" => cmd_datagen(&args),
        "pending" => cmd_pending(&args),
        "figure" => figures::run(&args),
        "table" => tables::run(&args),
        "inspect" => cmd_inspect(&args),
        other => bail!("unknown command '{other}'"),
    }
}

fn config_from(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = ExperimentConfig::default_with(
        args.get_or("dataset", "wiki"),
        args.get_or("model", "tgn"),
        args.usize_or("batch", 200)?,
        args.flag("pres"),
    );
    cfg.beta = args.f32_or("beta", cfg.beta)?;
    cfg.epochs = args.usize_or("epochs", 10)?;
    cfg.lr = args.f32_or("lr", 1e-3)?;
    cfg.seed = args.u64_or("seed", 0)?;
    cfg.anchor_fraction = args.f32_or("anchor", 1.0)?;
    cfg.artifacts_dir = args.get_or("artifacts", "artifacts").to_string();
    cfg.exec = args.get_or("exec", "auto").to_string();
    cfg.gemm = args.get_or("gemm", "auto").to_string();
    cfg.eval_every = args.usize_or("eval-every", 1)?;
    cfg.prefetch = !args.flag("no-prefetch");
    if let Some(depth) = args.usize_opt("pipeline-depth")? {
        cfg.pipeline.depth = depth;
    }
    if let Some(k) = args.usize_opt("staleness")? {
        cfg.pipeline.bounded_staleness = k;
    }
    if let Some(w) = args.usize_opt("pool-workers")? {
        cfg.pipeline.pool_workers = w;
    }
    if let Some(s) = args.usize_opt("exec-streams")? {
        cfg.pipeline.exec_streams = s;
    }
    if let Some(p) = args.usize_opt("param-staleness")? {
        cfg.pipeline.param_staleness = p;
    }
    cfg.memory_shards = args.usize_or("memory-shards", cfg.memory_shards)?;
    cfg.data_scale = args.f32_or("data-scale", 1.0)?;
    if let Some(p) = args.get("trace-out") {
        cfg.trace_out = Some(p.to_string());
    }
    if let Some(p) = args.get("metrics-out") {
        cfg.metrics_out = Some(p.to_string());
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    if cfg.trace_out.is_some() {
        trace::start();
    }
    if cfg.trace_out.is_some() || cfg.metrics_out.is_some() {
        trace::telemetry::enable_metrics();
    }
    let mut sink = match &cfg.metrics_out {
        Some(p) => Some(trace::MetricsSink::create(p).context("opening metrics sink")?),
        None => None,
    };
    log_info!(
        "# train: dataset={} model={} b={} mode={} beta={} epochs={} seed={}",
        cfg.dataset,
        cfg.model,
        cfg.batch_size,
        if cfg.pres { "PRES" } else { "STANDARD" },
        cfg.beta,
        cfg.epochs,
        cfg.seed
    );
    let mut trainer = Trainer::from_config(&cfg).context("building trainer")?;
    log_info!(
        "# exec: {} backend (requested '{}')",
        match trainer.engine.backend() {
            pres::runtime::ExecBackendKind::Pjrt => "pjrt",
            pres::runtime::ExecBackendKind::Host => "host",
        },
        cfg.exec
    );
    log_info!(
        "# gemm: {} kernels (requested '{}')",
        match trainer.engine.host_gemm() {
            Some(k) => k.name(),
            None => "none (pjrt)",
        },
        cfg.gemm
    );
    let (pend_frac, pend_pairs) = trainer.pending_summary();
    log_info!(
        "# pending: {:.1}% of events pend, {pend_pairs:.2} pairs/event",
        pend_frac * 100.0
    );
    log_info!(
        "# pipeline: depth={} staleness={}{} | exec streams={}{} param staleness={}{} | memory shards={}{} | pool workers={}{}",
        cfg.pipeline.depth,
        cfg.pipeline.bounded_staleness,
        if cfg.pipeline.depth == 0 { " (sequential)" } else { "" },
        cfg.pipeline.exec_streams,
        if cfg.pipeline.exec_streams == 1 { " (inline)" } else { "" },
        cfg.pipeline.param_staleness,
        if cfg.pipeline.param_staleness == 0 { " (exact chain)" } else { "" },
        cfg.memory_shards,
        if cfg.memory_shards == 1 { " (flat)" } else { "" },
        cfg.pipeline.pool_workers,
        if cfg.pipeline.pool_workers == 0 { " (auto)" } else { "" }
    );
    log_info!(
        "{:>5} {:>9} {:>9} {:>9} {:>9} {:>9} {:>8} {:>9} {:>7}",
        "epoch", "loss", "bce", "trainAP", "valAP", "coher", "gamma", "ev/s", "secs"
    );
    let mut best = f64::NEG_INFINITY;
    let mut overlap = (0.0f64, 0.0f64, 0.0f64); // (hidden, stall, idle frac)
    let mut tele_prev = trace::telemetry::snapshot();
    for e in 0..cfg.epochs {
        let mut r = trainer.train_epoch(e)?;
        if cfg.eval_every > 0 && (e + 1) % cfg.eval_every == 0 || e + 1 == cfg.epochs {
            r.val_ap = trainer.eval_val()?;
            best = best.max(r.val_ap);
        }
        log_info!(
            "{:>5} {:>9.4} {:>9.4} {:>9.4} {:>9.4} {:>9.4} {:>8.3} {:>9.0} {:>7.2}",
            r.epoch, r.train_loss, r.train_bce, r.train_ap, r.val_ap, r.coherence,
            r.gamma, r.events_per_sec, r.epoch_secs
        );
        overlap = (r.assemble_hidden_secs, r.prep_stall_secs, r.device_idle_frac);
        if let Some(s) = sink.as_mut() {
            let tele_now = trace::telemetry::snapshot();
            let mut rec = r.to_json();
            rec.set("telemetry", tele_now.delta_since(&tele_prev).to_json());
            tele_prev = tele_now;
            s.emit(&rec)?;
        }
    }
    if cfg.pipeline.depth > 0 {
        log_info!(
            "# overlap (last epoch): assemble hidden {:.3}s, prep stall {:.3}s, device idle {:.1}%",
            overlap.0,
            overlap.1,
            overlap.2 * 100.0
        );
    }
    let (test_ap, rows) = trainer.eval_test(true)?;
    let auc = pres::eval::nodeclf::train_and_auc(&trainer.engine, &rows, cfg.seed)?;
    log_info!("# best val AP = {best:.4}  test AP = {test_ap:.4}  node-clf AUC = {auc:.4}");
    log_info!(
        "# coordinator memory: {:.2} MB",
        trainer.memory_bytes() as f64 / 1e6
    );
    if let Some(p) = &cfg.trace_out {
        trace::stop();
        trace::export_chrome(p)?;
        log_info!("# trace: wrote {p}");
    }
    if let Some(p) = &cfg.metrics_out {
        log_info!("# metrics: wrote {p}");
    }
    Ok(())
}

fn cmd_datagen(args: &Args) -> Result<()> {
    let name = args.get_or("dataset", "all");
    let seed = args.u64_or("seed", 0)?;
    let profiles = if name == "all" {
        datagen::profiles()
    } else {
        vec![datagen::profile(name)
            .ok_or_else(|| anyhow::anyhow!("unknown dataset '{name}'"))?]
    };
    log_info!(
        "{:<8} {:>9} {:>9} {:>6} {:>10} {:>8} {:>9} {:>7}",
        "dataset", "vertices", "events", "efeat", "timespan", "repeat%", "labeled", "pos%"
    );
    for p in profiles {
        let ds = datagen::generate(&p, seed);
        let s = ds.stats();
        log_info!(
            "{:<8} {:>9} {:>9} {:>6} {:>10.0} {:>7.1}% {:>9} {:>6.1}%",
            s.name,
            s.num_nodes,
            s.num_events,
            s.d_edge,
            s.timespan,
            s.repeat_ratio * 100.0,
            s.labeled_events,
            s.label_positive_rate * 100.0
        );
    }
    Ok(())
}

fn cmd_pending(args: &Args) -> Result<()> {
    use pres::batching::{partition, BatchPlan};
    let cfg = config_from(args)?;
    let ds = Trainer::make_dataset(&cfg)?;
    log_info!("# pending-set statistics for '{}' (Def. 2)", cfg.dataset);
    log_info!(
        "{:>7} {:>12} {:>12} {:>12}",
        "batch", "pend-events%", "pairs/event", "collided%"
    );
    for b in [10, 25, 50, 100, 200, 400, 800, 1600] {
        let parts = partition(0..ds.log.len(), b);
        if parts.is_empty() {
            continue;
        }
        let mut ev = 0.0;
        let mut pairs = 0.0;
        let mut coll = 0.0;
        for r in &parts {
            let plan = BatchPlan::build(&ds.log, r.clone());
            ev += plan.stats.pending_events as f64;
            pairs += plan.stats.pending_pairs as f64;
            coll += plan.stats.collided_vertices as f64 / plan.stats.distinct_vertices as f64;
        }
        let n_ev = (parts.len() * b) as f64;
        log_info!(
            "{:>7} {:>11.1}% {:>12.2} {:>11.1}%",
            b,
            ev / n_ev * 100.0,
            pairs / n_ev,
            coll / parts.len() as f64 * 100.0
        );
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let engine = Rc::new(Engine::auto(Path::new(dir), args.get_or("exec", "auto"))?);
    let m = engine.manifest();
    log_info!(
        "# exec backend: {}",
        match engine.backend() {
            pres::runtime::ExecBackendKind::Pjrt => "pjrt (compiled artifacts)",
            pres::runtime::ExecBackendKind::Host =>
                "host (pure-rust step over the builtin manifest; any batch size)",
        }
    );
    log_info!(
        "# dims: d_mem={} d_msg={} d_edge={} d_time={} K={} heads={} d_emb={}",
        m.dims.d_mem,
        m.dims.d_msg,
        m.dims.d_edge,
        m.dims.d_time,
        m.dims.k_nbr,
        m.dims.heads,
        m.dims.d_emb
    );
    log_info!(
        "{:<22} {:>7} {:>8} {:>9}",
        "artifact", "batch", "inputs", "outputs"
    );
    for a in &m.artifacts {
        log_info!(
            "{:<22} {:>7} {:>8} {:>9}",
            a.name,
            a.batch,
            a.inputs.len(),
            a.outputs.len()
        );
    }
    Ok(())
}
