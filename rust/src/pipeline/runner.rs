//! The background PREP prefetcher: a worker thread running the pure stage
//! for plan indices `t+1..t+depth` ahead of the coordinator, over bounded
//! channels with recycled `PrepBatch` scratch.
//!
//! Channel topology (all std::sync::mpsc):
//!
//! ```text
//!   coordinator ── free (unbounded, recycled PrepBatch) ──▶ worker
//!   worker ────── data (sync_channel(depth), filled)  ────▶ coordinator
//! ```
//!
//! The data channel's bound IS the lookahead window: once the worker is
//! `depth` batches ahead it blocks in `send` until the coordinator consumes
//! one. Dropping the [`Prefetcher`] drops the receiver, which errors that
//! blocked `send` and lets the worker exit; `Drop` then joins it, so an
//! early coordinator error can never leak the thread or deadlock.
//!
//! Everything crossing the channel is plain host data — the EXEC handles
//! (`Engine`/`Step`, `Rc`-held, raw PJRT on that backend) never leave the
//! coordinator thread (the Send boundary; see `runtime/mod.rs`). The same
//! discipline governs the EXEC stream lanes (`stream.rs`): they receive
//! the Arc-shared Send + Sync `HostStep` plus plain buffer payloads, never
//! the `Step` wrapper or a literal. The coordinator consumes prepped
//! batches strictly in plan order; under bounded staleness it *blocks* on
//! the window entries (deterministic fill), so each host slot's PREP half
//! is installed exactly once per epoch no matter how EXEC is scheduled —
//! one rotating slot per staleness window entry (`k + 1` slots) is the
//! per-stream staging contract.

use std::ops::Range;
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{bail, Context, Result};

use crate::batching::BatchPlan;
use crate::graph::Dataset;
use crate::memory::ShardRouter;
use crate::pipeline::prep::{fill_prep_with, negative_stream, PrepBatch};
use crate::sampler::NegativeSampler;
use crate::trace::{self, telemetry, Stage};
use crate::util::pool::WorkerPool;

/// Everything the PREP worker needs — immutable shared state plus the
/// epoch's seeding. Deliberately contains no substrate or device state
/// (the memory backend's *routing policy* is pure data, so the worker can
/// precompute shard routes without ever touching the store).
#[derive(Clone)]
pub struct PrepContext {
    pub dataset: Arc<Dataset>,
    pub plans: Arc<Vec<BatchPlan>>,
    pub sampler: NegativeSampler,
    pub seed: u64,
    pub epoch: usize,
    pub batch_size: usize,
    pub d_edge: usize,
    /// Routing policy of the trainer's memory backend (flat = no routes).
    pub router: ShardRouter,
    /// Worker pool the PREP hot loops fan out on (shared with the trainer;
    /// submissions serialize on the pool's handoff lock, and the results
    /// are lane-count-invariant, so sharing is safe).
    pub pool: Arc<WorkerPool>,
}

/// Handle to one epoch's PREP worker. Yields `PrepBatch`es for plan
/// indices `range` strictly in order.
pub struct Prefetcher {
    rx: Option<Receiver<PrepBatch>>,
    free_tx: Option<Sender<PrepBatch>>,
    handle: Option<JoinHandle<()>>,
    /// Batches the worker still owes us — distinguishes a normally drained
    /// range from a worker that died mid-stream.
    outstanding: usize,
}

impl Prefetcher {
    /// Spawn the worker prepping plan indices `range` (each index `i` pairs
    /// plans `i-1`/`i`), at most `depth` batches ahead of consumption.
    #[allow(clippy::disallowed_methods)] // sanctioned thread-builder site
    pub fn spawn(ctx: PrepContext, range: Range<usize>, depth: usize) -> Result<Prefetcher> {
        assert!(depth > 0, "Prefetcher requires depth >= 1");
        assert!(range.start >= 1, "plan index 0 has no predecessor");
        let outstanding = range.len();
        let (data_tx, data_rx): (SyncSender<PrepBatch>, _) = sync_channel(depth);
        let (free_tx, free_rx): (Sender<PrepBatch>, Receiver<PrepBatch>) = channel();
        let handle = std::thread::Builder::new()
            .name("pres-prep".into())
            .spawn(move || {
                for i in range {
                    let mut buf = free_rx
                        .try_recv()
                        .unwrap_or_else(|_| PrepBatch::new(ctx.batch_size, ctx.d_edge));
                    let span = trace::span(Stage::Prep, i as u64);
                    let base = negative_stream(ctx.seed, ctx.epoch, i);
                    fill_prep_with(
                        &mut buf,
                        &ctx.dataset.log,
                        &ctx.plans[i - 1],
                        &ctx.plans[i],
                        &ctx.sampler,
                        &base,
                        ctx.router,
                        &ctx.pool,
                    );
                    buf.index = i;
                    buf.epoch = ctx.epoch;
                    drop(span); // span covers the fill, not the channel wait
                    telemetry::prep_depth_inc();
                    if data_tx.send(buf).is_err() {
                        return; // coordinator gone (early exit / error path)
                    }
                }
            })
            .context("spawning PREP worker thread")?;
        Ok(Prefetcher {
            rx: Some(data_rx),
            free_tx: Some(free_tx),
            handle: Some(handle),
            outstanding,
        })
    }

    /// Block until the next prepped batch arrives (in plan-index order).
    pub fn recv(&mut self) -> Result<PrepBatch> {
        match self.rx.as_ref().expect("prefetcher already shut down").recv() {
            Ok(b) => {
                self.outstanding -= 1;
                telemetry::prep_depth_dec();
                Ok(b)
            }
            Err(_) => bail!(
                "PREP worker died with {} batch(es) outstanding",
                self.outstanding
            ),
        }
    }

    /// Non-blocking: the next prepped batch if it is already waiting.
    /// `Ok(None)` means "nothing ready yet" or "range cleanly drained";
    /// a worker that died mid-stream is an error, not a quiet None.
    ///
    /// Test-only since the staleness window fill became deterministic:
    /// production consumers must use the blocking [`Prefetcher::recv`] so
    /// the splice schedule stays a pure function of `(n_train, k)` —
    /// gating work on `try_recv` would reintroduce the timing-dependent
    /// schedule this runtime deliberately removed.
    #[cfg(test)]
    pub fn try_recv(&mut self) -> Result<Option<PrepBatch>> {
        use std::sync::mpsc::TryRecvError;
        match self.rx.as_ref().expect("prefetcher already shut down").try_recv() {
            Ok(b) => {
                self.outstanding -= 1;
                telemetry::prep_depth_dec();
                Ok(Some(b))
            }
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) if self.outstanding == 0 => Ok(None),
            Err(TryRecvError::Disconnected) => bail!(
                "PREP worker died with {} batch(es) outstanding",
                self.outstanding
            ),
        }
    }

    /// Return a consumed batch's buffers to the worker for reuse (the
    /// double-buffering half of the design: steady state allocates nothing).
    pub fn recycle(&self, buf: PrepBatch) {
        if let Some(tx) = &self.free_tx {
            let _ = tx.send(buf); // worker done -> buffer is simply dropped
        }
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // Receiver first: unblocks a worker stuck in send, making join safe.
        drop(self.rx.take());
        drop(self.free_tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batching::partition;
    use crate::datagen;

    fn tiny_setup() -> (Arc<Dataset>, Arc<Vec<BatchPlan>>, NegativeSampler) {
        let ds = Arc::new(datagen::generate(&datagen::tiny_profile(), 3));
        let plans: Vec<BatchPlan> = partition(0..ds.log.len(), 25)
            .into_iter()
            .map(|r| BatchPlan::build(&ds.log, r))
            .collect();
        let sampler = NegativeSampler::new(&ds.log);
        (ds, Arc::new(plans), sampler)
    }

    #[test]
    fn prefetched_batches_match_inline_prep_exactly() {
        let (ds, plans, sampler) = tiny_setup();
        let n = plans.len().min(8);
        let router = ShardRouter { n_shards: 2 }; // sharded: routes prepped too
        let ctx = PrepContext {
            dataset: ds.clone(),
            plans: plans.clone(),
            sampler: sampler.clone(),
            seed: 42,
            epoch: 1,
            batch_size: 25,
            d_edge: ds.log.d_edge,
            router,
            pool: Arc::new(WorkerPool::new(3)),
        };
        let mut pf = Prefetcher::spawn(ctx, 1..n, 2).unwrap();
        for i in 1..n {
            let got = pf.recv().unwrap();
            assert_eq!(got.index, i, "batches must arrive in order");
            let mut want = PrepBatch::new(25, ds.log.d_edge);
            // inline fill on a different pool: prefetched results must be
            // pool-independent, not just thread-independent
            crate::pipeline::prep::fill_prep(
                &mut want,
                &ds.log,
                &plans[i - 1],
                &plans[i],
                &sampler,
                &negative_stream(42, 1, i),
                router,
            );
            assert_eq!(got.negatives, want.negatives, "batch {i}");
            assert_eq!(got.u_other, want.u_other, "batch {i}");
            assert_eq!(got.u_t, want.u_t, "batch {i}");
            assert_eq!(got.u_efeat, want.u_efeat, "batch {i}");
            assert_eq!(got.u_wmask, want.u_wmask, "batch {i}");
            assert_eq!(got.c_vertex, want.c_vertex, "batch {i}");
            assert_eq!(got.c_match, want.c_match, "batch {i}");
            assert_eq!(got.c_prev_t, want.c_prev_t, "batch {i}");
            assert_eq!(got.c_t, want.c_t, "batch {i}");
            assert_eq!(got.routes.n_shards, want.routes.n_shards, "batch {i}");
            assert_eq!(got.routes.u_self, want.routes.u_self, "batch {i}");
            assert_eq!(got.routes.u_other, want.routes.u_other, "batch {i}");
            assert_eq!(got.routes.c_vertex, want.routes.c_vertex, "batch {i}");
            pf.recycle(got);
        }
        assert!(pf.try_recv().unwrap().is_none(), "range must be drained");
    }

    #[test]
    fn dropping_early_joins_worker_without_deadlock() {
        let (ds, plans, sampler) = tiny_setup();
        let d_edge = ds.log.d_edge;
        let n = plans.len();
        let ctx = PrepContext {
            dataset: ds,
            plans,
            sampler,
            seed: 0,
            epoch: 0,
            batch_size: 25,
            d_edge,
            router: ShardRouter::flat(),
            pool: WorkerPool::global().clone(),
        };
        let mut pf = Prefetcher::spawn(ctx, 1..n, 1).unwrap();
        // consume one, then drop with the worker mid-stream
        let _ = pf.recv().unwrap();
        drop(pf); // must not hang
    }
}
