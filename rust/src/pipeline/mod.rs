//! Staged training pipeline: overlap host batch assembly with device
//! execution.
//!
//! One training iteration decomposes into four stages with very different
//! dependency structure:
//!
//! ```text
//!   PREP      negative sampling, edge features, lag-one match indices,
//!             update-row times, shard routes — pure in (dataset, plans,
//!             seed); reads NO mutable substrate. Runs on the background
//!             worker thread; its per-row hot loops fan out across the
//!             trainer's persistent WorkerPool (`--pool-workers`).
//!   SPLICE    memory-row gathers (store / neighbor index / mailbox / GMM
//!             predictions) — the only stage that depends on the previous
//!             batch's WRITEBACK. Coordinator thread; sharded gathers fan
//!             out on the same pool.
//!   EXEC      the fused training step — the AOT-compiled XLA executable
//!             (PJRT) or the pure-Rust host step (`--exec host`, the
//!             default without artifacts); the host step's GEMMs fan out
//!             on the same pool. Runs inline on the coordinator at
//!             `exec_streams = 1`, or on an executor lane
//!             ([`stream::StreamPool`]) at `exec_streams > 1` with the
//!             host backend.
//!   WRITEBACK corrected memory states, GMM observations, neighbor-index
//!             and mailbox updates. Coordinator thread, strictly in plan
//!             order (the [`stream::CommitQueue`] contract under
//!             multi-stream EXEC); sharded scatters fan out on the pool.
//! ```
//!
//! Steady-state timeline at `depth = 1` (the default; bit-identical to the
//! sequential loop because PREP is pure and the negative stream is derived
//! per `(seed, epoch, batch)` rather than drawn from a mutating RNG):
//!
//! ```text
//!   worker:       PREP t+1 | PREP t+2    | PREP t+3    | ...
//!   coordinator:  SPLICE t | EXEC t | WB t | SPLICE t+1 | EXEC t+1 | ...
//! ```
//!
//! The worker runs up to `depth` batches ahead over a bounded channel
//! ([`runner::Prefetcher`]); `PrepBatch` scratch is recycled through a free
//! list, so the steady state allocates nothing.
//!
//! ## Sharded memory (PR 2) on the persistent worker pool (PR 3)
//!
//! With `--memory-shards N > 1` the store behind SPLICE/WRITEBACK is a
//! [`crate::memory::ShardedMemoryStore`]: the batched gathers and the
//! masked write-back scatter fan out across pool lanes (one task per busy
//! shard) while EXEC's non-Send PJRT handles stay on the coordinator.
//! Routing (`shard = v mod N`) is pure data, so PREP precomputes per-row
//! [`crate::memory::RowRoute`]s into `PrepBatch::routes` and the
//! coordinator-side SPLICE degrades to a straight parallel copy. Any shard
//! count is bit-identical to the flat store at `staleness = 0` — sharding
//! changes layout, never values (`tests/shard_equivalence.rs`).
//!
//! ## Worker pool (PR 3)
//!
//! All host-side parallelism shares one persistent
//! [`crate::util::pool::WorkerPool`] (`--pool-workers`; 0 = auto-sized
//! process pool): workers spawn once at trainer construction, and each op
//! is a generation-barrier broadcast (~1–2 µs handoff vs tens of µs of
//! scoped-thread spawn per op previously). That collapse of the per-op
//! fixed cost is what pushed the sharded store's serial/parallel crossover
//! from `1 << 15` down to `1 << 12` elements per shard
//! (`benches/pool_scaling.rs` → `BENCH_pool.json`), and what makes
//! parallel PREP worthwhile at all: the prefetch worker submits its per-row
//! loops (negative sampling, feature copies, lag-one matches, routes) to
//! the same pool, so deeper lookahead scales with cores instead of
//! saturating one thread. Every pooled loop writes per-row disjoint slots,
//! so results are bit-identical for every lane count — the pool moves
//! work, never values. The trainer's memory backend is the closed
//! [`crate::memory::MemoryBackendKind`] enum, so the splice scalar pass
//! dispatches by branch, not vtable.
//!
//! ## Bounded staleness (MSPipe-style, off by default)
//!
//! With `bounded_staleness = k > 0` the coordinator may additionally run
//! SPLICE for batches `t+1..t+k` *before* batch `t`'s WRITEBACK lands, so
//! the memory view a splice reads can lag at most `k` commits. The lag-one
//! in-graph splice (`c_match`) still patches the single freshest state per
//! vertex, which is why a small `k` barely moves the loss. `k = 0` keeps
//! every splice exact and the whole pipeline bit-identical to the
//! sequential path.
//!
//! The window fill is **deterministic**: the coordinator blocks until the
//! PREP worker delivers each window entry, so which batches splice stale —
//! and therefore the results at any `k` — are a pure function of
//! `(n_train, k)`, never of thread timing. That determinism is what makes
//! the multi-stream equivalence gate below testable at all.
//!
//! ## Multi-stream EXEC (`exec_streams > 1`, host backend only)
//!
//! With `exec_streams = N >= 2` and `bounded_staleness = k >= 1`, step
//! execution moves onto N executor lanes ([`stream::StreamPool`]) over the
//! Arc-shared Send + Sync `HostStep`, and the coordinator's loop is
//! software-pipelined:
//!
//! ```text
//!   lane (i+1)%N:  ............ EXEC t+1 ..............
//!   coordinator:   wait t | absorb params | submit t+1 | WB t | metrics t
//!                  | SPLICE t+1+k |            wait t+1 | ...
//! ```
//!
//! Step `t+1` executes while the coordinator commits step `t`'s write-back,
//! computes its metrics and pre-splices window entry `t+1+k` — exactly the
//! overlap the staleness bound licenses. Two invariants keep every stream
//! count bit-identical to the serial staleness-k loop
//! (`tests/pipeline_equivalence.rs`):
//!
//! * **ordered commits** — the [`stream::CommitQueue`] applies write-backs
//!   strictly in plan order, so each splice sees exactly the commits the
//!   serial schedule shows it (`splice_lag_max` is byte-identical);
//! * **the parameter chain stays exact** — step `t+1` is submitted only
//!   after step `t`'s Adam outputs are absorbed, so at most one step is
//!   ever mid-flight and the overlap hides *coordinator* work (write-back,
//!   metrics, splice, pack), never relaxes parameter freshness.
//!
//! The PJRT backend rejects `exec_streams > 1` (its handles are not Send);
//! jobs cross the lane boundary as plain buffers, never literals — see
//! `stream.rs` module docs. Per-stream execute accounting (busy-union vs
//! wall clock) keeps `device_idle_frac` honest under overlap
//! ([`crate::metrics::EpochTimer`]).
//!
//! ## Bounded parameter staleness (`param_staleness > 0`, PR 7)
//!
//! The exact parameter chain above caps concurrency at one step in flight
//! no matter how many lanes exist. `param_staleness = p >= 1` relaxes
//! exactly that chain, DistTGL-style: lanes run the forward+backward
//! "grad" step kind against parameter snapshots *cloned* at submission,
//! and the coordinator owns the optimizer, applying Adam updates strictly
//! in plan order as each step commits. A window of
//! `W = min(p, exec_streams - 1) + 1` steps is then genuinely concurrent:
//!
//! ```text
//!   lanes:        EXEC t | EXEC t+1 | ... | EXEC t+W-1   (concurrent)
//!   coordinator:  wait t | Adam t | WB t | SPLICE t+1+k | submit t+W | ...
//! ```
//!
//! Step `j` executes against params missing at most `W - 1 =
//! min(p, exec_streams - 1)` plan-order commits — witnessed per epoch by
//! `EpochReport::param_lag_max` and the `param_lag` stage histogram. The
//! memory-splice schedule is untouched (still the serial staleness-k
//! schedule), and submissions/commits happen at fixed loop positions, so
//! the whole schedule is a pure function of `(n_train, k, p, streams)`:
//! relaxed runs are deterministic and repeatable even though lanes race.
//! Because batch `t+W` must already be spliced when submitted, config
//! validation requires `min(p, exec_streams - 1) <= bounded_staleness`.
//! `p = 0` (the default) keeps the exact chain and stays bit-identical to
//! the serial staleness-k loop; `p` only trades parameter freshness for
//! lane concurrency, never memory freshness.
//!
//! Knob semantics, in one line each:
//!
//! * `depth` — PREP lookahead (batches the worker may run ahead);
//! * `bounded_staleness` (`--staleness k`) — memory-view lag: how many
//!   commits a SPLICE may trail;
//! * `exec_streams` — executor lanes (host backend only);
//! * `param_staleness` (`--param-staleness p`) — parameter-version lag:
//!   how many plan-order Adam commits a step's snapshot may trail
//!   (0 = exact chain, clamped to `exec_streams - 1` lanes of benefit);
//! * `pool_workers` — shared worker-pool width under all of the above.
//!
//! Knobs live in [`crate::config::PipelineConfig`] (`--pipeline-depth` /
//! `--staleness` / `--exec-streams` / `--param-staleness` on the CLI);
//! overlap metrics (assemble-hidden seconds, device-idle fraction,
//! per-stream execute, splice/param lag) land in `EpochReport`,
//! `rust/benches/pipeline_overlap.rs` and
//! `rust/benches/stream_overlap.rs`.

pub mod prep;
pub mod runner;
pub mod stream;

pub use prep::{
    fill_prep, fill_prep_from, fill_prep_from_with, fill_prep_with, negative_stream, PrepBatch,
};
pub use runner::{PrepContext, Prefetcher};
pub use stream::{plain_to_literals, CommitQueue, PlainArg, StepDone, StreamPool};
