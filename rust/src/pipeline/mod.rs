//! Staged training pipeline: overlap host batch assembly with device
//! execution.
//!
//! One training iteration decomposes into four stages with very different
//! dependency structure:
//!
//! ```text
//!   PREP      negative sampling, edge features, lag-one match indices,
//!             update-row times, shard routes — pure in (dataset, plans,
//!             seed); reads NO mutable substrate. Runs on the background
//!             worker thread; its per-row hot loops fan out across the
//!             trainer's persistent WorkerPool (`--pool-workers`).
//!   SPLICE    memory-row gathers (store / neighbor index / mailbox / GMM
//!             predictions) — the only stage that depends on the previous
//!             batch's WRITEBACK. Coordinator thread; sharded gathers fan
//!             out on the same pool.
//!   EXEC      the fused training step — the AOT-compiled XLA executable
//!             (PJRT) or the pure-Rust host step (`--exec host`, the
//!             default without artifacts); the host step's GEMMs fan out
//!             on the same pool. Coordinator thread either way.
//!   WRITEBACK corrected memory states, GMM observations, neighbor-index
//!             and mailbox updates. Coordinator thread; sharded scatters
//!             fan out on the pool.
//! ```
//!
//! Steady-state timeline at `depth = 1` (the default; bit-identical to the
//! sequential loop because PREP is pure and the negative stream is derived
//! per `(seed, epoch, batch)` rather than drawn from a mutating RNG):
//!
//! ```text
//!   worker:       PREP t+1 | PREP t+2    | PREP t+3    | ...
//!   coordinator:  SPLICE t | EXEC t | WB t | SPLICE t+1 | EXEC t+1 | ...
//! ```
//!
//! The worker runs up to `depth` batches ahead over a bounded channel
//! ([`runner::Prefetcher`]); `PrepBatch` scratch is recycled through a free
//! list, so the steady state allocates nothing.
//!
//! ## Sharded memory (PR 2) on the persistent worker pool (PR 3)
//!
//! With `--memory-shards N > 1` the store behind SPLICE/WRITEBACK is a
//! [`crate::memory::ShardedMemoryStore`]: the batched gathers and the
//! masked write-back scatter fan out across pool lanes (one task per busy
//! shard) while EXEC's non-Send PJRT handles stay on the coordinator.
//! Routing (`shard = v mod N`) is pure data, so PREP precomputes per-row
//! [`crate::memory::RowRoute`]s into `PrepBatch::routes` and the
//! coordinator-side SPLICE degrades to a straight parallel copy. Any shard
//! count is bit-identical to the flat store at `staleness = 0` — sharding
//! changes layout, never values (`tests/shard_equivalence.rs`).
//!
//! ## Worker pool (PR 3)
//!
//! All host-side parallelism shares one persistent
//! [`crate::util::pool::WorkerPool`] (`--pool-workers`; 0 = auto-sized
//! process pool): workers spawn once at trainer construction, and each op
//! is a generation-barrier broadcast (~1–2 µs handoff vs tens of µs of
//! scoped-thread spawn per op previously). That collapse of the per-op
//! fixed cost is what pushed the sharded store's serial/parallel crossover
//! from `1 << 15` down to `1 << 12` elements per shard
//! (`benches/pool_scaling.rs` → `BENCH_pool.json`), and what makes
//! parallel PREP worthwhile at all: the prefetch worker submits its per-row
//! loops (negative sampling, feature copies, lag-one matches, routes) to
//! the same pool, so deeper lookahead scales with cores instead of
//! saturating one thread. Every pooled loop writes per-row disjoint slots,
//! so results are bit-identical for every lane count — the pool moves
//! work, never values. The trainer's memory backend is the closed
//! [`crate::memory::MemoryBackendKind`] enum, so the splice scalar pass
//! dispatches by branch, not vtable.
//!
//! ## Bounded staleness (MSPipe-style, off by default)
//!
//! With `bounded_staleness = k > 0` the coordinator may additionally run
//! SPLICE for batches `t+1..t+k` *before* batch `t`'s WRITEBACK lands, so
//! the memory view a splice reads can lag at most `k` commits. The lag-one
//! in-graph splice (`c_match`) still patches the single freshest state per
//! vertex, which is why a small `k` barely moves the loss. `k = 0` keeps
//! every splice exact and the whole pipeline bit-identical to the
//! sequential path.
//!
//! **Honest caveat:** today EXEC is a *synchronous* call on the
//! coordinator thread (PJRT or host), so pre-splicing only reorders
//! coordinator work — it cannot yet overlap anything and is roughly
//! perf-neutral versus simply raising `depth` (which costs no exactness).
//! The knob is the semantic seam for the planned multi-stream / async EXEC
//! (see ROADMAP "Open items"), where splicing batch `t+1` *while* batch
//! `t` runs on a second stream is exactly what bounded staleness licenses —
//! and the host backend's `HostStep` is Send + Sync, so that second stream
//! no longer needs a second PJRT client. Until then, prefer
//! `depth >= 1, staleness = 0`.
//!
//! Knobs live in [`crate::config::PipelineConfig`] (`--pipeline-depth` /
//! `--staleness` on the CLI); overlap metrics (assemble-hidden seconds,
//! device-idle fraction) land in `EpochReport` and
//! `rust/benches/pipeline_overlap.rs`.

pub mod prep;
pub mod runner;

pub use prep::{
    fill_prep, fill_prep_from, fill_prep_from_with, fill_prep_with, negative_stream, PrepBatch,
};
pub use runner::{PrepContext, Prefetcher};
