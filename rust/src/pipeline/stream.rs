//! Multi-stream EXEC: N executor lanes running host steps off the
//! coordinator thread, plus the commit queue that keeps write-backs in
//! plan order.
//!
//! ## Why lanes exist
//!
//! Under `bounded_staleness = k >= 1` the coordinator pre-splices up to
//! `k` future batches — their inputs are fully staged before the current
//! step's write-back lands. A [`StreamPool`] turns that license into
//! overlap, in one of two regimes selected by `param_staleness`:
//!
//! * **Exact chain** (`param_staleness = 0`, the default): step `t+1`
//!   executes on a lane while the coordinator commits step `t`'s
//!   write-back, computes its metrics and pre-splices the next window
//!   entry. The parameter chain still serializes the *computations*
//!   (step `t+1` consumes step `t`'s fused Adam output, which is what
//!   keeps results bit-identical to the serial staleness-k loop), so at
//!   any moment at most one step is mid-flight — the win is that the
//!   coordinator's commit work no longer sits on the EXEC critical path.
//! * **Relaxed chain** (`param_staleness = p >= 1`): lanes run the
//!   forward+backward "grad" step kind against parameter snapshots cloned
//!   at submission, and the coordinator applies the Adam updates strictly
//!   in plan order as each step commits. A window of
//!   `min(p, streams - 1) + 1` steps is then *genuinely* concurrent, each
//!   executing against params at most `min(p, streams - 1)` plan-order
//!   commits stale — DistTGL-style bounded parameter staleness. The
//!   schedule stays a pure function of `(n_train, k, p, streams)`, so
//!   runs remain deterministic even though lanes race.
//!
//! ## Why payloads are plain buffers
//!
//! Jobs cross the lane boundary as [`PlainArg`]s — owned `Vec<f32>` /
//! `Vec<i32>` payloads in ABI order — never as `xla::Literal`s. The
//! vendored stub's literal happens to be plain host data, but the real
//! xla-rs literal wraps a C pointer with no Send guarantee; keeping
//! literals out of the channel types means linking the real bindings
//! stays the advertised one-line swap. Lanes rebuild literals against the
//! step's own [`ArtifactSpec`] (every payload is length- and
//! dtype-checked), run, and ship the outputs back the same way.
//!
//! ## Ordering contract
//!
//! The [`CommitQueue`] holds the in-flight steps in submission order and
//! only ever surfaces the oldest one — write-backs are applied strictly
//! in plan order no matter which lane ran the step or when it finished.
//! `StepDone::seq` is checked against the queue front, so a reordering
//! bug is an error, not a silent corruption.
//!
//! Only the **host** backend can serve lanes ([`HostStep`] is Send + Sync
//! — plain data plus an `Arc<WorkerPool>`); the PJRT backend rejects
//! `exec_streams > 1` with a clear error at trainer construction.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};
use xla::{ElementType, Literal};

use crate::runtime::engine::{check_len, lit_f32, lit_i32};
use crate::runtime::manifest::DType;
use crate::runtime::{HostStep, TensorSpec};
use crate::trace::{self, Stage};

/// One tensor payload crossing the lane boundary: owned plain host data in
/// the ABI's dtype, shape-checked against the spec on both conversions.
#[derive(Clone, Debug, PartialEq)]
pub enum PlainArg {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl PlainArg {
    /// Copy a literal's payload out into a plain buffer (params / Adam
    /// state at submission time).
    pub fn from_literal(lit: &Literal) -> Result<PlainArg> {
        let n = lit.element_count();
        match lit.ty()? {
            ElementType::F32 => {
                let mut v = vec![0.0f32; n];
                lit.copy_raw_to(&mut v)?;
                Ok(PlainArg::F32(v))
            }
            ElementType::S32 => {
                let mut v = vec![0i32; n];
                lit.copy_raw_to(&mut v)?;
                Ok(PlainArg::I32(v))
            }
            other => bail!("stream payload: unsupported element type {other:?}"),
        }
    }

    /// Rebuild the literal for `spec` (length- and dtype-checked).
    pub fn to_literal(&self, spec: &TensorSpec) -> Result<Literal> {
        match (self, spec.dtype) {
            (PlainArg::F32(v), DType::F32) => {
                check_len(spec, v.len())?;
                lit_f32(v, &spec.shape)
            }
            (PlainArg::I32(v), DType::I32) => {
                check_len(spec, v.len())?;
                lit_i32(v, &spec.shape)
            }
            _ => bail!("tensor '{}': payload dtype does not match spec", spec.name),
        }
    }
}

/// Rebuild literals from plain payloads against their tensor specs
/// (positional; the caller picks the matching slice of the ABI — e.g. the
/// step outputs after the threaded parameter bank has been split off).
pub fn plain_to_literals(outs: &[PlainArg], specs: &[TensorSpec]) -> Result<Vec<Literal>> {
    if outs.len() != specs.len() {
        bail!(
            "stream payloads: got {} tensors, spec slice expects {}",
            outs.len(),
            specs.len()
        );
    }
    outs.iter()
        .zip(specs)
        .map(|(arg, tspec)| arg.to_literal(tspec))
        .collect()
}

/// Completion record for one submitted step.
pub struct StepDone {
    /// The submission sequence number (= plan index in the trainer).
    pub seq: usize,
    /// Which lane ran it (for per-stream execute accounting).
    pub stream: usize,
    /// The step outputs in ABI order, or the lane-side error.
    pub outputs: Result<Vec<PlainArg>>,
    /// Lane-side wall-clock span of the step execution proper. Payload
    /// staging/flattening (plain-buffer <-> literal copies) is excluded so
    /// `execute`/`device_idle_frac` stay comparable with the inline path,
    /// which books the equivalent pack work under `assemble`; that copy
    /// time runs on the lane, overlapped, and is deliberately untracked.
    pub started: Instant,
    pub finished: Instant,
}

struct Job {
    seq: usize,
    args: Vec<PlainArg>,
    reply: Sender<StepDone>,
}

struct Lane {
    tx: Option<Sender<Job>>,
    handle: Option<JoinHandle<()>>,
}

/// N executor lanes over one shared [`HostStep`]. The step is stateless
/// across runs (per-run activations are locals), so any number of lanes
/// may hold it; its pooled GEMMs fan out on the trainer's `WorkerPool`
/// from whichever thread runs them, bit-identical across lane counts.
pub struct StreamPool {
    lanes: Vec<Lane>,
}

impl StreamPool {
    /// Spawn `streams` lanes executing `step`. Lane threads live until the
    /// pool drops; an idle lane costs one parked thread.
    #[allow(clippy::disallowed_methods)] // sanctioned thread-builder site
    pub fn new(streams: usize, step: Arc<HostStep>) -> Result<StreamPool> {
        anyhow::ensure!(streams >= 1, "StreamPool requires >= 1 lane");
        let lanes = (0..streams)
            .map(|s| {
                let (tx, rx) = channel::<Job>();
                let step = step.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("pres-exec-{s}"))
                    .spawn(move || lane_main(s, &step, &rx))
                    .context("spawning EXEC stream lane")?;
                Ok(Lane { tx: Some(tx), handle: Some(handle) })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(StreamPool { lanes })
    }

    pub fn streams(&self) -> usize {
        self.lanes.len()
    }

    /// Submit step `seq` to lane `seq % streams`. Returns the receiver its
    /// completion arrives on (exactly one [`StepDone`] per job). A lane
    /// that died surfaces as a receive error on that channel.
    pub fn submit(&self, seq: usize, args: Vec<PlainArg>) -> Receiver<StepDone> {
        let (reply, rx) = channel();
        let lane = &self.lanes[seq % self.lanes.len()];
        let tx = lane.tx.as_ref().expect("StreamPool already shut down");
        // send only fails if the lane panicked; the caller then sees a
        // closed reply channel, which CommitQueue reports as a dead lane
        let _ = tx.send(Job { seq, args, reply });
        rx
    }
}

impl Drop for StreamPool {
    fn drop(&mut self) {
        // closing the job channels lets each lane drain and exit its loop
        for lane in &mut self.lanes {
            drop(lane.tx.take());
        }
        for lane in &mut self.lanes {
            if let Some(h) = lane.handle.take() {
                let _ = h.join();
            }
        }
    }
}

fn lane_main(stream: usize, step: &HostStep, rx: &Receiver<Job>) {
    while let Ok(job) = rx.recv() {
        let (outputs, (started, finished)) = run_job(step, &job.args);
        // recorded on the lane thread so the exported timeline shows one
        // row per EXEC lane; arg = the step's plan index
        trace::record_span(Stage::Exec, started, finished, job.seq as u64);
        // the coordinator may already be gone on an error path — dropping
        // the result is then correct
        let _ = job.reply.send(StepDone {
            seq: job.seq,
            stream,
            outputs,
            started,
            finished,
        });
    }
}

/// Stage plain payloads into literals, run the shared step, and flatten
/// the outputs back into plain payloads. The returned span brackets only
/// `HostStep::run` (see [`StepDone::started`]).
fn run_job(
    step: &HostStep,
    args: &[PlainArg],
) -> (Result<Vec<PlainArg>>, (Instant, Instant)) {
    let lits = match stage_inputs(step, args) {
        Ok(lits) => lits,
        Err(e) => {
            let t = crate::util::now();
            return (Err(e), (t, t));
        }
    };
    let refs: Vec<&Literal> = lits.iter().collect();
    let started = crate::util::now();
    let outs = step.run(&refs);
    let finished = crate::util::now();
    let flattened = outs.and_then(|outs| outs.iter().map(PlainArg::from_literal).collect());
    (flattened, (started, finished))
}

fn stage_inputs(step: &HostStep, args: &[PlainArg]) -> Result<Vec<Literal>> {
    if args.len() != step.spec.inputs.len() {
        bail!(
            "stream step {}: got {} args, ABI expects {}",
            step.spec.name,
            args.len(),
            step.spec.inputs.len()
        );
    }
    args.iter()
        .zip(&step.spec.inputs)
        .map(|(arg, spec)| arg.to_literal(spec))
        .collect()
}

/// In-flight steps ordered by submission; completions surface strictly in
/// that order regardless of lane or finish time — the write-back side of
/// the staleness-k exactness contract.
#[derive(Default)]
pub struct CommitQueue {
    pending: VecDeque<(usize, Receiver<StepDone>)>,
}

impl CommitQueue {
    pub fn new() -> CommitQueue {
        CommitQueue::default()
    }

    /// Record a submitted step. `seq` values must be pushed in increasing
    /// order (the trainer submits plan indices monotonically).
    pub fn push(&mut self, seq: usize, rx: Receiver<StepDone>) {
        if let Some(&(last, _)) = self.pending.back() {
            debug_assert!(seq > last, "commit queue requires monotone submission");
        }
        self.pending.push_back((seq, rx));
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Sequence number of the oldest in-flight step (the one `wait_next`
    /// will surface), or `None` when nothing is in flight. The relaxed
    /// parameter-chain loop uses this to assert its fixed submission
    /// schedule without consuming the front.
    pub fn front_seq(&self) -> Option<usize> {
        self.pending.front().map(|&(seq, _)| seq)
    }

    /// Block for the oldest in-flight step. Errors if nothing is in flight
    /// or the lane running it died.
    pub fn wait_next(&mut self) -> Result<StepDone> {
        let (seq, rx) = self
            .pending
            .pop_front()
            .ok_or_else(|| anyhow!("commit queue: no step in flight"))?;
        let done = rx
            .recv()
            .map_err(|_| anyhow!("EXEC stream lane died running step {seq}"))?;
        anyhow::ensure!(
            done.seq == seq,
            "commit order violated: lane returned step {}, queue front is {seq}",
            done.seq
        );
        Ok(done)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;
    use crate::runtime::ArtifactSpec;
    use crate::util::pool::WorkerPool;

    /// A small host train step (jodie avoids the attention path, so all-
    /// zero inputs stay NaN-free) plus matching all-zero ABI args.
    fn step_and_args() -> (Arc<HostStep>, Vec<PlainArg>) {
        let m = Manifest::builtin();
        let spec = ArtifactSpec::host(m.dims, "jodie", 4, "train").unwrap();
        let n_params = m.param_specs("jodie").unwrap().len();
        let step = Arc::new(HostStep::new(
            spec,
            m.dims,
            n_params,
            Arc::new(WorkerPool::new(2)),
        ));
        let mut args: Vec<PlainArg> = step
            .spec
            .inputs
            .iter()
            .map(|s| match s.dtype {
                DType::F32 => PlainArg::F32(vec![0.0; s.elems()]),
                DType::I32 => PlainArg::I32(vec![0; s.elems()]),
            })
            .collect();
        // step_t = 1 (t = 0 would zero Adam's bias correction); lr stays 0
        let last = args.len() - 1;
        args[last] = PlainArg::F32(vec![1.0]);
        (step, args)
    }

    #[test]
    fn host_step_is_send_and_sync() {
        fn check<T: Send + Sync>() {}
        check::<HostStep>();
        check::<PlainArg>();
        check::<StepDone>();
    }

    #[test]
    fn lane_run_matches_inline_run_bit_for_bit() {
        let (step, args) = step_and_args();
        // inline reference on the coordinator thread
        let (want, (t0, t1)) = run_job(&step, &args);
        let want = want.unwrap();
        assert!(t1 >= t0);
        // one job per lane, all identical inputs: every lane must agree
        // with the inline run exactly (the pool moves work, never values)
        let pool = StreamPool::new(3, step.clone()).unwrap();
        for seq in 0..3 {
            let rx = pool.submit(seq, args.clone());
            let done = rx.recv().unwrap();
            assert_eq!(done.seq, seq);
            assert_eq!(done.stream, seq % 3);
            let got = done.outputs.unwrap();
            assert_eq!(got.len(), step.spec.outputs.len());
            assert_eq!(got, want, "lane {seq} diverged from inline execution");
        }
    }

    #[test]
    fn commit_queue_surfaces_steps_in_submission_order() {
        let (step, args) = step_and_args();
        let pool = StreamPool::new(4, step).unwrap();
        let mut commits = CommitQueue::new();
        assert_eq!(commits.front_seq(), None);
        for seq in 1..=8usize {
            commits.push(seq, pool.submit(seq, args.clone()));
        }
        assert_eq!(commits.len(), 8);
        for expect in 1..=8usize {
            assert_eq!(commits.front_seq(), Some(expect), "front peeks without consuming");
            let done = commits.wait_next().unwrap();
            assert_eq!(done.seq, expect, "commit order must be submission order");
            assert_eq!(done.stream, expect % 4);
            assert!(done.outputs.is_ok());
            assert!(done.finished >= done.started);
        }
        assert!(commits.is_empty());
        assert_eq!(commits.front_seq(), None);
        assert!(commits.wait_next().is_err(), "empty queue must error");
    }

    #[test]
    fn grad_jobs_run_on_lanes_and_lead_with_gradients() {
        // the relaxed parameter chain ships grad-kind steps to lanes: the
        // ABI takes params + data (no Adam state, no trailing lr/step_t)
        // and leads its outputs with one gradient tensor per parameter
        let m = Manifest::builtin();
        let spec = ArtifactSpec::host(m.dims, "jodie", 4, "grad").unwrap();
        let n_params = m.param_specs("jodie").unwrap().len();
        let step = Arc::new(HostStep::new(
            spec,
            m.dims,
            n_params,
            Arc::new(WorkerPool::new(2)),
        ));
        let args: Vec<PlainArg> = step
            .spec
            .inputs
            .iter()
            .map(|s| match s.dtype {
                DType::F32 => PlainArg::F32(vec![0.0; s.elems()]),
                DType::I32 => PlainArg::I32(vec![0; s.elems()]),
            })
            .collect();
        let (want, _) = run_job(&step, &args);
        let want = want.unwrap();
        assert_eq!(want.len(), step.spec.outputs.len());
        assert!(step.spec.outputs[0].name.starts_with("grad_"));
        let pool = StreamPool::new(2, step.clone()).unwrap();
        for seq in 0..4 {
            let done = pool.submit(seq, args.clone()).recv().unwrap();
            let got = done.outputs.unwrap();
            assert_eq!(got, want, "lane {seq} grad run diverged from inline");
        }
    }

    #[test]
    fn bad_payload_surfaces_as_lane_error_not_panic() {
        let (step, mut args) = step_and_args();
        // truncate one tensor: the lane must report a step error, and the
        // pool must stay usable afterwards
        args[0] = PlainArg::F32(vec![0.0; 1]);
        let pool = StreamPool::new(1, step).unwrap();
        let done = pool.submit(0, args).recv().unwrap();
        assert!(done.outputs.is_err());
        let (_, good) = step_and_args();
        let done = pool.submit(1, good).recv().unwrap();
        assert!(done.outputs.is_ok(), "lane must survive a bad job");
    }

    #[test]
    fn plain_arg_roundtrips_and_checks_specs() {
        let spec = TensorSpec {
            name: "x".into(),
            shape: vec![2, 2],
            dtype: DType::F32,
        };
        let arg = PlainArg::F32(vec![1.0, -2.0, 3.5, 0.0]);
        let lit = arg.to_literal(&spec).unwrap();
        assert_eq!(PlainArg::from_literal(&lit).unwrap(), arg);
        // wrong length and wrong dtype both fail loudly
        assert!(PlainArg::F32(vec![0.0; 3]).to_literal(&spec).is_err());
        assert!(PlainArg::I32(vec![0; 4]).to_literal(&spec).is_err());
    }

    /// Hand-made completion record for driving `CommitQueue` without a
    /// `StreamPool` (the queue only reads `seq` on its control path).
    fn done(seq: usize) -> StepDone {
        let t = crate::util::now();
        StepDone {
            seq,
            stream: 0,
            outputs: Ok(vec![]),
            started: t,
            finished: t,
        }
    }

    #[test]
    fn commit_queue_empty_epoch_is_a_clean_error() {
        let mut commits = CommitQueue::new();
        assert!(commits.is_empty());
        assert_eq!(commits.len(), 0);
        assert_eq!(commits.front_seq(), None);
        let err = commits.wait_next().unwrap_err();
        assert!(
            err.to_string().contains("no step in flight"),
            "unexpected error: {err}"
        );
        // erroring on an empty queue must not poison it
        let (tx, rx) = channel();
        commits.push(0, rx);
        tx.send(done(0)).unwrap();
        assert_eq!(commits.wait_next().unwrap().seq, 0);
    }

    #[test]
    fn commit_queue_single_in_flight_step_and_dead_lane() {
        // one in-flight step: completion surfaces and empties the queue
        let mut commits = CommitQueue::new();
        let (tx, rx) = channel();
        commits.push(7, rx);
        assert_eq!(commits.front_seq(), Some(7));
        tx.send(done(7)).unwrap();
        let got = commits.wait_next().unwrap();
        assert_eq!(got.seq, 7);
        assert!(commits.is_empty());
        // a dropped sender models a lane that died mid-step: the error
        // names the lost step instead of hanging
        let (tx, rx) = channel::<StepDone>();
        commits.push(8, rx);
        drop(tx);
        let err = commits.wait_next().unwrap_err();
        assert!(
            err.to_string().contains("lane died running step 8"),
            "unexpected error: {err}"
        );
        assert!(commits.is_empty(), "a failed wait still consumes the front");
    }

    #[test]
    fn commit_queue_flags_out_of_order_arrival() {
        // the queue front says step 3 is oldest; a lane handing back step 5
        // on that channel is a plumbing bug the queue must refuse to commit
        let mut commits = CommitQueue::new();
        let (tx, rx) = channel();
        commits.push(3, rx);
        tx.send(done(5)).unwrap();
        let err = commits.wait_next().unwrap_err();
        assert!(
            err.to_string().contains("commit order violated"),
            "unexpected error: {err}"
        );
    }
}
