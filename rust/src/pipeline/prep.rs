//! PREP — the pure stage of the training pipeline.
//!
//! Everything a training iteration needs that does **not** read the memory
//! substrates (store / neighbor index / mailbox / GMM trackers) is computed
//! here: negative sampling, update-row event features and times, the
//! lag-one match indices, and the per-role vertex lists the SPLICE stage
//! gathers memory rows for. Because all of it is a pure function of the
//! immutable `(EventLog, BatchPlan, seed)` triple, PREP for batches
//! `t+1..t+depth` can run on a background thread while batch `t` executes
//! on the device — see [`crate::pipeline`] for the stage diagram.

use std::time::Instant;

use crate::batching::BatchPlan;
use crate::graph::EventLog;
use crate::memory::{ShardRouter, ShardRoutes};
use crate::sampler::NegativeSampler;
use crate::util::rng::{splitmix64, Pcg32};

/// The Send-able half of a host batch: every tensor the step consumes that
/// is independent of the mutable memory substrates. One `PrepBatch` covers
/// one iteration `i`: update rows come from the *previous* plan (whose
/// events are committed in-graph this step), current rows from plan `i`.
#[derive(Clone, Debug)]
pub struct PrepBatch {
    /// Plan index this batch was prepped for (ordering check).
    pub index: usize,
    /// Epoch the negative stream was seeded with.
    pub epoch: usize,
    /// Sampled negative destination per current event. [b]
    pub negatives: Vec<u32>,
    /// Other endpoint per update row (dst for src rows, src for dst rows),
    /// so SPLICE can batch-gather `u_other_mem`. [2b]
    pub u_other: Vec<u32>,
    /// Event time per update row (write-back timestamps + dt baseline). [2b]
    pub u_t: Vec<f32>,
    /// Edge features per update row. [2b * d_edge]
    pub u_efeat: Vec<f32>,
    /// Write-back mask (copy of the plan's last-occurrence mask). [2b]
    pub u_wmask: Vec<f32>,
    /// Vertex ids per role (src/dst/neg) of the current batch. [3][b]
    pub c_vertex: [Vec<u32>; 3],
    /// Lag-one match row into the previous batch, -1 when absent. [3][b]
    pub c_match: [Vec<i32>; 3],
    /// Event time of the previous-batch row matched above, or -inf when
    /// there is none (SPLICE takes max with the store clock). [3][b]
    pub c_prev_t: [Vec<f32>; 3],
    /// Event time of each current event. [b]
    pub c_t: Vec<f32>,
    /// Per-row shard routes for every gather/scatter list above, computed
    /// for the trainer's memory backend so SPLICE/WRITEBACK skip routing
    /// math on the coordinator thread (empty under flat routing).
    pub routes: ShardRoutes,
    /// Wall-clock nanoseconds spent filling this batch (overlap metrics).
    pub prep_ns: u64,
}

impl PrepBatch {
    pub fn new(b: usize, d_edge: usize) -> PrepBatch {
        let u = 2 * b;
        PrepBatch {
            index: 0,
            epoch: 0,
            negatives: vec![0; b],
            u_other: vec![0; u],
            u_t: vec![0.0; u],
            u_efeat: vec![0.0; u * d_edge],
            u_wmask: vec![0.0; u],
            c_vertex: std::array::from_fn(|_| vec![0; b]),
            c_match: std::array::from_fn(|_| vec![-1; b]),
            c_prev_t: std::array::from_fn(|_| vec![f32::NEG_INFINITY; b]),
            c_t: vec![0.0; b],
            routes: ShardRoutes::default(),
            prep_ns: 0,
        }
    }

    pub fn batch_size(&self) -> usize {
        self.c_t.len()
    }

    /// Update-row count (2b).
    pub fn rows(&self) -> usize {
        self.u_t.len()
    }
}

/// Derive the negative-sampling stream for `(seed, epoch, batch)` as a pure
/// function — NOT from a mutating trainer RNG. This is what lets PREP run
/// out of order / off-thread and still reproduce the sequential loop
/// bit-for-bit (the pipeline-vs-sequential equivalence guarantee).
pub fn negative_stream(seed: u64, epoch: usize, batch: usize) -> Pcg32 {
    let mut h = seed
        ^ 0x5EED_FACE_CAFE_F00Du64
        ^ ((epoch as u64) << 32 | batch as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    Pcg32::new(splitmix64(&mut h))
}

/// Fill `prep` for one iteration: sample negatives from `rng`, then build
/// every pure tensor. `prev`/`cur` must be consecutive plans of `log`;
/// `router` is the memory backend's routing policy (shard routes are part
/// of the pure PREP output — routing is a function of vertex id alone).
/// `prep_ns` covers the whole call — sampling included — so the overlap
/// metrics see the worker's true busy time.
pub fn fill_prep(
    prep: &mut PrepBatch,
    log: &EventLog,
    prev: &BatchPlan,
    cur: &BatchPlan,
    sampler: &NegativeSampler,
    rng: &mut Pcg32,
    router: ShardRouter,
) {
    let t0 = Instant::now();
    sampler.sample_batch(log, cur.range.clone(), rng, &mut prep.negatives);
    fill_prep_from(prep, log, prev, cur, router);
    prep.prep_ns = t0.elapsed().as_nanos() as u64;
}

/// Like [`fill_prep`] but with `prep.negatives` already populated by the
/// caller (the eval path samples from its own fixed-seed stream).
pub fn fill_prep_from(
    prep: &mut PrepBatch,
    log: &EventLog,
    prev: &BatchPlan,
    cur: &BatchPlan,
    router: ShardRouter,
) {
    let t0 = Instant::now();
    let b = prev.batch_size();
    debug_assert_eq!(cur.batch_size(), b);
    debug_assert_eq!(prep.batch_size(), b);
    let de = prep.u_efeat.len() / prep.rows().max(1);

    // ---- update rows (the previous batch, committed in-graph this step)
    for r in 0..prev.rows() {
        let ev = log.events[prev.upd_event[r] as usize];
        prep.u_other[r] = if r < b { ev.dst } else { ev.src };
        prep.u_t[r] = ev.t;
        if de > 0 {
            let feat = log.feat(prev.upd_event[r] as usize);
            if feat.is_empty() {
                prep.u_efeat[r * de..(r + 1) * de].fill(0.0);
            } else {
                prep.u_efeat[r * de..(r + 1) * de].copy_from_slice(feat);
            }
        }
    }
    prep.u_wmask.copy_from_slice(&prev.wmask);

    // ---- current batch: vertices, lag-one matches, event times
    for (j, i) in cur.range.clone().enumerate() {
        let ev = log.events[i];
        let vertices = [ev.src, ev.dst, prep.negatives[j]];
        prep.c_t[j] = ev.t;
        for (ri, &v) in vertices.iter().enumerate() {
            prep.c_vertex[ri][j] = v;
            match prev.last_row_of(v) {
                Some(r) => {
                    prep.c_match[ri][j] = r as i32;
                    prep.c_prev_t[ri][j] = log.events[prev.upd_event[r as usize] as usize].t;
                }
                None => {
                    prep.c_match[ri][j] = -1;
                    prep.c_prev_t[ri][j] = f32::NEG_INFINITY;
                }
            }
        }
    }

    // ---- shard routes for every list SPLICE gathers / WRITEBACK scatters
    ShardRoutes::compute(&mut prep.routes, router, &prev.upd_vertex, &prep.u_other, &prep.c_vertex);
    prep.prep_ns = t0.elapsed().as_nanos() as u64;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Event, EventLog, NO_LABEL};

    fn log_with(pairs: &[(u32, u32)], d_edge: usize) -> EventLog {
        let mut log = EventLog::new(16, 8, d_edge);
        for (i, &(s, d)) in pairs.iter().enumerate() {
            let feat: Vec<f32> = (0..d_edge).map(|k| (i * 10 + k) as f32).collect();
            log.push(Event { src: s, dst: d, t: i as f32 + 1.0, label: NO_LABEL }, &feat)
                .unwrap();
        }
        log
    }

    #[test]
    fn negative_stream_is_pure_and_decorrelated() {
        let mut a = negative_stream(7, 2, 13);
        let mut b = negative_stream(7, 2, 13);
        for _ in 0..64 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut c = negative_stream(7, 2, 14);
        let same = (0..64).filter(|_| a.next_u32() == c.next_u32()).count();
        assert!(same < 3);
    }

    #[test]
    fn prep_builds_pure_tensors() {
        let log = log_with(&[(0, 8), (1, 9), (0, 9), (2, 10)], 2);
        let prev = BatchPlan::build(&log, 0..2);
        let cur = BatchPlan::build(&log, 2..4);
        let mut prep = PrepBatch::new(2, 2);
        prep.negatives.copy_from_slice(&[11, 12]);
        fill_prep_from(&mut prep, &log, &prev, &cur, ShardRouter::flat());
        // update rows: src sides then dst sides of events 0..2
        assert_eq!(prep.u_other, vec![8, 9, 0, 1]);
        assert_eq!(prep.u_t, vec![1.0, 2.0, 1.0, 2.0]);
        assert_eq!(&prep.u_efeat[0..2], &[0.0, 1.0]);
        assert_eq!(prep.u_wmask, prev.wmask);
        // current event 2 = (0, 9): src 0 matched to prev row 0 (event t=1),
        // dst 9 to prev row 3 (event t=2), negative 11 unmatched
        assert_eq!(prep.c_vertex[0][0], 0);
        assert_eq!(prep.c_match[0][0], 0);
        assert_eq!(prep.c_prev_t[0][0], 1.0);
        assert_eq!(prep.c_match[1][0], 3);
        assert_eq!(prep.c_prev_t[1][0], 2.0);
        assert_eq!(prep.c_vertex[2][0], 11);
        assert_eq!(prep.c_match[2][0], -1);
        assert_eq!(prep.c_prev_t[2][0], f32::NEG_INFINITY);
        assert_eq!(prep.c_t, vec![3.0, 4.0]);
    }

    #[test]
    fn prep_is_deterministic_per_stream() {
        let log = log_with(&[(0, 8), (1, 9), (2, 10), (3, 11)], 0);
        let prev = BatchPlan::build(&log, 0..2);
        let cur = BatchPlan::build(&log, 2..4);
        let sampler = NegativeSampler::new(&log);
        let mut a = PrepBatch::new(2, 0);
        let mut b = PrepBatch::new(2, 0);
        fill_prep(
            &mut a, &log, &prev, &cur, &sampler, &mut negative_stream(3, 1, 5),
            ShardRouter::flat(),
        );
        fill_prep(
            &mut b, &log, &prev, &cur, &sampler, &mut negative_stream(3, 1, 5),
            ShardRouter::flat(),
        );
        assert_eq!(a.negatives, b.negatives);
        assert_eq!(a.c_prev_t, b.c_prev_t);
    }

    #[test]
    fn prep_precomputes_shard_routes_for_sharded_routers() {
        let log = log_with(&[(0, 8), (1, 9), (0, 9), (2, 10)], 0);
        let prev = BatchPlan::build(&log, 0..2);
        let cur = BatchPlan::build(&log, 2..4);
        let mut prep = PrepBatch::new(2, 0);
        prep.negatives.copy_from_slice(&[11, 12]);
        let router = ShardRouter { n_shards: 3 };
        fill_prep_from(&mut prep, &log, &prev, &cur, router);
        assert_eq!(prep.routes.n_shards, 3);
        assert_eq!(prep.routes.u_self.len(), prev.rows());
        assert_eq!(prep.routes.u_other.len(), prep.u_other.len());
        for ri in 0..3 {
            for (r, &v) in prep.routes.c_vertex[ri].iter().zip(&prep.c_vertex[ri]) {
                assert_eq!(*r, router.route(v));
            }
        }
        for (r, &v) in prep.routes.u_self.iter().zip(&prev.upd_vertex) {
            assert_eq!(*r, router.route(v));
        }
        // refilled under flat routing, the routes clear again
        fill_prep_from(&mut prep, &log, &prev, &cur, ShardRouter::flat());
        assert_eq!(prep.routes.n_shards, 1);
        assert!(prep.routes.u_self.is_empty());
    }
}
