//! PREP — the pure stage of the training pipeline.
//!
//! Everything a training iteration needs that does **not** read the memory
//! substrates (store / neighbor index / mailbox / GMM trackers) is computed
//! here: negative sampling, update-row event features and times, the
//! lag-one match indices, and the per-role vertex lists the SPLICE stage
//! gathers memory rows for. Because all of it is a pure function of the
//! immutable `(EventLog, BatchPlan, seed)` triple, PREP for batches
//! `t+1..t+depth` can run on a background thread while batch `t` executes
//! on the device — see [`crate::pipeline`] for the stage diagram.
//!
//! ## Parallel PREP (worker-pool fan-out)
//!
//! Each hot loop is **per-row independent**, so the `*_with` entry points
//! fan rows out across a persistent [`WorkerPool`] in fixed chunks:
//! negative sampling draws row `j` from its own `base.split(j)` stream
//! (see [`crate::sampler::NegativeSampler::sample_batch_rowwise`]), the
//! update-row loop writes `(u_other, u_t, u_efeat)[r]`, the current-batch
//! loop writes `(c_t, c_vertex, c_match, c_prev_t)[·][j]`, and route
//! precomputation writes `routes[·][r]` — all to disjoint slots at fixed
//! indices. Results are therefore bit-identical for every lane count
//! (chunking changes *where* a row is computed, never *what*), which keeps
//! the pipeline-vs-sequential equivalence intact while deep prefetch
//! finally scales with cores instead of saturating one PREP thread.

use crate::batching::BatchPlan;
use crate::graph::EventLog;
use crate::memory::{ShardRouter, ShardRoutes};
use crate::sampler::NegativeSampler;
use crate::util::pool::{chunk_for, take_chunk, WorkerPool};
use crate::util::rng::{splitmix64, Pcg32};

/// Rows below which the PREP loops stay on one lane: a chunk handoff costs
/// ~1–2 µs, which only pays once per-row work (event lookups, feature
/// copies, lag-one matching) dwarfs it.
const PREP_PAR_MIN_ROWS: usize = 256;

/// The Send-able half of a host batch: every tensor the step consumes that
/// is independent of the mutable memory substrates. One `PrepBatch` covers
/// one iteration `i`: update rows come from the *previous* plan (whose
/// events are committed in-graph this step), current rows from plan `i`.
#[derive(Clone, Debug)]
pub struct PrepBatch {
    /// Plan index this batch was prepped for (ordering check).
    pub index: usize,
    /// Epoch the negative stream was seeded with.
    pub epoch: usize,
    /// Sampled negative destination per current event. [b]
    pub negatives: Vec<u32>,
    /// Other endpoint per update row (dst for src rows, src for dst rows),
    /// so SPLICE can batch-gather `u_other_mem`. [2b]
    pub u_other: Vec<u32>,
    /// Event time per update row (write-back timestamps + dt baseline). [2b]
    pub u_t: Vec<f32>,
    /// Edge features per update row. [2b * d_edge]
    pub u_efeat: Vec<f32>,
    /// Write-back mask (copy of the plan's last-occurrence mask). [2b]
    pub u_wmask: Vec<f32>,
    /// Vertex ids per role (src/dst/neg) of the current batch. [3][b]
    pub c_vertex: [Vec<u32>; 3],
    /// Lag-one match row into the previous batch, -1 when absent. [3][b]
    pub c_match: [Vec<i32>; 3],
    /// Event time of the previous-batch row matched above, or -inf when
    /// there is none (SPLICE takes max with the store clock). [3][b]
    pub c_prev_t: [Vec<f32>; 3],
    /// Event time of each current event. [b]
    pub c_t: Vec<f32>,
    /// Per-row shard routes for every gather/scatter list above, computed
    /// for the trainer's memory backend so SPLICE/WRITEBACK skip routing
    /// math on the coordinator thread (empty under flat routing).
    pub routes: ShardRoutes,
    /// Wall-clock nanoseconds spent filling this batch (overlap metrics).
    pub prep_ns: u64,
}

impl PrepBatch {
    pub fn new(b: usize, d_edge: usize) -> PrepBatch {
        let u = 2 * b;
        PrepBatch {
            index: 0,
            epoch: 0,
            negatives: vec![0; b],
            u_other: vec![0; u],
            u_t: vec![0.0; u],
            u_efeat: vec![0.0; u * d_edge],
            u_wmask: vec![0.0; u],
            c_vertex: std::array::from_fn(|_| vec![0; b]),
            c_match: std::array::from_fn(|_| vec![-1; b]),
            c_prev_t: std::array::from_fn(|_| vec![f32::NEG_INFINITY; b]),
            c_t: vec![0.0; b],
            routes: ShardRoutes::default(),
            prep_ns: 0,
        }
    }

    pub fn batch_size(&self) -> usize {
        self.c_t.len()
    }

    /// Update-row count (2b).
    pub fn rows(&self) -> usize {
        self.u_t.len()
    }
}

/// Derive the negative-sampling stream for `(seed, epoch, batch)` as a pure
/// function — NOT from a mutating trainer RNG. This is what lets PREP run
/// out of order / off-thread and still reproduce the sequential loop
/// bit-for-bit (the pipeline-vs-sequential equivalence guarantee).
pub fn negative_stream(seed: u64, epoch: usize, batch: usize) -> Pcg32 {
    let mut h = seed
        ^ 0x5EED_FACE_CAFE_F00Du64
        ^ ((epoch as u64) << 32 | batch as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    Pcg32::new(splitmix64(&mut h))
}

/// Fill `prep` for one iteration: sample negatives row-wise from `base`'s
/// per-row split streams, then build every pure tensor. `prev`/`cur` must
/// be consecutive plans of `log`; `router` is the memory backend's routing
/// policy (shard routes are part of the pure PREP output — routing is a
/// function of vertex id alone). Runs on the shared process pool; the
/// trainer/prefetcher pass their own via [`fill_prep_with`]. `prep_ns`
/// covers the whole call — sampling included — so the overlap metrics see
/// the worker's true busy time.
pub fn fill_prep(
    prep: &mut PrepBatch,
    log: &EventLog,
    prev: &BatchPlan,
    cur: &BatchPlan,
    sampler: &NegativeSampler,
    base: &Pcg32,
    router: ShardRouter,
) {
    fill_prep_with(prep, log, prev, cur, sampler, base, router, WorkerPool::global());
}

/// [`fill_prep`] on an explicit worker pool.
#[allow(clippy::too_many_arguments)]
pub fn fill_prep_with(
    prep: &mut PrepBatch,
    log: &EventLog,
    prev: &BatchPlan,
    cur: &BatchPlan,
    sampler: &NegativeSampler,
    base: &Pcg32,
    router: ShardRouter,
    pool: &WorkerPool,
) {
    let t0 = crate::util::now();
    sampler.sample_batch_rowwise(log, cur.range.clone(), base, &mut prep.negatives, pool);
    fill_prep_from_with(prep, log, prev, cur, router, pool);
    prep.prep_ns = t0.elapsed().as_nanos() as u64;
}

/// Like [`fill_prep`] but with `prep.negatives` already populated by the
/// caller (the eval path samples from its own fixed-seed stream).
pub fn fill_prep_from(
    prep: &mut PrepBatch,
    log: &EventLog,
    prev: &BatchPlan,
    cur: &BatchPlan,
    router: ShardRouter,
) {
    fill_prep_from_with(prep, log, prev, cur, router, WorkerPool::global());
}

/// [`fill_prep_from`] on an explicit worker pool. Every loop writes
/// per-row disjoint slots, so the fan-out is bit-identical to the serial
/// path for any lane count (see the module docs).
pub fn fill_prep_from_with(
    prep: &mut PrepBatch,
    log: &EventLog,
    prev: &BatchPlan,
    cur: &BatchPlan,
    router: ShardRouter,
    pool: &WorkerPool,
) {
    let t0 = crate::util::now();
    let b = prev.batch_size();
    debug_assert_eq!(cur.batch_size(), b);
    debug_assert_eq!(prep.batch_size(), b);
    let rows = prev.rows();
    let de = prep.u_efeat.len() / prep.rows().max(1);

    // ---- update rows (the previous batch, committed in-graph this step)
    {
        struct UpdChunk<'a> {
            r0: usize,
            u_other: &'a mut [u32],
            u_t: &'a mut [f32],
            u_efeat: &'a mut [f32],
        }
        let chunk = chunk_for(rows, pool.lanes(), PREP_PAR_MIN_ROWS);
        let mut tasks: Vec<UpdChunk> = Vec::with_capacity(rows.div_ceil(chunk));
        let mut uo = prep.u_other.as_mut_slice();
        let mut ut = prep.u_t.as_mut_slice();
        let mut ue = prep.u_efeat.as_mut_slice();
        let mut r0 = 0;
        while r0 < rows {
            let n = chunk.min(rows - r0);
            tasks.push(UpdChunk {
                r0,
                u_other: take_chunk(&mut uo, n),
                u_t: take_chunk(&mut ut, n),
                u_efeat: take_chunk(&mut ue, n * de),
            });
            r0 += n;
        }
        pool.run(&mut tasks, |c| {
            for k in 0..c.u_other.len() {
                let r = c.r0 + k;
                let ev = log.events[prev.upd_event[r] as usize];
                c.u_other[k] = if r < b { ev.dst } else { ev.src };
                c.u_t[k] = ev.t;
                if de > 0 {
                    let feat = log.feat(prev.upd_event[r] as usize);
                    let slot = &mut c.u_efeat[k * de..(k + 1) * de];
                    if feat.is_empty() {
                        slot.fill(0.0);
                    } else {
                        slot.copy_from_slice(feat);
                    }
                }
            }
        });
    }
    prep.u_wmask.copy_from_slice(&prev.wmask);

    // ---- current batch: vertices, lag-one matches, event times
    {
        struct CurChunk<'a> {
            j0: usize,
            c_t: &'a mut [f32],
            c_vertex: [&'a mut [u32]; 3],
            c_match: [&'a mut [i32]; 3],
            c_prev_t: [&'a mut [f32]; 3],
        }
        let negatives = prep.negatives.as_slice();
        let [cv0, cv1, cv2] = &mut prep.c_vertex;
        let [cm0, cm1, cm2] = &mut prep.c_match;
        let [cp0, cp1, cp2] = &mut prep.c_prev_t;
        let mut cv = [cv0.as_mut_slice(), cv1.as_mut_slice(), cv2.as_mut_slice()];
        let mut cm = [cm0.as_mut_slice(), cm1.as_mut_slice(), cm2.as_mut_slice()];
        let mut cp = [cp0.as_mut_slice(), cp1.as_mut_slice(), cp2.as_mut_slice()];
        let mut ct = prep.c_t.as_mut_slice();
        let chunk = chunk_for(b, pool.lanes(), PREP_PAR_MIN_ROWS);
        let mut tasks: Vec<CurChunk> = Vec::with_capacity(b.div_ceil(chunk.max(1)));
        let mut j0 = 0;
        while j0 < b {
            let n = chunk.min(b - j0);
            tasks.push(CurChunk {
                j0,
                c_t: take_chunk(&mut ct, n),
                c_vertex: std::array::from_fn(|ri| take_chunk(&mut cv[ri], n)),
                c_match: std::array::from_fn(|ri| take_chunk(&mut cm[ri], n)),
                c_prev_t: std::array::from_fn(|ri| take_chunk(&mut cp[ri], n)),
            });
            j0 += n;
        }
        pool.run(&mut tasks, |c| {
            for k in 0..c.c_t.len() {
                let j = c.j0 + k;
                let ev = log.events[cur.range.start + j];
                let vertices = [ev.src, ev.dst, negatives[j]];
                c.c_t[k] = ev.t;
                for (ri, &v) in vertices.iter().enumerate() {
                    c.c_vertex[ri][k] = v;
                    match prev.last_row_of(v) {
                        Some(r) => {
                            c.c_match[ri][k] = r as i32;
                            c.c_prev_t[ri][k] =
                                log.events[prev.upd_event[r as usize] as usize].t;
                        }
                        None => {
                            c.c_match[ri][k] = -1;
                            c.c_prev_t[ri][k] = f32::NEG_INFINITY;
                        }
                    }
                }
            }
        });
    }

    // ---- shard routes for every list SPLICE gathers / WRITEBACK scatters
    prep.routes.compute_with(router, &prev.upd_vertex, &prep.u_other, &prep.c_vertex, pool);
    prep.prep_ns = t0.elapsed().as_nanos() as u64;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Event, EventLog, NO_LABEL};

    fn log_with(pairs: &[(u32, u32)], d_edge: usize) -> EventLog {
        let mut log = EventLog::new(16, 8, d_edge);
        for (i, &(s, d)) in pairs.iter().enumerate() {
            let feat: Vec<f32> = (0..d_edge).map(|k| (i * 10 + k) as f32).collect();
            log.push(Event { src: s, dst: d, t: i as f32 + 1.0, label: NO_LABEL }, &feat)
                .unwrap();
        }
        log
    }

    #[test]
    fn negative_stream_is_pure_and_decorrelated() {
        let mut a = negative_stream(7, 2, 13);
        let mut b = negative_stream(7, 2, 13);
        for _ in 0..64 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut c = negative_stream(7, 2, 14);
        let same = (0..64).filter(|_| a.next_u32() == c.next_u32()).count();
        assert!(same < 3);
    }

    #[test]
    fn prep_builds_pure_tensors() {
        let log = log_with(&[(0, 8), (1, 9), (0, 9), (2, 10)], 2);
        let prev = BatchPlan::build(&log, 0..2);
        let cur = BatchPlan::build(&log, 2..4);
        let mut prep = PrepBatch::new(2, 2);
        prep.negatives.copy_from_slice(&[11, 12]);
        fill_prep_from(&mut prep, &log, &prev, &cur, ShardRouter::flat());
        // update rows: src sides then dst sides of events 0..2
        assert_eq!(prep.u_other, vec![8, 9, 0, 1]);
        assert_eq!(prep.u_t, vec![1.0, 2.0, 1.0, 2.0]);
        assert_eq!(&prep.u_efeat[0..2], &[0.0, 1.0]);
        assert_eq!(prep.u_wmask, prev.wmask);
        // current event 2 = (0, 9): src 0 matched to prev row 0 (event t=1),
        // dst 9 to prev row 3 (event t=2), negative 11 unmatched
        assert_eq!(prep.c_vertex[0][0], 0);
        assert_eq!(prep.c_match[0][0], 0);
        assert_eq!(prep.c_prev_t[0][0], 1.0);
        assert_eq!(prep.c_match[1][0], 3);
        assert_eq!(prep.c_prev_t[1][0], 2.0);
        assert_eq!(prep.c_vertex[2][0], 11);
        assert_eq!(prep.c_match[2][0], -1);
        assert_eq!(prep.c_prev_t[2][0], f32::NEG_INFINITY);
        assert_eq!(prep.c_t, vec![3.0, 4.0]);
    }

    #[test]
    fn prep_is_deterministic_per_stream() {
        let log = log_with(&[(0, 8), (1, 9), (2, 10), (3, 11)], 0);
        let prev = BatchPlan::build(&log, 0..2);
        let cur = BatchPlan::build(&log, 2..4);
        let sampler = NegativeSampler::new(&log);
        let mut a = PrepBatch::new(2, 0);
        let mut b = PrepBatch::new(2, 0);
        fill_prep(
            &mut a, &log, &prev, &cur, &sampler, &negative_stream(3, 1, 5),
            ShardRouter::flat(),
        );
        fill_prep(
            &mut b, &log, &prev, &cur, &sampler, &negative_stream(3, 1, 5),
            ShardRouter::flat(),
        );
        assert_eq!(a.negatives, b.negatives);
        assert_eq!(a.c_prev_t, b.c_prev_t);
    }

    #[test]
    fn pooled_prep_is_bit_identical_for_every_worker_count() {
        // a batch large enough to clear PREP_PAR_MIN_ROWS so multi-lane
        // pools genuinely fan out, against a sharded router so route
        // precomputation is exercised too
        let pairs: Vec<(u32, u32)> = (0..1200).map(|i| (i % 8, 8 + (i * 3) % 8)).collect();
        let mut log = EventLog::new(32, 8, 2);
        for (i, &(s, d)) in pairs.iter().enumerate() {
            log.push(
                Event { src: s, dst: d, t: i as f32 + 1.0, label: NO_LABEL },
                &[i as f32, -(i as f32)],
            )
            .unwrap();
        }
        let b = 600;
        let prev = BatchPlan::build(&log, 0..b);
        let cur = BatchPlan::build(&log, b..2 * b);
        let sampler = NegativeSampler::new(&log);
        let router = ShardRouter { n_shards: 3 };
        let base = negative_stream(11, 2, 7);

        let mut want = PrepBatch::new(b, 2);
        fill_prep_with(
            &mut want, &log, &prev, &cur, &sampler, &base, router,
            &crate::util::pool::WorkerPool::new(1),
        );
        for lanes in [2usize, 4, 8] {
            let pool = crate::util::pool::WorkerPool::new(lanes);
            let mut got = PrepBatch::new(b, 2);
            fill_prep_with(&mut got, &log, &prev, &cur, &sampler, &base, router, &pool);
            assert_eq!(got.negatives, want.negatives, "lanes={lanes}");
            assert_eq!(got.u_other, want.u_other, "lanes={lanes}");
            assert_eq!(got.u_t, want.u_t, "lanes={lanes}");
            assert_eq!(got.u_efeat, want.u_efeat, "lanes={lanes}");
            assert_eq!(got.u_wmask, want.u_wmask, "lanes={lanes}");
            assert_eq!(got.c_vertex, want.c_vertex, "lanes={lanes}");
            assert_eq!(got.c_match, want.c_match, "lanes={lanes}");
            assert_eq!(got.c_prev_t, want.c_prev_t, "lanes={lanes}");
            assert_eq!(got.c_t, want.c_t, "lanes={lanes}");
            assert_eq!(got.routes.u_self, want.routes.u_self, "lanes={lanes}");
            assert_eq!(got.routes.u_other, want.routes.u_other, "lanes={lanes}");
            assert_eq!(got.routes.c_vertex, want.routes.c_vertex, "lanes={lanes}");
        }
    }

    #[test]
    fn prep_precomputes_shard_routes_for_sharded_routers() {
        let log = log_with(&[(0, 8), (1, 9), (0, 9), (2, 10)], 0);
        let prev = BatchPlan::build(&log, 0..2);
        let cur = BatchPlan::build(&log, 2..4);
        let mut prep = PrepBatch::new(2, 0);
        prep.negatives.copy_from_slice(&[11, 12]);
        let router = ShardRouter { n_shards: 3 };
        fill_prep_from(&mut prep, &log, &prev, &cur, router);
        assert_eq!(prep.routes.n_shards, 3);
        assert_eq!(prep.routes.u_self.len(), prev.rows());
        assert_eq!(prep.routes.u_other.len(), prep.u_other.len());
        for ri in 0..3 {
            for (r, &v) in prep.routes.c_vertex[ri].iter().zip(&prep.c_vertex[ri]) {
                assert_eq!(*r, router.route(v));
            }
        }
        for (r, &v) in prep.routes.u_self.iter().zip(&prev.upd_vertex) {
            assert_eq!(*r, router.route(v));
        }
        // refilled under flat routing, the routes clear again
        fill_prep_from(&mut prep, &log, &prev, &cur, ShardRouter::flat());
        assert_eq!(prep.routes.n_shards, 1);
        assert!(prep.routes.u_self.is_empty());
    }
}
