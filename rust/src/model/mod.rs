//! Host-side model state: parameter initialization from the manifest's
//! init specs and the device-resident parameter/optimizer buffers.

pub mod params;

pub use params::ModelState;
