//! Parameter initialization + step-to-step state threading.
//!
//! The manifest (produced by python/compile/aot.py) declares every
//! parameter's shape and init scheme; rust initializes with its own seeded
//! RNG. Parameters and Adam moments are kept as XLA literals that thread
//! from one train step's outputs into the next step's inputs — the PJRT
//! CPU client returns tupled results, so this host residency is the
//! canonical path (see runtime::engine module docs).

use anyhow::Result;
use xla::Literal;

use crate::runtime::engine::{fetch_f32, lit_f32};
use crate::runtime::{Engine, InitSpec, ParamSpec};
use crate::util::rng::Pcg32;

/// Parameters + Adam state for one model, as ready-to-execute literals.
pub struct ModelState {
    pub names: Vec<String>,
    pub params: Vec<Literal>,
    pub adam_m: Vec<Literal>,
    pub adam_v: Vec<Literal>,
    /// Adam step counter (bias-correction input `step_t`).
    pub step: u64,
    shapes: Vec<Vec<usize>>,
}

/// Initialize one parameter host-side per its init spec.
pub fn init_host(spec: &ParamSpec, rng: &mut Pcg32) -> Vec<f32> {
    match &spec.init {
        InitSpec::Zeros => vec![0.0; spec.elems()],
        InitSpec::Const(values) => {
            assert_eq!(values.len(), spec.elems(), "const init size mismatch");
            values.clone()
        }
        InitSpec::GlorotUniform { fan_in, fan_out } => {
            let limit = (6.0 / (*fan_in as f32 + *fan_out as f32)).sqrt();
            (0..spec.elems())
                .map(|_| rng.range_f32(-limit, limit))
                .collect()
        }
    }
}

impl ModelState {
    /// Initialize all parameters + zeroed Adam moments for `model`
    /// ("tgn" | "jodie" | "apan" | "clf").
    pub fn init(engine: &Engine, model: &str, seed: u64) -> Result<ModelState> {
        let specs = engine.manifest().param_specs(model)?.to_vec();
        let mut rng = Pcg32::new(seed ^ 0x9A7A);
        let mut names = Vec::new();
        let mut params = Vec::new();
        let mut adam_m = Vec::new();
        let mut adam_v = Vec::new();
        let mut shapes = Vec::new();
        for spec in &specs {
            let host = init_host(spec, &mut rng);
            params.push(lit_f32(&host, &spec.shape)?);
            let zeros = vec![0.0f32; spec.elems()];
            adam_m.push(lit_f32(&zeros, &spec.shape)?);
            adam_v.push(lit_f32(&zeros, &spec.shape)?);
            names.push(spec.name.clone());
            shapes.push(spec.shape.clone());
        }
        Ok(ModelState {
            names,
            params,
            adam_m,
            adam_v,
            step: 0,
            shapes,
        })
    }

    pub fn len(&self) -> usize {
        self.params.len()
    }

    pub fn is_empty(&self) -> bool {
        self.params.is_empty()
    }

    /// Consume a train step's leading output literals as the new state
    /// (ABI: [params..., m..., v..., step outputs...]). After the call,
    /// `outputs` holds only the step outputs.
    pub fn absorb_outputs(&mut self, outputs: &mut Vec<Literal>) {
        let n = self.params.len();
        debug_assert!(outputs.len() >= 3 * n);
        let mut rest = outputs.split_off(3 * n);
        let mut v = outputs.split_off(2 * n);
        let mut m = outputs.split_off(n);
        std::mem::swap(&mut self.params, outputs);
        std::mem::swap(&mut self.adam_m, &mut m);
        std::mem::swap(&mut self.adam_v, &mut v);
        std::mem::swap(outputs, &mut rest);
        self.step += 1;
    }

    /// Download one parameter (diagnostics; e.g. reading learned gamma).
    pub fn fetch(&self, name: &str) -> Result<Vec<f32>> {
        let idx = self
            .names
            .iter()
            .position(|n| n == name)
            .ok_or_else(|| anyhow::anyhow!("no param '{name}'"))?;
        let elems: usize = self.shapes[idx].iter().product();
        let mut out = vec![0.0f32; elems];
        fetch_f32(&self.params[idx], &mut out)?;
        Ok(out)
    }

    /// The learned PRES fusion weight gamma = sigmoid(gamma_raw) (Eq. 8).
    pub fn gamma(&self) -> Result<f32> {
        let raw = self.fetch("gamma_raw")?;
        Ok(1.0 / (1.0 + (-raw[0]).exp()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::InitSpec;

    #[test]
    fn glorot_respects_limit_and_seed() {
        let spec = ParamSpec {
            name: "w".into(),
            shape: vec![32, 16],
            init: InitSpec::GlorotUniform { fan_in: 32, fan_out: 16 },
        };
        let a = init_host(&spec, &mut Pcg32::new(1));
        let b = init_host(&spec, &mut Pcg32::new(1));
        assert_eq!(a, b);
        let limit = (6.0f32 / 48.0).sqrt();
        assert!(a.iter().all(|x| x.abs() <= limit));
        // not degenerate
        assert!(a.iter().any(|x| x.abs() > limit * 0.5));
    }

    #[test]
    fn zeros_and_const() {
        let z = ParamSpec { name: "b".into(), shape: vec![4], init: InitSpec::Zeros };
        assert_eq!(init_host(&z, &mut Pcg32::new(0)), vec![0.0; 4]);
        let c = ParamSpec {
            name: "c".into(),
            shape: vec![2],
            init: InitSpec::Const(vec![1.5, -2.0]),
        };
        assert_eq!(init_host(&c, &mut Pcg32::new(0)), vec![1.5, -2.0]);
    }
}
