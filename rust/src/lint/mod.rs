//! Repo-invariant lint (`pallas-lint`): mechanical checks for the
//! hand-maintained soundness rules the concurrent runtime rests on.
//!
//! PRs 1–8 turned the sequential PRES loop into a pipelined runtime whose
//! correctness is carried by conventions — pooled loops write disjoint
//! slots, the span rings have a single seqlock writer, commits apply in
//! plan order, every `unsafe` is justified by an argument about the
//! generation barrier. Conventions rot silently. This module walks
//! `src/`, `benches/` and `tests/` with a lightweight token-stream pass
//! (comments and string/char literals are lexed away first, the surviving
//! code is tokenized into identifier/punctuation streams; zero external
//! parser crates) and enforces the rules below. Line endings are
//! normalized before lexing (`\r\n` and `\n` lint identically), and
//! directive/safety-comment matching only ever sees real comment text —
//! a directive smuggled inside a string literal is data, not policy.
//! The same pass runs three ways: the `pallas-lint` binary (human
//! output, `--json` for machines), the `repo_tree_is_lint_clean` unit
//! test (so the tier-1 `cargo test` gate catches violations), and a
//! dedicated CI step.
//!
//! # Repo invariants
//!
//! ## `safety-comment`
//! Every line of `unsafe` code must carry a `// SAFETY:` comment on the
//! same line or in the comment/attribute block directly above it. The
//! pool's `'static` transmute in `WorkerPool::broadcast` is sound *only
//! because the submitter blocks at the generation barrier* — that kind of
//! argument stops being re-checked the moment it is not written next to
//! the code it justifies.
//!
//! ## `no-direct-print`
//! No `println!` / `eprintln!` / `print!` / `eprint!` outside `src/trace/`
//! — use the leveled `log_*!` macros. The CLI is scripted (CI parses the
//! traced run's artifacts); a stray print either corrupts machine-read
//! output or silently bypasses `--log-level`. Sanctioned: `src/trace/`
//! (the logger's own sink) and `src/bin/lint.rs` (findings *are* its
//! stdout product).
//!
//! ## `total-cmp`
//! No `partial_cmp(..).unwrap()` — the PR 5 bug class: ranking NaN-scored
//! candidates panicked mid-epoch because `partial_cmp` returns `None` for
//! NaN. `f32::total_cmp` / `f64::total_cmp` are total orders and never
//! panic.
//!
//! ## `thread-discipline`
//! No `std::thread::{spawn, scope, Builder}` outside the sanctioned
//! runtime modules: `util/pool.rs` (the generation-barrier pool),
//! `pipeline/stream.rs` (EXEC stream lanes), `pipeline/prep.rs` and
//! `pipeline/runner.rs` (the PREP stage and the prefetch thread it runs
//! on). All other host parallelism must flow through `WorkerPool::run` so
//! panic propagation, barrier semantics and span-ring registration hold.
//! Tests that genuinely need bare threads (the seqlock stress readers,
//! the scoped-spawn baseline in `benches/pool_scaling.rs`) carry an
//! explicit allow directive.
//!
//! ## `clock-discipline`
//! No `Instant::now()` outside `src/trace/` and `src/metrics/` — stage
//! code takes timestamps through `crate::util::now()` instead, one
//! greppable choke point, so clock-origin refactors (span origin
//! anchoring, a virtual clock for replay) touch a single function.
//! Sanctioned: `src/trace/`, `src/metrics/`, `src/util/mod.rs` (the
//! helper itself) and `src/util/bench.rs` (the bench harness timing its
//! own reps).
//!
//! ## `hash-iter-order`
//! No `HashMap`/`HashSet` anywhere in `src/`, `benches/` or `tests/` —
//! use `BTreeMap`/`BTreeSet` or a sorted `Vec` + `binary_search`.
//! `RandomState` hashing makes iteration order a per-process accident,
//! and the determinism contract (bit-identical results across shards,
//! workers and streams) cannot rest on every consumer of a hash table
//! happening to be order-independent. The historical hazard is exactly
//! that shape: `batching/pending.rs` built its last-row and
//! occurrence-count tables in hash order and stayed deterministic only
//! because each consumer was order-independent — one refactor (say,
//! emitting the write-mask from the iteration itself) away from a
//! nondeterministic splice. A *probe-only* table that is provably never
//! iterated may carry a justified allow instead of a conversion.
//!
//! ## `rng-discipline`
//! No `thread_rng` / `from_entropy` / `OsRng` / `StdRng` / `SmallRng` /
//! `getrandom` / `SystemTime::now()` — all randomness must be a `Pcg32`
//! stream derived from the run seed via `split` (`util/rng.rs`), so the
//! draw sequence is a pure function of `(seed, stream id)` no matter how
//! work lands on shards, workers or streams. The hazard is that an
//! entropy- or clock-seeded sampler passes every in-process equivalence
//! gate (both sides of the comparison share the process-local seed)
//! while silently destroying cross-run reproducibility — the failure
//! only surfaces when a CI rerun can't reproduce a regression.
//!
//! ## `float-reduction`
//! No bare `.sum::<f32>()` and no `fold` with an `f32` accumulator
//! outside the sanctioned reduction helpers (`src/runtime/gemm.rs`,
//! `src/runtime/host_step.rs`). f32 addition is not associative; a
//! reduction whose order follows worker count or stream interleaving
//! drifts in the last ulp and breaks the bit-equivalence gates that
//! license every pipelining optimization since PR 1. Inside the
//! sanctioned helpers the reduction tree is fixed by the kernel ABI
//! (blocked loops in a deterministic order), not by the schedule —
//! route new reductions through them or accumulate in a fixed order.
//!
//! ## `bench-manifest`
//! Every `[[bench]]` target in `Cargo.toml` has a `benches/<name>.rs`
//! that writes its `BENCH_*.json` artifact (`Bench::write_json`, or
//! `report_json` + `fs::write` for benches that post-process the doc), so
//! the ROADMAP's "benches emit comparable artifacts" promise stays true
//! as benches accrete instead of only holding for the ones CI uploads.
//!
//! # Suppression
//! `// lint: allow(<rule>) — <justification>` on the offending line or
//! the line directly above it. The justification is mandatory and the
//! rule name must be one of the rules above; a directive with an unknown
//! rule or an empty justification is itself a finding (`bad-allow`).
//! There is deliberately no file- or repo-level suppression: every
//! exception is visible at the site it excuses.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One rule violation at a source location.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Path relative to the crate root, forward slashes.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule name (one of [`RULES`]).
    pub rule: &'static str,
    /// Human-readable description of the violation.
    pub msg: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Every rule this pass enforces, with a one-line summary (the long-form
/// rationale lives in the module docs above).
pub const RULES: &[(&str, &str)] = &[
    ("safety-comment", "unsafe code must carry a `// SAFETY:` comment"),
    ("no-direct-print", "no direct print macros outside src/trace/ — use log_*!"),
    ("total-cmp", "no partial_cmp(..).unwrap() — use total_cmp"),
    ("thread-discipline", "no raw std::thread outside the sanctioned runtime modules"),
    ("clock-discipline", "no Instant::now() outside trace/metrics — use crate::util::now()"),
    ("hash-iter-order", "no HashMap/HashSet — use BTreeMap/BTreeSet or a sorted Vec"),
    ("rng-discipline", "all randomness via seed-derived rng streams, never entropy or clocks"),
    ("float-reduction", "no bare f32 reductions outside the sanctioned kernel helpers"),
    ("bench-manifest", "every [[bench]] target writes its BENCH_*.json artifact"),
    ("bad-allow", "allow directives must name a known rule and justify themselves"),
];

const SAFETY_RULE: &str = RULES[0].0;
const PRINT_RULE: &str = RULES[1].0;
const CMP_RULE: &str = RULES[2].0;
const THREAD_RULE: &str = RULES[3].0;
const CLOCK_RULE: &str = RULES[4].0;
const HASH_RULE: &str = RULES[5].0;
const RNG_RULE: &str = RULES[6].0;
const FLOAT_RULE: &str = RULES[7].0;
const BENCH_RULE: &str = RULES[8].0;
const ALLOW_RULE: &str = RULES[9].0;

/// Files (exact) or directories (trailing `/`) exempt from
/// `no-direct-print`.
const PRINT_SANCTIONED: &[&str] = &["src/trace/", "src/bin/lint.rs", "src/bin/verify.rs"];

/// Modules allowed to create threads directly (see module docs).
const THREAD_SANCTIONED: &[&str] = &[
    "src/util/pool.rs",
    "src/pipeline/stream.rs",
    "src/pipeline/prep.rs",
    "src/pipeline/runner.rs",
];

/// Modules allowed to read the raw monotonic clock.
const CLOCK_SANCTIONED: &[&str] =
    &["src/trace/", "src/metrics/", "src/util/mod.rs", "src/util/bench.rs"];

/// The reduction helpers whose f32 accumulation order is fixed by the
/// kernel ABI rather than the schedule (see module docs).
const FLOAT_SANCTIONED: &[&str] = &["src/runtime/gemm.rs", "src/runtime/host_step.rs"];

// ------------------------------------------------------------------ lexer

/// One source line split into executable code and comment text. String,
/// raw-string and char literals are dropped from `code` (a bare `"` marks
/// where each string literal sat); `//` and `/* */` bodies land in
/// `comment`; `toks` is the token stream of `code` (so every rule scan
/// sees identifiers with hard word boundaries, never literal contents).
#[derive(Debug, Default, Clone)]
struct Line {
    code: String,
    comment: String,
    toks: Vec<Tok>,
}

/// One token of literal-stripped line code. Whitespace is dropped; runs
/// of `[A-Za-z0-9_]` become `Ident`, everything else is a single-char
/// `Punct` (`::` is two `Punct(':')` in a row).
#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Ident(String),
    Punct(char),
}

fn tokenize(code: &str) -> Vec<Tok> {
    let mut toks = Vec::new();
    let mut cur = String::new();
    for c in code.chars() {
        if c.is_ascii_alphanumeric() || c == '_' {
            cur.push(c);
        } else {
            if !cur.is_empty() {
                toks.push(Tok::Ident(std::mem::take(&mut cur)));
            }
            if !c.is_whitespace() {
                toks.push(Tok::Punct(c));
            }
        }
    }
    if !cur.is_empty() {
        toks.push(Tok::Ident(cur));
    }
    toks
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Mode {
    Code,
    /// Inside `/* */`, tracking nesting depth.
    Block(u32),
    /// Inside a `"..."` literal (persists across lines).
    Str,
    /// Inside a raw string literal with this many `#`s.
    RawStr(u32),
}

/// Count `#`s after `chars[i] == 'r'` and require an opening quote; returns
/// the hash count for a raw-string start, `None` otherwise (covers raw
/// identifiers like `r#type`, which have no quote).
fn raw_string_hashes(chars: &[char], i: usize) -> Option<u32> {
    let mut j = i + 1;
    let mut hashes = 0u32;
    while j < chars.len() && chars[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j < chars.len() && chars[j] == '"' {
        Some(hashes)
    } else {
        None
    }
}

fn lex(text: &str) -> Vec<Line> {
    let mut out = Vec::new();
    let mut mode = Mode::Code;
    // Split on `\n` manually (rather than `str::lines`) so the CRLF
    // handling is explicit and regression-testable: exactly one trailing
    // `\r` is stripped per line *before* lexing. A surviving `\r` would
    // defeat the `ends_with('=')` continuation rule in `safety_covered`
    // and shift the scan-up window on CRLF checkouts.
    let mut segs: Vec<&str> = text.split('\n').collect();
    if segs.last() == Some(&"") && (text.is_empty() || text.ends_with('\n')) {
        segs.pop(); // match `lines()`: no phantom line after a final newline
    }
    for raw in segs {
        let raw = raw.strip_suffix('\r').unwrap_or(raw);
        let chars: Vec<char> = raw.chars().collect();
        let n = chars.len();
        let mut line = Line::default();
        let mut i = 0;
        while i < n {
            match mode {
                Mode::Block(depth) => {
                    if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                        i += 2;
                        mode = if depth == 1 { Mode::Code } else { Mode::Block(depth - 1) };
                    } else if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                        mode = Mode::Block(depth + 1);
                        i += 2;
                    } else {
                        line.comment.push(chars[i]);
                        i += 1;
                    }
                }
                Mode::Str => {
                    if chars[i] == '\\' {
                        i += 2; // skip the escaped char (may run past line end)
                    } else if chars[i] == '"' {
                        line.code.push('"');
                        mode = Mode::Code;
                        i += 1;
                    } else {
                        i += 1;
                    }
                }
                Mode::RawStr(hashes) => {
                    let h = hashes as usize;
                    if chars[i] == '"' && chars[i + 1..].len() >= h && chars[i + 1..i + 1 + h].iter().all(|&c| c == '#') {
                        line.code.push('"');
                        mode = Mode::Code;
                        i += 1 + h;
                    } else {
                        i += 1;
                    }
                }
                Mode::Code => {
                    let c = chars[i];
                    if c == '/' && i + 1 < n && chars[i + 1] == '/' {
                        // line comment (incl. /// and //!) runs to line end
                        line.comment.extend(chars[i + 2..].iter());
                        i = n;
                    } else if c == '/' && i + 1 < n && chars[i + 1] == '*' {
                        mode = Mode::Block(1);
                        i += 2;
                    } else if c == '"' {
                        line.code.push('"');
                        mode = Mode::Str;
                        i += 1;
                    } else if c == 'r' {
                        if let Some(h) = raw_string_hashes(&chars, i) {
                            line.code.push('"');
                            mode = Mode::RawStr(h);
                            i += 2 + h as usize;
                        } else {
                            line.code.push(c);
                            i += 1;
                        }
                    } else if c == '\'' {
                        // char literal vs lifetime
                        if i + 1 < n && chars[i + 1] == '\\' {
                            if i + 2 < n && chars[i + 2] == 'u' {
                                // '\u{..}': scan to the closing quote
                                let mut j = i + 3;
                                while j < n && chars[j] != '\'' {
                                    j += 1;
                                }
                                i = j + 1;
                            } else {
                                i += 4; // ' \ x '
                            }
                        } else if i + 2 < n && chars[i + 2] == '\'' {
                            i += 3; // 'x'
                        } else {
                            line.code.push('\''); // lifetime
                            i += 1;
                        }
                    } else {
                        line.code.push(c);
                        i += 1;
                    }
                }
            }
        }
        line.toks = tokenize(&line.code);
        out.push(line);
    }
    out
}

// ------------------------------------------------------------- rule scans

/// `name` appears as a whole identifier token (so `eprintln` never
/// matches a scan for `println`, and text inside literals never matches
/// at all — literals were stripped before tokenizing).
fn has_ident(toks: &[Tok], name: &str) -> bool {
    toks.iter().any(|t| matches!(t, Tok::Ident(s) if s == name))
}

/// `name!` is invoked: the identifier immediately followed by `!`.
fn calls_macro(toks: &[Tok], name: &str) -> bool {
    toks.windows(2).any(|w| {
        matches!((&w[0], &w[1]), (Tok::Ident(s), Tok::Punct('!')) if s == name)
    })
}

/// `a::b` appears as four consecutive tokens (`a` `:` `:` `b`), which is
/// how both `thread::spawn` and a reformatted `thread :: spawn` tokenize.
fn tok_path2(toks: &[Tok], a: &str, b: &str) -> bool {
    toks.windows(4).any(|w| {
        matches!(
            (&w[0], &w[1], &w[2], &w[3]),
            (Tok::Ident(x), Tok::Punct(':'), Tok::Punct(':'), Tok::Ident(y))
                if x == a && y == b
        )
    })
}

/// `.sum::<f32>()` — the turbofish tokenizes as `sum` `:` `:` `<` `f32`.
fn f32_sum_turbofish(toks: &[Tok]) -> bool {
    toks.windows(5).any(|w| {
        matches!(
            (&w[0], &w[1], &w[2], &w[3], &w[4]),
            (Tok::Ident(s), Tok::Punct(':'), Tok::Punct(':'), Tok::Punct('<'), Tok::Ident(t))
                if s == "sum" && t == "f32"
        )
    })
}

/// Any identifier naming or suffixed with `f32` (`f32`, `0f32`,
/// `0.5f32`'s fractional token) — the accumulator-type signal for the
/// `fold` arm of `float-reduction`.
fn mentions_f32(toks: &[Tok]) -> bool {
    toks.iter().any(|t| matches!(t, Tok::Ident(s) if s.ends_with("f32")))
}

/// An `unsafe` token at `lines[idx]` is covered if a `SAFETY` comment sits
/// on the same line or in the contiguous comment/attribute/blank block
/// directly above it. A line ending in `=` also passes through: the
/// comment above a multi-line `let x =\n    unsafe { .. }` binding covers
/// the whole statement.
fn safety_covered(lines: &[Line], idx: usize) -> bool {
    if lines[idx].comment.contains("SAFETY") {
        return true;
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let l = &lines[j];
        if l.comment.contains("SAFETY") {
            return true;
        }
        let code = l.code.trim();
        let passive = code.is_empty()
            || code.starts_with("#[")
            || code.starts_with("#![")
            || code.ends_with('=');
        if !passive {
            break;
        }
    }
    false
}

fn sanctioned(path: &str, list: &[&str]) -> bool {
    list.iter().any(|p| {
        if let Some(dir) = p.strip_suffix('/') {
            Path::new(path).starts_with(dir)
        } else {
            path == *p
        }
    })
}

// -------------------------------------------------------- allow directives

#[derive(Debug, Clone)]
struct Allow {
    rule: String,
    justified: bool,
}

const ALLOW_PREFIX: &str = "lint: allow(";

/// A directive must be the whole comment (`// lint: allow(rule) — why`),
/// so prose *about* the syntax (like the module docs above) never parses
/// as one.
fn parse_allow(comment: &str) -> Option<Allow> {
    let rest = comment.trim_start().strip_prefix(ALLOW_PREFIX)?;
    match rest.find(')') {
        None => Some(Allow { rule: rest.trim().to_string(), justified: false }),
        Some(close) => {
            let rule = rest[..close].trim().to_string();
            let just = rest[close + 1..]
                .trim_matches(|c: char| c.is_whitespace() || c == '\u{2014}' || c == '-' || c == ':');
            Some(Allow { rule, justified: !just.is_empty() })
        }
    }
}

// ------------------------------------------------------------ single file

/// Lint one source file. `path` is crate-root-relative with forward
/// slashes (it selects the per-rule sanctioned-module exemptions).
pub fn lint_source(path: &str, text: &str) -> Vec<Finding> {
    let lines = lex(text);
    let mut findings = Vec::new();
    let mut push = |line: usize, rule: &'static str, msg: String| {
        findings.push(Finding { file: path.to_string(), line, rule, msg });
    };

    let check_print = !sanctioned(path, PRINT_SANCTIONED);
    let check_thread = !sanctioned(path, THREAD_SANCTIONED);
    let check_clock = !sanctioned(path, CLOCK_SANCTIONED);
    let check_float = !sanctioned(path, FLOAT_SANCTIONED);

    for (idx, line) in lines.iter().enumerate() {
        let lineno = idx + 1;
        let toks = line.toks.as_slice();
        if has_ident(toks, "unsafe") && !safety_covered(&lines, idx) {
            push(
                lineno,
                SAFETY_RULE,
                "`unsafe` without a `// SAFETY:` comment on this line or directly above".to_string(),
            );
        }
        if check_print {
            for mac in ["println", "eprintln", "print", "eprint"] {
                if calls_macro(toks, mac) {
                    push(
                        lineno,
                        PRINT_RULE,
                        format!("direct `{mac}!` outside src/trace/ — use the log_*! macros"),
                    );
                    break;
                }
            }
        }
        if has_ident(toks, "partial_cmp") && has_ident(toks, "unwrap") {
            push(
                lineno,
                CMP_RULE,
                "`partial_cmp(..).unwrap()` panics on NaN — use `total_cmp`".to_string(),
            );
        }
        if check_thread {
            for meth in ["spawn", "scope", "Builder"] {
                if tok_path2(toks, "thread", meth) {
                    push(
                        lineno,
                        THREAD_RULE,
                        format!(
                            "raw `thread::{meth}` outside the sanctioned runtime modules — use WorkerPool"
                        ),
                    );
                    break;
                }
            }
        }
        if check_clock && tok_path2(toks, "Instant", "now") {
            push(
                lineno,
                CLOCK_RULE,
                "`Instant::now()` outside trace/metrics — take timestamps via `crate::util::now()`"
                    .to_string(),
            );
        }
        for ty in ["HashMap", "HashSet"] {
            if has_ident(toks, ty) {
                push(
                    lineno,
                    HASH_RULE,
                    format!(
                        "`{ty}` has nondeterministic iteration order — use BTreeMap/BTreeSet or a sorted Vec (probe-only tables may carry a justified allow)"
                    ),
                );
                break;
            }
        }
        let entropy = ["thread_rng", "from_entropy", "OsRng", "StdRng", "SmallRng", "getrandom"]
            .into_iter()
            .find(|&name| has_ident(toks, name));
        if let Some(name) = entropy {
            push(
                lineno,
                RNG_RULE,
                format!(
                    "`{name}` draws outside the seed-derived stream discipline — split a Pcg32 stream from the run seed (util/rng.rs)"
                ),
            );
        } else if tok_path2(toks, "SystemTime", "now") {
            push(
                lineno,
                RNG_RULE,
                "clock-derived state (`SystemTime::now`) breaks cross-run reproducibility — derive from the run seed instead"
                    .to_string(),
            );
        }
        if check_float
            && (f32_sum_turbofish(toks) || (has_ident(toks, "fold") && mentions_f32(toks)))
        {
            push(
                lineno,
                FLOAT_RULE,
                "bare f32 reduction outside the sanctioned kernel helpers — reduction order must not depend on worker count or stream interleaving"
                    .to_string(),
            );
        }
    }

    // Allow directives: validate every directive, then drop findings the
    // valid ones cover (their own line or the line directly below).
    let mut allows: Vec<(usize, Allow)> = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        if let Some(a) = parse_allow(&line.comment) {
            allows.push((idx + 1, a));
        }
    }
    for (lineno, a) in &allows {
        if !RULES.iter().any(|(name, _)| name == &a.rule) {
            findings.push(Finding {
                file: path.to_string(),
                line: *lineno,
                rule: ALLOW_RULE,
                msg: format!("allow directive names unknown rule `{}`", a.rule),
            });
        } else if !a.justified {
            findings.push(Finding {
                file: path.to_string(),
                line: *lineno,
                rule: ALLOW_RULE,
                msg: format!(
                    "allow({}) without a justification — write `// lint: allow({}) — <why>`",
                    a.rule, a.rule
                ),
            });
        }
    }
    findings.retain(|f| {
        f.rule == ALLOW_RULE
            || !allows.iter().any(|(lineno, a)| {
                a.justified && a.rule == f.rule && (f.line == *lineno || f.line == *lineno + 1)
            })
    });
    findings.sort_by_key(|f| f.line);
    findings
}

// --------------------------------------------------------- bench manifest

/// `(line, name)` of every `[[bench]]` target in a `Cargo.toml` text.
fn bench_targets(cargo_toml: &str) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    let mut in_bench = false;
    let mut section_line = 0usize;
    for (idx, line) in cargo_toml.lines().enumerate() {
        let t = line.trim();
        if t.starts_with('[') {
            in_bench = t == "[[bench]]";
            section_line = idx + 1;
            continue;
        }
        if !in_bench {
            continue;
        }
        if let Some(rest) = t.strip_prefix("name") {
            let rest = rest.trim_start();
            if let Some(v) = rest.strip_prefix('=') {
                let v = v.trim().trim_matches('"');
                out.push((section_line, v.to_string()));
            }
        }
    }
    out
}

fn check_bench_manifest(root: &Path, findings: &mut Vec<Finding>) -> crate::Result<()> {
    let manifest = root.join("Cargo.toml");
    let toml = fs::read_to_string(&manifest)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", manifest.display()))?;
    for (line, name) in bench_targets(&toml) {
        let bench_path = root.join("benches").join(format!("{name}.rs"));
        match fs::read_to_string(&bench_path) {
            Err(_) => findings.push(Finding {
                file: "Cargo.toml".to_string(),
                line,
                rule: BENCH_RULE,
                msg: format!("[[bench]] `{name}` has no benches/{name}.rs"),
            }),
            Ok(text) => {
                // either Bench::write_json or report_json + fs::write lands
                // the artifact; doc-comment mentions alone don't count
                let writes = text.contains("write_json") || text.contains("report_json");
                if !(text.contains("BENCH_") && writes) {
                    findings.push(Finding {
                        file: format!("benches/{name}.rs"),
                        line: 1,
                        rule: BENCH_RULE,
                        msg: format!(
                            "bench `{name}` does not write its BENCH_*.json artifact (write_json/report_json)"
                        ),
                    });
                }
            }
        }
    }
    Ok(())
}

// -------------------------------------------------------------- tree walk

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> crate::Result<()> {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint the whole crate rooted at `root` (the directory holding
/// `Cargo.toml`): `src/`, `benches/` and `tests/`, plus the bench
/// manifest cross-check. `vendor/` is deliberately out of scope — the
/// offline `xla` stub mirrors an external API and is not ours to style.
pub fn lint_tree(root: &Path) -> crate::Result<Vec<Finding>> {
    let mut files = Vec::new();
    for sub in ["src", "benches", "tests"] {
        let dir = root.join(sub);
        if dir.is_dir() {
            collect_rs(&dir, &mut files)?;
        }
    }
    let mut findings = Vec::new();
    for file in &files {
        let rel = file.strip_prefix(root).unwrap_or(file);
        let rel = rel.to_string_lossy().replace('\\', "/");
        let text = fs::read_to_string(file)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", file.display()))?;
        findings.extend(lint_source(&rel, &text));
    }
    check_bench_manifest(root, &mut findings)?;
    Ok(findings)
}

// ------------------------------------------------------------------ output

/// Human-readable report, one finding per line.
pub fn render(findings: &[Finding]) -> String {
    let mut out = String::new();
    for f in findings {
        out.push_str(&f.to_string());
        out.push('\n');
    }
    out
}

/// Machine-readable report for `pallas-lint --json`.
pub fn to_json(findings: &[Finding]) -> Json {
    Json::obj(vec![
        (
            "findings",
            Json::arr(findings.iter().map(|f| {
                Json::obj(vec![
                    ("file", Json::str(f.file.clone())),
                    ("line", Json::num(f.line as u32)),
                    ("rule", Json::str(f.rule)),
                    ("message", Json::str(f.msg.clone())),
                ])
            })),
        ),
        ("count", Json::num(findings.len() as u32)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    // ------------------------------------------------------------ lexer

    #[test]
    fn lexer_strips_strings_and_captures_comments() {
        let lines = lex("let x = \"unsafe println!\"; // SAFETY: not really code");
        assert_eq!(lines.len(), 1);
        assert!(!lines[0].code.contains("unsafe"));
        assert!(!lines[0].code.contains("println"));
        assert!(lines[0].comment.contains("SAFETY"));
    }

    #[test]
    fn lexer_handles_nested_block_comments_and_raw_strings() {
        let src = "a /* one /* two */ still comment */ b\nlet s = r#\"thread::spawn\"#;";
        let lines = lex(src);
        assert!(lines[0].code.contains('a') && lines[0].code.contains('b'));
        assert!(lines[0].comment.contains("still comment"));
        assert!(!lines[1].code.contains("thread::spawn"));
    }

    #[test]
    fn lexer_distinguishes_char_literals_from_lifetimes() {
        let lines = lex("fn f<'a>(x: &'a str) -> char { '\"' }");
        // the double quote inside the char literal must not open a string
        assert!(lines[0].code.contains("str"));
        let lines = lex("let c = '\\''; let d = 'x'; let l: &'static str = \"s\";");
        assert!(lines[0].code.contains("static"));
        assert!(!lines[0].code.contains('x'));
    }

    #[test]
    fn lexer_keeps_multiline_string_state() {
        let src = "let s = \"line one\nline two with unsafe\";\nlet t = 1;";
        let lines = lex(src);
        assert!(!lines[1].code.contains("unsafe"));
        assert!(lines[2].code.contains("let t"));
    }

    // -------------------------------------------- one negative per rule

    #[test]
    fn catches_undocumented_unsafe() {
        let src = "fn f(p: *const u32) -> u32 {\n    unsafe { *p }\n}\n";
        let f = lint_source("src/foo.rs", src);
        assert_eq!(rules_of(&f), vec!["safety-comment"]);
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn accepts_safety_comment_same_line_or_above() {
        let above = "// SAFETY: p is valid for reads\nfn f(p: *const u32) -> u32 { unsafe { *p } }\n";
        assert!(lint_source("src/foo.rs", above).is_empty());
        let trailing = "unsafe impl Send for X {} // SAFETY: X owns no borrows\n";
        assert!(lint_source("src/foo.rs", trailing).is_empty());
        let through_attr =
            "// SAFETY: repr(C) layout\n#[allow(dead_code)]\nunsafe fn g() {}\n";
        assert!(lint_source("src/foo.rs", through_attr).is_empty());
        let continuation =
            "// SAFETY: in bounds\nlet bytes =\n    unsafe { f(p) };\n";
        assert!(lint_source("src/foo.rs", continuation).is_empty());
    }

    #[test]
    fn catches_direct_print_outside_trace() {
        let src = "fn f() { println!(\"hi\"); }\n";
        assert_eq!(rules_of(&lint_source("src/foo.rs", src)), vec!["no-direct-print"]);
        // the logger's own sink and the lint CLI are sanctioned
        assert!(lint_source("src/trace/log.rs", src).is_empty());
        assert!(lint_source("src/bin/lint.rs", src).is_empty());
        // a print in a doc example is a comment, not code
        let doc = "/// ```\n/// println!(\"demo\");\n/// ```\nfn f() {}\n";
        assert!(lint_source("src/foo.rs", doc).is_empty());
    }

    #[test]
    fn catches_the_nan_panic_comparator_class() {
        let src = "v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n";
        assert_eq!(rules_of(&lint_source("src/foo.rs", src)), vec!["total-cmp"]);
        let fixed = "v.sort_by(|a, b| a.total_cmp(b));\n";
        assert!(lint_source("src/foo.rs", fixed).is_empty());
    }

    #[test]
    fn catches_raw_thread_outside_sanctioned_modules() {
        let src = "let h = std::thread::spawn(|| {});\n";
        assert_eq!(rules_of(&lint_source("src/foo.rs", src)), vec!["thread-discipline"]);
        assert!(lint_source("src/util/pool.rs", src).is_empty());
        assert!(lint_source("src/pipeline/runner.rs", src).is_empty());
        let builder = "std::thread::Builder::new()\n    .spawn(f)\n";
        assert_eq!(rules_of(&lint_source("src/foo.rs", builder)), vec!["thread-discipline"]);
    }

    #[test]
    fn catches_instant_now_outside_trace_and_metrics() {
        let src = "let t0 = std::time::Instant::now();\n";
        assert_eq!(rules_of(&lint_source("src/foo.rs", src)), vec!["clock-discipline"]);
        assert!(lint_source("src/trace/span.rs", src).is_empty());
        assert!(lint_source("src/metrics/timing.rs", src).is_empty());
        assert!(lint_source("src/util/mod.rs", src).is_empty());
        let routed = "let t0 = crate::util::now();\n";
        assert!(lint_source("src/foo.rs", routed).is_empty());
    }

    #[test]
    fn catches_hash_collections_anywhere_in_code() {
        let map = "use std::collections::HashMap;\nlet m: HashMap<u32, u32> = HashMap::new();\n";
        let f = lint_source("src/foo.rs", map);
        assert_eq!(rules_of(&f), vec!["hash-iter-order", "hash-iter-order"]);
        let set = "let s: std::collections::HashSet<u32> = Default::default();\n";
        assert_eq!(rules_of(&lint_source("src/foo.rs", set)), vec!["hash-iter-order"]);
        // ordered replacements and mere mentions (comments, strings) pass
        let btree = "use std::collections::BTreeMap;\nlet m: BTreeMap<u32, u32> = BTreeMap::new();\n";
        assert!(lint_source("src/foo.rs", btree).is_empty());
        let prose = "// HashMap iteration order is the hazard\nlet s = \"HashMap\";\n";
        assert!(lint_source("src/foo.rs", prose).is_empty());
    }

    #[test]
    fn justified_allow_covers_a_probe_only_hash_table() {
        let src = "// lint: allow(hash-iter-order) — probe-only membership set, never iterated\nlet seen: HashSet<(u32, u32)> = HashSet::new();\n";
        assert!(lint_source("src/foo.rs", src).is_empty());
    }

    #[test]
    fn catches_entropy_and_clock_seeded_rng() {
        let entropy = "let mut rng = rand::thread_rng();\n";
        assert_eq!(rules_of(&lint_source("src/foo.rs", entropy)), vec!["rng-discipline"]);
        let reseed = "let rng = Pcg32::from_entropy();\n";
        assert_eq!(rules_of(&lint_source("src/foo.rs", reseed)), vec!["rng-discipline"]);
        let clock = "let seed = SystemTime::now().duration_since(UNIX_EPOCH)?.as_nanos();\n";
        assert_eq!(rules_of(&lint_source("src/foo.rs", clock)), vec!["rng-discipline"]);
        // the sanctioned pattern: a stream split off the run seed
        let stream = "let rng = base.split(plan_idx as u64);\n";
        assert!(lint_source("src/foo.rs", stream).is_empty());
    }

    #[test]
    fn justified_allow_covers_an_rng_exception() {
        let src = "let mut rng = rand::thread_rng(); // lint: allow(rng-discipline) — bench warm-up only, draws never reach results\n";
        assert!(lint_source("src/foo.rs", src).is_empty());
    }

    #[test]
    fn catches_bare_f32_reductions_outside_kernels() {
        let sum = "let total = xs.iter().sum::<f32>();\n";
        assert_eq!(rules_of(&lint_source("src/foo.rs", sum)), vec!["float-reduction"]);
        let fold = "let total = xs.iter().fold(0.0f32, |a, &b| a + b);\n";
        assert_eq!(rules_of(&lint_source("src/foo.rs", fold)), vec!["float-reduction"]);
        // the sanctioned kernel helpers own their reduction order
        assert!(lint_source("src/runtime/gemm.rs", sum).is_empty());
        assert!(lint_source("src/runtime/host_step.rs", fold).is_empty());
        // f64 accumulation is associative enough for the stats paths
        let f64_sum = "let total = xs.iter().map(|&x| x as f64).sum::<f64>();\n";
        assert!(lint_source("src/foo.rs", f64_sum).is_empty());
        let f64_fold = "let m = xs.iter().fold(f64::MAX, |a, &b| a.min(b));\n";
        assert!(lint_source("src/foo.rs", f64_fold).is_empty());
    }

    #[test]
    fn justified_allow_covers_a_fixed_order_reduction() {
        let src = "// lint: allow(float-reduction) — single-threaded scan, order fixed by event id\nlet total = xs.iter().sum::<f32>();\n";
        assert!(lint_source("src/foo.rs", src).is_empty());
    }

    // ------------------------------------------------- lexer regressions

    #[test]
    fn allow_directive_inside_string_literal_is_not_honored() {
        // the directive text is literal DATA here — it must neither
        // suppress the finding on the next line nor parse as a directive
        let src = "let s = \"// lint: allow(no-direct-print) — smuggled\";\nprintln!(\"hi\");\n";
        let f = lint_source("src/foo.rs", src);
        assert_eq!(rules_of(&f), vec!["no-direct-print"]);
        assert_eq!(f[0].line, 2);
        // same for banned names smuggled into literals: data, not code
        let data = "let s = \"HashMap thread_rng sum::<f32>\";\n";
        assert!(lint_source("src/foo.rs", data).is_empty());
    }

    #[test]
    fn crlf_line_endings_do_not_shift_findings_or_the_safety_window() {
        let lf = "// SAFETY: in bounds\n#[inline]\nunsafe fn g() {}\nfn f() { println!(\"x\"); }\n";
        let crlf = lf.replace('\n', "\r\n");
        let a = lint_source("src/foo.rs", lf);
        let b = lint_source("src/foo.rs", &crlf);
        assert_eq!(rules_of(&a), vec!["no-direct-print"]);
        assert_eq!(rules_of(&b), rules_of(&a));
        assert_eq!(a[0].line, b[0].line, "CRLF must not shift line numbers");
        // the `=`-continuation scan-up must see through a trailing \r:
        // a surviving \r would break `ends_with('=')` and flag the unsafe
        let cont = "// SAFETY: bounds checked\nlet x =\r\n    unsafe { f(p) };\r\n";
        assert!(lint_source("src/foo.rs", cont).is_empty());
        // and a CRLF allow directive still covers the line below it
        let allow = "// lint: allow(no-direct-print) — CLI usage text\r\nprintln!(\"usage\");\r\n";
        assert!(lint_source("src/foo.rs", allow).is_empty());
    }

    // -------------------------------------------------- allow directives

    #[test]
    fn justified_allow_suppresses_same_and_next_line() {
        let above = "// lint: allow(no-direct-print) — CLI usage text\nprintln!(\"usage\");\n";
        assert!(lint_source("src/foo.rs", above).is_empty());
        let inline = "println!(\"usage\"); // lint: allow(no-direct-print) — CLI usage text\n";
        assert!(lint_source("src/foo.rs", inline).is_empty());
    }

    #[test]
    fn allow_does_not_leak_past_the_next_line() {
        let src = "// lint: allow(no-direct-print) — only covers the next line\nprintln!(\"ok\");\nprintln!(\"not covered\");\n";
        let f = lint_source("src/foo.rs", src);
        assert_eq!(rules_of(&f), vec!["no-direct-print"]);
        assert_eq!(f[0].line, 3);
    }

    #[test]
    fn unjustified_or_unknown_allow_is_itself_a_finding() {
        let bare = "// lint: allow(no-direct-print)\nprintln!(\"hi\");\n";
        let f = lint_source("src/foo.rs", bare);
        assert_eq!(rules_of(&f), vec!["bad-allow", "no-direct-print"]);
        let unknown = "// lint: allow(no-such-rule) — because\nlet x = 1;\n";
        assert_eq!(rules_of(&lint_source("src/foo.rs", unknown)), vec!["bad-allow"]);
    }

    #[test]
    fn allow_only_suppresses_its_own_rule() {
        let src = "// lint: allow(total-cmp) — wrong rule named\nprintln!(\"hi\");\n";
        let f = lint_source("src/foo.rs", src);
        assert_eq!(rules_of(&f), vec!["no-direct-print"]);
    }

    // --------------------------------------------------- bench manifest

    #[test]
    fn bench_targets_parse_from_manifest_text() {
        let toml = "[package]\nname = \"x\"\n\n[[bench]]\nname = \"alpha\"\nharness = false\n\n[[bin]]\nname = \"tool\"\n\n[[bench]]\nname = \"beta\"\n";
        let targets = bench_targets(toml);
        let names: Vec<&str> = targets.iter().map(|(_, n)| n.as_str()).collect();
        assert_eq!(names, vec!["alpha", "beta"]);
        assert_eq!(targets[0].0, 4);
    }

    #[test]
    fn bench_manifest_flags_missing_file_and_missing_artifact() {
        let root = std::env::temp_dir().join(format!("pallas-lint-test-{}", std::process::id()));
        let benches = root.join("benches");
        fs::create_dir_all(&benches).unwrap();
        fs::write(
            root.join("Cargo.toml"),
            "[[bench]]\nname = \"ghost\"\n\n[[bench]]\nname = \"mute\"\n",
        )
        .unwrap();
        fs::write(benches.join("mute.rs"), "fn main() {}\n").unwrap();
        let mut findings = Vec::new();
        check_bench_manifest(&root, &mut findings).unwrap();
        assert_eq!(rules_of(&findings), vec!["bench-manifest", "bench-manifest"]);
        assert!(findings[0].msg.contains("ghost"));
        assert!(findings[1].msg.contains("mute"));
        fs::remove_dir_all(&root).unwrap();
    }

    // ------------------------------------------------------- the gate

    #[test]
    fn repo_tree_is_lint_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let findings = lint_tree(root).unwrap();
        assert!(
            findings.is_empty(),
            "pallas-lint found {} violation(s):\n{}",
            findings.len(),
            render(&findings)
        );
    }

    #[test]
    fn json_report_round_trips() {
        let findings = vec![Finding {
            file: "src/a.rs".to_string(),
            line: 7,
            rule: "total-cmp",
            msg: "uses \"quotes\" and \\ backslash".to_string(),
        }];
        let doc = to_json(&findings);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed.get("count").unwrap().as_usize().unwrap(), 1);
        let arr = parsed.get("findings").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].get("line").unwrap().as_usize().unwrap(), 7);
        assert_eq!(arr[0].get("rule").unwrap().as_str().unwrap(), "total-cmp");
        assert!(arr[0].get("message").unwrap().as_str().unwrap().contains("quotes"));
    }
}
