//! Dataset = event log + chronological split (paper App. A.1: the stream
//! is partitioned into [0, T_train], (T_train, T_val], (T_val, T_test]).

use crate::graph::events::{EventLog, NO_LABEL};

/// Chronological split boundaries as event indices into the log.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Split {
    pub train_end: usize,
    pub val_end: usize,
}

#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub log: EventLog,
    pub split: Split,
}

/// Table 3-style dataset statistics.
#[derive(Clone, Debug)]
pub struct DatasetStats {
    pub name: String,
    pub num_nodes: u32,
    pub num_events: usize,
    pub d_edge: usize,
    pub timespan: f32,
    pub repeat_ratio: f64,
    pub labeled_events: usize,
    pub label_positive_rate: f64,
}

impl Dataset {
    /// Chronological 70/15/15 split (the TGL/TGN convention).
    pub fn with_chrono_split(name: &str, log: EventLog) -> Dataset {
        let n = log.len();
        Dataset {
            name: name.to_string(),
            log,
            split: Split {
                train_end: n * 70 / 100,
                val_end: n * 85 / 100,
            },
        }
    }

    pub fn train_range(&self) -> std::ops::Range<usize> {
        0..self.split.train_end
    }

    pub fn val_range(&self) -> std::ops::Range<usize> {
        self.split.train_end..self.split.val_end
    }

    pub fn test_range(&self) -> std::ops::Range<usize> {
        self.split.val_end..self.log.len()
    }

    pub fn stats(&self) -> DatasetStats {
        let labeled: Vec<i8> = self
            .log
            .events
            .iter()
            .map(|e| e.label)
            .filter(|&l| l != NO_LABEL)
            .collect();
        let pos = labeled.iter().filter(|&&l| l == 1).count();
        DatasetStats {
            name: self.name.clone(),
            num_nodes: self.log.num_nodes,
            num_events: self.log.len(),
            d_edge: self.log.d_edge,
            timespan: self.log.timespan(),
            repeat_ratio: self.log.repeat_ratio(),
            labeled_events: labeled.len(),
            label_positive_rate: if labeled.is_empty() {
                0.0
            } else {
                pos as f64 / labeled.len() as f64
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::events::Event;

    fn make_log(n: usize) -> EventLog {
        let mut log = EventLog::new(10, 5, 0);
        for i in 0..n {
            log.push(
                Event {
                    src: (i % 5) as u32,
                    dst: 5 + (i % 5) as u32,
                    t: i as f32,
                    label: if i % 3 == 0 { 1 } else { NO_LABEL },
                },
                &[],
            )
            .unwrap();
        }
        log
    }

    #[test]
    fn chrono_split_covers_everything_in_order() {
        let d = Dataset::with_chrono_split("t", make_log(100));
        assert_eq!(d.train_range(), 0..70);
        assert_eq!(d.val_range(), 70..85);
        assert_eq!(d.test_range(), 85..100);
        let total = d.train_range().len() + d.val_range().len() + d.test_range().len();
        assert_eq!(total, 100);
    }

    #[test]
    fn stats_count_labels() {
        let d = Dataset::with_chrono_split("t", make_log(9));
        let s = d.stats();
        assert_eq!(s.num_events, 9);
        assert_eq!(s.labeled_events, 3);
        assert_eq!(s.label_positive_rate, 1.0);
    }
}
