//! Temporal graph substrate: event-based dynamic graph representation
//! (paper §3), chronological splits, and dataset statistics (Table 3).

pub mod dataset;
pub mod events;

pub use dataset::{Dataset, DatasetStats, Split};
pub use events::{Event, EventLog, NO_LABEL};
