//! Event-based representation of a dynamic graph (paper §3): a node set
//! plus a chronologically sorted stream of interaction events e_ij(t),
//! each optionally carrying an edge feature vector and a dynamic node
//! label (the JODIE "state change" signal used for node classification).

use anyhow::{bail, Result};

/// Sentinel for events without a dynamic node label.
pub const NO_LABEL: i8 = -1;

/// One interaction event between `src` and `dst` at time `t`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Event {
    pub src: u32,
    pub dst: u32,
    pub t: f32,
    /// Dynamic label of `src` at the time of this event (0/1) or NO_LABEL.
    pub label: i8,
}

/// Chronologically sorted event stream with a dense edge-feature table.
///
/// Features are stored row-major `[num_events, d_edge]`; non-attributed
/// datasets use `d_edge = 0` and the batch assembler feeds zero vectors to
/// the model, matching the paper's convention for MOOC/LastFM.
#[derive(Clone, Debug)]
pub struct EventLog {
    pub num_nodes: u32,
    /// First node id that is a "destination"/item node (bipartite datasets:
    /// actors are [0, dst_lo), items are [dst_lo, num_nodes)). Negative
    /// sampling draws destinations from this range.
    pub dst_lo: u32,
    pub events: Vec<Event>,
    pub d_edge: usize,
    feats: Vec<f32>,
}

impl EventLog {
    pub fn new(num_nodes: u32, dst_lo: u32, d_edge: usize) -> Self {
        EventLog {
            num_nodes,
            dst_lo,
            events: Vec::new(),
            d_edge,
            feats: Vec::new(),
        }
    }

    /// Append an event (must be non-decreasing in time).
    pub fn push(&mut self, ev: Event, feat: &[f32]) -> Result<()> {
        if let Some(last) = self.events.last() {
            if ev.t < last.t {
                bail!("events must be pushed in chronological order");
            }
        }
        if ev.src >= self.num_nodes || ev.dst >= self.num_nodes {
            bail!("event endpoint out of range");
        }
        if feat.len() != self.d_edge {
            bail!("feature width {} != d_edge {}", feat.len(), self.d_edge);
        }
        self.events.push(ev);
        self.feats.extend_from_slice(feat);
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Edge features of event `i` (empty slice when non-attributed).
    #[inline]
    pub fn feat(&self, i: usize) -> &[f32] {
        if self.d_edge == 0 {
            &[]
        } else {
            &self.feats[i * self.d_edge..(i + 1) * self.d_edge]
        }
    }

    /// Total timespan (t_last - t_first); 0 for < 2 events.
    pub fn timespan(&self) -> f32 {
        match (self.events.first(), self.events.last()) {
            (Some(a), Some(b)) => b.t - a.t,
            _ => 0.0,
        }
    }

    /// Fraction of events whose (src, dst) pair occurred before — the
    /// "repeat interaction" ratio that makes memory modules pay off.
    pub fn repeat_ratio(&self) -> f64 {
        let mut seen = std::collections::BTreeSet::new();
        let mut repeats = 0usize;
        for e in &self.events {
            if !seen.insert((e.src, e.dst)) {
                repeats += 1;
            }
        }
        if self.events.is_empty() {
            0.0
        } else {
            repeats as f64 / self.events.len() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(src: u32, dst: u32, t: f32) -> Event {
        Event { src, dst, t, label: NO_LABEL }
    }

    #[test]
    fn push_and_feat_roundtrip() {
        let mut log = EventLog::new(10, 5, 2);
        log.push(ev(0, 5, 0.0), &[1.0, 2.0]).unwrap();
        log.push(ev(1, 6, 1.0), &[3.0, 4.0]).unwrap();
        assert_eq!(log.len(), 2);
        assert_eq!(log.feat(1), &[3.0, 4.0]);
        assert_eq!(log.timespan(), 1.0);
    }

    #[test]
    fn rejects_out_of_order_and_bad_endpoints() {
        let mut log = EventLog::new(10, 5, 0);
        log.push(ev(0, 5, 1.0), &[]).unwrap();
        assert!(log.push(ev(0, 5, 0.5), &[]).is_err());
        assert!(log.push(ev(11, 5, 2.0), &[]).is_err());
        assert!(log.push(ev(0, 5, 2.0), &[0.0]).is_err());
    }

    #[test]
    fn repeat_ratio_counts_pairs() {
        let mut log = EventLog::new(4, 2, 0);
        log.push(ev(0, 2, 0.0), &[]).unwrap();
        log.push(ev(0, 2, 1.0), &[]).unwrap();
        log.push(ev(1, 3, 2.0), &[]).unwrap();
        log.push(ev(0, 2, 3.0), &[]).unwrap();
        assert_eq!(log.repeat_ratio(), 0.5);
    }

    #[test]
    fn non_attributed_feat_is_empty() {
        let mut log = EventLog::new(4, 2, 0);
        log.push(ev(0, 2, 0.0), &[]).unwrap();
        assert_eq!(log.feat(0), &[] as &[f32]);
    }
}
