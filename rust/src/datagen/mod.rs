//! Synthetic temporal-interaction-graph generators.
//!
//! The paper evaluates on the JODIE datasets (Wikipedia, Reddit, MOOC,
//! LastFM) plus GDELT, which are not redistributable here; per DESIGN.md §6
//! we substitute generators that match the *shape* that drives the paper's
//! phenomenon — temporal discontinuity is a function of (a) pending-event
//! density (heavy-tailed actor/item activity packs many same-vertex events
//! into one temporal batch) and (b) how much signal lives in the memory
//! (repeat-interaction affinity + regime drift). Both are explicit knobs.
//!
//! Latent model per event:
//!   1. actor ~ Zipf(alpha_actor)
//!   2. with prob `p_repeat`: item from the actor's recency list;
//!      otherwise: item ~ popularity x topic-affinity x drift(t)
//!   3. edge features encode the item topic + actor state (learnable signal)
//!   4. actor state flips 0->1 with hazard per event (dynamic node labels,
//!      the JODIE ban/dropout analogue); state shifts preferences so the
//!      label is recoverable from behaviour.

use crate::graph::{Dataset, Event, EventLog, NO_LABEL};
use crate::util::rng::{zipf_cumulative, Pcg32};

pub const N_TOPICS: usize = 8;

/// Generator knobs for one dataset profile.
#[derive(Clone, Debug)]
pub struct Profile {
    pub name: &'static str,
    pub n_actors: u32,
    pub n_items: u32,
    pub n_events: usize,
    pub d_edge: usize,
    /// Zipf exponent of actor activity (higher -> heavier head -> denser
    /// pending sets at a given batch size).
    pub alpha_actor: f64,
    /// Zipf exponent of item popularity.
    pub alpha_item: f64,
    /// Probability an event repeats a recently used item.
    pub p_repeat: f64,
    /// Actor recency list capacity.
    pub recency: usize,
    /// Amplitude of topic drift over time (0 = stationary).
    pub drift: f64,
    /// Number of drift periods across the stream.
    pub drift_periods: f64,
    /// Per-event hazard of an actor's state flipping 0 -> 1.
    pub flip_hazard: f64,
    /// Total timespan the stream is normalized to.
    pub timespan: f32,
}

/// The five profiles mirror Table 3's relative scales (scaled ~10x down for
/// the CPU-PJRT testbed) and qualitative traits: WIKI/LASTFM are
/// repeat-heavy, MOOC is label-dense, GDELT is drift-heavy and widest.
pub fn profiles() -> Vec<Profile> {
    vec![
        Profile {
            name: "wiki",
            n_actors: 1500, n_items: 500, n_events: 25_000, d_edge: 16,
            alpha_actor: 1.1, alpha_item: 1.0, p_repeat: 0.70, recency: 6,
            drift: 0.4, drift_periods: 3.0, flip_hazard: 2e-4, timespan: 2000.0,
        },
        Profile {
            name: "reddit",
            n_actors: 2000, n_items: 600, n_events: 35_000, d_edge: 16,
            alpha_actor: 1.2, alpha_item: 1.1, p_repeat: 0.60, recency: 8,
            drift: 0.5, drift_periods: 4.0, flip_hazard: 1.5e-4, timespan: 2000.0,
        },
        Profile {
            name: "mooc",
            n_actors: 1500, n_items: 300, n_events: 30_000, d_edge: 0,
            alpha_actor: 0.9, alpha_item: 0.8, p_repeat: 0.40, recency: 4,
            drift: 0.3, drift_periods: 2.0, flip_hazard: 8e-4, timespan: 2000.0,
        },
        Profile {
            name: "lastfm",
            n_actors: 1200, n_items: 800, n_events: 40_000, d_edge: 0,
            alpha_actor: 1.0, alpha_item: 1.3, p_repeat: 0.75, recency: 10,
            drift: 0.2, drift_periods: 2.0, flip_hazard: 1e-4, timespan: 2000.0,
        },
        Profile {
            name: "gdelt",
            n_actors: 2500, n_items: 800, n_events: 45_000, d_edge: 16,
            alpha_actor: 1.3, alpha_item: 1.1, p_repeat: 0.30, recency: 4,
            drift: 0.8, drift_periods: 6.0, flip_hazard: 1e-4, timespan: 2000.0,
        },
    ]
}

pub fn profile(name: &str) -> Option<Profile> {
    profiles().into_iter().find(|p| p.name == name)
}

/// A smaller profile for unit/integration tests and the quickstart example.
pub fn tiny_profile() -> Profile {
    Profile {
        name: "tiny",
        n_actors: 120, n_items: 60, n_events: 3_000, d_edge: 16,
        alpha_actor: 1.1, alpha_item: 1.0, p_repeat: 0.6, recency: 4,
        drift: 0.4, drift_periods: 2.0, flip_hazard: 1e-3, timespan: 300.0,
    }
}

/// Generate a dataset from a profile, deterministically from `seed`.
pub fn generate(p: &Profile, seed: u64) -> Dataset {
    let mut rng = Pcg32::new(seed ^ 0xD47A_5E7);
    let num_nodes = p.n_actors + p.n_items;
    let mut log = EventLog::new(num_nodes, p.n_actors, p.d_edge);

    // latent structure
    let actor_cum = zipf_cumulative(p.n_actors as usize, p.alpha_actor);
    let item_pop: Vec<f64> = {
        let mut pops: Vec<f64> = (0..p.n_items as usize)
            .map(|i| 1.0 / ((i + 1) as f64).powf(p.alpha_item))
            .collect();
        // randomize which item ids are popular
        let mut idx: Vec<usize> = (0..pops.len()).collect();
        rng.shuffle(&mut idx);
        let mut out = vec![0.0; pops.len()];
        for (rank, &i) in idx.iter().enumerate() {
            out[i] = pops[rank];
        }
        pops.copy_from_slice(&out);
        pops
    };
    let item_topic: Vec<usize> = (0..p.n_items)
        .map(|_| rng.below(N_TOPICS as u32) as usize)
        .collect();
    // actor preference over topics (sparse-ish, unit-normalized)
    let actor_pref: Vec<[f64; N_TOPICS]> = (0..p.n_actors)
        .map(|_| {
            let mut w = [0.0; N_TOPICS];
            for slot in w.iter_mut() {
                *slot = rng.f64().powi(3); // sparse preferences
            }
            let s: f64 = w.iter().sum();
            for slot in w.iter_mut() {
                *slot /= s;
            }
            w
        })
        .collect();
    // topic feature directions for edge features
    let topic_dir: Vec<Vec<f32>> = (0..N_TOPICS)
        .map(|_| (0..p.d_edge).map(|_| rng.normal() * 0.8).collect())
        .collect();

    let mut recency: Vec<Vec<u32>> = vec![Vec::new(); p.n_actors as usize];
    let mut state: Vec<u8> = vec![0; p.n_actors as usize];
    let mut feat = vec![0.0f32; p.d_edge];
    let dt_scale = p.timespan / p.n_events as f32;
    let mut t = 0.0f32;

    // per-item sampling cache: cumulative weights refreshed per drift phase
    let mut phase_cache: (i64, Vec<f64>) = (-1, Vec::new());

    for _ in 0..p.n_events {
        t += rng.exponential(1.0) * dt_scale;
        let phase01 = (t / p.timespan) as f64 * p.drift_periods;

        let actor = rng.weighted(&actor_cum) as u32;
        let ai = actor as usize;
        let st = state[ai];

        // item choice
        let use_repeat = !recency[ai].is_empty() && rng.f64() < p.p_repeat;
        let item_local: u32 = if use_repeat {
            let list = &recency[ai];
            list[rng.below(list.len() as u32) as usize]
        } else {
            // refresh the drift-weighted popularity table once per 1% phase
            let bucket = (phase01 * 100.0) as i64;
            if phase_cache.0 != bucket {
                let mut cum = Vec::with_capacity(item_pop.len());
                let mut acc = 0.0;
                for (i, &pop) in item_pop.iter().enumerate() {
                    let topic = item_topic[i];
                    let drift_w = 1.0
                        + p.drift
                            * (2.0 * std::f64::consts::PI
                                * (phase01 + topic as f64 / N_TOPICS as f64))
                                .sin();
                    acc += pop * drift_w.max(0.05);
                    cum.push(acc);
                }
                phase_cache = (bucket, cum);
            }
            // topic-affinity via rejection on the actor preference (cheap,
            // bounded retries; state-1 actors invert preferences so the
            // dynamic label is recoverable from behaviour)
            let mut pick = rng.weighted(&phase_cache.1) as u32;
            for _ in 0..4 {
                let topic = item_topic[pick as usize];
                let pref = if st == 0 {
                    actor_pref[ai][topic]
                } else {
                    actor_pref[ai][N_TOPICS - 1 - topic]
                };
                if rng.f64() < pref * N_TOPICS as f64 {
                    break;
                }
                pick = rng.weighted(&phase_cache.1) as u32;
            }
            pick
        };

        // state flip hazard (sticky: never flips back, like a ban)
        if st == 0 && rng.f64() < p.flip_hazard * (1.0 + recency[ai].len() as f64) {
            state[ai] = 1;
        }

        // edge features: topic direction + state offset + noise
        if p.d_edge > 0 {
            let dir = &topic_dir[item_topic[item_local as usize]];
            for (j, f) in feat.iter_mut().enumerate() {
                *f = dir[j] + state[ai] as f32 * 0.5 + rng.normal() * 0.3;
            }
        }

        let label = if rng.f64() < 0.3 { state[ai] as i8 } else { NO_LABEL };
        log.push(
            Event {
                src: actor,
                dst: p.n_actors + item_local,
                t,
                label,
            },
            &feat[..p.d_edge],
        )
        .expect("generator produces valid events");

        let list = &mut recency[ai];
        if let Some(pos) = list.iter().position(|&x| x == item_local) {
            list.remove(pos);
        }
        list.push(item_local);
        if list.len() > p.recency {
            list.remove(0);
        }
    }

    Dataset::with_chrono_split(p.name, log)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let p = tiny_profile();
        let a = generate(&p, 7);
        let b = generate(&p, 7);
        assert_eq!(a.log.events, b.log.events);
        let c = generate(&p, 8);
        assert_ne!(a.log.events, c.log.events);
    }

    #[test]
    fn events_sorted_and_bipartite() {
        let p = tiny_profile();
        let d = generate(&p, 1);
        assert_eq!(d.log.len(), p.n_events);
        let mut last_t = f32::NEG_INFINITY;
        for e in &d.log.events {
            assert!(e.t >= last_t);
            last_t = e.t;
            assert!(e.src < p.n_actors);
            assert!(e.dst >= p.n_actors && e.dst < p.n_actors + p.n_items);
        }
    }

    #[test]
    fn repeat_heavy_profile_repeats_more() {
        let mut hi = tiny_profile();
        hi.p_repeat = 0.9;
        let mut lo = tiny_profile();
        lo.p_repeat = 0.05;
        let r_hi = generate(&hi, 3).log.repeat_ratio();
        let r_lo = generate(&lo, 3).log.repeat_ratio();
        assert!(r_hi > r_lo + 0.2, "hi={r_hi} lo={r_lo}");
    }

    #[test]
    fn labels_present_and_sticky() {
        let mut p = tiny_profile();
        p.flip_hazard = 5e-3;
        let d = generate(&p, 4);
        let stats = d.stats();
        assert!(stats.labeled_events > 0);
        assert!(stats.label_positive_rate > 0.0, "{stats:?}");
        // stickiness: per actor, once labeled 1 never labeled 0 afterwards
        let mut flipped = std::collections::BTreeSet::new();
        for e in &d.log.events {
            match e.label {
                1 => {
                    flipped.insert(e.src);
                }
                0 => assert!(!flipped.contains(&e.src), "state flip reverted"),
                _ => {}
            }
        }
    }

    #[test]
    fn all_profiles_generate() {
        for p in profiles() {
            let mut small = p.clone();
            small.n_events = 500; // keep the test fast
            let d = generate(&small, 0);
            assert_eq!(d.log.len(), 500);
            assert_eq!(d.log.d_edge, p.d_edge);
        }
    }

    #[test]
    fn zipf_head_concentration() {
        let p = tiny_profile();
        let d = generate(&p, 5);
        let mut counts = vec![0usize; p.n_actors as usize];
        for e in &d.log.events {
            counts[e.src as usize] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let top10: usize = counts.iter().take(12).sum();
        // heavy-tailed activity: top 10% of actors produce > 25% of events
        assert!(top10 * 4 > d.log.len(), "top12={top10}");
    }
}
