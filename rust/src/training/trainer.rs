//! The epoch/batch loop (paper Algorithm 1 & 2) + evaluation, staged as a
//! PREP / SPLICE / EXEC / WRITEBACK pipeline (see [`crate::pipeline`]).
//!
//! With `pipeline.depth > 0` (default 1) the pure PREP stage runs on a
//! background thread up to `depth` batches ahead; the coordinator thread
//! keeps the device handles and runs SPLICE → EXEC → WRITEBACK. At
//! `depth = 1, bounded_staleness = 0` the pipelined loop is bit-identical
//! to the sequential `depth = 0` path (same pure negative streams, same
//! stage order) — only the thread PREP runs on differs.

use std::collections::VecDeque;
use std::path::Path;
use std::rc::Rc;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};
use xla::Literal;

use crate::batching::{partition, BatchPlan};
use crate::config::ExperimentConfig;
use crate::datagen;
use crate::graph::Dataset;
use crate::memory::{self, GmmTrackers, Mailbox, MemoryBackend, MemoryBackendKind};
use crate::metrics::ranking::link_ap;
use crate::metrics::{EpochTimer, StageQuantiles};
use crate::model::ModelState;
use crate::pipeline::{
    fill_prep_with, negative_stream, plain_to_literals, CommitQueue, PlainArg, PrepBatch,
    PrepContext, Prefetcher, StreamPool,
};
use crate::runtime::engine::{fetch_f32, fetch_scalar, lit_scalar};
use crate::runtime::{gemm, ArtifactSpec, Engine, ExecBackendKind, GemmBackendKind, Step};
use crate::sampler::{NegativeSampler, NeighborIndex};
use crate::trace::{self, Stage};
use crate::training::{Assembler, HostBatch};
use crate::util::json::Json;
use crate::util::pool::WorkerPool;
use crate::util::rng::Pcg32;

/// Per-epoch record (drives Fig. 5/14/16/17 and Table 1 timing).
#[derive(Clone, Debug)]
pub struct EpochReport {
    pub epoch: usize,
    pub train_loss: f64,
    pub train_bce: f64,
    pub train_ap: f64,
    pub coherence: f64,
    pub val_ap: f64,
    pub epoch_secs: f64,
    pub assemble_secs: f64,
    /// Step-run busy time summed over all EXEC streams (the single-stream
    /// meaning at `exec_streams = 1`; may exceed `epoch_secs` when lanes
    /// overlap — see `exec_union_secs`).
    pub execute_secs: f64,
    /// Busy-union of EXEC across streams (never exceeds `epoch_secs`);
    /// what `device_idle_frac` is measured against.
    pub exec_union_secs: f64,
    /// Coordinator wall time attributable to EXEC: the inline run at one
    /// stream, the commit-queue wait under stream lanes.
    pub exec_wait_secs: f64,
    /// Per-stream EXEC busy seconds (index = stream id; sums to
    /// `execute_secs`). One entry at `exec_streams = 1`.
    pub exec_stream_busy_secs: Vec<f64>,
    pub writeback_secs: f64,
    /// Background PREP busy time (0 when running sequentially).
    pub prep_secs: f64,
    /// Coordinator time blocked waiting on the PREP worker.
    pub prep_stall_secs: f64,
    /// Host assembly work hidden behind device execution:
    /// `prep_secs - prep_stall_secs`, clamped at 0.
    pub assemble_hidden_secs: f64,
    /// Fraction of the epoch the device spent NOT executing a step.
    pub device_idle_frac: f64,
    /// Largest number of commits any SPLICE's memory view lagged behind
    /// this epoch: 0 when exact (staleness 0 or sequential), bounded by
    /// `pipeline.bounded_staleness` otherwise.
    pub splice_lag_max: usize,
    /// Largest number of plan-order Adam commits any step's parameter
    /// snapshot lagged behind this epoch: 0 in the exact chain
    /// (`param_staleness = 0`, or any single-stream loop), exactly
    /// `min(param_staleness, exec_streams - 1)` once the relaxed chain's
    /// in-flight window fills.
    pub param_lag_max: usize,
    pub events_per_sec: f64,
    /// Resolved host GEMM kernel backend ("naive" | "blocked"; "none" on
    /// the PJRT backend, which has its own kernels).
    pub gemm_backend: String,
    /// GEMM kernel busy seconds accrued inside this epoch's step
    /// executions (a subset of `execute_secs`; always-on counters in
    /// `runtime::gemm`, drained once per epoch).
    pub gemm_secs: f64,
    /// Share of summed EXEC busy time spent inside GEMM kernels
    /// (`gemm_secs / execute_secs`; 0 when no step executed).
    pub gemm_share: f64,
    pub gamma: f32,
    /// Per-stage per-step p50/p95/p99 from the epoch's latency histograms.
    pub stage_quantiles: Vec<StageQuantiles>,
    /// Vertices the GMM prediction filter tracked at epoch end.
    pub gmm_tracked: usize,
    /// Non-finite pos/neg logits observed in training steps this epoch.
    pub nan_logit_events: u64,
}

impl EpochReport {
    /// Hand-rolled JSON (no serde offline). Non-finite floats (`val_ap`
    /// before evaluation, `gamma` on non-PRES runs) emit as `null`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("epoch", Json::num(self.epoch as f64)),
            ("train_loss", Json::finite(self.train_loss)),
            ("train_bce", Json::finite(self.train_bce)),
            ("train_ap", Json::finite(self.train_ap)),
            ("coherence", Json::finite(self.coherence)),
            ("val_ap", Json::finite(self.val_ap)),
            ("epoch_secs", Json::finite(self.epoch_secs)),
            ("assemble_secs", Json::finite(self.assemble_secs)),
            ("execute_secs", Json::finite(self.execute_secs)),
            ("exec_union_secs", Json::finite(self.exec_union_secs)),
            ("exec_wait_secs", Json::finite(self.exec_wait_secs)),
            (
                "exec_stream_busy_secs",
                Json::arr(self.exec_stream_busy_secs.iter().map(|&s| Json::finite(s))),
            ),
            ("writeback_secs", Json::finite(self.writeback_secs)),
            ("prep_secs", Json::finite(self.prep_secs)),
            ("prep_stall_secs", Json::finite(self.prep_stall_secs)),
            ("assemble_hidden_secs", Json::finite(self.assemble_hidden_secs)),
            ("device_idle_frac", Json::finite(self.device_idle_frac)),
            ("splice_lag_max", Json::num(self.splice_lag_max as f64)),
            ("param_lag_max", Json::num(self.param_lag_max as f64)),
            ("events_per_sec", Json::finite(self.events_per_sec)),
            ("gemm_backend", Json::str(&self.gemm_backend)),
            ("gemm_secs", Json::finite(self.gemm_secs)),
            ("gemm_share", Json::finite(self.gemm_share)),
            ("gamma", Json::finite(self.gamma as f64)),
            (
                "stage_quantiles",
                Json::arr(self.stage_quantiles.iter().map(|q| q.to_json())),
            ),
            ("gmm_tracked", Json::num(self.gmm_tracked as f64)),
            ("nan_logit_events", Json::num(self.nan_logit_events as f64)),
        ])
    }
}

/// Whole-run summary.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub config: ExperimentConfig,
    pub epochs: Vec<EpochReport>,
    pub best_val_ap: f64,
    pub test_ap: f64,
    pub test_auc: f64,
    pub total_train_secs: f64,
    pub mean_epoch_secs: f64,
    /// (iteration, train batch AP) samples for statistical-efficiency plots.
    pub iteration_ap: Vec<(usize, f64)>,
    /// Coordinator-side live bytes (Fig. 19).
    pub coordinator_bytes: usize,
}

impl RunReport {
    /// Whole-run JSON: config + per-epoch reports + summary scalars. The
    /// JSONL emitter and `BENCH_*.json` writers build on this instead of
    /// hand-rolling their own formats.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("config", self.config.to_json()),
            ("best_val_ap", Json::finite(self.best_val_ap)),
            ("test_ap", Json::finite(self.test_ap)),
            ("test_auc", Json::finite(self.test_auc)),
            ("total_train_secs", Json::finite(self.total_train_secs)),
            ("mean_epoch_secs", Json::finite(self.mean_epoch_secs)),
            ("coordinator_bytes", Json::num(self.coordinator_bytes as f64)),
            (
                "epochs",
                Json::arr(self.epochs.iter().map(|e| e.to_json())),
            ),
            (
                "iteration_ap",
                Json::arr(self.iteration_ap.iter().map(|&(i, ap)| {
                    Json::arr([Json::num(i as f64), Json::finite(ap)])
                })),
            ),
        ])
    }
}

/// The training coordinator for one (dataset, model, batch, mode) run.
///
/// Owns the EXEC handles (`Rc<Engine>` / `Rc<Step>` — deliberately NOT
/// Send, see `runtime/mod.rs` on the Send boundary; the engine dispatches
/// PJRT or the pure-Rust host step per `cfg.exec`) and the mutable
/// substrates. Only plain prepped host data ever crosses to/from the
/// background PREP thread.
pub struct Trainer {
    pub cfg: ExperimentConfig,
    pub engine: Rc<Engine>,
    pub dataset: Arc<Dataset>,
    state: ModelState,
    /// Vertex memory behind the closed backend enum: flat at
    /// `memory_shards = 1` (the exact legacy layout), sharded with pooled
    /// parallel gather/scatter above that. Enum (not `Box<dyn>`) so the
    /// assembler's per-row scalar reads monomorphize to branch dispatch.
    /// Routing is pure data, so PREP precomputes shard routes off-thread
    /// while the backend itself never leaves the coordinator.
    store: MemoryBackendKind,
    /// Persistent worker lanes shared by the sharded store's
    /// gather/scatter, the PREP hot loops (both inline and on the prefetch
    /// thread) — spawned once here, reused every op
    /// (`--pool-workers`; 0 = the auto-sized process pool).
    pool: Arc<WorkerPool>,
    nbr: NeighborIndex,
    mailbox: Option<Mailbox>,
    gmm: GmmTrackers,
    assembler: Assembler,
    /// Rotating host staging slots: slot `i % hosts.len()` stages batch
    /// `i`. One slot suffices at `bounded_staleness = 0`; staleness `k`
    /// keeps `k + 1` slots alive so pre-spliced batches don't clobber the
    /// one in flight.
    hosts: Vec<HostBatch>,
    train_step: Rc<Step>,
    eval_step: Rc<Step>,
    plans: Arc<Vec<BatchPlan>>,
    neg_sampler: NegativeSampler,
    // reusable output scratch
    sbar_scratch: Vec<f32>,
    msg_scratch: Vec<f32>,
    logit_scratch: [Vec<f32>; 2],
    pub iteration_ap: Vec<(usize, f64)>,
    iterations: usize,
    /// Non-finite pos/neg logits seen in training steps this epoch
    /// (telemetry; reset by `train_epoch`).
    nan_logits: u64,
    /// Fault-injection hook for the epoch error-path tests: when set to
    /// `Some(i)`, the stream submit for plan index `i` truncates its
    /// payload so the lane rejects the step mid-epoch. Never set outside
    /// tests; `None` is a no-op on the hot path.
    pub exec_fault_at: Option<usize>,
}

impl Trainer {
    /// Build everything from a config: dataset (generated deterministically
    /// from the seed), engine (PJRT or host per `cfg.exec` — "auto" picks
    /// host whenever `artifacts_dir` has no manifest), steps, substrates.
    pub fn from_config(cfg: &ExperimentConfig) -> Result<Trainer> {
        let engine = Rc::new(Engine::auto(Path::new(&cfg.artifacts_dir), &cfg.exec)?);
        let dataset = Arc::new(Self::make_dataset(cfg)?);
        Self::with_shared(cfg, engine, dataset)
    }

    /// Variant sharing an engine + dataset across runs (sweeps, figures).
    pub fn with_shared(
        cfg: &ExperimentConfig,
        engine: Rc<Engine>,
        dataset: Arc<Dataset>,
    ) -> Result<Trainer> {
        cfg.validate()?;
        // config validation rejects the statically-knowable case
        // (exec = "pjrt"); this catches "auto" resolving to PJRT too
        if cfg.pipeline.exec_streams > 1 && engine.backend() == ExecBackendKind::Pjrt {
            anyhow::bail!(
                "exec_streams = {} requires the host EXEC backend: PJRT handles are not \
                 Send, so steps cannot run on stream lanes — use --exec host or \
                 --exec-streams 1",
                cfg.pipeline.exec_streams
            );
        }
        let dims = engine.manifest().dims;
        let b = cfg.batch_size;
        // one persistent pool per trainer (or the shared process pool at
        // the 0 = auto default): workers spawn here, never per op. Created
        // before the steps so host EXEC matmuls fan out on the same lanes
        // as SPLICE/WRITEBACK/PREP (no-op on the PJRT backend).
        let pool = match cfg.pipeline.pool_workers {
            0 => WorkerPool::global().clone(),
            n => Arc::new(WorkerPool::new(n)),
        };
        engine.set_host_pool(pool.clone());
        // resolve the GEMM kernel backend before any step is built
        // ("auto" -> blocked; no-op on the PJRT backend)
        engine.set_host_gemm(GemmBackendKind::resolve(&cfg.gemm)?);
        let train_step = engine
            .step(&cfg.model, b, "train")
            .context("loading train step")?;
        let eval_step = engine.step(&cfg.model, b, "eval")?;
        let state = ModelState::init(&engine, &cfg.model, cfg.seed)?;
        let n_nodes = dataset.log.num_nodes;
        let mailbox = (cfg.model == "apan").then(|| Mailbox::new(n_nodes, dims.k_nbr, dims.d_msg));
        // plans are pure functions of (log, b): compute once, reuse across
        // epochs (cfg.prefetch=false rebuilds per epoch for the ablation)
        let plans = Arc::new(Self::build_plans(&dataset, b));
        let neg_sampler = NegativeSampler::new(&dataset.log);
        let u = 2 * b;
        let hosts = (0..cfg.pipeline.bounded_staleness + 1)
            .map(|_| HostBatch::new(&cfg.model, b, dims))
            .collect();
        Ok(Trainer {
            cfg: cfg.clone(),
            state,
            store: memory::make_backend_pooled(
                n_nodes,
                dims.d_mem,
                cfg.memory_shards,
                pool.clone(),
            ),
            pool,
            nbr: NeighborIndex::new(n_nodes, dims.k_nbr),
            mailbox,
            gmm: GmmTrackers::new(n_nodes, dims.d_mem, cfg.anchor_fraction, cfg.seed),
            assembler: Assembler::new(dims),
            hosts,
            train_step,
            eval_step,
            plans,
            neg_sampler,
            sbar_scratch: vec![0.0; u * dims.d_mem],
            msg_scratch: vec![0.0; u * dims.d_msg],
            logit_scratch: [vec![0.0; b], vec![0.0; b]],
            iteration_ap: Vec::new(),
            iterations: 0,
            nan_logits: 0,
            exec_fault_at: None,
            engine,
            dataset,
        })
    }

    pub fn make_dataset(cfg: &ExperimentConfig) -> Result<Dataset> {
        let mut profile = if cfg.dataset == "tiny" {
            datagen::tiny_profile()
        } else {
            datagen::profile(&cfg.dataset)
                .ok_or_else(|| anyhow::anyhow!("unknown dataset '{}'", cfg.dataset))?
        };
        profile.n_events = ((profile.n_events as f32 * cfg.data_scale) as usize).max(64);
        Ok(datagen::generate(&profile, cfg.seed))
    }

    fn build_plans(dataset: &Dataset, b: usize) -> Vec<BatchPlan> {
        partition(0..dataset.log.len(), b)
            .into_iter()
            .map(|r| BatchPlan::build(&dataset.log, r))
            .collect()
    }

    /// Plans whose *predicted* batch lies inside the training split.
    fn train_plan_count(&self) -> usize {
        self.plans
            .iter()
            .filter(|p| p.range.end <= self.dataset.split.train_end)
            .count()
    }

    fn reset_epoch_state(&mut self) {
        self.store.reset();
        self.nbr.clear();
        if let Some(mb) = &mut self.mailbox {
            mb.clear();
        }
        self.gmm.reset();
        if !self.cfg.prefetch {
            // ablation: rebuild plans every epoch instead of reusing
            self.plans = Arc::new(Self::build_plans(&self.dataset, self.cfg.batch_size));
        }
        // cfg.pipeline may have been tightened after construction (benches
        // sweep depth/staleness on one trainer): grow the slot pool to fit
        let slots = self.cfg.pipeline.bounded_staleness + 1;
        while self.hosts.len() < slots {
            self.hosts
                .push(HostBatch::new(&self.cfg.model, self.cfg.batch_size, self.assembler.dims));
        }
    }

    /// One training epoch (Algorithm 2 body), pipelined when
    /// `cfg.pipeline.depth > 0`. Returns the epoch report with
    /// val_ap = NaN (the caller decides whether to evaluate).
    pub fn train_epoch(&mut self, epoch: usize) -> Result<EpochReport> {
        self.reset_epoch_state();
        self.nan_logits = 0;
        let n_train = self.train_plan_count();
        let mut timer = EpochTimer::default();
        // snapshot the process-global GEMM counters so the epoch delta
        // attributes kernel time to this epoch only (lane threads included
        // — the counters are shared atomics)
        let (gemm_ns0, _) = gemm::timing_totals();
        timer.start_epoch();

        let (results, splice_lag_max) = if self.cfg.pipeline.depth > 0 && n_train > 1 {
            if self.cfg.pipeline.exec_streams > 1 {
                if self.cfg.pipeline.param_staleness > 0 {
                    self.run_relaxed_multistream_epoch(epoch, n_train, &mut timer)?
                } else {
                    self.run_multistream_epoch(epoch, n_train, &mut timer)?
                }
            } else {
                self.run_pipelined_epoch(epoch, n_train, &mut timer)?
            }
        } else {
            let mut out = Vec::with_capacity(n_train.saturating_sub(1));
            for i in 1..n_train {
                out.push(self.run_train_iteration(i, epoch, &mut timer)?);
            }
            (out, 0) // sequential splices are always exact
        };

        let mut losses = Vec::with_capacity(results.len());
        let mut bces = Vec::with_capacity(results.len());
        let mut cohs = Vec::with_capacity(results.len());
        let mut aps = Vec::with_capacity(results.len());
        for (loss, bce, coh, ap) in results {
            losses.push(loss);
            bces.push(bce);
            cohs.push(coh);
            aps.push(ap);
            self.iterations += 1;
            self.iteration_ap.push((self.iterations, ap));
        }
        timer.steps = n_train.saturating_sub(1);
        timer.finish_epoch();
        let (gemm_ns1, _) = gemm::timing_totals();
        timer.absorb_gemm(
            Duration::from_nanos(gemm_ns1.saturating_sub(gemm_ns0)),
            &gemm::take_call_hist(),
        );

        Ok(EpochReport {
            epoch,
            train_loss: crate::util::stats::mean(&losses),
            train_bce: crate::util::stats::mean(&bces),
            train_ap: crate::util::stats::mean(&aps),
            coherence: crate::util::stats::mean(&cohs),
            val_ap: f64::NAN,
            epoch_secs: timer.total.as_secs_f64(),
            assemble_secs: timer.assemble.as_secs_f64(),
            execute_secs: timer.execute.as_secs_f64(),
            exec_union_secs: timer.exec_union.as_secs_f64(),
            exec_wait_secs: timer.exec_wait.as_secs_f64(),
            exec_stream_busy_secs: timer.stream_busy.iter().map(|d| d.as_secs_f64()).collect(),
            writeback_secs: timer.writeback.as_secs_f64(),
            prep_secs: timer.prep_busy.as_secs_f64(),
            prep_stall_secs: timer.prep_stall.as_secs_f64(),
            assemble_hidden_secs: timer.assemble_hidden().as_secs_f64(),
            device_idle_frac: timer.device_idle_fraction(),
            splice_lag_max,
            param_lag_max: timer.param_lag_max,
            events_per_sec: timer.events_per_sec(executed_events(&self.plans, n_train)),
            gemm_backend: self
                .engine
                .host_gemm()
                .map(|k| k.name().to_string())
                .unwrap_or_else(|| "none".to_string()),
            gemm_secs: timer.gemm_busy.as_secs_f64(),
            gemm_share: if timer.execute.is_zero() {
                0.0
            } else {
                timer.gemm_busy.as_secs_f64() / timer.execute.as_secs_f64()
            },
            gamma: self.state.gamma().unwrap_or(f32::NAN),
            stage_quantiles: timer.stage_quantiles(),
            gmm_tracked: self.gmm.tracked_vertices(),
            nan_logit_events: self.nan_logits,
        })
    }

    /// The PREP worker context for one epoch (shared by the single- and
    /// multi-stream pipelined loops).
    fn prep_context(&self, epoch: usize) -> PrepContext {
        PrepContext {
            dataset: self.dataset.clone(),
            plans: self.plans.clone(),
            sampler: self.neg_sampler.clone(),
            seed: self.cfg.seed,
            epoch,
            batch_size: self.cfg.batch_size,
            d_edge: self.assembler.dims.d_edge,
            router: self.store.router(),
            pool: self.pool.clone(),
        }
    }

    /// The pipelined epoch body: a background PREP worker feeds the
    /// coordinator's SPLICE → EXEC → WRITEBACK loop over bounded channels.
    /// With `bounded_staleness = k > 0` up to `k` future batches are
    /// spliced before the in-flight write-back lands (their memory view
    /// lags at most `k` commits). The window fill blocks on the PREP
    /// worker, so which batches splice stale is a pure function of
    /// `(n_train, k)` — deterministic, and the exact schedule the
    /// multi-stream loop replays. Returns the per-iteration metrics plus
    /// the maximum observed splice lag (the staleness bound's witness).
    fn run_pipelined_epoch(
        &mut self,
        epoch: usize,
        n_train: usize,
        timer: &mut EpochTimer,
    ) -> Result<(Vec<(f64, f64, f64, f64)>, usize)> {
        let stale = self.cfg.pipeline.bounded_staleness;
        let slots = self.hosts.len();
        let ctx = self.prep_context(epoch);
        let mut pf = Prefetcher::spawn(ctx, 1..n_train, self.cfg.pipeline.depth)?;
        let mut presliced: VecDeque<usize> = VecDeque::new();
        let mut results = Vec::with_capacity(n_train.saturating_sub(1));
        let mut splice_lag_max = 0usize;

        for i in 1..n_train {
            // ---- SPLICE (unless already pre-spliced under staleness)
            if presliced.front() == Some(&i) {
                presliced.pop_front();
            } else {
                self.recv_install_splice(&mut pf, i, timer)?;
                timer.record_splice_lag(0); // exact splice: all commits landed
            }

            // ---- EXEC
            let (spec, mut outputs) = self.exec_train_slot(i % slots, timer)?;

            // ---- pre-SPLICE the staleness window before this write-back
            while stale > 0 && presliced.len() < stale {
                let next = i + presliced.len() + 1;
                if next >= n_train {
                    break;
                }
                self.recv_install_splice(&mut pf, next, timer)?;
                // batch `next` should see commits up to `next - 1` but only
                // `i - 1` have landed: its view lags `next - i` commits
                splice_lag_max = splice_lag_max.max(next - i);
                timer.record_splice_lag(next - i);
                presliced.push_back(next);
            }

            // ---- WRITEBACK
            let t2 = crate::util::now();
            self.state.absorb_outputs(&mut outputs);
            let metrics = self.consume_step_outputs(&spec, &outputs, i % slots, i)?;
            let took = t2.elapsed();
            timer.add_writeback(took);
            trace::record_span(Stage::Writeback, t2, t2 + took, i as u64);
            results.push(metrics);
        }
        Ok((results, splice_lag_max))
    }

    /// The multi-stream epoch body (`exec_streams >= 2`, host backend,
    /// `bounded_staleness = k >= 1`): steps execute on [`StreamPool`]
    /// lanes while the coordinator commits write-backs strictly in plan
    /// order through a [`CommitQueue`]. Software-pipelined so step `i+1`
    /// runs concurrently with step `i`'s write-back, metrics and the next
    /// window splice:
    ///
    /// ```text
    ///   wait i → absorb params → submit i+1 → WB i → metrics i → splice i+1+k
    /// ```
    ///
    /// Bit-identical to [`Trainer::run_pipelined_epoch`] at the same `k`
    /// for every stream count: each splice sees exactly the serial
    /// schedule's commits (batch `j` lags `min(k, j - 1)` commits, capped
    /// by the range end), and step `i+1` is only submitted after step
    /// `i`'s outputs returned the parameter bank — the parameter chain
    /// stays exact, so at most one step is mid-flight and the lanes hide
    /// *coordinator* work, never relax freshness. This is the
    /// `param_staleness = 0` default; see
    /// [`Trainer::run_relaxed_multistream_epoch`] for the bounded-lag
    /// sibling that keeps `min(p, streams - 1) + 1` steps genuinely in
    /// flight.
    ///
    /// The parameters + Adam state thread through the epoch as a plain
    /// [`PlainArg`] bank: exported from `state` once at epoch start, moved
    /// into each job, and handed back zero-copy from each step's outputs —
    /// no per-step literal round-trip on the coordinator critical path.
    /// The bank AND the Adam step counter are re-imported into `state`
    /// only when the epoch completes, so a mid-flight error (dead lane,
    /// bad payload) leaves `state` exactly at its consistent epoch-start
    /// values — params and `step` never drift apart.
    fn run_multistream_epoch(
        &mut self,
        epoch: usize,
        n_train: usize,
        timer: &mut EpochTimer,
    ) -> Result<(Vec<(f64, f64, f64, f64)>, usize)> {
        let stale = self.cfg.pipeline.bounded_staleness;
        anyhow::ensure!(
            stale >= 1,
            "exec_streams > 1 requires bounded_staleness >= 1 (nothing can overlap at k = 0)"
        );
        let spec = self.train_step.spec.clone();
        let host_step = self.train_step.host_step().ok_or_else(|| {
            anyhow::anyhow!(
                "exec_streams = {} requires the host EXEC backend: PJRT handles are not \
                 Send, so steps cannot run on stream lanes",
                self.cfg.pipeline.exec_streams
            )
        })?;
        let streams = StreamPool::new(self.cfg.pipeline.exec_streams, host_step)?;
        let ctx = self.prep_context(epoch);
        let mut pf = Prefetcher::spawn(ctx, 1..n_train, self.cfg.pipeline.depth)?;
        let mut commits = CommitQueue::new();
        let mut results = Vec::with_capacity(n_train.saturating_sub(1));
        let mut splice_lag_max = 0usize;
        let n = self.state.len();
        let last = n_train - 1; // highest plan index executed this epoch
        // Adam step numbers this epoch: step `i` of 1..n_train executes
        // with step_t = step0 + i (exactly the inline path's
        // `state.step + 1` sequence); `state.step` itself is only advanced
        // at the successful epoch-end import below
        let step0 = self.state.step;

        // export the parameter bank once (the literals in `state` stay
        // untouched — and stale — until the epoch-end import below)
        let mut bank: Vec<PlainArg> = Vec::with_capacity(3 * n);
        for lit in self
            .state
            .params
            .iter()
            .chain(self.state.adam_m.iter())
            .chain(self.state.adam_v.iter())
        {
            bank.push(PlainArg::from_literal(lit)?);
        }

        // ---- prologue: batch 1 splices exactly (lag 0) and goes in
        // flight; the window then pre-splices batches 2..=1+k against the
        // initial memory view — the serial loop's iteration-1 fill
        self.recv_install_splice(&mut pf, 1, timer)?;
        timer.record_splice_lag(0); // batch 1 splices exactly
        timer.record_param_lag(0); // exact chain: every snapshot is current
        let job =
            self.submit_train_slot(&streams, 1, std::mem::take(&mut bank), step0 + 1, timer)?;
        commits.push(1, job);
        let mut hi = 1usize; // highest plan index spliced so far
        while hi < (1 + stale).min(last) {
            let next = hi + 1;
            self.recv_install_splice(&mut pf, next, timer)?;
            splice_lag_max = splice_lag_max.max(next - 1);
            timer.record_splice_lag(next - 1);
            hi = next;
        }

        for i in 1..n_train {
            // ---- ordered commit: wait for step i (always the queue front)
            let t0 = crate::util::now();
            let done = commits.wait_next()?;
            let waited = t0.elapsed();
            timer.add_exec_wait(waited);
            trace::record_span(Stage::CommitWait, t0, t0 + waited, i as u64);
            anyhow::ensure!(
                done.seq == i,
                "commit queue returned step {}, expected {i}",
                done.seq
            );
            timer.record_exec(done.stream, done.started, done.finished);
            let mut outs = done
                .outputs
                .with_context(|| format!("EXEC stream step {i}"))?;
            anyhow::ensure!(
                outs.len() == spec.outputs.len(),
                "EXEC stream step {i}: got {} outputs, ABI expects {}",
                outs.len(),
                spec.outputs.len()
            );

            // ---- reclaim the updated parameter bank (zero-copy) and put
            // batch i+1 (pre-spliced) in flight so it executes under the
            // write-back below
            let t1 = crate::util::now();
            let step_outs = outs.split_off(3 * n);
            bank = outs;
            let outputs = plain_to_literals(&step_outs, &spec.outputs[3 * n..])?;
            timer.writeback += t1.elapsed();
            if i < last {
                timer.record_param_lag(0); // step i+1 sees all i commits
                let job = self.submit_train_slot(
                    &streams,
                    i + 1,
                    std::mem::take(&mut bank),
                    step0 + (i + 1) as u64,
                    timer,
                )?;
                commits.push(i + 1, job);
            }

            // ---- WRITEBACK i, strictly in plan order
            let t2 = crate::util::now();
            let metrics =
                self.consume_step_outputs(&spec, &outputs, i % self.hosts.len(), i)?;
            let took = t2.elapsed();
            timer.add_writeback(took);
            trace::record_span(Stage::Writeback, t2, t2 + took, i as u64);
            results.push(metrics);

            // ---- top up the staleness window: batch i+1+k sees commits
            // <= i, exactly the serial loop's iteration-(i+1) fill
            while hi < (i + 1 + stale).min(last) {
                let next = hi + 1;
                self.recv_install_splice(&mut pf, next, timer)?;
                splice_lag_max = splice_lag_max.max(next - (i + 1));
                timer.record_splice_lag(next - (i + 1));
                hi = next;
            }
        }

        // ---- re-import the final parameter bank + step counter into the
        // state (one conversion per epoch; eval and reporting read `state`)
        anyhow::ensure!(bank.len() == 3 * n, "parameter bank lost tensors mid-epoch");
        let v_bank = bank.split_off(2 * n);
        let m_bank = bank.split_off(n);
        for (dst, src, specs) in [
            (&mut self.state.params, &bank, &spec.inputs[..n]),
            (&mut self.state.adam_m, &m_bank, &spec.inputs[n..2 * n]),
            (&mut self.state.adam_v, &v_bank, &spec.inputs[2 * n..3 * n]),
        ] {
            for ((lit, plain), tspec) in dst.iter_mut().zip(src).zip(specs) {
                *lit = plain.to_literal(tspec)?;
            }
        }
        self.state.step = step0 + results.len() as u64;
        Ok((results, splice_lag_max))
    }

    /// The relaxed multi-stream epoch body (`param_staleness = p >= 1`,
    /// `exec_streams = s >= 2`, host backend): a window of
    /// `W = min(p, s - 1) + 1` steps is *genuinely* in flight at once.
    /// Lanes run the "grad" step kind — forward + backward only, no fused
    /// Adam — against a parameter snapshot cloned at submission; the
    /// coordinator owns the optimizer and applies [`adam_update`] strictly
    /// in plan order as each step commits, so step `j` executes against
    /// params missing at most `W - 1 = min(p, s - 1)` plan-order commits
    /// (the `param_lag` histogram's witness):
    ///
    /// ```text
    ///   wait i → Adam i → WB i → splice i+1+k → submit i+W (params after i)
    /// ```
    ///
    /// The schedule — which step runs against which parameter version and
    /// which memory view — is a pure function of `(n_train, k, p, s)`:
    /// submissions and commits happen at fixed loop positions, never in
    /// response to lane timing, so two runs of the same config are
    /// bit-identical even though lanes race. The memory-splice schedule is
    /// exactly the serial/exact loop's (batch `j` lags `min(k, j - 1)`
    /// commits); only the parameter chain is relaxed. Config validation
    /// guarantees `W - 1 <= bounded_staleness`, which is what makes batch
    /// `i + W` already spliced when it is submitted.
    ///
    /// Like the exact loop, `self.state` (params, Adam moments, step
    /// counter) is read once at epoch start and written once at successful
    /// epoch end — the working banks live in coordinator-local
    /// `Vec<Vec<f32>>`s — so a mid-epoch error (dead lane, bad payload)
    /// leaves `ModelState` at its consistent epoch-start values.
    ///
    /// [`adam_update`]: crate::runtime::host_step::adam_update
    fn run_relaxed_multistream_epoch(
        &mut self,
        epoch: usize,
        n_train: usize,
        timer: &mut EpochTimer,
    ) -> Result<(Vec<(f64, f64, f64, f64)>, usize)> {
        let stale = self.cfg.pipeline.bounded_staleness;
        let window = self
            .cfg
            .pipeline
            .param_staleness
            .min(self.cfg.pipeline.exec_streams - 1)
            + 1;
        // validate() enforces this; re-check because benches/tests mutate
        // cfg.pipeline after construction
        anyhow::ensure!(
            window - 1 <= stale,
            "param_staleness window holds {} commits in flight but bounded_staleness = {} \
             cannot pre-splice that far ahead",
            window - 1,
            stale
        );
        let grad_step = self
            .engine
            .step(&self.cfg.model, self.cfg.batch_size, "grad")
            .context("loading grad step")?;
        let spec = grad_step.spec.clone();
        let host_step = grad_step.host_step().ok_or_else(|| {
            anyhow::anyhow!(
                "param_staleness = {} requires the host EXEC backend: PJRT handles are \
                 not Send, so grad steps cannot run on stream lanes",
                self.cfg.pipeline.param_staleness
            )
        })?;
        let streams = StreamPool::new(self.cfg.pipeline.exec_streams, host_step)?;
        let ctx = self.prep_context(epoch);
        let mut pf = Prefetcher::spawn(ctx, 1..n_train, self.cfg.pipeline.depth)?;
        let mut commits = CommitQueue::new();
        let mut results = Vec::with_capacity(n_train.saturating_sub(1));
        let mut splice_lag_max = 0usize;
        let n = self.state.len();
        let last = n_train - 1;
        let step0 = self.state.step;

        // coordinator-owned working banks: cloned params travel into each
        // job, gradients come back, Adam applies here in strict plan order
        let export = |lits: &[Literal]| -> Result<Vec<Vec<f32>>> {
            lits.iter()
                .map(|lit| {
                    let mut v = vec![0.0f32; lit.element_count()];
                    lit.copy_raw_to(&mut v)?;
                    Ok(v)
                })
                .collect()
        };
        let mut params = export(&self.state.params)?;
        let mut adam_m = export(&self.state.adam_m)?;
        let mut adam_v = export(&self.state.adam_v)?;

        // ---- prologue: batch 1 splices exactly, the memory window
        // pre-splices 2..=1+k against the initial view (the serial loop's
        // iteration-1 fill), then the first W steps go in flight against
        // params v0 — step j's snapshot misses its j - 1 predecessors
        self.recv_install_splice(&mut pf, 1, timer)?;
        timer.record_splice_lag(0);
        let mut hi = 1usize; // highest plan index spliced so far
        while hi < (1 + stale).min(last) {
            let next = hi + 1;
            self.recv_install_splice(&mut pf, next, timer)?;
            splice_lag_max = splice_lag_max.max(next - 1);
            timer.record_splice_lag(next - 1);
            hi = next;
        }
        for j in 1..=window.min(last) {
            timer.record_param_lag(j - 1);
            let job = self.submit_grad_slot(&streams, j, &spec, &params, timer)?;
            commits.push(j, job);
        }

        for i in 1..n_train {
            // ---- ordered commit: wait for step i (always the queue front)
            let t0 = crate::util::now();
            let done = commits.wait_next()?;
            let waited = t0.elapsed();
            timer.add_exec_wait(waited);
            trace::record_span(Stage::CommitWait, t0, t0 + waited, i as u64);
            anyhow::ensure!(
                done.seq == i,
                "commit queue returned step {}, expected {i}",
                done.seq
            );
            timer.record_exec(done.stream, done.started, done.finished);
            let mut outs = done
                .outputs
                .with_context(|| format!("EXEC stream step {i}"))?;
            anyhow::ensure!(
                outs.len() == spec.outputs.len(),
                "EXEC stream step {i}: got {} outputs, ABI expects {}",
                outs.len(),
                spec.outputs.len()
            );

            // ---- the coordinator's Adam commit, strictly in plan order:
            // gradients are the leading n outputs of the grad ABI
            let t1 = crate::util::now();
            let step_outs = outs.split_off(n);
            let mut grads = Vec::with_capacity(n);
            for (gi, g) in outs.into_iter().enumerate() {
                match g {
                    PlainArg::F32(v) => grads.push(v),
                    PlainArg::I32(_) => anyhow::bail!(
                        "EXEC stream step {i}: gradient output {} is not f32",
                        spec.outputs[gi].name
                    ),
                }
            }
            crate::runtime::host_step::adam_update(
                &mut params,
                &grads,
                &mut adam_m,
                &mut adam_v,
                self.cfg.lr,
                (step0 + i as u64) as f32,
            );
            let outputs = plain_to_literals(&step_outs, &spec.outputs[n..])?;
            timer.writeback += t1.elapsed();

            // ---- WRITEBACK i, strictly in plan order
            let t2 = crate::util::now();
            let metrics = self.consume_step_outputs(&spec, &outputs, i % self.hosts.len(), i)?;
            let took = t2.elapsed();
            timer.add_writeback(took);
            trace::record_span(Stage::Writeback, t2, t2 + took, i as u64);
            results.push(metrics);

            // ---- top up the memory staleness window: batch i+1+k sees
            // commits <= i, exactly the serial loop's iteration-(i+1) fill
            while hi < (i + 1 + stale).min(last) {
                let next = hi + 1;
                self.recv_install_splice(&mut pf, next, timer)?;
                splice_lag_max = splice_lag_max.max(next - (i + 1));
                timer.record_splice_lag(next - (i + 1));
                hi = next;
            }

            // ---- refill the in-flight window: step i+W snapshots the
            // params with commits 1..=i applied — lag W-1 = min(p, s-1)
            if i + window <= last {
                timer.record_param_lag(window - 1);
                let job = self.submit_grad_slot(&streams, i + window, &spec, &params, timer)?;
                commits.push(i + window, job);
            }
        }

        // ---- single state import on success (eval and reporting read
        // `state`; an error above leaves it at the epoch-start values)
        for (dst, src) in [
            (&mut self.state.params, &params),
            (&mut self.state.adam_m, &adam_m),
            (&mut self.state.adam_v, &adam_v),
        ] {
            for ((lit, vals), tspec) in dst.iter_mut().zip(src).zip(&spec.inputs[..n]) {
                *lit = crate::runtime::engine::lit_f32(vals, &tspec.shape)?;
            }
        }
        self.state.step = step0 + results.len() as u64;
        Ok((results, splice_lag_max))
    }

    /// Stage host slot `i % slots` as plain payloads behind a *cloned*
    /// parameter snapshot and put the grad step in flight on a
    /// [`StreamPool`] lane. Unlike [`Trainer::submit_train_slot`] the bank
    /// is copied, not moved — that copy is exactly what lets
    /// `min(p, streams - 1) + 1` steps share lanes concurrently — and the
    /// grad ABI takes no trailing lr / step_t (the coordinator owns the
    /// optimizer step). Pack time lands in the assemble bucket.
    fn submit_grad_slot(
        &mut self,
        streams: &StreamPool,
        i: usize,
        spec: &ArtifactSpec,
        params: &[Vec<f32>],
        timer: &mut EpochTimer,
    ) -> Result<std::sync::mpsc::Receiver<crate::pipeline::StepDone>> {
        let n_params = self.state.len();
        debug_assert_eq!(params.len(), n_params, "parameter bank out of step");
        let t0 = crate::util::now();
        let mut args: Vec<PlainArg> = params.iter().map(|v| PlainArg::F32(v.clone())).collect();
        args.extend(self.hosts[i % self.hosts.len()].pack_plain(spec, n_params, 0)?);
        if self.exec_fault_at == Some(i) {
            args.pop(); // fault injection: the lane rejects the short payload
        }
        timer.add_assemble(t0.elapsed());
        Ok(streams.submit(i, args))
    }

    /// Consistency witness over the optimizer-visible state: the Adam step
    /// counter plus per-tensor f64 sums of params / m / v, in bank order.
    /// Summing identical bits yields identical doubles, so tests can
    /// assert "unchanged across a failed epoch" without reaching into the
    /// literals.
    pub fn param_state_digest(&self) -> Result<(u64, Vec<f64>)> {
        let mut sums = Vec::with_capacity(3 * self.state.len());
        for lit in self
            .state
            .params
            .iter()
            .chain(self.state.adam_m.iter())
            .chain(self.state.adam_v.iter())
        {
            let mut buf = vec![0.0f32; lit.element_count()];
            lit.copy_raw_to(&mut buf)?;
            sums.push(buf.iter().map(|&x| x as f64).sum::<f64>());
        }
        Ok((self.state.step, sums))
    }

    /// Block for the PREP worker's batch `idx` (stall time accounted),
    /// install it into its rotating slot and SPLICE against the current
    /// memory view.
    fn recv_install_splice(
        &mut self,
        pf: &mut Prefetcher,
        idx: usize,
        timer: &mut EpochTimer,
    ) -> Result<()> {
        let t0 = crate::util::now();
        let prep = pf.recv()?;
        let stalled = t0.elapsed();
        timer.add_prep_stall(stalled);
        trace::record_span(Stage::PrepStall, t0, t0 + stalled, idx as u64);
        self.install_and_splice(prep, idx, pf, timer)
    }

    /// One sequential iteration (`pipeline.depth = 0`): PREP runs inline on
    /// the coordinator, inside the classic assemble phase.
    fn run_train_iteration(
        &mut self,
        i: usize,
        epoch: usize,
        timer: &mut EpochTimer,
    ) -> Result<(f64, f64, f64, f64)> {
        // -------- PREP + SPLICE (assemble)
        let t0 = crate::util::now();
        {
            let prev = &self.plans[i - 1];
            let cur = &self.plans[i];
            let host = &mut self.hosts[0];
            let base = negative_stream(self.cfg.seed, epoch, i);
            fill_prep_with(
                &mut host.prep,
                &self.dataset.log,
                prev,
                cur,
                &self.neg_sampler,
                &base,
                self.store.router(),
                &self.pool,
            );
            host.prep.index = i;
            host.prep.epoch = epoch;
        }
        self.splice_slot(0, i);
        let assembled = t0.elapsed();
        timer.add_assemble(assembled);
        trace::record_span(Stage::Splice, t0, t0 + assembled, i as u64);

        // -------- EXEC
        let (spec, mut outputs) = self.exec_train_slot(0, timer)?;

        // -------- WRITEBACK + metrics
        let t2 = crate::util::now();
        self.state.absorb_outputs(&mut outputs);
        let metrics = self.consume_step_outputs(&spec, &outputs, 0, i)?;
        let took = t2.elapsed();
        timer.add_writeback(took);
        trace::record_span(Stage::Writeback, t2, t2 + took, i as u64);
        Ok(metrics)
    }

    /// Shared receive-side handling for a prepped batch: order check,
    /// overlap accounting, install into its rotating slot (recycling the
    /// displaced scratch to the worker), and SPLICE against the current
    /// memory view.
    fn install_and_splice(
        &mut self,
        prep: PrepBatch,
        idx: usize,
        pf: &Prefetcher,
        timer: &mut EpochTimer,
    ) -> Result<()> {
        anyhow::ensure!(
            prep.index == idx,
            "pipeline out of order: got prep for batch {}, expected {}",
            prep.index,
            idx
        );
        timer.add_prep_busy(Duration::from_nanos(prep.prep_ns));
        let t = crate::util::now();
        let slot = idx % self.hosts.len();
        let old = self.hosts[slot].install_prep(prep);
        pf.recycle(old);
        self.splice_slot(slot, idx);
        let took = t.elapsed();
        timer.add_assemble(took);
        trace::record_span(Stage::Splice, t, t + took, idx as u64);
        Ok(())
    }

    /// SPLICE host slot `slot` for plan index `i` against the current
    /// memory view.
    fn splice_slot(&mut self, slot: usize, i: usize) {
        let prev = &self.plans[i - 1];
        let host = &mut self.hosts[slot];
        self.assembler.splice(
            host,
            &self.dataset.log,
            prev,
            &self.store, // concrete enum: the scalar pass devirtualizes
            &self.nbr,
            self.mailbox.as_ref(),
            &self.gmm,
            self.cfg.pres,
            self.cfg.beta, // smoothing and correction are independent (Fig. 17)
        );
    }

    /// Pack host slot `slot` and run the train step (pack time lands in the
    /// assemble bucket, the EXEC call — PJRT or host — in execute).
    fn exec_train_slot(
        &mut self,
        slot: usize,
        timer: &mut EpochTimer,
    ) -> Result<(ArtifactSpec, Vec<Literal>)> {
        let spec = self.train_step.spec.clone();
        let n_params = self.state.len();
        let t0 = crate::util::now();
        let data_lits = self.hosts[slot].pack(&spec, 3 * n_params, 2)?;
        let lr_lit = lit_scalar(self.cfg.lr)?;
        let t_lit = lit_scalar((self.state.step + 1) as f32)?;
        let args: Vec<&Literal> = self
            .state
            .params
            .iter()
            .chain(self.state.adam_m.iter())
            .chain(self.state.adam_v.iter())
            .chain(data_lits.iter())
            .chain([&lr_lit, &t_lit])
            .collect();
        timer.add_assemble(t0.elapsed());
        let t1 = crate::util::now();
        let outputs = self.train_step.run(&args)?;
        let t_end = crate::util::now();
        timer.record_exec_inline(t1, t_end);
        trace::record_span(Stage::Exec, t1, t_end, slot as u64);
        Ok((spec, outputs))
    }

    /// Stage host slot `i % slots` as plain payloads behind the threaded
    /// parameter bank (params + Adam state, moved in — the step's outputs
    /// hand it back) and put the step in flight on a [`StreamPool`] lane
    /// (lane `i % streams`). `step_t` is the Adam step number this
    /// execution uses (the multistream loop tracks it locally so `state`
    /// stays consistent if the epoch errors mid-flight). Pack time lands
    /// in the assemble bucket, like the inline path.
    fn submit_train_slot(
        &mut self,
        streams: &StreamPool,
        i: usize,
        bank: Vec<PlainArg>,
        step_t: u64,
        timer: &mut EpochTimer,
    ) -> Result<std::sync::mpsc::Receiver<crate::pipeline::StepDone>> {
        let step = self.train_step.clone();
        let spec = &step.spec;
        let n_params = self.state.len();
        debug_assert_eq!(bank.len(), 3 * n_params, "parameter bank out of step");
        let t0 = crate::util::now();
        let mut args = bank;
        // data tensors straight from the staged host buffers (the same ABI
        // slice the inline path packs), then the trailing lr / step_t
        args.extend(self.hosts[i % self.hosts.len()].pack_plain(spec, 3 * n_params, 2)?);
        args.push(PlainArg::F32(vec![self.cfg.lr]));
        args.push(PlainArg::F32(vec![step_t as f32]));
        if self.exec_fault_at == Some(i) {
            args.pop(); // fault injection: the lane rejects the short payload
        }
        timer.add_assemble(t0.elapsed());
        Ok(streams.submit(i, args))
    }

    /// Shared post-step handling: write-back, trackers, metrics. `slot` is
    /// the host staging the step ran from. `outputs` holds the *step*
    /// outputs only; the leading ABI block — params/m/v on "train"
    /// (stripped by `absorb_outputs`), gradients on "grad" (consumed by
    /// the coordinator's Adam commit), nothing on eval kinds — determines
    /// the index offset, derived here from the spec's kind.
    fn consume_step_outputs(
        &mut self,
        spec: &ArtifactSpec,
        outputs: &[Literal],
        slot: usize,
        i: usize,
    ) -> Result<(f64, f64, f64, f64)> {
        let off = match spec.kind.as_str() {
            "train" => 3 * self.state.len(),
            "grad" => self.state.len(),
            _ => 0,
        };
        let train = matches!(spec.kind.as_str(), "train" | "grad");
        let idx = |name: &str| -> Result<usize> { Ok(spec.output_index(name)? - off) };

        fetch_f32(&outputs[idx("u_sbar")?], &mut self.sbar_scratch)?;
        let u_msg = if self.mailbox.is_some() {
            fetch_f32(&outputs[idx("u_msg")?], &mut self.msg_scratch)?;
            Some(self.msg_scratch.as_slice())
        } else {
            None
        };
        let prev = &self.plans[i - 1];
        let host = &self.hosts[slot];
        self.assembler.commit(
            host,
            &self.dataset.log,
            prev,
            &self.sbar_scratch,
            u_msg,
            &mut self.store,
            &mut self.nbr,
            self.mailbox.as_mut(),
            &mut self.gmm,
            self.cfg.pres,
        );

        fetch_f32(&outputs[idx("pos_logit")?], &mut self.logit_scratch[0])?;
        fetch_f32(&outputs[idx("neg_logit")?], &mut self.logit_scratch[1])?;
        if train {
            // NaN-logit telemetry: cheap linear scan over scratch already
            // in cache, surfaced per epoch in EpochReport
            let nans = self.logit_scratch[0]
                .iter()
                .chain(self.logit_scratch[1].iter())
                .filter(|v| !v.is_finite())
                .count() as u64;
            self.nan_logits += nans;
        }
        let ap = link_ap(&self.logit_scratch[0], &self.logit_scratch[1]);
        let loss = fetch_scalar(&outputs[idx("loss")?])? as f64;
        let bce = fetch_scalar(&outputs[idx("bce")?])? as f64;
        let coh = fetch_scalar(&outputs[idx("coherence")?])? as f64;
        Ok((loss, bce, coh, ap))
    }

    /// Evaluate the span [lo, hi) of event indices in one pass. Memory
    /// keeps evolving (the standard TGN protocol). Returns per-event
    /// (event index, pos logit, neg logit) plus collected (h_src, label)
    /// rows for node classification. Always sequential: eval is not on the
    /// throughput-critical path and reuses host slot 0.
    fn eval_range(
        &mut self,
        lo: usize,
        hi: usize,
        collect_embeddings: bool,
    ) -> Result<(Vec<(usize, f32, f32)>, Vec<(Vec<f32>, f32)>)> {
        let spec = self.eval_step.spec.clone();
        let b = self.cfg.batch_size;
        let d_emb = self.assembler.dims.d_emb;
        let mut logits = Vec::new();
        let mut rows = Vec::new();
        let mut h_scratch = vec![0.0f32; b * d_emb];

        // any plan overlapping [lo, hi) participates; per-event logits are
        // filtered below so only in-range events are scored (at large b a
        // small split may not contain a single fully-enclosed batch)
        let indices: Vec<usize> = (1..self.plans.len())
            .filter(|&i| self.plans[i].range.end > lo && self.plans[i].range.start < hi)
            .collect();
        for i in indices {
            let mut negatives = vec![0u32; b];
            // fixed eval seed: comparable across runs/configs
            let mut neg_rng = Pcg32::new(0xE7A1_5EED ^ i as u64);
            self.neg_sampler.sample_batch(
                &self.dataset.log,
                self.plans[i].range.clone(),
                &mut neg_rng,
                &mut negatives,
            );
            {
                let (prev, cur) = (&self.plans[i - 1], &self.plans[i]);
                self.assembler.fill(
                    &mut self.hosts[0],
                    &self.dataset.log,
                    prev,
                    cur,
                    &negatives,
                    &self.store,
                    &self.nbr,
                    self.mailbox.as_ref(),
                    &self.gmm,
                    self.cfg.pres,
                    0.0, // no loss at eval time
                );
            }
            let data_lits = self.hosts[0].pack(&spec, self.state.len(), 0)?;
            let args: Vec<&Literal> =
                self.state.params.iter().chain(data_lits.iter()).collect();
            let outputs = self.eval_step.run(&args)?;
            let (_, _, _, _) = self.consume_step_outputs(&spec, &outputs, 0, i)?;
            for (j, ev_i) in self.plans[i].range.clone().enumerate() {
                if ev_i >= lo && ev_i < hi {
                    logits.push((ev_i, self.logit_scratch[0][j], self.logit_scratch[1][j]));
                }
            }

            if collect_embeddings {
                fetch_f32(&outputs[spec.output_index("h_src")?], &mut h_scratch)?;
                for (j, ev_i) in self.plans[i].range.clone().enumerate() {
                    let label = self.dataset.log.events[ev_i].label;
                    if label >= 0 && ev_i >= lo && ev_i < hi {
                        rows.push((
                            h_scratch[j * d_emb..(j + 1) * d_emb].to_vec(),
                            label as f32,
                        ));
                    }
                }
            }
        }
        Ok((logits, rows))
    }

    fn ap_of(logits: &[(usize, f32, f32)], lo: usize, hi: usize) -> f64 {
        let pos: Vec<f32> = logits
            .iter()
            .filter(|(i, _, _)| *i >= lo && *i < hi)
            .map(|(_, p, _)| *p)
            .collect();
        let neg: Vec<f32> = logits
            .iter()
            .filter(|(i, _, _)| *i >= lo && *i < hi)
            .map(|(_, _, n)| *n)
            .collect();
        link_ap(&pos, &neg)
    }

    /// Validation AP (continues memory from the training state; restores it
    /// afterwards so training can proceed).
    pub fn eval_val(&mut self) -> Result<f64> {
        let snap = self.store.snapshot();
        let nbr_snap = self.nbr.clone();
        let mb_snap = self.mailbox.clone();
        let (lo, hi) = (self.dataset.split.train_end, self.dataset.split.val_end);
        let (logits, _) = self.eval_range(lo, hi, false)?;
        self.store.restore(&snap);
        self.nbr = nbr_snap;
        self.mailbox = mb_snap;
        Ok(Self::ap_of(&logits, lo, hi))
    }

    /// Test AP + collected (embedding, label) rows for node classification.
    /// Single pass over val + test so memory is warm at the test boundary
    /// and no boundary-straddling batch is processed twice.
    pub fn eval_test(&mut self, collect: bool) -> Result<(f64, Vec<(Vec<f32>, f32)>)> {
        let (logits, rows) =
            self.eval_range(self.dataset.split.train_end, self.dataset.log.len(), collect)?;
        let ap = Self::ap_of(&logits, self.dataset.split.val_end, self.dataset.log.len());
        Ok((ap, rows))
    }

    /// Full run: epochs of training (+ periodic val), final val/test eval,
    /// node-classification AUC.
    pub fn run(&mut self) -> Result<RunReport> {
        let mut epochs = Vec::new();
        let mut best_val = f64::NEG_INFINITY;
        let t0 = crate::util::now();
        for e in 0..self.cfg.epochs {
            let mut report = self.train_epoch(e)?;
            let evaluate = self.cfg.eval_every > 0 && (e + 1) % self.cfg.eval_every == 0;
            if evaluate || e + 1 == self.cfg.epochs {
                report.val_ap = self.eval_val()?;
                best_val = best_val.max(report.val_ap);
            }
            epochs.push(report);
        }
        let total_train_secs = t0.elapsed().as_secs_f64();
        let (test_ap, rows) = self.eval_test(true)?;
        let test_auc = crate::eval::nodeclf::train_and_auc(&self.engine, &rows, self.cfg.seed)?;
        let mean_epoch_secs =
            crate::util::stats::mean(&epochs.iter().map(|e| e.epoch_secs).collect::<Vec<_>>());
        Ok(RunReport {
            config: self.cfg.clone(),
            best_val_ap: best_val.max(epochs.last().map(|e| e.val_ap).unwrap_or(0.0)),
            test_ap,
            test_auc,
            epochs,
            total_train_secs,
            mean_epoch_secs,
            iteration_ap: self.iteration_ap.clone(),
            coordinator_bytes: self.memory_bytes(),
        })
    }

    /// Coordinator-side live bytes (Fig. 19 accounting).
    pub fn memory_bytes(&self) -> usize {
        self.store.bytes()
            + self.nbr.bytes()
            + self.gmm.bytes()
            + self.mailbox.as_ref().map_or(0, |m| m.bytes())
    }

    /// Mean pending-event statistics across training batches (Def. 2).
    pub fn pending_summary(&self) -> (f64, f64) {
        let n = self.train_plan_count().max(1);
        let mut frac = 0.0;
        let mut pairs = 0.0;
        for p in self.plans.iter().take(n) {
            frac += p.stats.pending_events as f64 / p.batch_size() as f64;
            pairs += p.stats.pending_pairs as f64 / p.batch_size() as f64;
        }
        (frac / n as f64, pairs / n as f64)
    }

    pub fn gamma(&self) -> f32 {
        self.state.gamma().unwrap_or(f32::NAN)
    }
}

/// Events actually executed in one training epoch: the plan ranges for
/// indices `1..n_train` (plan 0 is never predicted). Counting real range
/// lengths — not `steps * batch_size` — keeps `events_per_sec` honest
/// when a partition is ragged (a tail plan shorter than `batch_size`).
fn executed_events(plans: &[BatchPlan], n_train: usize) -> usize {
    plans
        .iter()
        .take(n_train)
        .skip(1)
        .map(|p| p.range.len())
        .sum()
}

/// Deep-copy a literal (the xla crate exposes no Clone).
pub fn clone_literal(lit: &Literal) -> Result<Literal> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let n = lit.element_count();
    match lit.ty()? {
        xla::ElementType::F32 => {
            let mut host = vec![0.0f32; n];
            lit.copy_raw_to(&mut host)?;
            crate::runtime::engine::lit_f32(&host, &dims)
        }
        xla::ElementType::S32 => {
            let mut host = vec![0i32; n];
            lit.copy_raw_to(&mut host)?;
            crate::runtime::engine::lit_i32(&host, &dims)
        }
        other => anyhow::bail!("clone_literal: unsupported type {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batching::partition;
    use crate::datagen;

    #[test]
    fn executed_events_counts_ragged_tails_honestly() {
        // 3000 events in batches of 64: 46 full plans + a ragged 56-event
        // tail. steps * batch_size would claim (47 - 1) * 64 = 2944 events;
        // the real executed count (plans 1..47) is 3000 - 64 = 2936.
        let ds = datagen::generate(&datagen::tiny_profile(), 5);
        let plans: Vec<BatchPlan> = partition(0..ds.log.len(), 64)
            .into_iter()
            .map(|r| BatchPlan::build(&ds.log, r))
            .collect();
        assert_eq!(ds.log.len(), 3000, "tiny profile size changed — update the test");
        assert_eq!(plans.len(), 47);
        let n_train = plans.len();
        let actual = executed_events(&plans, n_train);
        assert_eq!(actual, 3000 - 64);
        assert_ne!(
            actual,
            (n_train - 1) * 64,
            "ragged tail must not be rounded up to a full batch"
        );
        // no executable plan -> no events
        assert_eq!(executed_events(&plans, 0), 0);
        assert_eq!(executed_events(&plans, 1), 0);
    }
}
