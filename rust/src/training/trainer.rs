//! The epoch/batch loop (paper Algorithm 1 & 2) + evaluation.

use std::path::Path;
use std::rc::Rc;

use anyhow::{Context, Result};
use xla::Literal;

use crate::batching::{partition, BatchPlan};
use crate::config::ExperimentConfig;
use crate::datagen;
use crate::graph::Dataset;
use crate::memory::{GmmTrackers, Mailbox, MemoryStore};
use crate::metrics::ranking::link_ap;
use crate::metrics::EpochTimer;
use crate::model::ModelState;
use crate::runtime::engine::{fetch_f32, fetch_scalar, lit_scalar};
use crate::runtime::{Engine, Step};
use crate::sampler::{NegativeSampler, NeighborIndex};
use crate::training::{Assembler, HostBatch};
use crate::util::rng::Pcg32;

/// Per-epoch record (drives Fig. 5/14/16/17 and Table 1 timing).
#[derive(Clone, Debug)]
pub struct EpochReport {
    pub epoch: usize,
    pub train_loss: f64,
    pub train_bce: f64,
    pub train_ap: f64,
    pub coherence: f64,
    pub val_ap: f64,
    pub epoch_secs: f64,
    pub assemble_secs: f64,
    pub execute_secs: f64,
    pub writeback_secs: f64,
    pub events_per_sec: f64,
    pub gamma: f32,
}

/// Whole-run summary.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub config: ExperimentConfig,
    pub epochs: Vec<EpochReport>,
    pub best_val_ap: f64,
    pub test_ap: f64,
    pub test_auc: f64,
    pub total_train_secs: f64,
    pub mean_epoch_secs: f64,
    /// (iteration, train batch AP) samples for statistical-efficiency plots.
    pub iteration_ap: Vec<(usize, f64)>,
    /// Coordinator-side live bytes (Fig. 19).
    pub coordinator_bytes: usize,
}

/// The training coordinator for one (dataset, model, batch, mode) run.
pub struct Trainer {
    pub cfg: ExperimentConfig,
    pub engine: Rc<Engine>,
    pub dataset: Rc<Dataset>,
    state: ModelState,
    store: MemoryStore,
    nbr: NeighborIndex,
    mailbox: Option<Mailbox>,
    gmm: GmmTrackers,
    assembler: Assembler,
    host: HostBatch,
    train_step: Rc<Step>,
    eval_step: Rc<Step>,
    plans: Vec<BatchPlan>,
    neg_sampler: NegativeSampler,
    rng: Pcg32,
    // reusable output scratch
    sbar_scratch: Vec<f32>,
    msg_scratch: Vec<f32>,
    logit_scratch: [Vec<f32>; 2],
    pub iteration_ap: Vec<(usize, f64)>,
    iterations: usize,
}

impl Trainer {
    /// Build everything from a config: dataset (generated deterministically
    /// from the seed), engine, compiled steps, substrates.
    pub fn from_config(cfg: &ExperimentConfig) -> Result<Trainer> {
        let engine = Rc::new(Engine::new(Path::new(&cfg.artifacts_dir))?);
        let dataset = Rc::new(Self::make_dataset(cfg)?);
        Self::with_shared(cfg, engine, dataset)
    }

    /// Variant sharing an engine + dataset across runs (sweeps, figures).
    pub fn with_shared(
        cfg: &ExperimentConfig,
        engine: Rc<Engine>,
        dataset: Rc<Dataset>,
    ) -> Result<Trainer> {
        cfg.validate()?;
        let dims = engine.manifest().dims;
        let b = cfg.batch_size;
        let train_step = engine
            .step(&cfg.model, b, "train")
            .context("loading train step")?;
        let eval_step = engine.step(&cfg.model, b, "eval")?;
        let state = ModelState::init(&engine, &cfg.model, cfg.seed)?;
        let n_nodes = dataset.log.num_nodes;
        let mailbox = (cfg.model == "apan").then(|| Mailbox::new(n_nodes, dims.k_nbr, dims.d_msg));
        // plans are pure functions of (log, b): compute once, reuse across
        // epochs (cfg.prefetch=false rebuilds per epoch for the ablation)
        let plans = Self::build_plans(&dataset, b);
        let neg_sampler = NegativeSampler::new(&dataset.log);
        let u = 2 * b;
        Ok(Trainer {
            cfg: cfg.clone(),
            state,
            store: MemoryStore::new(n_nodes, dims.d_mem),
            nbr: NeighborIndex::new(n_nodes, dims.k_nbr),
            mailbox,
            gmm: GmmTrackers::new(n_nodes, dims.d_mem, cfg.anchor_fraction, cfg.seed),
            assembler: Assembler::new(dims),
            host: HostBatch::new(&cfg.model, b, dims),
            train_step,
            eval_step,
            plans,
            neg_sampler,
            rng: Pcg32::new(cfg.seed ^ 0x7E57),
            sbar_scratch: vec![0.0; u * dims.d_mem],
            msg_scratch: vec![0.0; u * dims.d_msg],
            logit_scratch: [vec![0.0; b], vec![0.0; b]],
            iteration_ap: Vec::new(),
            iterations: 0,
            engine,
            dataset,
        })
    }

    pub fn make_dataset(cfg: &ExperimentConfig) -> Result<Dataset> {
        let mut profile = if cfg.dataset == "tiny" {
            datagen::tiny_profile()
        } else {
            datagen::profile(&cfg.dataset)
                .ok_or_else(|| anyhow::anyhow!("unknown dataset '{}'", cfg.dataset))?
        };
        profile.n_events = ((profile.n_events as f32 * cfg.data_scale) as usize).max(64);
        Ok(datagen::generate(&profile, cfg.seed))
    }

    fn build_plans(dataset: &Dataset, b: usize) -> Vec<BatchPlan> {
        partition(0..dataset.log.len(), b)
            .into_iter()
            .map(|r| BatchPlan::build(&dataset.log, r))
            .collect()
    }

    /// Plans whose *predicted* batch lies inside the training split.
    fn train_plan_count(&self) -> usize {
        self.plans
            .iter()
            .filter(|p| p.range.end <= self.dataset.split.train_end)
            .count()
    }

    fn reset_epoch_state(&mut self) {
        self.store.reset();
        self.nbr.clear();
        if let Some(mb) = &mut self.mailbox {
            mb.clear();
        }
        self.gmm.reset();
        if !self.cfg.prefetch {
            // ablation: rebuild plans every epoch instead of reusing
            self.plans = Self::build_plans(&self.dataset, self.cfg.batch_size);
        }
    }

    /// One training epoch (Algorithm 2 body). Returns the epoch report with
    /// val_ap = NaN (the caller decides whether to evaluate).
    pub fn train_epoch(&mut self, epoch: usize) -> Result<EpochReport> {
        self.reset_epoch_state();
        let n_train = self.train_plan_count();
        let mut timer = EpochTimer::default();
        timer.start_epoch();
        let mut losses = Vec::with_capacity(n_train);
        let mut bces = Vec::with_capacity(n_train);
        let mut cohs = Vec::with_capacity(n_train);
        let mut aps = Vec::with_capacity(n_train);

        for i in 1..n_train {
            let (loss, bce, coh, ap) = self.run_train_iteration(i, epoch, &mut timer)?;
            losses.push(loss);
            bces.push(bce);
            cohs.push(coh);
            aps.push(ap);
            self.iterations += 1;
            self.iteration_ap.push((self.iterations, ap));
        }
        timer.steps = n_train.saturating_sub(1);
        timer.finish_epoch();

        Ok(EpochReport {
            epoch,
            train_loss: crate::util::stats::mean(&losses),
            train_bce: crate::util::stats::mean(&bces),
            train_ap: crate::util::stats::mean(&aps),
            coherence: crate::util::stats::mean(&cohs),
            val_ap: f64::NAN,
            epoch_secs: timer.total.as_secs_f64(),
            assemble_secs: timer.assemble.as_secs_f64(),
            execute_secs: timer.execute.as_secs_f64(),
            writeback_secs: timer.writeback.as_secs_f64(),
            events_per_sec: timer.events_per_sec(n_train.saturating_sub(1) * self.cfg.batch_size),
            gamma: self.state.gamma().unwrap_or(f32::NAN),
        })
    }

    fn run_train_iteration(
        &mut self,
        i: usize,
        epoch: usize,
        timer: &mut EpochTimer,
    ) -> Result<(f64, f64, f64, f64)> {
        let b = self.cfg.batch_size;
        let spec = self.train_step.spec.clone();
        let n_params = self.state.len();

        // -------- assemble
        let t0 = std::time::Instant::now();
        let mut negatives = vec![0u32; b];
        let mut neg_rng = self.rng.split((epoch * 1_000_003 + i) as u64);
        self.neg_sampler.sample_batch(
            &self.dataset.log,
            self.plans[i].range.clone(),
            &mut neg_rng,
            &mut negatives,
        );
        let (prev, cur) = (&self.plans[i - 1], &self.plans[i]);
        self.assembler.fill(
            &mut self.host,
            &self.dataset.log,
            prev,
            cur,
            &negatives,
            &self.store,
            &self.nbr,
            self.mailbox.as_ref(),
            &self.gmm,
            self.cfg.pres,
            self.cfg.beta, // smoothing and correction are independent (Fig. 17)
        );
        let data_lits = self.host.pack(&spec, 3 * n_params, 2)?;
        let lr_lit = lit_scalar(self.cfg.lr)?;
        let t_lit = lit_scalar((self.state.step + 1) as f32)?;
        let args: Vec<&Literal> = self
            .state
            .params
            .iter()
            .chain(self.state.adam_m.iter())
            .chain(self.state.adam_v.iter())
            .chain(data_lits.iter())
            .chain([&lr_lit, &t_lit])
            .collect();
        timer.assemble += t0.elapsed();

        // -------- execute
        let t1 = std::time::Instant::now();
        let mut outputs = self.train_step.run(&args)?;
        timer.execute += t1.elapsed();

        // -------- write-back + metrics
        let t2 = std::time::Instant::now();
        self.state.absorb_outputs(&mut outputs);
        let (loss, bce, coh, ap) = self.consume_step_outputs(&spec, &outputs, i, true)?;
        timer.writeback += t2.elapsed();
        Ok((loss, bce, coh, ap))
    }

    /// Shared post-step handling: write-back, trackers, metrics.
    fn consume_step_outputs(
        &mut self,
        spec: &crate::runtime::ArtifactSpec,
        outputs: &[Literal],
        i: usize,
        train: bool,
    ) -> Result<(f64, f64, f64, f64)> {
        // output indices are relative to the *step* outputs (train outputs
        // had params/m/v stripped by absorb_outputs)
        let off = if train { 3 * self.state.len() } else { 0 };
        let idx = |name: &str| -> Result<usize> { Ok(spec.output_index(name)? - off) };

        fetch_f32(&outputs[idx("u_sbar")?], &mut self.sbar_scratch)?;
        let u_msg = if self.mailbox.is_some() {
            fetch_f32(&outputs[idx("u_msg")?], &mut self.msg_scratch)?;
            Some(self.msg_scratch.as_slice())
        } else {
            None
        };
        let prev = &self.plans[i - 1];
        self.assembler.commit(
            &self.host,
            &self.dataset.log,
            prev,
            &self.sbar_scratch,
            u_msg,
            &mut self.store,
            &mut self.nbr,
            self.mailbox.as_mut(),
            &mut self.gmm,
            self.cfg.pres,
        );

        fetch_f32(&outputs[idx("pos_logit")?], &mut self.logit_scratch[0])?;
        fetch_f32(&outputs[idx("neg_logit")?], &mut self.logit_scratch[1])?;
        let ap = link_ap(&self.logit_scratch[0], &self.logit_scratch[1]);
        let loss = fetch_scalar(&outputs[idx("loss")?])? as f64;
        let bce = fetch_scalar(&outputs[idx("bce")?])? as f64;
        let coh = fetch_scalar(&outputs[idx("coherence")?])? as f64;
        Ok((loss, bce, coh, ap))
    }

    /// Evaluate the span [lo, hi) of event indices in one pass. Memory
    /// keeps evolving (the standard TGN protocol). Returns per-event
    /// (event index, pos logit, neg logit) plus collected (h_src, label)
    /// rows for node classification.
    fn eval_range(
        &mut self,
        lo: usize,
        hi: usize,
        collect_embeddings: bool,
    ) -> Result<(Vec<(usize, f32, f32)>, Vec<(Vec<f32>, f32)>)> {
        let spec = self.eval_step.spec.clone();
        let b = self.cfg.batch_size;
        let d_emb = self.assembler.dims.d_emb;
        let mut logits = Vec::new();
        let mut rows = Vec::new();
        let mut h_scratch = vec![0.0f32; b * d_emb];

        // any plan overlapping [lo, hi) participates; per-event logits are
        // filtered below so only in-range events are scored (at large b a
        // small split may not contain a single fully-enclosed batch)
        let indices: Vec<usize> = (1..self.plans.len())
            .filter(|&i| self.plans[i].range.end > lo && self.plans[i].range.start < hi)
            .collect();
        for i in indices {
            let mut negatives = vec![0u32; b];
            // fixed eval seed: comparable across runs/configs
            let mut neg_rng = Pcg32::new(0xE7A1_5EED ^ i as u64);
            self.neg_sampler.sample_batch(
                &self.dataset.log,
                self.plans[i].range.clone(),
                &mut neg_rng,
                &mut negatives,
            );
            let (prev, cur) = (&self.plans[i - 1], &self.plans[i]);
            self.assembler.fill(
                &mut self.host,
                &self.dataset.log,
                prev,
                cur,
                &negatives,
                &self.store,
                &self.nbr,
                self.mailbox.as_ref(),
                &self.gmm,
                self.cfg.pres,
                0.0, // no loss at eval time
            );
            let data_lits = self.host.pack(&spec, self.state.len(), 0)?;
            let args: Vec<&Literal> =
                self.state.params.iter().chain(data_lits.iter()).collect();
            let outputs = self.eval_step.run(&args)?;
            let (_, _, _, _) = self.consume_step_outputs(&spec, &outputs, i, false)?;
            for (j, ev_i) in self.plans[i].range.clone().enumerate() {
                if ev_i >= lo && ev_i < hi {
                    logits.push((ev_i, self.logit_scratch[0][j], self.logit_scratch[1][j]));
                }
            }

            if collect_embeddings {
                fetch_f32(&outputs[spec.output_index("h_src")?], &mut h_scratch)?;
                for (j, ev_i) in self.plans[i].range.clone().enumerate() {
                    let label = self.dataset.log.events[ev_i].label;
                    if label >= 0 && ev_i >= lo && ev_i < hi {
                        rows.push((
                            h_scratch[j * d_emb..(j + 1) * d_emb].to_vec(),
                            label as f32,
                        ));
                    }
                }
            }
        }
        Ok((logits, rows))
    }

    fn ap_of(logits: &[(usize, f32, f32)], lo: usize, hi: usize) -> f64 {
        let pos: Vec<f32> = logits
            .iter()
            .filter(|(i, _, _)| *i >= lo && *i < hi)
            .map(|(_, p, _)| *p)
            .collect();
        let neg: Vec<f32> = logits
            .iter()
            .filter(|(i, _, _)| *i >= lo && *i < hi)
            .map(|(_, _, n)| *n)
            .collect();
        link_ap(&pos, &neg)
    }

    /// Validation AP (continues memory from the training state; restores it
    /// afterwards so training can proceed).
    pub fn eval_val(&mut self) -> Result<f64> {
        let snap = self.store.snapshot();
        let nbr_snap = self.nbr.clone();
        let mb_snap = self.mailbox.clone();
        let (lo, hi) = (self.dataset.split.train_end, self.dataset.split.val_end);
        let (logits, _) = self.eval_range(lo, hi, false)?;
        self.store.restore(&snap);
        self.nbr = nbr_snap;
        self.mailbox = mb_snap;
        Ok(Self::ap_of(&logits, lo, hi))
    }

    /// Test AP + collected (embedding, label) rows for node classification.
    /// Single pass over val + test so memory is warm at the test boundary
    /// and no boundary-straddling batch is processed twice.
    pub fn eval_test(&mut self, collect: bool) -> Result<(f64, Vec<(Vec<f32>, f32)>)> {
        let (logits, rows) =
            self.eval_range(self.dataset.split.train_end, self.dataset.log.len(), collect)?;
        let ap = Self::ap_of(&logits, self.dataset.split.val_end, self.dataset.log.len());
        Ok((ap, rows))
    }

    /// Full run: epochs of training (+ periodic val), final val/test eval,
    /// node-classification AUC.
    pub fn run(&mut self) -> Result<RunReport> {
        let mut epochs = Vec::new();
        let mut best_val = f64::NEG_INFINITY;
        let t0 = std::time::Instant::now();
        for e in 0..self.cfg.epochs {
            let mut report = self.train_epoch(e)?;
            let evaluate = self.cfg.eval_every > 0 && (e + 1) % self.cfg.eval_every == 0;
            if evaluate || e + 1 == self.cfg.epochs {
                report.val_ap = self.eval_val()?;
                best_val = best_val.max(report.val_ap);
            }
            epochs.push(report);
        }
        let total_train_secs = t0.elapsed().as_secs_f64();
        let (test_ap, rows) = self.eval_test(true)?;
        let test_auc = crate::eval::nodeclf::train_and_auc(&self.engine, &rows, self.cfg.seed)?;
        let mean_epoch_secs =
            crate::util::stats::mean(&epochs.iter().map(|e| e.epoch_secs).collect::<Vec<_>>());
        Ok(RunReport {
            config: self.cfg.clone(),
            best_val_ap: best_val.max(epochs.last().map(|e| e.val_ap).unwrap_or(0.0)),
            test_ap,
            test_auc,
            epochs,
            total_train_secs,
            mean_epoch_secs,
            iteration_ap: self.iteration_ap.clone(),
            coordinator_bytes: self.memory_bytes(),
        })
    }

    /// Coordinator-side live bytes (Fig. 19 accounting).
    pub fn memory_bytes(&self) -> usize {
        self.store.bytes()
            + self.nbr.bytes()
            + self.gmm.bytes()
            + self.mailbox.as_ref().map_or(0, |m| m.bytes())
    }

    /// Mean pending-event statistics across training batches (Def. 2).
    pub fn pending_summary(&self) -> (f64, f64) {
        let n = self.train_plan_count().max(1);
        let mut frac = 0.0;
        let mut pairs = 0.0;
        for p in self.plans.iter().take(n) {
            frac += p.stats.pending_events as f64 / p.batch_size() as f64;
            pairs += p.stats.pending_pairs as f64 / p.batch_size() as f64;
        }
        (frac / n as f64, pairs / n as f64)
    }

    pub fn gamma(&self) -> f32 {
        self.state.gamma().unwrap_or(f32::NAN)
    }
}

/// Deep-copy a literal (the xla crate exposes no Clone).
pub fn clone_literal(lit: &Literal) -> Result<Literal> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let n = lit.element_count();
    match lit.ty()? {
        xla::ElementType::F32 => {
            let mut host = vec![0.0f32; n];
            lit.copy_raw_to(&mut host)?;
            crate::runtime::engine::lit_f32(&host, &dims)
        }
        xla::ElementType::S32 => {
            let mut host = vec![0i32; n];
            lit.copy_raw_to(&mut host)?;
            crate::runtime::engine::lit_i32(&host, &dims)
        }
        other => anyhow::bail!("clone_literal: unsupported type {other:?}"),
    }
}
