//! The training orchestrator: Algorithm 1 (STANDARD) / Algorithm 2 (PRES)
//! from the paper, driving the AOT-compiled step executables.
//!
//! One iteration = one PJRT call: the previous temporal batch's events
//! update (and PRES-correct) the memory of their vertices in-graph, the
//! current batch is predicted through the lag-one splice, and Adam updates
//! the parameters — see python/compile/model.py for the fused step and
//! DESIGN.md §1 for the dataflow diagram.
//!
//! Iterations are staged as PREP / SPLICE / EXEC / WRITEBACK and, by
//! default, pipelined: a background thread preps batch `t+1..t+depth`
//! while batch `t` executes (see [`crate::pipeline`] for the stage
//! diagram, staleness semantics, and the equivalence guarantee).

pub mod assembler;
pub mod trainer;

pub use assembler::{Assembler, HostBatch};
pub use trainer::{EpochReport, RunReport, Trainer};
