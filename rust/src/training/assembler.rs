//! Batch assembly: gathers memory rows, neighbor tensors, PRES predictions
//! and lag-one match indices into reusable host buffers, then packs them as
//! step inputs in manifest ABI order.
//!
//! This is the L3 hot path: every buffer is allocated once per trainer and
//! reused across steps (§Perf: zero per-step allocation in the assembler).
//!
//! Since the pipelined runtime (see [`crate::pipeline`]) the assembly is
//! split along the Send boundary:
//!
//! * the **PREP half** ([`PrepBatch`], owned as `HostBatch::prep`) holds
//!   every tensor that is pure in `(dataset, plans, seed)` — it can be
//!   filled by the background prefetch thread and swapped in wholesale via
//!   [`HostBatch::install_prep`];
//! * the **SPLICE half** (the remaining `HostBatch` fields) holds every
//!   tensor gathered from the mutable substrates (memory store, neighbor
//!   index, mailbox, GMM) and is filled by [`Assembler::splice`] on the
//!   coordinator thread.
//!
//! [`Assembler::fill`] = PREP + SPLICE in place, the sequential
//! convenience used by the eval path and the `depth = 0` trainer loop.

use anyhow::{bail, Result};
use xla::Literal;

use crate::batching::BatchPlan;
use crate::graph::EventLog;
use crate::memory::gmm::Role;
use crate::memory::{GmmTrackers, Mailbox, MemoryBackend};
use crate::pipeline::prep::{fill_prep_from, PrepBatch};
use crate::pipeline::stream::PlainArg;
use crate::runtime::engine::{lit_f32, lit_i32};
use crate::runtime::{ArtifactSpec, Dims, TensorSpec};
use crate::sampler::{NeighborEntry, NeighborIndex};

/// Reusable host-side staging for one step's data inputs.
pub struct HostBatch {
    pub b: usize,
    pub model: String,
    dims: Dims,
    /// The Send-able pure half (negatives, edge features, match indices,
    /// event times). Swappable with prefetched batches.
    pub prep: PrepBatch,
    // ---- splice half: update rows (U = 2b), substrate-dependent
    pub u_self_mem: Vec<f32>,
    pub u_other_mem: Vec<f32>,
    pub u_dt: Vec<f32>,
    pub u_pred: Vec<f32>,
    pub u_cmask: Vec<f32>,
    // ---- splice half: current batch
    pub c_mem: [Vec<f32>; 3], // src, dst, neg
    pub c_dt: [Vec<f32>; 3],
    // ---- splice half: neighbors (tgn: mem+efeat; apan: mail) per role
    pub n_key: [Vec<f32>; 3],   // tgn: n_mem [b*K*d]; apan: n_mail [b*K*dm]
    pub n_efeat: [Vec<f32>; 3], // tgn only
    pub n_dt: [Vec<f32>; 3],
    pub n_mask: [Vec<f32>; 3],
    // scalars
    pub beta: f32,
    pub pres_on: f32,
    // scratch
    nbr_scratch: Vec<NeighborEntry>,
}

const ROLES: [&str; 3] = ["src", "dst", "neg"];

/// Borrowed view of one staged input's host payload (dtype-tagged).
enum HostSlice<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl HostBatch {
    pub fn new(model: &str, b: usize, dims: Dims) -> HostBatch {
        let u = 2 * b;
        let (d, de, dm, k) = (dims.d_mem, dims.d_edge, dims.d_msg, dims.k_nbr);
        let key_w = if model == "apan" { dm } else { d };
        HostBatch {
            b,
            model: model.to_string(),
            dims,
            prep: PrepBatch::new(b, de),
            u_self_mem: vec![0.0; u * d],
            u_other_mem: vec![0.0; u * d],
            u_dt: vec![0.0; u],
            u_pred: vec![0.0; u * d],
            u_cmask: vec![0.0; u],
            c_mem: std::array::from_fn(|_| vec![0.0; b * d]),
            c_dt: std::array::from_fn(|_| vec![0.0; b]),
            n_key: std::array::from_fn(|_| vec![0.0; b * k * key_w]),
            n_efeat: std::array::from_fn(|_| vec![0.0; b * k * de]),
            n_dt: std::array::from_fn(|_| vec![0.0; b * k]),
            n_mask: std::array::from_fn(|_| vec![0.0; b * k]),
            beta: 0.0,
            pres_on: 0.0,
            nbr_scratch: vec![NeighborEntry::default(); k],
        }
    }

    /// Swap in a (prefetched) PREP half, returning the old one so the
    /// caller can recycle its buffers back to the worker.
    pub fn install_prep(&mut self, prep: PrepBatch) -> PrepBatch {
        debug_assert_eq!(prep.batch_size(), self.b);
        std::mem::replace(&mut self.prep, prep)
    }

    /// The host slice backing one manifest data input by name — the single
    /// source of truth behind both [`HostBatch::literal_for`] (inline
    /// EXEC) and [`HostBatch::plain_for`] (stream-lane submission).
    fn slice_for(&self, name: &str) -> Result<HostSlice<'_>> {
        if let Some(role_field) = name.strip_prefix("n_") {
            // n_{role}_{field}
            let (role, field) = role_field
                .split_once('_')
                .ok_or_else(|| anyhow::anyhow!("bad neighbor input '{name}'"))?;
            let ri = ROLES
                .iter()
                .position(|r| *r == role)
                .ok_or_else(|| anyhow::anyhow!("bad role in '{name}'"))?;
            let data = match field {
                "mem" | "mail" => &self.n_key[ri],
                "efeat" => &self.n_efeat[ri],
                "dt" => &self.n_dt[ri],
                "mask" => &self.n_mask[ri],
                _ => bail!("unknown neighbor field '{field}'"),
            };
            return Ok(HostSlice::F32(data));
        }
        if let Some(rest) = name.strip_prefix("c_") {
            let (role, field) = rest
                .split_once('_')
                .ok_or_else(|| anyhow::anyhow!("bad current input '{name}'"))?;
            let ri = ROLES
                .iter()
                .position(|r| *r == role)
                .ok_or_else(|| anyhow::anyhow!("bad role in '{name}'"))?;
            return match field {
                "mem" => Ok(HostSlice::F32(&self.c_mem[ri])),
                "match" => Ok(HostSlice::I32(&self.prep.c_match[ri])),
                "dt" => Ok(HostSlice::F32(&self.c_dt[ri])),
                _ => bail!("unknown current field '{field}'"),
            };
        }
        Ok(match name {
            "u_self_mem" => HostSlice::F32(&self.u_self_mem),
            "u_other_mem" => HostSlice::F32(&self.u_other_mem),
            "u_efeat" => HostSlice::F32(&self.prep.u_efeat),
            "u_dt" => HostSlice::F32(&self.u_dt),
            "u_pred" => HostSlice::F32(&self.u_pred),
            "u_wmask" => HostSlice::F32(&self.prep.u_wmask),
            "u_cmask" => HostSlice::F32(&self.u_cmask),
            "beta" => HostSlice::F32(std::slice::from_ref(&self.beta)),
            "pres_on" => HostSlice::F32(std::slice::from_ref(&self.pres_on)),
            _ => bail!("unknown data input '{name}'"),
        })
    }

    /// Produce the literal for one manifest data input by name.
    pub fn literal_for(&self, spec: &TensorSpec) -> Result<Literal> {
        match self.slice_for(&spec.name)? {
            HostSlice::F32(data) => lit_f32(data, &spec.shape),
            HostSlice::I32(data) => lit_i32(data, &spec.shape),
        }
    }

    /// The same payload as [`HostBatch::literal_for`], as an owned plain
    /// buffer for submission to an EXEC stream lane (`pipeline/stream.rs`
    /// keeps `xla::Literal` out of the cross-thread channel types).
    pub fn plain_for(&self, spec: &TensorSpec) -> Result<PlainArg> {
        Ok(match self.slice_for(&spec.name)? {
            HostSlice::F32(data) => PlainArg::F32(data.to_vec()),
            HostSlice::I32(data) => PlainArg::I32(data.to_vec()),
        })
    }

    /// Pack all data inputs of `spec` (after `skip` leading param/opt slots,
    /// before any trailing scalars the caller appends) in ABI order.
    pub fn pack(&self, spec: &ArtifactSpec, skip: usize, trailing: usize) -> Result<Vec<Literal>> {
        let end = spec.inputs.len() - trailing;
        spec.inputs[skip..end]
            .iter()
            .map(|t| self.literal_for(t))
            .collect()
    }

    /// [`HostBatch::pack`] for an EXEC stream-lane submission: the same
    /// ABI slice, as owned plain payloads.
    pub fn pack_plain(
        &self,
        spec: &ArtifactSpec,
        skip: usize,
        trailing: usize,
    ) -> Result<Vec<PlainArg>> {
        let end = spec.inputs.len() - trailing;
        spec.inputs[skip..end]
            .iter()
            .map(|t| self.plain_for(t))
            .collect()
    }
}

/// Stateless assembly logic binding the substrates together.
pub struct Assembler {
    pub dims: Dims,
}

impl Assembler {
    pub fn new(dims: Dims) -> Assembler {
        Assembler { dims }
    }

    /// Fill `host` for one iteration in place (PREP + SPLICE): `prev` is
    /// the batch whose events update memory in-graph; `cur` + `negatives`
    /// is the predicted batch. Sequential convenience — the pipelined loop
    /// installs a prefetched PREP half and calls [`Assembler::splice`].
    ///
    /// Generic over the backend (like every store-touching method here) so
    /// the trainer's calls monomorphize against
    /// [`crate::memory::MemoryBackendKind`] — the per-row `row` and
    /// `last_update` reads in the scalar passes dispatch by branch instead
    /// of vtable. `?Sized` keeps plain `&dyn MemoryBackend` callers
    /// compiling unchanged.
    #[allow(clippy::too_many_arguments)]
    pub fn fill<S: MemoryBackend + ?Sized>(
        &self,
        host: &mut HostBatch,
        log: &EventLog,
        prev: &BatchPlan,
        cur: &BatchPlan,
        negatives: &[u32],
        store: &S,
        nbr: &NeighborIndex,
        mailbox: Option<&Mailbox>,
        gmm: &GmmTrackers,
        pres_on: bool,
        beta: f32,
    ) {
        debug_assert_eq!(negatives.len(), host.b);
        host.prep.negatives.copy_from_slice(negatives);
        fill_prep_from(&mut host.prep, log, prev, cur, store.router());
        self.splice(host, log, prev, store, nbr, mailbox, gmm, pres_on, beta);
    }

    /// SPLICE: fill every substrate-dependent tensor from `host.prep` plus
    /// the current memory view. The ONLY stage that must observe the
    /// previous batch's write-back — under bounded staleness it may run
    /// against a view lagging at most `k` commits. On a sharded backend
    /// the batched gathers fan out across pool lanes, steered by the
    /// routes PREP precomputed into `host.prep.routes`.
    #[allow(clippy::too_many_arguments)]
    pub fn splice<S: MemoryBackend + ?Sized>(
        &self,
        host: &mut HostBatch,
        log: &EventLog,
        prev: &BatchPlan,
        store: &S,
        nbr: &NeighborIndex,
        mailbox: Option<&Mailbox>,
        gmm: &GmmTrackers,
        pres_on: bool,
        beta: f32,
    ) {
        let d = self.dims.d_mem;
        let b = host.b;
        debug_assert_eq!(prev.batch_size(), b);
        debug_assert_eq!(host.prep.rows(), prev.rows());

        host.pres_on = if pres_on { 1.0 } else { 0.0 };
        host.beta = beta;

        // ---- update rows: batched gathers, then the per-row scalar pass
        let rshards = host.prep.routes.n_shards;
        store.gather_rows_routed(
            &prev.upd_vertex,
            &host.prep.routes.u_self,
            rshards,
            &mut host.u_self_mem,
        );
        store.gather_rows_routed(
            &host.prep.u_other,
            &host.prep.routes.u_other,
            rshards,
            &mut host.u_other_mem,
        );
        // correct only rows that (a) suffer temporal discontinuity and
        // (b) have a prediction backed by enough clean observations —
        // an uninformed prediction would inject noise instead of removing it
        const MIN_OBS: u32 = 3;
        for r in 0..prev.rows() {
            let v = prev.upd_vertex[r];
            let role = if r < b { Role::Src } else { Role::Dst };
            let dt = (host.prep.u_t[r] - store.last_update(v)).max(0.0);
            host.u_dt[r] = dt;
            let pred_row = &mut host.u_pred[r * d..(r + 1) * d];
            if pres_on {
                gmm.predict_into(v, role, store.row(v), dt, pred_row);
            } else {
                pred_row.fill(0.0);
            }
            host.u_cmask[r] =
                if prev.collided[r] == 1.0 && gmm.count(v, role) >= MIN_OBS {
                    1.0
                } else {
                    0.0
                };
        }

        // ---- current batch rows
        for ri in 0..3 {
            store.gather_rows_routed(
                &host.prep.c_vertex[ri],
                &host.prep.routes.c_vertex[ri],
                rshards,
                &mut host.c_mem[ri],
            );
        }
        for j in 0..b {
            let t_now = host.prep.c_t[j];
            let vertices = [
                host.prep.c_vertex[0][j],
                host.prep.c_vertex[1][j],
                host.prep.c_vertex[2][j],
            ];
            for (ri, &v) in vertices.iter().enumerate() {
                // dt vs the vertex's true latest update: if the previous
                // batch updated it, that event's time is fresher than the
                // store clock (write-back happens after this call)
                let last = host.prep.c_prev_t[ri][j].max(store.last_update(v));
                host.c_dt[ri][j] = (t_now - last).max(0.0);
            }
            // neighbor / mailbox tensors
            self.fill_context(host, log, store, nbr, mailbox, j, t_now, &vertices);
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn fill_context<S: MemoryBackend + ?Sized>(
        &self,
        host: &mut HostBatch,
        log: &EventLog,
        store: &S,
        nbr: &NeighborIndex,
        mailbox: Option<&Mailbox>,
        j: usize,
        t_now: f32,
        vertices: &[u32; 3],
    ) {
        let k = self.dims.k_nbr;
        let d = self.dims.d_mem;
        let de = self.dims.d_edge;
        let dm = self.dims.d_msg;
        match host.model.as_str() {
            "jodie" => {}
            "apan" => {
                let mb = mailbox.expect("apan requires a mailbox");
                for (ri, &v) in vertices.iter().enumerate() {
                    let mails = &mut host.n_key[ri][j * k * dm..(j + 1) * k * dm];
                    let times = &mut host.n_dt[ri][j * k..(j + 1) * k];
                    let n = mb.gather(v, mails, times);
                    for slot in 0..k {
                        host.n_mask[ri][j * k + slot] = (slot < n) as u8 as f32;
                    }
                    for time in times.iter_mut().take(n) {
                        *time = (t_now - *time).max(0.0);
                    }
                }
            }
            _ => {
                // tgn: most-recent-K temporal neighbors
                for (ri, &v) in vertices.iter().enumerate() {
                    let scratch = &mut host.nbr_scratch;
                    let n = nbr.gather(v, scratch);
                    for slot in 0..k {
                        let base_m = (j * k + slot) * d;
                        let base_e = (j * k + slot) * de;
                        if slot < n {
                            let e = scratch[slot];
                            host.n_key[ri][base_m..base_m + d]
                                .copy_from_slice(store.row(e.nbr));
                            if de > 0 {
                                let feat = log.feat(e.event as usize);
                                if feat.is_empty() {
                                    host.n_efeat[ri][base_e..base_e + de].fill(0.0);
                                } else {
                                    host.n_efeat[ri][base_e..base_e + de]
                                        .copy_from_slice(feat);
                                }
                            }
                            host.n_dt[ri][j * k + slot] = (t_now - e.t).max(0.0);
                            host.n_mask[ri][j * k + slot] = 1.0;
                        } else {
                            host.n_key[ri][base_m..base_m + d].fill(0.0);
                            if de > 0 {
                                host.n_efeat[ri][base_e..base_e + de].fill(0.0);
                            }
                            host.n_dt[ri][j * k + slot] = 0.0;
                            host.n_mask[ri][j * k + slot] = 0.0;
                        }
                    }
                }
            }
        }
    }

    /// WRITEBACK: commit a finished step — feed the GMM trackers, scatter
    /// corrected states back for the winning rows, register the batch's
    /// events in the neighbor index, and (APAN) deliver mails. `host` must
    /// be the staging the step ran from (its PREP half carries the
    /// write-back timestamps, its SPLICE half the pre-step states the
    /// trackers observe transitions against).
    #[allow(clippy::too_many_arguments)]
    pub fn commit<S: MemoryBackend + ?Sized>(
        &self,
        host: &HostBatch,
        log: &EventLog,
        prev: &BatchPlan,
        u_sbar: &[f32],
        u_msg: Option<&[f32]>,
        store: &mut S,
        nbr: &mut NeighborIndex,
        mailbox: Option<&mut Mailbox>,
        gmm: &mut GmmTrackers,
        pres_on: bool,
    ) {
        let d = self.dims.d_mem;
        let b = prev.batch_size();
        debug_assert_eq!(host.prep.rows(), prev.rows());
        if pres_on {
            for r in 0..prev.rows() {
                // clean transitions only: rows without pending events are
                // exact per-event updates, the filter's "good measurements";
                // collided rows are the noisy ones being corrected.
                if prev.wmask[r] != 1.0 || prev.collided[r] != 0.0 {
                    continue;
                }
                let role = if r < b { Role::Src } else { Role::Dst };
                let s_t1 = &host.u_self_mem[r * d..(r + 1) * d];
                let row = &u_sbar[r * d..(r + 1) * d];
                gmm.observe(prev.upd_vertex[r], role, s_t1, row, host.u_dt[r]);
            }
        }
        // the update rows double as the write-back targets, so WRITEBACK
        // reuses PREP's u_self routes to fan out across shards
        store.scatter_rows_routed(
            &prev.upd_vertex,
            u_sbar,
            &host.prep.u_t,
            Some(&prev.wmask),
            &host.prep.routes.u_self,
            host.prep.routes.n_shards,
        );
        for i in prev.range.clone() {
            let ev = log.events[i];
            nbr.insert_event(ev.src, ev.dst, ev.t, i as u32);
        }
        if let (Some(mb), Some(msgs)) = (mailbox, u_msg) {
            let dm = self.dims.d_msg;
            for r in 0..prev.rows() {
                let v = prev.upd_vertex[r];
                mb.deliver(v, &msgs[r * dm..(r + 1) * dm], host.prep.u_t[r]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Dataset, Event, NO_LABEL};
    use crate::memory::{MemoryStore, ShardRouter, ShardedMemoryStore};

    fn dims() -> Dims {
        Dims {
            d_mem: 4,
            d_msg: 4,
            d_edge: 2,
            d_time: 2,
            k_nbr: 3,
            heads: 1,
            d_emb: 4,
            clf_batch: 8,
        }
    }

    fn toy_dataset() -> Dataset {
        let mut log = EventLog::new(8, 4, 2);
        let evs = [(0u32, 4u32), (1, 5), (0, 5), (2, 6), (1, 4), (3, 7)];
        for (i, &(s, dst)) in evs.iter().enumerate() {
            log.push(
                Event { src: s, dst, t: i as f32 + 1.0, label: NO_LABEL },
                &[i as f32, -(i as f32)],
            )
            .unwrap();
        }
        Dataset::with_chrono_split("toy", log)
    }

    /// Populate the PREP half the way the real flow does before a commit
    /// (the write-back needs the update-row timestamps).
    fn prep_times(host: &mut HostBatch, ds: &Dataset, prev: &BatchPlan) {
        for r in 0..prev.rows() {
            host.prep.u_t[r] = ds.log.events[prev.upd_event[r] as usize].t;
        }
    }

    #[test]
    fn fill_gathers_memory_and_matches() {
        let ds = toy_dataset();
        let dims = dims();
        let mut store = MemoryStore::new(8, dims.d_mem);
        store.scatter(0, &[1.0, 2.0, 3.0, 4.0], 0.5);
        let nbr = NeighborIndex::new(8, dims.k_nbr);
        let gmm = GmmTrackers::new(8, dims.d_mem, 1.0, 0);
        let prev = BatchPlan::build(&ds.log, 0..2); // events (0,4), (1,5)
        let cur = BatchPlan::build(&ds.log, 2..4); // events (0,5), (2,6)
        let asm = Assembler::new(dims);
        let mut host = HostBatch::new("tgn", 2, dims);
        asm.fill(
            &mut host, &ds.log, &prev, &cur, &[6, 7], &store, &nbr, None, &gmm, false, 0.0,
        );
        // row 0 = src side of event 0 = vertex 0, whose memory we planted
        assert_eq!(&host.u_self_mem[0..4], &[1.0, 2.0, 3.0, 4.0]);
        // u_dt = t_event - last_update = 1.0 - 0.5
        assert_eq!(host.u_dt[0], 0.5);
        // current event 2 is (0, 5): src 0 matched to prev row 0, dst 5 to row 3
        assert_eq!(host.prep.c_match[0][0], 0);
        assert_eq!(host.prep.c_match[1][0], 3);
        // negative 6 is not in prev batch
        assert_eq!(host.prep.c_match[2][0], -1);
        // std mode: predictions zeroed
        assert!(host.u_pred.iter().all(|&x| x == 0.0));
        // edge features flow through
        assert_eq!(&host.prep.u_efeat[0..2], &[0.0, -0.0]);
    }

    #[test]
    fn split_prep_plus_splice_equals_fill() {
        // the pipeline-vs-sequential equivalence at the host-buffer level:
        // installing a separately-prepped half and splicing must reproduce
        // the one-shot fill exactly, field for field.
        let ds = toy_dataset();
        let dims = dims();
        let mut store = MemoryStore::new(8, dims.d_mem);
        store.scatter(0, &[1.0, 2.0, 3.0, 4.0], 0.5);
        store.scatter(5, &[-1.0, -2.0, -3.0, -4.0], 0.25);
        let mut nbr = NeighborIndex::new(8, dims.k_nbr);
        nbr.insert_event(0, 4, 0.5, 0);
        let gmm = GmmTrackers::new(8, dims.d_mem, 1.0, 0);
        let prev = BatchPlan::build(&ds.log, 0..2);
        let cur = BatchPlan::build(&ds.log, 2..4);
        let asm = Assembler::new(dims);

        let mut a = HostBatch::new("tgn", 2, dims);
        asm.fill(
            &mut a, &ds.log, &prev, &cur, &[6, 7], &store, &nbr, None, &gmm, true, 0.1,
        );

        let mut detached = crate::pipeline::PrepBatch::new(2, dims.d_edge);
        detached.negatives.copy_from_slice(&[6, 7]);
        crate::pipeline::fill_prep_from(&mut detached, &ds.log, &prev, &cur, ShardRouter::flat());
        let mut b = HostBatch::new("tgn", 2, dims);
        let _old = b.install_prep(detached);
        asm.splice(&mut b, &ds.log, &prev, &store, &nbr, None, &gmm, true, 0.1);

        assert_eq!(a.u_self_mem, b.u_self_mem);
        assert_eq!(a.u_other_mem, b.u_other_mem);
        assert_eq!(a.u_dt, b.u_dt);
        assert_eq!(a.u_pred, b.u_pred);
        assert_eq!(a.u_cmask, b.u_cmask);
        assert_eq!(a.c_mem, b.c_mem);
        assert_eq!(a.c_dt, b.c_dt);
        assert_eq!(a.n_key, b.n_key);
        assert_eq!(a.n_efeat, b.n_efeat);
        assert_eq!(a.n_dt, b.n_dt);
        assert_eq!(a.n_mask, b.n_mask);
        assert_eq!(a.prep.c_match, b.prep.c_match);
        assert_eq!(a.prep.u_wmask, b.prep.u_wmask);
        assert_eq!(a.prep.u_efeat, b.prep.u_efeat);
    }

    #[test]
    fn sharded_splice_and_commit_match_flat_buffers_exactly() {
        // the host-level half of the shard-equivalence gate: the same
        // splice against a flat and a 3-shard backend (with PREP-computed
        // routes) must fill identical buffers, and commits must leave both
        // backends in the same logical state.
        let ds = toy_dataset();
        let dims = dims();
        let mut flat = MemoryStore::new(8, dims.d_mem);
        let mut sharded = ShardedMemoryStore::new(8, dims.d_mem, 3);
        for (v, t) in [(0u32, 0.5f32), (5, 0.25), (6, 0.75)] {
            let row: Vec<f32> = (0..dims.d_mem).map(|i| v as f32 + i as f32).collect();
            flat.scatter(v, &row, t);
            MemoryBackend::scatter(&mut sharded, v, &row, t);
        }
        let mut nbr = NeighborIndex::new(8, dims.k_nbr);
        nbr.insert_event(0, 4, 0.5, 0);
        let mut gmm_a = GmmTrackers::new(8, dims.d_mem, 1.0, 0);
        let mut gmm_b = GmmTrackers::new(8, dims.d_mem, 1.0, 0);
        let prev = BatchPlan::build(&ds.log, 0..2);
        let cur = BatchPlan::build(&ds.log, 2..4);
        let asm = Assembler::new(dims);

        let mut a = HostBatch::new("tgn", 2, dims);
        let mut b = HostBatch::new("tgn", 2, dims);
        asm.fill(&mut a, &ds.log, &prev, &cur, &[6, 7], &flat, &nbr, None, &gmm_a, true, 0.1);
        asm.fill(&mut b, &ds.log, &prev, &cur, &[6, 7], &sharded, &nbr, None, &gmm_b, true, 0.1);
        assert_eq!(b.prep.routes.n_shards, 3, "fill must route for the sharded backend");
        assert_eq!(a.u_self_mem, b.u_self_mem);
        assert_eq!(a.u_other_mem, b.u_other_mem);
        assert_eq!(a.u_dt, b.u_dt);
        assert_eq!(a.u_pred, b.u_pred);
        assert_eq!(a.c_mem, b.c_mem);
        assert_eq!(a.c_dt, b.c_dt);
        assert_eq!(a.n_key, b.n_key);

        let u_sbar: Vec<f32> = (0..prev.rows() * dims.d_mem).map(|x| x as f32 * 0.5).collect();
        let mut nbr_b = nbr.clone();
        asm.commit(
            &a, &ds.log, &prev, &u_sbar, None, &mut flat, &mut nbr, None, &mut gmm_a, true,
        );
        asm.commit(
            &b, &ds.log, &prev, &u_sbar, None, &mut sharded, &mut nbr_b, None, &mut gmm_b, true,
        );
        assert_eq!(flat.snapshot(), MemoryBackend::snapshot(&sharded));
    }

    #[test]
    fn commit_writes_back_winners_and_indexes_events() {
        let ds = toy_dataset();
        let dims = dims();
        let mut store = MemoryStore::new(8, dims.d_mem);
        let mut nbr = NeighborIndex::new(8, dims.k_nbr);
        let mut gmm = GmmTrackers::new(8, dims.d_mem, 1.0, 0);
        let prev = BatchPlan::build(&ds.log, 0..2);
        let asm = Assembler::new(dims);
        let mut host = HostBatch::new("tgn", 2, dims);
        prep_times(&mut host, &ds, &prev);
        let u_sbar: Vec<f32> = (0..prev.rows() * dims.d_mem).map(|x| x as f32).collect();
        asm.commit(
            &host, &ds.log, &prev, &u_sbar, None, &mut store, &mut nbr, None, &mut gmm, false,
        );
        // all four vertices were winners (no collision in batch 0..2)
        assert_eq!(store.row(0), &u_sbar[0..4]);
        assert_eq!(store.last_update(0), 1.0);
        assert_eq!(store.row(5), &u_sbar[12..16]);
        // events are now visible as neighbors
        assert_eq!(nbr.degree(0), 1);
        assert_eq!(nbr.degree(5), 1);
    }

    #[test]
    fn collided_vertex_keeps_only_last_row() {
        // batch 2..5 contains (0,5), (2,6), (1,4): no collision; use 0..3
        // instead: (0,4), (1,5), (0,5): vertex 0 rows 0 and 2; vertex 5 rows
        // 4 and 5
        let ds = toy_dataset();
        let dims = dims();
        let mut store = MemoryStore::new(8, dims.d_mem);
        let mut nbr = NeighborIndex::new(8, dims.k_nbr);
        let mut gmm = GmmTrackers::new(8, dims.d_mem, 1.0, 0);
        let prev = BatchPlan::build(&ds.log, 0..3);
        let asm = Assembler::new(dims);
        let mut host = HostBatch::new("tgn", 3, dims);
        prep_times(&mut host, &ds, &prev);
        let u_sbar: Vec<f32> = (0..prev.rows() * dims.d_mem).map(|x| x as f32).collect();
        asm.commit(
            &host, &ds.log, &prev, &u_sbar, None, &mut store, &mut nbr, None, &mut gmm, false,
        );
        // vertex 0's state comes from row 2 (its last occurrence)
        let d = dims.d_mem;
        assert_eq!(store.row(0), &u_sbar[2 * d..3 * d]);
        // vertex 5 last occurs at dst row 3 + 2 = 5
        assert_eq!(store.row(5), &u_sbar[5 * d..6 * d]);
    }

    #[test]
    fn apan_fills_mail_and_delivers() {
        let ds = toy_dataset();
        let dims = dims();
        let mut store = MemoryStore::new(8, dims.d_mem);
        let mut nbr = NeighborIndex::new(8, dims.k_nbr);
        let mut gmm = GmmTrackers::new(8, dims.d_mem, 1.0, 0);
        let mut mb = Mailbox::new(8, dims.k_nbr, dims.d_msg);
        let prev = BatchPlan::build(&ds.log, 0..2);
        let cur = BatchPlan::build(&ds.log, 2..4);
        let asm = Assembler::new(dims);
        let mut host = HostBatch::new("apan", 2, dims);
        // no mail yet -> masks all zero
        asm.fill(
            &mut host, &ds.log, &prev, &cur, &[6, 7], &store, &nbr, Some(&mb), &gmm, true, 0.1,
        );
        assert!(host.n_mask.iter().all(|m| m.iter().all(|&x| x == 0.0)));
        // deliver messages via commit, then refill: src of event 2 is vertex
        // 0, which received mail in batch 0
        let u_sbar = vec![0.0f32; prev.rows() * dims.d_mem];
        let u_msg: Vec<f32> = (0..prev.rows() * dims.d_msg).map(|x| x as f32 + 1.0).collect();
        asm.commit(
            &host, &ds.log, &prev, &u_sbar, Some(&u_msg), &mut store, &mut nbr,
            Some(&mut mb), &mut gmm, true,
        );
        asm.fill(
            &mut host, &ds.log, &prev, &cur, &[6, 7], &store, &nbr, Some(&mb), &gmm, true, 0.1,
        );
        assert_eq!(host.n_mask[0][0], 1.0); // src role, slot 0
        assert_eq!(&host.n_key[0][0..4], &[1.0, 2.0, 3.0, 4.0]); // mail row 0
    }
}
