//! Table harnesses: regenerate Tables 1-3 of the paper on the synthetic
//! testbed.
//!
//! ```text
//!     pres-train table <1|2|3|all> [--quick] [--trials N] [--epochs N]
//! ```
//!
//! Table 1: link-prediction AP + training speedup from PRES's 4x larger
//!          temporal batches, per model x dataset.
//! Table 2: dynamic node-classification ROC-AUC w/wo PRES.
//! Table 3: dataset statistics.

use anyhow::{bail, Result};

use crate::datagen;
use crate::figures::common::{write_csv, Lab};
use crate::util::cli::Args;
use crate::util::stats;

pub fn run(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    match which {
        "1" => table1(&Lab::from_args(args)?, args),
        "2" => table2(&Lab::from_args(args)?, args),
        "3" => table3(args),
        "all" => {
            table3(args)?;
            let lab = Lab::from_args(args)?;
            table1(&lab, args)?;
            table2(&lab, args)
        }
        other => bail!("unknown table '{other}'"),
    }
}

/// The datasets included in a sweep (--dataset to restrict; --quick keeps
/// the two fastest).
fn datasets(args: &Args, quick_set: &[&'static str]) -> Vec<&'static str> {
    if let Some(d) = args.get("dataset") {
        return datagen::profiles()
            .iter()
            .map(|p| p.name)
            .filter(|n| *n == d)
            .collect();
    }
    if args.flag("quick") {
        quick_set.to_vec()
    } else {
        datagen::profiles().iter().map(|p| p.name).collect()
    }
}

/// Table 1: AP + speedup. STANDARD trains at the base batch (the largest
/// size with near-peak accuracy in the small-batch regime); PRES at 4x.
/// Speedup = STANDARD epoch time / PRES epoch time, the paper's metric.
fn table1(lab: &Lab, args: &Args) -> Result<()> {
    crate::log_info!("\n=== Table 1: AP & speedup, STANDARD(b0) vs PRES(4*b0) ===");
    let b0 = args.usize_or("base-batch", 50)?;
    let b1 = 4 * b0;
    let mut rows = Vec::new();
    crate::log_info!(
        "{:<8} {:<12} {:>16} {:>16} {:>9}",
        "dataset", "model", "AP (STANDARD)", "AP (PRES 4x)", "speedup"
    );
    for ds in datasets(args, &["wiki", "mooc"]) {
        for model in ["tgn", "jodie", "apan"] {
            let cfg_std = lab.config(ds, model, b0, false);
            let cfg_pres = lab.config(ds, model, b1, true);
            let mut ap_std = Vec::new();
            let mut ap_pres = Vec::new();
            let mut t_std = Vec::new();
            let mut t_pres = Vec::new();
            for t in 1..=lab.trials as u64 {
                let (ap, secs) = lab.final_val_ap(&cfg_std, t)?;
                ap_std.push(ap);
                t_std.push(secs);
                let (ap, secs) = lab.final_val_ap(&cfg_pres, t)?;
                ap_pres.push(ap);
                t_pres.push(secs);
            }
            let speedup = stats::mean(&t_std) / stats::mean(&t_pres).max(1e-9);
            crate::log_info!(
                "{:<8} {:<12} {:>16} {:>16} {:>8.2}x",
                ds,
                format!("{model}/-PRES"),
                stats::fmt_mean_std(&ap_std, 3),
                stats::fmt_mean_std(&ap_pres, 3),
                speedup
            );
            rows.push(format!(
                "{ds},{model},{:.4},{:.4},{:.4},{:.4},{:.3},{:.3},{speedup:.2}",
                stats::mean(&ap_std),
                stats::std_dev(&ap_std),
                stats::mean(&ap_pres),
                stats::std_dev(&ap_pres),
                stats::mean(&t_std),
                stats::mean(&t_pres),
            ));
        }
    }
    write_csv(
        "table1_ap_speedup",
        "dataset,model,ap_std,ap_std_sd,ap_pres,ap_pres_sd,std_epoch_s,pres_epoch_s,speedup",
        &rows,
    )
}

/// Table 2: node classification ROC-AUC w/wo PRES (REDDIT/WIKI/MOOC in the
/// paper; same trio here).
fn table2(lab: &Lab, args: &Args) -> Result<()> {
    crate::log_info!("\n=== Table 2: node classification ROC-AUC ===");
    let b0 = args.usize_or("base-batch", 50)?;
    let mut rows = Vec::new();
    crate::log_info!(
        "{:<8} {:<12} {:>14} {:>14}",
        "dataset", "model", "AUC (STD)", "AUC (PRES)"
    );
    let all = datasets(args, &["wiki", "mooc"]);
    let trio: Vec<&str> = all
        .into_iter()
        .filter(|d| ["reddit", "wiki", "mooc"].contains(d))
        .collect();
    for ds in trio {
        for model in ["tgn", "jodie", "apan"] {
            let mut auc = [Vec::new(), Vec::new()];
            for (i, pres) in [false, true].into_iter().enumerate() {
                let mut cfg = lab.config(ds, model, if pres { 4 * b0 } else { b0 }, pres);
                cfg.seed = 0;
                for t in 1..=lab.trials as u64 {
                    let mut run_cfg = cfg.clone();
                    run_cfg.seed = t * 1000;
                    let ds_rc = lab.dataset(&cfg)?;
                    let mut tr = crate::training::Trainer::with_shared(
                        &run_cfg,
                        lab.engine.clone(),
                        ds_rc,
                    )?;
                    for e in 0..cfg.epochs {
                        tr.train_epoch(e)?;
                    }
                    let (_, emb_rows) = tr.eval_test(true)?;
                    let a = crate::eval::nodeclf::train_and_auc(&lab.engine, &emb_rows, t)?;
                    if a.is_finite() {
                        auc[i].push(a);
                    }
                }
            }
            crate::log_info!(
                "{:<8} {:<12} {:>14} {:>14}",
                ds,
                format!("{model}/-PRES"),
                stats::fmt_mean_std(&auc[0], 3),
                stats::fmt_mean_std(&auc[1], 3)
            );
            rows.push(format!(
                "{ds},{model},{:.4},{:.4},{:.4},{:.4}",
                stats::mean(&auc[0]),
                stats::std_dev(&auc[0]),
                stats::mean(&auc[1]),
                stats::std_dev(&auc[1])
            ));
        }
    }
    write_csv(
        "table2_nodeclf_auc",
        "dataset,model,auc_std,auc_std_sd,auc_pres,auc_pres_sd",
        &rows,
    )
}

/// Table 3: dataset statistics (generator outputs vs the profiles).
fn table3(args: &Args) -> Result<()> {
    crate::log_info!("\n=== Table 3: dataset statistics ===");
    let seed = args.u64_or("seed", 0)?;
    let mut rows = Vec::new();
    crate::log_info!(
        "{:<8} {:>9} {:>9} {:>8} {:>9} {:>9}",
        "dataset", "vertices", "events", "efeat", "repeat%", "labeled"
    );
    for p in datagen::profiles() {
        let ds = datagen::generate(&p, seed);
        let s = ds.stats();
        crate::log_info!(
            "{:<8} {:>9} {:>9} {:>8} {:>8.1}% {:>9}",
            s.name,
            s.num_nodes,
            s.num_events,
            s.d_edge,
            s.repeat_ratio * 100.0,
            s.labeled_events
        );
        rows.push(format!(
            "{},{},{},{},{:.4},{}",
            s.name, s.num_nodes, s.num_events, s.d_edge, s.repeat_ratio, s.labeled_events
        ));
    }
    write_csv(
        "table3_datasets",
        "dataset,vertices,events,edge_feat_dim,repeat_ratio,labeled_events",
        &rows,
    )
}
