//! Sharded vertex memory: partition [`MemoryStore`] rows across `N` owned
//! shards so SPLICE gathers and WRITEBACK scatters fan out across cores.
//!
//! ## Routing policy
//!
//! Rows are routed by a deterministic modular interleave:
//!
//! ```text
//!   shard(v) = v mod N        local(v) = v div N
//! ```
//!
//! Interleaving (rather than range partitioning) spreads the Zipf-head hot
//! vertices of temporal interaction streams evenly across shards, so every
//! shard sees a near-identical share of each batch's rows. The policy is a
//! pure function of `(v, N)` — captured by [`ShardRouter`] — which lets the
//! PREP stage precompute per-row [`RowRoute`]s ([`ShardRoutes`]) off-thread;
//! SPLICE then degrades to a straight parallel copy with no division on the
//! coordinator's critical path.
//!
//! ## Lock granularity: none
//!
//! There are no locks. Each shard is an *owned* [`MemoryStore`]; parallel
//! sections hand each pool lane either disjoint `&mut` output slots
//! (gather) or the `&mut` shard itself (scatter) — one *task* per busy
//! shard on the store's persistent [`WorkerPool`] — so the borrow checker
//! proves data-race freedom. Because every vertex routes to exactly one
//! shard, per-shard work lists preserve the caller's row order, a task runs
//! its list sequentially on a single lane, and the flat store's "last
//! masked row wins" semantics carry over unchanged.
//!
//! ## Why `N = 1` is the legacy layout
//!
//! With one shard, `local(v) = v` and the single shard's `[num_nodes, d]`
//! row-major buffer is byte-for-byte the flat [`MemoryStore`] layout — and
//! [`crate::memory::make_backend`] doesn't even use this type there, it
//! returns the legacy flat store (`MemoryBackendKind::Flat`). For `N > 1`
//! the layout changes but the values cannot:
//! gathers and scatters are pure `f32` copies with no arithmetic, so any
//! shard count is bit-identical to the flat store (the property/equivalence
//! harness in this module's tests and `tests/shard_equivalence.rs` pins
//! this).

use std::sync::Arc;

use crate::memory::store::{MemorySnapshot, MemoryStore};
use crate::memory::MemoryBackend;
use crate::util::pool::{chunk_for, claims, take_chunk, WorkerPool};

/// Elements (`rows * d`) of *per-shard* work below which gather/scatter
/// stay serial. The scoped-spawn design this store started with paid
/// ~tens of µs of thread spawn per op and needed `1 << 15`; the persistent
/// [`WorkerPool`] hands work off for ~1–2 µs, so the crossover drops an
/// order of magnitude and wiki-scale batches (~1.2k rows × d=100 over 4
/// shards) take the parallel path instead of only gdelt-scale ones
/// (`benches/pool_scaling.rs` sweeps the small-batch regime around this
/// value → `BENCH_pool.json`). Gating on per-shard rather than total work
/// keeps high shard counts from fanning out tiny copies.
pub const PAR_MIN_ELEMS: usize = 1 << 12;

/// Rows below which route precomputation stays on one lane (pure `%`/`/`
/// per row — memory-bandwidth trivial until batches are large).
const ROUTE_PAR_MIN_ROWS: usize = 1 << 12;

/// The deterministic routing policy: `shard = v % n`, `local = v / n`.
/// `n_shards = 1` is the identity (flat) routing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardRouter {
    pub n_shards: u32,
}

/// One routed row: which shard owns it and its row index inside that shard.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RowRoute {
    pub shard: u32,
    pub local: u32,
}

impl ShardRouter {
    /// The identity routing of the flat store.
    pub fn flat() -> ShardRouter {
        ShardRouter { n_shards: 1 }
    }

    #[inline]
    pub fn route(&self, v: u32) -> RowRoute {
        RowRoute { shard: v % self.n_shards, local: v / self.n_shards }
    }

    /// Rows shard `s` owns out of `num_nodes` (interleave remainder goes to
    /// the lowest shard ids).
    pub fn shard_len(&self, s: u32, num_nodes: u32) -> u32 {
        let n = self.n_shards;
        num_nodes / n + u32::from(s < num_nodes % n)
    }

    /// Precompute routes for a vertex list into reusable scratch.
    pub fn fill_routes(&self, vs: &[u32], out: &mut Vec<RowRoute>) {
        out.clear();
        out.extend(vs.iter().map(|&v| self.route(v)));
    }

    /// [`ShardRouter::fill_routes`] fanned out across `pool` lanes (falls
    /// back to one inline chunk below [`ROUTE_PAR_MIN_ROWS`]). Routing is a
    /// pure per-row function, so chunking cannot change the result.
    pub fn fill_routes_with(&self, vs: &[u32], out: &mut Vec<RowRoute>, pool: &WorkerPool) {
        out.resize(vs.len(), RowRoute::default());
        let chunk = chunk_for(vs.len(), pool.lanes(), ROUTE_PAR_MIN_ROWS);
        let mut tasks: Vec<(&[u32], &mut [RowRoute])> = Vec::new();
        let mut rest = out.as_mut_slice();
        let mut done = 0;
        while done < vs.len() {
            let n = chunk.min(vs.len() - done);
            tasks.push((&vs[done..done + n], take_chunk(&mut rest, n)));
            done += n;
        }
        let router = *self;
        pool.run(&mut tasks, |(vs, out)| {
            // checked-claims: chunks come from a split_at_mut cursor, so
            // they are disjoint by construction; claim them anyway so the
            // barrier re-proves it every run
            claims::claim(&out[..], "route-chunk");
            for (slot, &v) in out.iter_mut().zip(vs.iter()) {
                *slot = router.route(v);
            }
        });
    }
}

/// Per-batch precomputed routes for every vertex list SPLICE gathers and
/// WRITEBACK scatters (the update rows double as the write-back targets).
/// Computed by PREP — off the coordinator thread in the pipelined loop —
/// for the shard count the trainer's backend reported; a backend with a
/// different shard count simply ignores them and routes inline.
#[derive(Clone, Debug)]
pub struct ShardRoutes {
    /// Shard count the routes were computed for (1 = flat, vectors empty).
    pub n_shards: u32,
    /// Routes of the previous plan's update rows (`upd_vertex`). [2b]
    pub u_self: Vec<RowRoute>,
    /// Routes of the update rows' other endpoints. [2b]
    pub u_other: Vec<RowRoute>,
    /// Routes of the current batch's src/dst/neg vertices. [3][b]
    pub c_vertex: [Vec<RowRoute>; 3],
}

impl Default for ShardRoutes {
    fn default() -> ShardRoutes {
        ShardRoutes {
            n_shards: 1,
            u_self: Vec::new(),
            u_other: Vec::new(),
            c_vertex: std::array::from_fn(|_| Vec::new()),
        }
    }
}

impl ShardRoutes {
    /// Recompute every route list for `router`. Flat routing clears the
    /// lists — the flat backend never reads them.
    pub fn compute(
        &mut self,
        router: ShardRouter,
        u_self: &[u32],
        u_other: &[u32],
        c_vertex: &[Vec<u32>; 3],
    ) {
        self.compute_with(router, u_self, u_other, c_vertex, WorkerPool::global());
    }

    /// [`ShardRoutes::compute`] on an explicit pool (PREP's route
    /// precomputation hot loop; the prefetch worker passes the trainer's).
    pub fn compute_with(
        &mut self,
        router: ShardRouter,
        u_self: &[u32],
        u_other: &[u32],
        c_vertex: &[Vec<u32>; 3],
        pool: &WorkerPool,
    ) {
        self.n_shards = router.n_shards.max(1);
        if self.n_shards <= 1 {
            self.u_self.clear();
            self.u_other.clear();
            for r in &mut self.c_vertex {
                r.clear();
            }
            return;
        }
        router.fill_routes_with(u_self, &mut self.u_self, pool);
        router.fill_routes_with(u_other, &mut self.u_other, pool);
        for (out, vs) in self.c_vertex.iter_mut().zip(c_vertex) {
            router.fill_routes_with(vs, out, pool);
        }
    }
}

/// `N` owned [`MemoryStore`] shards behind the [`MemoryBackend`] interface,
/// with batched gather/scatter fanned out over a persistent [`WorkerPool`]
/// (serial below [`PAR_MIN_ELEMS`] copied elements per shard, where even
/// the pooled handoff would dominate).
#[derive(Clone, Debug)]
pub struct ShardedMemoryStore {
    router: ShardRouter,
    shards: Vec<MemoryStore>,
    num_nodes: u32,
    d: usize,
    par_min_elems: usize,
    /// Persistent lanes for the parallel paths. Defaults to the shared
    /// process pool; the trainer injects its own via
    /// [`ShardedMemoryStore::with_pool`] so `--pool-workers` governs it.
    pool: Arc<WorkerPool>,
}

impl ShardedMemoryStore {
    pub fn new(num_nodes: u32, d: usize, n_shards: usize) -> ShardedMemoryStore {
        assert!(n_shards >= 1, "ShardedMemoryStore requires n_shards >= 1");
        let router = ShardRouter { n_shards: n_shards as u32 };
        let shards = (0..n_shards as u32)
            .map(|s| MemoryStore::new(router.shard_len(s, num_nodes), d))
            .collect();
        ShardedMemoryStore {
            router,
            shards,
            num_nodes,
            d,
            par_min_elems: PAR_MIN_ELEMS,
            pool: WorkerPool::global().clone(),
        }
    }

    /// Override the serial/parallel crossover (tests force both paths;
    /// benches isolate handoff overhead).
    pub fn with_par_threshold(mut self, elems: usize) -> ShardedMemoryStore {
        self.par_min_elems = elems;
        self
    }

    /// Run the parallel paths on `pool` instead of the shared process pool.
    pub fn with_pool(mut self, pool: Arc<WorkerPool>) -> ShardedMemoryStore {
        self.pool = pool;
        self
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shard(&self, s: usize) -> &MemoryStore {
        &self.shards[s]
    }

    #[inline]
    fn parallel(&self, rows: usize) -> bool {
        // saturating: the test harness pins the threshold to usize::MAX to
        // force the serial path
        self.shards.len() > 1
            && self.pool.lanes() > 1
            && rows * self.d >= self.par_min_elems.saturating_mul(self.shards.len())
    }

    /// The one gather body behind both trait entry points: `routes` is
    /// `Some` on the division-free planned path (PREP precomputed it) and
    /// `None` when routing happens inline — everything else (work-list
    /// distribution, the pool fan-out, the serial fallback) is shared so
    /// the two paths cannot drift.
    fn gather_impl(&self, vs: &[u32], routes: Option<&[RowRoute]>, out: &mut [f32]) {
        debug_assert_eq!(out.len(), vs.len() * self.d);
        if let Some(r) = routes {
            debug_assert_eq!(r.len(), vs.len());
        }
        let router = self.router;
        let route_of = |i: usize, v: u32| {
            let r = match routes {
                Some(rs) => rs[i],
                None => router.route(v),
            };
            debug_assert_eq!(r, router.route(v), "stale route for row {i}");
            r
        };
        if self.parallel(vs.len()) {
            let mut work: Vec<Vec<(u32, &mut [f32])>> = self.work_lists(vs.len());
            for (i, (slot, &v)) in out.chunks_exact_mut(self.d).zip(vs).enumerate() {
                let r = route_of(i, v);
                work[r.shard as usize].push((r.local, slot));
            }
            // one pool task per busy shard; idle shards cost nothing
            let mut tasks: Vec<(&MemoryStore, Vec<(u32, &mut [f32])>)> = self
                .shards
                .iter()
                .zip(work)
                .filter(|(_, items)| !items.is_empty())
                .collect();
            self.pool.run(&mut tasks, |(shard, items)| {
                for (local, slot) in items.iter_mut() {
                    // checked-claims: rows route to exactly one shard, so
                    // out-slots are cross-task disjoint by construction —
                    // the claim table asserts it at the barrier
                    claims::claim(&slot[..], "shard-gather-row");
                    slot.copy_from_slice(shard.row(*local));
                }
            });
        } else {
            for (i, (slot, &v)) in out.chunks_exact_mut(self.d).zip(vs).enumerate() {
                let r = route_of(i, v);
                slot.copy_from_slice(self.shards[r.shard as usize].row(r.local));
            }
        }
    }

    fn work_lists<T>(&self, total: usize) -> Vec<Vec<T>> {
        let per = total / self.shards.len() + 1;
        (0..self.shards.len()).map(|_| Vec::with_capacity(per)).collect()
    }
}

impl MemoryBackend for ShardedMemoryStore {
    fn dim(&self) -> usize {
        self.d
    }

    fn num_nodes(&self) -> usize {
        self.num_nodes as usize
    }

    fn router(&self) -> ShardRouter {
        self.router
    }

    fn reset(&mut self) {
        // memset-bound: threads would just contend on memory bandwidth
        for s in &mut self.shards {
            s.reset();
        }
    }

    fn row(&self, v: u32) -> &[f32] {
        let r = self.router.route(v);
        self.shards[r.shard as usize].row(r.local)
    }

    fn last_update(&self, v: u32) -> f32 {
        let r = self.router.route(v);
        self.shards[r.shard as usize].last_update(r.local)
    }

    fn scatter(&mut self, v: u32, values: &[f32], t: f32) {
        let r = self.router.route(v);
        self.shards[r.shard as usize].scatter(r.local, values, t);
    }

    fn gather_rows_into(&self, vs: &[u32], out: &mut [f32]) {
        self.gather_impl(vs, None, out);
    }

    fn gather_rows_routed(
        &self,
        vs: &[u32],
        routes: &[RowRoute],
        routes_shards: u32,
        out: &mut [f32],
    ) {
        // routes computed for a different shard count (or not at all):
        // ignore them and route inline
        let planned = routes_shards == self.router.n_shards && routes.len() == vs.len();
        self.gather_impl(vs, planned.then_some(routes), out);
    }

    fn scatter_rows(&mut self, vs: &[u32], rows: &[f32], ts: &[f32], mask: Option<&[f32]>) {
        self.scatter_rows_routed(vs, rows, ts, mask, &[], 0);
    }

    fn scatter_rows_routed(
        &mut self,
        vs: &[u32],
        rows: &[f32],
        ts: &[f32],
        mask: Option<&[f32]>,
        routes: &[RowRoute],
        routes_shards: u32,
    ) {
        debug_assert_eq!(rows.len(), vs.len() * self.d);
        debug_assert_eq!(ts.len(), vs.len());
        if let Some(m) = mask {
            debug_assert_eq!(m.len(), vs.len());
        }
        let router = self.router;
        let planned = routes_shards == router.n_shards && routes.len() == vs.len();
        // The mask and routing decisions live in these two closures, shared
        // by both branches (mirroring gather_impl) so the semantics cannot
        // drift between the serial and threaded paths. A vertex's rows
        // always land in the same shard and per-shard work keeps the
        // caller's row order, so "last masked row wins" is preserved.
        let keep = |r: usize| mask.is_none_or(|m| m[r] == 1.0);
        let route_of = |r: usize, v: u32| {
            let rt = if planned { routes[r] } else { router.route(v) };
            debug_assert_eq!(rt, router.route(v), "stale route for row {r}");
            rt
        };
        if self.parallel(vs.len()) {
            let mut work: Vec<Vec<(u32, &[f32], f32)>> = self.work_lists(vs.len());
            for (r, (&v, row)) in vs.iter().zip(rows.chunks_exact(self.d)).enumerate() {
                if !keep(r) {
                    continue;
                }
                let rt = route_of(r, v);
                work[rt.shard as usize].push((rt.local, row, ts[r]));
            }
            // each task owns its `&mut` shard plus that shard's work list,
            // applied in caller row order on a single lane — last masked
            // row targeting a vertex still wins
            let pool = self.pool.clone();
            let mut tasks: Vec<(&mut MemoryStore, Vec<(u32, &[f32], f32)>)> = self
                .shards
                .iter_mut()
                .zip(work)
                .filter(|(_, items)| !items.is_empty())
                .collect();
            pool.run(&mut tasks, |(shard, items)| {
                // checked-claims: the task owns its whole `&mut` shard, so
                // it claims the shard's backing storage outright
                #[cfg(any(debug_assertions, feature = "checked-claims"))]
                {
                    let (data, last) = shard.claim_ranges();
                    claims::claim(data, "shard-scatter-data");
                    claims::claim(last, "shard-scatter-clock");
                }
                for &(local, row, t) in items.iter() {
                    shard.scatter(local, row, t);
                }
            });
        } else {
            // zero-allocation apply, like gather_impl's serial branch
            for (r, (&v, row)) in vs.iter().zip(rows.chunks_exact(self.d)).enumerate() {
                if !keep(r) {
                    continue;
                }
                let rt = route_of(r, v);
                self.shards[rt.shard as usize].scatter(rt.local, row, ts[r]);
            }
        }
    }

    /// Snapshot in *logical* (flat) row order, so snapshots of a sharded
    /// and a flat store holding the same state compare equal — the hook the
    /// equivalence harness leans on.
    fn snapshot(&self) -> MemorySnapshot {
        let mut data = vec![0.0; self.num_nodes as usize * self.d];
        let mut last = vec![0.0; self.num_nodes as usize];
        for v in 0..self.num_nodes {
            data[v as usize * self.d..(v as usize + 1) * self.d].copy_from_slice(self.row(v));
            last[v as usize] = self.last_update(v);
        }
        MemorySnapshot::from_parts(data, last)
    }

    fn restore(&mut self, snap: &MemorySnapshot) {
        let (data, last) = snap.parts();
        debug_assert_eq!(data.len(), self.num_nodes as usize * self.d);
        for v in 0..self.num_nodes {
            self.scatter(v, &data[v as usize * self.d..(v as usize + 1) * self.d], last[v as usize]);
        }
    }

    fn bytes(&self) -> usize {
        self.shards.iter().map(|s| s.bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg32;

    /// One randomized case for the flat-vs-sharded equivalence properties.
    #[derive(Debug)]
    struct Case {
        num_nodes: u32,
        d: usize,
        n_shards: usize,
        /// (vs, rows, ts, mask) scatter batches applied in order.
        batches: Vec<(Vec<u32>, Vec<f32>, Vec<f32>, Option<Vec<f32>>)>,
        /// Vertex list for the final gather comparison.
        gather: Vec<u32>,
    }

    fn gen_case(rng: &mut Pcg32) -> Case {
        let num_nodes = 1 + rng.below(64);
        let d = 1 + rng.below(8) as usize;
        let n_shards = 1 + rng.below(8) as usize; // may exceed num_nodes
        let batches = (0..1 + rng.below(4))
            .map(|_| {
                let b = 1 + rng.below(32) as usize;
                let vs = prop::vertex_vec(rng, num_nodes, b);
                let rows = prop::f32_vec(rng, b * d);
                let ts = prop::f32_vec(rng, b);
                let mask = (rng.below(2) == 0).then(|| {
                    (0..b).map(|_| if rng.below(2) == 0 { 1.0 } else { 0.0 }).collect()
                });
                (vs, rows, ts, mask)
            })
            .collect();
        let gather = prop::vertex_vec(rng, num_nodes, 1 + rng.below(48) as usize);
        Case { num_nodes, d, n_shards, batches, gather }
    }

    fn run_case(c: &Case, par_threshold: usize) -> Result<(), String> {
        run_case_on(c, par_threshold, WorkerPool::global().clone())
    }

    fn run_case_on(c: &Case, par_threshold: usize, pool: Arc<WorkerPool>) -> Result<(), String> {
        let mut flat = MemoryStore::new(c.num_nodes, c.d);
        let mut sharded = ShardedMemoryStore::new(c.num_nodes, c.d, c.n_shards)
            .with_par_threshold(par_threshold)
            .with_pool(pool);
        for (vs, rows, ts, mask) in &c.batches {
            MemoryBackend::scatter_rows(&mut flat, vs, rows, ts, mask.as_deref());
            sharded.scatter_rows(vs, rows, ts, mask.as_deref());
        }
        let mut a = vec![0.0; c.gather.len() * c.d];
        let mut b = vec![0.0; c.gather.len() * c.d];
        MemoryBackend::gather_rows_into(&flat, &c.gather, &mut a);
        sharded.gather_rows_into(&c.gather, &mut b);
        if a != b {
            return Err("gather after scatter diverged from flat store".into());
        }
        // routed gather must agree with the unplanned one
        let router = sharded.router();
        let mut routes = Vec::new();
        router.fill_routes(&c.gather, &mut routes);
        let mut c_out = vec![0.0; c.gather.len() * c.d];
        sharded.gather_rows_routed(&c.gather, &routes, router.n_shards, &mut c_out);
        if b != c_out {
            return Err("routed gather diverged from inline-routed gather".into());
        }
        if MemoryBackend::snapshot(&flat) != sharded.snapshot() {
            return Err("logical snapshots diverged".into());
        }
        Ok(())
    }

    #[test]
    fn property_sharded_roundtrip_equals_flat_serial() {
        prop::check_msg("sharded == flat (serial path)", 11, 150, gen_case, |c| {
            run_case(c, usize::MAX)
        });
    }

    #[test]
    fn property_sharded_roundtrip_equals_flat_parallel() {
        // threshold 0 forces the pooled path even on tiny cases (a 4-lane
        // pool guarantees real fan-out whatever the host's core count)
        let pool = Arc::new(WorkerPool::new(4));
        prop::check_msg("sharded == flat (parallel path)", 13, 60, gen_case, |c| {
            run_case_on(c, 0, pool.clone())
        });
    }

    #[test]
    fn property_roundtrip_is_identical_for_every_worker_count() {
        // the acceptance bit: results cannot depend on the pool's lane
        // count — 1 lane (inline), 2, 3 and 8 lanes all reproduce the flat
        // store on the forced-parallel path
        let pools: Vec<Arc<WorkerPool>> =
            [1usize, 2, 3, 8].into_iter().map(|l| Arc::new(WorkerPool::new(l))).collect();
        prop::check_msg("sharded == flat for all worker counts", 29, 40, gen_case, |c| {
            for pool in &pools {
                run_case_on(c, 0, pool.clone())
                    .map_err(|e| format!("lanes={}: {e}", pool.lanes()))?;
            }
            Ok(())
        });
    }

    #[test]
    fn pooled_scatter_preserves_last_masked_row_wins_order() {
        // regression (pool rewrite): many masked rows hitting the SAME
        // vertex must apply in caller order inside the per-shard work list,
        // so the last masked row wins — exactly like the flat store
        let pool = Arc::new(WorkerPool::new(4));
        let d = 3;
        let mut flat = MemoryStore::new(9, d);
        let mut sharded =
            ShardedMemoryStore::new(9, d, 3).with_par_threshold(0).with_pool(pool);
        // 12 rows: vertex 6 six times (mask pattern 1,0,1,1,0,1), vertex 2
        // four times (all masked), vertex 4 twice (mask 0,1)
        let vs = [6u32, 6, 6, 6, 6, 6, 2, 2, 2, 2, 4, 4];
        let mask = [1.0f32, 0.0, 1.0, 1.0, 0.0, 1.0, 1.0, 1.0, 1.0, 1.0, 0.0, 1.0];
        let rows: Vec<f32> = (0..vs.len() * d).map(|x| x as f32).collect();
        let ts: Vec<f32> = (0..vs.len()).map(|r| r as f32 + 1.0).collect();
        flat.scatter_rows(&vs, &rows, &ts, Some(&mask));
        sharded.scatter_rows(&vs, &rows, &ts, Some(&mask));
        // vertex 6: last masked occurrence is row 5
        assert_eq!(MemoryBackend::row(&sharded, 6), &rows[5 * d..6 * d]);
        assert_eq!(MemoryBackend::last_update(&sharded, 6), ts[5]);
        // vertex 2: last occurrence is row 9; vertex 4: row 11 (row 10 masked out)
        assert_eq!(MemoryBackend::row(&sharded, 2), &rows[9 * d..10 * d]);
        assert_eq!(MemoryBackend::row(&sharded, 4), &rows[11 * d..12 * d]);
        assert_eq!(MemoryBackend::snapshot(&flat), sharded.snapshot());
    }

    #[test]
    fn property_routing_covers_every_row_exactly_once() {
        prop::check_msg(
            "routing is a bijection onto shard-local rows",
            17,
            200,
            |rng: &mut Pcg32| (1 + rng.below(500), 1 + rng.below(16)),
            |&(num_nodes, n_shards)| {
                let router = ShardRouter { n_shards };
                let mut seen: Vec<Vec<bool>> = (0..n_shards)
                    .map(|s| vec![false; router.shard_len(s, num_nodes) as usize])
                    .collect();
                for v in 0..num_nodes {
                    let r = router.route(v);
                    let slot = seen
                        .get_mut(r.shard as usize)
                        .and_then(|s| s.get_mut(r.local as usize))
                        .ok_or_else(|| format!("v={v} routed out of bounds: {r:?}"))?;
                    if *slot {
                        return Err(format!("v={v} double-routed to {r:?}"));
                    }
                    *slot = true;
                }
                // every local row claimed => total == num_nodes and onto
                if seen.iter().flatten().any(|&hit| !hit) {
                    return Err("a shard-local row was never routed to".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_reset_zeroes_all_shards() {
        prop::check_msg(
            "reset() zeroes every shard",
            19,
            100,
            |rng: &mut Pcg32| {
                let mut c = gen_case(rng);
                c.gather = (0..c.num_nodes).collect(); // inspect everything
                c
            },
            |c| {
                let mut sharded = ShardedMemoryStore::new(c.num_nodes, c.d, c.n_shards);
                for (vs, rows, ts, mask) in &c.batches {
                    sharded.scatter_rows(vs, rows, ts, mask.as_deref());
                }
                sharded.reset();
                let mut out = vec![1.0; c.gather.len() * c.d];
                sharded.gather_rows_into(&c.gather, &mut out);
                if out.iter().any(|&x| x != 0.0) {
                    return Err("memory row survived reset".into());
                }
                if (0..c.num_nodes).any(|v| sharded.last_update(v) != 0.0) {
                    return Err("last_update clock survived reset".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn single_shard_layout_is_bit_identical_to_flat() {
        let mut flat = MemoryStore::new(6, 3);
        let mut one = ShardedMemoryStore::new(6, 3, 1);
        let vs = [0u32, 5, 2, 5];
        let rows: Vec<f32> = (0..12).map(|x| x as f32).collect();
        let ts = [1.0, 2.0, 3.0, 4.0];
        flat.scatter_rows(&vs, &rows, &ts, None);
        one.scatter_rows(&vs, &rows, &ts, None);
        // not just logically equal — the one shard IS the flat layout
        assert_eq!(one.shard(0).snapshot(), flat.snapshot());
        assert_eq!(one.row(5), flat.row(5));
        assert_eq!(one.bytes(), flat.bytes());
    }

    #[test]
    fn snapshot_restore_roundtrip_across_backends() {
        let mut sharded = ShardedMemoryStore::new(10, 2, 4);
        sharded.scatter(7, &[1.5, -2.5], 3.0);
        sharded.scatter(2, &[9.0, 9.0], 1.0);
        let snap = sharded.snapshot();
        // restore into a *flat* store: logical layout is interchangeable
        let mut flat = MemoryStore::new(10, 2);
        MemoryBackend::restore(&mut flat, &snap);
        assert_eq!(flat.row(7), &[1.5, -2.5]);
        assert_eq!(flat.last_update(7), 3.0);
        sharded.scatter(7, &[0.0, 0.0], 9.0);
        sharded.restore(&snap);
        assert_eq!(sharded.row(7), &[1.5, -2.5]);
        assert_eq!(sharded.last_update(7), 3.0);
    }

    #[test]
    fn stale_routes_fall_back_to_inline_routing() {
        let mut sharded = ShardedMemoryStore::new(8, 2, 4);
        sharded.scatter(6, &[4.0, 5.0], 1.0);
        let wrong_router = ShardRouter { n_shards: 2 };
        let vs = [6u32, 0];
        let mut routes = Vec::new();
        wrong_router.fill_routes(&vs, &mut routes);
        let mut out = [0.0; 4];
        // routes computed for 2 shards against a 4-shard store: ignored
        sharded.gather_rows_routed(&vs, &routes, wrong_router.n_shards, &mut out);
        assert_eq!(&out[0..2], &[4.0, 5.0]);
    }

    #[test]
    fn pooled_route_fill_matches_serial_for_any_lane_count() {
        let router = ShardRouter { n_shards: 5 };
        let mut rng = Pcg32::new(31);
        // above ROUTE_PAR_MIN_ROWS so multi-lane pools actually fan out
        let vs = prop::vertex_vec(&mut rng, 1000, 10_000);
        let mut serial = Vec::new();
        router.fill_routes(&vs, &mut serial);
        for lanes in [1usize, 2, 4] {
            let pool = WorkerPool::new(lanes);
            // stale, wrongly-sized scratch must be fully overwritten
            let mut pooled = vec![RowRoute { shard: 9, local: 9 }; 3];
            router.fill_routes_with(&vs, &mut pooled, &pool);
            assert_eq!(pooled, serial, "lanes={lanes}");
        }
    }

    #[test]
    fn shard_routes_compute_and_flat_clear() {
        let router = ShardRouter { n_shards: 3 };
        let mut routes = ShardRoutes::default();
        let u_self = vec![0u32, 4, 7];
        let u_other = vec![1u32, 2, 3];
        let c_vertex = [vec![5u32], vec![6], vec![8]];
        routes.compute(router, &u_self, &u_other, &c_vertex);
        assert_eq!(routes.n_shards, 3);
        assert_eq!(routes.u_self[1], RowRoute { shard: 1, local: 1 });
        assert_eq!(routes.c_vertex[2][0], RowRoute { shard: 2, local: 2 });
        routes.compute(ShardRouter::flat(), &u_self, &u_other, &c_vertex);
        assert_eq!(routes.n_shards, 1);
        assert!(routes.u_self.is_empty() && routes.c_vertex[0].is_empty());
    }
}
