//! Per-vertex memory state s_i(t) + last-update clocks.
//!
//! Row-major [num_nodes, d] f32 storage with O(d) gather/scatter per row.
//! The trainer resets it at epoch boundaries (S_0 <- 0, Algorithm 1) and
//! snapshots it between the train and val/test phases so evaluation
//! continues from the trained state without contaminating it.

/// Memory matrix + last-update timestamps.
#[derive(Clone, Debug)]
pub struct MemoryStore {
    d: usize,
    data: Vec<f32>,
    last_update: Vec<f32>,
}

impl MemoryStore {
    pub fn new(num_nodes: u32, d: usize) -> Self {
        MemoryStore {
            d,
            data: vec![0.0; num_nodes as usize * d],
            last_update: vec![0.0; num_nodes as usize],
        }
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    pub fn num_nodes(&self) -> usize {
        self.last_update.len()
    }

    /// Zero all state (epoch boundary; Algorithm 1's S_0 <- 0).
    pub fn reset(&mut self) {
        self.data.iter_mut().for_each(|x| *x = 0.0);
        self.last_update.iter_mut().for_each(|x| *x = 0.0);
    }

    #[inline]
    pub fn row(&self, v: u32) -> &[f32] {
        let base = v as usize * self.d;
        &self.data[base..base + self.d]
    }

    /// Copy vertex `v`'s state into `out`.
    #[inline]
    pub fn gather_into(&self, v: u32, out: &mut [f32]) {
        out.copy_from_slice(self.row(v));
    }

    /// Overwrite vertex `v`'s state.
    #[inline]
    pub fn scatter(&mut self, v: u32, values: &[f32], t: f32) {
        debug_assert_eq!(values.len(), self.d);
        let base = v as usize * self.d;
        self.data[base..base + self.d].copy_from_slice(values);
        self.last_update[v as usize] = t;
    }

    #[inline]
    pub fn last_update(&self, v: u32) -> f32 {
        self.last_update[v as usize]
    }

    /// Elapsed time since v's last update (clamped at 0 for same-time events).
    #[inline]
    pub fn dt(&self, v: u32, now: f32) -> f32 {
        (now - self.last_update[v as usize]).max(0.0)
    }

    /// Snapshot for train -> eval handoff.
    pub fn snapshot(&self) -> MemorySnapshot {
        MemorySnapshot {
            data: self.data.clone(),
            last_update: self.last_update.clone(),
        }
    }

    pub fn restore(&mut self, snap: &MemorySnapshot) {
        self.data.copy_from_slice(&snap.data);
        self.last_update.copy_from_slice(&snap.last_update);
    }

    /// Live bytes (Fig. 19 accounting).
    pub fn bytes(&self) -> usize {
        self.data.len() * 4 + self.last_update.len() * 4
    }
}

#[derive(Clone, Debug)]
pub struct MemorySnapshot {
    data: Vec<f32>,
    last_update: Vec<f32>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_scatter_roundtrip() {
        let mut m = MemoryStore::new(4, 3);
        m.scatter(2, &[1.0, 2.0, 3.0], 5.0);
        assert_eq!(m.row(2), &[1.0, 2.0, 3.0]);
        assert_eq!(m.last_update(2), 5.0);
        assert_eq!(m.row(1), &[0.0, 0.0, 0.0]);
        assert_eq!(m.dt(2, 7.5), 2.5);
        assert_eq!(m.dt(2, 4.0), 0.0); // clamped
    }

    #[test]
    fn reset_zeroes_everything() {
        let mut m = MemoryStore::new(2, 2);
        m.scatter(0, &[1.0, 1.0], 3.0);
        m.reset();
        assert_eq!(m.row(0), &[0.0, 0.0]);
        assert_eq!(m.last_update(0), 0.0);
    }

    #[test]
    fn snapshot_restore() {
        let mut m = MemoryStore::new(2, 2);
        m.scatter(1, &[4.0, 5.0], 1.0);
        let snap = m.snapshot();
        m.scatter(1, &[9.0, 9.0], 2.0);
        m.restore(&snap);
        assert_eq!(m.row(1), &[4.0, 5.0]);
        assert_eq!(m.last_update(1), 1.0);
    }
}
