//! Per-vertex memory state s_i(t) + last-update clocks.
//!
//! Row-major [num_nodes, d] f32 storage with O(d) gather/scatter per row.
//! The trainer resets it at epoch boundaries (S_0 <- 0, Algorithm 1) and
//! snapshots it between the train and val/test phases so evaluation
//! continues from the trained state without contaminating it.
//!
//! This flat store is the `--memory-shards 1` backend and doubles as the
//! building block of the sharded backend (`shard.rs`), which owns one
//! `MemoryStore` per shard.

use crate::memory::shard::ShardRouter;
use crate::memory::MemoryBackend;

/// Memory matrix + last-update timestamps.
#[derive(Clone, Debug)]
pub struct MemoryStore {
    d: usize,
    data: Vec<f32>,
    last_update: Vec<f32>,
}

impl MemoryStore {
    pub fn new(num_nodes: u32, d: usize) -> Self {
        MemoryStore {
            d,
            data: vec![0.0; num_nodes as usize * d],
            last_update: vec![0.0; num_nodes as usize],
        }
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    /// Backing-storage views for checked-claims registration: a pooled
    /// scatter task claims the whole shard it exclusively owns (see
    /// `util::pool::claims`). Gated like the checker so release builds
    /// carry no extra surface.
    #[cfg(any(debug_assertions, feature = "checked-claims"))]
    pub(crate) fn claim_ranges(&self) -> (&[f32], &[f32]) {
        (&self.data, &self.last_update)
    }

    pub fn num_nodes(&self) -> usize {
        self.last_update.len()
    }

    /// Zero all state (epoch boundary; Algorithm 1's S_0 <- 0).
    /// `fill` lowers to memset — the element-wise loop this replaces was
    /// measurable at gdelt scale (|V| * d floats every epoch).
    pub fn reset(&mut self) {
        self.data.fill(0.0);
        self.last_update.fill(0.0);
    }

    #[inline]
    pub fn row(&self, v: u32) -> &[f32] {
        let base = v as usize * self.d;
        &self.data[base..base + self.d]
    }

    /// Copy vertex `v`'s state into `out`.
    #[inline]
    pub fn gather_into(&self, v: u32, out: &mut [f32]) {
        out.copy_from_slice(self.row(v));
    }

    /// Overwrite vertex `v`'s state.
    #[inline]
    pub fn scatter(&mut self, v: u32, values: &[f32], t: f32) {
        debug_assert_eq!(values.len(), self.d);
        let base = v as usize * self.d;
        self.data[base..base + self.d].copy_from_slice(values);
        self.last_update[v as usize] = t;
    }

    /// Batched gather: `out[i*d..(i+1)*d] = row(vs[i])`. The SPLICE stage's
    /// workhorse — one call per tensor instead of one `row()` per vertex.
    pub fn gather_rows_into(&self, vs: &[u32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), vs.len() * self.d);
        for (slot, &v) in out.chunks_exact_mut(self.d).zip(vs) {
            let base = v as usize * self.d;
            slot.copy_from_slice(&self.data[base..base + self.d]);
        }
    }

    /// Batched scatter used by the WRITEBACK stage: for every row `r` with
    /// `mask[r] == 1.0` (or every row when `mask` is `None`), overwrite
    /// vertex `vs[r]`'s state with `rows[r*d..]` and stamp its clock with
    /// `ts[r]`. Rows targeting the same vertex apply in order, so the
    /// caller's last masked row wins — matching the batch-plan dedup.
    pub fn scatter_rows(&mut self, vs: &[u32], rows: &[f32], ts: &[f32], mask: Option<&[f32]>) {
        debug_assert_eq!(rows.len(), vs.len() * self.d);
        debug_assert_eq!(ts.len(), vs.len());
        if let Some(m) = mask {
            debug_assert_eq!(m.len(), vs.len());
        }
        for (r, (&v, row)) in vs.iter().zip(rows.chunks_exact(self.d)).enumerate() {
            if let Some(m) = mask {
                if m[r] != 1.0 {
                    continue;
                }
            }
            let base = v as usize * self.d;
            self.data[base..base + self.d].copy_from_slice(row);
            self.last_update[v as usize] = ts[r];
        }
    }

    #[inline]
    pub fn last_update(&self, v: u32) -> f32 {
        self.last_update[v as usize]
    }

    /// Elapsed time since v's last update (clamped at 0 for same-time events).
    #[inline]
    pub fn dt(&self, v: u32, now: f32) -> f32 {
        (now - self.last_update[v as usize]).max(0.0)
    }

    /// Snapshot for train -> eval handoff.
    pub fn snapshot(&self) -> MemorySnapshot {
        MemorySnapshot {
            data: self.data.clone(),
            last_update: self.last_update.clone(),
        }
    }

    pub fn restore(&mut self, snap: &MemorySnapshot) {
        self.data.copy_from_slice(&snap.data);
        self.last_update.copy_from_slice(&snap.last_update);
    }

    /// Live bytes (Fig. 19 accounting).
    pub fn bytes(&self) -> usize {
        self.data.len() * 4 + self.last_update.len() * 4
    }
}

/// The flat store IS the single-shard layout, so the trait impl forwards
/// to the inherent methods and reports the identity routing. Keeping the
/// legacy type as the `--memory-shards 1` backend (rather than a 1-shard
/// [`crate::memory::ShardedMemoryStore`]) makes "N = 1 is exactly today's
/// store" true by construction.
impl MemoryBackend for MemoryStore {
    fn dim(&self) -> usize {
        MemoryStore::dim(self)
    }

    fn num_nodes(&self) -> usize {
        MemoryStore::num_nodes(self)
    }

    fn router(&self) -> ShardRouter {
        ShardRouter::flat()
    }

    fn reset(&mut self) {
        MemoryStore::reset(self)
    }

    fn row(&self, v: u32) -> &[f32] {
        MemoryStore::row(self, v)
    }

    fn last_update(&self, v: u32) -> f32 {
        MemoryStore::last_update(self, v)
    }

    fn scatter(&mut self, v: u32, values: &[f32], t: f32) {
        MemoryStore::scatter(self, v, values, t)
    }

    fn gather_rows_into(&self, vs: &[u32], out: &mut [f32]) {
        MemoryStore::gather_rows_into(self, vs, out)
    }

    fn scatter_rows(&mut self, vs: &[u32], rows: &[f32], ts: &[f32], mask: Option<&[f32]>) {
        MemoryStore::scatter_rows(self, vs, rows, ts, mask)
    }

    fn snapshot(&self) -> MemorySnapshot {
        MemoryStore::snapshot(self)
    }

    fn restore(&mut self, snap: &MemorySnapshot) {
        MemoryStore::restore(self, snap)
    }

    fn bytes(&self) -> usize {
        MemoryStore::bytes(self)
    }
}

/// Memory state in *logical* (flat, vertex-major) row order, whatever the
/// backend's physical layout — snapshots of a flat and a sharded store
/// holding the same state compare equal (`PartialEq` is the equivalence
/// harness's bit-exactness check).
#[derive(Clone, Debug, PartialEq)]
pub struct MemorySnapshot {
    data: Vec<f32>,
    last_update: Vec<f32>,
}

impl MemorySnapshot {
    pub(crate) fn from_parts(data: Vec<f32>, last_update: Vec<f32>) -> MemorySnapshot {
        MemorySnapshot { data, last_update }
    }

    pub(crate) fn parts(&self) -> (&[f32], &[f32]) {
        (&self.data, &self.last_update)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_scatter_roundtrip() {
        let mut m = MemoryStore::new(4, 3);
        m.scatter(2, &[1.0, 2.0, 3.0], 5.0);
        assert_eq!(m.row(2), &[1.0, 2.0, 3.0]);
        assert_eq!(m.last_update(2), 5.0);
        assert_eq!(m.row(1), &[0.0, 0.0, 0.0]);
        assert_eq!(m.dt(2, 7.5), 2.5);
        assert_eq!(m.dt(2, 4.0), 0.0); // clamped
    }

    #[test]
    fn reset_zeroes_everything() {
        let mut m = MemoryStore::new(2, 2);
        m.scatter(0, &[1.0, 1.0], 3.0);
        m.reset();
        assert_eq!(m.row(0), &[0.0, 0.0]);
        assert_eq!(m.last_update(0), 0.0);
    }

    #[test]
    fn gather_rows_matches_single_row_gather() {
        let mut m = MemoryStore::new(5, 2);
        m.scatter(1, &[1.0, 2.0], 1.0);
        m.scatter(4, &[7.0, 8.0], 2.0);
        let mut out = vec![0.0; 6];
        m.gather_rows_into(&[4, 1, 4], &mut out);
        assert_eq!(out, vec![7.0, 8.0, 1.0, 2.0, 7.0, 8.0]);
    }

    #[test]
    fn scatter_rows_respects_mask_and_last_write_wins() {
        let mut m = MemoryStore::new(4, 2);
        let vs = [0u32, 2, 0];
        let rows = [1.0, 1.0, 2.0, 2.0, 3.0, 3.0];
        let ts = [1.0, 2.0, 3.0];
        m.scatter_rows(&vs, &rows, &ts, Some(&[1.0, 0.0, 1.0]));
        // vertex 0: both rows masked in -> last one wins
        assert_eq!(m.row(0), &[3.0, 3.0]);
        assert_eq!(m.last_update(0), 3.0);
        // vertex 2: masked out -> untouched
        assert_eq!(m.row(2), &[0.0, 0.0]);
        assert_eq!(m.last_update(2), 0.0);
        // no mask -> every row lands
        m.scatter_rows(&vs, &rows, &ts, None);
        assert_eq!(m.row(2), &[2.0, 2.0]);
        assert_eq!(m.last_update(2), 2.0);
    }

    #[test]
    fn snapshot_restore() {
        let mut m = MemoryStore::new(2, 2);
        m.scatter(1, &[4.0, 5.0], 1.0);
        let snap = m.snapshot();
        m.scatter(1, &[9.0, 9.0], 2.0);
        m.restore(&snap);
        assert_eq!(m.row(1), &[4.0, 5.0]);
        assert_eq!(m.last_update(1), 1.0);
    }
}
