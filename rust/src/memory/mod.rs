//! Vertex memory state: the MDGNN's stateful substrate, owned by the
//! coordinator (the executables only ever see gathered rows; DESIGN.md §1).
//!
//! The memory matrix itself lives behind [`MemoryBackend`]: the flat
//! single-buffer [`MemoryStore`] (the `--memory-shards 1` legacy layout)
//! or the [`ShardedMemoryStore`], which partitions rows across owned
//! shards so SPLICE/WRITEBACK fan out across cores (see `shard.rs` for the
//! routing policy and the no-lock ownership story). Both are bit-identical
//! in values — sharding changes layout, never results.

pub mod gmm;
pub mod mailbox;
pub mod shard;
pub mod store;

use std::sync::Arc;

use crate::util::pool::WorkerPool;

pub use gmm::GmmTrackers;
pub use mailbox::Mailbox;
pub use shard::{RowRoute, ShardRouter, ShardRoutes, ShardedMemoryStore};
pub use store::{MemorySnapshot, MemoryStore};

/// Common interface over the flat and sharded memory stores: everything the
/// assembler's SPLICE/WRITEBACK stages and the trainer's epoch machinery
/// touch. The trainer holds the closed [`MemoryBackendKind`] enum (so the
/// per-row scalar reads in the splice pass compile to a branch + direct
/// call instead of a vtable hop), but the trait stays object-safe for
/// callers that genuinely want `&dyn MemoryBackend`.
///
/// The `*_routed` methods accept per-row [`RowRoute`]s precomputed by the
/// PREP stage (off the coordinator thread); the default impls ignore them —
/// only the sharded backend overrides, and it falls back to inline routing
/// whenever the routes were computed for a different shard count.
pub trait MemoryBackend {
    /// Memory dimension `d`.
    fn dim(&self) -> usize;
    /// Logical vertex count (across all shards).
    fn num_nodes(&self) -> usize;
    /// The backend's routing policy, for PREP-side route precomputation.
    fn router(&self) -> ShardRouter;
    /// Zero all state (epoch boundary; Algorithm 1's S_0 <- 0).
    fn reset(&mut self);
    /// Vertex `v`'s state row (contiguous in every backend).
    fn row(&self, v: u32) -> &[f32];
    /// Vertex `v`'s last-update clock.
    fn last_update(&self, v: u32) -> f32;
    /// Overwrite one vertex's state + clock.
    fn scatter(&mut self, v: u32, values: &[f32], t: f32);
    /// Batched gather: `out[i*d..(i+1)*d] = row(vs[i])` (SPLICE workhorse).
    fn gather_rows_into(&self, vs: &[u32], out: &mut [f32]);
    /// [`MemoryBackend::gather_rows_into`] with routes precomputed for
    /// `routes_shards` shards; ignored unless they match this backend.
    fn gather_rows_routed(
        &self,
        vs: &[u32],
        routes: &[RowRoute],
        routes_shards: u32,
        out: &mut [f32],
    ) {
        let _ = (routes, routes_shards);
        self.gather_rows_into(vs, out);
    }
    /// Batched scatter (WRITEBACK): masked rows land in order, so the last
    /// masked row targeting a vertex wins — matching the batch-plan dedup.
    fn scatter_rows(&mut self, vs: &[u32], rows: &[f32], ts: &[f32], mask: Option<&[f32]>);
    /// [`MemoryBackend::scatter_rows`] with precomputed routes (same
    /// contract as [`MemoryBackend::gather_rows_routed`]).
    fn scatter_rows_routed(
        &mut self,
        vs: &[u32],
        rows: &[f32],
        ts: &[f32],
        mask: Option<&[f32]>,
        routes: &[RowRoute],
        routes_shards: u32,
    ) {
        let _ = (routes, routes_shards);
        self.scatter_rows(vs, rows, ts, mask);
    }
    /// Snapshot in logical row order (train -> eval handoff; comparable
    /// across backends).
    fn snapshot(&self) -> MemorySnapshot;
    fn restore(&mut self, snap: &MemorySnapshot);
    /// Live bytes (Fig. 19 accounting).
    fn bytes(&self) -> usize;
}

/// The closed set of memory layouts, dispatched by `match` instead of
/// vtable. The splice scalar passes (`training/assembler.rs`) read
/// `row`/`last_update` once per update row; with the trainer monomorphized
/// over this enum those reads devirtualize — the compiler sees both
/// concrete bodies and the two-way branch next to them, instead of an
/// opaque indirect call between every pair of batched copies.
#[derive(Clone, Debug)]
pub enum MemoryBackendKind {
    /// The exact legacy flat layout (`--memory-shards 1`).
    Flat(MemoryStore),
    /// Row-interleaved shards with pooled parallel gather/scatter.
    Sharded(ShardedMemoryStore),
}

macro_rules! dispatch {
    ($self:ident, $s:ident => $body:expr) => {
        match $self {
            MemoryBackendKind::Flat($s) => $body,
            MemoryBackendKind::Sharded($s) => $body,
        }
    };
}

impl MemoryBackend for MemoryBackendKind {
    fn dim(&self) -> usize {
        dispatch!(self, s => MemoryBackend::dim(s))
    }

    fn num_nodes(&self) -> usize {
        dispatch!(self, s => MemoryBackend::num_nodes(s))
    }

    fn router(&self) -> ShardRouter {
        dispatch!(self, s => s.router())
    }

    fn reset(&mut self) {
        dispatch!(self, s => MemoryBackend::reset(s))
    }

    fn row(&self, v: u32) -> &[f32] {
        dispatch!(self, s => MemoryBackend::row(s, v))
    }

    fn last_update(&self, v: u32) -> f32 {
        dispatch!(self, s => MemoryBackend::last_update(s, v))
    }

    fn scatter(&mut self, v: u32, values: &[f32], t: f32) {
        dispatch!(self, s => MemoryBackend::scatter(s, v, values, t))
    }

    fn gather_rows_into(&self, vs: &[u32], out: &mut [f32]) {
        dispatch!(self, s => MemoryBackend::gather_rows_into(s, vs, out))
    }

    fn gather_rows_routed(
        &self,
        vs: &[u32],
        routes: &[RowRoute],
        routes_shards: u32,
        out: &mut [f32],
    ) {
        dispatch!(self, s => s.gather_rows_routed(vs, routes, routes_shards, out))
    }

    fn scatter_rows(&mut self, vs: &[u32], rows: &[f32], ts: &[f32], mask: Option<&[f32]>) {
        dispatch!(self, s => MemoryBackend::scatter_rows(s, vs, rows, ts, mask))
    }

    fn scatter_rows_routed(
        &mut self,
        vs: &[u32],
        rows: &[f32],
        ts: &[f32],
        mask: Option<&[f32]>,
        routes: &[RowRoute],
        routes_shards: u32,
    ) {
        dispatch!(self, s => s.scatter_rows_routed(vs, rows, ts, mask, routes, routes_shards))
    }

    fn snapshot(&self) -> MemorySnapshot {
        dispatch!(self, s => MemoryBackend::snapshot(s))
    }

    fn restore(&mut self, snap: &MemorySnapshot) {
        dispatch!(self, s => MemoryBackend::restore(s, snap))
    }

    fn bytes(&self) -> usize {
        dispatch!(self, s => MemoryBackend::bytes(s))
    }
}

/// Build the memory backend for a shard count: `shards <= 1` returns the
/// flat legacy [`MemoryStore`] itself (exact `--memory-shards 1`
/// compatibility by construction), anything larger a [`ShardedMemoryStore`]
/// on the shared process pool.
pub fn make_backend(num_nodes: u32, d: usize, shards: usize) -> MemoryBackendKind {
    make_backend_pooled(num_nodes, d, shards, WorkerPool::global().clone())
}

/// [`make_backend`] with an explicit worker pool for the sharded layout's
/// parallel gather/scatter (the trainer passes its `--pool-workers` pool;
/// the flat layout has no parallel paths and ignores it).
pub fn make_backend_pooled(
    num_nodes: u32,
    d: usize,
    shards: usize,
    pool: Arc<WorkerPool>,
) -> MemoryBackendKind {
    if shards <= 1 {
        MemoryBackendKind::Flat(MemoryStore::new(num_nodes, d))
    } else {
        MemoryBackendKind::Sharded(ShardedMemoryStore::new(num_nodes, d, shards).with_pool(pool))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn make_backend_picks_layout_by_shard_count() {
        let flat = make_backend(10, 4, 1);
        assert_eq!(flat.router(), ShardRouter::flat());
        assert_eq!(flat.num_nodes(), 10);
        let sharded = make_backend(10, 4, 4);
        assert_eq!(sharded.router().n_shards, 4);
        assert_eq!(sharded.num_nodes(), 10);
        assert_eq!(sharded.dim(), flat.dim());
        // zero shards degrades to flat rather than panicking
        assert_eq!(make_backend(10, 4, 0).router(), ShardRouter::flat());
    }

    #[test]
    fn backend_kind_picks_the_right_variant() {
        assert!(matches!(make_backend(10, 4, 1), MemoryBackendKind::Flat(_)));
        assert!(matches!(make_backend(10, 4, 3), MemoryBackendKind::Sharded(_)));
        let pool = Arc::new(WorkerPool::new(2));
        assert!(matches!(make_backend_pooled(10, 4, 0, pool), MemoryBackendKind::Flat(_)));
    }

    #[test]
    fn backends_agree_through_the_trait_surface() {
        let mut a = make_backend(9, 3, 1);
        let mut b = make_backend(9, 3, 3);
        for (v, t) in [(0u32, 1.0f32), (8, 2.0), (4, 3.0)] {
            let row: Vec<f32> = (0..3).map(|i| v as f32 + i as f32 + t).collect();
            a.scatter(v, &row, t);
            b.scatter(v, &row, t);
        }
        assert_eq!(a.snapshot(), b.snapshot());
        assert_eq!(a.row(8), b.row(8));
        assert_eq!(a.last_update(4), b.last_update(4));
    }
}
