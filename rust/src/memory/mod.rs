//! Vertex memory state: the MDGNN's stateful substrate, owned by the
//! coordinator (the executables only ever see gathered rows; DESIGN.md §1).

pub mod gmm;
pub mod mailbox;
pub mod store;

pub use gmm::GmmTrackers;
pub use mailbox::Mailbox;
pub use store::MemoryStore;
