//! PRES prediction model: per-vertex Gaussian mixture over memory-state
//! transition rates (paper §5.1, Eq. 7 & 9).
//!
//! The paper models the change delta_s of each vertex's memory with a
//! 2-component GMM and predicts s_hat(t2) = s(t1) + (t2 - t1) * delta_s.
//! Applying MLE naively would need the full history; Eq. 9's trackers
//! reduce it to running sums. Two rate estimators coexist, each matched to
//! its use:
//!
//! * **prediction** uses the time-weighted rate `mu = xi / tau`
//!   (sum of deltas over sum of elapsed time) — robust to near-zero
//!   per-event dt, where a mean of per-event rates explodes;
//! * **variance** is over the *per-event* rates `r_k = delta_k / dt_k`:
//!   `Sigma = psi / n - (rho / n)^2` (diagonal), with `rho` the running
//!   sum of rates and `psi` the running sum of their squares. Mean and
//!   second moment come from the same estimator, so `Sigma >= 0` up to
//!   float rounding by construction (the `max(0)` clamp only absorbs
//!   rounding, never a systematic inconsistency).
//!
//! Component assignment: the two mixture components correspond to the two
//! event *roles* a vertex's update can arrive from — source-side vs
//! destination-side (in the paper's temporal-link-prediction framing these
//! are its "positive event types"; in bipartite streams they are genuinely
//! different populations with different drift statistics). Prediction uses
//! the component of the role being updated; the mixture weights alpha_j
//! follow from the counts.
//!
//! Storage is O(|V| * 2 * d) for the full tracker set; the *anchor set*
//! heuristic (paper §5.3) tracks only a hash-selected fraction of vertices
//! and falls back to a zero-drift prediction (s_hat = s(t1)) elsewhere.

use crate::util::rng::splitmix64;

/// Update-role of a memory transition (the GMM component selector).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Src = 0,
    Dst = 1,
}

#[derive(Clone, Debug)]
pub struct GmmTrackers {
    d: usize,
    /// vertex -> tracked slot, u32::MAX when outside the anchor set.
    slot: Vec<u32>,
    /// [slots * 2] event counts n_i^(j).
    n: Vec<u32>,
    /// [slots * 2] accumulated elapsed time per component.
    tau: Vec<f32>,
    /// [slots * 2 * d] running sums xi_i^(j) of state deltas.
    xi: Vec<f32>,
    /// [slots * 2 * d] running sums rho_i^(j) of per-event rates.
    rho: Vec<f32>,
    /// [slots * 2 * d] running square sums psi_i^(j) of per-event rates.
    psi: Vec<f32>,
}

/// Elapsed-time floor shared by the rate denominator and the accumulated
/// time `tau`: a `dt = 0` burst contributes one bounded rate sample AND the
/// matching sliver of accumulated time, keeping the two estimators
/// consistent (previously `tau` gained nothing while the rate divided by
/// the floor).
const DT_FLOOR: f32 = 1e-3;

impl GmmTrackers {
    /// `anchor_fraction` = 1.0 tracks every vertex; < 1.0 tracks a stable
    /// hash-selected subset (the anchor set).
    pub fn new(num_nodes: u32, d: usize, anchor_fraction: f32, seed: u64) -> Self {
        let mut slot = vec![u32::MAX; num_nodes as usize];
        let threshold = (anchor_fraction.clamp(0.0, 1.0) as f64 * u32::MAX as f64) as u64;
        let mut next = 0u32;
        // fraction 0.0 must track NOTHING: with `hash <= threshold` a zero
        // threshold would still admit every vertex whose 32-bit hash is 0
        if threshold > 0 {
            for (v, s) in slot.iter_mut().enumerate() {
                let mut h = seed ^ (v as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                let hash = splitmix64(&mut h) as u32 as u64;
                if hash <= threshold {
                    *s = next;
                    next += 1;
                }
            }
        }
        GmmTrackers {
            d,
            slot,
            n: vec![0; next as usize * 2],
            tau: vec![0.0; next as usize * 2],
            xi: vec![0.0; next as usize * 2 * d],
            rho: vec![0.0; next as usize * 2 * d],
            psi: vec![0.0; next as usize * 2 * d],
        }
    }

    pub fn tracked_vertices(&self) -> usize {
        self.n.len() / 2
    }

    pub fn is_tracked(&self, v: u32) -> bool {
        self.slot[v as usize] != u32::MAX
    }

    #[inline]
    fn base(&self, v: u32, role: Role) -> Option<usize> {
        let s = self.slot[v as usize];
        if s == u32::MAX {
            None
        } else {
            Some((s as usize * 2 + role as usize) * self.d)
        }
    }

    /// Predict s_hat(t2) = s(t1) + dt * mu (Eq. 7) into `out`, where the
    /// rate mu is the time-weighted MLE mu = (sum of deltas) / (sum of dt).
    /// The ratio-of-sums estimator is robust to near-zero per-event dt
    /// (a mean of per-event rates explodes on bursty vertices). Untracked
    /// or unseen vertices predict zero drift (s_hat = s(t1)), making the
    /// correction a no-op for them regardless of gamma.
    pub fn predict_into(&self, v: u32, role: Role, s_t1: &[f32], dt: f32, out: &mut [f32]) {
        debug_assert_eq!(s_t1.len(), self.d);
        match self.base(v, role) {
            Some(base) => {
                let k = base / self.d;
                if self.n[k] == 0 || self.tau[k] <= 1e-9 {
                    out.copy_from_slice(s_t1);
                    return;
                }
                let inv_tau = 1.0 / self.tau[k];
                for i in 0..self.d {
                    let mu = self.xi[base + i] * inv_tau;
                    out[i] = s_t1[i] + dt * mu;
                }
            }
            None => out.copy_from_slice(s_t1),
        }
    }

    /// Fold one observed transition delta = s_bar(t2) - s(t1) over elapsed
    /// time dt into the trackers (Eq. 9).
    pub fn observe(&mut self, v: u32, role: Role, s_t1: &[f32], s_bar: &[f32], dt: f32) {
        let Some(base) = self.base(v, role) else { return };
        let k = base / self.d;
        // one floor for BOTH the accumulated time and the rate denominator
        // (see DT_FLOOR): a zero-dt burst cannot contribute a rate sample
        // while adding zero accumulated time
        let dt_eff = dt.max(DT_FLOOR);
        self.n[k] += 1;
        self.tau[k] += dt_eff;
        let inv_dt = 1.0 / dt_eff;
        for i in 0..self.d {
            let delta = s_bar[i] - s_t1[i];
            self.xi[base + i] += delta;
            let r = delta * inv_dt;
            self.rho[base + i] += r;
            self.psi[base + i] += r * r;
        }
    }

    /// Component mean rate mu_i^(j) (Eq. 9); None when untracked/unseen.
    pub fn mean(&self, v: u32, role: Role) -> Option<Vec<f32>> {
        let base = self.base(v, role)?;
        let k = base / self.d;
        if self.n[k] == 0 || self.tau[k] <= 1e-9 {
            return None;
        }
        let inv_tau = 1.0 / self.tau[k];
        Some((0..self.d).map(|i| self.xi[base + i] * inv_tau).collect())
    }

    /// Diagonal variance of the per-event rates, Sigma_i^(j) =
    /// psi/n - (rho/n)^2 (Eq. 9): mean and second moment both come from
    /// the per-event rate samples, so the estimator is consistent and
    /// non-negative up to float rounding (the clamp only absorbs rounding).
    pub fn variance(&self, v: u32, role: Role) -> Option<Vec<f32>> {
        let base = self.base(v, role)?;
        let k = base / self.d;
        let count = self.n[k];
        if count == 0 || self.tau[k] <= 1e-9 {
            return None;
        }
        let inv = 1.0 / count as f32;
        let mut clamped = 0u64;
        let out = (0..self.d)
            .map(|i| {
                let mean_rate = self.rho[base + i] * inv;
                let raw = self.psi[base + i] * inv - mean_rate * mean_rate;
                if raw < 0.0 {
                    clamped += 1;
                }
                raw.max(0.0)
            })
            .collect();
        crate::trace::telemetry::count_gmm_var_clamps(clamped);
        Some(out)
    }

    /// Observation count n_i^(j) (0 when untracked).
    pub fn count(&self, v: u32, role: Role) -> u32 {
        match self.base(v, role) {
            Some(base) => self.n[base / self.d],
            None => 0,
        }
    }

    /// Mixture weights alpha_j = n_j / (n_0 + n_1) for vertex `v`.
    pub fn alpha(&self, v: u32) -> Option<[f32; 2]> {
        let s = self.slot[v as usize];
        if s == u32::MAX {
            return None;
        }
        let n0 = self.n[s as usize * 2] as f32;
        let n1 = self.n[s as usize * 2 + 1] as f32;
        let total = n0 + n1;
        if total == 0.0 {
            return None;
        }
        Some([n0 / total, n1 / total])
    }

    /// Reset all trackers (epoch boundary, Algorithm 2's xi,psi,n <- 0).
    pub fn reset(&mut self) {
        self.n.iter_mut().for_each(|x| *x = 0);
        self.tau.iter_mut().for_each(|x| *x = 0.0);
        self.xi.iter_mut().for_each(|x| *x = 0.0);
        self.rho.iter_mut().for_each(|x| *x = 0.0);
        self.psi.iter_mut().for_each(|x| *x = 0.0);
    }

    /// Live bytes (Fig. 19 accounting; O(anchor_fraction * |V| * d)).
    pub fn bytes(&self) -> usize {
        self.slot.len() * 4
            + (self.n.len() + self.tau.len()) * 4
            + (self.xi.len() + self.rho.len() + self.psi.len()) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg32;

    #[test]
    fn predict_before_any_observation_is_identity() {
        let g = GmmTrackers::new(4, 3, 1.0, 0);
        let s = [1.0, 2.0, 3.0];
        let mut out = [0.0; 3];
        g.predict_into(1, Role::Src, &s, 10.0, &mut out);
        assert_eq!(out, s);
    }

    #[test]
    fn tracker_learns_constant_rate() {
        let mut g = GmmTrackers::new(2, 2, 1.0, 0);
        // transitions with rate exactly [0.5, -1.0]
        let mut s = vec![0.0f32, 0.0];
        for step in 0..10 {
            let dt = 1.0 + (step % 3) as f32;
            let s2 = vec![s[0] + 0.5 * dt, s[1] - 1.0 * dt];
            g.observe(0, Role::Src, &s, &s2, dt);
            s = s2;
        }
        let mu = g.mean(0, Role::Src).unwrap();
        assert!((mu[0] - 0.5).abs() < 1e-5);
        assert!((mu[1] + 1.0).abs() < 1e-5);
        let var = g.variance(0, Role::Src).unwrap();
        assert!(var[0] < 1e-6 && var[1] < 1e-6);
        // prediction extrapolates the rate
        let mut out = [0.0; 2];
        g.predict_into(0, Role::Src, &[2.0, 2.0], 4.0, &mut out);
        assert!((out[0] - 4.0).abs() < 1e-5);
        assert!((out[1] + 2.0).abs() < 1e-5);
    }

    #[test]
    fn roles_are_independent_components() {
        let mut g = GmmTrackers::new(1, 1, 1.0, 0);
        g.observe(0, Role::Src, &[0.0], &[1.0], 1.0);
        g.observe(0, Role::Dst, &[0.0], &[-1.0], 1.0);
        assert_eq!(g.mean(0, Role::Src).unwrap()[0], 1.0);
        assert_eq!(g.mean(0, Role::Dst).unwrap()[0], -1.0);
        assert_eq!(g.alpha(0).unwrap(), [0.5, 0.5]);
    }

    #[test]
    fn anchor_fraction_limits_tracking() {
        let g_full = GmmTrackers::new(1000, 2, 1.0, 7);
        assert_eq!(g_full.tracked_vertices(), 1000);
        let g_half = GmmTrackers::new(1000, 2, 0.5, 7);
        let frac = g_half.tracked_vertices() as f64 / 1000.0;
        assert!((0.4..0.6).contains(&frac), "{frac}");
        assert!(g_half.bytes() < g_full.bytes());
        // untracked vertices predict zero drift
        let v = (0..1000u32).find(|&v| !g_half.is_tracked(v)).unwrap();
        let mut out = [0.0; 2];
        g_half.predict_into(v, Role::Src, &[3.0, 4.0], 5.0, &mut out);
        assert_eq!(out, [3.0, 4.0]);
    }

    #[test]
    fn reset_clears_state() {
        let mut g = GmmTrackers::new(2, 1, 1.0, 0);
        g.observe(0, Role::Src, &[0.0], &[1.0], 1.0);
        g.reset();
        assert!(g.mean(0, Role::Src).is_none());
    }

    #[test]
    fn reset_is_equivalent_to_fresh_trackers() {
        // Algorithm 2 zeroes (n, tau, xi, psi) at each epoch start; a
        // reset tracker must be indistinguishable from a fresh one — same
        // anchor set, same fallbacks, and identical statistics after the
        // next epoch's observations.
        let mut g = GmmTrackers::new(64, 2, 0.5, 3);
        let fresh = GmmTrackers::new(64, 2, 0.5, 3);
        let v = (0..64u32).find(|&v| g.is_tracked(v)).unwrap_or(0);
        g.observe(v, Role::Src, &[0.0, 0.0], &[1.0, -1.0], 2.0);
        g.observe(v, Role::Dst, &[1.0, 1.0], &[0.0, 3.0], 1.0);
        g.reset();
        assert_eq!(g.count(v, Role::Src), 0);
        assert_eq!(g.count(v, Role::Dst), 0);
        assert!(g.mean(v, Role::Src).is_none());
        assert!(g.variance(v, Role::Dst).is_none());
        assert!(g.alpha(v).is_none());
        // the anchor set survives the reset (it is seed-derived, not state)
        assert_eq!(g.tracked_vertices(), fresh.tracked_vertices());
        assert_eq!(g.bytes(), fresh.bytes());
        // next-epoch observations replay identically on reset vs fresh
        let mut f = fresh.clone();
        g.observe(v, Role::Src, &[0.5, 0.5], &[1.5, 2.5], 4.0);
        f.observe(v, Role::Src, &[0.5, 0.5], &[1.5, 2.5], 4.0);
        assert_eq!(g.mean(v, Role::Src), f.mean(v, Role::Src));
        assert_eq!(g.variance(v, Role::Src), f.variance(v, Role::Src));
        assert_eq!(g.alpha(v), f.alpha(v));
    }

    #[test]
    fn clone_snapshot_is_independent_of_the_original() {
        // the epoch machinery may clone trackers for a side computation;
        // observations on the original must not bleed into the snapshot.
        let mut g = GmmTrackers::new(2, 1, 1.0, 0);
        g.observe(0, Role::Src, &[0.0], &[2.0], 1.0);
        let snap = g.clone();
        g.observe(0, Role::Src, &[2.0], &[6.0], 1.0);
        assert_eq!(snap.count(0, Role::Src), 1);
        assert_eq!(snap.mean(0, Role::Src).unwrap()[0], 2.0);
        assert_eq!(g.count(0, Role::Src), 2);
        assert_eq!(g.mean(0, Role::Src).unwrap()[0], 3.0);
        // restoring by assignment rewinds the trajectory
        g = snap;
        assert_eq!(g.count(0, Role::Src), 1);
    }

    #[test]
    fn anchor_fraction_zero_tracks_nothing() {
        // regression: `hash <= threshold` with threshold 0 used to keep
        // every vertex whose 32-bit hash is exactly 0 in the anchor set
        for seed in 0..8u64 {
            let g = GmmTrackers::new(1 << 16, 2, 0.0, seed);
            assert_eq!(g.tracked_vertices(), 0, "seed {seed}");
            assert!((0..1u32 << 16).all(|v| !g.is_tracked(v)));
        }
        // untracked everywhere -> every prediction is the identity
        let mut g = GmmTrackers::new(16, 2, 0.0, 1);
        g.observe(3, Role::Src, &[0.0, 0.0], &[5.0, 5.0], 1.0); // no-op
        let mut out = [0.0; 2];
        g.predict_into(3, Role::Src, &[1.0, -1.0], 10.0, &mut out);
        assert_eq!(out, [1.0, -1.0]);
    }

    #[test]
    fn zero_dt_burst_accumulates_time_and_rate_consistently() {
        // regression: dt = 0 used to add a rate sample (divided by the
        // 1e-3 floor) while adding ZERO accumulated time — now both sides
        // use the same floor
        let mut g = GmmTrackers::new(1, 1, 1.0, 0);
        g.observe(0, Role::Src, &[0.0], &[2.0], 0.0);
        assert_eq!(g.count(0, Role::Src), 1);
        // tau gained the same floored dt the rate divided by
        let mu = g.mean(0, Role::Src).unwrap();
        assert!((mu[0] - 2.0 / 1e-3).abs() < 1.0, "mu {}", mu[0]);
        // a single sample has zero variance under the consistent estimator
        let var = g.variance(0, Role::Src).unwrap();
        assert!(var[0].abs() < 1e-3 * (2.0f32 / 1e-3).powi(2), "var {}", var[0]);
        // negative dt clamps to the same floor as zero
        let mut h = GmmTrackers::new(1, 1, 1.0, 0);
        h.observe(0, Role::Src, &[0.0], &[2.0], -5.0);
        assert_eq!(h.mean(0, Role::Src), g.mean(0, Role::Src));
    }

    #[test]
    fn variance_is_nonnegative_before_clamp_under_mixed_dt() {
        // regression for the mixed estimator Sigma = psi/n - (xi/tau)^2:
        // when slow transitions carry large deltas, the time-weighted mean
        // exceeds the rms per-event rate and the old formula went
        // systematically negative — silently clamped to 0. The consistent
        // estimator must equal the naive per-event-rate sample variance.
        let stream = [(10.0f32, 100.0f32), (0.1, 0.01)]; // (dt, delta)
        let mut g = GmmTrackers::new(1, 1, 1.0, 0);
        let mut rates: Vec<f64> = Vec::new();
        for &(dt, delta) in &stream {
            g.observe(0, Role::Src, &[0.0], &[delta], dt);
            rates.push((delta / dt) as f64);
        }
        let n = rates.len() as f64;
        let second = rates.iter().map(|r| r * r).sum::<f64>() / n;
        let m_r = rates.iter().sum::<f64>() / n;
        let naive = second - m_r * m_r;
        assert!(naive > 1.0, "scenario sanity: {naive}");
        // the old formula really is negative on this stream
        let total_dt: f64 = stream.iter().map(|&(d, _)| d as f64).sum();
        let total_delta: f64 = stream.iter().map(|&(_, x)| x as f64).sum();
        let mu_tw = total_delta / total_dt;
        assert!(
            second - mu_tw * mu_tw < 0.0,
            "scenario sanity: old estimator should be negative here"
        );
        let var = g.variance(0, Role::Src).unwrap()[0] as f64;
        assert!(
            (var - naive).abs() < 1e-2 * naive,
            "tracker variance {var} != naive {naive}"
        );
    }

    #[test]
    fn property_tracker_matches_naive_mle() {
        // running sums == batch MLE over the full history (Eq. 9's claim)
        prop::check_msg(
            "gmm trackers == naive MLE",
            5,
            100,
            |rng: &mut Pcg32| {
                let n = 1 + rng.below(20) as usize;
                (0..n)
                    .map(|_| {
                        let dt = 0.1 + rng.f32() * 3.0;
                        let s1: Vec<f32> = (0..2).map(|_| rng.normal()).collect();
                        let s2: Vec<f32> = (0..2).map(|_| rng.normal()).collect();
                        (s1, s2, dt)
                    })
                    .collect::<Vec<_>>()
            },
            |transitions| {
                let mut g = GmmTrackers::new(1, 2, 1.0, 0);
                let mut deltas: Vec<Vec<f64>> = Vec::new();
                let mut rates: Vec<Vec<f64>> = Vec::new();
                let mut total_dt = 0.0f64;
                for (s1, s2, dt) in transitions {
                    g.observe(0, Role::Src, s1, s2, *dt);
                    deltas.push(s1.iter().zip(s2).map(|(a, b)| (b - a) as f64).collect());
                    rates.push(
                        s1.iter()
                            .zip(s2)
                            .map(|(a, b)| ((b - a) / dt.max(1e-3)) as f64)
                            .collect(),
                    );
                    total_dt += *dt as f64;
                }
                let mu = g.mean(0, Role::Src).unwrap();
                let var = g.variance(0, Role::Src).unwrap();
                let n = transitions.len() as f64;
                for i in 0..2 {
                    // prediction mean: time-weighted rate sum(delta)/sum(dt)
                    let m: f64 = deltas.iter().map(|d| d[i]).sum::<f64>() / total_dt;
                    // variance: sample variance of the per-event rates —
                    // mean and second moment from the SAME estimator
                    let m_r: f64 = rates.iter().map(|r| r[i]).sum::<f64>() / n;
                    let v: f64 =
                        rates.iter().map(|r| r[i] * r[i]).sum::<f64>() / n - m_r * m_r;
                    assert!(v >= -1e-9, "naive per-event variance cannot be negative");
                    if (mu[i] as f64 - m).abs() > 1e-3 * (1.0 + m.abs()) {
                        return Err(format!("mean[{i}] {} != {m}", mu[i]));
                    }
                    if (var[i] as f64 - v.max(0.0)).abs() > 1e-2 * (1.0 + v.abs()) {
                        return Err(format!("var[{i}] {} != {v}", var[i]));
                    }
                }
                Ok(())
            },
        );
    }
}
