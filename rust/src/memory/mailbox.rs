//! APAN mailbox: per-vertex ring buffer of the K most recent incoming
//! message ("mail") vectors (Wang et al. 2021). The coordinator delivers
//! the step's output messages to both endpoints' mailboxes; the APAN
//! embedding attends over the mailbox instead of sampled neighbors.

/// Ring buffer of [K, d_msg] mail vectors + their timestamps per vertex.
#[derive(Clone, Debug)]
pub struct Mailbox {
    k: usize,
    d: usize,
    mails: Vec<f32>,   // [num_nodes * k * d]
    times: Vec<f32>,   // [num_nodes * k]
    heads: Vec<(u16, u16)>,
}

impl Mailbox {
    pub fn new(num_nodes: u32, k: usize, d: usize) -> Self {
        Mailbox {
            k,
            d,
            mails: vec![0.0; num_nodes as usize * k * d],
            times: vec![0.0; num_nodes as usize * k],
            heads: vec![(0, 0); num_nodes as usize],
        }
    }

    /// Deliver one mail vector to vertex `v`.
    pub fn deliver(&mut self, v: u32, mail: &[f32], t: f32) {
        debug_assert_eq!(mail.len(), self.d);
        let (head, len) = &mut self.heads[v as usize];
        let slot = v as usize * self.k + *head as usize;
        self.mails[slot * self.d..(slot + 1) * self.d].copy_from_slice(mail);
        self.times[slot] = t;
        *head = ((*head as usize + 1) % self.k) as u16;
        *len = (*len + 1).min(self.k as u16);
    }

    /// Gather the up-to-K most recent mails of `v`, newest first.
    /// `mails_out` is [K * d], `times_out` is [K]. Returns the valid count.
    pub fn gather(&self, v: u32, mails_out: &mut [f32], times_out: &mut [f32]) -> usize {
        let (head, len) = self.heads[v as usize];
        let len = len as usize;
        for i in 0..len {
            let pos = (head as usize + self.k - 1 - i) % self.k;
            let slot = v as usize * self.k + pos;
            mails_out[i * self.d..(i + 1) * self.d]
                .copy_from_slice(&self.mails[slot * self.d..(slot + 1) * self.d]);
            times_out[i] = self.times[slot];
        }
        len
    }

    pub fn clear(&mut self) {
        self.heads.iter_mut().for_each(|h| *h = (0, 0));
    }

    pub fn bytes(&self) -> usize {
        self.mails.len() * 4 + self.times.len() * 4 + self.heads.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deliver_and_gather_newest_first() {
        let mut mb = Mailbox::new(3, 2, 2);
        mb.deliver(1, &[1.0, 1.0], 0.5);
        mb.deliver(1, &[2.0, 2.0], 1.5);
        let mut mails = [0.0; 4];
        let mut times = [0.0; 2];
        let n = mb.gather(1, &mut mails, &mut times);
        assert_eq!(n, 2);
        assert_eq!(&mails, &[2.0, 2.0, 1.0, 1.0]);
        assert_eq!(&times, &[1.5, 0.5]);
    }

    #[test]
    fn ring_overwrites_oldest() {
        let mut mb = Mailbox::new(2, 2, 1);
        for i in 0..4 {
            mb.deliver(0, &[i as f32], i as f32);
        }
        let mut mails = [0.0; 2];
        let mut times = [0.0; 2];
        assert_eq!(mb.gather(0, &mut mails, &mut times), 2);
        assert_eq!(&mails, &[3.0, 2.0]);
    }

    #[test]
    fn empty_mailbox_gathers_zero() {
        let mb = Mailbox::new(2, 3, 2);
        let mut mails = [9.0; 6];
        let mut times = [9.0; 3];
        assert_eq!(mb.gather(1, &mut mails, &mut times), 0);
    }

    #[test]
    fn clear_is_an_epoch_boundary_reset() {
        // the trainer calls clear() at each epoch start: old mail must be
        // unobservable and the ring must restart from slot 0, exactly as
        // if the mailbox were freshly constructed.
        let mut mb = Mailbox::new(2, 2, 1);
        mb.deliver(0, &[1.0], 1.0);
        mb.deliver(0, &[2.0], 2.0);
        mb.deliver(1, &[3.0], 3.0);
        mb.clear();
        let mut mails = [9.0; 2];
        let mut times = [9.0; 2];
        assert_eq!(mb.gather(0, &mut mails, &mut times), 0);
        assert_eq!(mb.gather(1, &mut mails, &mut times), 0);
        // post-clear deliveries behave like a fresh mailbox (the stale
        // buffer contents behind the reset heads never resurface)
        mb.deliver(0, &[7.0], 7.0);
        let mut fresh = Mailbox::new(2, 2, 1);
        fresh.deliver(0, &[7.0], 7.0);
        let (mut a, mut at) = ([0.0; 2], [0.0; 2]);
        let (mut b, mut bt) = ([0.0; 2], [0.0; 2]);
        assert_eq!(mb.gather(0, &mut a, &mut at), fresh.gather(0, &mut b, &mut bt));
        assert_eq!(a[0], b[0]);
        assert_eq!(at[0], bt[0]);
        // clear() keeps capacity: bytes accounting is unchanged
        assert_eq!(mb.bytes(), fresh.bytes());
    }

    #[test]
    fn clone_snapshot_restores_across_eval() {
        // eval_val snapshots the mailbox by clone and restores by
        // assignment; deliveries in between must not leak through.
        let mut mb = Mailbox::new(3, 2, 2);
        mb.deliver(2, &[1.0, 2.0], 1.0);
        let snap = mb.clone();
        mb.deliver(2, &[8.0, 8.0], 5.0);
        mb.deliver(0, &[9.0, 9.0], 6.0);
        mb = snap;
        let mut mails = [0.0; 4];
        let mut times = [0.0; 2];
        assert_eq!(mb.gather(2, &mut mails, &mut times), 1);
        assert_eq!(&mails[0..2], &[1.0, 2.0]);
        assert_eq!(times[0], 1.0);
        assert_eq!(mb.gather(0, &mut mails, &mut times), 0);
    }
}
