//! Shared experiment-lab infrastructure for the figure/table harnesses:
//! engine + dataset caching across runs, sweep execution, CSV emission and
//! terminal ASCII plots.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;
use std::sync::Arc;

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::graph::Dataset;
use crate::runtime::Engine;
use crate::training::Trainer;
use crate::util::cli::Args;

/// Experiment laboratory: one engine (compile cache) + one dataset instance
/// per (name, seed, scale) shared by every trainer in a sweep.
pub struct Lab {
    pub engine: Rc<Engine>,
    /// `Arc` (not `Rc`): trainers hand the dataset to their background
    /// PREP worker (see `pipeline/`), so the handle must be Send.
    datasets: RefCell<BTreeMap<(String, u64, u32), Arc<Dataset>>>,
    /// Effort knobs (CLI-overridable; --quick shrinks everything).
    pub trials: usize,
    pub epochs: usize,
    pub data_scale: f32,
}

impl Lab {
    pub fn from_args(args: &Args) -> Result<Lab> {
        let quick = args.flag("quick");
        Ok(Lab {
            engine: Rc::new(Engine::auto(
                Path::new(args.get_or("artifacts", "artifacts")),
                args.get_or("exec", "auto"),
            )?),
            datasets: RefCell::new(BTreeMap::new()),
            trials: args.usize_or("trials", if quick { 1 } else { 3 })?,
            epochs: args.usize_or("epochs", if quick { 3 } else { 6 })?,
            data_scale: args.f32_or("data-scale", if quick { 0.25 } else { 0.5 })?,
        })
    }

    pub fn dataset(&self, cfg: &ExperimentConfig) -> Result<Arc<Dataset>> {
        let key = (
            cfg.dataset.clone(),
            cfg.seed,
            (cfg.data_scale * 1000.0) as u32,
        );
        if let Some(ds) = self.datasets.borrow().get(&key) {
            return Ok(ds.clone());
        }
        let ds = Arc::new(Trainer::make_dataset(cfg)?);
        self.datasets.borrow_mut().insert(key, ds.clone());
        Ok(ds)
    }

    pub fn trainer(&self, cfg: &ExperimentConfig) -> Result<Trainer> {
        Trainer::with_shared(cfg, self.engine.clone(), self.dataset(cfg)?)
    }

    /// Base config with the lab's effort knobs applied.
    pub fn config(&self, dataset: &str, model: &str, batch: usize, pres: bool) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default_with(dataset, model, batch, pres);
        cfg.epochs = self.epochs;
        cfg.data_scale = self.data_scale;
        cfg.eval_every = 0;
        cfg
    }

    /// Train `cfg.epochs` epochs, return (final val AP, mean epoch secs).
    /// The dataset seed stays fixed (the paper varies only the training
    /// stochasticity across trials); `trial` seeds init + negatives.
    pub fn final_val_ap(&self, cfg: &ExperimentConfig, trial: u64) -> Result<(f64, f64)> {
        let mut cfg = cfg.clone();
        let data_seed = cfg.seed;
        cfg.seed = data_seed * 1000 + trial;
        // keep the dataset cache hit: regenerate under the data seed
        let ds = {
            let mut dcfg = cfg.clone();
            dcfg.seed = data_seed;
            self.dataset(&dcfg)?
        };
        let mut tr = Trainer::with_shared(&cfg, self.engine.clone(), ds)?;
        let mut secs = Vec::new();
        for e in 0..cfg.epochs {
            secs.push(tr.train_epoch(e)?.epoch_secs);
        }
        Ok((tr.eval_val()?, crate::util::stats::mean(&secs)))
    }

    /// Per-epoch val-AP curve for one trial.
    pub fn val_curve(&self, cfg: &ExperimentConfig, trial: u64) -> Result<Vec<f64>> {
        let mut cfg = cfg.clone();
        let data_seed = cfg.seed;
        cfg.seed = data_seed * 1000 + trial;
        let ds = {
            let mut dcfg = cfg.clone();
            dcfg.seed = data_seed;
            self.dataset(&dcfg)?
        };
        let mut tr = Trainer::with_shared(&cfg, self.engine.clone(), ds)?;
        let mut curve = Vec::with_capacity(cfg.epochs);
        for e in 0..cfg.epochs {
            tr.train_epoch(e)?;
            curve.push(tr.eval_val()?);
        }
        Ok(curve)
    }
}

/// Write a CSV under results/ and report the path.
pub fn write_csv(name: &str, header: &str, rows: &[String]) -> Result<()> {
    std::fs::create_dir_all("results")?;
    let path = format!("results/{name}.csv");
    let mut out = String::from(header);
    out.push('\n');
    for r in rows {
        out.push_str(r);
        out.push('\n');
    }
    std::fs::write(&path, out)?;
    crate::log_info!("-> wrote {path}");
    Ok(())
}

/// Minimal terminal line plot: one row of series, shared x.
pub fn ascii_plot(title: &str, xlabel: &str, series: &[(&str, &[(f64, f64)])]) {
    const W: usize = 64;
    const H: usize = 16;
    let all: Vec<(f64, f64)> = series.iter().flat_map(|(_, s)| s.iter().copied()).collect();
    if all.is_empty() {
        return;
    }
    let (x0, x1) = all
        .iter()
        .fold((f64::MAX, f64::MIN), |(a, b), &(x, _)| (a.min(x), b.max(x)));
    let (y0, y1) = all
        .iter()
        .fold((f64::MAX, f64::MIN), |(a, b), &(_, y)| (a.min(y), b.max(y)));
    let (y0, y1) = if (y1 - y0).abs() < 1e-12 {
        (y0 - 0.5, y1 + 0.5)
    } else {
        (y0, y1)
    };
    let mut grid = vec![vec![' '; W]; H];
    let marks = ['o', 'x', '+', '*', '#', '@'];
    for (si, (_, pts)) in series.iter().enumerate() {
        for &(x, y) in pts.iter() {
            let cx = (((x - x0) / (x1 - x0).max(1e-12)) * (W - 1) as f64).round() as usize;
            let cy = (((y - y0) / (y1 - y0)) * (H - 1) as f64).round() as usize;
            let row = H - 1 - cy.min(H - 1);
            grid[row][cx.min(W - 1)] = marks[si % marks.len()];
        }
    }
    crate::log_info!("\n  {title}");
    crate::log_info!("  {:+.3} ┐", y1);
    for row in &grid {
        crate::log_info!("         │{}", row.iter().collect::<String>());
    }
    crate::log_info!("  {:+.3} └{}", y0, "─".repeat(W));
    crate::log_info!("          {x0:<10.1} {xlabel:^42} {x1:>10.1}");
    let legend: Vec<String> = series
        .iter()
        .enumerate()
        .map(|(i, (n, _))| format!("{} {}", marks[i % marks.len()], n))
        .collect();
    crate::log_info!("          legend: {}", legend.join("   "));
}
