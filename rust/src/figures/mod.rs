//! Figure harnesses: regenerate every figure in the paper's evaluation
//! (Fig. 3, 4/9-13, 5/14, 15, 16, 17, 18, 19) on the synthetic testbed.
//!
//! ```text
//!     pres-train figure <id|all> [--dataset X] [--trials N] [--epochs N]
//!                                 [--quick] [--data-scale F]
//! ```
//!
//! Each harness prints the paper-shaped series, renders a terminal plot,
//! and writes a CSV under results/ for EXPERIMENTS.md.

pub mod common;

use anyhow::{bail, Result};

use crate::config::ExperimentConfig;
use crate::util::cli::Args;
use crate::util::stats;
use common::{ascii_plot, write_csv, Lab};

pub fn run(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let lab = Lab::from_args(args)?;
    match which {
        "3" => fig3(&lab, args),
        "4" | "9" | "10" | "11" | "12" | "13" => fig4(&lab, args),
        "5" | "14" => fig5(&lab, args),
        "15" => fig15(&lab, args),
        "16" => fig16(&lab, args),
        "17" => fig17(&lab, args),
        "18" => fig18(&lab, args),
        "19" => fig19(&lab, args),
        "all" => {
            for f in ["3", "4", "5", "15", "16", "17", "18", "19"] {
                let mut raw = vec!["figure".to_string(), f.to_string()];
                for (k, v) in &args.options {
                    raw.push(format!("--{k}={v}"));
                }
                for fl in &args.flags {
                    raw.push(format!("--{fl}"));
                }
                run(&Args::parse(raw, &["quick", "pres", "no-prefetch", "verbose"])?)?;
            }
            Ok(())
        }
        other => bail!("unknown figure '{other}'"),
    }
}

fn trial_seeds(lab: &Lab) -> Vec<u64> {
    (1..=lab.trials as u64).collect()
}

/// Fig. 3: small temporal batches hurt — gradient variance (Theorem 1).
/// AP of the three baselines (STANDARD mode) across the small-batch regime.
fn fig3(lab: &Lab, args: &Args) -> Result<()> {
    crate::log_info!("\n=== Figure 3: baseline AP in the small-batch regime ===");
    let dataset = args.get_or("dataset", "wiki");
    let mut rows = Vec::new();
    let mut plot: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    for model in ["tgn", "jodie", "apan"] {
        let batches: &[usize] = if model == "tgn" {
            &[5, 10, 25, 50, 100, 200]
        } else {
            &[25, 50, 100, 200]
        };
        let mut series = Vec::new();
        for &b in batches {
            let cfg = lab.config(dataset, model, b, false);
            let aps: Vec<f64> = trial_seeds(lab)
                .iter()
                .map(|&t| lab.final_val_ap(&cfg, t).map(|(ap, _)| ap))
                .collect::<Result<_>>()?;
            crate::log_info!(
                "  {model:<6} b={b:<5} AP = {}",
                stats::fmt_mean_std(&aps, 4)
            );
            rows.push(format!(
                "{model},{b},{:.4},{:.4}",
                stats::mean(&aps),
                stats::std_dev(&aps)
            ));
            series.push((b as f64, stats::mean(&aps)));
        }
        plot.push((model.to_string(), series));
    }
    let view: Vec<(&str, &[(f64, f64)])> = plot
        .iter()
        .map(|(n, s)| (n.as_str(), s.as_slice()))
        .collect();
    ascii_plot("Fig 3: AP vs (small) batch size", "batch size", &view);
    write_csv("fig3_small_batch", "model,batch,ap_mean,ap_std", &rows)
}

/// Fig. 4 (+ 9-13 per dataset): AP vs batch size, STANDARD vs PRES.
fn fig4(lab: &Lab, args: &Args) -> Result<()> {
    let dataset = args.get_or("dataset", "wiki");
    let model = args.get_or("model", "tgn");
    crate::log_info!("\n=== Figure 4: AP vs batch size w/wo PRES ({model} on {dataset}) ===");
    let batches = [100usize, 200, 400, 800, 1600];
    let mut rows = Vec::new();
    let mut std_series = Vec::new();
    let mut pres_series = Vec::new();
    for &b in &batches {
        let mut means = [0.0f64; 2];
        for (mi, pres) in [false, true].into_iter().enumerate() {
            let mut cfg = lab.config(dataset, model, b, pres);
            cfg.beta = if pres { 0.1 } else { 0.0 };
            let aps: Vec<f64> = trial_seeds(lab)
                .iter()
                .map(|&t| lab.final_val_ap(&cfg, t).map(|(ap, _)| ap))
                .collect::<Result<_>>()?;
            means[mi] = stats::mean(&aps);
            rows.push(format!(
                "{model},{b},{},{:.4},{:.4}",
                if pres { "pres" } else { "std" },
                stats::mean(&aps),
                stats::std_dev(&aps)
            ));
        }
        crate::log_info!(
            "  b={b:<5} STANDARD {:.4}   PRES {:.4}   (delta {:+.4})",
            means[0],
            means[1],
            means[1] - means[0]
        );
        std_series.push((b as f64, means[0]));
        pres_series.push((b as f64, means[1]));
    }
    ascii_plot(
        &format!("Fig 4: AP vs batch ({model}, {dataset})"),
        "batch size",
        &[("STANDARD", &std_series), ("PRES", &pres_series)],
    );
    write_csv(
        &format!("fig4_batch_sweep_{dataset}_{model}"),
        "model,batch,mode,ap_mean,ap_std",
        &rows,
    )
}

/// Fig. 5/14: statistical efficiency — val AP vs training epoch at a large
/// batch, STANDARD vs PRES (with the smoothing objective).
fn fig5(lab: &Lab, args: &Args) -> Result<()> {
    let dataset = args.get_or("dataset", "wiki");
    let model = args.get_or("model", "tgn");
    let b = args.usize_or("batch", 800)?;
    crate::log_info!("\n=== Figure 5: statistical efficiency at b={b} ({model} on {dataset}) ===");
    let mut rows = Vec::new();
    let mut curves = Vec::new();
    for pres in [false, true] {
        let mut cfg = lab.config(dataset, model, b, pres);
        cfg.epochs = (lab.epochs * 2).max(8);
        let mut acc: Vec<Vec<f64>> = Vec::new();
        for t in trial_seeds(lab) {
            acc.push(lab.val_curve(&cfg, t)?);
        }
        let curve: Vec<(f64, f64)> = (0..cfg.epochs)
            .map(|e| {
                let vals: Vec<f64> = acc.iter().map(|c| c[e]).collect();
                (e as f64 + 1.0, stats::mean(&vals))
            })
            .collect();
        for (e, ap) in &curve {
            rows.push(format!(
                "{},{e},{ap:.4}",
                if pres { "pres" } else { "std" }
            ));
        }
        crate::log_info!(
            "  {}: {}",
            if pres { "PRES    " } else { "STANDARD" },
            curve
                .iter()
                .map(|(_, ap)| format!("{ap:.3}"))
                .collect::<Vec<_>>()
                .join(" ")
        );
        curves.push((if pres { "PRES" } else { "STANDARD" }, curve));
    }
    let view: Vec<(&str, &[(f64, f64)])> =
        curves.iter().map(|(n, c)| (*n, c.as_slice())).collect();
    ascii_plot("Fig 5: val AP vs epoch", "epoch", &view);
    write_csv(
        &format!("fig5_efficiency_{dataset}_{model}_b{b}"),
        "mode,epoch,val_ap",
        &rows,
    )
}

/// Fig. 15: speed-vs-accuracy trade-off scatter against other-domain
/// efficiency methods (literature constants, as in the paper) + our point.
fn fig15(lab: &Lab, args: &Args) -> Result<()> {
    crate::log_info!("\n=== Figure 15: relative speedup vs accuracy impact ===");
    // literature-reported points, as the paper's App. F.4 collects them
    let literature = [
        ("PipeGCN", 1.7, 0.4),
        ("SAPipe", 1.6, 0.3),
        ("Sancus", 2.0, 1.5),
        ("AdaQP", 1.8, 0.4),
        ("FastGCN", 2.0, 1.5),
    ];
    // our PRES point: measured on the fly (dataset scaled for speed)
    let dataset = args.get_or("dataset", "wiki");
    let model = args.get_or("model", "tgn");
    let (b_std, b_pres) = (25usize, 100usize);
    let cfg_std = lab.config(dataset, model, b_std, false);
    let cfg_pres = lab.config(dataset, model, b_pres, true);
    let (ap_std, s_std) = lab.final_val_ap(&cfg_std, 1)?;
    let (ap_pres, s_pres) = lab.final_val_ap(&cfg_pres, 1)?;
    let speedup = s_std / s_pres.max(1e-9);
    let acc_drop = ((ap_std - ap_pres) * 100.0).max(0.0);
    let mut rows: Vec<String> = literature
        .iter()
        .map(|(n, s, d)| format!("{n},{s},{d},literature"))
        .collect();
    rows.push(format!("PRES(ours),{speedup:.2},{acc_drop:.2},measured"));
    crate::log_info!("  {:<12} {:>9} {:>10}", "method", "speedup", "acc drop%");
    for r in &rows {
        let parts: Vec<&str> = r.split(',').collect();
        crate::log_info!("  {:<12} {:>8}x {:>9}%", parts[0], parts[1], parts[2]);
    }
    write_csv("fig15_tradeoff", "method,speedup,acc_drop_pct,source", &rows)
}

/// Fig. 16: extended training sessions — the PRES-vs-STANDARD gap narrows
/// with more epochs.
fn fig16(lab: &Lab, args: &Args) -> Result<()> {
    let dataset = args.get_or("dataset", "wiki");
    let model = args.get_or("model", "tgn");
    let b = args.usize_or("batch", 800)?;
    let epochs = args.usize_or("long-epochs", lab.epochs * 4)?;
    crate::log_info!("\n=== Figure 16: extended training ({epochs} epochs, b={b}, {dataset}) ===");
    let mut rows = Vec::new();
    let mut curves = Vec::new();
    for pres in [false, true] {
        let mut cfg = lab.config(dataset, model, b, pres);
        cfg.epochs = epochs;
        let curve = lab.val_curve(&cfg, 1)?;
        for (e, ap) in curve.iter().enumerate() {
            rows.push(format!("{},{e},{ap:.4}", if pres { "pres" } else { "std" }));
        }
        curves.push((
            if pres { "PRES" } else { "STANDARD" },
            curve
                .iter()
                .enumerate()
                .map(|(e, &ap)| (e as f64 + 1.0, ap))
                .collect::<Vec<_>>(),
        ));
    }
    let gap_first = curves[1].1[0].1 - curves[0].1[0].1;
    let gap_last = curves[1].1.last().unwrap().1 - curves[0].1.last().unwrap().1;
    crate::log_info!("  AP gap (PRES - STANDARD): first epoch {gap_first:+.4}, last epoch {gap_last:+.4}");
    let view: Vec<(&str, &[(f64, f64)])> =
        curves.iter().map(|(n, c)| (*n, c.as_slice())).collect();
    ascii_plot("Fig 16: extended training", "epoch", &view);
    write_csv(
        &format!("fig16_extended_{dataset}_{model}_b{b}"),
        "mode,epoch,val_ap",
        &rows,
    )
}

/// Fig. 17: ablation — smoothing-only (PRES-S), correction-only (PRES-V),
/// both (PRES), neither (STANDARD).
fn fig17(lab: &Lab, args: &Args) -> Result<()> {
    let dataset = args.get_or("dataset", "wiki");
    let model = args.get_or("model", "tgn");
    let b = args.usize_or("batch", 800)?;
    crate::log_info!("\n=== Figure 17: PRES ablation at b={b} ({model} on {dataset}) ===");
    let variants: [(&str, bool, f32); 4] = [
        ("STANDARD", false, 0.0),
        ("PRES-S", false, 0.1), // memory-coherence smoothing only
        ("PRES-V", true, 0.0),  // prediction-correction only
        ("PRES", true, 0.1),
    ];
    let mut rows = Vec::new();
    let mut curves = Vec::new();
    for (name, pres, beta) in variants {
        let mut cfg = lab.config(dataset, model, b, pres);
        cfg.beta = beta;
        cfg.epochs = (lab.epochs * 2).max(8);
        let curve = lab.val_curve(&cfg, 1)?;
        crate::log_info!(
            "  {name:<9} final AP {:.4}  curve {}",
            curve.last().unwrap(),
            curve
                .iter()
                .map(|ap| format!("{ap:.3}"))
                .collect::<Vec<_>>()
                .join(" ")
        );
        for (e, ap) in curve.iter().enumerate() {
            rows.push(format!("{name},{e},{ap:.4}"));
        }
        curves.push((
            name,
            curve
                .iter()
                .enumerate()
                .map(|(e, &ap)| (e as f64 + 1.0, ap))
                .collect::<Vec<_>>(),
        ));
    }
    let view: Vec<(&str, &[(f64, f64)])> =
        curves.iter().map(|(n, c)| (*n, c.as_slice())).collect();
    ascii_plot("Fig 17: ablation", "epoch", &view);
    write_csv(
        &format!("fig17_ablation_{dataset}_{model}_b{b}"),
        "variant,epoch,val_ap",
        &rows,
    )
}

/// Fig. 18: beta sensitivity — convergence speed vs final accuracy.
fn fig18(lab: &Lab, args: &Args) -> Result<()> {
    let dataset = args.get_or("dataset", "wiki");
    let model = args.get_or("model", "tgn");
    let b = args.usize_or("batch", 800)?;
    crate::log_info!("\n=== Figure 18: beta ablation at b={b} ({model} on {dataset}) ===");
    let betas = [0.0f32, 0.01, 0.05, 0.1, 0.3, 1.0];
    let mut rows = Vec::new();
    for beta in betas {
        let mut cfg = lab.config(dataset, model, b, true);
        cfg.beta = beta;
        cfg.epochs = (lab.epochs * 2).max(8);
        let curve = lab.val_curve(&cfg, 1)?;
        // "epochs to reach 95% of final AP" as the convergence-speed proxy
        let last = *curve.last().unwrap();
        let thresh = last * 0.95;
        let conv = curve.iter().position(|&ap| ap >= thresh).unwrap_or(0) + 1;
        crate::log_info!("  beta={beta:<5} final AP {last:.4}  reaches 95% at epoch {conv}");
        for (e, ap) in curve.iter().enumerate() {
            rows.push(format!("{beta},{e},{ap:.4}"));
        }
    }
    write_csv(
        &format!("fig18_beta_{dataset}_{model}_b{b}"),
        "beta,epoch,val_ap",
        &rows,
    )
}

/// Fig. 19: coordinator memory vs batch size, STANDARD vs PRES — PRES's
/// tracker overhead is O(|V|), independent of batch size.
fn fig19(lab: &Lab, args: &Args) -> Result<()> {
    let dataset = args.get_or("dataset", "wiki");
    let model = args.get_or("model", "tgn");
    crate::log_info!("\n=== Figure 19: coordinator memory vs batch size ({dataset}) ===");
    let mut rows = Vec::new();
    crate::log_info!(
        "  {:>7} {:>14} {:>14} {:>16}",
        "batch", "STANDARD MB", "PRES MB", "PRES overhead MB"
    );
    for b in [100usize, 200, 400, 800, 1600] {
        let mut bytes = [0usize; 2];
        for (i, pres) in [false, true].into_iter().enumerate() {
            let mut cfg = lab.config(dataset, model, b, pres);
            cfg.anchor_fraction = if pres { 1.0 } else { 0.0 };
            let tr = lab.trainer(&cfg)?;
            bytes[i] = tr.memory_bytes() + host_batch_bytes(&cfg, &lab.engine.manifest().dims);
        }
        crate::log_info!(
            "  {:>7} {:>14.2} {:>14.2} {:>16.2}",
            b,
            bytes[0] as f64 / 1e6,
            bytes[1] as f64 / 1e6,
            (bytes[1] - bytes[0]) as f64 / 1e6
        );
        rows.push(format!(
            "{b},{:.3},{:.3}",
            bytes[0] as f64 / 1e6,
            bytes[1] as f64 / 1e6
        ));
    }
    crate::log_info!("  (PRES tracker overhead is constant in b — the paper's scalability point)");
    write_csv(
        &format!("fig19_memory_{dataset}_{model}"),
        "batch,std_mb,pres_mb",
        &rows,
    )
}

/// Approximate per-step staging bytes (scales with b; part of Fig. 19).
fn host_batch_bytes(cfg: &ExperimentConfig, dims: &crate::runtime::Dims) -> usize {
    let b = cfg.batch_size;
    let u = 2 * b;
    let (d, de, k) = (dims.d_mem, dims.d_edge, dims.k_nbr);
    // update rows + current rows + neighbor tensors (3 roles)
    (u * (3 * d + de + 2) + 3 * b * (d + 2) + 3 * b * k * (d + de + 2)) * 4
}
