//! Offline stub of the `xla` crate: the exact API surface `pres` consumes,
//! with **host-side literals implemented for real** and **PJRT entry
//! points failing at runtime** with a clear message.
//!
//! Why a stub: the build environment has no network and no prebuilt
//! `xla_extension`, but the crate's host data path (assembler staging,
//! literal packing, property/equivalence suites) is pure Rust and fully
//! testable without a device runtime. Artifact-dependent integration tests
//! already skip when `artifacts/manifest.json` is absent, and with this
//! stub `PjRtClient::cpu()` is never reached on that path — so
//! `cargo build --release && cargo test -q` (the tier-1 gate) runs
//! everywhere, and linking the real bindings is a one-line change to the
//! `xla = { path = "vendor/xla" }` dependency.
//!
//! Layout mirrors xla-rs: `Literal` owns `(element type, dims, raw bytes)`
//! row-major host data; `Shape`/`ArrayShape` describe it; the PJRT types
//! (`PjRtClient`, `PjRtLoadedExecutable`, `PjRtBuffer`) and the HLO
//! loaders (`HloModuleProto`, `XlaComputation`) are unavailable.

use std::fmt;
use std::path::Path;

/// Stub error: either "PJRT is not linked" or a host-side shape/type
/// mismatch. Converts into `anyhow::Error` via `std::error::Error`.
#[derive(Debug)]
pub enum Error {
    /// The operation needs the real XLA runtime.
    Unavailable(&'static str),
    /// Host-side usage error (wrong length / element type / non-tuple).
    Usage(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "xla stub: {what} requires the real XLA/PJRT runtime (swap \
                 the `xla` path dependency in rust/Cargo.toml for xla-rs)"
            ),
            Error::Usage(msg) => write!(f, "xla stub: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element dtypes (the subset the manifest ABI can mention).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

impl ElementType {
    /// Bytes per element.
    pub fn size(self) -> usize {
        match self {
            ElementType::Pred | ElementType::S8 | ElementType::U8 => 1,
            ElementType::S16 | ElementType::U16 | ElementType::F16 | ElementType::Bf16 => 2,
            ElementType::S32 | ElementType::U32 | ElementType::F32 => 4,
            ElementType::S64 | ElementType::U64 | ElementType::F64 => 8,
        }
    }
}

/// Host types that can view a literal's payload.
pub trait ArrayElement: Copy {
    const TY: ElementType;
}

impl ArrayElement for f32 {
    const TY: ElementType = ElementType::F32;
}
impl ArrayElement for f64 {
    const TY: ElementType = ElementType::F64;
}
impl ArrayElement for i32 {
    const TY: ElementType = ElementType::S32;
}
impl ArrayElement for i64 {
    const TY: ElementType = ElementType::S64;
}
impl ArrayElement for u8 {
    const TY: ElementType = ElementType::U8;
}

/// Array shape: element type + row-major dims (i64, like the bindings).
#[derive(Clone, Debug, PartialEq)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }
}

/// A (possibly tuple) shape.
#[derive(Clone, Debug, PartialEq)]
pub enum Shape {
    Array(ArrayShape),
    Tuple(Vec<Shape>),
}

/// Host literal: row-major raw bytes + dtype + dims. Fully functional —
/// this is what the assembler stages into and fetches from.
#[derive(Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let elems: usize = dims.iter().product();
        if elems * ty.size() != data.len() {
            return Err(Error::Usage(format!(
                "literal payload {} bytes does not match shape {dims:?} of {ty:?}",
                data.len()
            )));
        }
        Ok(Literal {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            data: data.to_vec(),
        })
    }

    pub fn element_count(&self) -> usize {
        self.data.len() / self.ty.size()
    }

    pub fn ty(&self) -> Result<ElementType> {
        Ok(self.ty)
    }

    pub fn shape(&self) -> Result<Shape> {
        Ok(Shape::Array(self.array_shape()?))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { ty: self.ty, dims: self.dims.clone() })
    }

    /// Copy the payload into a typed host slice (must match length + type).
    pub fn copy_raw_to<T: ArrayElement>(&self, dst: &mut [T]) -> Result<()> {
        if T::TY != self.ty {
            return Err(Error::Usage(format!(
                "copy_raw_to type {:?} != literal type {:?}",
                T::TY,
                self.ty
            )));
        }
        if dst.len() != self.element_count() {
            return Err(Error::Usage(format!(
                "copy_raw_to length {} != literal element count {}",
                dst.len(),
                self.element_count()
            )));
        }
        // SAFETY: lengths validated above; T is a plain scalar.
        unsafe {
            std::ptr::copy_nonoverlapping(
                self.data.as_ptr(),
                dst.as_mut_ptr() as *mut u8,
                self.data.len(),
            );
        }
        Ok(())
    }

    pub fn get_first_element<T: ArrayElement>(&self) -> Result<T> {
        if T::TY != self.ty {
            return Err(Error::Usage(format!(
                "get_first_element type {:?} != literal type {:?}",
                T::TY,
                self.ty
            )));
        }
        if self.data.is_empty() {
            return Err(Error::Usage("get_first_element on empty literal".into()));
        }
        // SAFETY: payload holds at least one validated element of T.
        Ok(unsafe { std::ptr::read_unaligned(self.data.as_ptr() as *const T) })
    }

    /// Stub literals are always arrays — only PJRT outputs could be tuples,
    /// and PJRT is unavailable here.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error::Usage("decompose_tuple on a non-tuple host literal".into()))
    }
}

// ------------------------------------------------------------ PJRT (stubs)

#[derive(Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(Error::Unavailable("PjRtClient::buffer_from_host_literal"))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrips_f32_payloads() {
        let host = [1.5f32, -2.0, 3.25, 0.0, 7.0, -8.5];
        let bytes =
            unsafe { std::slice::from_raw_parts(host.as_ptr() as *const u8, host.len() * 4) };
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 3], bytes).unwrap();
        assert_eq!(lit.element_count(), 6);
        assert_eq!(lit.ty().unwrap(), ElementType::F32);
        assert_eq!(lit.array_shape().unwrap().dims(), &[2, 3]);
        let mut back = [0.0f32; 6];
        lit.copy_raw_to(&mut back).unwrap();
        assert_eq!(back, host);
        assert_eq!(lit.get_first_element::<f32>().unwrap(), 1.5);
    }

    #[test]
    fn literal_rejects_mismatches() {
        let bytes = [0u8; 8];
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).is_err()
        );
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &bytes).unwrap();
        let mut wrong_len = [0.0f32; 3];
        assert!(lit.copy_raw_to(&mut wrong_len).is_err());
        let mut wrong_ty = [0i32; 2];
        assert!(lit.copy_raw_to(&mut wrong_ty).is_err());
    }

    #[test]
    fn pjrt_surface_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("real XLA/PJRT runtime"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }

    #[test]
    fn scalar_shape_is_zero_rank() {
        let bytes = 4.0f32.to_le_bytes();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[], &bytes).unwrap();
        assert_eq!(lit.element_count(), 1);
        match lit.shape().unwrap() {
            Shape::Array(a) => assert!(a.dims().is_empty()),
            Shape::Tuple(_) => panic!("scalar is not a tuple"),
        }
    }
}
