"""AOT pipeline: lower every (model, batch, kind) step to HLO *text* and
emit artifacts/manifest.json describing the exact ABI for the rust runtime.

HLO text — NOT ``lowered.compiler_ir('hlo').as_serialized_hlo_module_proto()``
— is the interchange format: jax >= 0.5 emits protos with 64-bit instruction
ids which xla_extension 0.5.1 (the version the published ``xla`` crate binds)
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Run via ``make artifacts`` (a no-op when inputs are unchanged):

    cd python && python -m compile.aot --out ../artifacts [--quick]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
from jax._src.lib import xla_client as xc

from . import model

# The compiled temporal-batch sizes. Figures sweep across these; Table 1
# contrasts the per-dataset base size with 4x larger PRES batches.
BATCH_SIZES = (25, 50, 100, 200, 400, 800, 1600)
# Sequential-oracle artifacts (per-event replay in tests / fig. 3): TGN only.
ORACLE_BATCHES = (1, 5, 10)

QUICK_MATRIX = [
    ("tgn", 25), ("tgn", 100), ("jodie", 100), ("apan", 100), ("tgn", 1),
]


def to_hlo_text(fn, args) -> str:
    # keep_unused pins the ABI: inputs a model variant ignores (e.g. TGN's
    # c_*_dt) must still be ENTRY parameters so rust can pack positionally.
    lowered = jax.jit(fn, keep_unused=True).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_json(specs):
    return [
        {"name": n, "shape": list(s), "dtype": d} for n, s, d in specs
    ]


def build(out_dir: str, quick: bool = False, only: str | None = None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    if quick:
        matrix = list(QUICK_MATRIX)
    else:
        matrix = [(m, b) for m in model.MODELS for b in BATCH_SIZES]
        matrix += [("tgn", b) for b in ORACLE_BATCHES]

    artifacts = []
    t_start = time.time()
    for name_model, b in matrix:
        for kind in ("train", "eval"):
            name = f"{name_model}_b{b}_{kind}"
            if only and only not in name:
                continue
            fn, inputs, outs = model.make_step(name_model, b, kind)
            t0 = time.time()
            text = to_hlo_text(fn, model.example_args(inputs))
            fname = f"{name}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            artifacts.append(
                {
                    "name": name,
                    "file": fname,
                    "model": name_model,
                    "kind": kind,
                    "batch": b,
                    "inputs": _spec_json(inputs),
                    "outputs": _spec_json(outs),
                }
            )
            print(f"  {name}: {len(text)/1e6:.2f} MB in {time.time()-t0:.1f}s")

    for kind in ("train", "eval"):
        name = f"clf_{kind}"
        if only and only not in name:
            continue
        fn, inputs, outs = model.make_clf_step(kind)
        text = to_hlo_text(fn, model.example_args(inputs))
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        artifacts.append(
            {
                "name": name,
                "file": fname,
                "model": "clf",
                "kind": kind,
                "batch": model.DIMS["clf_batch"],
                "inputs": _spec_json(inputs),
                "outputs": _spec_json(outs),
            }
        )

    manifest = {
        "version": 1,
        "dims": model.DIMS,
        "adam": {"b1": model.ADAM_B1, "b2": model.ADAM_B2, "eps": model.ADAM_EPS},
        "params": {
            m: [
                {"name": n, "shape": list(s), "init": init}
                for n, s, init in model.param_specs(m)
            ]
            for m in model.MODELS
        },
        "clf_params": [
            {"name": n, "shape": list(s), "init": init}
            for n, s, init in model.clf_param_specs()
        ],
        "artifacts": artifacts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(
        f"wrote {len(artifacts)} artifacts + manifest to {out_dir} "
        f"in {time.time()-t_start:.1f}s"
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true", help="reduced matrix for CI")
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    args = ap.parse_args()
    jax.config.update("jax_platform_name", "cpu")
    build(args.out, quick=args.quick, only=args.only)


if __name__ == "__main__":
    main()
