"""L2: MDGNN encoders (TGN / JODIE / APAN) + PRES objective, fused per-step.

One jitted function = one training iteration of Algorithm 2 (paper App. A):

    messages -> memory update -> PRES correction (Eq. 8) -> lag-one splice
    -> embeddings -> BCE + beta * (1 - memory coherence) (Eq. 10) -> Adam

Everything differentiable lives here so the rust coordinator performs exactly
one PJRT call per step. The executable never sees the [N, d] memory: the
coordinator gathers rows for the 2b "update rows" of the previous batch and
the current batch's vertices/neighbors, and splices fresh states via match
indices (DESIGN.md §1). STANDARD training is the same artifact with
pres_on = 0 and beta = 0.

Shapes depend only on (model, batch size); see aot.py for the artifact
matrix and the manifest consumed by rust/src/runtime.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from . import kernels

# ---------------------------------------------------------------------------
# Dimension conventions (DESIGN.md §3). MXU-aligned: d=64, gate bank 192.
# ---------------------------------------------------------------------------

DIMS = dict(
    d_mem=64,     # memory state width
    d_msg=64,     # message width
    d_edge=16,    # edge feature width (zero vector for non-attributed data)
    d_time=16,    # functional time encoding width
    k_nbr=10,     # sampled temporal neighbors / mailbox slots
    heads=2,      # attention heads
    d_qk=64,      # total query/key width (heads * 32)
    d_val=64,     # total value width
    d_emb=64,     # output embedding width
    msg_hidden=128,
    dec_hidden=128,
    clf_hidden=64,
    clf_batch=256,
)

MODELS = ("tgn", "jodie", "apan")

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


# ---------------------------------------------------------------------------
# Parameter specs. The manifest serializes these so the rust coordinator can
# initialize parameters host-side with its own RNG and upload them once.
# ---------------------------------------------------------------------------


def _glorot(shape):
    fan_in, fan_out = shape[0], shape[-1]
    return {"kind": "glorot_uniform", "fan_in": fan_in, "fan_out": fan_out}


def _zeros():
    return {"kind": "zeros"}


def _const(values):
    return {"kind": "const", "values": [float(v) for v in values]}


def _time_encoder_specs():
    # TGN-style timescale spread: omega_i = 10^{-4 i / D}. phi = 0.
    d = DIMS["d_time"]
    omega = [10.0 ** (-4.0 * i / max(d - 1, 1)) for i in range(d)]
    return [
        ("time_omega", (d,), _const(omega)),
        ("time_phi", (d,), _const([0.0] * d)),
    ]


def param_specs(model: str):
    """Ordered [(name, shape, init)] for ``model``. Order defines the ABI."""
    d, dm, de, dt = DIMS["d_mem"], DIMS["d_msg"], DIMS["d_edge"], DIMS["d_time"]
    dqk, dv, demb = DIMS["d_qk"], DIMS["d_val"], DIMS["d_emb"]
    mh, dh = DIMS["msg_hidden"], DIMS["dec_hidden"]
    msg_in = 2 * d + de + dt

    specs = list(_time_encoder_specs())
    specs += [
        ("msg_w1", (msg_in, mh), _glorot((msg_in, mh))),
        ("msg_b1", (mh,), _zeros()),
        ("msg_w2", (mh, dm), _glorot((mh, dm))),
        ("msg_b2", (dm,), _zeros()),
    ]
    if model == "jodie":
        # vanilla RNN memory cell
        specs += [
            ("rnn_wx", (dm, d), _glorot((dm, d))),
            ("rnn_wh", (d, d), _glorot((d, d))),
            ("rnn_b", (d,), _zeros()),
            ("proj_w", (d,), _zeros()),  # drift starts at identity projection
        ]
    else:
        specs += [
            ("gru_wx", (dm, 3 * d), _glorot((dm, 3 * d))),
            ("gru_wh", (d, 3 * d), _glorot((d, 3 * d))),
            ("gru_b", (2, 3 * d), _zeros()),
        ]
    if model == "tgn":
        k_in = d + de + dt
        specs += [
            ("att_wq", (d + dt, dqk), _glorot((d + dt, dqk))),
            ("att_wk", (k_in, dqk), _glorot((k_in, dqk))),
            ("att_wv", (k_in, dv), _glorot((k_in, dv))),
            ("att_wo", (d + dv, demb), _glorot((d + dv, demb))),
            ("att_bo", (demb,), _zeros()),
        ]
    elif model == "apan":
        k_in = dm + dt
        specs += [
            ("att_wq", (d, dqk), _glorot((d, dqk))),
            ("att_wk", (k_in, dqk), _glorot((k_in, dqk))),
            ("att_wv", (k_in, dv), _glorot((k_in, dv))),
            ("att_wo", (d + 2 * dv, demb), _glorot((d + 2 * dv, demb))),
            ("att_bo", (demb,), _zeros()),
        ]
    # decoder (temporal link prediction head)
    specs += [
        ("dec_w1", (2 * demb, dh), _glorot((2 * demb, dh))),
        ("dec_b1", (dh,), _zeros()),
        ("dec_w2", (dh, 1), _glorot((dh, 1))),
        ("dec_b2", (1,), _zeros()),
        # PRES learnable fusion gamma (Eq. 8), sigmoid-squashed. raw=3.9 ->
        # gamma ~ 0.98: the correction starts as a gentle nudge toward the
        # prediction and training adapts the gain.
        ("gamma_raw", (1,), _const([3.9])),
    ]
    return specs


def clf_param_specs():
    """Node-classification head (Table 2 protocol): 2-layer MLP on embeddings."""
    demb, ch = DIMS["d_emb"], DIMS["clf_hidden"]
    return [
        ("clf_w1", (demb, ch), _glorot((demb, ch))),
        ("clf_b1", (ch,), _zeros()),
        ("clf_w2", (ch, 1), _glorot((ch, 1))),
        ("clf_b2", (1,), _zeros()),
    ]


def init_params(model: str, seed: int = 0):
    """Python-side initialization (tests only; rust has its own impl)."""
    key = jax.random.PRNGKey(seed)
    out = {}
    for name, shape, init in param_specs(model) if model != "clf" else clf_param_specs():
        key, sub = jax.random.split(key)
        if init["kind"] == "zeros":
            out[name] = jnp.zeros(shape, jnp.float32)
        elif init["kind"] == "const":
            out[name] = jnp.asarray(init["values"], jnp.float32).reshape(shape)
        else:
            limit = (6.0 / (init["fan_in"] + init["fan_out"])) ** 0.5
            out[name] = jax.random.uniform(sub, shape, jnp.float32, -limit, limit)
    return out


# ---------------------------------------------------------------------------
# Modules
# ---------------------------------------------------------------------------


def _time_enc(p, dt):
    return kernels.time_encode(dt, p["time_omega"], p["time_phi"])


def _message(p, self_mem, other_mem, efeat, dt):
    """MSG module: MLP over [s_self, s_other, e, phi(dt)] (paper Eq. 1)."""
    x = jnp.concatenate([self_mem, other_mem, efeat, _time_enc(p, dt)], axis=1)
    hidden = jax.nn.relu(x @ p["msg_w1"] + p["msg_b1"])
    return hidden @ p["msg_w2"] + p["msg_b2"]


def _memory_update(model, p, msg, mem):
    """MEM module: GRU (TGN/APAN) or vanilla RNN (JODIE)."""
    if model == "jodie":
        return jnp.tanh(msg @ p["rnn_wx"] + mem @ p["rnn_wh"] + p["rnn_b"])
    return kernels.fused_gru(msg, mem, p["gru_wx"], p["gru_wh"], p["gru_b"])


def _coherence(prev_mem, new_mem, wmask):
    """Memory coherence of a batch (Eq. 10): Frobenius cosine between the
    masked previous and new memory state matrices of the updated vertices."""
    w = wmask[:, None]
    a = prev_mem * w
    b = new_mem * w
    num = jnp.sum(a * b)
    den = jnp.sqrt(jnp.sum(a * a)) * jnp.sqrt(jnp.sum(b * b))
    return num / jnp.maximum(den, 1e-9)


def _embed_tgn(p, mem, dt, nbr_mem, nbr_efeat, nbr_dt, nbr_mask):
    b, K, _ = nbr_mem.shape
    q_in = jnp.concatenate([mem, _time_enc(p, jnp.zeros_like(dt))], axis=1)
    q = q_in @ p["att_wq"]
    phi_n = _time_enc(p, nbr_dt.reshape(-1)).reshape(b, K, -1)
    kv_in = jnp.concatenate([nbr_mem, nbr_efeat, phi_n], axis=2)
    flat = kv_in.reshape(b * K, -1)
    k = (flat @ p["att_wk"]).reshape(b, K, -1)
    v = (flat @ p["att_wv"]).reshape(b, K, -1)
    att = kernels.temporal_attention(q, k, v, nbr_mask, DIMS["heads"])
    return jnp.tanh(jnp.concatenate([mem, att], axis=1) @ p["att_wo"] + p["att_bo"])


def _embed_jodie(p, mem, dt):
    return kernels.jodie_project(mem, dt, p["proj_w"])


def _embed_apan(p, mem, mail, mail_dt, mail_mask):
    b, K, _ = mail.shape
    q = mem @ p["att_wq"]
    phi_m = _time_enc(p, mail_dt.reshape(-1)).reshape(b, K, -1)
    kv_in = jnp.concatenate([mail, phi_m], axis=2)
    flat = kv_in.reshape(b * K, -1)
    k = (flat @ p["att_wk"]).reshape(b, K, -1)
    v = (flat @ p["att_wv"]).reshape(b, K, -1)
    att = kernels.temporal_attention(q, k, v, mail_mask, DIMS["heads"])
    pooled = kernels.masked_mean(v, mail_mask)
    cat = jnp.concatenate([mem, att, pooled], axis=1)
    return jnp.tanh(cat @ p["att_wo"] + p["att_bo"])


def _decode(p, h_src, h_dst):
    x = jnp.concatenate([h_src, h_dst], axis=1)
    hidden = jax.nn.relu(x @ p["dec_w1"] + p["dec_b1"])
    return (hidden @ p["dec_w2"] + p["dec_b2"])[:, 0]


def _splice(match, updated, store_mem):
    """Lag-one intra-step splice: take the freshly corrected state for
    vertices the previous batch just updated, else the store value."""
    idx = jnp.maximum(match, 0)
    sel = updated[idx]
    return jnp.where((match >= 0)[:, None], sel, store_mem)


# ---------------------------------------------------------------------------
# Data input specs (the step ABI; mirrored into the manifest for rust)
# ---------------------------------------------------------------------------


def data_input_specs(model: str, b: int):
    """Ordered [(name, shape, dtype)] of non-parameter inputs."""
    d, dm, de, K = DIMS["d_mem"], DIMS["d_msg"], DIMS["d_edge"], DIMS["k_nbr"]
    U = 2 * b
    specs = [
        # update rows (previous batch, src-side then dst-side; U = 2b)
        ("u_self_mem", (U, d), "f32"),
        ("u_other_mem", (U, d), "f32"),
        ("u_efeat", (U, de), "f32"),
        ("u_dt", (U,), "f32"),
        ("u_pred", (U, d), "f32"),
        ("u_wmask", (U,), "f32"),
        # 1.0 where the row's vertex has pending events inside the batch —
        # the rows whose measurement is noisy and gets filtered (Eq. 8)
        ("u_cmask", (U,), "f32"),
        # current (predicted) batch
        ("c_src_mem", (b, d), "f32"),
        ("c_dst_mem", (b, d), "f32"),
        ("c_neg_mem", (b, d), "f32"),
        ("c_src_match", (b,), "i32"),
        ("c_dst_match", (b,), "i32"),
        ("c_neg_match", (b,), "i32"),
        ("c_src_dt", (b,), "f32"),
        ("c_dst_dt", (b,), "f32"),
        ("c_neg_dt", (b,), "f32"),
    ]
    if model == "tgn":
        for role in ("src", "dst", "neg"):
            specs += [
                (f"n_{role}_mem", (b, K, d), "f32"),
                (f"n_{role}_efeat", (b, K, de), "f32"),
                (f"n_{role}_dt", (b, K), "f32"),
                (f"n_{role}_mask", (b, K), "f32"),
            ]
    elif model == "apan":
        for role in ("src", "dst", "neg"):
            specs += [
                (f"n_{role}_mail", (b, K, dm), "f32"),
                (f"n_{role}_dt", (b, K), "f32"),
                (f"n_{role}_mask", (b, K), "f32"),
            ]
    specs += [
        ("beta", (), "f32"),
        ("pres_on", (), "f32"),
    ]
    return specs


TRAIN_SCALARS = [("lr", (), "f32"), ("step_t", (), "f32")]


def output_specs(model: str, b: int, kind: str):
    """Ordered [(name, shape, dtype)] of step outputs after params/opt."""
    d, dm, demb = DIMS["d_mem"], DIMS["d_msg"], DIMS["d_emb"]
    U = 2 * b
    return [
        ("u_sbar", (U, d), "f32"),
        ("u_delta", (U, d), "f32"),
        ("u_msg", (U, dm), "f32"),
        ("pos_logit", (b,), "f32"),
        ("neg_logit", (b,), "f32"),
        # dynamic source embeddings, consumed by the node-classification head
        ("h_src", (b, demb), "f32"),
        ("loss", (), "f32"),
        ("bce", (), "f32"),
        ("coherence", (), "f32"),
    ]


# ---------------------------------------------------------------------------
# The fused step
# ---------------------------------------------------------------------------


def _forward(model: str, p: dict, data: dict):
    """Shared forward pass. Returns (loss, aux dict)."""
    # 1-2. messages + memory update for the previous batch's update rows
    msg = _message(p, data["u_self_mem"], data["u_other_mem"], data["u_efeat"], data["u_dt"])
    s_new = _memory_update(model, p, msg, data["u_self_mem"])

    # 3. PRES prediction-correction (Eq. 8), gated to pending-event rows:
    # rows without temporal discontinuity are clean measurements and keep
    # gamma = 1 (no-op). pres_on = 0 forces gamma = 1 everywhere -> STANDARD.
    g = jax.nn.sigmoid(p["gamma_raw"])[0]
    gate = data["pres_on"] * data["u_cmask"]
    gamma_rows = 1.0 - gate * (1.0 - g)
    s_bar, delta = kernels.pres_correct(s_new, data["u_pred"], gamma_rows)

    # 4. memory coherence of this batch (Eq. 10)
    coh = _coherence(data["u_self_mem"], s_bar, data["u_wmask"])

    # 5. lag-one splice into the current batch's memory rows
    mem_src = _splice(data["c_src_match"], s_bar, data["c_src_mem"])
    mem_dst = _splice(data["c_dst_match"], s_bar, data["c_dst_mem"])
    mem_neg = _splice(data["c_neg_match"], s_bar, data["c_neg_mem"])

    # 6. embeddings
    if model == "tgn":
        embed = lambda role, mem, dt: _embed_tgn(
            p, mem, dt,
            data[f"n_{role}_mem"], data[f"n_{role}_efeat"],
            data[f"n_{role}_dt"], data[f"n_{role}_mask"],
        )
    elif model == "apan":
        embed = lambda role, mem, dt: _embed_apan(
            p, mem, data[f"n_{role}_mail"], data[f"n_{role}_dt"], data[f"n_{role}_mask"]
        )
    else:
        embed = lambda role, mem, dt: _embed_jodie(p, mem, dt)
    h_src = embed("src", mem_src, data["c_src_dt"])
    h_dst = embed("dst", mem_dst, data["c_dst_dt"])
    h_neg = embed("neg", mem_neg, data["c_neg_dt"])

    # 7. temporal link prediction loss (self-supervised BCE)
    pos = _decode(p, h_src, h_dst)
    neg = _decode(p, h_src, h_neg)
    bce = jnp.mean(jax.nn.softplus(-pos) + jax.nn.softplus(neg))

    # 8. total objective (Eq. 10)
    loss = bce + data["beta"] * (1.0 - coh)
    aux = dict(
        u_sbar=s_bar, u_delta=delta, u_msg=msg,
        pos_logit=pos, neg_logit=neg, bce=bce, coherence=coh,
        h_src=h_src, h_dst=h_dst,
    )
    return loss, aux


def _adam(params: list, grads: list, m: list, v: list, lr, t):
    new_p, new_m, new_v = [], [], []
    bc1 = 1.0 - ADAM_B1**t
    bc2 = 1.0 - ADAM_B2**t
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
        vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * g * g
        step = lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + ADAM_EPS)
        new_p.append(p - step)
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v


def make_step(model: str, b: int, kind: str) -> tuple[Callable, list, list]:
    """Build the flat-argument step function for (model, batch, kind).

    kind: "train" (params + Adam state + data + lr/step_t) or
          "eval"  (params + data only; no parameter update).

    Returns (fn, input_specs, output_specs) where input_specs is the exact
    positional ABI: params, [m, v,] data..., [lr, step_t].
    All outputs are returned as one flat tuple:
    train: (*params', *m', *v', *step_outputs); eval: (*step_outputs,).
    """
    assert model in MODELS and kind in ("train", "eval")
    pspecs = param_specs(model)
    dspecs = data_input_specs(model, b)
    names = [n for n, _, _ in pspecs]
    n_params = len(pspecs)

    inputs = [(n, s, "f32") for n, s, _ in pspecs]
    if kind == "train":
        inputs += [(f"adam_m_{n}", s, "f32") for n, s, _ in pspecs]
        inputs += [(f"adam_v_{n}", s, "f32") for n, s, _ in pspecs]
    inputs += dspecs
    if kind == "train":
        inputs += TRAIN_SCALARS

    aux_order = [n for n, _, _ in output_specs(model, b, kind)]

    def unpack_data(flat_data):
        return {n: a for (n, _, _), a in zip(dspecs, flat_data)}

    if kind == "eval":

        def fn(*args):
            params = {n: a for n, a in zip(names, args[:n_params])}
            data = unpack_data(args[n_params:])
            loss, aux = _forward(model, params, data)
            return tuple(aux[n] if n != "loss" else loss for n in aux_order)

    else:

        def fn(*args):
            plist = list(args[:n_params])
            m = list(args[n_params : 2 * n_params])
            v = list(args[2 * n_params : 3 * n_params])
            data = unpack_data(args[3 * n_params : 3 * n_params + len(dspecs)])
            lr, step_t = args[3 * n_params + len(dspecs) :]

            def loss_fn(pl):
                params = {n: a for n, a in zip(names, pl)}
                return _forward(model, params, data)

            (loss_unused, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(plist)
            new_p, new_m, new_v = _adam(plist, grads, m, v, lr, step_t)
            return tuple(new_p) + tuple(new_m) + tuple(new_v) + tuple(
                aux[n] if n != "loss" else loss_unused for n in aux_order
            )

    outs = output_specs(model, b, kind)
    if kind == "train":
        outs = (
            [(n, s, "f32") for n, s, _ in pspecs]
            + [(f"adam_m_{n}", s, "f32") for n, s, _ in pspecs]
            + [(f"adam_v_{n}", s, "f32") for n, s, _ in pspecs]
            + outs
        )
    return fn, inputs, outs


# ---------------------------------------------------------------------------
# Node-classification head (Table 2)
# ---------------------------------------------------------------------------


def _clf_forward(p, emb):
    hidden = jax.nn.relu(emb @ p["clf_w1"] + p["clf_b1"])
    return (hidden @ p["clf_w2"] + p["clf_b2"])[:, 0]


def make_clf_step(kind: str) -> tuple[Callable, list, list]:
    """Classifier train/eval step over frozen dynamic embeddings.

    train inputs: params(4), m(4), v(4), emb [b, d_emb], labels [b],
                  weight [b] (masks padding rows), lr, step_t.
    eval inputs:  params(4), emb.
    """
    b = DIMS["clf_batch"]
    demb = DIMS["d_emb"]
    pspecs = clf_param_specs()
    names = [n for n, _, _ in pspecs]
    n_params = len(pspecs)

    if kind == "eval":
        inputs = [(n, s, "f32") for n, s, _ in pspecs] + [("emb", (b, demb), "f32")]
        outs = [("logits", (b,), "f32")]

        def fn(*args):
            p = {n: a for n, a in zip(names, args[:n_params])}
            return (_clf_forward(p, args[n_params]),)

    else:
        inputs = (
            [(n, s, "f32") for n, s, _ in pspecs]
            + [(f"adam_m_{n}", s, "f32") for n, s, _ in pspecs]
            + [(f"adam_v_{n}", s, "f32") for n, s, _ in pspecs]
            + [
                ("emb", (b, demb), "f32"),
                ("labels", (b,), "f32"),
                ("weight", (b,), "f32"),
            ]
            + TRAIN_SCALARS
        )
        outs = (
            [(n, s, "f32") for n, s, _ in pspecs]
            + [(f"adam_m_{n}", s, "f32") for n, s, _ in pspecs]
            + [(f"adam_v_{n}", s, "f32") for n, s, _ in pspecs]
            + [("loss", (), "f32"), ("logits", (b,), "f32")]
        )

        def fn(*args):
            plist = list(args[:n_params])
            m = list(args[n_params : 2 * n_params])
            v = list(args[2 * n_params : 3 * n_params])
            emb, labels, weight, lr, step_t = args[3 * n_params :]

            def loss_fn(pl):
                p = {n: a for n, a in zip(names, pl)}
                logits = _clf_forward(p, emb)
                per = labels * jax.nn.softplus(-logits) + (1.0 - labels) * jax.nn.softplus(logits)
                return jnp.sum(per * weight) / jnp.maximum(jnp.sum(weight), 1.0), logits

            (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(plist)
            new_p, new_m, new_v = _adam(plist, grads, m, v, lr, step_t)
            return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss, logits)

    return fn, inputs, outs


# ---------------------------------------------------------------------------
# Example-argument helper for lowering / tests
# ---------------------------------------------------------------------------


def example_args(input_specs, seed: int = 0):
    """ShapeDtypeStructs for jit lowering (no values materialized)."""
    out = []
    for _, shape, dtype in input_specs:
        out.append(
            jax.ShapeDtypeStruct(shape, jnp.int32 if dtype == "i32" else jnp.float32)
        )
    return out
