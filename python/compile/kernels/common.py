"""Shared Pallas helpers for the PRES kernel suite.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode lowers each kernel to plain HLO ops
that the rust runtime can compile and run. On a real TPU the same
``pallas_call`` bodies lower to Mosaic; the BlockSpecs below are written for
that target (VMEM-sized batch blocks, MXU-aligned feature widths — see
DESIGN.md §5).
"""

from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl

# Batch-block size used by all kernels. 128 rows x <=384 f32 features keeps
# each kernel's working set well under 1 MB of VMEM while feeding the MXU
# (128x128 systolic array) full tiles on the row dimension.
MAX_BLOCK_B = 128

INTERPRET = True  # CPU PJRT: interpret-mode only. See module docstring.


def pick_block_b(b: int) -> int:
    """Largest divisor of ``b`` that is <= MAX_BLOCK_B.

    The compiled batch sizes (25, 50, 100, 200, ..., 1600) all admit a
    divisor of 100 or are themselves <= 128; arbitrary test sizes fall back
    to smaller divisors (worst case 1 — still correct, just more grid steps).
    """
    if b <= MAX_BLOCK_B:
        return b
    for cand in range(MAX_BLOCK_B, 0, -1):
        if b % cand == 0:
            return cand
    return 1


def call(kernel, out_shape, grid, in_specs, out_specs):
    """``pl.pallas_call`` with the suite-wide interpret setting."""
    return pl.pallas_call(
        kernel,
        out_shape=out_shape,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        interpret=INTERPRET,
    )


def row_spec(block_b: int, *feature_dims: int):
    """BlockSpec for a tensor blocked over dim 0, full width elsewhere."""
    shape = (block_b, *feature_dims)
    ndim = len(shape)

    def index_map(i, _nd=ndim):
        return (i,) + (0,) * (_nd - 1)

    return pl.BlockSpec(shape, index_map)


def full_spec(*dims: int):
    """BlockSpec for a tensor replicated to every grid step (weights)."""
    ndim = len(dims)

    def index_map(i, _nd=ndim):
        return (0,) * _nd

    return pl.BlockSpec(tuple(dims), index_map)


def ref_vjp(ref_fn):
    """Wrap a pallas forward with a custom VJP whose backward runs the
    pure-jnp reference formula.

    Pallas has no general autodiff; the forward hot path stays a kernel
    while XLA fuses the reference backward. ``ref_fn`` must be numerically
    identical to the kernel (enforced by python/tests/test_kernels.py).
    """

    def decorator(pallas_fn):
        @jax.custom_vjp
        @functools.wraps(pallas_fn)
        def wrapped(*args):
            return pallas_fn(*args)

        def fwd(*args):
            return pallas_fn(*args), args

        def bwd(args, ct):
            _, pullback = jax.vjp(ref_fn, *args)
            return pullback(ct)

        wrapped.defvjp(fwd, bwd)
        return wrapped

    return decorator
