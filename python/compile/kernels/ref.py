"""Pure-jnp oracle for every Pallas kernel in the suite.

These are the ground truth the kernels are tested against
(python/tests/test_kernels.py, hypothesis sweeps) and the formulas the
custom-VJP backward passes differentiate through (kernels/common.py).
Keep each function a line-for-line mathematical statement of the op.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def time_encode(dt, omega, phi):
    """Bochner-style functional time encoding: cos(dt * omega + phi).

    dt: [n] non-negative time deltas; omega, phi: [D]. Returns [n, D].
    (Xu et al. 2020 / TGN's learnable time encoder.)
    """
    return jnp.cos(dt[:, None] * omega[None, :] + phi[None, :])


def fused_gru(x, h, wx, wh, bias):
    """cuDNN-layout GRU cell (single fused gate bank per operand).

    x: [b, dx] input (message), h: [b, dh] previous state,
    wx: [dx, 3*dh], wh: [dh, 3*dh], bias: [2, 3*dh] (input bias, hidden bias).
    Gate order along the 3*dh axis: reset | update | candidate.
    Returns [b, dh].
    """
    dh = h.shape[1]
    gx = x @ wx + bias[0][None, :]
    gh = h @ wh + bias[1][None, :]
    r = jax.nn.sigmoid(gx[:, :dh] + gh[:, :dh])
    z = jax.nn.sigmoid(gx[:, dh : 2 * dh] + gh[:, dh : 2 * dh])
    n = jnp.tanh(gx[:, 2 * dh :] + r * gh[:, 2 * dh :])
    return (1.0 - z) * n + z * h


def temporal_attention(q, k, v, mask, num_heads):
    """Multi-head scaled-dot attention of one query over K neighbors.

    q: [b, H*dk], k: [b, K, H*dk], v: [b, K, H*dv], mask: [b, K] in {0,1}.
    Fully-masked rows (no temporal neighbors yet) return zeros.
    Returns [b, H*dv].
    """
    b, K, hdk = k.shape
    dv = v.shape[2] // num_heads
    dk = hdk // num_heads
    qh = q.reshape(b, num_heads, dk)
    kh = k.reshape(b, K, num_heads, dk)
    vh = v.reshape(b, K, num_heads, dv)
    scores = jnp.einsum("bhd,bkhd->bhk", qh, kh) / jnp.sqrt(jnp.float32(dk))
    scores = scores + (1.0 - mask[:, None, :]) * jnp.float32(-1e9)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    expw = jnp.exp(scores) * mask[:, None, :]
    denom = jnp.sum(expw, axis=-1, keepdims=True)
    att = expw / jnp.maximum(denom, 1e-9)
    out = jnp.einsum("bhk,bkhd->bhd", att, vh)
    return out.reshape(b, num_heads * dv)


def pres_correct(s_new, s_pred, gamma):
    """PRES correction (paper Eq. 8) + GMM innovation (Eq. 9 input).

    s_bar = gamma * s_new + (1 - gamma) * s_pred,  delta = s_bar - s_new.

    gamma: [b] per-row fusion weight. The coordinator gates the correction
    to rows whose vertex actually has pending events in the batch (the
    "noisy measurements" of the paper's filter); clean rows get gamma = 1
    and pass through untouched. delta is the innovation the rust-side GMM
    trackers accumulate. Returns (s_bar [b, d], delta [b, d]).
    """
    g = gamma[:, None]
    s_bar = g * s_new + (1.0 - g) * s_pred
    delta = s_bar - s_new
    return s_bar, delta


def jodie_project(s, dt, w):
    """JODIE's time-projected embedding: h = s * (1 + dt * w).

    s: [b, d] memory, dt: [b] elapsed time, w: [d] learnable projection.
    """
    return s * (1.0 + dt[:, None] * w[None, :])


def masked_mean(x, mask):
    """Masked mean over axis 1 (APAN mailbox aggregation).

    x: [b, K, d], mask: [b, K] in {0,1}. Empty mailboxes yield zeros.
    """
    num = jnp.sum(x * mask[:, :, None], axis=1)
    den = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
    return num / den
