"""Pallas kernel: PRES prediction-correction fusion (paper Eq. 8 + Eq. 9 input).

s_bar = gamma * s_new + (1 - gamma) * s_pred and the innovation
delta = s_bar - s_new are produced in one elementwise pass. gamma is a
*learnable* scalar (sigmoid-squashed upstream so it stays in [0, 1]; the
paper's gamma), so this kernel sits on the differentiated path — the
custom VJP routes gradients to s_new, s_pred and gamma via the reference
formula.

The rust coordinator consumes delta to update the per-vertex GMM trackers
(Eq. 9) and writes s_bar back into the memory store.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common, ref


def _kernel(s_new_ref, s_pred_ref, gamma_ref, sbar_ref, delta_ref):
    s_new = s_new_ref[...]
    s_pred = s_pred_ref[...]
    g = gamma_ref[...][:, None]
    s_bar = g * s_new + (1.0 - g) * s_pred
    sbar_ref[...] = s_bar
    delta_ref[...] = s_bar - s_new


@common.ref_vjp(ref.pres_correct)
def pres_correct(s_new, s_pred, gamma):
    """s_new/s_pred: [b, d], gamma: [b] per row -> (s_bar, delta) [b, d].

    gamma rows equal to 1 make the correction a no-op for that row — the
    coordinator uses this to gate the filter onto pending-event rows only.
    """
    b, d = s_new.shape
    bb = common.pick_block_b(b)
    out = jax.ShapeDtypeStruct((b, d), jnp.float32)
    return common.call(
        _kernel,
        out_shape=(out, out),
        grid=(b // bb,),
        in_specs=[
            common.row_spec(bb, d),
            common.row_spec(bb, d),
            common.row_spec(bb),
        ],
        out_specs=(common.row_spec(bb, d), common.row_spec(bb, d)),
    )(s_new, s_pred, gamma)
