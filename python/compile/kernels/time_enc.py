"""Pallas kernel: functional time encoding phi(dt) = cos(dt * omega + phi).

The time encoder is evaluated 2+3*(K+1) times per training step (every
message and every attention key carries one), which made it a named hot
spot in TGOpt (Wang & Mendis 2023); fusing it keeps the encode on-chip.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common, ref


def _kernel(dt_ref, omega_ref, phi_ref, o_ref):
    dt = dt_ref[...]
    o_ref[...] = jnp.cos(dt[:, None] * omega_ref[...][None, :] + phi_ref[...][None, :])


@common.ref_vjp(ref.time_encode)
def time_encode(dt, omega, phi):
    """dt: [n], omega/phi: [D] -> [n, D]. See ref.time_encode."""
    n = dt.shape[0]
    d = omega.shape[0]
    bb = common.pick_block_b(n)
    return common.call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        grid=(n // bb,),
        in_specs=[
            common.row_spec(bb),
            common.full_spec(d),
            common.full_spec(d),
        ],
        out_specs=common.row_spec(bb, d),
    )(dt, omega, phi)
