"""Pallas kernel: fused temporal neighbor attention (the EMB module's hot spot).

One query per batch vertex attends over its K sampled temporal neighbors
(keys/values carry neighbor memory, edge features and time encodings,
projected upstream). Scores, mask, numerically-stable softmax and the
weighted value sum are fused in one kernel — the [b, H, K] score tensor
never round-trips to HBM.

The paper's GPU baselines (TGL) do this with a threadblock per
destination-node chunk; here the same schedule is the pallas grid over
batch blocks (DESIGN.md §5).

VMEM per block (block_b=128, K=10, H=2, dk=dv=32, f32):
  q 32KB + k 320KB + v 320KB + mask 5KB + out 32KB ~ 0.69 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import common, ref


def _kernel(q_ref, k_ref, v_ref, m_ref, o_ref, *, num_heads: int):
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    mask = m_ref[...]
    b, K, hdk = k.shape
    dk = hdk // num_heads
    dv = v.shape[2] // num_heads
    qh = q.reshape(b, num_heads, dk)
    kh = k.reshape(b, K, num_heads, dk)
    vh = v.reshape(b, K, num_heads, dv)
    scores = jnp.einsum("bhd,bkhd->bhk", qh, kh) / jnp.sqrt(jnp.float32(dk))
    scores = scores + (1.0 - mask[:, None, :]) * jnp.float32(-1e9)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    expw = jnp.exp(scores) * mask[:, None, :]
    denom = jnp.sum(expw, axis=-1, keepdims=True)
    att = expw / jnp.maximum(denom, 1e-9)
    o_ref[...] = jnp.einsum("bhk,bkhd->bhd", att, vh).reshape(b, num_heads * dv)


def _make(num_heads: int):
    ref_fn = functools.partial(ref.temporal_attention, num_heads=num_heads)

    @common.ref_vjp(lambda q, k, v, m: ref_fn(q, k, v, m))
    def attn(q, k, v, mask):
        b, K, hdk = k.shape
        hdv = v.shape[2]
        bb = common.pick_block_b(b)
        return common.call(
            functools.partial(_kernel, num_heads=num_heads),
            out_shape=jax.ShapeDtypeStruct((b, hdv), jnp.float32),
            grid=(b // bb,),
            in_specs=[
                common.row_spec(bb, hdk),
                common.row_spec(bb, K, hdk),
                common.row_spec(bb, K, hdv),
                common.row_spec(bb, K),
            ],
            out_specs=common.row_spec(bb, hdv),
        )(q, k, v, mask)

    return attn


_CACHE: dict[int, object] = {}


def temporal_attention(q, k, v, mask, num_heads: int):
    """q: [b, H*dk], k: [b, K, H*dk], v: [b, K, H*dv], mask: [b, K] -> [b, H*dv].

    See ref.temporal_attention. ``num_heads`` is static (one custom-vjp
    closure per head count, cached).
    """
    if num_heads not in _CACHE:
        _CACHE[num_heads] = _make(num_heads)
    return _CACHE[num_heads](q, k, v, mask)
