"""L1: Pallas kernels for the MDGNN hot spots (interpret-mode on CPU).

Every kernel has a pure-jnp oracle in ref.py; correctness is enforced by
python/tests/test_kernels.py (hypothesis sweeps over shapes) and backward
passes go through the oracle formulas via custom VJP (see common.ref_vjp).
"""

from .attention import temporal_attention
from .gru import fused_gru
from .jodie import jodie_project
from .mailbox import masked_mean
from .pres import pres_correct
from .time_enc import time_encode

__all__ = [
    "temporal_attention",
    "fused_gru",
    "jodie_project",
    "masked_mean",
    "pres_correct",
    "time_encode",
]
